"""Paper-table reproductions (Experiments 1 & 2 analogues, §11–§12).

One function per reported table/figure:

  * ``bench_algorithms``  — Fig.5/6 + the postings/data-read tables: average
    query time, postings read, bytes read for SE1 and SE2.1–SE2.4 over
    stop-lemma queries on a Zipf corpus.
  * ``bench_duplicates``  — §12's duplicate-lemma case ("to be or not to be"):
    SE2.3 vs SE2.4 work (intermediate records / time).
  * ``bench_vectorized``  — the TPU-native path (batched cover) vs the scalar
    Combiner, and the Pallas kernel in interpret mode vs the jnp ref.

The absolute times are CPU-container numbers; the paper's CLAIMS are about
ratios and orderings, which is what EXPERIMENTS.md §Paper records.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.baselines import (
    se1_ordinary,
    se21_main_cell,
    se22_intermediate,
    se23_optimized,
)
from repro.core.combiner import se24_combiner
from repro.core.keys import Subquery, expand_subqueries
from repro.core.lemma import Lemmatizer, LemmaType
from repro.core.postings import QueryStats
from repro.index import build_indexes, synthesize_corpus
from repro.search.vectorized import VectorizedEngine

ALGOS = {
    "SE1": se1_ordinary,
    "SE2.1": se21_main_cell,
    "SE2.2": se22_intermediate,
    "SE2.3": se23_optimized,
    "SE2.4": se24_combiner,
}


def _stop_lemma_queries(store, idx, n_queries=30, lens=(3, 4, 5), seed=3):
    """Sample stop-lemma-only queries from real document windows (so they
    have non-trivial result sets), mirroring the paper's query selection."""
    rng = np.random.default_rng(seed)
    queries: list[Subquery] = []
    docs = store.documents
    while len(queries) < n_queries:
        d = docs[int(rng.integers(len(docs)))]
        if len(d) < 12:
            continue
        start = int(rng.integers(0, len(d) - 8))
        want = int(rng.choice(lens))
        lemmas = []
        for lem_tuple in d.lemma_stream[start : start + 10]:
            l = lem_tuple[0]
            if idx.fl.lemma_type(l) == LemmaType.STOP:
                lemmas.append(l)
            if len(lemmas) == want:
                break
        if len(lemmas) == want:
            queries.append(Subquery(tuple(lemmas)))
    return queries


def build_benchmark_index(n_docs=150, doc_len=220, seed=13):
    store = synthesize_corpus(n_docs=n_docs, doc_len=doc_len, vocab_size=3000,
                              seed=seed)
    idx = build_indexes(store, sw_count=80, fu_count=300, max_distance=5)
    return store, idx


def bench_algorithms(n_queries=30):
    store, idx = build_benchmark_index()
    queries = _stop_lemma_queries(store, idx, n_queries=n_queries)
    rows = []
    for name, fn in ALGOS.items():
        total = QueryStats()
        t0 = time.perf_counter()
        for sub in queries:
            _, stats = fn(sub, idx)
            total.merge(stats)
        dt = time.perf_counter() - t0
        rows.append({
            "algorithm": name,
            "avg_ms": 1000 * dt / len(queries),
            "avg_postings": total.postings_read / len(queries),
            "avg_kb": total.bytes_read / 1024 / len(queries),
            "avg_intermediate": total.intermediate_records / len(queries),
            "avg_results": total.results / len(queries),
        })
    return rows


def bench_duplicates():
    """§12: 'to be or not to be' — SE2.4's duplicate handling vs SE2.3."""
    store, idx = build_benchmark_index()
    lem = Lemmatizer()
    sub = expand_subqueries("to be or not to be", lem)[0]
    out = {}
    for name in ("SE2.1", "SE2.2", "SE2.3", "SE2.4"):
        t0 = time.perf_counter()
        for _ in range(5):
            _, stats = ALGOS[name](sub, idx)
        out[name] = {
            "ms": 1000 * (time.perf_counter() - t0) / 5,
            "postings": stats.postings_read,
            "intermediate": stats.intermediate_records,
            "results": stats.results,
        }
    return out


def bench_vectorized():
    store, idx = build_benchmark_index()
    queries = _stop_lemma_queries(store, idx, n_queries=10)
    out = []
    eng_ref = VectorizedEngine(idx, use_kernel=False)
    eng_k = VectorizedEngine(idx, use_kernel=True)
    for name, runner in [
        ("scalar_combiner", lambda s: se24_combiner(s, idx)),
        ("vectorized_jnp", eng_ref.search_subquery),
        ("pallas_interpret", eng_k.search_subquery),
    ]:
        # full warmup pass: deployed serving uses fixed shape budgets, so
        # steady-state (jit-cached) latency is the meaningful number
        for sub in queries:
            runner(sub)
        t0 = time.perf_counter()
        n_results = 0
        for sub in queries:
            r, _ = runner(sub)
            n_results += len(r)
        out.append({
            "engine": name,
            "avg_ms": 1000 * (time.perf_counter() - t0) / len(queries),
            "results": n_results,
        })
    return out


# ---------------------------------------------------------------------------
# fused batched serving vs the seed per-subquery vectorized path
# ---------------------------------------------------------------------------


def _seed_search_subquery(idx, sub, doc_len=512):
    """The SEED per-subquery serving path, kept verbatim as the benchmark
    baseline: dense host-side [B, L, doc_len] occupancy, ONE device call per
    subquery, per-document Python fragment readout."""
    import jax.numpy as jnp

    from repro.core.keys import select_keys
    from repro.core.postings import SearchResult
    from repro.core.window import results_from_cover
    from repro.kernels.ops import proximity_search_scores

    keys = select_keys(sub, idx.fl)
    lemmas = sub.unique_lemmas()
    lid = {l: i for i, l in enumerate(lemmas)}
    L = len(lemmas)
    mult_map = sub.multiplicity()
    mult = np.array([mult_map[l] for l in lemmas], dtype=np.int32)
    ev_doc, ev_pos, ev_lem = [], [], []
    for key in keys:
        rows = np.asarray(idx.key_postings(key.components))
        if not len(rows):
            continue
        comps, stars = key.components, key.starred
        for slot in range(len(comps)):
            if stars[slot]:
                continue
            pos = rows[:, 1] if slot == 0 else rows[:, 1] + rows[:, 1 + slot]
            ev_doc.append(rows[:, 0])
            ev_pos.append(pos)
            ev_lem.append(np.full(len(rows), lid[comps[slot]], np.int32))
    if ev_doc:
        doc_a = np.concatenate(ev_doc)
        pos_a = np.concatenate(ev_pos)
        lem_a = np.concatenate(ev_lem)
        ok = (pos_a >= 0) & (pos_a < doc_len)
        doc_a, pos_a, lem_a = doc_a[ok], pos_a[ok], lem_a[ok]
        docs, doc_idx = np.unique(doc_a, return_inverse=True)
    else:
        docs = np.empty((0,), np.int32)
    b_real = max(1, len(docs))
    B = 1 << (b_real - 1).bit_length()
    occ_t = np.zeros((B, L, doc_len), dtype=np.int32)
    doc_ids = np.full((B,), -1, dtype=np.int32)
    if len(docs):
        occ_t[doc_idx, lem_a, pos_a] = 1
        doc_ids[: len(docs)] = docs
    mult_b = np.broadcast_to(mult, (B, L))
    emit, start, _ = proximity_search_scores(
        jnp.asarray(occ_t), jnp.asarray(mult_b), idx.max_distance
    )
    emit_np, start_np = np.asarray(emit), np.asarray(start)
    results = []
    for i, doc in enumerate(doc_ids.tolist()):
        if doc < 0:
            continue
        for d, s, e in results_from_cover(doc, emit_np[i], start_np[i]):
            results.append(SearchResult(doc_id=d, start=s, end=e))
    return results


def bench_serving(n_queries=8, subs_per_query=2, repeats=3):
    """Old per-subquery serving vs the fused query-at-a-time batch.

    ``n_queries`` multi-subquery queries are served (a) through the seed
    path — one device dispatch + host readout per subquery — and (b) as ONE
    fused device program for the whole batch.  Reports steady-state
    (jit-cached) us per served query.
    """
    from repro.search import fused

    store, idx = build_benchmark_index()
    subs = _stop_lemma_queries(
        store, idx, n_queries=n_queries * subs_per_query, seed=5
    )
    batch = [
        subs[i * subs_per_query : (i + 1) * subs_per_query]
        for i in range(n_queries)
    ]
    eng = VectorizedEngine(idx)

    # warmup both paths (fixed shape budgets -> steady-state latency)
    for q in batch:
        for sub in q:
            _seed_search_subquery(idx, sub)
    eng.search_query_batch(batch)

    # best-of-rounds: steady-state serving latency, robust to machine noise
    seed_rounds = []
    seed_results = 0
    for _ in range(repeats):
        t0 = time.perf_counter()
        seed_results = 0
        for q in batch:
            got = set()
            for sub in q:
                got.update(_seed_search_subquery(idx, sub))
            seed_results += len(got)
        seed_rounds.append(time.perf_counter() - t0)
    seed_us = 1e6 * min(seed_rounds) / n_queries

    fused.reset_dispatch_count()
    fused_rounds = []
    fused_results = 0
    for _ in range(repeats):
        t0 = time.perf_counter()
        res, _ = eng.search_query_batch(batch)
        # offset arithmetic — counting must not force the §15.1 lazy
        # SearchResult materialization inside the timed region
        fused_results = sum(res.n_results(qi) for qi in range(n_queries))
        fused_rounds.append(time.perf_counter() - t0)
    fused_us = 1e6 * min(fused_rounds) / n_queries
    dispatches = fused.dispatch_count() / repeats

    # phase attribution (DESIGN.md §15.3): one instrumented pass splits the
    # batch into plan / pack / H2D / dispatch / compute / readout µs —
    # disjoint brackets that sum to the serial batch wall time
    phases: dict = {}
    prev = fused.collect_phases(phases)
    eng.search_query_batch(batch)
    fused.collect_phases(prev)
    phases_us = {k: sum(v) for k, v in phases.items()}

    return {
        "n_queries": n_queries,
        "subs_per_query": subs_per_query,
        "per_subquery_seed": {"us_per_call": seed_us, "results": seed_results},
        "fused_batch": {
            "us_per_call": fused_us,
            "results": fused_results,
            "device_dispatches_per_batch": dispatches,
            "phases_us_per_batch": phases_us,
            "readout_fraction": readout_fraction(phases_us),
        },
        "speedup": seed_us / max(fused_us, 1e-9),
    }


def readout_fraction(phases_us: dict) -> float:
    """Share of one batch's phase-bracketed wall time spent in host readout
    (DESIGN.md §15.3) — the §15.1 device-side assembly keeps this under 10%
    (``readout_fraction_GATE`` in ``benchmarks/run.py``)."""
    total = sum(phases_us.values())
    return phases_us.get("readout_us", 0.0) / total if total > 0 else 0.0


def bench_serving_results_match(serving: dict) -> bool:
    """Acceptance guard: both paths must return the same fragment count."""
    return (
        serving["per_subquery_seed"]["results"]
        == serving["fused_batch"]["results"]
    )


# ---------------------------------------------------------------------------
# device-resident posting arena vs the host-pack path (DESIGN.md §13)
# ---------------------------------------------------------------------------


def bench_arena(quick=False, n_queries=8, subs_per_query=2, repeats=5):
    """Arena-resident serving vs the host-pack path on an FU/stop-heavy
    batch (DESIGN.md §13.5) — the paper's expensive case: every query is
    drawn over frequently-occurring words, so per-key posting lists are
    large, occurrence ranks are deep (the host pack's ``[R, L, K]`` table is
    at its worst) and per-batch host assembly dominates.

    Both paths serve the IDENTICAL (query, subquery) batch through
    ``serve_query_batch``; the arena path ships only descriptors against
    posting columns uploaded once per index generation.  Reports
    steady-state best-of-``repeats`` µs per served query for each path, the
    per-phase attribution, the residency statistics, and the fragment-set
    equality verdict (``results_match`` — a CI gate, with
    ``device_dispatches_per_batch == 1`` for the resident path).
    """
    from repro.core.postings import QueryStats
    from repro.search import fused
    from repro.search.arena import PostingArena

    n_docs, doc_len = (150, 220) if quick else (300, 300)
    store = synthesize_corpus(n_docs=n_docs, doc_len=doc_len, vocab_size=3000,
                              seed=13)
    idx = build_indexes(store, sw_count=80, fu_count=300, max_distance=5)
    subs = _stop_lemma_queries(
        store, idx, n_queries=n_queries * subs_per_query, seed=5
    )
    work = [
        [(s, idx) for s in subs[i * subs_per_query : (i + 1) * subs_per_query]]
        for i in range(n_queries)
    ]

    arena = PostingArena(budget_bytes=1 << 30)
    t0 = time.perf_counter()
    res = arena.acquire(idx, 0)
    upload_sec = time.perf_counter() - t0
    residencies = {id(idx): res}

    # warm both paths (fixed shape budgets -> steady-state latency)
    fused.serve_query_batch(work, max_distance=idx.max_distance)
    fused.serve_query_batch(
        work, max_distance=idx.max_distance, residencies=residencies
    )

    out = {}
    for name, kwargs in (("host_pack", {}), ("arena", {"residencies": residencies})):
        rounds = []
        result = None
        for _ in range(repeats):
            t0 = time.perf_counter()
            result = fused.serve_query_batch(
                work, max_distance=idx.max_distance, **kwargs
            )
            rounds.append(time.perf_counter() - t0)
        phases: dict = {}
        prev = fused.collect_phases(phases)
        fused.serve_query_batch(work, max_distance=idx.max_distance, **kwargs)
        fused.collect_phases(prev)
        phases_us = {k: sum(v) for k, v in phases.items()}
        out[name] = {
            "us_per_query": 1e6 * min(rounds) / n_queries,
            "results": sum(len(p) for p in result.per_query),
            "fragments": [sorted((r.doc_id, r.start, r.end) for r in p)
                          for p in result.per_query],
            "phases_us_per_batch": phases_us,
            "readout_fraction": readout_fraction(phases_us),
        }

    stats = QueryStats()
    fused.reset_dispatch_count()
    fused.serve_query_batch(
        work, max_distance=idx.max_distance, residencies=residencies,
        stats=stats, batch_stats=stats,
    )
    dispatches = fused.dispatch_count()
    match = out["host_pack"]["fragments"] == out["arena"]["fragments"]
    for v in out.values():
        v.pop("fragments")  # equality verdict recorded; keep the JSON small
    m = arena.metrics()
    key_lookups = stats.arena_hits + stats.arena_misses
    # release the device buffers before returning: later bench sections
    # (indexing/persistence) time memory-sensitive paths, and ~150 MB of
    # lingering arena buffers measurably skews them in one-process runs
    import gc

    arena.release()
    del res, residencies
    gc.collect()
    return {
        "n_docs": n_docs,
        "doc_len": doc_len,
        "n_queries": n_queries,
        "host_pack": out["host_pack"],
        "arena_path": out["arena"],
        "speedup": out["host_pack"]["us_per_query"]
        / max(out["arena"]["us_per_query"], 1e-9),
        "results_match": bool(match),
        "device_dispatches_per_batch": dispatches,
        "arena": {
            "upload_sec": upload_sec,
            "resident_bytes": m["arena_bytes"],
            "resident_families": m["arena_entries"],
            # per-batch key residency: keys served from device extents over
            # all key lookups (misses = host-pack fallbacks)
            "hit_rate": stats.arena_hits / key_lookups if key_lookups else 0.0,
            "key_hits": stats.arena_hits,
            "key_misses": stats.arena_misses,
            "h2d_bytes_per_batch": stats.h2d_bytes,
        },
    }


# ---------------------------------------------------------------------------
# §15.2 pipelined dispatch + §15.4 serving-program roofline
# ---------------------------------------------------------------------------


def bench_overlap(n_queries=16, max_batch=4, repeats=3):
    """Two-deep pipelined micro-batch loop vs the serial submit→finish loop
    (DESIGN.md §15.2).

    The same request slate runs through ``search_many`` on fresh frontends
    with ``pipeline=True`` (batch N+1's plan/pack/H2D overlaps batch N's
    device compute) and ``pipeline=False`` (each chunk fully finished before
    the next is planned).  Reports best-of-``repeats`` µs per query for both
    modes, the overlap speedup, and the response-equality verdict — the two
    drivers must produce byte-identical responses in admission order
    (``overlap_results_MISMATCH`` gates ``benchmarks/run.py``).
    """
    from repro.search.frontend import SearchRequest, ServingFrontend

    store, idx = build_benchmark_index()
    subs = _stop_lemma_queries(store, idx, n_queries=n_queries * 2, seed=11)
    queries = list(dict.fromkeys(" ".join(s.lemmas) for s in subs))[:n_queries]
    requests = [SearchRequest(q, top_k=16) for q in queries]

    def run(pipeline):
        # jit-warm on a throwaway frontend; timed rounds use fresh frontends
        # so result/posting caches are cold and only the loop shape differs
        ServingFrontend(
            idx, lemmatizer=store.lemmatizer, max_batch=max_batch,
            pipeline=pipeline,
        ).search_many(requests)
        best = None
        responses = None
        for _ in range(repeats):
            fe = ServingFrontend(
                idx, lemmatizer=store.lemmatizer, max_batch=max_batch,
                pipeline=pipeline,
            )
            t0 = time.perf_counter()
            responses = fe.search_many(requests)
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        return best, responses

    serial_sec, serial_resp = run(False)
    pipe_sec, pipe_resp = run(True)

    def key(resp):
        return [
            (d.doc_id, d.score, tuple((f.start, f.end) for f in d.fragments))
            for d in resp.docs
        ]

    match = len(serial_resp) == len(pipe_resp) and all(
        key(a) == key(b) for a, b in zip(serial_resp, pipe_resp)
    )
    return {
        "n_queries": len(queries),
        "max_batch": max_batch,
        "serial_us_per_query": 1e6 * serial_sec / len(queries),
        "pipelined_us_per_query": 1e6 * pipe_sec / len(queries),
        "overlap_speedup": serial_sec / max(pipe_sec, 1e-9),
        "results_match": bool(match),
    }


def bench_roofline(n_queries=8, subs_per_query=2, out_dir="artifacts/serving_hlo"):
    """Compiled-program roofline for the serving device programs (DESIGN.md
    §15.4).

    Lowers the EXACT fused and arena programs a representative batch would
    dispatch (``lower_query_batch`` / ``lower_arena_batch``), compiles them,
    and feeds the optimized HLO to ``launch/hlo_analysis.analyze_hlo`` →
    ``benchmarks/roofline.program_roofline``.  The HLO text is written under
    ``out_dir`` (shipped as a CI artifact) so an intensity drop against the
    committed baseline can be diffed down to the instruction.  Serving is
    expected to sit deep on the memory-bound side of the ridge — a dominant
    ``compute`` term or an hbm_bytes spike flags an accidental dense
    materialization.
    """
    import gc
    from pathlib import Path

    from repro.core.keys import select_keys
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.search import fused
    from repro.search.arena import (
        PostingArena,
        lower_arena_batch,
        plan_arena_batch,
    )

    from benchmarks.roofline import program_roofline

    store, idx = build_benchmark_index()
    subs = _stop_lemma_queries(
        store, idx, n_queries=n_queries * subs_per_query, seed=5
    )
    work = [
        [(s, idx) for s in subs[i * subs_per_query : (i + 1) * subs_per_query]]
        for i in range(n_queries)
    ]

    path = Path(out_dir)
    path.mkdir(parents=True, exist_ok=True)
    out = {"n_queries": n_queries, "hlo_dir": str(path)}

    plan = fused.plan_query_batch(work)
    hlo = (
        fused.lower_query_batch(plan, max_distance=idx.max_distance)
        .compile()
        .as_text()
    )
    (path / "fused_serve_batch.hlo.txt").write_text(hlo)
    out["fused"] = program_roofline(analyze_hlo(hlo))

    # arena program: resolve every key against a resident arena, mirroring
    # serve_query_batch's routing (provably-empty items short-circuit)
    arena = PostingArena(budget_bytes=1 << 30)
    res = arena.acquire(idx, 0)
    items = []
    for qi, q_items in enumerate(work):
        for sub, view in q_items:
            keys = select_keys(sub, view.fl)
            extents = [res.lookup(k.components) for k in keys]
            if not keys or any(e is None for e in extents):
                continue
            if all(e.n_rows == 0 for e in extents) or (
                len(keys) >= 2 and any(e.n_rows == 0 for e in extents)
            ):
                continue
            items.append((qi, sub, keys, extents, res))
    aplan = plan_arena_batch(items, n_queries=len(work))
    if aplan is not None:
        hlo = (
            lower_arena_batch(aplan, max_distance=idx.max_distance)
            .compile()
            .as_text()
        )
        (path / "arena_serve_batch.hlo.txt").write_text(hlo)
        out["arena"] = program_roofline(analyze_hlo(hlo))
    arena.release()
    del res
    gc.collect()
    return out


# ---------------------------------------------------------------------------
# planner + deadline-aware frontend (DESIGN.md §11)
# ---------------------------------------------------------------------------


def bench_frontend(n_queries=32, repeats=3):
    """Serving-frontend bench: cache hit rates, tail latency, deadlines.

    Four passes over one frontend (``search/frontend.py``):

      * ``cold``        — every query served individually, result cache
        empty (per-request p50/p99 from the best-of-``repeats`` rounds on a
        fresh frontend each round, steady-state jit);
      * ``warm_cached`` — the same slate again on the LAST cold frontend:
        every request is a result-cache hit (hit rate must be 1.0 — a CI
        gate, see ``benchmarks/README.md``);
      * ``microbatch``  — the whole slate as ONE ``search_many`` call
        (one fused dispatch per ``max_batch`` chunk);
      * ``deadline``    — a deterministic admission-throughput model
        (``calibrate=False, postings_per_sec=1``) with a budget at 60% of
        the median plan cost: counts partial responses and skipped
        subqueries (arXiv 2009.03679's recall-for-latency trade).

    The equality guard compares the frontend's fragment sets against the
    unplanned fused engine — the planner/caching layer must be invisible in
    results (``results_match_unplanned`` gates ``benchmarks/run.py``).
    """
    from repro.search import fused as fused_mod
    from repro.search.engine import SearchEngine
    from repro.search.frontend import SearchRequest, ServingFrontend

    store, idx = build_benchmark_index()
    subs = _stop_lemma_queries(store, idx, n_queries=n_queries * 2, seed=9)
    queries = list(dict.fromkeys(" ".join(s.lemmas) for s in subs))[:n_queries]

    def frag_set(resp):
        return {
            (d.doc_id, f.start, f.end) for d in resp.docs for f in d.fragments
        }

    # equality guard vs the unplanned fused engine (exactness, not speed)
    eng = SearchEngine(idx, lemmatizer=store.lemmatizer, algorithm="fused")
    guard = ServingFrontend(idx, lemmatizer=store.lemmatizer)
    match = all(
        frag_set(guard.search(q, top_k=16)) == frag_set(eng.search(q, top_k=16))
        for q in queries
    )

    # cold pass: per-request latency on a fresh frontend (caches empty)
    best_lat: list[float] | None = None
    frontend = None
    for _ in range(repeats):
        frontend = ServingFrontend(idx, lemmatizer=store.lemmatizer)
        lat = []
        for q in queries:
            t0 = time.perf_counter()
            frontend.search(q, top_k=16)
            lat.append(time.perf_counter() - t0)
        if best_lat is None or sum(lat) < sum(best_lat):
            best_lat = lat
    cold = np.asarray(best_lat)

    # warm pass: same slate on the last cold frontend -> all cache hits
    hits0 = frontend.metrics()["result_cache_hits"]
    warm_lat = []
    for q in queries:
        t0 = time.perf_counter()
        frontend.search(q, top_k=16)
        warm_lat.append(time.perf_counter() - t0)
    warm = np.asarray(warm_lat)
    warm_hits = frontend.metrics()["result_cache_hits"] - hits0
    hit_rate = warm_hits / len(queries)

    # micro-batch pass: the slate as one call.  The first call compiles the
    # batch-shape program (a one-time cost per shape bucket, DESIGN.md §9.2);
    # timing uses a SECOND fresh frontend so caches are cold but jit is warm.
    requests = [SearchRequest(q, top_k=16) for q in queries]
    ServingFrontend(
        idx, lemmatizer=store.lemmatizer, max_batch=len(queries)
    ).search_many(requests)
    mb_frontend = ServingFrontend(
        idx, lemmatizer=store.lemmatizer, max_batch=len(queries)
    )
    fused_mod.reset_dispatch_count()
    t0 = time.perf_counter()
    mb_frontend.search_many(requests)
    mb_sec = time.perf_counter() - t0
    mb_dispatches = fused_mod.dispatch_count()

    # deadline pass: deterministic model, budget = 60% of the median cost.
    # " are" appends the paper's multi-lemma word (are -> are/be), so every
    # request has >= 2 subqueries and admission has something to trade.
    dl_queries = [q + " are" for q in queries]
    dl_frontend = ServingFrontend(
        idx,
        lemmatizer=store.lemmatizer,
        calibrate=False,
        postings_per_sec=1.0,  # budget is denominated in postings
    )
    est = sorted(
        dl_frontend.planner.plan(q).est_postings for q in dl_queries
    )
    budget = 0.6 * est[len(est) // 2]
    partials = skipped = 0
    for q in dl_queries:
        resp = dl_frontend.search(q, top_k=16, deadline_sec=budget)
        partials += int(resp.stats.partial)
        skipped += resp.stats.skipped_subqueries

    pct = lambda a, p: float(np.percentile(a, p) * 1e6)
    return {
        "n_queries": len(queries),
        "results_match_unplanned": bool(match),
        "cold": {
            "us_per_query": float(cold.mean() * 1e6),
            "p50_us": pct(cold, 50),
            "p99_us": pct(cold, 99),
        },
        "warm_cached": {
            "us_per_query": float(warm.mean() * 1e6),
            "p50_us": pct(warm, 50),
            "p99_us": pct(warm, 99),
            "hit_rate": float(hit_rate),
        },
        "microbatch": {
            "us_per_query": 1e6 * mb_sec / len(queries),
            "device_dispatches": mb_dispatches,
        },
        "deadline": {
            "budget_postings": float(budget),
            "partial_responses": partials,
            "skipped_subqueries": skipped,
        },
        "posting_cache": {
            "hit_rate": mb_frontend.metrics()["posting_cache_hit_rate"],
            "entries": mb_frontend.metrics()["posting_cache_entries"],
            "bytes": mb_frontend.metrics()["posting_cache_bytes"],
        },
    }


def bench_indexing(n_docs=120, doc_len=180, n_batches=6, quick=False):
    """Index-construction throughput: full build vs incremental ingest vs
    merge + compact (the arXiv 2006.07954 construction concern).

    Reported docs/sec:
      * ``full_build``          — one-shot ``build_indexes`` over the corpus;
      * ``incremental_pinned``  — batch ingest, FL pinned after the first
        generation (``commit(refresh_fl=False)``, the serving mode);
      * ``incremental_refresh`` — batch ingest with a full FL refresh and
        drift re-keying at every generation (the exactness mode);
      * ``compact``             — k-way merge of all generations' segments
        (plus tombstone GC for 10% deletes), in segments/sec and docs/sec.

    The differential guard at the end checks the pinned-FL incremental
    index equals a rebuild pinning the same FL-list, and the refresh-mode
    index equals a plain rebuild; the verdict is returned as
    ``results_match_rebuild`` (+ ``mismatch_reason``) and gated by the
    caller (``benchmarks/run.py`` exits non-zero on a mismatch).
    """
    from repro.index import DocumentStore, IncrementalIndexer, index_sets_equal
    from repro.index.builder import build_indexes as _build

    if quick:
        n_docs, doc_len, n_batches = 60, 120, 4
    store = synthesize_corpus(n_docs=n_docs, doc_len=doc_len, vocab_size=2000, seed=17)
    texts = [d.text for d in store.documents]
    batch = max(1, len(texts) // n_batches)

    t0 = time.perf_counter()
    full = _build(store, sw_count=80, fu_count=300, max_distance=5)
    t_full = time.perf_counter() - t0

    def ingest(refresh_fl: bool):
        ix = IncrementalIndexer(
            sw_count=80, fu_count=300, max_distance=5, lemmatizer=store.lemmatizer
        )
        t0 = time.perf_counter()
        for i in range(0, len(texts), batch):
            ix.add_documents(texts[i : i + batch])
            ix.commit(refresh_fl=refresh_fl or i == 0)
        return ix, time.perf_counter() - t0

    ix_pin, t_pin = ingest(refresh_fl=False)
    ix_ref, t_ref = ingest(refresh_fl=True)

    # deletes + compaction over the refresh-mode index
    ids = sorted(ix_ref.documents)
    for victim in ids[::10]:  # ~10% deletes
        ix_ref.delete_document(victim)
    ix_ref.commit()
    n_segments = len(ix_ref.segments)
    t0 = time.perf_counter()
    ix_ref.compact()
    t_compact = time.perf_counter() - t0

    eq_pin, why_pin = index_sets_equal(
        ix_pin.index.to_index_set(),
        _build(ix_pin.surviving_store(), sw_count=80, fu_count=300,
               max_distance=5, fl=ix_pin.fl),
    )
    eq_ref, why_ref = index_sets_equal(
        ix_ref.index.to_index_set(), ix_ref.rebuild_index_set()
    )
    mismatch = []
    if not eq_pin:
        mismatch.append(f"pinned-FL incremental != pinned rebuild: {why_pin}")
    if not eq_ref:
        mismatch.append(f"refresh incremental != rebuild: {why_ref}")

    return {
        "n_docs": len(texts),
        "doc_len": doc_len,
        "batch_docs": batch,
        "full_build": {"sec": t_full, "docs_per_sec": len(texts) / t_full},
        "incremental_pinned": {"sec": t_pin, "docs_per_sec": len(texts) / t_pin},
        "incremental_refresh": {"sec": t_ref, "docs_per_sec": len(texts) / t_ref},
        "compact": {
            "sec": t_compact,
            "segments_merged": n_segments,
            "docs_per_sec": len(ix_ref.documents) / max(t_compact, 1e-9),
        },
        "results_match_rebuild": bool(eq_pin and eq_ref),
        "mismatch_reason": "; ".join(mismatch),
    }


# Frozen baseline for the ingest_speedup gate: the committed full-build
# throughput from BENCH_indexing.json as of the PR that added bulk ingest
# (bench_indexing corpus, one-shot ``build_indexes``, 31.868 docs/s).  The
# §17 claim is "bulk ingest retires the builder this repo used to ship" —
# an absolute floor against the historical figure, not a same-run ratio
# (the same-run ratio is also reported, informationally: at bench scale the
# in-RAM builder's dict churn grows with the corpus, so same-run flatters
# the comparison on small corpora and starves it on big ones).
SEED_FULL_BUILD_DOCS_PER_SEC = 31.87
INGEST_SPEEDUP_GATE = 10.0


def bench_ingest(n_docs=480, doc_len=180, docs_per_spill=120, reps=3,
                 quick=False, artifact_dir=None):
    """§17 external-memory bulk ingest vs the in-RAM builder.

    Reported:
      * ``bulk``                 — best-of-``reps`` SPIMI build (lemmatize +
        spill + merge + snapshot publish) in docs/sec, with per-phase wall
        times and spilled bytes;
      * ``full_build_same_run``  — one-shot ``build_indexes`` over the SAME
        corpus, same machine, same run (informational ratio);
      * ``speedup_vs_seed_full_build`` — bulk docs/sec over the frozen
        ``SEED_FULL_BUILD_DOCS_PER_SEC`` figure; CI gates this at
        ``>= INGEST_SPEEDUP_GATE`` (``ingest_speedup``);
      * ``ingest_equality``      — the published snapshot, restored from
        disk, is ``index_sets_equal`` to the in-RAM build (hard gate:
        throughput means nothing if the postings differ).

    ``--quick`` keeps the SAME corpus and only drops a repetition: the
    speedup gate compares against a frozen absolute figure, so shrinking
    the corpus would change what is being measured.  ``artifact_dir`` (CI)
    receives run 0's spill directory — the on-disk intermediate the §17
    format docs describe, uploadable for postmortems.
    """
    import shutil
    import tempfile
    from pathlib import Path

    from repro.index import index_sets_equal
    from repro.index.builder import build_indexes as _build
    from repro.index.ingest import bulk_build
    from repro.index.store import load_snapshot

    if quick:
        reps = 2
    store = synthesize_corpus(n_docs=n_docs, doc_len=doc_len, vocab_size=2000,
                              seed=17)
    docs = store.documents

    tmpdir = Path(tempfile.mkdtemp(prefix="bench_ingest_"))
    try:
        best = None
        for r in range(reps):
            st = bulk_build(
                documents=docs,
                out_dir=tmpdir / f"run{r}",
                sw_count=80, fu_count=300, max_distance=5,
                docs_per_spill=docs_per_spill,
                keep_spills=(r == 0),
            )
            if best is None or st.total_s < best.total_s:
                best = st

        t0 = time.perf_counter()
        ref = _build(store, sw_count=80, fu_count=300, max_distance=5)
        t_full = time.perf_counter() - t0

        restored = load_snapshot(tmpdir / "run0")
        eq, why = index_sets_equal(restored.index.to_index_set(), ref)

        if artifact_dir is not None:
            artifact_dir = Path(artifact_dir)
            if artifact_dir.exists():
                shutil.rmtree(artifact_dir)
            shutil.copytree(tmpdir / "run0" / "ingest_run", artifact_dir)
    finally:
        shutil.rmtree(tmpdir, ignore_errors=True)

    full_dps = len(docs) / t_full
    return {
        "n_docs": len(docs),
        "doc_len": doc_len,
        "docs_per_spill": docs_per_spill,
        "reps": reps,
        "bulk": {
            "sec": best.total_s,
            "docs_per_sec": best.docs_per_sec,
            "lemmatize_s": best.lemmatize_s,
            "spill_s": best.spill_s,
            "merge_s": best.merge_s,
            "spill_bytes": best.spill_bytes,
            "n_chunks": best.n_chunks,
        },
        "full_build_same_run": {"sec": t_full, "docs_per_sec": full_dps},
        "seed_full_build_docs_per_sec": SEED_FULL_BUILD_DOCS_PER_SEC,
        "speedup_vs_seed_full_build": best.docs_per_sec
        / SEED_FULL_BUILD_DOCS_PER_SEC,
        "speedup_same_run": best.docs_per_sec / full_dps,
        "ingest_equality": bool(eq),
        "mismatch_reason": "" if eq else why,
    }


def bench_persistence(n_docs=120, doc_len=180, n_batches=4, quick=False):
    """Durable index store (DESIGN.md §12): snapshot/restore throughput,
    cold-boot-from-snapshot vs full-rebuild speedup, on-disk compression.

    Reported:
      * ``snapshot``  — wall time + docs/sec to write an atomic ``snap_<N>``
        (delta+bitpacked segment stores + pre-lemmatized documents);
      * ``rebuild``   — what a snapshot-less server pays at boot:
        re-lemmatize the corpus texts and ``build_indexes`` from scratch;
      * ``restore``   — the §12 warm start: manifest + document parse +
        ``mmap``; postings decode lazily on first touch, so this is the
        time-to-first-servable-query, and ``speedup_vs_rebuild`` =
        rebuild/restore is the cold-boot claim CI gates at >= 5x;
      * ``first_touch`` — forcing every posting decode (a full-corpus scan:
        the worst case the lazy boot amortizes);
      * ``compression`` — posting+NSW blob bytes on disk vs the
        ``size_bytes()`` in-memory footprint of the same segments; CI gates
        ``ratio`` >= 1.5x (the §12.1 codec floor);
      * ``restore_equality`` — the restored view is ``index_sets_equal``-
        identical to the live one (gated, like every §12 exactness claim).
    """
    import shutil
    import tempfile
    from pathlib import Path

    from repro.index import IncrementalIndexer, index_sets_equal
    from repro.index.builder import build_indexes as _build
    from repro.index.corpus import DocumentStore

    if quick:
        n_docs, doc_len, n_batches = 60, 120, 3
    store = synthesize_corpus(n_docs=n_docs, doc_len=doc_len, vocab_size=2000, seed=23)
    texts = [d.text for d in store.documents]
    batch = max(1, len(texts) // n_batches)

    ix = IncrementalIndexer(sw_count=80, fu_count=300, max_distance=5,
                            lemmatizer=store.lemmatizer)
    for i in range(0, len(texts), batch):
        ix.add_documents(texts[i : i + batch])
        ix.commit()
    ids = sorted(ix.documents)
    for victim in ids[::10]:  # ~10% tombstones ride along in the snapshot
        ix.delete_document(victim)

    tmpdir = Path(tempfile.mkdtemp(prefix="bench_persist_"))
    try:
        t0 = time.perf_counter()
        snap_path = ix.snapshot(tmpdir)
        t_snapshot = time.perf_counter() - t0

        mem_bytes = sum(seg.index.size_bytes()["total"] for seg in ix.segments)
        blob_bytes = sum(
            f.stat().st_size
            for seg_dir in snap_path.glob("seg_*")
            for f in (seg_dir / "postings.bin", seg_dir / "nsw.bin")
        )
        disk_total = sum(f.stat().st_size for f in snap_path.rglob("*") if f.is_file())

        # the snapshot-less cold boot: re-lemmatize + rebuild from texts
        t0 = time.perf_counter()
        rebuilt_store = DocumentStore.from_texts([store.documents[i].text for i in sorted(ix.documents)])
        _build(rebuilt_store, sw_count=80, fu_count=300, max_distance=5)
        t_rebuild = time.perf_counter() - t0

        t0 = time.perf_counter()
        rx = IncrementalIndexer.restore(tmpdir, lemmatizer=store.lemmatizer)
        t_restore = time.perf_counter() - t0

        t0 = time.perf_counter()
        restored_view = rx.index.to_index_set()  # forces every lazy decode
        t_touch = time.perf_counter() - t0

        eq, why = index_sets_equal(restored_view, ix.index.to_index_set())
    finally:
        shutil.rmtree(tmpdir, ignore_errors=True)

    n_live = len(ix.documents)
    return {
        "n_docs": n_live,
        "doc_len": doc_len,
        "segments": len(ix.segments),
        "snapshot": {"sec": t_snapshot, "docs_per_sec": n_live / max(t_snapshot, 1e-9)},
        "rebuild": {"sec": t_rebuild, "docs_per_sec": n_live / max(t_rebuild, 1e-9)},
        "restore": {
            "sec": t_restore,
            "docs_per_sec": n_live / max(t_restore, 1e-9),
            "speedup_vs_rebuild": t_rebuild / max(t_restore, 1e-9),
        },
        "first_touch": {"sec": t_touch},
        "compression": {
            "memory_bytes": int(mem_bytes),
            "posting_blob_bytes": int(blob_bytes),
            "snapshot_bytes_total": int(disk_total),
            "ratio": mem_bytes / max(blob_bytes, 1),
        },
        "restore_equality": bool(eq),
        "mismatch_reason": "" if eq else why,
    }


def bench_robustness(quick=False, chaos_seeds=(101, 202, 303)):
    """Resilient-serving bench (DESIGN.md §14): what failure costs, and the
    gates proving it never costs correctness.

    Five measurements over one sharded incremental service:

      * ``fault_free``    — per-batch p50/p99 with the resilience layer ON
        but an empty fault schedule, plus the clean-counters check (every
        §14 counter must be zero — the layer must be free when nothing
        fails);
      * ``degraded``      — per-batch p50/p99 with one shard killed and
        recovery disabled: flagged rate must be 1.0 and every response must
        equal the baseline minus exactly the dead shard's documents;
      * ``recovery``      — wall time of the batch in which a killed shard
        is detected and re-restored from its §12.2 snapshot, vs the
        fault-free batch time; post-recovery responses must equal the
        baseline exactly;
      * ``chaos``         — the seeded chaos-differential sweep (the CI
        gate): for each schedule seed, every response over the run is
        either exact (== the clean baseline) or flagged partial with exact
        coverage of the surviving shards; the sweep always includes one
        UNRECOVERABLE schedule (kill + every restore candidate corrupted)
        so the degraded path is provably exercised — ``flagged`` must be
        >= 1 and ``mismatches`` must be 0;
      * ``wal_replay``    — §18.2 crash-recovery cost: restore a WAL'd
        service with a logged post-snapshot tail and report replay wall
        time normalized to ms per 1k records, plus the zero-data-loss
        check (replayed state ``index_sets_equal`` to the live service).

    The gates feed ``benchmarks/run.py`` (``chaos_results_MISMATCH``,
    ``robustness_counters_DIRTY``, ``robustness_chaos_flag_GATE``,
    ``robustness_mttr_GATE``) and ``BENCH_robustness.json``.
    """
    import shutil
    import tempfile
    from pathlib import Path as _Path

    from repro.runtime.fault_tolerance import RestartPolicy
    from repro.search.distributed import ShardedSearchService
    from repro.search.resilience import (
        FaultEvent,
        FaultInjector,
        ResiliencePolicy,
    )

    n_shards = 3
    n_docs = 36 if quick else 60
    rounds = 6 if quick else 12
    store = synthesize_corpus(n_docs=n_docs, doc_len=120, vocab_size=1500,
                              seed=7)
    queries = [
        "who are you who", "to be or not to be", "what do you do all day",
    ]
    kw = dict(n_shards=n_shards, sw_count=40, fu_count=120, max_distance=5,
              algorithm="fused", incremental=True)
    policy_kw = dict(
        restart=RestartPolicy(max_restarts=2, min_backoff_s=0.0),
        breaker_cooldown_s=0.0,
    )
    top_k = 10_000  # past every doc: fragment sets compare fully

    def frags(resp):
        return {(d.doc_id, f.start, f.end) for d in resp.docs
                for f in d.fragments}

    # clean baseline: no resilience layer at all
    baseline_svc = ShardedSearchService(store, **kw)
    baseline_svc.search_batch(queries, top_k=top_k)  # jit warm
    baseline = [frags(r) for r in baseline_svc.search_batch(queries, top_k=top_k)]

    def run_batches(svc, n):
        times, resps = [], []
        for _ in range(n):
            t0 = time.perf_counter()
            out = svc.search_batch(queries, top_k=top_k)
            times.append(time.perf_counter() - t0)
            resps.append(out)
        return np.asarray(times), resps

    tmpdir = _Path(tempfile.mkdtemp(prefix="bench_robust_"))
    try:
        # ---- fault-free pass: latency + the clean-counters gate -----------
        svc = ShardedSearchService(store, **kw)
        svc.snapshot(tmpdir / "ff")
        svc.enable_resilience(policy=ResiliencePolicy(**policy_kw))
        ff_times, ff_resps = run_batches(svc, rounds)
        counters_clean = all(
            (r.stats.retries, r.stats.hedges, r.stats.shards_degraded,
             r.stats.recoveries, r.stats.shed) == (0, 0, 0, 0, 0)
            and not r.stats.partial
            for out in ff_resps for r in out
        )
        ff_match = all(
            [frags(r) for r in out] == baseline for out in ff_resps
        )

        # ---- degraded pass: one shard down, recovery off ------------------
        dead = 1
        svc = ShardedSearchService(store, **kw)
        svc.enable_resilience(
            policy=ResiliencePolicy(recover=False, **policy_kw),
            injector=FaultInjector(schedule=[
                FaultEvent("shard.search", "kill", shard=dead, at_call=0),
            ]),
        )
        deg_times, deg_resps = run_batches(svc, rounds)
        flagged = sum(
            1 for out in deg_resps for r in out
            if r.stats.partial and r.stats.shards_degraded == 1
        )
        deg_total = sum(len(out) for out in deg_resps)
        deg_expected = [
            {f for f in b if f[0] % n_shards != dead} for b in baseline
        ]
        deg_match = all(
            [frags(r) for r in out] == deg_expected for out in deg_resps
        )

        # ---- recovery pass: kill -> detect -> snapshot re-restore ---------
        svc = ShardedSearchService(store, **kw)
        svc.snapshot(tmpdir / "rec")
        svc.enable_resilience(
            policy=ResiliencePolicy(**policy_kw),
            injector=FaultInjector(schedule=[
                FaultEvent("shard.search", "kill", shard=dead, at_call=1),
            ]),
        )
        svc.search_batch(queries, top_k=top_k)  # arrival 0: healthy
        t0 = time.perf_counter()
        rec_out = svc.search_batch(queries, top_k=top_k)  # arrival 1: kill
        recovery_batch_sec = time.perf_counter() - t0
        rec_match = (
            [frags(r) for r in rec_out] == baseline
            and all(r.stats.recoveries == 1 for r in rec_out)
            and all(r.stats.shards_degraded == 0 for r in rec_out)
        )

        # ---- seeded chaos-differential sweep (the CI gate) ----------------
        chaos_responses = 0
        chaos_flagged = 0
        chaos_mismatches = 0
        chaos_fired = 0
        for seed in chaos_seeds:
            svc = ShardedSearchService(store, **kw)
            svc.snapshot(tmpdir / f"chaos_{seed}")
            svc.enable_resilience(
                policy=ResiliencePolicy(**policy_kw),
                injector=FaultInjector.from_seed(seed, n_shards=n_shards),
            )
            for _ in range(rounds):
                out = svc.search_batch(queries, top_k=top_k)
                excluded = svc.supervisor.last_excluded
                for got_resp, want in zip(out, baseline):
                    chaos_responses += 1
                    got = frags(got_resp)
                    if got_resp.stats.shards_degraded:
                        chaos_flagged += 1
                        ok = got_resp.stats.partial and got == {
                            f for f in want if f[0] % n_shards not in excluded
                        }
                    else:
                        ok = not got_resp.stats.partial and got == want
                    chaos_mismatches += 0 if ok else 1
            chaos_fired += len(svc.injector.log)

        # one guaranteed-unrecoverable schedule: the kill sticks because
        # EVERY restore candidate is corrupted, so every response must be
        # flagged partial with exact surviving-shard coverage — this is
        # what keeps ``flagged`` > 0 (a sweep whose seeds all recover
        # would otherwise leave the degraded path unproven)
        svc = ShardedSearchService(store, **kw)
        svc.snapshot(tmpdir / "chaos_unrec")
        svc.enable_resilience(
            policy=ResiliencePolicy(**policy_kw),
            injector=FaultInjector(schedule=[
                FaultEvent("shard.search", "kill", shard=dead, at_call=0),
                FaultEvent("store.load_snapshot", "bitflip", at_call=0,
                           count=50, param=0.3),
            ]),
        )
        for _ in range(rounds):
            out = svc.search_batch(queries, top_k=top_k)
            excluded = svc.supervisor.last_excluded
            for got_resp, want in zip(out, baseline):
                chaos_responses += 1
                got = frags(got_resp)
                if got_resp.stats.shards_degraded:
                    chaos_flagged += 1
                    ok = got_resp.stats.partial and got == {
                        f for f in want if f[0] % n_shards not in excluded
                    }
                else:
                    ok = not got_resp.stats.partial and got == want
                chaos_mismatches += 0 if ok else 1
        chaos_fired += len(svc.injector.log)

        # ---- §18.2 WAL replay cost: restore with a logged tail -------------
        from repro.index.incremental import index_sets_equal

        svc = ShardedSearchService(store, **kw)
        svc.enable_wal(tmpdir / "walrep")
        svc.snapshot(tmpdir / "walrep")
        n_ops = 25 if quick else 120
        for i in range(n_ops):
            svc.add_documents([f"wal bench doc {i} alpha beta gamma delta"])
            svc.commit()
        t0 = time.perf_counter()
        restored = ShardedSearchService.restore(tmpdir / "walrep")
        restore_total_sec = time.perf_counter() - t0
        replay_records = sum(ix.last_wal_replay["records"]
                             for ix in restored.indexers)
        replay_sec = sum(ix.last_wal_replay["seconds"]
                         for ix in restored.indexers)
        wal_match = replay_records > 0 and all(
            index_sets_equal(a.index.to_index_set(), b.index.to_index_set())[0]
            and a.documents.keys() == b.documents.keys()
            for a, b in zip(restored.indexers, svc.indexers)
        )

        pct = lambda a, p: float(np.percentile(a, p) * 1e6)
        return {
            "fault_free": {
                "p50_us": pct(ff_times, 50),
                "p99_us": pct(ff_times, 99),
                "counters_clean": bool(counters_clean),
                "results_match": bool(ff_match),
            },
            "degraded": {
                "p50_us": pct(deg_times, 50),
                "p99_us": pct(deg_times, 99),
                "flagged_rate": flagged / max(deg_total, 1),
                "results_match": bool(deg_match),
            },
            "recovery": {
                "batch_ms": 1000 * recovery_batch_sec,
                "fault_free_batch_ms": 1000 * float(np.median(ff_times)),
                "results_match": bool(rec_match),
            },
            "chaos": {
                "seeds": list(chaos_seeds),
                "rounds": rounds,
                "responses": chaos_responses,
                "flagged": chaos_flagged,
                "faults_fired": chaos_fired,
                "mismatches": chaos_mismatches,
            },
            "wal_replay": {
                "records": int(replay_records),
                "replay_ms": 1000 * replay_sec,
                "ms_per_1k_records": (
                    1e6 * replay_sec / max(replay_records, 1)
                ),
                "restore_total_ms": 1000 * restore_total_sec,
                "results_match": bool(wal_match),
            },
            "results_match": bool(
                ff_match and deg_match and rec_match and wal_match
                and chaos_mismatches == 0
            ),
        }
    finally:
        shutil.rmtree(tmpdir, ignore_errors=True)
