"""Paper-table reproductions (Experiments 1 & 2 analogues, §11–§12).

One function per reported table/figure:

  * ``bench_algorithms``  — Fig.5/6 + the postings/data-read tables: average
    query time, postings read, bytes read for SE1 and SE2.1–SE2.4 over
    stop-lemma queries on a Zipf corpus.
  * ``bench_duplicates``  — §12's duplicate-lemma case ("to be or not to be"):
    SE2.3 vs SE2.4 work (intermediate records / time).
  * ``bench_vectorized``  — the TPU-native path (batched cover) vs the scalar
    Combiner, and the Pallas kernel in interpret mode vs the jnp ref.

The absolute times are CPU-container numbers; the paper's CLAIMS are about
ratios and orderings, which is what EXPERIMENTS.md §Paper records.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.baselines import (
    se1_ordinary,
    se21_main_cell,
    se22_intermediate,
    se23_optimized,
)
from repro.core.combiner import se24_combiner
from repro.core.keys import Subquery, expand_subqueries
from repro.core.lemma import Lemmatizer, LemmaType
from repro.core.postings import QueryStats
from repro.index import build_indexes, synthesize_corpus
from repro.search.vectorized import VectorizedEngine

ALGOS = {
    "SE1": se1_ordinary,
    "SE2.1": se21_main_cell,
    "SE2.2": se22_intermediate,
    "SE2.3": se23_optimized,
    "SE2.4": se24_combiner,
}


def _stop_lemma_queries(store, idx, n_queries=30, lens=(3, 4, 5), seed=3):
    """Sample stop-lemma-only queries from real document windows (so they
    have non-trivial result sets), mirroring the paper's query selection."""
    rng = np.random.default_rng(seed)
    queries: list[Subquery] = []
    docs = store.documents
    while len(queries) < n_queries:
        d = docs[int(rng.integers(len(docs)))]
        if len(d) < 12:
            continue
        start = int(rng.integers(0, len(d) - 8))
        want = int(rng.choice(lens))
        lemmas = []
        for lem_tuple in d.lemma_stream[start : start + 10]:
            l = lem_tuple[0]
            if idx.fl.lemma_type(l) == LemmaType.STOP:
                lemmas.append(l)
            if len(lemmas) == want:
                break
        if len(lemmas) == want:
            queries.append(Subquery(tuple(lemmas)))
    return queries


def build_benchmark_index(n_docs=150, doc_len=220, seed=13):
    store = synthesize_corpus(n_docs=n_docs, doc_len=doc_len, vocab_size=3000,
                              seed=seed)
    idx = build_indexes(store, sw_count=80, fu_count=300, max_distance=5)
    return store, idx


def bench_algorithms(n_queries=30):
    store, idx = build_benchmark_index()
    queries = _stop_lemma_queries(store, idx, n_queries=n_queries)
    rows = []
    for name, fn in ALGOS.items():
        total = QueryStats()
        t0 = time.perf_counter()
        for sub in queries:
            _, stats = fn(sub, idx)
            total.merge(stats)
        dt = time.perf_counter() - t0
        rows.append({
            "algorithm": name,
            "avg_ms": 1000 * dt / len(queries),
            "avg_postings": total.postings_read / len(queries),
            "avg_kb": total.bytes_read / 1024 / len(queries),
            "avg_intermediate": total.intermediate_records / len(queries),
            "avg_results": total.results / len(queries),
        })
    return rows


def bench_duplicates():
    """§12: 'to be or not to be' — SE2.4's duplicate handling vs SE2.3."""
    store, idx = build_benchmark_index()
    lem = Lemmatizer()
    sub = expand_subqueries("to be or not to be", lem)[0]
    out = {}
    for name in ("SE2.1", "SE2.2", "SE2.3", "SE2.4"):
        t0 = time.perf_counter()
        for _ in range(5):
            _, stats = ALGOS[name](sub, idx)
        out[name] = {
            "ms": 1000 * (time.perf_counter() - t0) / 5,
            "postings": stats.postings_read,
            "intermediate": stats.intermediate_records,
            "results": stats.results,
        }
    return out


def bench_vectorized():
    store, idx = build_benchmark_index()
    queries = _stop_lemma_queries(store, idx, n_queries=10)
    out = []
    eng_ref = VectorizedEngine(idx, use_kernel=False)
    eng_k = VectorizedEngine(idx, use_kernel=True)
    for name, runner in [
        ("scalar_combiner", lambda s: se24_combiner(s, idx)),
        ("vectorized_jnp", eng_ref.search_subquery),
        ("pallas_interpret", eng_k.search_subquery),
    ]:
        # full warmup pass: deployed serving uses fixed shape budgets, so
        # steady-state (jit-cached) latency is the meaningful number
        for sub in queries:
            runner(sub)
        t0 = time.perf_counter()
        n_results = 0
        for sub in queries:
            r, _ = runner(sub)
            n_results += len(r)
        out.append({
            "engine": name,
            "avg_ms": 1000 * (time.perf_counter() - t0) / len(queries),
            "results": n_results,
        })
    return out
