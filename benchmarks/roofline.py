"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline)
and over the compiled SERVING programs (DESIGN.md §15.4).

Three terms per (arch x shape x mesh), all in seconds-per-step, from the
compiled HLO (per-device numbers; see launch/hlo_analysis.py):

  compute     = flops_per_device / PEAK_FLOPS
  memory      = hbm_bytes_per_device / HBM_BW
  collective  = collective_bytes_per_device / LINK_BW

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

The step's lower-bound time is max(terms); the dominant term is the
bottleneck; roofline fraction = compute / max(terms) (how much of the
machine's FLOP roof the step can possibly use).  MODEL_FLOPS / HLO_FLOPS
shows how much of the compiled compute is "useful" (remat/dispatch waste).

:func:`program_roofline` applies the same terms to ONE compiled serving
program — ``fused_serve_batch`` / ``arena_serve_batch`` lowered and
analyzed by ``launch/hlo_analysis.analyze_hlo`` — so BENCH_serving.json
reports how far from memory-bound the device side of a batch runs
(``benchmarks/paper_tables.bench_roofline`` wires it; the HLO text ships
as a CI artifact).
"""

from __future__ import annotations

import glob
import json
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

__all__ = [
    "load_records",
    "program_roofline",
    "roofline_terms",
    "roofline_table",
    "main",
]


def program_roofline(cost) -> dict:
    """Roofline terms for one compiled serving program (DESIGN.md §15.4).

    ``cost`` is the :class:`~repro.launch.hlo_analysis.HloCost` of the
    program's partitioned HLO.  Returns the raw totals plus the §Roofline
    terms; ``arithmetic_intensity`` (flops per HBM byte) against
    ``ridge_intensity`` (= PEAK_FLOPS / HBM_BW) says how far from
    memory-bound the program is — serving gathers/sorts are expected to sit
    deep on the memory side of the ridge, and a *drop* in intensity from
    the committed baseline flags a regression (an accidental dense
    materialization shows up as an hbm_bytes spike).
    """
    comp = cost.flops / PEAK_FLOPS
    mem = cost.hbm_bytes / HBM_BW
    coll = cost.collective_bytes / LINK_BW
    terms = {"compute_s": comp, "memory_s": mem, "collective_s": coll}
    bound = max(comp, mem, coll)
    dominant = max(terms, key=terms.get).replace("_s", "")
    return {
        "flops": cost.flops,
        "hbm_bytes": cost.hbm_bytes,
        "collective_bytes": cost.collective_bytes,
        "arithmetic_intensity": (
            cost.flops / cost.hbm_bytes if cost.hbm_bytes else 0.0
        ),
        "ridge_intensity": PEAK_FLOPS / HBM_BW,
        **terms,
        "dominant": dominant,
        "roofline_fraction": comp / bound if bound > 0 else 0.0,
        "step_lower_bound_s": bound,
    }


def load_records(art_dir: str = "artifacts/dryrun", mesh: str = "singlepod") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(f"{art_dir}/*__{mesh}.json")):
        r = json.load(open(f))
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def roofline_terms(rec: dict) -> dict:
    comp = rec["flops_per_device"] / PEAK_FLOPS
    mem = rec["hbm_bytes_per_device"] / HBM_BW
    coll = rec["collective_total_per_device"] / LINK_BW
    terms = {"compute_s": comp, "memory_s": mem, "collective_s": coll}
    dominant = max(terms, key=terms.get)
    bound = max(comp, mem, coll)
    model = rec.get("model_flops_global", 0.0)
    hlo_global = rec["flops_per_device"] * rec["n_devices"]
    out = {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "roofline_fraction": comp / bound if bound > 0 else 0.0,
        "model_over_hlo_flops": (model / hlo_global) if hlo_global else 0.0,
        "step_lower_bound_s": bound,
    }
    return out


_ADVICE = {
    "compute": "compute-bound: raise MXU utilization (tile alignment, fuse "
               "small ops, drop redundant recompute) or accept — this is the roof",
    "memory": "memory-bound: cut HBM traffic (fuse producers into consumers, "
              "avoid materialized masks/intermediates, recompute-in-VMEM, "
              "smaller activation dtypes)",
    "collective": "collective-bound: reshard to shrink cross-device bytes "
                  "(different TP axis, overlap collectives with compute, "
                  "compress payloads)",
}


def roofline_table(recs: list[dict]) -> str:
    rows = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) | "
        "dominant | roofline frac | model/HLO flops | bound (s) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        t = roofline_terms(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} | {t['collective_s']:.2e} "
            f"| {t['dominant']} | {t['roofline_fraction']:.3f} "
            f"| {t['model_over_hlo_flops']:.3f} | {t['step_lower_bound_s']:.2e} |"
        )
    return "\n".join(rows)


def main() -> None:
    recs = load_records()
    print(roofline_table(recs))
    print()
    # the three §Perf candidates
    scored = [(r, roofline_terms(r)) for r in recs]
    worst = min(scored, key=lambda rt: rt[1]["roofline_fraction"])
    coll_bound = max(scored, key=lambda rt: rt[1]["collective_s"])
    print(f"worst roofline fraction : {worst[0]['arch']} x {worst[0]['shape']} "
          f"({worst[1]['roofline_fraction']:.3f}) -> {_ADVICE[worst[1]['dominant']]}")
    print(f"most collective-bound   : {coll_bound[0]['arch']} x {coll_bound[0]['shape']} "
          f"({coll_bound[1]['collective_s']:.2e}s) -> {_ADVICE['collective']}")


if __name__ == "__main__":
    main()
