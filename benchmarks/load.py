"""Deterministic traffic/load generator for the §16 serving daemon.

Drives the ``ServiceDaemon`` three ways and records ``BENCH_traffic.json``:

* **throughput** — deterministic batched-vs-serial QPS over fixed slates
  (warm jit cache, same compiled shapes every run): the same-run ratio is
  the machine-independent regression metric the CI gate checks.
* **closed loop** — C concurrent clients, one outstanding request each,
  resubmitting on completion (real clock, threaded): sustained QPS,
  p50/p99/p999 latency, batch-occupancy histogram.
* **open loop** — a seeded arrival schedule at a target QPS paced in real
  time through the started daemon: sustained QPS, tail latency,
  partial/shed/error rates under bursty admission.
* **replay** — the SAME seeded schedule replayed on a virtual clock
  (``ServiceDaemon.replay``): exact, machine-independent batch occupancy
  (the continuous-batching evidence: occupancy > 1 at saturation).

The query mix is Zipf over the corpus's stop / frequently-used / ordinary
lemma classes (§5 traffic shape) and fully determined by ``seed``: equal
seeds produce the identical request sequence, so the exactness section —
sampled responses compared against a fresh single-frontend reference —
is a differential gate (``traffic_results_MISMATCH`` /
``traffic_shed_UNFLAGGED``), not a statistical one: every sampled
no-deadline response must be byte-identical to the reference, and every
response that diverges (deadline partial, shed) must carry its flag.

Run: ``PYTHONPATH=src python -m benchmarks.load [--smoke] [--json PATH]``
"""

from __future__ import annotations

import argparse
import json
import sys
import threading
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent.parent))

from repro.core.lemma import LemmaType  # noqa: E402
from repro.index import build_indexes, synthesize_corpus  # noqa: E402
from repro.runtime.clock import ManualClock  # noqa: E402
from repro.search.frontend import SearchRequest, ServingFrontend  # noqa: E402
from repro.search.service import ServiceDaemon  # noqa: E402

# Zipf class weights of the generated traffic: stop-heavy, like the
# paper's worst-case evaluation queries
CLASS_WEIGHTS = {LemmaType.STOP: 0.5, LemmaType.FREQUENTLY_USED: 0.3,
                 LemmaType.ORDINARY: 0.2}


def build_stack(n_docs=120, doc_len=90, seed=29):
    store = synthesize_corpus(n_docs=n_docs, doc_len=doc_len, vocab_size=2000,
                              seed=seed)
    index = build_indexes(store, sw_count=60, fu_count=200, max_distance=5)
    return store, index


def make_query_mix(store, index, n_queries, seed):
    """Seeded query mix sampled from real document windows (so proximity
    result sets are non-trivial — independent word draws almost never
    co-occur within max_distance), with per-word lemma class drawn from
    the stop-heavy ``CLASS_WEIGHTS`` mix, mirroring the paper's worst-case
    query selection."""
    rng = np.random.default_rng(seed)
    docs = store.documents
    classes = list(CLASS_WEIGHTS)
    weights = np.array([CLASS_WEIGHTS[t] for t in classes], dtype=np.float64)
    weights /= weights.sum()
    queries = []
    while len(queries) < n_queries:
        d = docs[int(rng.integers(len(docs)))]
        if len(d) < 12:
            continue
        start = int(rng.integers(0, len(d) - 10))
        window = [lt[0] for lt in d.lemma_stream[start : start + 10]]
        if not window:
            continue
        by_class = {
            t: [w for w in window if index.fl.lemma_type(w) == t] for t in classes
        }
        words = []
        for _ in range(int(rng.integers(2, 5))):
            t = classes[int(rng.choice(len(classes), p=weights))]
            pool = by_class[t] or window  # window lacks the class: any word
            words.append(pool[int(rng.integers(len(pool)))])
        queries.append(" ".join(words))
    return queries


def _percentiles(latencies_s):
    if not latencies_s:
        return {"p50_ms": 0.0, "p99_ms": 0.0, "p999_ms": 0.0}
    arr = np.asarray(latencies_s, dtype=np.float64) * 1e3
    return {
        "p50_ms": float(np.percentile(arr, 50)),
        "p99_ms": float(np.percentile(arr, 99)),
        "p999_ms": float(np.percentile(arr, 99.9)),
    }


def _rates(pairs):
    n = max(1, len(pairs))
    partial = sum(1 for _, r in pairs if r.stats.partial)
    shed = sum(1 for t, r in pairs if r.stats.shed or t.shed_at_queue)
    return {"partial_rate": partial / n, "shed_rate": shed / n}


def run_throughput_ratio(store, index, queries, *, max_batch=8):
    """Deterministic batched-vs-serial throughput — the gated ratio.

    Fixed slates of ``max_batch`` requests through the §15 batched
    pipeline (``search_many``) vs one-at-a-time ``search``, each on a
    fresh frontend, each run twice: the untimed first pass compiles every
    (pow2-bucketed) program shape into the process-wide jit cache, the
    second pass measures steady state.  Slate composition is a pure
    function of the seeded query list, so the compiled shapes — and hence
    the ratio — are stable run to run, unlike the racy threaded loop
    whose batch compositions depend on scheduler interleaving.
    """

    def batched_qps():
        fe = ServingFrontend(index, lemmatizer=store.lemmatizer,
                             max_batch=max_batch)
        reqs = [SearchRequest(q, top_k=10) for q in queries]
        t0 = time.perf_counter()
        for lo in range(0, len(reqs), max_batch):
            fe.search_many(reqs[lo : lo + max_batch])
        dt = time.perf_counter() - t0
        return len(reqs) / dt if dt > 0 else 0.0

    def serial_qps():
        fe = ServingFrontend(index, lemmatizer=store.lemmatizer,
                             max_batch=max_batch)
        t0 = time.perf_counter()
        for q in queries:
            fe.search(q, top_k=10)
        dt = time.perf_counter() - t0
        return len(queries) / dt if dt > 0 else 0.0

    batched_qps()  # warm-up: compile slate shapes
    serial_qps()  # warm-up: compile single-query shapes
    b, s = batched_qps(), serial_qps()
    return {
        "requests": len(queries),
        "batched_qps": b,
        "serial_qps": s,
        "qps_ratio": b / s if s > 0 else 0.0,
    }


def run_closed_loop(store, index, queries, *, clients=6, per_client=8,
                    max_batch=8):
    """C clients, one outstanding request each, resubmit on completion.
    Real threads, real clock: reported for QPS/latency/occupancy, not
    gated (batch composition is scheduler-dependent)."""
    frontend = ServingFrontend(index, lemmatizer=store.lemmatizer,
                               max_batch=max_batch)
    daemon = ServiceDaemon(frontend, max_queue=4 * clients).start()
    pairs: list[list] = [[] for _ in range(clients)]
    errors: list[BaseException] = []
    start = threading.Barrier(clients + 1)

    def client(c):
        try:
            start.wait()
            for i in range(per_client):
                q = queries[(c * per_client + i) % len(queries)]
                t = daemon.submit(SearchRequest(q, top_k=10))
                pairs[c].append((t, t.result(timeout=300.0)))
        except BaseException as e:
            errors.append(e)

    threads = [threading.Thread(target=client, args=(c,)) for c in range(clients)]
    for t in threads:
        t.start()
    start.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0
    daemon.stop()
    flat = [p for per in pairs for p in per]
    m = daemon.metrics()
    n = len(flat)
    return {
        "clients": clients,
        "requests": n,
        "errors": len(errors),
        "sustained_qps": n / elapsed if elapsed > 0 else 0.0,
        **_percentiles([t.latency_sec for t, _ in flat]),
        **_rates(flat),
        "mean_batch_occupancy": m["mean_batch_occupancy"],
        "batch_occupancy_hist": m["batch_occupancy_hist"],
    }, flat


def make_open_schedule(queries, *, target_qps, n_requests, seed,
                       deadline_frac=0.25, deadline_sec=0.05):
    """Seeded Poisson arrivals at ``target_qps``; a ``deadline_frac``
    slice of requests carries a deadline, a third of those a ZERO budget
    (guaranteed flagged partials: the shed-flagging gate has teeth)."""
    rng = np.random.default_rng(seed + 1)
    t, events = 0.0, []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / target_qps))
        d = None
        if rng.random() < deadline_frac:
            d = 0.0 if rng.random() < (1.0 / 3.0) else deadline_sec
        events.append((t, SearchRequest(queries[i % len(queries)], top_k=10,
                                        deadline_sec=d)))
    return events


def run_open_loop(store, index, schedule, *, max_batch=8, max_queue=32):
    """Pace the seeded schedule in real time through the started daemon."""
    frontend = ServingFrontend(index, lemmatizer=store.lemmatizer,
                               max_batch=max_batch)
    daemon = ServiceDaemon(frontend, max_queue=max_queue).start()
    t0 = time.perf_counter()
    tickets = []
    for at, req in schedule:
        now = time.perf_counter() - t0
        if at > now:
            time.sleep(at - now)
        tickets.append(daemon.submit(req))
    pairs = [(t, t.result(timeout=300.0)) for t in tickets]
    elapsed = time.perf_counter() - t0
    daemon.stop()
    m = daemon.metrics()
    n = len(pairs)
    offered = n / schedule[-1][0] if schedule and schedule[-1][0] > 0 else 0.0
    return {
        "requests": n,
        "offered_qps": offered,
        "sustained_qps": n / elapsed if elapsed > 0 else 0.0,
        **_percentiles([t.latency_sec for t, _ in pairs]),
        **_rates(pairs),
        "mean_batch_occupancy": m["mean_batch_occupancy"],
        "batch_occupancy_hist": m["batch_occupancy_hist"],
        "queue_sheds": m["shed_queue"],
    }, pairs


def run_replay(store, index, schedule, *, max_batch=8, service_time_sec=0.02):
    """The same schedule on a virtual clock: exact, machine-independent
    occupancy (every run of a seed yields the identical batch sequence)."""
    clock = ManualClock()
    frontend = ServingFrontend(index, lemmatizer=store.lemmatizer,
                               max_batch=max_batch, clock=clock)
    daemon = ServiceDaemon(frontend, clock=clock, max_queue=4096)
    tickets = daemon.replay(schedule, service_time_sec=service_time_sec)
    m = daemon.metrics()
    pairs = [(t, t.result(timeout=0)) for t in tickets]
    return {
        "requests": len(tickets),
        "service_time_sec": service_time_sec,
        "batches": m["batches"],
        "mean_batch_occupancy": m["mean_batch_occupancy"],
        "batch_occupancy_hist": m["batch_occupancy_hist"],
        **_rates(pairs),
    }, pairs


def check_exactness(store, index, sampled_pairs, *, max_batch=8):
    """Differential gate: sampled responses vs a fresh single-frontend
    reference.  No-deadline responses must be byte-identical; ANY
    divergent response must be flagged (partial/shed)."""
    reference = ServingFrontend(index, lemmatizer=store.lemmatizer,
                                max_batch=max_batch)

    def key(resp):
        return [
            (d.doc_id, d.score, [(f.doc_id, f.start, f.end) for f in d.fragments])
            for d in resp.docs
        ]

    sampled = mismatches = unflagged = flagged_divergent = 0
    for t, resp in sampled_pairs:
        want = reference.search(t.request.query, top_k=t.request.top_k)
        sampled += 1
        if key(resp) == key(want):
            continue
        flagged = bool(resp.stats.partial or resp.stats.shed or t.shed_at_queue)
        if not flagged:
            unflagged += 1
        if t.request.deadline_sec is None and not t.shed_at_queue:
            mismatches += 1  # no budget, not shed: divergence is a bug
        elif flagged:
            flagged_divergent += 1
    return {
        "sampled": sampled,
        "mismatches": mismatches,
        "unflagged_divergence": unflagged,
        "flagged_divergent": flagged_divergent,
    }


def bench_traffic(quick=False, seed=29):
    """The full traffic profile: closed loop + open loop + virtual replay
    + exactness sampling, as recorded in ``BENCH_traffic.json``."""
    n_docs = 60 if quick else 120
    store, index = build_stack(n_docs=n_docs, seed=seed)
    queries = make_query_mix(store, index, 24 if quick else 48, seed)

    throughput = run_throughput_ratio(store, index, queries)

    clients = 4 if quick else 6
    per_client = 6 if quick else 10
    closed, closed_pairs = run_closed_loop(
        store, index, queries, clients=clients, per_client=per_client
    )

    n_open = 24 if quick else 60
    schedule = make_open_schedule(
        queries, target_qps=40.0, n_requests=n_open, seed=seed
    )
    open_loop, open_pairs = run_open_loop(store, index, schedule)

    replay_schedule = [
        (i * 0.002, SearchRequest(queries[i % len(queries)], top_k=10))
        for i in range(32 if quick else 64)
    ]
    replay, replay_pairs = run_replay(store, index, replay_schedule)

    rng = np.random.default_rng(seed + 2)
    pool = closed_pairs + open_pairs + replay_pairs
    idx = rng.choice(len(pool), size=min(32, len(pool)), replace=False)
    exactness = check_exactness(store, index, [pool[int(i)] for i in idx])

    return {
        "config": {
            "seed": seed,
            "quick": bool(quick),
            "n_docs": n_docs,
            "n_queries": len(queries),
            "class_weights": {t.name: w for t, w in CLASS_WEIGHTS.items()},
        },
        "throughput": throughput,
        "closed_loop": closed,
        "open_loop": open_loop,
        "replay": replay,
        "exactness": exactness,
    }


def traffic_gates(results, committed=None):
    """The CI gate table (benchmarks/README.md): returns CSV-row tuples
    ``(name, value, detail)`` for every violated gate — empty when green."""
    failures = []
    ex = results["exactness"]
    if ex["mismatches"]:
        failures.append(("traffic_results_MISMATCH", ex["mismatches"],
                         f"sampled={ex['sampled']}"))
    if ex["unflagged_divergence"]:
        failures.append(("traffic_shed_UNFLAGGED", ex["unflagged_divergence"],
                         f"sampled={ex['sampled']}"))
    occ = results["replay"]["mean_batch_occupancy"]
    if occ <= 1.0:
        failures.append(("traffic_occupancy_GATE", f"{occ:.2f}",
                         "replay occupancy must exceed 1 at saturation"))
    if results["closed_loop"]["errors"]:
        failures.append(("traffic_client_ERRORS",
                         results["closed_loop"]["errors"], "closed loop"))
    if committed is not None:
        committed_ratio = committed.get("throughput", {}).get("qps_ratio")
        ratio = results["throughput"]["qps_ratio"]
        # SAME-RUN ratio (batched vs serial on this machine, this run,
        # warm jit cache, deterministic slates) vs the committed ratio:
        # machine speed cancels, so 0.5x is a real regression, not noise
        if committed_ratio is not None and ratio < 0.5 * committed_ratio:
            failures.append(("traffic_qps_REGRESSION", f"{ratio:.2f}",
                             f"committed_ratio={committed_ratio:.2f};gate=0.5x"))
    return failures


def print_rows(results):
    c, o, r = results["closed_loop"], results["open_loop"], results["replay"]
    t = results["throughput"]
    print(f"traffic_throughput_ratio,{t['qps_ratio']:.2f},"
          f"batched_qps={t['batched_qps']:.1f};serial_qps={t['serial_qps']:.1f}")
    print(f"traffic_closed_qps,{c['sustained_qps']:.1f},"
          f"clients={c['clients']};p50_ms={c['p50_ms']:.1f};"
          f"p99_ms={c['p99_ms']:.1f};p999_ms={c['p999_ms']:.1f};"
          f"occupancy={c['mean_batch_occupancy']:.2f}")
    print(f"traffic_open_qps,{o['sustained_qps']:.1f},"
          f"offered={o['offered_qps']:.1f};p50_ms={o['p50_ms']:.1f};"
          f"p99_ms={o['p99_ms']:.1f};p999_ms={o['p999_ms']:.1f};"
          f"partial_rate={o['partial_rate']:.2f};shed_rate={o['shed_rate']:.2f}")
    print(f"traffic_replay_occupancy,{r['mean_batch_occupancy']:.2f},"
          f"batches={r['batches']};requests={r['requests']}")
    ex = results["exactness"]
    print(f"traffic_exactness,{ex['sampled']},"
          f"mismatches={ex['mismatches']};"
          f"unflagged={ex['unflagged_divergence']};"
          f"flagged_divergent={ex['flagged_divergent']}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short deterministic profile (the CI traffic step)")
    ap.add_argument("--json", type=Path, default=None,
                    help="write the profile to this path (BENCH_traffic.json)")
    ap.add_argument("--seed", type=int, default=29)
    args = ap.parse_args()

    committed_path = Path(__file__).parent.parent / "BENCH_traffic.json"
    committed = None
    if committed_path.exists():
        try:
            committed = json.loads(committed_path.read_text())
        except json.JSONDecodeError:
            pass

    print("name,value,detail")
    results = bench_traffic(quick=args.smoke, seed=args.seed)
    print_rows(results)
    failures = traffic_gates(results, committed=committed)
    for name, value, detail in failures:
        print(f"{name},{value},{detail}")
    if args.json is not None:
        args.json.write_text(json.dumps(results, indent=2) + "\n")
        print(f"# wrote {args.json}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
