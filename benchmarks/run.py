"""Benchmark harness: one section per paper table + system benches.

Prints ``name,us_per_call,derived`` CSV rows (per harness contract) and a
human-readable table; roofline sections read the dry-run artifacts.
``--json`` additionally records the serving comparison (seed per-subquery
path vs fused query-at-a-time batch) in ``BENCH_serving.json``, the
indexing/persistence numbers in ``BENCH_indexing.json``, and the §14
resilience numbers (recovery time, degraded p50/p99, the seeded
chaos-differential gate) in ``BENCH_robustness.json``, and the §16 serving
daemon's traffic profile (closed/open-loop QPS, tail latency, batch
occupancy, exactness sampling) in ``BENCH_traffic.json``.

Run: PYTHONPATH=src python -m benchmarks.run [--quick] [--json]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.load import (  # noqa: E402
    bench_traffic,
    print_rows as print_traffic_rows,
    traffic_gates,
)
from benchmarks.paper_tables import (  # noqa: E402
    INGEST_SPEEDUP_GATE,
    bench_algorithms,
    bench_arena,
    bench_duplicates,
    bench_frontend,
    bench_indexing,
    bench_ingest,
    bench_overlap,
    bench_persistence,
    bench_robustness,
    bench_roofline,
    bench_serving,
    bench_serving_results_match,
    bench_vectorized,
)

# §15.3 gate: host readout's share of one batch's phase-bracketed wall time.
# The §15.1 device-side assembly + lazy materialization must keep the host's
# post-compute work a thin constant slice on both serving paths.
READOUT_FRACTION_GATE = 0.10


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--json",
        action="store_true",
        help="write the serving comparison to BENCH_serving.json",
    )
    args = ap.parse_args()
    n_queries = 10 if args.quick else 30

    print("name,us_per_call,derived")

    # ---- paper Experiment 1/2 analogue: Fig.5/6 + postings tables ---------
    rows = bench_algorithms(n_queries=n_queries)
    se1_ms = next(r["avg_ms"] for r in rows if r["algorithm"] == "SE1")
    for r in rows:
        speedup = se1_ms / r["avg_ms"] if r["avg_ms"] else 0.0
        print(f"paper_fig5_{r['algorithm']},{r['avg_ms']*1000:.1f},"
              f"speedup_vs_SE1={speedup:.2f}")
        print(f"paper_postings_{r['algorithm']},{r['avg_postings']:.0f},"
              f"avg_kb={r['avg_kb']:.1f};intermediate={r['avg_intermediate']:.0f};"
              f"results={r['avg_results']:.1f}")

    # ---- §12 duplicate-lemma case ------------------------------------------
    dup = bench_duplicates()
    for name, d in dup.items():
        print(f"paper_dup_{name},{d['ms']*1000:.1f},"
              f"postings={d['postings']};intermediate={d['intermediate']};"
              f"results={d['results']}")

    # ---- vectorized / Pallas engines ---------------------------------------
    for r in bench_vectorized():
        print(f"engine_{r['engine']},{r['avg_ms']*1000:.1f},results={r['results']}")

    # ---- fused batched serving vs seed per-subquery path --------------------
    committed = Path(__file__).parent.parent / "BENCH_serving.json"
    committed_speedup = None
    if committed.exists():
        try:
            committed_speedup = json.loads(committed.read_text())["speedup"]
        except (json.JSONDecodeError, KeyError):
            pass
    serving = bench_serving(repeats=2 if args.quick else 5)
    for path in ("per_subquery_seed", "fused_batch"):
        print(f"serving_{path},{serving[path]['us_per_call']:.1f},"
              f"results={serving[path]['results']}")
    print(f"serving_speedup,{serving['speedup']:.2f},"
          f"dispatches_per_batch="
          f"{serving['fused_batch']['device_dispatches_per_batch']:.0f}")
    for phase, us in serving["fused_batch"]["phases_us_per_batch"].items():
        print(f"serving_phase_{phase.removesuffix('_us')},{us:.0f},per_batch")
    print(f"serving_readout_fraction,"
          f"{serving['fused_batch']['readout_fraction']:.3f},"
          f"gate={READOUT_FRACTION_GATE}")
    # CI gate (benchmarks/README.md): with the §15.1 device-side assembly the
    # host readout must stay a thin slice of the batch
    if serving["fused_batch"]["readout_fraction"] >= READOUT_FRACTION_GATE:
        print(f"readout_fraction_GATE,0,"
              f"fused={serving['fused_batch']['readout_fraction']:.3f};"
              f"gate={READOUT_FRACTION_GATE}")
        sys.exit(1)
    if not bench_serving_results_match(serving):
        print("serving_results_MISMATCH,0,"
              f"seed={serving['per_subquery_seed']['results']};"
              f"fused={serving['fused_batch']['results']}")
        sys.exit(1)
    # CI gate (benchmarks/README.md): the fused path's µs/query advantage
    # over the seed path — a SAME-RUN ratio, so machine speed cancels —
    # must stay within 2x of the committed BENCH_serving.json speedup
    if (
        committed_speedup is not None
        and serving["speedup"] < 0.5 * committed_speedup
    ):
        print(f"serving_fused_REGRESSION,{serving['speedup']:.2f},"
              f"committed_speedup={committed_speedup:.2f};gate=0.5x")
        sys.exit(1)

    # ---- device-resident posting arena vs host-pack path (DESIGN.md §13) ---
    arena = bench_arena(quick=args.quick, repeats=3 if args.quick else 5)
    for path in ("host_pack", "arena_path"):
        print(f"arena_{path},{arena[path]['us_per_query']:.1f},"
              f"results={arena[path]['results']}")
    print(f"arena_speedup,{arena['speedup']:.2f},"
          f"dispatches_per_batch={arena['device_dispatches_per_batch']};"
          f"hit_rate={arena['arena']['hit_rate']:.2f};"
          f"resident_mb={arena['arena']['resident_bytes'] / (1 << 20):.1f};"
          f"h2d_per_batch={arena['arena']['h2d_bytes_per_batch']};"
          f"upload_ms={arena['arena']['upload_sec'] * 1e3:.0f}")
    for phase, us in arena["arena_path"]["phases_us_per_batch"].items():
        print(f"arena_phase_{phase.removesuffix('_us')},{us:.0f},per_batch")
    print(f"arena_readout_fraction,"
          f"{arena['arena_path']['readout_fraction']:.3f},"
          f"gate={READOUT_FRACTION_GATE}")
    # CI gates (benchmarks/README.md): the arena must be invisible in
    # results, keep one-dispatch-per-batch serving, and hold the §15.3
    # readout budget on its own path too
    if not arena["results_match"]:
        print("arena_results_MISMATCH,0,arena != host-pack fragments")
        sys.exit(1)
    if arena["device_dispatches_per_batch"] != 1:
        print(f"arena_dispatch_GATE,0,"
              f"dispatches={arena['device_dispatches_per_batch']}")
        sys.exit(1)
    if arena["arena_path"]["readout_fraction"] >= READOUT_FRACTION_GATE:
        print(f"readout_fraction_GATE,0,"
              f"arena={arena['arena_path']['readout_fraction']:.3f};"
              f"gate={READOUT_FRACTION_GATE}")
        sys.exit(1)
    serving["arena"] = arena

    # ---- §15.2 pipelined dispatch: two-deep overlap vs serial loop ----------
    overlap = bench_overlap(
        n_queries=8 if args.quick else 16, repeats=2 if args.quick else 3
    )
    print(f"overlap_serial,{overlap['serial_us_per_query']:.1f},"
          f"max_batch={overlap['max_batch']}")
    print(f"overlap_pipelined,{overlap['pipelined_us_per_query']:.1f},"
          f"speedup={overlap['overlap_speedup']:.2f}")
    # CI gate (benchmarks/README.md): the pipelined driver must be invisible
    # in results — byte-identical responses in admission order
    if not overlap["results_match"]:
        print("overlap_results_MISMATCH,0,pipelined != serial responses")
        sys.exit(1)
    serving["overlap"] = overlap

    # ---- §15.4 serving-program roofline (fused + arena compiled HLO) --------
    roofline = bench_roofline()
    for prog in ("fused", "arena"):
        if prog not in roofline:
            continue
        r = roofline[prog]
        print(f"roofline_serving_{prog},{r['step_lower_bound_s']*1e6:.0f},"
              f"dominant={r['dominant']};"
              f"intensity={r['arithmetic_intensity']:.4f};"
              f"ridge={r['ridge_intensity']:.0f}")
    serving["roofline"] = roofline

    # ---- planner + deadline-aware frontend (cache hit rate, tail latency) ---
    frontend = bench_frontend(
        n_queries=16 if args.quick else 32, repeats=2 if args.quick else 3
    )
    for path in ("cold", "warm_cached"):
        extra = (
            f";hit_rate={frontend[path]['hit_rate']:.2f}"
            if path == "warm_cached" else ""
        )
        print(f"frontend_{path},{frontend[path]['us_per_query']:.1f},"
              f"p50_us={frontend[path]['p50_us']:.1f};"
              f"p99_us={frontend[path]['p99_us']:.1f}{extra}")
    print(f"frontend_microbatch,{frontend['microbatch']['us_per_query']:.1f},"
          f"dispatches={frontend['microbatch']['device_dispatches']}")
    print(f"frontend_deadline,{frontend['deadline']['budget_postings']:.0f},"
          f"partials={frontend['deadline']['partial_responses']};"
          f"skipped_subqueries={frontend['deadline']['skipped_subqueries']}")
    # CI gates (benchmarks/README.md): the planner/caching layer must be
    # invisible in results, and a repeat pass must be fully cache-served
    if not frontend["results_match_unplanned"]:
        print("frontend_results_MISMATCH,0,planned != unplanned fragments")
        sys.exit(1)
    if frontend["warm_cached"]["hit_rate"] < 1.0:
        print(f"frontend_cache_MISS,0,"
              f"hit_rate={frontend['warm_cached']['hit_rate']:.2f}")
        sys.exit(1)
    serving["frontend"] = frontend
    if args.json:
        out_path = Path(__file__).parent.parent / "BENCH_serving.json"
        out_path.write_text(json.dumps(serving, indent=2) + "\n")
        print(f"# wrote {out_path}")

    # ---- index construction: full build vs incremental ingest vs compact ----
    indexing = bench_indexing(quick=args.quick)
    for path in ("full_build", "incremental_pinned", "incremental_refresh"):
        print(f"indexing_{path},{indexing[path]['sec']*1e6:.0f},"
              f"docs_per_sec={indexing[path]['docs_per_sec']:.1f}")
    print(f"indexing_compact,{indexing['compact']['sec']*1e6:.0f},"
          f"segments_merged={indexing['compact']['segments_merged']};"
          f"docs_per_sec={indexing['compact']['docs_per_sec']:.1f}")
    if not indexing["results_match_rebuild"]:
        print(f"indexing_results_MISMATCH,0,{indexing['mismatch_reason']}")
        sys.exit(1)

    # ---- §17 external-memory bulk ingest ------------------------------------
    ingest = bench_ingest(
        quick=args.quick,
        artifact_dir=(Path(__file__).parent.parent / "artifacts"
                      / "ingest_spills") if args.json else None,
    )
    print(f"ingest_bulk,{ingest['bulk']['sec']*1e6:.0f},"
          f"docs_per_sec={ingest['bulk']['docs_per_sec']:.1f};"
          f"lemmatize_s={ingest['bulk']['lemmatize_s']:.2f};"
          f"spill_s={ingest['bulk']['spill_s']:.2f};"
          f"merge_s={ingest['bulk']['merge_s']:.2f};"
          f"spill_bytes={ingest['bulk']['spill_bytes']}")
    print(f"ingest_full_build_same_run,"
          f"{ingest['full_build_same_run']['sec']*1e6:.0f},"
          f"docs_per_sec={ingest['full_build_same_run']['docs_per_sec']:.1f};"
          f"same_run_ratio={ingest['speedup_same_run']:.2f}")
    # CI gates (benchmarks/README.md): the published bulk snapshot must be
    # index_sets_equal to the in-RAM build (exactness first), and bulk
    # throughput must clear 10x the frozen pre-§17 full-build figure
    if not ingest["ingest_equality"]:
        print(f"ingest_equality_GATE,0,{ingest['mismatch_reason']}")
        sys.exit(1)
    if ingest["speedup_vs_seed_full_build"] < INGEST_SPEEDUP_GATE:
        print(f"ingest_speedup_GATE,0,"
              f"speedup={ingest['speedup_vs_seed_full_build']:.2f};"
              f"gate={INGEST_SPEEDUP_GATE}x_vs_"
              f"{ingest['seed_full_build_docs_per_sec']}_docs_per_sec")
        sys.exit(1)
    print(f"ingest_speedup,{ingest['bulk']['sec']*1e6:.0f},"
          f"vs_seed_full_build={ingest['speedup_vs_seed_full_build']:.2f}x;"
          f"gate={INGEST_SPEEDUP_GATE}x")
    indexing["ingest"] = ingest

    # ---- durable index store: snapshot / restore / compression --------------
    persistence = bench_persistence(quick=args.quick)
    for path in ("snapshot", "rebuild", "restore"):
        print(f"persistence_{path},{persistence[path]['sec']*1e6:.0f},"
              f"docs_per_sec={persistence[path]['docs_per_sec']:.1f}")
    print(f"persistence_cold_boot,{persistence['restore']['sec']*1e6:.0f},"
          f"speedup_vs_rebuild={persistence['restore']['speedup_vs_rebuild']:.1f};"
          f"first_touch_us={persistence['first_touch']['sec']*1e6:.0f}")
    print(f"persistence_compression,{persistence['compression']['posting_blob_bytes']},"
          f"ratio={persistence['compression']['ratio']:.2f};"
          f"memory_bytes={persistence['compression']['memory_bytes']}")
    # CI gates (benchmarks/README.md): restore must be exact, the §12.1 codec
    # must actually compress, and the cold-boot claim must hold with margin
    if not persistence["restore_equality"]:
        print(f"persistence_restore_MISMATCH,0,{persistence['mismatch_reason']}")
        sys.exit(1)
    if persistence["compression"]["ratio"] < 1.5:
        print(f"persistence_compression_LOW,0,"
              f"ratio={persistence['compression']['ratio']:.2f}")
        sys.exit(1)
    if persistence["restore"]["speedup_vs_rebuild"] < 5.0:
        print(f"persistence_cold_boot_SLOW,0,"
              f"speedup={persistence['restore']['speedup_vs_rebuild']:.1f}")
        sys.exit(1)
    indexing["persistence"] = persistence
    if args.json:
        out_path = Path(__file__).parent.parent / "BENCH_indexing.json"
        out_path.write_text(json.dumps(indexing, indent=2) + "\n")
        print(f"# wrote {out_path}")

    # ---- resilient serving under injected faults (DESIGN.md §14) -----------
    robustness = bench_robustness(quick=args.quick)
    ff, deg, rec, chaos, walrep = (robustness[k] for k in
                                   ("fault_free", "degraded", "recovery",
                                    "chaos", "wal_replay"))
    print(f"robustness_fault_free,{ff['p50_us']:.0f},"
          f"p99_us={ff['p99_us']:.0f};counters_clean={ff['counters_clean']}")
    print(f"robustness_degraded,{deg['p50_us']:.0f},"
          f"p99_us={deg['p99_us']:.0f};flagged_rate={deg['flagged_rate']:.2f}")
    print(f"robustness_recovery,{rec['batch_ms']*1000:.0f},"
          f"batch_ms={rec['batch_ms']:.1f};"
          f"fault_free_batch_ms={rec['fault_free_batch_ms']:.1f}")
    print(f"robustness_chaos,{chaos['responses']},"
          f"seeds={len(chaos['seeds'])};flagged={chaos['flagged']};"
          f"faults_fired={chaos['faults_fired']};"
          f"mismatches={chaos['mismatches']}")
    print(f"robustness_wal_replay,{walrep['ms_per_1k_records']:.1f},"
          f"records={walrep['records']};replay_ms={walrep['replay_ms']:.1f};"
          f"results_match={walrep['results_match']}")
    # CI gates (benchmarks/README.md): under ANY seeded fault schedule every
    # response must be exact or flagged-partial-with-exact-coverage; the
    # chaos sweep must actually exercise the degraded path (flagged >= 1);
    # a degraded fan-out must flag 100% of its responses; fault-free
    # traffic must leave every §14 counter zero; and §18.2 recovery must
    # stay within 10x of the fault-free batch (the MTTR bound)
    if chaos["mismatches"] or not robustness["results_match"]:
        print(f"chaos_results_MISMATCH,0,mismatches={chaos['mismatches']};"
              f"fault_free={ff['results_match']};"
              f"degraded={deg['results_match']};"
              f"recovery={rec['results_match']};"
              f"wal_replay={walrep['results_match']}")
        sys.exit(1)
    if chaos["flagged"] < 1:
        print(f"robustness_chaos_flag_GATE,0,flagged={chaos['flagged']};"
              "unrecoverable schedule produced no degraded responses")
        sys.exit(1)
    if deg["flagged_rate"] < 1.0:
        print(f"robustness_flag_GATE,0,flagged_rate={deg['flagged_rate']:.2f}")
        sys.exit(1)
    if not ff["counters_clean"]:
        print("robustness_counters_DIRTY,0,fault-free counters non-zero")
        sys.exit(1)
    if rec["batch_ms"] > 10 * rec["fault_free_batch_ms"]:
        print(f"robustness_mttr_GATE,0,batch_ms={rec['batch_ms']:.1f};"
              f"fault_free_batch_ms={rec['fault_free_batch_ms']:.1f};"
              "recovery batch exceeded 10x fault-free")
        sys.exit(1)
    if args.json:
        out_path = Path(__file__).parent.parent / "BENCH_robustness.json"
        out_path.write_text(json.dumps(robustness, indent=2) + "\n")
        print(f"# wrote {out_path}")

    # ---- §16 serving daemon under load: traffic profile + gates -------------
    committed_traffic_path = Path(__file__).parent.parent / "BENCH_traffic.json"
    committed_traffic = None
    if committed_traffic_path.exists():
        try:
            committed_traffic = json.loads(committed_traffic_path.read_text())
        except json.JSONDecodeError:
            pass
    traffic = bench_traffic(quick=args.quick)
    print_traffic_rows(traffic)
    # CI gates (benchmarks/README.md): sampled daemon responses must match
    # the single-frontend reference or carry a partial/shed flag; the virtual
    # replay must show continuous batching (occupancy > 1); and the SAME-RUN
    # batched-vs-serial QPS ratio must stay within 2x of the committed one
    traffic_failures = traffic_gates(traffic, committed=committed_traffic)
    for name, value, detail in traffic_failures:
        print(f"{name},{value},{detail}")
    if traffic_failures:
        sys.exit(1)
    if args.json:
        committed_traffic_path.write_text(json.dumps(traffic, indent=2) + "\n")
        print(f"# wrote {committed_traffic_path}")

    # ---- roofline (from dry-run artifacts, if present) ----------------------
    try:
        from benchmarks.roofline import load_records, roofline_terms

        recs = load_records()
        for r in recs:
            t = roofline_terms(r)
            print(f"roofline_{r['arch']}__{r['shape']},"
                  f"{t['step_lower_bound_s']*1e6:.0f},"
                  f"dominant={t['dominant']};frac={t['roofline_fraction']:.3f};"
                  f"model_over_hlo={t['model_over_hlo_flops']:.3f}")
    except Exception as e:  # artifacts absent on a fresh checkout
        print(f"roofline_skipped,0,reason={type(e).__name__}")


if __name__ == "__main__":
    main()
