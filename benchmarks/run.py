"""Benchmark harness: one section per paper table + system benches.

Prints ``name,us_per_call,derived`` CSV rows (per harness contract) and a
human-readable table; roofline sections read the dry-run artifacts.

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.paper_tables import (  # noqa: E402
    bench_algorithms,
    bench_duplicates,
    bench_vectorized,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    n_queries = 10 if args.quick else 30

    print("name,us_per_call,derived")

    # ---- paper Experiment 1/2 analogue: Fig.5/6 + postings tables ---------
    rows = bench_algorithms(n_queries=n_queries)
    se1_ms = next(r["avg_ms"] for r in rows if r["algorithm"] == "SE1")
    for r in rows:
        speedup = se1_ms / r["avg_ms"] if r["avg_ms"] else 0.0
        print(f"paper_fig5_{r['algorithm']},{r['avg_ms']*1000:.1f},"
              f"speedup_vs_SE1={speedup:.2f}")
        print(f"paper_postings_{r['algorithm']},{r['avg_postings']:.0f},"
              f"avg_kb={r['avg_kb']:.1f};intermediate={r['avg_intermediate']:.0f};"
              f"results={r['avg_results']:.1f}")

    # ---- §12 duplicate-lemma case ------------------------------------------
    dup = bench_duplicates()
    for name, d in dup.items():
        print(f"paper_dup_{name},{d['ms']*1000:.1f},"
              f"postings={d['postings']};intermediate={d['intermediate']};"
              f"results={d['results']}")

    # ---- vectorized / Pallas engines ---------------------------------------
    for r in bench_vectorized():
        print(f"engine_{r['engine']},{r['avg_ms']*1000:.1f},results={r['results']}")

    # ---- roofline (from dry-run artifacts, if present) ----------------------
    try:
        from benchmarks.roofline import load_records, roofline_terms

        recs = load_records()
        for r in recs:
            t = roofline_terms(r)
            print(f"roofline_{r['arch']}__{r['shape']},"
                  f"{t['step_lower_bound_s']*1e6:.0f},"
                  f"dominant={t['dominant']};frac={t['roofline_fraction']:.3f};"
                  f"model_over_hlo={t['model_over_hlo_flops']:.3f}")
    except Exception as e:  # artifacts absent on a fresh checkout
        print(f"roofline_skipped,0,reason={type(e).__name__}")


if __name__ == "__main__":
    main()
