"""Vectorized (batched, device-side) subquery execution.

This is the serving-path implementation of the Combiner: identical result
semantics to ``core/combiner.py`` (validated in tests), expressed through the
fused query-at-a-time pipeline in ``search/fused.py`` — compact (doc_slot,
pos, lemma) event transport, on-device scatter + window cover + §14 scoring +
per-query top-k in ONE jit'd program per query batch, and a single-`nonzero`
fragment readout.

Used by ``search/distributed.py`` (document-sharded serving) and by the
``paper_search`` architecture's ``serve_step``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from ..core.keys import SelectedKey, Subquery
from ..core.postings import QueryStats, SearchResult
from ..index.builder import IndexSet
from .fused import (
    FusedBatchResult,
    bucket_pow2,
    extract_segment_events,
    serve_query_batch,
)

__all__ = ["VectorizedEngine", "PackedEvents", "pack_subquery_events"]


@dataclass
class PackedEvents:
    """Compact fixed-shape event transport for one subquery (DESIGN.md §9.1).

    ``events`` replaces the old dense ``[B, L, doc_len]`` host occupancy: the
    device scatter rebuilds occupancy on-chip from E event triples, so host
    transport is O(events), not O(docs * lemmas * doc_len).
    """

    events: np.ndarray  # [E, 3] int32 (doc_slot, pos, lemma), pad = -1
    doc_ids: np.ndarray  # [B] int32 (pad = -1)
    mult: np.ndarray  # [L] int32
    lemmas: list[str]  # local lemma id -> lemma


def pack_subquery_events(
    subquery: Subquery,
    index: IndexSet,
    keys: Sequence[SelectedKey] | None = None,
    doc_len: int = 512,
    stats: QueryStats | None = None,
) -> PackedEvents | None:
    """Host-side: key postings -> compact event triples (§10.4's Set calls,
    batched).  Dedup is free: the on-device occupancy scatter is idempotent.

    Returns ``None`` for an empty subquery — callers short-circuit before the
    device call instead of dispatching an all-padding batch (the skip is
    counted in ``QueryStats.empty_subqueries``).  Budgets are padded to
    powers of two: stable shapes -> jit cache hits.
    """
    seg = extract_segment_events(
        subquery, index, keys=keys, doc_len=doc_len, stats=stats
    )
    if seg is None:
        return None
    e_budget = bucket_pow2(len(seg.slot), lo=64)
    b_budget = bucket_pow2(len(seg.doc_ids), lo=8)
    events = np.full((e_budget, 3), -1, np.int32)
    events[: len(seg.slot), 0] = seg.slot
    events[: len(seg.slot), 1] = seg.pos
    events[: len(seg.slot), 2] = seg.lem
    doc_ids = np.full((b_budget,), -1, np.int32)
    doc_ids[: len(seg.doc_ids)] = seg.doc_ids
    return PackedEvents(
        events=events, doc_ids=doc_ids, mult=seg.mult, lemmas=seg.lemmas
    )


class VectorizedEngine:
    """Batched Combiner over one index shard (the DESIGN.md §9 fused serving
    pipeline); fragment sets identical to the scalar §10 Combiner."""

    def __init__(
        self,
        index: IndexSet,
        use_kernel: bool = False,
        doc_len: int = 512,
        compute_dtype: str = "uint8",
        arena=None,
    ):
        # plain IndexSet or IncrementalIndexer (live view resolved per call)
        self._index_source = index
        self.use_kernel = use_kernel
        self.doc_len = doc_len
        self.compute_dtype = compute_dtype
        # optional device-resident posting arena (DESIGN.md §13): resident
        # keys gather/pack on device, others fall back to the host path
        self.arena = arena

    @property
    def index(self) -> IndexSet:
        from ..index.incremental import as_index_set

        return as_index_set(self._index_source)

    def search_query_batch(
        self,
        batch: Sequence[Sequence[Subquery]],
        top_k: int = 16,
        per_query_stats: Sequence[QueryStats] | None = None,
    ) -> tuple[FusedBatchResult, QueryStats]:
        """Serve a whole query batch with ONE device program.

        ``batch[qi]`` lists query ``qi``'s subqueries; the result carries the
        exact (deduplicated) fragment union per query plus the device-side
        slot-level top-k ranking.  ``per_query_stats`` (one accumulator per
        query) splits the I/O accounting per query; the returned stats stay
        batch-level either way.
        """
        stats = QueryStats()
        view = self.index
        work = [[(sub, view) for sub in subs] for subs in batch]
        residencies = None
        if self.arena is not None:
            from ..index.incremental import generation_token

            res = self.arena.acquire(view, generation_token(self._index_source))
            residencies = {id(view): res}
        result = serve_query_batch(
            work,
            max_distance=view.max_distance,
            top_k=top_k,
            doc_len=self.doc_len,
            use_kernel=self.use_kernel,
            compute_dtype=self.compute_dtype,
            stats=per_query_stats if per_query_stats is not None else stats,
            batch_stats=stats,
            residencies=residencies,
        )
        if per_query_stats is not None:
            for st in per_query_stats:
                st.device_dispatches = stats.device_dispatches
                stats.postings_read += st.postings_read
                stats.bytes_read += st.bytes_read
                stats.empty_subqueries += st.empty_subqueries
        # offset arithmetic, not len(per_query[qi]): counting must not force
        # the lazy SearchResult materialization of the §15.1 device readout
        stats.results = sum(result.n_results(qi) for qi in range(len(batch)))
        return result, stats

    def search_subquery(
        self, subquery: Subquery
    ) -> tuple[list[SearchResult], QueryStats]:
        result, stats = self.search_query_batch([[subquery]])
        return result.per_query[0], stats
