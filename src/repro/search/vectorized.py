"""Vectorized (batched, device-side) subquery execution.

This is the serving-path implementation of the Combiner: identical result
semantics to ``core/combiner.py`` (validated in tests), but expressed as
fixed-shape array programs — scatter postings into per-document occupancy,
run the parallel window cover (Pallas kernel or jnp ref), read fragments out.

Used by ``search/distributed.py`` (document-sharded shard_map serving) and
by the ``paper_search`` architecture's ``serve_step``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from ..core.keys import SelectedKey, Subquery, select_keys
from ..core.postings import QueryStats, SearchResult
from ..core.window import results_from_cover
from ..index.builder import IndexSet
from ..kernels.ops import proximity_search_scores

__all__ = ["VectorizedEngine", "pack_subquery_events"]


@dataclass
class PackedEvents:
    """Fixed-shape per-document event tensors for one subquery."""

    doc_ids: np.ndarray  # [B] int32 (pad = -1)
    occ: np.ndarray  # [B, L, N] int32
    mult: np.ndarray  # [L] int32
    lemmas: list[str]  # local lemma id -> lemma


def pack_subquery_events(
    subquery: Subquery,
    index: IndexSet,
    keys: Sequence[SelectedKey] | None = None,
    doc_len: int = 512,
    stats: QueryStats | None = None,
) -> PackedEvents:
    """Host-side: key postings -> dense per-doc occupancy (§10.4's Set calls,
    batched).  Dedup is free: occupancy is idempotent under scatter."""
    keys = list(keys) if keys is not None else select_keys(subquery, index.fl)
    lemmas = subquery.unique_lemmas()
    lid = {l: i for i, l in enumerate(lemmas)}
    L = len(lemmas)
    mult_map = subquery.multiplicity()
    mult = np.array([mult_map[l] for l in lemmas], dtype=np.int32)

    # vectorized event extraction: one (doc, pos, lemma) column set per
    # unstarred key slot — no per-posting Python work
    ev_doc, ev_pos, ev_lem = [], [], []
    for key in keys:
        rows = np.asarray(index.key_postings(key.components))
        if stats is not None:
            stats.postings_read += len(rows)
            stats.bytes_read += rows.nbytes
        if not len(rows):
            continue
        comps, stars = key.components, key.starred
        for slot in range(len(comps)):
            if stars[slot]:
                continue
            pos = rows[:, 1] if slot == 0 else rows[:, 1] + rows[:, 1 + slot]
            ev_doc.append(rows[:, 0])
            ev_pos.append(pos)
            ev_lem.append(np.full(len(rows), lid[comps[slot]], np.int32))
    if ev_doc:
        doc_a = np.concatenate(ev_doc)
        pos_a = np.concatenate(ev_pos)
        lem_a = np.concatenate(ev_lem)
        ok = (pos_a >= 0) & (pos_a < doc_len)
        doc_a, pos_a, lem_a = doc_a[ok], pos_a[ok], lem_a[ok]
        docs, doc_idx = np.unique(doc_a, return_inverse=True)
    else:
        docs = np.empty((0,), np.int32)
    # pad the doc batch to a power of two: stable shapes -> jit cache hits
    b_real = max(1, len(docs))
    B = 1 << (b_real - 1).bit_length()
    occ_t = np.zeros((B, L, doc_len), dtype=np.int32)
    doc_ids = np.full((B,), -1, dtype=np.int32)
    if len(docs):
        occ_t[doc_idx, lem_a, pos_a] = 1
        doc_ids[: len(docs)] = docs
    return PackedEvents(doc_ids=doc_ids, occ=occ_t, mult=mult, lemmas=lemmas)


class VectorizedEngine:
    """Batched Combiner over one index shard."""

    def __init__(self, index: IndexSet, use_kernel: bool = False, doc_len: int = 512):
        self.index = index
        self.use_kernel = use_kernel
        self.doc_len = doc_len

    def search_subquery(
        self, subquery: Subquery
    ) -> tuple[list[SearchResult], QueryStats]:
        stats = QueryStats()
        packed = pack_subquery_events(
            subquery, self.index, doc_len=self.doc_len, stats=stats
        )
        B = packed.occ.shape[0]
        mult = np.broadcast_to(packed.mult, (B, packed.mult.shape[0]))
        emit, start, scores = proximity_search_scores(
            jnp.asarray(packed.occ),
            jnp.asarray(mult),
            self.index.max_distance,
            use_kernel=self.use_kernel,
        )
        emit_np, start_np = np.asarray(emit), np.asarray(start)
        results: list[SearchResult] = []
        for i, doc in enumerate(packed.doc_ids.tolist()):
            if doc < 0:
                continue
            for d, s, e in results_from_cover(doc, emit_np[i], start_np[i]):
                results.append(SearchResult(doc_id=d, start=s, end=e))
        stats.results = len(results)
        return results, stats
