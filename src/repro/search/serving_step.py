"""Device-side, fixed-shape serving & index-build steps for ``paper_search``.

These are the jit-compiled programs the dry-run lowers for the paper's own
architecture — the full query pipeline after host-side key lookup:

  ``serve_step``:  postings -> (scatter) per-cluster occupancy -> parallel
                   window cover -> §14 relevance -> per-query top-k docs.
  ``build_step``:  token streams -> windowed stop-lemma triple extraction
                   (the (f,s,t) index build cost model) -> key histogram.

Shapes: B queries x P postings x C candidate clusters x L lemmas x N window
positions — all static budgets (real serving packs variable work into these,
exactly like padded batching in LM serving).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from ..core.window import window_cover_batch

__all__ = ["serve_step", "build_step"]




@functools.partial(
    jax.jit,
    static_argnames=("max_distance", "top_k", "n_clusters", "window_len", "compute_dtype"),
)
def serve_step(
    postings: jax.Array,  # [B, P, 3] int32: (cluster, rel_pos, lemma) pad=-1
    cluster_doc: jax.Array,  # [B, C] int32 doc id per cluster (pad=-1)
    mult: jax.Array,  # [B, L] int32 subquery multiplicities
    *,
    max_distance: int,
    n_clusters: int,
    window_len: int,
    top_k: int = 16,
    compute_dtype: str = "uint8",  # §Perf-3: occupancy/prefix counts fit u8
):
    """One fixed-shape serving step: scatter §10.4 posting events into
    per-cluster occupancy, run the vectorized §10.2 window cover, score §14
    relevance and select per-query top-k docs (see module docstring)."""
    b, p, _ = postings.shape
    l = mult.shape[1]
    c, n = n_clusters, window_len
    cdt = jnp.dtype(compute_dtype)

    # ---- stage 1: scatter postings into per-cluster occupancy -------------
    cl = postings[..., 0]
    pos = postings[..., 1]
    lem = postings[..., 2]
    ok = (cl >= 0) & (pos >= 0) & (pos < n) & (lem >= 0)
    flat = (jnp.maximum(cl, 0) * l + jnp.maximum(lem, 0)) * n + jnp.maximum(pos, 0)
    occ_flat = jnp.zeros((b, c * l * n), cdt)
    occ = jax.vmap(
        lambda of, fl, okk: of.at[fl].max(okk.astype(cdt))
    )(occ_flat, flat, ok)
    occ = occ.reshape(b, c, l, n)

    # ---- stage 2: parallel window cover (the Combiner, vectorized) --------
    occ2 = occ.reshape(b * c, l, n)
    mult2 = jnp.repeat(mult, c, axis=0).astype(cdt)
    emit, start = window_cover_batch(occ2, mult2, window=2 * max_distance + 1)

    # ---- stage 3: §14 relevance + per-query top-k docs ---------------------
    span = jnp.arange(n, dtype=jnp.float32)[None, :] - start.astype(jnp.float32)
    contrib = jnp.where(emit, 1.0 / (span + 1.0) ** 2, 0.0)
    scores = contrib.sum(axis=-1).reshape(b, c)
    scores = jnp.where(cluster_doc >= 0, scores, -1.0)
    top_scores, top_idx = jax.lax.top_k(scores, min(top_k, c))
    top_docs = jnp.take_along_axis(cluster_doc, top_idx, axis=1)
    n_fragments = emit.reshape(b, c, n).sum(axis=(1, 2))
    return {
        "top_docs": top_docs,
        "top_scores": top_scores,
        "n_fragments": n_fragments,
    }


@functools.partial(
    jax.jit,
    static_argnames=("max_distance", "top_k", "n_clusters", "window_len", "compute_dtype"),
)
def serve_step_sharded(
    postings: jax.Array,  # [NS, B, P_loc, 3] int32, cluster ids shard-local
    cluster_doc: jax.Array,  # [NS, B, C_loc] int32
    mult: jax.Array,  # [B, L]
    *,
    max_distance: int,
    n_clusters: int,  # C_loc (per shard)
    window_len: int,
    top_k: int = 16,
    compute_dtype: str = "uint8",
):
    """Document-sharded serving (§Perf-3 iteration 3, the deployed layout).

    Each device owns one cluster shard's postings end-to-end: local scatter,
    local cover, local top-k.  The only collective is the final tree merge of
    per-shard top-k lists (KBs).  This is exactly DESIGN.md §4's
    document-parallel layout — B stays replicated, clusters are the grid.
    """
    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    mesh = get_mesh() if get_mesh is not None else None
    ns, b = postings.shape[0], postings.shape[1]
    kk = min(top_k, n_clusters)

    def local(post, cdoc, m):
        out = serve_step(
            post[0], cdoc[0], m,
            max_distance=max_distance, n_clusters=n_clusters,
            window_len=window_len, top_k=kk, compute_dtype=compute_dtype,
        )
        return (
            out["top_docs"][None],
            out["top_scores"][None],
            out["n_fragments"][None],
        )

    if mesh is not None and mesh.axis_names:
        axes = tuple(mesh.axis_names)
        inner = jax.shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axes), P(axes), P()),
            out_specs=(P(axes), P(axes), P(axes)),
            check_vma=False,
        )
        docs, scores, nfrag = inner(postings, cluster_doc, mult)
    else:
        # host fallback (tests): vmap over the shard axis
        docs, scores, nfrag = jax.vmap(
            lambda pp, cc: tuple(x[0] for x in local(pp[None], cc[None], mult))
        )(postings, cluster_doc)
    # tree merge: [NS, B, K] -> global top-k per query (tiny all-gather)
    docs_t = docs.transpose(1, 0, 2).reshape(b, -1)
    scores_t = scores.transpose(1, 0, 2).reshape(b, -1)
    top_scores, idx = jax.lax.top_k(scores_t, min(top_k, scores_t.shape[-1]))
    top_docs = jnp.take_along_axis(docs_t, idx, axis=1)
    return {
        "top_docs": top_docs,
        "top_scores": top_scores,
        "n_fragments": nfrag.sum(axis=0),
    }


@functools.partial(jax.jit, static_argnames=("max_distance", "n_buckets"))
def build_step(
    tokens: jax.Array,  # [B, N] int32 lemma FL-numbers
    is_stop: jax.Array,  # [B, N] bool
    *,
    max_distance: int,
    n_buckets: int = 65536,
):
    """Windowed (f,s,t) co-occurrence extraction over token streams.

    For every center position and offset pair (d1, d2), d1 < d2, both within
    ±MaxDistance: a triple posting exists when all three positions hold stop
    lemmas.  Postings are hash-bucketed (the shard-local histogram a real
    builder uses to size posting lists before the big segmented sort).
    """
    b, n = tokens.shape
    d = max_distance
    t32 = tokens.astype(jnp.uint32)

    def shift(x, o):
        if o == 0:
            return x
        if o > 0:
            pad = jnp.zeros((b, o), x.dtype)
            return jnp.concatenate([pad, x[:, : n - o]], axis=1)
        pad = jnp.zeros((b, -o), x.dtype)
        return jnp.concatenate([x[:, -o:], pad], axis=1)

    hist = jnp.zeros((n_buckets,), jnp.int32)
    total = jnp.zeros((), jnp.int32)
    stop = is_stop.astype(jnp.int32)
    offsets = [
        (d1, d2)
        for d1 in range(-d, d + 1)
        for d2 in range(-d, d + 1)
        if d1 != 0 and d2 != 0 and d1 < d2
    ]
    for d1, d2 in offsets:  # static unroll: |offsets| = D*(2D-1)
        valid = stop * shift(stop, -d1) * shift(stop, -d2)
        s1 = shift(t32, -d1)
        s2 = shift(t32, -d2)
        h = (t32 * jnp.uint32(2654435761) ^ s1 * jnp.uint32(40503) ^ s2) % n_buckets
        hist = hist.at[h.reshape(-1)].add(valid.reshape(-1))
        total = total + valid.sum()
    return {"bucket_histogram": hist, "n_postings": total}
