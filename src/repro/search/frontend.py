"""Deadline-aware serving front-end (arXiv 2009.03679's guarantee, §5 serving).

The missing layer between "a library of engines" and "a servable system":
:class:`ServingFrontend` sits in front of any index source (plain
``IndexSet``, ``IncrementalIndexer``, or ``ShardedSearchService``) and adds
the three things heavy traffic needs (ROADMAP north star):

* **Micro-batching** — concurrent requests are admitted into batches of at
  most ``max_batch`` and each admitted batch is ONE fused device dispatch
  (``search/fused.py``); per-request latency amortizes the dispatch exactly
  like LM serving batches decode steps.  Consecutive chunks run as a
  two-deep pipeline (DESIGN.md §15.2): chunk N+1's plan/pack/H2D overlaps
  chunk N's device compute, riding jax async dispatch — responses stay in
  admission order and byte-identical to the serial loop.
* **Caching** — two LRU caches keyed by the index source's generation token
  (``index.incremental.generation_token``): a whole-query result cache and a
  hot posting-slice cache that the planner's cost probe warms (plan-time
  reads ARE the prefetch).  A ``commit``/``compact``/``delete`` bumps the
  token, so stale entries become unreachable without any explicit flush —
  cache-invalidation-after-compact is pinned by ``tests/test_planner.py``.
* **Arena residency** (DESIGN.md §13, opt-in via ``arena_budget_mb``) — hot
  posting columns upload to the device once per generation token and
  batches then gather/pack on device from descriptors; ``warmup()``
  precompiles the bucketed device programs so cold p99 excludes jit
  compile.  Fragments are identical with the arena on or off.
* **Resilience** (DESIGN.md §14) — over a source with an enabled
  ``ShardSupervisor`` (``search/resilience.py``) every slate first runs the
  shard probe barrier: crashed shards are retried/hedged/recovered before
  views resolve, and responses that could not cover every shard are flagged
  (``QueryStats.shards_degraded`` / ``partial``) and exactly ranked over
  the shards they did cover — never silently wrong.  Opt-in
  ``max_inflight`` load shedding re-admits overflow misses under
  ``shed_deadline_sec`` through the same partial machinery (flagged via
  ``QueryStats.shed``) instead of erroring.
* **Deadlines** — per-request response-time budgets enforced at *admission*
  (the 2009.03679 approach: bound the work before dispatch, don't abort
  mid-kernel).  Estimated cost is the plan's exact posting counts divided by
  a calibrated throughput (EWMA over observed batches); subqueries are
  admitted cheapest-first until the budget is spent.  An early-exited
  response is **partial but still correctly ranked**: every returned
  fragment and score is exact for the executed subqueries (skipped
  subqueries could only add fragments), and it is flagged via
  ``QueryStats.partial`` / ``skipped_subqueries``.

Exactness contract: with no deadline pressure, frontend responses are
fragment-identical to the unplanned SE2.4 / fused engines on the same live
view (the §10 oracle differential in ``tests/test_planner.py``).
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Sequence

import numpy as np

from ..core.lemma import Lemmatizer
from ..core.postings import QueryStats
from ..index.builder import IndexSet
from ..index.incremental import generation_token
from ..runtime.clock import SystemClock
from .planner import QueryPlan, QueryPlanner, SubqueryPlan, execute_plans, resolve_index_views

__all__ = ["SearchRequest", "ServingFrontend", "PostingCache"]


@dataclass(frozen=True)
class SearchRequest:
    """One serving request: a word query plus its §5 serving parameters.

    ``deadline_sec`` is the response-time budget (arXiv 2009.03679); ``None``
    falls back to the frontend default, and 0 (or negative) admits no work —
    an immediate empty *partial* response.
    """

    query: str
    top_k: int = 10
    deadline_sec: float | None = None


class PostingCache:
    """Byte-budgeted LRU over merged posting slices (§4 sorted arrays).

    Entries are keyed ``(generation token, shard, canonical key)`` — a
    generation bump strands old entries, which age out by LRU; the arrays
    themselves are the immutable merge outputs of the live view, shared (not
    copied) with execution, so a hit saves the ``SegmentedIndexSet`` k-way
    merge *and* keeps plan cost == execution cost exact.
    """

    def __init__(self, capacity_bytes: int = 64 << 20):
        self.capacity_bytes = capacity_bytes
        self._entries: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0

    def get(self, key: tuple):
        arr = self._entries.get(key)
        if arr is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return arr

    def put(self, key: tuple, arr) -> None:
        nbytes = int(getattr(arr, "nbytes", 0))
        if nbytes > self.capacity_bytes:
            return  # one slice larger than the whole budget: never cache
        old = self._entries.pop(key, None)
        if old is not None:
            self._bytes -= int(getattr(old, "nbytes", 0))
        self._entries[key] = arr
        self._bytes += nbytes
        while self._bytes > self.capacity_bytes and self._entries:
            _, evicted = self._entries.popitem(last=False)
            self._bytes -= int(getattr(evicted, "nbytes", 0))

    @property
    def size_bytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._entries)


class _CachedView:
    """A posting-cache wrapper around one live shard view.

    Duck-compatible with the slice of the ``IndexSet`` surface the planned
    execution path touches (``n_docs``, ``fl``, ``max_distance``,
    ``key_postings``); lookups go through the frontend's :class:`PostingCache`
    keyed by (generation, shard), so planner probes and execution reads share
    one fetch of each hot slice.
    """

    __slots__ = ("_base", "_cache", "_key_prefix")

    def __init__(self, base: IndexSet, cache: PostingCache, key_prefix: tuple):
        self._base = base
        self._cache = cache
        self._key_prefix = key_prefix

    @property
    def n_docs(self) -> int:
        return self._base.n_docs

    @property
    def fl(self):
        return self._base.fl

    @property
    def max_distance(self) -> int:
        return self._base.max_distance

    def key_postings(self, key: tuple):
        ck = self._key_prefix + (key,)
        arr = self._cache.get(ck)
        if arr is None:
            arr = self._base.key_postings(key)
            self._cache.put(ck, arr)
        return arr


class ServingFrontend:
    """Micro-batching, caching, deadline-aware serving front door (§5).

    Wraps any index source the engines accept and serves whole requests:
    plan (classify + bind + cost, ``search/planner.py``) -> admit under the
    deadline budget -> micro-batch -> ONE fused dispatch per admitted batch
    -> exact rank -> cache.  See the module docstring for the exactness and
    partial-result contracts.
    """

    def __init__(
        self,
        source,
        *,
        lemmatizer: Lemmatizer | None = None,
        max_batch: int = 16,
        result_cache_entries: int = 512,
        posting_cache_bytes: int = 64 << 20,
        default_deadline_sec: float | None = None,
        postings_per_sec: float = 2e6,
        calibrate: bool = True,
        use_kernel: bool = False,
        doc_len: int = 512,
        compute_dtype: str = "uint8",
        arena_budget_mb: float = 0.0,
        arena=None,
        max_inflight: int | None = None,
        shed_deadline_sec: float = 0.0,
        pipeline: bool = True,
        clock=None,
    ):
        self._source = source
        # injectable clock (DESIGN.md §16.4): every deadline/EWMA timing in
        # this frontend reads it, so tests drive a ManualClock to exact
        # tick boundaries while production (SystemClock) is unchanged
        self.clock = clock or SystemClock()
        self.max_batch = max(1, int(max_batch))
        # two-deep micro-batch pipeline (DESIGN.md §15.2): overlap batch
        # N+1's plan/pack/H2D with batch N's device compute.  Responses are
        # byte-identical with it on or off; off = the serial reference.
        self.pipeline = bool(pipeline)
        # admission-control load shedding (DESIGN.md §14): at most
        # max_inflight planned misses per slate run at full budget; the
        # overflow re-admits under shed_deadline_sec -> flagged partial
        self.max_inflight = max_inflight if max_inflight is None else max(0, int(max_inflight))
        self.shed_deadline_sec = float(shed_deadline_sec)
        self.default_deadline_sec = default_deadline_sec
        self.postings_per_sec = float(postings_per_sec)
        self.calibrate = calibrate
        self.use_kernel = use_kernel
        self.doc_len = doc_len
        self.compute_dtype = compute_dtype
        # device-resident posting arena (DESIGN.md §13): opt-in via a byte
        # budget (or an externally shared PostingArena).  Resident keys
        # gather/pack on device; non-resident keys keep the host path, so
        # enabling the arena never changes fragments, only locality.  Only
        # an arena this frontend CREATED is attached to the source's
        # mutation hook (and detached by ``close()``); a shared arena's
        # attach/detach lifecycle belongs to its owner — attaching here too
        # would duplicate listeners.
        self._owns_arena = False
        if arena is None and arena_budget_mb and arena_budget_mb > 0:
            from .arena import PostingArena

            arena = PostingArena(budget_bytes=int(arena_budget_mb * (1 << 20)))
            arena.attach(source)
            self._owns_arena = True
        self.arena = arena
        self.planner = QueryPlanner(source, lemmatizer=lemmatizer)
        self.posting_cache = PostingCache(capacity_bytes=posting_cache_bytes)
        self._result_cache: OrderedDict[tuple, object] = OrderedDict()
        self._result_cache_entries = max(1, int(result_cache_entries))
        self._result_hits = 0
        self._result_misses = 0
        self._partials = 0
        self._served = 0
        self._sheds = 0

    # ---- warm start (DESIGN.md §12.5) ------------------------------------

    @classmethod
    def from_snapshot(
        cls,
        directory,
        *,
        use_mmap: bool = True,
        verify: bool = True,
        lemmatizer: Lemmatizer | None = None,
        warmup_shapes: Sequence[tuple] | None = None,
        **kwargs,
    ) -> "ServingFrontend":
        """Warm-start a frontend from a §12.2 snapshot directory: a sharded
        service snapshot (``service.json`` present) restores a
        ``ShardedSearchService``, anything else restores a single
        ``IncrementalIndexer`` — in both cases segments serve lazily from
        ``mmap`` pages, nothing is replayed, and the restored source's
        generation token resumes under a bumped restore epoch so caches can
        never serve a pre-restart entry against a post-restart index state
        (§12.5 invariant; exactness pinned by ``tests/test_store.py``).
        Snapshots store lemma *streams*, not the lemmatizer's rule set —
        a stack built with a customized ``Lemmatizer`` must pass the same
        one here (it reaches both the restored source and the planner's
        query expansion), or restored query-time lemmatization diverges
        from the pre-restart stack.  ``kwargs`` are the normal frontend
        options."""
        from pathlib import Path

        from ..index.incremental import IncrementalIndexer

        directory = Path(directory)
        if (directory / "service.json").exists():
            from .distributed import ShardedSearchService

            source = ShardedSearchService.restore(
                directory, use_mmap=use_mmap, verify=verify, lemmatizer=lemmatizer
            )
        else:
            source = IncrementalIndexer.restore(
                directory, use_mmap=use_mmap, verify=verify, lemmatizer=lemmatizer
            )
        frontend = cls(source, lemmatizer=lemmatizer, **kwargs)
        if warmup_shapes is not None:
            # precompile the bucketed device programs at warm-start so the
            # first served requests pay no jit compile (DESIGN.md §13.5)
            frontend.warmup(shapes=warmup_shapes)
        return frontend

    # ---- public serving API ----------------------------------------------

    def search(self, query: str, top_k: int = 10, deadline_sec: float | None = None):
        """Serve one request (a batch of one — see ``search_many``)."""
        return self.search_many(
            [SearchRequest(query=query, top_k=top_k, deadline_sec=deadline_sec)]
        )[0]

    def search_many(self, requests: Sequence[SearchRequest | str]) -> list:
        """Serve a slate of concurrent requests.

        Result-cache hits are answered immediately; duplicate no-deadline
        misses within the slate coalesce into one planned execution; the
        remaining misses are planned, deadline-admitted, micro-batched into
        chunks of ``max_batch`` and each chunk runs as ONE fused device
        dispatch.  Responses come back in request order, each trimmed to its
        own request's ``top_k``.
        """
        return self.submit_many(requests)()

    def submit_many(self, requests: Sequence[SearchRequest | str]):
        """Submit a slate and return a zero-arg ``finalize`` callable.

        The continuous-batching hook (DESIGN.md §16.2): ALL pre-dispatch
        work — the §14 probe barrier, cache lookups, planning, deadline
        admission, shedding, residency acquisition — runs now, and the
        first micro-batch chunk is SUBMITTED to the device without being
        awaited (``pipeline=True``; with ``pipeline=False`` it runs to
        completion, the serial reference).  Calling the returned finalize
        performs the blocking readout (plus any remaining chunks, two-deep
        pipelined) and returns the responses.  ``search_many`` is exactly
        ``submit_many(requests)()`` — responses are byte-identical, in
        request order — which is what lets ``search/service.py`` admit new
        requests into its queue while this slate's device program is in
        flight.  Not thread-safe per frontend: one submitted slate must be
        finalized before the next is submitted (the daemon serializes).
        """
        reqs = [
            r if isinstance(r, SearchRequest) else SearchRequest(query=r)
            for r in requests
        ]
        # §14 probe barrier FIRST: recovery replaces shard indexers, so the
        # generation token and views must resolve after it (a recovered
        # shard's fresh restore epoch is what strands pre-crash cache keys)
        supervisor = getattr(self._source, "supervisor", None)
        rstats = None
        live_shard_ids: list[int] | None = None
        if supervisor is not None:
            rstats = QueryStats()
            live_shard_ids = supervisor.probe_live_shards(rstats)
        token = generation_token(self._source)
        views, _, max_distance, _ = resolve_index_views(self._source)
        shard_ids = list(range(len(views)))
        if live_shard_ids is not None and len(live_shard_ids) < len(views):
            shard_ids = list(live_shard_ids)
            views = [views[i] for i in shard_ids]
        # posting-cache keys carry the TRUE shard id (not the position in
        # the degraded live list), so a degraded slate can never reuse a
        # slice cached for a different shard under the same token
        cached_views = [
            _CachedView(v, self.posting_cache, (token, shard_ids[i]))
            for i, v in enumerate(views)
        ]

        responses: list = [None] * len(reqs)
        miss_idx: list[int] = []
        miss_plans: list[QueryPlan] = []
        miss_admitted: list[list[SubqueryPlan]] = []
        miss_budget: list[float] = []
        miss_shed: list[bool] = []
        pending: dict[tuple, int] = {}  # (query, top_k) -> first miss index
        aliases: list[tuple[int, int]] = []  # (dup index, first index)
        for i, req in enumerate(reqs):
            ck = (token, req.query, req.top_k, self.use_kernel)
            hit = self._result_cache.get(ck)
            if hit is not None:
                self._result_cache.move_to_end(ck)
                self._result_hits += 1
                responses[i] = self._from_cache(hit)
                continue
            budget = (
                req.deadline_sec
                if req.deadline_sec is not None
                else self.default_deadline_sec
            )
            # coalesce duplicate no-deadline misses: plan + execute once,
            # fan the single response out (deadlined requests keep their own
            # admission, so they are never coalesced)
            dk = (req.query, req.top_k)
            if budget is None and dk in pending:
                aliases.append((i, pending[dk]))
                continue
            self._result_misses += 1
            p_hits0 = self.posting_cache.hits
            plan = self.planner.plan(req.query, views=cached_views, generation=token)
            p_hits = self.posting_cache.hits - p_hits0
            admitted, _skipped = self._admit(plan, budget)
            if budget is None:
                pending[dk] = i
            miss_idx.append(i)
            miss_plans.append(plan)
            miss_admitted.append(admitted)
            miss_budget.append(0.0 if budget is None else float(budget))
            miss_shed.append(False)
            # stash plan-time accounting to merge into the response stats
            plan._posting_cache_hits = p_hits  # type: ignore[attr-defined]

        # admission-control load shedding (DESIGN.md §14): misses beyond
        # max_inflight re-admit under the shed budget — they degrade to
        # flagged, exactly-ranked partial responses instead of erroring or
        # queueing unboundedly (request order decides who sheds:
        # deterministic, and earlier requests are older)
        if self.max_inflight is not None and len(miss_idx) > self.max_inflight:
            for j in range(self.max_inflight, len(miss_idx)):
                admitted, _ = self._admit(miss_plans[j], self.shed_deadline_sec)
                miss_admitted[j] = admitted
                miss_budget[j] = self.shed_deadline_sec
                miss_shed[j] = True
                self._sheds += 1

        # arena residencies are acquired only when something will actually
        # execute: a fully cache-served slate must never pay acquire work
        # (a cold acquire re-uploads whole families)
        residencies = (
            self._acquire_residencies(views, cached_views, token, shard_ids)
            if miss_idx
            else None
        )
        # micro-batch the misses: one fused dispatch per admitted batch.
        # Ranking runs at the chunk-wide max top_k; each response is trimmed
        # to its own request's top_k afterwards — rank_documents is a total
        # deterministic order, so the prefix equals a direct top_k ranking.
        #
        # With ``pipeline=True`` the chunks run as a two-deep pipeline
        # (DESIGN.md §15.2): chunk c is SUBMITTED (plan/pack/H2D + dispatch,
        # no barrier), then chunk c-1 — whose device program has been
        # computing meanwhile — is finalized (readout + response build).
        # Exactly one batch is ever in flight, chunks finalize in admission
        # order, and responses land by ``miss_idx`` — byte-identical to the
        # serial loop (``tests/test_readout.py``).
        def _submit(lo: int):
            hi = lo + self.max_batch
            chunk_plans = miss_plans[lo:hi]
            chunk_admitted = miss_admitted[lo:hi]
            chunk_reqs = [reqs[i] for i in miss_idx[lo:hi]]
            top_k = max((r.top_k for r in chunk_reqs), default=10)
            t0 = self.clock.now()
            out = execute_plans(
                chunk_plans,
                cached_views,
                max_distance=max_distance,
                top_k=top_k,
                doc_len=self.doc_len,
                use_kernel=self.use_kernel,
                compute_dtype=self.compute_dtype,
                admitted=chunk_admitted,
                residencies=residencies,
                defer=self.pipeline,
            )
            return lo, chunk_plans, chunk_admitted, t0, out

        def _finish(state) -> None:
            lo, chunk_plans, chunk_admitted, t0, out = state
            if self.pipeline:
                out = out()  # blocking readout + response build
            elapsed = self.clock.now() - t0
            self._calibrate(chunk_admitted, elapsed)
            for j, resp in enumerate(out):
                i = miss_idx[lo + j]
                resp.docs = resp.docs[: reqs[i].top_k]
                resp.stats.cache_misses = 1
                resp.stats.posting_cache_hits = getattr(
                    chunk_plans[j], "_posting_cache_hits", 0
                )
                resp.stats.deadline_sec = miss_budget[lo + j]
                if miss_shed[lo + j]:
                    resp.stats.shed = 1
                if rstats is not None:
                    # batch-level §14 counters; a degraded fan-out flags the
                    # response partial BEFORE the caching branch below, so a
                    # response missing shards is never cached as complete
                    resp.stats.retries = rstats.retries
                    resp.stats.hedges = rstats.hedges
                    resp.stats.recoveries = rstats.recoveries
                    resp.stats.shards_degraded = rstats.shards_degraded
                    if rstats.shards_degraded:
                        resp.stats.partial = True
                self._served += 1
                if resp.stats.partial:
                    self._partials += 1
                else:
                    # only complete responses are cacheable (a partial result
                    # is an artifact of one request's budget, not the corpus)
                    ck = (token, resp.query, reqs[i].top_k, self.use_kernel)
                    self._result_cache[ck] = resp
                    self._result_cache.move_to_end(ck)
                    while len(self._result_cache) > self._result_cache_entries:
                        self._result_cache.popitem(last=False)
                responses[i] = resp

        chunk_los = list(range(0, len(miss_idx), self.max_batch))
        # submit the FIRST chunk now (enqueue-only under pipeline=True): by
        # the time submit_many returns, the device is already computing it
        inflight = _submit(chunk_los[0]) if chunk_los else None

        done = False

        def finalize() -> list:
            nonlocal inflight, done
            if done:  # idempotent, like PendingBatch.result()
                return responses
            for lo in chunk_los[1:]:
                state = _submit(lo)
                _finish(inflight)
                inflight = state
            if inflight is not None:
                _finish(inflight)
                inflight = None
            for dup, first in aliases:
                responses[dup] = self._from_cache(responses[first])
            done = True
            return responses

        return finalize

    def close(self) -> None:
        """Release this frontend's hold on long-lived state (DESIGN.md
        §13.2): if the frontend created its own posting arena, detach its
        mutation listeners from the index source and drop the device
        buffers.  Idempotent; a frontend over a long-lived indexer that is
        discarded without ``close()`` leaves its listener (and arena
        buffers) alive for the indexer's lifetime.  Shared arenas
        (``arena=`` passed in) are untouched — their owner closes them."""
        if self._owns_arena and self.arena is not None:
            self.arena.detach()
            self.arena.release()

    # ---- internals --------------------------------------------------------

    def _acquire_residencies(self, views, cached_views, token, shard_ids=None):
        """Posting-arena residencies per live shard view (DESIGN.md §13).

        Keyed by ``id(cached_view)`` because that is the view object
        ``execute_plans`` packs into work items; uploads read the RAW view
        (the arena walks family dicts, which the cache wrapper does not
        carry).  A sharded source's tuple token splits into per-shard
        tokens, so one shard's commit only invalidates its own buffers.
        ``shard_ids`` maps each live view to its TRUE shard id — under a
        §14-degraded fan-out positions shift, but tokens and arena keys
        must keep naming the same shard exactly.
        """
        if self.arena is None:
            return None
        if shard_ids is None:
            shard_ids = list(range(len(views)))
        if self.arena.injector is None:
            # share the source's §14 fault injector (if resilience is on)
            self.arena.injector = getattr(self._source, "injector", None)
        # the token is a per-shard tuple exactly when the source is the
        # sharded service (a lone restored indexer's (epoch, mutations)
        # tuple must NOT be split)
        n_shards = getattr(self._source, "n_shards", None)
        per_shard = (
            [token[s] for s in shard_ids]
            if isinstance(token, tuple) and n_shards is not None
            and len(token) == n_shards
            else [token] * len(views)
        )
        all_res = self.arena.acquire_many(
            [(raw, per_shard[i], shard_ids[i]) for i, raw in enumerate(views)]
        )
        return {id(cached): res for cached, res in zip(cached_views, all_res)}

    def warmup(
        self,
        shapes: Sequence[tuple] | None = None,
        queries: Sequence[str] | None = None,
        top_k: int = 10,
    ) -> dict:
        """Precompile the bucketed device programs so cold-start p99 no
        longer includes jit compile (DESIGN.md §13.5).

        ``queries`` — the reliable form — plans and executes representative
        queries through the REAL serving path (arena gather kernels
        included, result cache untouched), compiling exactly the buckets a
        matching real slate hits; pass the ``top_k`` real requests will use
        (it is a STATIC device-program argument, like every shape budget).
        ``shapes`` lists explicit host-program buckets ``(events, rows,
        lemmas, table_depth, queries, window)`` for operators replaying
        observed budgets — note the window is the pow2 position budget of
        the traffic, not ``doc_len``.  With neither argument, one default
        bucket at the frontend's ``max_batch``/``doc_len`` is compiled (a
        guess: real traffic buckets are data-dependent, so prefer
        ``queries``).  Returns ``{"seconds", "programs"}``;
        ``launch/serve.py`` reports the time.
        """
        import numpy as np_

        import jax as jax_
        import jax.numpy as jnp_

        from .fused import bucket_pow2, fused_serve_batch

        t0 = time.perf_counter()
        programs = 0
        if shapes is None and queries is None:
            shapes = [
                (4096, 512, 4, 64, bucket_pow2(self.max_batch),
                 bucket_pow2(self.doc_len, lo=64))
            ]
        for e, r, l, k, q, n in shapes or ():
            out = fused_serve_batch(
                jnp_.asarray(np_.full((e, 3), -1, np_.int32)),
                jnp_.asarray(np_.zeros((e,), np_.int8)),
                jnp_.asarray(np_.full((r, l, k), n, np_.int32)),
                jnp_.asarray(np_.full((r,), -1, np_.int32)),
                jnp_.asarray(np_.full((r,), -1, np_.int32)),
                jnp_.asarray(np_.zeros((r, l), np_.int32)),
                max_distance=resolve_index_views(self._source)[2],
                query_budget=q,
                window_len=n,
                top_k=top_k,
                compute_dtype=self.compute_dtype,
                use_kernel=self.use_kernel,
                interpret=True,
            )
            jax_.block_until_ready(out)
            programs += 1
        if queries:
            token = generation_token(self._source)
            views, _, max_distance, _ = resolve_index_views(self._source)
            cached_views = [
                _CachedView(v, self.posting_cache, (token, i))
                for i, v in enumerate(views)
            ]
            residencies = self._acquire_residencies(views, cached_views, token)
            plans = [
                self.planner.plan(q, views=cached_views, generation=token)
                for q in queries
            ]
            for lo in range(0, len(plans), self.max_batch):
                execute_plans(
                    plans[lo : lo + self.max_batch],
                    cached_views,
                    max_distance=max_distance,
                    top_k=top_k,
                    doc_len=self.doc_len,
                    use_kernel=self.use_kernel,
                    compute_dtype=self.compute_dtype,
                    residencies=residencies,
                )
                programs += 1
        return {"seconds": time.perf_counter() - t0, "programs": programs}

    def _from_cache(self, resp):
        """A cache-hit response: shared docs, fresh hit-marked stats."""
        from .engine import QueryResponse

        st = QueryStats()
        st.cache_hits = 1
        st.results = resp.stats.results
        self._served += 1
        return QueryResponse(
            query=resp.query,
            docs=resp.docs,
            stats=st,
            n_subqueries=resp.n_subqueries,
        )

    def _admit(
        self, plan: QueryPlan, budget_sec: float | None
    ) -> tuple[list[SubqueryPlan], int]:
        """Deadline admission: cheapest-first under the estimated budget.

        With no budget every executable subquery is admitted (plan order).
        With a budget, subqueries are admitted in ascending estimated cost
        while the cumulative estimate ``postings / postings_per_sec`` fits;
        a non-positive budget admits nothing.  Admission is monotone in the
        budget, and the executed subset's results are exact (module
        docstring) — the response-time guarantee trades recall, never
        correctness.
        """
        execs = plan.executable()
        if budget_sec is None:
            return execs, 0
        if budget_sec <= 0:
            return [], len(execs)
        admitted: list[SubqueryPlan] = []
        cum = 0
        for sp in sorted(execs, key=lambda sp: sp.est_postings):
            if admitted and (cum + sp.est_postings) / self.postings_per_sec > budget_sec:
                continue
            admitted.append(sp)
            cum += sp.est_postings
        return admitted, len(execs) - len(admitted)

    def _calibrate(self, chunk_admitted, elapsed: float) -> None:
        """EWMA throughput update from the observed batch (postings/sec)."""
        if not self.calibrate or elapsed <= 0:
            return
        postings = sum(
            sp.est_postings for subs in chunk_admitted for sp in subs
        )
        if postings <= 0:
            return
        observed = postings / elapsed
        self.postings_per_sec = 0.5 * self.postings_per_sec + 0.5 * observed

    def metrics(self) -> dict:
        """Serving counters for dashboards and the bench harness."""
        n_lookups = self._result_hits + self._result_misses
        p_lookups = self.posting_cache.hits + self.posting_cache.misses
        arena = self.arena.metrics() if self.arena is not None else {}
        return {
            **arena,
            "served": self._served,
            "result_cache_hits": self._result_hits,
            "result_cache_misses": self._result_misses,
            "result_cache_hit_rate": (
                self._result_hits / n_lookups if n_lookups else 0.0
            ),
            "posting_cache_hits": self.posting_cache.hits,
            "posting_cache_misses": self.posting_cache.misses,
            "posting_cache_hit_rate": (
                self.posting_cache.hits / p_lookups if p_lookups else 0.0
            ),
            "posting_cache_bytes": self.posting_cache.size_bytes,
            "posting_cache_entries": len(self.posting_cache),
            "partial_responses": self._partials,
            "postings_per_sec_estimate": self.postings_per_sec,
            "sheds": self._sheds,
            # §14 resilience counters (empty dict when the layer is off)
            "resilience": (
                self._source.resilience_metrics()
                if hasattr(self._source, "resilience_metrics")
                else {}
            ),
        }
