"""Relevance calculation (paper §5 stage 4, §14).

The paper adopts the proximity-relevance model of Yan et al. [20]: "the
relevance of the document is inversely proportional to the square of the
distance between searched words".  Each minimal fragment of span ``d``
contributes ``1 / (d + 1)^2``; a document's score is the sum over its
fragments, which rewards many tight co-occurrences.

Exactness contract: every serving path (host SE2.4 loop, fused batch,
planner/frontend) ranks with :func:`rank_documents` over its exact fragment
union, so two paths that agree on fragments agree on ranking bit-for-bit.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Iterable, Sequence

from ..core.postings import SearchResult

__all__ = ["fragment_score", "rank_documents"]


def fragment_score(result: SearchResult) -> float:
    """§14 proximity relevance of one minimal fragment: ``1 / (span + 1)^2``
    (span in word positions; a single-word match scores 1.0)."""
    return 1.0 / float(result.span + 1) ** 2


def rank_documents(
    results: Iterable[SearchResult], top_k: int = 10
) -> list[tuple[int, float, list[SearchResult]]]:
    """Rank documents by §14 proximity relevance, deterministically.

    Ordering specification (total and input-order independent):

    * documents sort by **decreasing score**, ties broken by **ascending
      doc_id** — so the ``top_k`` cut is stable under every permutation of
      ``results`` and across engines/runs;
    * each document's score is the sum of its fragments' §14 contributions,
      accumulated in **sorted fragment order** ``(start, end)`` — float
      addition is order-sensitive in the last ulp, and callers pass sets, so
      an unsorted sum could rank equal-score documents differently between
      otherwise fragment-identical serving paths;
    * the returned ``fragments`` list is sorted by ``(start, end)`` (the
      ``SearchResult`` tuple order restricted to one document).

    Empty or duplicate-free input degrades naturally: no results -> ``[]``;
    ``top_k <= 0`` -> ``[]``.

    >>> from repro.core.postings import SearchResult
    >>> r = rank_documents(
    ...     {SearchResult(7, 4, 5), SearchResult(3, 0, 1), SearchResult(3, 9, 10)},
    ...     top_k=2,
    ... )
    >>> [(doc, round(score, 4)) for doc, score, _ in r]
    [(3, 0.5), (7, 0.25)]
    >>> rank_documents([])
    []
    """
    if top_k <= 0:
        return []
    per_doc: dict[int, list[SearchResult]] = defaultdict(list)
    for r in results:
        per_doc[r.doc_id].append(r)
    scored = []
    for doc, frs in per_doc.items():
        frs = sorted(frs)  # deterministic float-summation order + output order
        scored.append((doc, sum(fragment_score(r) for r in frs), frs))
    scored.sort(key=lambda t: (-t[1], t[0]))
    return scored[:top_k]
