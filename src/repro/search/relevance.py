"""Relevance calculation (paper §5 stage 4, §14).

The paper adopts the proximity-relevance model of Yan et al. [20]: "the
relevance of the document is inversely proportional to the square of the
distance between searched words".  Each minimal fragment of span ``d``
contributes ``1 / (d + 1)^2``; a document's score is the sum over its
fragments, which rewards many tight co-occurrences.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Iterable, Sequence

from ..core.postings import SearchResult

__all__ = ["fragment_score", "rank_documents"]


def fragment_score(result: SearchResult) -> float:
    return 1.0 / float(result.span + 1) ** 2


def rank_documents(
    results: Iterable[SearchResult], top_k: int = 10
) -> list[tuple[int, float, list[SearchResult]]]:
    """(doc_id, score, fragments) sorted by decreasing score."""
    per_doc: dict[int, list[SearchResult]] = defaultdict(list)
    for r in results:
        per_doc[r.doc_id].append(r)
    scored = [
        (doc, sum(fragment_score(r) for r in frs), sorted(frs))
        for doc, frs in per_doc.items()
    ]
    scored.sort(key=lambda t: (-t[1], t[0]))
    return scored[:top_k]
