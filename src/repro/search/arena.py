"""Device-resident posting arena: on-device gather/pack for the fused
pipeline (DESIGN.md §13).

The fused pipeline (§9) made the *device* side of serving one program per
query batch, but ``plan_query_batch`` still gathered posting slices, built
occurrence tables and packed padded event arrays **on the host in numpy for
every batch**.  For stop/FU-heavy batches — precisely the case the paper's
multi-component indexes exist to make fast (2009.02684), with the hot path
bounded by index reads rather than per-query assembly (2009.03679) — that
host phase plus the H2D copy dominated end-to-end latency while the device
sat idle.

This module moves the hot posting columns onto the device **once per index
generation** and does the gather/pack there:

* :class:`PostingArena` — a byte-budgeted LRU of device-resident posting
  families.  Per ``(generation token, shard)``, each §3 family's keys are
  transformed into **per-slot event streams**: for every key and component
  slot, the sorted-unique ``(doc, pos)`` pairs the slot contributes — the
  §10.4 ``Set`` events with the query-independent half of the host pack
  (delta resolution, within-slot dedup, the §4 sort) hoisted to upload
  time.  For stop-lemma (f,s,t) keys this also *shrinks* the transport:
  raw rows enumerate occurrence pairs (O(occ³) per document) while the
  distinct positions per slot are O(occ).  Streams are concatenated
  (``index.store.family_rows`` key order, every extent aligned to
  ``ARENA_BLOCK`` rows) into ONE int32 device buffer per family.  A
  commit/delete/compact bumps the generation token, so stale buffers become
  unreachable and age out by LRU (or are evicted eagerly through the
  ``IncrementalIndexer.subscribe`` mutation hook).

* :func:`plan_arena_batch` — per batch, the host ships only **descriptors**:
  per (query, subquery, shard) work item, per selected key, the slot
  extents plus (segment id, lemma id, Step-1/emit flags, multiplicities).
  No posting row is touched on the host; planning cost is O(keys), not
  O(postings).

* :func:`arena_serve_batch` — ONE jit'd device program per batch: the
  ``kernels/gather.py`` scalar-prefetch block gather slices the arena, then
  on-device sorts rebuild exactly the host pack's event pipeline — Step-1
  document alignment (distinct-key counting per candidate doc), cross-key
  event dedup, Step-2 multiplicity gate, the event-centric rank cover
  (binary search over the (row, lemma, pos)-sorted stream — the ``postab``
  content of §9.1 without materializing the ``[R, L, K]`` table, so no
  data-dependent K budget exists), then the SAME §14 scoring and per-query
  top-k stages as ``fused_serve_batch``.

Exactness contract: arena-path fragment sets are identical to the host-pack
path and therefore to the §10 oracle — the same dedup, the same Step-1/
Step-2 gates, the same cover identity, pinned by ``tests/test_arena.py`` and
the ``tests/test_differential.py`` §13 case across live mutation and
budget-forced partial residency.  Keys that are not resident (family
evicted under the byte budget) fall back transparently to the host-pack
path, as do batches whose packed int32 composites would overflow
(:class:`ArenaOverflow` — e.g. per-shard doc-id spaces beyond ~2^24).
"""

from __future__ import annotations

import functools
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from ..core.postings import QueryStats, SearchResult
from ..index.builder import POSTING_WIDTH, IndexSet
from ..kernels.gather import ARENA_BLOCK, gather_blocks, gather_blocks_ref
from .fused import _assemble_fragments, bucket_pow2 as _bucket

__all__ = [
    "ARENA_BLOCK",
    "ArenaOverflow",
    "ArenaResidency",
    "KeyExtent",
    "PostingArena",
    "plan_arena_batch",
    "arena_serve_batch",
    "lower_arena_batch",
    "run_arena_batch",
]

# §3 families `IndexSet.key_postings` serves (ordinary/NSW never reach it)
_ARENA_FAMILIES = ("stop_single", "stop_pair", "pair", "triple")

_I32_MAX = np.int32(np.iinfo(np.int32).max)


class ArenaOverflow(RuntimeError):
    """A batch's packed composites would not fit the int32 bit budgets of
    the §13.4 device program (DESIGN.md §13.3).  Callers fall back to the
    host-pack path — exactness is never at stake, only the gather
    locality."""


class SlotExtent(NamedTuple):
    """One (key, slot) event stream's slice of its §3 family buffer
    (DESIGN.md §13.1)."""

    block_start: int  # first arena block of the extent
    n_events: int  # sorted-unique (doc, pos) pairs in the stream
    max_pos: int


class KeyExtent(NamedTuple):
    """One §6 key's arena residency (DESIGN.md §13.1): per-slot stream
    extents plus the upload-time statistics the planner needs to size
    budgets — and keep the §11 postings-read accounting exact — without
    reading a single row."""

    family: str
    n_rows: int  # raw §4 rows (the §11 postings-read accounting unit)
    n_docs: int  # distinct doc ids (slot-0 stream — every row contributes)
    max_doc: int
    slots: tuple  # SlotExtent per component slot


_ZERO_EXTENT = KeyExtent("", 0, 0, 0, ())


@dataclass
class _FamilyBuffer:
    """One resident (token, shard, family) upload."""

    buf: jax.Array  # [n_blocks_pow2 * BLOCK, 2] int32 (doc, pos) streams
    extents: dict  # canonical key -> KeyExtent
    nbytes: int


@dataclass
class ArenaResidency:
    """The resident §3 families of one (generation token, shard) — the
    handle work items carry into ``serve_query_batch`` (DESIGN.md §13.2)."""

    token: object
    shard: int
    families: dict = field(default_factory=dict)  # fname -> _FamilyBuffer

    def lookup(self, components: tuple) -> KeyExtent | None:
        """Arena extent for a canonical key, mirroring
        ``IndexSet.key_postings`` dispatch exactly; ``None`` = the serving
        family is not resident (host fallback), a zero-row extent = the key
        is resident-but-absent (provably empty, no fallback needed)."""
        arity = len(components)
        if arity == 3:
            fams = ("triple",)
        elif arity == 2:
            # stop_pair precedes pair in key_postings; the two key spaces
            # are disjoint (stop/stop vs FU-anchored), so a hit in either is
            # authoritative, but proving ABSENCE needs both resident.
            fams = ("stop_pair", "pair")
        else:
            fams = ("stop_single",)
        for fname in fams:
            fb = self.families.get(fname)
            if fb is not None:
                ext = fb.extents.get(components)
                if ext is not None:
                    return ext
        if all(f in self.families for f in fams):
            return _ZERO_EXTENT
        return None

    def buffer(self, fname: str) -> jax.Array:
        return self.families[fname].buf


def _slot_streams(a: np.ndarray, width: int) -> list[tuple[np.ndarray, np.ndarray]]:
    """Per-slot sorted-unique (doc, pos) event streams of one key's §4 rows
    — the query-independent half of ``extract_segment_events`` hoisted to
    upload time.  Slot ``s``'s position is the anchor position plus the
    slot's signed distance; real token positions are never negative, and
    distinct (doc, pos) pairs are what the host pack's ``np.unique``
    produces for the slot (DESIGN.md §13.1)."""
    doc = a[:, 0].astype(np.int64)
    out = []
    for s in range(width - 1):
        pos = a[:, 1].astype(np.int64)
        if s > 0:
            pos = pos + a[:, 1 + s]
        comp = np.unique((doc << 32) | pos)
        out.append(((comp >> 32).astype(np.int32), (comp & 0xFFFFFFFF).astype(np.int32)))
    return out


class PostingArena:
    """Byte-budgeted LRU of device-resident posting families (DESIGN.md
    §13.1).

    ``acquire`` is the only serving-path entry: it returns (uploading on
    first touch) the :class:`ArenaResidency` for a live index view under its
    generation token.  Warm acquires are dictionary hits; a token bump makes
    old entries unreachable and LRU reclaims them under the byte budget.
    Families that do not fit the budget are simply left non-resident —
    ``serve_query_batch`` routes their work items through the host pack, so
    residency is a pure locality optimization, never a correctness surface.
    """

    def __init__(self, budget_bytes: int = 256 << 20, block: int = ARENA_BLOCK):
        self.budget_bytes = int(budget_bytes)
        self.block = int(block)
        self._entries: OrderedDict[tuple, _FamilyBuffer] = OrderedDict()
        self._bytes = 0
        # entry keys refused under the CURRENT budget: not re-attempted
        # (re-building the host-side concat per batch would reintroduce the
        # per-batch O(postings) host work the arena exists to remove).  A
        # bounded FIFO, shared across callers — a token bump changes the
        # key, so stale refusals age out by generation or by capacity
        self._refused: OrderedDict[tuple, None] = OrderedDict()
        self._refused_cap = 512
        self._unsubscribers: list = []
        self._source_ids = 0  # monotonically unique view identities
        self.hits = 0  # warm family acquires
        self.misses = 0  # family uploads + budget refusals
        self.uploads = 0
        self.upload_bytes = 0  # H2D bytes spent on arena uploads
        self.evictions = 0
        # §14 fault-injection hook (DESIGN.md §14): when set, acquire
        # rounds fire the "arena.acquire" injection point; injected
        # pressure refuses the whole round (host fallback, fragments
        # identical) instead of erroring
        self.injector = None
        self.pressure_events = 0

    # ---- residency --------------------------------------------------------

    def acquire(self, view: IndexSet, token: object, shard: int = 0) -> ArenaResidency:
        """Resident families of ``view`` under ``token`` — uploads what is
        missing (and fits), touches what is warm.  O(families) dict work when
        warm; O(total postings) once per (token, shard) when cold."""
        return self.acquire_many([(view, token, shard)])[0]

    def acquire_many(self, specs: Sequence[tuple]) -> list[ArenaResidency]:
        """Residencies for a whole serving round — ``specs`` lists
        ``(view, token, shard)`` per live shard.  All of the round's entries
        are PINNED against each other's admissions: a budget smaller than
        the round's working set yields stable partial residency (some
        families non-resident, host fallback) instead of shards evicting one
        another's buffers and re-uploading every batch."""
        if self.injector is not None:
            from .resilience import InjectedFault

            try:
                self.injector.fire("arena.acquire")
            except InjectedFault:
                # injected device-memory pressure (§14): refuse the round —
                # empty residencies route every key through the host pack,
                # so fragments are identical, only locality degrades
                self.pressure_events += 1
                return [
                    ArenaResidency(token=token, shard=shard)
                    for _view, token, shard in specs
                ]
        # entry keys carry a per-VIEW identity stamped on first acquire:
        # generation tokens alone are not globally unique (every plain
        # IndexSet has token 0; two indexers can share (epoch, mutations)),
        # so a shared arena must never let one source's buffers answer for
        # another's.  The stamp is a monotone counter (never reused, unlike
        # id()), travels with the view object, and a recreated view (new
        # generation) simply gets a fresh stamp.
        def source_id(view) -> int:
            sid = getattr(view, "_arena_source_id", None)
            if sid is None:
                self._source_ids += 1
                sid = self._source_ids
                try:
                    view._arena_source_id = sid
                except AttributeError:  # __slots__ view: fall back to id()
                    sid = id(view)
            return sid

        sids = [source_id(view) for view, _token, _shard in specs]
        pinned = {
            (sid, token, shard, fname)
            for sid, (_view, token, shard) in zip(sids, specs)
            for fname in _ARENA_FAMILIES
        }
        out = []
        for sid, (view, token, shard) in zip(sids, specs):
            res = ArenaResidency(token=token, shard=shard)
            for fname in _ARENA_FAMILIES:
                key = (sid, token, shard, fname)
                fb = self._entries.get(key)
                if fb is not None:
                    self._entries.move_to_end(key)
                    self.hits += 1
                    res.families[fname] = fb
                    continue
                self.misses += 1
                if key in self._refused:
                    continue
                fb = self._upload_family(view, fname)
                if fb is None:
                    continue
                if not self._admit(key, fb, pinned):
                    self._refused[key] = None
                    while len(self._refused) > self._refused_cap:
                        self._refused.popitem(last=False)
                    continue
                res.families[fname] = fb
            out.append(res)
        return out

    def _admit(self, key: tuple, fb: _FamilyBuffer, pinned: frozenset = frozenset()) -> bool:
        """Insert under the byte budget, evicting LRU entries (never the
        current round's ``pinned`` ones); refuse (and drop) an upload that
        cannot fit even after evicting everything evictable."""
        if fb.nbytes > self.budget_bytes:
            return False
        while self._bytes + fb.nbytes > self.budget_bytes:
            victim = next((k for k in self._entries if k not in pinned), None)
            if victim is None:
                return False
            old = self._entries.pop(victim)
            self._bytes -= old.nbytes
            self.evictions += 1
        self._entries[key] = fb
        self._bytes += fb.nbytes
        return True

    def _upload_family(self, view: IndexSet, fname: str) -> _FamilyBuffer | None:
        from ..index.store import family_rows

        width = POSTING_WIDTH[fname]
        mapping = getattr(view, fname)
        keys, arrays, _rows, _starts = family_rows(mapping, width)
        block = self.block
        chunks: list[np.ndarray] = []
        extents: dict = {}
        blk = 0
        for k, a in zip(keys, arrays):
            n = len(a)
            if n == 0:
                extents[k] = KeyExtent(fname, 0, 0, 0, ())
                continue
            doc_col = a[:, 0]
            n_docs = 1 + int(np.count_nonzero(np.diff(doc_col)))
            slots = []
            for doc, pos in _slot_streams(a, width):
                ne = len(doc)
                n_blocks = -(-ne // block)
                pad = np.full((n_blocks * block, 2), -1, np.int32)
                pad[:ne, 0] = doc
                pad[:ne, 1] = pos
                chunks.append(pad)
                slots.append(
                    SlotExtent(blk, ne, int(pos.max()) if ne else 0)
                )
                blk += n_blocks
            extents[k] = KeyExtent(
                family=fname,
                n_rows=n,
                n_docs=n_docs,
                max_doc=int(doc_col[-1]),  # §4 order: doc column is sorted
                slots=tuple(slots),
            )
        # pow2 total blocks: arena buffer SHAPES bucket, so the serving
        # program's jit cache stays stable across generations (§9.2)
        total_blocks = 1 << max(0, (max(blk, 1) - 1).bit_length())
        concat = np.full((total_blocks * block, 2), -1, np.int32)
        if chunks:
            cat = np.concatenate(chunks)
            concat[: len(cat)] = cat
        buf = jnp.asarray(concat)
        self.uploads += 1
        self.upload_bytes += concat.nbytes
        return _FamilyBuffer(buf=buf, extents=extents, nbytes=concat.nbytes)

    # ---- invalidation (generation hooks, DESIGN.md §13.2) ------------------

    def attach(self, source) -> None:
        """Subscribe eager eviction to an index source's mutation hook: on
        every commit/committed-delete/compact, entries whose token is no
        longer live for the source are dropped immediately instead of aging
        out by LRU.  Token-keyed residency is already correct without this
        (stale tokens are unreachable); attaching just returns the bytes
        sooner.  Attach one arena to one source (or sources sharing a token
        namespace); ``detach()`` removes the subscriptions (an arena that
        outlives its usefulness must detach, or the indexer's listener list
        keeps it alive)."""
        from ..index.incremental import IncrementalIndexer

        indexers = getattr(source, "indexers", None)
        if indexers is None and isinstance(source, IncrementalIndexer):
            indexers = [source]
        if not indexers:
            return

        # evict ONLY tokens this source previously served (tracked across
        # mutations), never unrelated sources' entries that happen to carry
        # a colliding token value — entry keys are (sid, token, shard,
        # family) and a shared arena may hold other sources' buffers
        prev_tokens = {ix.generation_token for ix in indexers}

        def _on_mutation(_ix) -> None:
            nonlocal prev_tokens
            live = {ix.generation_token for ix in indexers}
            stale = prev_tokens - live
            for key in [k for k in self._entries if k[1] in stale]:
                fb = self._entries.pop(key)
                self._bytes -= fb.nbytes
                self.evictions += 1
            prev_tokens = live

        for ix in indexers:
            self._unsubscribers.append(ix.subscribe(_on_mutation))

    def detach(self) -> None:
        """Remove every mutation subscription made by ``attach`` (DESIGN.md
        §13.2) — idempotent; the arena keeps working, invalidation reverts
        to token-keyed LRU aging."""
        for unsub in self._unsubscribers:
            unsub()
        self._unsubscribers = []

    def release(self) -> None:
        """Drop every resident buffer and refusal record (DESIGN.md §13.2)
        — the normal eviction path, so counters stay consistent.  For
        consumers done serving (benches, shutdown); the arena remains
        usable and re-uploads on the next acquire."""
        self.evictions += len(self._entries)
        self._entries.clear()
        self._bytes = 0
        self._refused.clear()

    # ---- introspection ----------------------------------------------------

    @property
    def size_bytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._entries)

    def metrics(self) -> dict:
        lookups = self.hits + self.misses
        return {
            "arena_bytes": self._bytes,
            "arena_entries": len(self._entries),
            "arena_hit_rate": self.hits / lookups if lookups else 0.0,
            "arena_hits": self.hits,
            "arena_misses": self.misses,
            "arena_uploads": self.uploads,
            "arena_upload_bytes": self.upload_bytes,
            "arena_evictions": self.evictions,
            "arena_pressure_events": self.pressure_events,
        }


# ---------------------------------------------------------------------------
# §13.3 descriptor planning (host side: O(keys), zero posting reads)
# ---------------------------------------------------------------------------


@dataclass
class ArenaBatchPlan:
    """Fixed-shape descriptor tensors for one arena device dispatch — the
    §13.3 descriptor ABI.  Everything here is O(work items + arena blocks);
    no posting row is ever materialized on the host.

    Descriptors reference (key, slot) event-stream extents.  Every key
    contributes its slot-0 stream as the Step-1 membership witness
    (``kd=1``: every §4 row has a slot-0 event, so the stream's doc set IS
    the key's doc set); streams of unstarred slots additionally emit events
    (``emit=1``).  Two ABI forms ride in one plan: the block-aligned form
    steers the Pallas gather kernel's DMA, the dense form packs extents
    back-to-back for the jnp gather so the event budget tracks real rows.
    """

    # one gather GROUP per (residency, family) pair — distinct shards keep
    # distinct device buffers even for the same family name
    families: tuple  # static: group labels (fname per group)
    buffers: list  # per group: device buffer (resident, NOT per-batch H2D)
    # block-aligned form, consumed by the Pallas gather (use_kernel=True):
    src: list  # per group: [Gg] int32 arena block index per output block
    nv: list  # per group: [Gg] int32 live rows per output block
    blk_meta: list  # per group: [Gg, 5] int32 (seg, lem, kd, emit, key)
    # dense form, consumed by the jnp gather (no block padding):
    d_src: list  # per group: [Dg] int32 first arena ROW of each descriptor
    d_n: list  # per group: [Dg] int32 events per descriptor
    d_dest: list  # per group: [Dg] int32 dense output offset (cumsum of d_n)
    d_meta: list  # per group: [Dg, 5] int32 (seg, lem, kd, emit, key)
    e_budget: list  # per group: pow2 dense event budget
    n_keys: np.ndarray  # [S] int32
    mult: np.ndarray  # [S, L] int32
    seg_query: np.ndarray  # [S] int32
    n_queries: int
    query_budget: int
    n_budget: int  # position budget (pow2)
    row_budget: int  # candidate-row budget (pow2)
    lemma_budget: int  # pow2
    key_budget: int  # keys-per-work-item budget (pow2)
    doc_bits: int  # bit width of the largest doc id in the batch
    tier: str  # "pack32" (one fused sort) or "argsort" (wide doc ids)
    block: int
    n_events: int  # gathered stream events (pre-padding), for accounting


def plan_arena_batch(
    items: Sequence[tuple],
    *,
    n_queries: int,
    block: int = ARENA_BLOCK,
) -> ArenaBatchPlan | None:
    """Pack arena-resident work items into one device program's descriptors
    — the §13.3 descriptor ABI (the host-side half of the §10.4 event
    pipeline, reduced to extent arithmetic).

    ``items`` are ``(query_index, subquery, keys, extents, residency)``
    tuples whose keys ALL resolved to arena extents (``serve_query_batch``
    does the split and the empty-work short-circuits).  Returns ``None``
    when nothing would be gathered; raises :class:`ArenaOverflow` when the
    packed int32 composites cannot hold this batch.
    """
    if not items:
        return None
    # gather groups keyed by (residency identity, family): items from
    # different shards never share a group even for the same family name
    fam_desc: dict[tuple, list] = {}
    group_buf: dict[tuple, object] = {}
    n_keys = np.zeros(len(items), np.int32)
    seg_query = np.full(len(items), -1, np.int32)
    max_l = 1
    max_pos = 0
    max_doc = 0
    row_bound = 0
    n_events = 0
    mult_rows: list[np.ndarray] = []
    for seg, (qi, sub, keys, extents, res) in enumerate(items):
        lemmas = sub.unique_lemmas()
        lid = {l: i for i, l in enumerate(lemmas)}
        mult_map = sub.multiplicity()
        mult_rows.append(np.array([mult_map[l] for l in lemmas], np.int32))
        max_l = max(max_l, len(lemmas))
        seg_query[seg] = qi
        n_keys[seg] = len(keys)
        for key_local, (key, ext) in enumerate(zip(keys, extents)):
            # group order must be DETERMINISTIC across rounds (it shapes the
            # static argument tuple of arena_serve_batch, i.e. the jit cache
            # key): order by (shard, family); id() only breaks the
            # pathological tie of two residencies claiming one shard
            gkey = (res.shard, ext.family, id(res))
            group_buf.setdefault(gkey, res.buffer(ext.family))
            max_doc = max(max_doc, ext.max_doc)
            row_bound += ext.n_docs
            unstarred = {s for s, _ in key.active_components()}
            for slot, se in enumerate(ext.slots):
                kd = 1 if slot == 0 else 0
                emit = 1 if slot in unstarred else 0
                if not (kd or emit) or se.n_events == 0:
                    continue
                if emit:
                    max_pos = max(max_pos, se.max_pos)
                n_events += se.n_events
                fam_desc.setdefault(gkey, []).append(
                    (
                        se.block_start,
                        se.n_events,
                        seg,
                        lid[key.components[slot]] if emit else 0,
                        kd,
                        emit,
                        key_local,
                    )
                )
    if not fam_desc:
        return None

    # ---- int32 composite bit budgets (x64 stays off on device) -----------
    n_budget = _bucket(max_pos + 1, lo=64)
    lemma_budget = _bucket(max_l, lo=2)
    s_budget = _bucket(len(items))
    key_budget = _bucket(int(n_keys.max()))
    row_budget = _bucket(min(max(row_bound, 1), max(n_events, 1)), lo=8)
    rb = max((row_budget - 1).bit_length(), 1)
    nb = (n_budget - 1).bit_length()
    lb = max((lemma_budget - 1).bit_length(), 1)
    sb = max((s_budget - 1).bit_length(), 1)
    kb = max((key_budget - 1).bit_length(), 1)
    db = max(int(max_doc).bit_length(), 1)
    if rb + nb + lb > 30:
        raise ArenaOverflow(
            f"dedup composite bits {rb}+{nb}+{lb} > 30 (rows={row_budget}, "
            f"positions={n_budget}, lemmas={lemma_budget})"
        )
    # one fused (seg, doc, key, kd, emit, pos, lemma) sort when everything
    # fits int32; wide doc-id spaces drop pos/lemma from the sort key and
    # pay payload gathers instead; wider still -> host-pack fallback
    if sb + db + kb + 2 + nb + lb <= 30:
        tier = "pack32"
    elif sb + db + kb + 2 <= 30:
        tier = "argsort"
    else:
        raise ArenaOverflow(
            f"row-group bits {sb}+{db}+{kb}+2 > 30 (doc ids up to {max_doc}; "
            f"wider per-shard doc spaces take the host path)"
        )

    group_keys = sorted(fam_desc, key=lambda gk: gk[:2])
    families = tuple(gk[1] for gk in group_keys)
    buffers = [group_buf[gk] for gk in group_keys]
    src: list = []
    nv: list = []
    blk_meta: list = []
    d_src: list = []
    d_n_d: list = []
    d_dest: list = []
    d_meta_d: list = []
    e_budget: list = []
    for gk in group_keys:
        descs = fam_desc[gk]
        d_bstart = np.asarray([d[0] for d in descs], np.int64)
        d_n = np.asarray([d[1] for d in descs], np.int64)
        d_meta = np.asarray([d[2:] for d in descs], np.int32)  # [D, 5]
        nblk = np.maximum(1, -(-d_n // block))
        g = _bucket(int(nblk.sum()))
        total = int(nblk.sum())
        # vectorized block expansion: block j of descriptor d reads arena
        # block bstart[d] + j and holds min(block, n[d] - j*block) live rows
        desc_of = np.repeat(np.arange(len(descs)), nblk)
        starts = np.zeros(len(descs), np.int64)
        np.cumsum(nblk[:-1], out=starts[1:])
        within = np.arange(total, dtype=np.int64) - np.repeat(starts, nblk)
        pad = g - total
        src.append(np.concatenate(
            [(d_bstart[desc_of] + within).astype(np.int32), np.zeros(pad, np.int32)]
        ))
        nv.append(np.concatenate(
            [
                np.minimum(block, d_n[desc_of] - within * block).astype(np.int32),
                np.zeros(pad, np.int32),
            ]
        ))
        blk_meta.append(np.concatenate(
            [d_meta[desc_of], np.tile(np.array([[-1, 0, 0, 0, 0]], np.int32), (pad, 1))]
        ))
        # dense form: descriptor extents packed back to back, descriptor
        # table pow2-padded (zero-row pads), event budget = bucket(real rows)
        d = _bucket(len(descs))
        dest = np.zeros(len(descs), np.int64)
        np.cumsum(d_n[:-1], out=dest[1:])
        e_budget.append(_bucket(int(d_n.sum()), lo=block))
        d_src.append(np.concatenate(
            [(d_bstart * block).astype(np.int32), np.zeros(d - len(descs), np.int32)]
        ))
        d_n_d.append(np.concatenate(
            [d_n.astype(np.int32), np.zeros(d - len(descs), np.int32)]
        ))
        d_dest.append(np.concatenate(
            [dest.astype(np.int32), np.full(d - len(descs), int(d_n.sum()), np.int32)]
        ))
        d_meta_d.append(np.concatenate(
            [d_meta, np.tile(np.array([[-1, 0, 0, 0, 0]], np.int32), (d - len(descs), 1))]
        ))

    mult = np.zeros((s_budget, lemma_budget), np.int32)
    for seg, row in enumerate(mult_rows):
        mult[seg, : len(row)] = row
    n_keys_p = np.zeros(s_budget, np.int32)
    n_keys_p[: len(items)] = n_keys
    seg_query_p = np.full(s_budget, -1, np.int32)
    seg_query_p[: len(items)] = seg_query

    return ArenaBatchPlan(
        families=families,
        buffers=buffers,
        src=src,
        nv=nv,
        blk_meta=blk_meta,
        d_src=d_src,
        d_n=d_n_d,
        d_dest=d_dest,
        d_meta=d_meta_d,
        e_budget=e_budget,
        n_keys=n_keys_p,
        mult=mult,
        seg_query=seg_query_p,
        n_queries=n_queries,
        query_budget=_bucket(n_queries),
        n_budget=n_budget,
        row_budget=row_budget,
        lemma_budget=lemma_budget,
        key_budget=key_budget,
        doc_bits=db,
        tier=tier,
        block=block,
        n_events=n_events,
    )


# ---------------------------------------------------------------------------
# §13.4 the arena device program (gather -> pack -> cover -> score -> top-k)
# ---------------------------------------------------------------------------


def _binary_search(a: jax.Array, v: jax.Array, right: bool) -> jax.Array:
    """``searchsorted`` over sorted int32 ``a`` as a static log2(n) gather
    loop — the device form of the §9.3 binary search, measurably faster on
    CPU than ``jnp.searchsorted`` and trivially TPU-mappable (each step is
    one gather + compare over the query tensor)."""
    n = a.shape[0]
    lo = jnp.zeros(v.shape, jnp.int32)
    step = 1 << max(0, (n - 1).bit_length())
    while step > 1:
        step //= 2
        probe = a[jnp.minimum(lo + step - 1, n - 1)]
        go = (probe <= v) if right else (probe < v)
        lo = jnp.where(go, lo + step, lo)
    probe = a[jnp.minimum(lo, n - 1)]
    go = (probe <= v) if right else (probe < v)
    return lo + go.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "families",
        "e_budgets",
        "block",
        "max_distance",
        "query_budget",
        "n_budget",
        "row_budget",
        "lemma_budget",
        "s_budget",
        "key_budget",
        "doc_bits",
        "tier",
        "top_k",
        "use_kernel",
        "interpret",
    ),
)
def arena_serve_batch(
    buffers: tuple,  # per-family arena buffer, order = `families`
    gather_args: tuple,  # per-family descriptor arrays (form picked by
    #   use_kernel: block-aligned (src, nv, meta[G,5]) for the Pallas
    #   gather; dense (src_row, n, dest, meta[D,5]) for the jnp form)
    n_keys: jax.Array,  # [S] int32
    mult: jax.Array,  # [S, L] int32
    seg_query: jax.Array,  # [S] int32
    *,
    families: tuple,
    e_budgets: tuple,  # per-family dense event budgets (jnp form)
    block: int,
    max_distance: int,
    query_budget: int,
    n_budget: int,
    row_budget: int,
    lemma_budget: int,
    s_budget: int,
    key_budget: int,
    doc_bits: int,
    tier: str,
    top_k: int = 16,
    use_kernel: bool = False,
    interpret: bool = True,
):
    """One device program for an arena-resident query batch (DESIGN.md
    §13.4) — the on-device form of ``extract_segment_events`` +
    ``plan_query_batch`` + ``fused_serve_batch``:

    stage 0  block gather: ``kernels/gather.py`` slices every descriptor's
             arena extent into one (doc, pos) event workspace (Pallas
             scalar-prefetch kernel with ``use_kernel=True``, its dense jnp
             form otherwise — identical fragments either way);
    stage 1  one packed sort groups events by (segment, doc): dense
             candidate-row ids + Step-1 document alignment (distinct-key
             counting over each key's slot-0 stream keeps docs present in
             EVERY key iterator);
    stage 2  cross-key event dedup to one (doc, pos, lemma) + the Step-2
             multiplicity gate — exactly the host pack's ``np.unique`` +
             ``bincount`` gates;
    stage 3  event-centric rank cover: binary search over the (row, lemma,
             pos)-sorted stream replaces the §9 ``postab`` gather (same
             rank identity, no ``[R, L, K]`` materialization);
    stage 4  §14 scoring + per-query top-k — the same stages as
             ``fused_serve_batch``.

    Returns the §15.1 dense result buffer ``res`` (sorted unique
    ``(q, doc, start, end)`` rows plus per-query counts — the device
    readout's ONE fixed-shape D2H copy) alongside the per-event
    ``emit``/``start`` (aligned to the returned sorted ``comp`` stream) and
    the row maps the legacy host readout decodes fragments from.  Fragment
    sets are byte-identical to the host-pack path.
    """
    nb = (n_budget - 1).bit_length()
    lb = max((lemma_budget - 1).bit_length(), 1)
    kb = max((key_budget - 1).bit_length(), 1)
    db = doc_bits
    window = 2 * max_distance + 1

    # ---- stage 0: gather the (doc, pos) event streams ---------------------
    doc_l, pos_l, seg_l, lem_l, kd_l, em_l, key_l = [], [], [], [], [], [], []
    for fi, _fname in enumerate(families):
        if use_kernel:
            # block-aligned Pallas gather (scalar-prefetched DMA steering)
            f_src, f_nv, meta_b = gather_args[fi]
            rows = gather_blocks(
                buffers[fi], f_src, f_nv, block=block, interpret=interpret
            )
            meta = jnp.repeat(meta_b, block, axis=0)  # [G*B, 5]
        else:
            # dense jnp gather: descriptor extents pack back to back, so the
            # event budget tracks REAL rows (no per-extent block padding)
            d_srcrow, d_n, d_dest, d_meta = gather_args[fi]
            iota = jnp.arange(e_budgets[fi], dtype=jnp.int32)
            desc = _binary_search(d_dest, iota, right=True) - 1
            desc = jnp.clip(desc, 0, d_dest.shape[0] - 1)
            within = iota - d_dest[desc]
            alive = within < d_n[desc]
            srcrow = jnp.clip(d_srcrow[desc] + within, 0, buffers[fi].shape[0] - 1)
            rows = jnp.take(buffers[fi], srcrow, axis=0)
            rows = jnp.where(alive[:, None], rows, jnp.int32(-1))
            meta = d_meta[desc]  # [E, 5]
        doc_l.append(rows[:, 0])
        pos_l.append(rows[:, 1])
        seg_l.append(meta[:, 0])
        lem_l.append(meta[:, 1])
        kd_l.append(meta[:, 2])
        em_l.append(meta[:, 3])
        key_l.append(meta[:, 4])
    doc = jnp.concatenate(doc_l)
    pos = jnp.concatenate(pos_l)
    seg = jnp.concatenate(seg_l)
    lem = jnp.concatenate(lem_l)
    kd = jnp.concatenate(kd_l)
    emit_f = jnp.concatenate(em_l)
    key = jnp.concatenate(key_l)
    e = doc.shape[0]
    valid0 = (doc >= 0) & (seg >= 0)

    # ---- stage 1: one packed sort -> (seg, doc) rows + Step-1 gate --------
    # Composite layout (high -> low): seg | doc | key | kd-inverted | emit
    # | pos | lemma.  kd streams (slot 0) sort to the head of each
    # (seg, doc, key) group, so group-first & kd counts every key exactly
    # once per candidate doc — the §10.1 Step-1 iterator alignment as a
    # segmented count.  Invalid elements carry the int32 sentinel and sort
    # last.  ``tier`` picks one fused sort (everything fits 30 bits) or an
    # argsort + payload gathers (wide per-shard doc-id spaces).
    pos_c = jnp.where(emit_f > 0, pos, 0)
    head = ((((seg << db) | doc) << kb) | key) << 1 | (1 - kd)
    if tier == "pack32":
        pack = ((((head << 1) | emit_f) << nb) | pos_c) << lb | lem
        pack = jnp.where(valid0, pack, _I32_MAX)
        pack = jnp.sort(pack)
        fin1 = pack < _I32_MAX
        lem_s = pack & (lemma_budget - 1)
        pos_s = (pack >> lb) & (n_budget - 1)
        em_s = ((pack >> (lb + nb)) & 1) > 0
        head_s = pack >> (lb + nb + 1)
    else:  # "argsort"
        hkey = jnp.where(valid0, head, _I32_MAX)
        perm = jnp.argsort(hkey)
        head_s = hkey[perm]
        fin1 = head_s < _I32_MAX
        pos_s = pos_c[perm]
        em_s = emit_f[perm] > 0
        lem_s = lem[perm]
    kd_s = (head_s & 1) == 0  # kd-inverted bit
    sd = head_s >> (kb + 1)  # (seg, doc) group id
    grp_key = head_s >> 1  # (seg, doc, key) group id
    prev_sd = jnp.concatenate([jnp.array([-1], jnp.int32), sd[:-1]])
    prev_gk = jnp.concatenate([jnp.array([-1], jnp.int32), grp_key[:-1]])
    new_row = fin1 & (sd != prev_sd)
    row_id = jnp.where(fin1, jnp.cumsum(new_row.astype(jnp.int32)) - 1, row_budget)
    row_idc = jnp.clip(row_id, 0, row_budget - 1)
    # row boundaries: row_id is sorted, so per-row ranges come from binary
    # search instead of scatters (rows are contiguous runs of the sort)
    r_iota = jnp.arange(row_budget, dtype=jnp.int32)
    row_lo = _binary_search(row_id, r_iota, right=False)
    row_hi = _binary_search(row_id, r_iota, right=True)
    row_used = row_lo < row_hi
    row_lo_c = jnp.minimum(row_lo, e - 1)
    row_seg = jnp.where(row_used, sd[row_lo_c] >> db, 0)
    row_doc = jnp.where(row_used, sd[row_lo_c] & ((1 << db) - 1), -1)
    row_seg_c = jnp.clip(row_seg, 0, s_budget - 1)
    # Step-1: distinct keys present per (seg, doc) == the work item's key
    # count (single-key items skip the gate, as the host pack does)
    kd_first = fin1 & kd_s & (grp_key != prev_gk)
    cum_kd = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(kd_first.astype(jnp.int32))]
    )
    key_count = cum_kd[row_hi] - cum_kd[row_lo]
    need = n_keys[row_seg_c]
    row_pass = row_used & ((need < 2) | (key_count >= need))

    # ---- stage 2: dedup to one (doc, pos, lemma) + Step-2 gate ------------
    keep = fin1 & em_s & (pos_s < n_budget) & row_pass[row_idc]
    comp = (((row_idc << nb) | pos_s) << lb) | lem_s
    comp = jnp.where(keep, comp, _I32_MAX)
    comp = jnp.sort(comp)
    fin = comp < _I32_MAX
    prev = jnp.concatenate([jnp.array([-1], jnp.int32), comp[:-1]])
    uniq = fin & (comp != prev)
    lem2 = comp & (lemma_budget - 1)
    pos2 = (comp >> lb) & (n_budget - 1)
    row2 = jnp.clip(comp >> (lb + nb), 0, row_budget - 1)

    # ---- stage 3: the (row, lemma, pos)-sorted stream IS the §9.1 postab --
    cov = (((row2 << lb) | lem2) << nb) | pos2
    cov = jnp.where(uniq, cov, _I32_MAX)
    cov = jnp.sort(cov)
    # per-(row, lemma) group bounds once (small), reused by Step-2 and the
    # per-event cover; `cov` holds deduped events only, so range sizes are
    # exactly the distinct-position counts the host pack bincounts
    l_iota = jnp.arange(lemma_budget, dtype=jnp.int32)
    grp_rl = ((r_iota[:, None] << lb) | l_iota[None, :]) << nb  # [R, L]
    lo_rl = _binary_search(cov, grp_rl, right=False)
    cnt_rl = _binary_search(cov, grp_rl | (n_budget - 1), right=True) - lo_rl
    mult_rows = mult[row_seg_c]  # [R, L] (0 = unused slot, trivially passes)
    ok_row = row_used & jnp.all(cnt_rl >= mult_rows, axis=1)
    live = uniq & ok_row[row2]

    # event-centric rank cover (§9.3 identity): for event (row, pos) and
    # lemma l, cnt = occurrences of l at or before pos; the fragment start
    # is the mult-th latest, gathered straight from the sorted stream
    grp_e = ((row2[:, None] << lb) | l_iota[None, :]) << nb  # [E, L]
    hi_e = _binary_search(cov, grp_e | pos2[:, None], right=True)
    lo_e = lo_rl[row2]  # [E, L]
    cnt = hi_e - lo_e
    mult_e = mult_rows[row2]  # [E, L]
    active = mult_e > 0
    have = cnt >= mult_e
    sel = jnp.clip(lo_e + cnt - mult_e, 0, e - 1)
    p_sel = cov[sel] & (n_budget - 1)
    p_sel = jnp.where(active & have, p_sel, n_budget)
    start = jnp.min(p_sel, axis=-1)
    covered = jnp.all(have | ~active, axis=-1) & jnp.any(active, axis=-1)
    emit = live & covered & (start < n_budget) & (pos2 - start < window)
    start = jnp.where(emit, start, pos2)

    # ---- stage 4: §14 scoring + per-query top-k (as fused_serve_batch) ----
    pp = comp >> lb
    prev_pp = jnp.concatenate([jnp.array([-1], jnp.int32), pp[:-1]])
    primary = fin & (pp != prev_pp)
    emit_primary = emit & primary
    span = (pos2 - start).astype(jnp.float32)
    contrib = jnp.where(emit_primary, 1.0 / (span + 1.0) ** 2, 0.0)
    # per-row reductions via prefix sums over the row-sorted stream (`comp`
    # groups rows contiguously) — no [E]->[R] scatters on the hot path
    crow = jnp.where(fin, comp >> (lb + nb), row_budget)
    c_lo = _binary_search(crow, r_iota, right=False)
    c_hi = _binary_search(crow, r_iota, right=True)
    cum_scores = jnp.concatenate(
        [jnp.zeros((1,), jnp.float32), jnp.cumsum(contrib)]
    )
    scores = cum_scores[c_hi] - cum_scores[c_lo]
    scores = jnp.where(ok_row & (row_doc >= 0), scores, -jnp.inf)
    row_query = jnp.where(row_used, seg_query[row_seg_c], -1)
    qids = jax.lax.broadcasted_iota(jnp.int32, (query_budget, 1), 0)
    scores_q = jnp.where(row_query[None, :] == qids, scores[None, :], -jnp.inf)
    kk = min(top_k, row_budget)
    top_scores, idx = jax.lax.top_k(scores_q, kk)
    top_docs = jnp.where(jnp.isfinite(top_scores), row_doc[idx], -1)

    cum_frag = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(emit_primary.astype(jnp.int32))]
    )
    frag_per_row = cum_frag[c_hi] - cum_frag[c_lo]
    n_fragments = (
        jnp.zeros((query_budget,), jnp.int32)
        .at[jnp.clip(row_query, 0, query_budget - 1)]
        .add(jnp.where(row_query >= 0, frag_per_row, 0))
    )

    # §15.1 device-side result assembly over the deduped event stream —
    # identical dedup + output order to the fused host pack's buffer
    ev_q = row_query[row2]
    ev_d = row_doc[row2]
    frag_valid = emit_primary & (ev_q >= 0) & (ev_d >= 0)
    res = _assemble_fragments(ev_q, ev_d, start, pos2, frag_valid, query_budget)

    return {
        "emit": emit_primary,
        "start": start,
        "comp": comp,
        "row_doc": row_doc,
        "row_query": row_query,
        "res": res,
        "top_docs": top_docs,
        "top_scores": top_scores,
        "n_fragments": n_fragments,
    }


def _device_args(plan: ArenaBatchPlan, use_kernel: bool):
    """Assemble ONE arena program's device arguments from a plan.

    Returns ``(args, h2d_bytes)`` where ``args`` matches the positional
    signature of :func:`arena_serve_batch` and ``h2d_bytes`` counts the
    descriptor bytes enqueued host-to-device (the resident posting buffers
    themselves never move — that's the point of the arena, §13.1).  Shared
    by :func:`run_arena_batch` and :func:`lower_arena_batch` so the HLO
    captured for the §15.4 roofline is the program that actually serves.
    """
    groups = range(len(plan.families))
    if use_kernel:
        gather_args = tuple(
            (
                jnp.asarray(plan.src[g]),
                jnp.asarray(plan.nv[g]),
                jnp.asarray(plan.blk_meta[g]),
            )
            for g in groups
        )
        h2d = sum(
            plan.src[g].nbytes + plan.nv[g].nbytes + plan.blk_meta[g].nbytes
            for g in groups
        )
    else:
        gather_args = tuple(
            (
                jnp.asarray(plan.d_src[g]),
                jnp.asarray(plan.d_n[g]),
                jnp.asarray(plan.d_dest[g]),
                jnp.asarray(plan.d_meta[g]),
            )
            for g in groups
        )
        h2d = sum(
            plan.d_src[g].nbytes * 3 + plan.d_meta[g].nbytes for g in groups
        )
    args = (
        tuple(plan.buffers[g] for g in groups),
        gather_args,
        jnp.asarray(plan.n_keys),
        jnp.asarray(plan.mult),
        jnp.asarray(plan.seg_query),
    )
    h2d += plan.n_keys.nbytes + plan.mult.nbytes + plan.seg_query.nbytes
    return args, h2d


def _static_kwargs(
    plan: ArenaBatchPlan,
    *,
    max_distance: int,
    top_k: int,
    use_kernel: bool,
    interpret: bool,
) -> dict:
    """Static (jit-cache-keyed) kwargs of :func:`arena_serve_batch` for a
    plan — the shape/config half of the program's signature."""
    return dict(
        families=plan.families,
        e_budgets=tuple(plan.e_budget),
        block=plan.block,
        max_distance=max_distance,
        query_budget=plan.query_budget,
        n_budget=plan.n_budget,
        row_budget=plan.row_budget,
        lemma_budget=plan.lemma_budget,
        s_budget=len(plan.n_keys),
        key_budget=plan.key_budget,
        doc_bits=plan.doc_bits,
        tier=plan.tier,
        top_k=top_k,
        use_kernel=use_kernel,
        interpret=interpret,
    )


def lower_arena_batch(
    plan: ArenaBatchPlan,
    *,
    max_distance: int,
    top_k: int = 16,
    use_kernel: bool = False,
    interpret: bool = True,
):
    """Lower ONE arena device program WITHOUT dispatching it (DESIGN.md
    §15.4).  Returns the jax ``Lowered`` object; callers compile it and feed
    ``.as_text()`` to ``launch/hlo_analysis.analyze_hlo`` for the serving
    roofline (``benchmarks/paper_tables.bench_roofline``)."""
    args, _ = _device_args(plan, use_kernel)
    return arena_serve_batch.lower(
        *args,
        **_static_kwargs(
            plan,
            max_distance=max_distance,
            top_k=top_k,
            use_kernel=use_kernel,
            interpret=interpret,
        ),
    )


def run_arena_batch(
    plan: ArenaBatchPlan,
    *,
    max_distance: int,
    top_k: int = 16,
    use_kernel: bool = False,
    interpret: bool = True,
    stats: QueryStats | None = None,
    phases: dict | None = None,
    readout: str = "device",
    defer: bool = False,
):
    """Dispatch ONE arena device program and read results out (DESIGN.md
    §13.4).  The readout mirrors ``run_query_batch``: ``readout="device"``
    splits the §15.1 device-assembled result buffer (one fixed-shape D2H
    copy); ``readout="host"`` keeps the legacy ``np.nonzero`` +
    two-tier dedup over the event stream as the differential reference.
    ``defer=True`` returns a :class:`~repro.search.fused.PendingBatch`
    right after submit (§15.2).  Fragment sets are byte-identical to the
    host-pack path (``tests/test_arena.py``)."""
    from .fused import (
        FusedBatchResult,
        PendingBatch,
        _dedup_fragments,
        _split_result_buffer,
    )

    if readout not in ("device", "host"):
        raise ValueError(f"unknown readout mode: {readout!r}")
    t0 = time.perf_counter()
    args, h2d = _device_args(plan, use_kernel)
    if stats is not None:
        stats.h2d_bytes += h2d
    # enqueue time only — the premature block_until_ready(args[1:]) that
    # used to sit here forced a full descriptor H2D sync into the dispatch
    # window (the fused path's twin of the same bug)
    if phases is not None:
        phases.setdefault("h2d_us", []).append((time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
    out = arena_serve_batch(
        *args,
        **_static_kwargs(
            plan,
            max_distance=max_distance,
            top_k=top_k,
            use_kernel=use_kernel,
            interpret=interpret,
        ),
    )
    if stats is not None:
        stats.device_dispatches += 1
    if phases is not None:
        phases.setdefault("dispatch_us", []).append((time.perf_counter() - t0) * 1e6)

    nq = plan.n_queries

    def finalize():
        t1 = time.perf_counter()
        if phases is not None:
            # bench-only barrier: device time goes to compute_us, not to
            # whichever phase bracket encloses the first fetch
            jax.block_until_ready(out)
            now = time.perf_counter()
            phases.setdefault("compute_us", []).append((now - t1) * 1e6)
            t2 = now
        else:
            t2 = t1
        if readout == "device":
            buf = np.asarray(out["res"])
            frag_rows, frag_offsets = _split_result_buffer(
                buf, nq, plan.query_budget
            )
            result = FusedBatchResult(
                frag_rows=frag_rows,
                frag_offsets=frag_offsets,
                top_docs=np.asarray(out["top_docs"])[:nq],
                top_scores=np.asarray(out["top_scores"])[:nq],
                n_fragments=np.asarray(out["n_fragments"])[:nq],
            )
        else:
            nb = (plan.n_budget - 1).bit_length()
            lb = max((plan.lemma_budget - 1).bit_length(), 1)
            emit = np.asarray(out["emit"])
            (hits,) = np.nonzero(emit)
            comp = np.asarray(out["comp"])[hits].astype(np.int64)
            starts = np.asarray(out["start"])[hits].astype(np.int64)
            ends = (comp >> lb) & (plan.n_budget - 1)
            rows = comp >> (lb + nb)
            row_doc = np.asarray(out["row_doc"]).astype(np.int64)
            row_query = np.asarray(out["row_query"]).astype(np.int64)
            docs = row_doc[rows]
            q_of = row_query[rows]
            live = (q_of >= 0) & (q_of < nq)
            u_q, u_doc, u_start, u_end = _dedup_fragments(
                q_of[live], docs[live], starts[live], ends[live]
            )
            per_query: list[list[SearchResult]] = [[] for _ in range(nq)]
            for qi, d, st, en in zip(
                u_q.tolist(), u_doc.tolist(), u_start.tolist(), u_end.tolist()
            ):
                per_query[qi].append(SearchResult(doc_id=d, start=st, end=en))
            result = FusedBatchResult(
                per_query=per_query,
                top_docs=np.asarray(out["top_docs"])[:nq],
                top_scores=np.asarray(out["top_scores"])[:nq],
                n_fragments=np.asarray(out["n_fragments"])[:nq],
            )
        if phases is not None:
            phases.setdefault("readout_us", []).append(
                (time.perf_counter() - t2) * 1e6
            )
        return result

    if defer:
        return PendingBatch(finalize)
    return finalize()
