"""Query pipeline (paper §5, Figures 2–3).

1) Lemmatization            — multi-lemma dictionary expansion.
2) Building subqueries      — cartesian product over lemma alternatives.
3) Processing subqueries    — key selection + one of the §4 algorithms.
4) Combining results        — union of fragments, §14 proximity relevance.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Literal

from ..core.baselines import (
    se1_ordinary,
    se21_main_cell,
    se22_intermediate,
    se23_optimized,
)
from ..core.combiner import se24_combiner
from ..core.keys import Subquery, expand_subqueries
from ..core.lemma import Lemmatizer
from ..core.postings import QueryStats, SearchResult
from ..index.builder import IndexSet
from .relevance import rank_documents

__all__ = ["SearchEngine", "RankedDoc", "QueryResponse", "ALGORITHMS"]

Algorithm = Literal["se1", "se2.1", "se2.2", "se2.3", "se2.4"]

ALGORITHMS: dict[str, Callable[[Subquery, IndexSet], tuple[list[SearchResult], QueryStats]]] = {
    "se1": se1_ordinary,
    "se2.1": se21_main_cell,
    "se2.2": se22_intermediate,
    "se2.3": se23_optimized,
    "se2.4": se24_combiner,
}


@dataclass
class RankedDoc:
    doc_id: int
    score: float
    fragments: list[SearchResult]


@dataclass
class QueryResponse:
    query: str
    docs: list[RankedDoc]
    stats: QueryStats
    n_subqueries: int = 0


class SearchEngine:
    """Front door over one index shard (the distributed engine fans out to
    many of these — see ``search/distributed.py``)."""

    def __init__(
        self,
        index: IndexSet,
        lemmatizer: Lemmatizer | None = None,
        algorithm: Algorithm = "se2.4",
    ):
        self.index = index
        self.lemmatizer = lemmatizer or Lemmatizer()
        self.algorithm = algorithm

    def search(self, query: str, top_k: int = 10) -> QueryResponse:
        t0 = time.perf_counter()
        fn = ALGORITHMS[self.algorithm]
        subqueries = expand_subqueries(query, self.lemmatizer)
        total = QueryStats()
        all_results: set[SearchResult] = set()
        for sub in subqueries:
            results, stats = fn(sub, self.index)
            total.merge(stats)
            all_results.update(results)
        ranked = [
            RankedDoc(doc_id=d, score=s, fragments=f)
            for d, s, f in rank_documents(all_results, top_k=top_k)
        ]
        total.results = len(all_results)
        total.elapsed_sec = time.perf_counter() - t0
        return QueryResponse(
            query=query, docs=ranked, stats=total, n_subqueries=len(subqueries)
        )
