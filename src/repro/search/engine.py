"""Query pipeline (paper §5, Figures 2–3).

1) Lemmatization            — multi-lemma dictionary expansion.
2) Building subqueries      — cartesian product over lemma alternatives.
3) Processing subqueries    — key selection + one of the §4 algorithms.
4) Combining results        — union of fragments, §14 proximity relevance.

The host algorithms (``se1`` .. ``se2.4``) run one subquery at a time; the
``fused`` algorithm routes the whole query — and, through ``search_batch``, a
whole query *batch* — into one device program (``search/fused.py``).

Exactness contract: every algorithm choice returns the identical fragment
union for a query (the differential harness pins all of them against the
§10 oracle); they differ only in work and dispatch shape.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Literal, Sequence

from ..core.baselines import (
    se1_ordinary,
    se21_main_cell,
    se22_intermediate,
    se23_optimized,
)
from ..core.combiner import se24_combiner
from ..core.keys import Subquery, expand_subqueries
from ..core.lemma import Lemmatizer
from ..core.postings import QueryStats, SearchResult
from ..index.builder import IndexSet
from .relevance import rank_documents

__all__ = ["SearchEngine", "RankedDoc", "QueryResponse", "ALGORITHMS"]

Algorithm = Literal["se1", "se2.1", "se2.2", "se2.3", "se2.4", "fused"]

ALGORITHMS: dict[str, Callable[[Subquery, IndexSet], tuple[list[SearchResult], QueryStats]]] = {
    "se1": se1_ordinary,
    "se2.1": se21_main_cell,
    "se2.2": se22_intermediate,
    "se2.3": se23_optimized,
    "se2.4": se24_combiner,
}


@dataclass
class RankedDoc:
    """One ranked document: §14 proximity score plus its minimal fragments
    (sorted ``(start, end)`` — the ``rank_documents`` ordering spec)."""

    doc_id: int
    score: float
    fragments: list[SearchResult]


@dataclass
class QueryResponse:
    """A served query: §14-ranked docs plus the §11 per-query accounting
    (``QueryStats`` — postings/bytes read, cache and deadline counters)."""

    query: str
    docs: list[RankedDoc]
    stats: QueryStats
    n_subqueries: int = 0


class SearchEngine:
    """Front door over one index shard: the §5 pipeline end to end
    (lemmatize -> subqueries -> §4 algorithm -> §14 rank).  The distributed
    engine fans out to many of these — see ``search/distributed.py``."""

    def __init__(
        self,
        index: IndexSet,
        lemmatizer: Lemmatizer | None = None,
        algorithm: Algorithm = "se2.4",
        use_kernel: bool = False,
        doc_len: int = 512,
        arena=None,
    ):
        if algorithm != "fused" and algorithm not in ALGORITHMS:
            raise KeyError(algorithm)
        # ``index`` may be a plain IndexSet or an IncrementalIndexer; the
        # live multi-segment view is resolved per call, so commits, deletes
        # and compactions are picked up without rebuilding the engine.
        self._index_source = index
        self.lemmatizer = lemmatizer or Lemmatizer()
        self.algorithm = algorithm
        self.use_kernel = use_kernel
        self.doc_len = doc_len
        # optional device-resident posting arena (DESIGN.md §13), used by
        # the fused/planned paths; host algorithms never touch it
        self.arena = arena
        self._vec = None

    @property
    def index(self) -> IndexSet:
        from ..index.incremental import as_index_set

        return as_index_set(self._index_source)

    def _vectorized(self):
        if self._vec is None:
            from .vectorized import VectorizedEngine

            self._vec = VectorizedEngine(
                self._index_source,
                use_kernel=self.use_kernel,
                doc_len=self.doc_len,
                arena=self.arena,
            )
        return self._vec

    def search(self, query: str, top_k: int = 10) -> QueryResponse:
        return self.search_batch([query], top_k=top_k)[0]

    # ---- planned path (§5 made explicit; see search/planner.py) -----------

    def plan(self, query: str):
        """Build a :class:`~repro.search.planner.QueryPlan` for ``query``:
        §5 lemma classification, §6 key selection, §3 index-family bindings
        and live-view cost estimates.  Executing it (``search_planned``) is
        fragment-identical to ``search`` — the plan only makes the engine's
        implicit choices inspectable and prunable."""
        from .planner import QueryPlanner

        return QueryPlanner(self._index_source, lemmatizer=self.lemmatizer).plan(
            query
        )

    def search_planned(self, plan, top_k: int = 10) -> QueryResponse:
        """Execute a pre-built plan through the fused pipeline (one device
        dispatch).  Exactness: byte-identical fragments to ``search`` with
        ``algorithm="fused"`` on the same live view (``tests/test_planner.py``
        pins this against the §10 oracle)."""
        from .planner import execute_plans

        view = self.index
        residencies = None
        if self.arena is not None:
            from ..index.incremental import generation_token

            res = self.arena.acquire(view, generation_token(self._index_source))
            residencies = {id(view): res}
        return execute_plans(
            [plan],
            [view],
            max_distance=view.max_distance,
            top_k=top_k,
            doc_len=self.doc_len,
            use_kernel=self.use_kernel,
            residencies=residencies,
        )[0]

    def search_batch(
        self, queries: Sequence[str], top_k: int = 10
    ) -> list[QueryResponse]:
        """Serve a batch of queries.

        With ``algorithm="fused"`` the whole batch — every subquery of every
        query — is one device dispatch; host algorithms fall back to the
        per-subquery loop.
        """
        if self.algorithm == "fused":
            return self._search_batch_fused(queries, top_k)
        return [self._search_host(q, top_k) for q in queries]

    # ---- host per-subquery path -------------------------------------------

    def _search_host(self, query: str, top_k: int) -> QueryResponse:
        t0 = time.perf_counter()
        fn = ALGORITHMS[self.algorithm]
        subqueries = expand_subqueries(query, self.lemmatizer)
        total = QueryStats()
        all_results: set[SearchResult] = set()
        for sub in subqueries:
            results, stats = fn(sub, self.index)
            total.merge(stats)
            all_results.update(results)
        ranked = [
            RankedDoc(doc_id=d, score=s, fragments=f)
            for d, s, f in rank_documents(all_results, top_k=top_k)
        ]
        total.results = len(all_results)
        total.elapsed_sec = time.perf_counter() - t0
        return QueryResponse(
            query=query, docs=ranked, stats=total, n_subqueries=len(subqueries)
        )

    # ---- fused batched path ------------------------------------------------

    def _search_batch_fused(
        self, queries: Sequence[str], top_k: int
    ) -> list[QueryResponse]:
        t0 = time.perf_counter()
        per_query_subs = [expand_subqueries(q, self.lemmatizer) for q in queries]
        per_stats = [QueryStats() for _ in queries]
        result, _ = self._vectorized().search_query_batch(
            per_query_subs, top_k=top_k, per_query_stats=per_stats
        )
        elapsed = time.perf_counter() - t0
        responses = []
        for qi, query in enumerate(queries):
            docs = [
                RankedDoc(doc_id=d, score=s, fragments=f)
                for d, s, f in rank_documents(result.per_query[qi], top_k=top_k)
            ]
            qstats = per_stats[qi]
            qstats.results = len(result.per_query[qi])
            qstats.elapsed_sec = elapsed  # batch wall time (shared dispatch)
            responses.append(
                QueryResponse(
                    query=query,
                    docs=docs,
                    stats=qstats,
                    n_subqueries=len(per_query_subs[qi]),
                )
            )
        return responses
