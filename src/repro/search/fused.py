"""Fused, batched, query-at-a-time serving pipeline (DESIGN.md §9).

The serving unit is a *query batch*.  Every (query, subquery, shard) work
item becomes one fixed-shape **segment** of compact event triples
``(doc_slot, pos, lemma)`` — no dense host-side occupancy.  Candidate
(segment, doc) pairs share one global row axis R, packed densely, and a
single jit'd device program runs, for all segments of all queries at once:

    per-event rank cover  ->  §14 scoring  ->  per-query top-k

The cover is the *event-centric* form of the rank identity behind
``core.window.window_cover_rank_batch``: a fragment ending at event position
``e`` starts at ``min over lemmas l of p_l(e)``, the position of the
``mult[l]``-th latest occurrence of ``l`` at or before ``e``.  The device
gathers ``p_l(e)`` from per-(row, lemma) occurrence-position tables, so the
work is O(events) — proportional to real occurrences, like the paper's
Combiner — instead of O(rows * positions) dense occupancy sweeps.  With
``use_kernel=True`` the cover instead scatters occupancy on-device and runs
the Pallas window kernel (the TPU-native dense layout), gathering back to
event granularity; both paths produce identical fragments.

Fragment dedup and result assembly run **on device** (DESIGN.md §15.1): the
program sorts the (query, doc, start, end) fragment keys, drops adjacent
duplicates, and compacts the survivors into a dense result buffer, so the
host readout is ONE fixed-shape D2H copy per batch — no host ``np.nonzero``
/ ``np.unique`` on the serving path (``readout="host"`` keeps the legacy
host dedup as a differential reference).  All shape budgets (events E, rows
R, lemmas L, table depth K, queries Q) are bucketed to powers of two so the
number of distinct compiled programs stays logarithmic in the workload
spread (DESIGN.md §9.2).

Candidate selection for multi-key subqueries additionally runs the
Combiner's Step-1 document alignment as a *pre-filter* over sorted doc-id
lists (``kernels/intersect.py``), and Step 2's counting gate drops candidate
documents that cannot meet any lemma's multiplicity — only surviving
documents enter the row budget.

``serve_query_batch`` is the routing entry over this host-pack path and the
DESIGN.md §13 device-resident posting arena (``search/arena.py``): work
items whose keys are resident ship only descriptors and gather/pack on
device; the rest run through ``plan_query_batch`` exactly as before.
Fragment sets are identical for every routing.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from ..core.keys import SelectedKey, Subquery, select_keys
from ..core.postings import QueryStats, SearchResult
from ..index.builder import IndexSet, POSTING_WIDTH
from ..kernels.intersect import PAD, block_offsets, intersect_sorted
from ..kernels.proximity import proximity_window

__all__ = [
    "SegmentEvents",
    "QueryBatchPlan",
    "FusedBatchResult",
    "PendingBatch",
    "bucket_pow2",
    "extract_segment_events",
    "intersect_candidates",
    "plan_query_batch",
    "fused_serve_batch",
    "lower_query_batch",
    "run_query_batch",
    "serve_query_batch",
    "dispatch_count",
    "reset_dispatch_count",
    "collect_phases",
    "compile_count",
]

# Default list size above which the Step-1 pre-filter pays for a device
# round-trip; below it the same block intersection runs as host searchsorted.
INTERSECT_DEVICE_THRESHOLD = 4096

_DISPATCHES = 0


def dispatch_count() -> int:
    """Device programs issued by this module since the last reset (tests and
    the DESIGN.md §9 benches count these to assert one-dispatch-per-batch
    serving)."""
    return _DISPATCHES


def reset_dispatch_count() -> None:
    """Zero the DESIGN.md §9 dispatch counter (see ``dispatch_count``)."""
    global _DISPATCHES
    _DISPATCHES = 0


# ---------------------------------------------------------------------------
# phase attribution + compile accounting (DESIGN.md §13.5 benches)
# ---------------------------------------------------------------------------

# When a sink dict is installed, the serving paths attribute wall time to
# the six phases of a batch, appended per batch in µs (DESIGN.md §15.3):
#
#   plan_us      host posting reads + segment extraction
#   pack_us      host-side batch packing (or arena descriptor planning)
#   h2d_us       ENQUEUE time of the input transfers (async; no barrier)
#   dispatch_us  jit-call SUBMIT time (tracing/cache lookup + enqueue)
#   compute_us   block_until_ready wait for the device program (only
#                recorded when a sink is installed — production serving
#                never inserts this barrier; under the two-deep pipeline it
#                measures the NON-overlapped remainder of device time)
#   readout_us   the fixed-shape D2H result-buffer copy + split
#
# The six sum to the serial batch wall time with no double-counting: every
# timestamp closes one phase and opens the next.  The sink itself adds no
# barriers beyond the compute_us wait.
_PHASE_SINK: dict | None = None


def collect_phases(sink: dict | None) -> dict | None:
    """Install (or clear, with ``None``) the phase-breakdown sink used by
    ``benchmarks/run.py`` to attribute batch latency (plan / pack / h2d /
    dispatch / compute / readout — the DESIGN.md §15.3 attribution).
    Returns the previous sink."""
    global _PHASE_SINK
    prev, _PHASE_SINK = _PHASE_SINK, sink
    return prev


def _phase(sink: dict | None, name: str, t0: float) -> float:
    now = time.perf_counter()
    if sink is not None:
        sink.setdefault(name, []).append((now - t0) * 1e6)
    return now


def compile_count() -> int | None:
    """Compiled-program count across the serving device entry points
    (``fused_serve_batch`` + ``arena_serve_batch``), or ``None`` when the
    jax version exposes no jit-cache introspection.  The recompile-churn
    regression test pins that identically-bucketed batches reuse ONE
    compiled program (DESIGN.md §9.2/§13.4)."""
    from .arena import arena_serve_batch

    total = 0
    for fn in (fused_serve_batch, arena_serve_batch):
        cache_size = getattr(fn, "_cache_size", None)
        if cache_size is None:
            return None
        total += cache_size()
    return total


def bucket_pow2(n: int, lo: int = 1) -> int:
    """Smallest power of two >= max(n, lo) — the jit-cache shape budget of
    DESIGN.md §9.2 (padded batching, logarithmically many compiled programs)."""
    n = max(n, lo)
    return 1 << (n - 1).bit_length()


# ---------------------------------------------------------------------------
# compact event transport (host side)
# ---------------------------------------------------------------------------


@dataclass
class SegmentEvents:
    """Compact event transport for one (subquery, shard) work item — the
    §10.4 ``Set`` calls batched into triples (DESIGN.md §9.1).

    Events are deduplicated and sorted by (doc, pos, lemma).  ``rank`` is the
    event's occurrence index within its (doc, lemma) group — the row of the
    per-(row, lemma) position table it fills; ``primary`` marks the first
    event at each (doc, pos) so positionwise quantities (scores, fragment
    counts) are not double-counted for multi-lemma positions.
    """

    doc_ids: np.ndarray  # [Bd] sorted unique candidate doc ids
    slot: np.ndarray  # [E] int32 index into doc_ids
    pos: np.ndarray  # [E] int32 document position
    lem: np.ndarray  # [E] int32 local lemma id
    rank: np.ndarray  # [E] int32 occurrence index within (doc, lemma)
    primary: np.ndarray  # [E] bool first event of its (doc, pos)
    mult: np.ndarray  # [L] int32 required multiplicity per local lemma
    lemmas: list[str]  # local lemma id -> lemma


def _device_intersect(
    a: np.ndarray, b: np.ndarray, block_a: int = 128, block_b: int = 256
) -> np.ndarray:
    """Membership mask of sorted-unique ``a`` in sorted-unique ``b`` via the
    Pallas block-intersection kernel (scalar-prefetched offsets)."""
    global _DISPATCHES
    na = bucket_pow2(len(a), block_a)
    nb = bucket_pow2(len(b), block_b)
    a_p = np.full((na,), PAD, np.int32)
    a_p[: len(a)] = a
    b_p = np.full((nb,), PAD, np.int32)
    b_p[: len(b)] = b
    offsets = block_offsets(a_p, b_p, block_a, block_b)
    # size the chunk sweep from data statistics: matches of a real a-block
    # end before searchsorted(b, block_last, right)
    n_blocks = na // block_a
    last_idx = np.minimum(np.arange(1, n_blocks + 1) * block_a - 1, len(a) - 1)
    ends = np.searchsorted(b_p[: len(b)], a_p[last_idx], side="right")
    span = np.maximum(ends - offsets, 1)
    n_chunks = bucket_pow2(int(np.ceil(span.max() / block_b)))
    hit = np.asarray(
        intersect_sorted(
            jnp.asarray(a_p),
            jnp.asarray(b_p),
            jnp.asarray(offsets),
            block_a=block_a,
            block_b=block_b,
            n_chunks=n_chunks,
        )
    )
    _DISPATCHES += 1
    return hit[: len(a)] > 0


def intersect_candidates(
    doc_lists: Sequence[np.ndarray],
    device_threshold: int = INTERSECT_DEVICE_THRESHOLD,
) -> np.ndarray:
    """Sorted-unique doc-list intersection across a subquery's keys — the
    Combiner's §10.1 Step-1 document alignment, run once as a batch
    pre-filter (DESIGN.md §9.1).

    Lists at or above ``device_threshold`` go through the Pallas block
    intersection (``kernels/intersect.py``); smaller ones use the identical
    host form (searchsorted) where a device round-trip would not pay off.
    """
    lists = sorted((np.asarray(d) for d in doc_lists), key=len)
    acc = lists[0]
    for other in lists[1:]:
        if not len(acc):
            return acc
        if min(len(acc), len(other)) >= device_threshold:
            hit = _device_intersect(acc, other)
        else:
            i = np.minimum(np.searchsorted(other, acc), len(other) - 1)
            hit = other[i] == acc
        acc = acc[hit]
    return acc


def extract_segment_events(
    subquery: Subquery,
    index: IndexSet,
    keys: Sequence[SelectedKey] | None = None,
    doc_len: int = 512,
    stats: QueryStats | None = None,
    intersect_device_threshold: int = INTERSECT_DEVICE_THRESHOLD,
) -> SegmentEvents | None:
    """Key postings -> compact (doc_slot, pos, lemma) event triples — the
    §10.4 ``Set`` calls batched, plus the §10.1/§10.3 pre-filters
    (DESIGN.md §9.1).

    Returns ``None`` for an empty subquery (no key events, or the Step-1
    candidate intersection is empty) so callers short-circuit instead of
    dispatching an all-padding batch; the skip is counted in
    ``QueryStats.empty_subqueries``.  An index with no live documents (an
    empty shard, or a multi-segment view whose docs are all tombstoned)
    short-circuits before any key-posting lookup — segment-union merges are
    never forced for work items that cannot contribute candidates.
    """
    if index.n_docs == 0:
        if stats is not None:
            stats.empty_subqueries += 1
        return None
    keys = list(keys) if keys is not None else select_keys(subquery, index.fl)
    lemmas = subquery.unique_lemmas()
    lid = {l: i for i, l in enumerate(lemmas)}
    mult_map = subquery.multiplicity()
    mult = np.array([mult_map[l] for l in lemmas], dtype=np.int32)

    # vectorized event extraction: one (doc, pos, lemma) column set per
    # unstarred key slot — no per-posting Python work
    ev_doc, ev_pos, ev_lem = [], [], []
    key_docs: list[np.ndarray] = []
    for key in keys:
        rows = np.asarray(index.key_postings(key.components))
        if stats is not None:
            stats.postings_read += len(rows)
            stats.bytes_read += rows.nbytes
        key_docs.append(
            np.unique(rows[:, 0]) if len(rows) else np.empty((0,), np.int32)
        )
        if not len(rows):
            continue
        comps, stars = key.components, key.starred
        for slot in range(len(comps)):
            if stars[slot]:
                continue
            pos = rows[:, 1] if slot == 0 else rows[:, 1] + rows[:, 1 + slot]
            ev_doc.append(rows[:, 0])
            ev_pos.append(pos)
            ev_lem.append(np.full(len(rows), lid[comps[slot]], np.int32))

    if not ev_doc:
        if stats is not None:
            stats.empty_subqueries += 1
        return None
    doc_a = np.concatenate(ev_doc)
    pos_a = np.concatenate(ev_pos)
    lem_a = np.concatenate(ev_lem)
    ok = pos_a >= 0
    doc_a, pos_a, lem_a = doc_a[ok], pos_a[ok], lem_a[ok]
    if len(pos_a):
        # the position modulus must cover every real position: documents
        # longer than the caller's doc_len hint must not lose fragments
        doc_len = max(doc_len, int(pos_a.max()) + 1)

    # Step-1 pre-filter: a fragment needs every key iterator on the document
    if len(key_docs) >= 2:
        cand = intersect_candidates(key_docs, device_threshold=intersect_device_threshold)
        if len(cand) and len(doc_a):
            i = np.minimum(np.searchsorted(cand, doc_a), len(cand) - 1)
            keep = cand[i] == doc_a
            doc_a, pos_a, lem_a = doc_a[keep], pos_a[keep], lem_a[keep]
        else:
            doc_a = doc_a[:0]

    if not len(doc_a):
        if stats is not None:
            stats.empty_subqueries += 1
        return None

    # dedup events (occupancy semantics: one event per (doc, pos, lemma))
    # and run Step 2's counting gate batched: a candidate doc whose distinct
    # positions of some lemma fall short of its multiplicity can never emit
    # a fragment — drop its rows before the device budget.
    n_lem = len(lemmas)
    comp = (doc_a.astype(np.int64) * doc_len + pos_a) * n_lem + lem_a
    comp = np.unique(comp)  # sorted by (doc, pos, lemma)
    lem_a = (comp % n_lem).astype(np.int32)
    pos_a = ((comp // n_lem) % doc_len).astype(np.int32)
    doc_a = (comp // (n_lem * doc_len)).astype(np.int32)
    docs, slot = np.unique(doc_a, return_inverse=True)
    counts = np.bincount(
        slot * n_lem + lem_a, minlength=len(docs) * n_lem
    ).reshape(len(docs), n_lem)
    ok_doc = (counts >= mult[None, :]).all(axis=1)
    if not ok_doc.all():
        keep = ok_doc[slot]
        doc_a, pos_a, lem_a = doc_a[keep], pos_a[keep], lem_a[keep]
        if not len(doc_a):
            if stats is not None:
                stats.empty_subqueries += 1
            return None
        docs, slot = np.unique(doc_a, return_inverse=True)

    # occurrence rank within (doc, lemma) + primary flag per (doc, pos)
    order = np.lexsort((pos_a, lem_a, slot))
    grp = slot[order].astype(np.int64) * n_lem + lem_a[order]
    new_grp = np.r_[True, grp[1:] != grp[:-1]]
    grp_start = np.maximum.accumulate(
        np.where(new_grp, np.arange(len(order)), 0)
    )
    rank = np.empty(len(order), np.int32)
    rank[order] = (np.arange(len(order)) - grp_start).astype(np.int32)
    pos_key = slot.astype(np.int64) * doc_len + pos_a
    primary = np.r_[True, pos_key[1:] != pos_key[:-1]]

    return SegmentEvents(
        doc_ids=docs.astype(np.int32),
        slot=slot.astype(np.int32),
        pos=pos_a.astype(np.int32),
        lem=lem_a.astype(np.int32),
        rank=rank,
        primary=primary,
        mult=mult,
        lemmas=lemmas,
    )


# ---------------------------------------------------------------------------
# query-batch plan (bucketed, padded, fixed-shape)
# ---------------------------------------------------------------------------


@dataclass
class QueryBatchPlan:
    """Fixed-shape tensors for one fused device dispatch (DESIGN.md §9.2
    bucketed budgets; the §10.4 events of every work item, packed).

    The batch is packed *row-major*: every (segment, candidate-doc) pair of
    every query occupies one row of a single global row axis ``R`` — no
    per-segment doc-slot padding, so total device work tracks the real
    candidate count, not ``segments x max(docs per segment)``.  ``postab``
    is the per-(row, lemma) occurrence-position table the event-centric
    cover gathers from (pad = ``doc_len``, which compares greater than every
    real position).  Padding rows have ``row_doc = -1`` / ``row_query = -1``
    / ``mult = 0`` and provably emit nothing.
    """

    events: np.ndarray  # [E, 3] int32 (row, pos, lemma), pad = -1
    primary: np.ndarray  # [E] int8 first-event-of-(row, pos) flag
    postab: np.ndarray  # [R, L, K] int32 k-th occurrence position, pad = doc_len
    row_doc: np.ndarray  # [R] int32 global doc id per row, pad = -1
    row_query: np.ndarray  # [R] int32 query index per row, pad = -1
    mult: np.ndarray  # [R, L] int32 (0 = unused lemma slot)
    n_queries: int  # live queries (<= query_budget)
    query_budget: int  # bucket_pow2(n_queries), static in the device program
    doc_len: int  # bucketed window budget (<= the caller's doc_len cap)


def plan_query_batch(
    work: Sequence[Sequence[tuple]],
    doc_len: int = 512,
    stats: QueryStats | Sequence[QueryStats] | None = None,
    intersect_device_threshold: int = INTERSECT_DEVICE_THRESHOLD,
) -> QueryBatchPlan | None:
    """Pack a query batch into one device program's inputs.

    ``work[qi]`` lists query ``qi``'s ``(subquery, index-shard)`` items — the
    cross product the per-subquery serving loops used to dispatch one call
    each for.  An item may carry a third element, the §6 keys to use
    (``(subquery, index, keys)``): the query planner passes its pre-selected
    bindings this way so plan execution reads exactly the postings the plan
    costed (``search/planner.py``); two-element items select keys themselves,
    and both forms produce identical events for identical key sets.
    ``stats`` is one accumulator for the batch or one per query.  Returns
    ``None`` when every item is empty (nothing to dispatch).
    """
    def stat_for(qi: int) -> QueryStats | None:
        if stats is None or isinstance(stats, QueryStats):
            return stats
        return stats[qi]

    sink = _PHASE_SINK
    t0 = time.perf_counter()
    segs: list[tuple[int, SegmentEvents]] = []
    for qi, items in enumerate(work):
        for item in items:
            sub, index = item[0], item[1]
            keys = item[2] if len(item) > 2 else None
            se = extract_segment_events(
                sub,
                index,
                keys=keys,
                doc_len=doc_len,
                stats=stat_for(qi),
                intersect_device_threshold=intersect_device_threshold,
            )
            if se is not None:
                segs.append((qi, se))
    t0 = _phase(sink, "plan_us", t0)
    if not segs:
        return None

    n_rows = sum(len(se.doc_ids) for _, se in segs)
    n_events = sum(len(se.slot) for _, se in segs)
    r_budget = bucket_pow2(n_rows, lo=8)
    e_budget = bucket_pow2(n_events, lo=64)
    l_budget = bucket_pow2(max(len(se.lemmas) for _, se in segs), lo=2)
    k_budget = bucket_pow2(max(int(se.rank.max()) for _, se in segs) + 1, lo=4)
    # position budget: bucketed from the last real event, NOT clamped to the
    # caller's doc_len hint — long documents keep their fragments (the event
    # path's cost barely depends on it; only the dense kernel path scatters
    # [R, L, N] occupancy)
    max_pos = max(int(se.pos.max()) for _, se in segs)
    n_budget = bucket_pow2(max_pos + 1, lo=64)

    events = np.full((e_budget, 3), -1, np.int32)
    primary = np.zeros((e_budget,), np.int8)
    postab = np.full((r_budget, l_budget, k_budget), n_budget, np.int32)
    row_doc = np.full((r_budget,), -1, np.int32)
    row_query = np.full((r_budget,), -1, np.int32)
    mult = np.zeros((r_budget, l_budget), np.int32)
    row = ev = 0
    for qi, se in segs:
        nd, ne = len(se.doc_ids), len(se.slot)
        events[ev : ev + ne, 0] = se.slot + row
        events[ev : ev + ne, 1] = se.pos
        events[ev : ev + ne, 2] = se.lem
        primary[ev : ev + ne] = se.primary
        postab[se.slot + row, se.lem, se.rank] = se.pos
        row_doc[row : row + nd] = se.doc_ids
        row_query[row : row + nd] = qi
        mult[row : row + nd, : len(se.mult)] = se.mult
        row += nd
        ev += ne
    _phase(sink, "pack_us", t0)
    return QueryBatchPlan(
        events=events,
        primary=primary,
        postab=postab,
        row_doc=row_doc,
        row_query=row_query,
        mult=mult,
        n_queries=len(work),
        query_budget=bucket_pow2(len(work)),
        doc_len=n_budget,
    )


# ---------------------------------------------------------------------------
# the fused device program
# ---------------------------------------------------------------------------

_I32_SENTINEL = np.int32(np.iinfo(np.int32).max)


def _assemble_fragments(
    q: jax.Array,  # [E] int32 query index per event
    d: jax.Array,  # [E] int32 doc id per event
    s: jax.Array,  # [E] int32 fragment start per event
    e: jax.Array,  # [E] int32 fragment end per event
    valid: jax.Array,  # [E] bool emitting primary events
    query_budget: int,
) -> jax.Array:
    """Device-side fragment dedup + result assembly (DESIGN.md §15.1).

    Sorts the per-event fragment keys ``(q, d, s, e)`` lexicographically
    (invalid events carry the int32 sentinel in every column and sort last),
    drops adjacent duplicates, and compacts the survivors to the head of a
    dense ``[E + Q, 4]`` int32 result buffer — the same dedup the host
    readout's ``np.unique`` over packed ``frag_key`` performs, with the same
    output order (ascending ``(q, doc, start, end)``), but with no host
    ``nonzero``/``unique`` and no bit-packing (four int32 sort keys instead
    of one packed int64, so there is no width budget to overflow).

    The trailing ``Q`` rows carry the per-query unique-fragment counts in
    column 0, so the whole readout is ONE fixed-shape D2H copy: the host
    splits ``buf[:counts.sum()]`` by ``cumsum(counts)`` — rows are already
    grouped by query because the sort key leads with ``q``.
    """
    cap = q.shape[0]
    qk = jnp.where(valid, q, _I32_SENTINEL)
    dk = jnp.where(valid, d, _I32_SENTINEL)
    sk = jnp.where(valid, s, _I32_SENTINEL)
    ek = jnp.where(valid, e, _I32_SENTINEL)
    qs, ds, ss, es = jax.lax.sort((qk, dk, sk, ek), num_keys=4)

    def prev(col: jax.Array) -> jax.Array:
        return jnp.concatenate([jnp.full((1,), -1, col.dtype), col[:-1]])

    fin = qs < _I32_SENTINEL
    dup = (qs == prev(qs)) & (ds == prev(ds)) & (ss == prev(ss)) & (es == prev(es))
    uniq = fin & ~dup
    # compaction scatter: unique survivors go to their prefix-sum slot,
    # everything else to an out-of-bounds destination dropped by the scatter
    dest = jnp.where(
        uniq, jnp.cumsum(uniq.astype(jnp.int32)) - 1, cap + query_budget
    )
    rows = jnp.stack([qs, ds, ss, es], axis=1)
    buf = jnp.full((cap + query_budget, 4), -1, jnp.int32)
    buf = buf.at[dest].set(rows, mode="drop")
    counts = (
        jnp.zeros((query_budget,), jnp.int32)
        .at[jnp.clip(qs, 0, query_budget - 1)]
        .add(uniq.astype(jnp.int32))
    )
    return buf.at[cap:, 0].set(counts)


@functools.partial(
    jax.jit,
    static_argnames=(
        "max_distance",
        "query_budget",
        "window_len",
        "top_k",
        "compute_dtype",
        "use_kernel",
        "interpret",
    ),
)
def fused_serve_batch(
    events: jax.Array,  # [E, 3] int32 (row, pos, lemma), pad = -1
    primary: jax.Array,  # [E] int8 first-event-of-(row, pos) flag
    postab: jax.Array,  # [R, L, K] int32 occurrence positions, pad = window_len
    row_doc: jax.Array,  # [R] int32 global doc id per row, pad = -1
    row_query: jax.Array,  # [R] int32 query index per row, pad = -1
    mult: jax.Array,  # [R, L] int32
    *,
    max_distance: int,
    query_budget: int,
    window_len: int,
    top_k: int = 16,
    compute_dtype: str = "uint8",  # §Perf-3: dense-path occupancy fits u8
    use_kernel: bool = False,
    interpret: bool = True,
):
    """One device program for a whole query batch.

    stage 1  per-event rank cover: for every event, gather the mult-th
             latest occurrence position of every lemma from ``postab`` —
             fragment start = min over active lemmas, emit iff the span
             fits ``2 * max_distance`` (O(events), no dense occupancy);
             with ``use_kernel=True``: scatter occupancy [R, L, N] on-device
             instead and run the Pallas window kernel, then gather emit and
             start back to event granularity;
    stage 2  §14 relevance per row (scatter-add of per-event contributions);
    stage 3  per-query top-k via a [Q, R] masked selection over row scores.

    ``top_docs`` is row-level: a document reachable through two subqueries
    of the same query occupies two rows — exact ranking uses the fragment
    readout (DESIGN.md §9.3).  Fragments themselves ARE deduplicated on
    device: ``res`` is the §15.1 dense result buffer
    (``_assemble_fragments`` — sorted unique ``(q, doc, start, end)`` rows
    plus per-query counts), read out as one fixed-shape D2H copy.
    """
    r, l, k = postab.shape
    n = window_len
    q = query_budget
    window = 2 * max_distance + 1

    row = events[..., 0]
    pos = events[..., 1]
    lem = events[..., 2]
    ok = (row >= 0) & (row < r) & (pos >= 0) & (pos < n) & (lem >= 0) & (lem < l)
    row_s = jnp.clip(row, 0, r - 1)

    if use_kernel:
        # ---- dense path: on-device scatter + Pallas window kernel ---------
        cdt = jnp.dtype(compute_dtype)
        flat = (row_s * l + jnp.maximum(lem, 0)) * n + jnp.maximum(pos, 0)
        occ = jnp.zeros((r * l * n,), cdt).at[flat].max(ok.astype(cdt))
        occ = occ.reshape(r, l, n)
        emit_rn, start_rn = proximity_window(
            occ, mult, max_distance, interpret=interpret, compute_dtype=compute_dtype
        )
        pos_s = jnp.clip(pos, 0, n - 1)
        emit = ok & emit_rn[row_s, pos_s]
        start = start_rn[row_s, pos_s]
    else:
        # ---- event-centric rank cover -------------------------------------
        tab = postab[row_s]  # [E, L, K]
        mrow = mult[row_s]  # [E, L]
        active = mrow > 0
        # C_l(pos): occurrences of lemma l at/before this event's position.
        # postab rows are position-sorted, so this is a log2(K)-step binary
        # search per (event, lemma) instead of a K-wide compare-reduce.
        cnt = jnp.zeros(tab.shape[:2], jnp.int32)  # [E, L]
        step = k
        while step > 1:
            step //= 2
            probe = jnp.take_along_axis(
                tab, jnp.minimum(cnt + step - 1, k - 1)[..., None], axis=-1
            )[..., 0]
            cnt = jnp.where(probe <= pos[:, None], cnt + step, cnt)
        # strides sum to k-1, so a full prefix undercounts by one: final probe
        probe = jnp.take_along_axis(
            tab, jnp.minimum(cnt, k - 1)[..., None], axis=-1
        )[..., 0]
        cnt = cnt + (probe <= pos[:, None]).astype(jnp.int32)
        have = cnt >= mrow
        sel = jnp.clip(cnt - mrow, 0, k - 1)
        p_sel = jnp.take_along_axis(tab, sel[..., None], axis=-1)[..., 0]
        p_sel = jnp.where(active & have, p_sel, n)  # inactive -> +inf for min
        start = jnp.min(p_sel, axis=-1)  # [E] largest covering q
        covered = jnp.all(have | ~active, axis=-1) & jnp.any(active, axis=-1)
        emit = ok & covered & (start < n) & (pos - start < window)
        start = jnp.where(emit, start, pos)

    # ---- §14 relevance per row (primary events only: one per position) ----
    span = (pos - start).astype(jnp.float32)
    contrib = jnp.where(emit & (primary > 0), 1.0 / (span + 1.0) ** 2, 0.0)
    scores = jnp.zeros((r,), jnp.float32).at[row_s].add(
        jnp.where(ok, contrib, 0.0)
    )
    scores = jnp.where(row_doc >= 0, scores, -jnp.inf)

    # ---- per-query top-k ---------------------------------------------------
    qids = jax.lax.broadcasted_iota(jnp.int32, (q, 1), 0)
    scores_q = jnp.where(row_query[None, :] == qids, scores[None, :], -jnp.inf)
    kk = min(top_k, r)
    top_scores, idx = jax.lax.top_k(scores_q, kk)  # [Q, K]
    top_docs = jnp.where(jnp.isfinite(top_scores), row_doc[idx], -1)

    frag_per_row = (
        jnp.zeros((r,), jnp.int32)
        .at[row_s]
        .add((emit & (primary > 0)).astype(jnp.int32))
    )
    n_fragments = (
        jnp.zeros((q,), jnp.int32)
        .at[jnp.clip(row_query, 0, q - 1)]
        .add(jnp.where(row_query >= 0, frag_per_row, 0))
    )

    # ---- §15.1 device-side result assembly --------------------------------
    ev_q = row_query[row_s]
    ev_d = row_doc[row_s]
    frag_valid = emit & (primary > 0) & (ev_q >= 0) & (ev_d >= 0)
    res = _assemble_fragments(ev_q, ev_d, start, pos, frag_valid, q)

    return {
        "emit": emit,
        "start": start,
        "res": res,
        "top_docs": top_docs,
        "top_scores": top_scores,
        "n_fragments": n_fragments,
    }


# ---------------------------------------------------------------------------
# execution + vectorized readout
# ---------------------------------------------------------------------------


class FusedBatchResult:
    """Per-query exact fragment sets plus the device's slot-level ranking
    (DESIGN.md §9.3: the fragment readout is the exact §10.2 result; the
    device top-k is row-level, for dashboards/serve_step consumers).

    The device readout (§15.1) carries fragments as the compact
    ``frag_rows``/``frag_offsets`` pair — ``per_query`` materializes
    ``SearchResult`` objects lazily on first access, keeping Python object
    construction off the readout-phase critical path.  The host readout and
    the empty/merge paths construct eagerly with ``per_query=...``.
    """

    __slots__ = (
        "top_docs",
        "top_scores",
        "n_fragments",
        "frag_rows",
        "frag_offsets",
        "_per_query",
    )

    def __init__(
        self,
        *,
        top_docs: np.ndarray,  # [Q, K] int32 (-1 pad)
        top_scores: np.ndarray,  # [Q, K] float32
        n_fragments: np.ndarray,  # [Q] pre-dedup emit counts
        per_query: list[list[SearchResult]] | None = None,
        frag_rows: np.ndarray | None = None,  # [F, 3] int32 (doc, start, end)
        frag_offsets: np.ndarray | None = None,  # [Q + 1] int64 cumulative
    ):
        if per_query is None and frag_offsets is None:
            raise ValueError("need per_query or frag_rows/frag_offsets")
        self.top_docs = top_docs
        self.top_scores = top_scores
        self.n_fragments = n_fragments
        self.frag_rows = frag_rows
        self.frag_offsets = frag_offsets
        self._per_query = per_query

    @property
    def n_queries(self) -> int:
        if self._per_query is not None:
            return len(self._per_query)
        return len(self.frag_offsets) - 1

    def n_results(self, qi: int) -> int:
        """Deduped fragment count for query ``qi`` without materializing
        ``SearchResult`` objects (stats accounting on the serving path)."""
        if self._per_query is not None:
            return len(self._per_query[qi])
        return int(self.frag_offsets[qi + 1] - self.frag_offsets[qi])

    @property
    def per_query(self) -> list[list[SearchResult]]:
        """Deduped fragment union per query, sorted by (doc, start, end);
        materialized from ``frag_rows`` on first access and cached."""
        if self._per_query is None:
            rows = self.frag_rows.tolist()
            offs = self.frag_offsets.tolist()
            make = SearchResult._make
            self._per_query = [
                [make(r) for r in rows[offs[qi] : offs[qi + 1]]]
                for qi in range(len(offs) - 1)
            ]
        return self._per_query


class PendingBatch:
    """Handle for an in-flight query batch (DESIGN.md §15.2).

    ``run_query_batch``/``run_arena_batch``/``serve_query_batch`` with
    ``defer=True`` return one of these right after SUBMITTING the device
    program — the H2D copies and the program itself are enqueued but not
    awaited, so the caller can plan/pack/submit the next batch while this
    one computes.  ``result()`` performs the blocking readout (idempotent;
    the result is cached).
    """

    __slots__ = ("_thunk", "_result")

    def __init__(self, thunk):
        self._thunk = thunk
        self._result = None

    def result(self) -> FusedBatchResult:
        if self._thunk is not None:
            self._result = self._thunk()
            self._thunk = None
        return self._result


def empty_batch_result(n_queries: int, top_k: int) -> FusedBatchResult:
    return FusedBatchResult(
        per_query=[[] for _ in range(n_queries)],
        top_docs=np.full((n_queries, top_k), -1, np.int32),
        top_scores=np.full((n_queries, top_k), -np.inf, np.float32),
        n_fragments=np.zeros((n_queries,), np.int64),
    )


def _dedup_fragments(
    q_of: np.ndarray, docs: np.ndarray, starts: np.ndarray, ends: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Host-side fragment dedup: sorted unique ``(q, doc, start, end)``
    columns, in the same ascending order the §15.1 device assembly emits.

    Two tiers, mirroring the arena's pack32/argsort split
    (``plan_arena_batch``): when the packed key fits int64 the dedup is one
    ``np.unique`` over ``((q * D + doc) * N + start) * N + end``; otherwise
    — wide doc-id spaces or very long documents, where packing would
    silently alias distinct fragments — it falls back to ``np.lexsort`` +
    adjacent-diff, which has no width budget at all.
    """
    if q_of.size == 0:
        z = np.zeros((0,), np.int64)
        return z, z, z, z
    doc_mod = int(docs.max(initial=0)) + 1
    n_mod = int(max(starts.max(initial=0), ends.max(initial=0))) + 1
    q_mod = int(q_of.max(initial=0)) + 1
    if (q_mod * doc_mod * n_mod * n_mod - 1).bit_length() <= 63:
        frag_key = ((q_of * doc_mod + docs) * n_mod + starts) * n_mod + ends
        uniq = np.unique(frag_key)
        u_end = uniq % n_mod
        u_start = (uniq // n_mod) % n_mod
        u_doc = (uniq // (n_mod * n_mod)) % doc_mod
        u_q = uniq // (n_mod * n_mod * doc_mod)
        return u_q, u_doc, u_start, u_end
    order = np.lexsort((ends, starts, docs, q_of))
    q_s, d_s, s_s, e_s = q_of[order], docs[order], starts[order], ends[order]
    keep = np.ones(q_s.shape, bool)
    keep[1:] = (
        (q_s[1:] != q_s[:-1])
        | (d_s[1:] != d_s[:-1])
        | (s_s[1:] != s_s[:-1])
        | (e_s[1:] != e_s[:-1])
    )
    return q_s[keep], d_s[keep], s_s[keep], e_s[keep]


def _split_result_buffer(
    buf: np.ndarray, n_queries: int, query_budget: int
) -> tuple[np.ndarray, np.ndarray]:
    """Split the fetched §15.1 result buffer into ``(frag_rows,
    frag_offsets)``: the trailing ``query_budget`` rows carry per-query
    counts in column 0; the head rows are the compacted unique fragments,
    already grouped by query in ascending key order."""
    cap = buf.shape[0] - query_budget
    counts = buf[cap : cap + n_queries, 0].astype(np.int64)
    offsets = np.zeros((n_queries + 1,), np.int64)
    np.cumsum(counts, out=offsets[1:])
    frag_rows = buf[: int(offsets[-1]), 1:4]
    return frag_rows, offsets


def lower_query_batch(
    plan: QueryBatchPlan,
    *,
    max_distance: int,
    top_k: int = 16,
    use_kernel: bool = False,
    compute_dtype: str = "uint8",
    interpret: bool = True,
):
    """Lower ONE fused device program WITHOUT dispatching it (DESIGN.md
    §15.4).  Returns the jax ``Lowered`` object for the exact program
    :func:`run_query_batch` would execute; callers compile it and feed
    ``.as_text()`` to ``launch/hlo_analysis.analyze_hlo`` for the serving
    roofline (``benchmarks/paper_tables.bench_roofline``)."""
    return fused_serve_batch.lower(
        jnp.asarray(plan.events),
        jnp.asarray(plan.primary),
        jnp.asarray(plan.postab),
        jnp.asarray(plan.row_doc),
        jnp.asarray(plan.row_query),
        jnp.asarray(plan.mult),
        max_distance=max_distance,
        query_budget=plan.query_budget,
        window_len=plan.doc_len,
        top_k=top_k,
        compute_dtype=compute_dtype,
        use_kernel=use_kernel,
        interpret=interpret,
    )


def run_query_batch(
    plan: QueryBatchPlan,
    *,
    max_distance: int,
    top_k: int = 16,
    use_kernel: bool = False,
    compute_dtype: str = "uint8",
    interpret: bool = True,
    stats: QueryStats | None = None,
    readout: str = "device",
    defer: bool = False,
) -> FusedBatchResult | PendingBatch:
    """Dispatch ONE device program for the plan and read results out of the
    §15.1 device-assembled dense buffer — one fixed-shape D2H copy
    (``readout="device"``; the fragment sets are exact §10.2 results,
    identical to the scalar Combiner).  ``readout="host"`` instead fetches
    the per-event emit/start arrays and dedups on the host — the legacy
    path, kept as the differential reference (``tests/test_readout.py``).
    ``defer=True`` returns a :class:`PendingBatch` right after submit, so
    the device program runs while the caller prepares the next batch
    (§15.2)."""
    if readout not in ("device", "host"):
        raise ValueError(f"unknown readout mode: {readout!r}")
    global _DISPATCHES
    sink = _PHASE_SINK
    t0 = time.perf_counter()
    inputs = (
        jnp.asarray(plan.events),
        jnp.asarray(plan.primary),
        jnp.asarray(plan.postab),
        jnp.asarray(plan.row_doc),
        jnp.asarray(plan.row_query),
        jnp.asarray(plan.mult),
    )
    if stats is not None:
        stats.h2d_bytes += (
            plan.events.nbytes + plan.primary.nbytes + plan.postab.nbytes
            + plan.row_doc.nbytes + plan.row_query.nbytes + plan.mult.nbytes
        )
    # enqueue time only: the transfers complete asynchronously, overlapped
    # with submit — the premature block_until_ready(inputs) that used to sit
    # here forced a full H2D sync inside the dispatch window
    t0 = _phase(sink, "h2d_us", t0)
    out = fused_serve_batch(
        *inputs,
        max_distance=max_distance,
        query_budget=plan.query_budget,
        window_len=plan.doc_len,
        top_k=top_k,
        compute_dtype=compute_dtype,
        use_kernel=use_kernel,
        interpret=interpret,
    )
    _DISPATCHES += 1
    if stats is not None:
        stats.device_dispatches += 1
    _phase(sink, "dispatch_us", t0)

    nq = plan.n_queries

    def finalize() -> FusedBatchResult:
        t1 = time.perf_counter()
        if sink is not None:
            # bench-only barrier: bills device time to compute_us instead of
            # whichever phase bracket happens to enclose the first fetch
            jax.block_until_ready(out)
            t1 = _phase(sink, "compute_us", t1)
        if readout == "device":
            buf = np.asarray(out["res"])
            frag_rows, frag_offsets = _split_result_buffer(
                buf, nq, plan.query_budget
            )
            result = FusedBatchResult(
                frag_rows=frag_rows,
                frag_offsets=frag_offsets,
                top_docs=np.asarray(out["top_docs"])[:nq],
                top_scores=np.asarray(out["top_scores"])[:nq],
                n_fragments=np.asarray(out["n_fragments"])[:nq],
            )
        else:
            # legacy host readout: one nonzero over the event batch (primary
            # events carry one fragment per emitting position), then the
            # two-tier host dedup — differential reference for §15.1
            emit = np.asarray(out["emit"]) & (plan.primary > 0)
            (hits,) = np.nonzero(emit)
            starts = np.asarray(out["start"])[hits].astype(np.int64)
            ends = plan.events[hits, 1].astype(np.int64)
            rows = plan.events[hits, 0]
            docs = plan.row_doc[rows].astype(np.int64)
            q_of = plan.row_query[rows].astype(np.int64)
            live = (q_of >= 0) & (q_of < nq)
            u_q, u_doc, u_start, u_end = _dedup_fragments(
                q_of[live], docs[live], starts[live], ends[live]
            )
            per_query: list[list[SearchResult]] = [[] for _ in range(nq)]
            for qi, d, st, en in zip(
                u_q.tolist(), u_doc.tolist(), u_start.tolist(), u_end.tolist()
            ):
                per_query[qi].append(SearchResult(doc_id=d, start=st, end=en))
            result = FusedBatchResult(
                per_query=per_query,
                top_docs=np.asarray(out["top_docs"])[:nq],
                top_scores=np.asarray(out["top_scores"])[:nq],
                n_fragments=np.asarray(out["n_fragments"])[:nq],
            )
        _phase(sink, "readout_us", t1)
        return result

    if defer:
        return PendingBatch(finalize)
    return finalize()


# ---------------------------------------------------------------------------
# arena/host orchestration (DESIGN.md §13: resident descriptors, host fallback)
# ---------------------------------------------------------------------------


def _merge_results(
    results: Sequence[FusedBatchResult], n_queries: int, top_k: int
) -> FusedBatchResult:
    """Union per-query fragment sets and re-merge the row-level top-k lists
    of a split arena + host execution.  Device-readout results merge at the
    array level — concatenate fragment columns, re-dedup with the two-tier
    host dedup — so a mixed batch never materializes ``SearchResult``
    objects; results that already carry ``per_query`` lists union as sets
    (the same dedup)."""
    if len(results) == 1:
        return results[0]
    scores = np.concatenate([r.top_scores for r in results], axis=1)
    docs = np.concatenate([r.top_docs for r in results], axis=1)
    order = np.argsort(-scores, axis=1, kind="stable")[:, :top_k]
    top_docs = np.take_along_axis(docs, order, axis=1)
    top_scores = np.take_along_axis(scores, order, axis=1)
    n_fragments = sum(r.n_fragments for r in results)
    if all(r.frag_offsets is not None and r._per_query is None for r in results):
        q_col = np.concatenate(
            [
                np.repeat(
                    np.arange(n_queries, dtype=np.int64),
                    np.diff(r.frag_offsets),
                )
                for r in results
            ]
        )
        rows = np.concatenate(
            [r.frag_rows for r in results], dtype=np.int64, casting="unsafe"
        ).reshape(-1, 3)
        u_q, u_d, u_s, u_e = _dedup_fragments(
            q_col, rows[:, 0], rows[:, 1], rows[:, 2]
        )
        counts = np.bincount(u_q, minlength=n_queries)
        offsets = np.zeros((n_queries + 1,), np.int64)
        np.cumsum(counts, out=offsets[1:])
        return FusedBatchResult(
            frag_rows=np.stack([u_d, u_s, u_e], axis=1).astype(np.int32),
            frag_offsets=offsets,
            top_docs=top_docs,
            top_scores=top_scores,
            n_fragments=n_fragments,
        )
    per_query: list[list[SearchResult]] = []
    for qi in range(n_queries):
        union: set[SearchResult] = set()
        for r in results:
            union.update(r.per_query[qi])
        per_query.append(sorted(union))
    return FusedBatchResult(
        per_query=per_query,
        top_docs=top_docs,
        top_scores=top_scores,
        n_fragments=n_fragments,
    )


def serve_query_batch(
    work: Sequence[Sequence[tuple]],
    *,
    max_distance: int,
    top_k: int = 16,
    doc_len: int = 512,
    use_kernel: bool = False,
    compute_dtype: str = "uint8",
    interpret: bool = True,
    stats: QueryStats | Sequence[QueryStats] | None = None,
    batch_stats: QueryStats | None = None,
    residencies: dict | None = None,
    intersect_device_threshold: int = INTERSECT_DEVICE_THRESHOLD,
    readout: str = "device",
    defer: bool = False,
) -> FusedBatchResult | PendingBatch:
    """Serve one query batch, routing each (subquery, shard) work item over
    the device-resident posting arena when its keys are resident and through
    the host-pack path otherwise (DESIGN.md §13).

    ``work`` is the ``plan_query_batch`` cross product (items are
    ``(subquery, index[, keys])``); ``residencies`` maps ``id(view)`` to the
    :class:`~repro.search.arena.ArenaResidency` acquired for that view (no
    entry = host path for that view's items).  A fully resident batch is ONE
    arena dispatch; a fully host batch is ONE host dispatch; a mixed batch
    runs both and merges — never more than two device programs.

    Exactness contract: the returned per-query fragment sets are identical
    for every routing (arena, host, or mixed) and equal to the §10 oracle —
    the arena program reproduces the host pack's dedup, Step-1/Step-2 gates
    and rank cover bit-for-bit (``tests/test_arena.py``,
    ``tests/test_differential.py``).

    ``readout``/``defer`` forward to ``run_query_batch`` /
    ``run_arena_batch``: with ``defer=True`` the return value is a
    :class:`PendingBatch` whose device program(s) are submitted but not
    awaited — the §15.2 double-buffer hook the frontend pipeline rides.
    """
    from .arena import ArenaOverflow, plan_arena_batch, run_arena_batch

    global _DISPATCHES

    def stat_for(qi: int) -> QueryStats | None:
        if stats is None or isinstance(stats, QueryStats):
            return stats
        return stats[qi]

    sink = _PHASE_SINK
    host_work: list[list[tuple]] = [[] for _ in work]
    arena_items: list[tuple] = []
    arena_fallback: list[tuple[int, tuple]] = []
    t0 = time.perf_counter()
    for qi, items in enumerate(work):
        for item in items:
            sub, view = item[0], item[1]
            res = residencies.get(id(view)) if residencies else None
            if res is None:
                host_work[qi].append(item)
                continue
            keys = (
                list(item[2])
                if len(item) > 2 and item[2] is not None
                else select_keys(sub, view.fl)
            )
            st = stat_for(qi)
            extents = []
            for key in keys:
                ext = res.lookup(key.components)
                if ext is None:
                    break
                extents.append(ext)
            if len(extents) < len(keys):
                if st is not None:
                    # per-key units, like arena_hits: every key of the item
                    # is served by the host pack
                    st.arena_misses += len(keys)
                # carry the selected keys: the host pack accepts 3-tuples,
                # so key selection is not recomputed for the fallback
                host_work[qi].append((sub, view, keys))
                continue

            def account(hit=True, st=st, keys=keys, extents=extents):
                # §11 accounting parity with the host pack: the arena path
                # reads the same rows, just on the device.  ``hit=False``
                # records an overflow fallback — the keys resolved but the
                # batch executed on the host, which does its own counting.
                if st is None:
                    return
                if not hit:
                    st.arena_misses += len(keys)
                    return
                st.arena_hits += len(keys)
                for ext in extents:
                    st.postings_read += ext.n_rows
                    st.bytes_read += ext.n_rows * 4 * POSTING_WIDTH.get(
                        ext.family, 2
                    )

            # provably-empty short-circuits, mirroring the host pack
            # (extract_segment_events returning None):
            if (
                not keys
                or all(e.n_rows == 0 for e in extents)
                or (len(keys) >= 2 and any(e.n_rows == 0 for e in extents))
            ):
                account()
                if st is not None:
                    st.empty_subqueries += 1
                continue
            arena_items.append((qi, sub, keys, extents, res))
            # fallback bookkeeping: the (sub, view, keys) item for host
            # re-queueing (keys carried, not recomputed), the accounting
            # thunk applied ONLY if the arena plan succeeds (on
            # ArenaOverflow the host pack does its own counting — no double
            # charge, no phantom arena_hits)
            arena_fallback.append((qi, (sub, view, keys), account))

    results: list[FusedBatchResult] = []
    if arena_items:
        try:
            aplan = plan_arena_batch(arena_items, n_queries=len(work))
        except ArenaOverflow:
            aplan = None
            for qi, item3, account in arena_fallback:
                account(hit=False)
                host_work[qi].append(item3)
        if aplan is not None:
            for _qi, _item3, account in arena_fallback:
                account()
        # the arena's whole host side — routing + descriptor planning —
        # is the pack phase (there is no plan phase: no posting is read)
        t0 = _phase(sink, "pack_us", t0)
        if aplan is not None:
            results.append(
                run_arena_batch(
                    aplan,
                    max_distance=max_distance,
                    top_k=top_k,
                    use_kernel=use_kernel,
                    interpret=interpret,
                    stats=batch_stats,
                    phases=sink,
                    readout=readout,
                    defer=defer,
                )
            )
            _DISPATCHES += 1
    if any(host_work):
        hplan = plan_query_batch(
            host_work,
            doc_len=doc_len,
            stats=stats,
            intersect_device_threshold=intersect_device_threshold,
        )
        if hplan is not None:
            results.append(
                run_query_batch(
                    hplan,
                    max_distance=max_distance,
                    top_k=top_k,
                    use_kernel=use_kernel,
                    compute_dtype=compute_dtype,
                    interpret=interpret,
                    stats=batch_stats,
                    readout=readout,
                    defer=defer,
                )
            )
    n_queries = len(work)
    if not results:
        empty = empty_batch_result(n_queries, top_k)
        return PendingBatch(lambda: empty) if defer else empty
    if defer:
        pending = list(results)
        return PendingBatch(
            lambda: _merge_results(
                [p.result() for p in pending], n_queries, top_k
            )
        )
    return _merge_results(results, n_queries, top_k)
