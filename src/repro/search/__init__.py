from .engine import SearchEngine, RankedDoc, QueryResponse
from .relevance import fragment_score, rank_documents

__all__ = ["SearchEngine", "RankedDoc", "QueryResponse", "fragment_score", "rank_documents"]
