from .engine import SearchEngine, RankedDoc, QueryResponse
from .frontend import PostingCache, SearchRequest, ServingFrontend
from .planner import KeyBinding, QueryPlan, QueryPlanner, SubqueryPlan, execute_plans
from .relevance import fragment_score, rank_documents
from .service import (
    ReplicatedServiceDaemon,
    RequestHandle,
    ServiceDaemon,
    Ticket,
    request_over_tcp,
    serve_tcp,
)

__all__ = [
    "SearchEngine",
    "RankedDoc",
    "QueryResponse",
    "fragment_score",
    "rank_documents",
    "QueryPlanner",
    "QueryPlan",
    "SubqueryPlan",
    "KeyBinding",
    "execute_plans",
    "ServingFrontend",
    "SearchRequest",
    "PostingCache",
    "ServiceDaemon",
    "ReplicatedServiceDaemon",
    "RequestHandle",
    "Ticket",
    "serve_tcp",
    "request_over_tcp",
]
