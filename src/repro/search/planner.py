"""Query planner (paper §5–§6; companion arXiv 2009.03679 §serving).

The paper's query pipeline is *planned*: every query lemma is classified
against the corpus FL-list thresholds (stop / frequently-used / ordinary,
§5), and the classification decides which §3 multi-component index family —
(f,s,t) triple, (w,v) pair, NSW, or ordinary — answers each subquery.  The
engines in this repo previously hard-coded that choice inside each call
(``select_keys`` ran inline, costs were discovered by reading postings); this
module lifts it into an explicit, inspectable **plan**:

* :class:`QueryPlanner` classifies lemmas (``core.keys.classify_lemmas``),
  selects §6 keys, binds each key to its §3 index family
  (``core.keys.key_family``) and attaches a per-subquery cost estimate —
  real posting-list lengths and byte sizes read from the **live** index view
  (a ``SegmentedIndexSet`` resolves per call, so estimates track commits,
  deletes and compactions).
* Subqueries proved empty at plan time are **pruned exactly**: a subquery
  emits a fragment only if every lemma supplies at least one event, and a
  lemma's events come solely from the posting lists of keys carrying it
  unstarred — zero total supply therefore implies zero fragments, which is
  precisely when the engines would return nothing after doing the work.
* :func:`execute_plans` runs a batch of plans through the fused device
  pipeline (ONE dispatch per batch, ``search/fused.py``) using the plan's
  own key bindings, so execution reads exactly the postings the plan costed.

Exactness contract: planned execution returns byte-identical fragment sets
to the unplanned SE2.4 / fused engines on the same live view — the planner
only *re-orders and prunes provably-empty work*, never changes results
(pinned by ``tests/test_planner.py`` against the §10 oracle).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Sequence

from ..core.keys import (
    EXECUTABLE_FAMILIES,
    SelectedKey,
    Subquery,
    classify_lemmas,
    expand_subqueries,
    key_family,
    select_keys,
)
from ..core.lemma import FLList, Lemmatizer, LemmaType
from ..core.postings import QueryStats
from ..index.builder import IndexSet
from .fused import serve_query_batch
from .relevance import rank_documents

__all__ = [
    "KeyBinding",
    "SubqueryPlan",
    "QueryPlan",
    "QueryPlanner",
    "execute_plans",
    "resolve_index_views",
]

_POSTING_BYTES = 4  # int32 fields


@dataclass(frozen=True)
class KeyBinding:
    """One §6 key bound to the §3 index family that serves it (§5 step 3).

    ``est_postings`` / ``est_bytes`` are the *actual* posting rows and bytes
    the key reads from the live view at plan time — not a model estimate, so
    plan cost equals execution cost exactly (the live view caches the merged
    arrays the execution then reuses).  Non-executable families (``"nsw"``,
    ``"ordinary"`` — see ``core.keys.key_family``) always cost zero.
    """

    key: SelectedKey
    family: str
    est_postings: int
    est_bytes: int

    @property
    def executable(self) -> bool:
        return self.family in EXECUTABLE_FAMILIES


@dataclass
class SubqueryPlan:
    """The plan for one §5 subquery: classified lemmas, bound keys, cost.

    ``pruned`` marks subqueries proved empty at plan time (some lemma has
    zero event supply across all bound keys) — exact, the engines would
    return no fragments for them; ``prune_reason`` names the witness.
    """

    subquery: Subquery
    keys: tuple[SelectedKey, ...]
    bindings: tuple[KeyBinding, ...]
    lemma_types: dict[str, LemmaType]
    est_postings: int
    est_bytes: int
    pruned: bool = False
    prune_reason: str = ""


@dataclass
class QueryPlan:
    """An executable plan for one word query (§5 stages 1–3, made explicit).

    ``generation`` snapshots the index source's cache-invalidation token at
    plan time (DESIGN.md §11): a plan is valid exactly while the token
    matches the live source, and frontend caches key on it.
    """

    query: str
    subqueries: list[SubqueryPlan]
    generation: object = 0
    plan_sec: float = 0.0

    def executable(self) -> list[SubqueryPlan]:
        """Subqueries that survive exact pruning, in plan order."""
        return [sp for sp in self.subqueries if not sp.pruned]

    @property
    def est_postings(self) -> int:
        return sum(sp.est_postings for sp in self.executable())

    @property
    def est_bytes(self) -> int:
        return sum(sp.est_bytes for sp in self.executable())

    @property
    def n_pruned(self) -> int:
        return sum(1 for sp in self.subqueries if sp.pruned)

    def explain(self) -> str:
        """Human-readable plan dump (the ``launch/serve.py --explain`` view)."""
        lines = [f"plan {self.query!r}: {len(self.subqueries)} subqueries, "
                 f"~{self.est_postings} postings "
                 f"({self.est_bytes / 1024:.1f} KB), "
                 f"{self.n_pruned} pruned, planned in "
                 f"{self.plan_sec * 1e3:.2f} ms"]
        type_names = {LemmaType.STOP: "stop", LemmaType.FREQUENTLY_USED: "fu",
                      LemmaType.ORDINARY: "ord"}
        for sp in self.subqueries:
            classes = " ".join(
                f"{l}/{type_names[t]}" for l, t in sp.lemma_types.items()
            )
            status = f"PRUNED ({sp.prune_reason})" if sp.pruned else (
                f"{sp.est_postings} postings")
            lines.append(f"  [{' '.join(sp.subquery.lemmas)}]  {classes}  -> {status}")
            for b in sp.bindings:
                star = "".join("*" if s else "." for s in b.key.starred)
                lines.append(
                    f"    {b.family:<11} ({', '.join(b.key.components)}) "
                    f"[{star}] {b.est_postings} rows"
                )
        return "\n".join(lines)


def resolve_index_views(source) -> tuple[list[IndexSet], FLList, int, Lemmatizer | None]:
    """Resolve any index source into ``(live views, fl, max_distance, lemmatizer)``.

    Accepted sources (the same duck types the engines accept, §5 serving):

    * ``ShardedSearchService`` — every live shard view, the corpus-global
      FL-list, the service's lemmatizer;
    * ``IncrementalIndexer``   — its live multi-segment view;
    * plain ``IndexSet`` (or ``SegmentedIndexSet``) — itself.

    Views are resolved *per call*: planning immediately after a commit or
    compact sees the new generation.
    """
    shards = getattr(source, "shards", None)
    if shards is not None:  # ShardedSearchService
        views = list(shards)
        return (
            views,
            source.fl,
            source.max_distance,
            getattr(source, "lemmatizer", None),
        )
    from ..index.incremental import IncrementalIndexer

    if isinstance(source, IncrementalIndexer):
        view = source.index
        return [view], view.fl, source.max_distance, source.lemmatizer
    return [source], source.fl, source.max_distance, None


class QueryPlanner:
    """§5 planning front-half: classify, select keys, bind, cost, prune.

    Planning reads posting-list *lengths* from the live view, which on a
    ``SegmentedIndexSet`` forces (and caches) exactly the per-key merges that
    execution will reuse — the probe is a prefetch, not duplicated work.
    Exactness: plans carry the same ``select_keys`` output the unplanned
    engines compute, so executing a plan is fragment-identical to the
    unplanned path (``tests/test_planner.py``).
    """

    def __init__(self, source, lemmatizer: Lemmatizer | None = None):
        self._source = source
        src_lem = resolve_index_views(source)[3]
        self.lemmatizer = lemmatizer or src_lem or Lemmatizer()

    def plan(
        self,
        query: str,
        views: Sequence[IndexSet] | None = None,
        generation: object = None,
    ) -> QueryPlan:
        """Build the executable plan for ``query`` against the live view.

        ``views`` overrides the source-resolved live views (the frontend
        passes its posting-cache-wrapped views here so the cost probe warms
        the cache); ``generation`` stamps the plan's validity token.
        """
        from ..index.incremental import generation_token

        t0 = time.perf_counter()
        if views is None:
            views, fl, _, _ = resolve_index_views(self._source)
        else:
            views = list(views)
            fl = views[0].fl if views else resolve_index_views(self._source)[1]
        if generation is None:
            generation = generation_token(self._source)

        plan = QueryPlan(query=query, subqueries=[], generation=generation)
        for sub in expand_subqueries(query, self.lemmatizer):
            plan.subqueries.append(self._plan_subquery(sub, fl, views))
        plan.plan_sec = time.perf_counter() - t0
        return plan

    def _plan_subquery(
        self, sub: Subquery, fl: FLList, views: Sequence[IndexSet]
    ) -> SubqueryPlan:
        keys = tuple(select_keys(sub, fl))
        lemma_types = classify_lemmas(sub.lemmas, fl)
        bindings: list[KeyBinding] = []
        supply: dict[str, int] = {l: 0 for l in sub.unique_lemmas()}
        for key in keys:
            n_rows = 0
            n_bytes = 0
            for view in views:
                if getattr(view, "n_docs", 0) == 0:
                    continue  # empty shard: engines short-circuit it too
                rows = view.key_postings(key.components)
                n_rows += len(rows)
                n_bytes += int(getattr(rows, "nbytes", len(rows) * _POSTING_BYTES))
            bindings.append(
                KeyBinding(
                    key=key,
                    family=key_family(key, fl),
                    est_postings=n_rows,
                    est_bytes=n_bytes,
                )
            )
            for _slot, lemma in key.active_components():
                supply[lemma] += n_rows
        pruned, reason = False, ""
        if not keys:
            pruned, reason = True, "empty subquery"
        else:
            for lemma, n in supply.items():
                if n == 0:
                    pruned = True
                    reason = f"no postings supply lemma {lemma!r}"
                    break
        return SubqueryPlan(
            subquery=sub,
            keys=keys,
            bindings=tuple(bindings),
            lemma_types=lemma_types,
            est_postings=sum(b.est_postings for b in bindings),
            est_bytes=sum(b.est_bytes for b in bindings),
            pruned=pruned,
            prune_reason=reason,
        )


def execute_plans(
    plans: Sequence[QueryPlan],
    views: Sequence[IndexSet],
    *,
    max_distance: int,
    top_k: int = 10,
    doc_len: int = 512,
    use_kernel: bool = False,
    compute_dtype: str = "uint8",
    admitted: Sequence[Sequence[SubqueryPlan]] | None = None,
    residencies: dict | None = None,
    defer: bool = False,
) -> list:
    """Execute a batch of plans as ONE fused device dispatch (§5 stage 3–4).

    ``admitted[qi]`` optionally restricts query ``qi`` to a subquery subset
    (the frontend's deadline admission); default is every executable
    subquery.  Each subquery carries its plan's key bindings into the batch
    packer, so execution reads exactly the costed postings.  ``residencies``
    maps ``id(view)`` to a posting-arena residency (DESIGN.md §13): resident
    work items gather/pack on device, the rest take the host path —
    fragments are identical either way.  Returns ``QueryResponse`` objects
    whose fragment sets are byte-identical to the unplanned engines over the
    admitted subqueries (exactness pinned by ``tests/test_planner.py``);
    ranking is ``rank_documents`` over the exact fragment union, identical
    to ``SearchEngine``.

    ``defer=True`` returns a zero-argument *finalize* callable instead: the
    device program is submitted but not awaited, and calling it performs
    the readout and builds the responses — the DESIGN.md §15.2 hook the
    frontend's two-deep pipeline uses to overlap batch N's compute with
    batch N+1's plan/pack/H2D.
    """
    from .engine import QueryResponse, RankedDoc

    t0 = time.perf_counter()
    if admitted is None:
        admitted = [plan.executable() for plan in plans]
    per_stats = [QueryStats() for _ in plans]
    work = [
        [(sp.subquery, view, sp.keys) for sp in subs for view in views]
        for subs in admitted
    ]
    batch_stats = QueryStats()
    pending = serve_query_batch(
        work,
        max_distance=max_distance,
        top_k=top_k,
        doc_len=doc_len,
        use_kernel=use_kernel,
        compute_dtype=compute_dtype,
        stats=per_stats,
        batch_stats=batch_stats,
        residencies=residencies,
        defer=defer,
    )

    def finalize() -> list:
        result = pending.result() if defer else pending
        for st in per_stats:
            # batch-level quantities: one shared dispatch/transfer, assigned
            # (not accumulated) per query so aggregation never over-counts
            st.device_dispatches = batch_stats.device_dispatches
            st.h2d_bytes = batch_stats.h2d_bytes
        elapsed = time.perf_counter() - t0
        responses = []
        for qi, plan in enumerate(plans):
            fragments = result.per_query[qi]
            docs = [
                RankedDoc(doc_id=d, score=s, fragments=f)
                for d, s, f in rank_documents(fragments, top_k=top_k)
            ]
            st = per_stats[qi]
            st.results = len(fragments)
            st.pruned_subqueries = plan.n_pruned
            n_admitted = len(admitted[qi])
            st.skipped_subqueries = len(plan.executable()) - n_admitted
            st.partial = st.skipped_subqueries > 0
            st.elapsed_sec = elapsed  # batch wall time (one shared dispatch)
            responses.append(
                QueryResponse(
                    query=plan.query,
                    docs=docs,
                    stats=st,
                    n_subqueries=len(plan.subqueries),
                )
            )
        return responses

    if defer:
        return finalize
    return finalize()
