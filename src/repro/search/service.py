"""Continuous-batching serving daemon (DESIGN.md §16; §5 serving at scale).

The service boundary between "a servable frontend" and "a served system":
:class:`ServiceDaemon` owns a FIFO request queue and N
:class:`~repro.search.frontend.ServingFrontend` replicas over ONE index
source / snapshot lineage, and schedules **continuous micro-batches** —
a batch is formed from everything queued the instant a replica goes idle,
and new requests are admitted into the queue *while* batches are in
flight on the device (riding ``submit_many``'s deferred finalize, the
§15.2 pipeline hook), not in lockstep rounds.  Per-request deadlines
shrink by the observed queue wait before dispatch and map onto the
frontend's §5 partial-result machinery; queue overflow load-sheds at
admission (an immediate, explicitly flagged empty partial — never an
error, never cached).

Exactness contract (DESIGN.md §16.2, pinned by ``tests/test_service.py``
and the property suite in ``tests/test_queue_properties.py``): for any
arrival schedule, the multiset of responses the daemon returns is
**byte-identical** to a serial ``ServingFrontend.search_many`` run over
the same requests with the same effective deadlines — batching, queueing
and replica routing change *when* work runs, never what a response
contains — and every response that is not complete is flagged
(``QueryStats.partial`` / ``shed`` / ``shards_degraded``).  All queue
timing reads an injectable clock (§16.4): under a virtual clock the whole
daemon — admission, deadline shrinking, retirement — replays a given
schedule deterministically with no real sleeps or sockets
(:meth:`ServiceDaemon.replay`), which is what lets tier-1 tests assert
exact tick boundaries.  A thin JSON-lines TCP transport
(:func:`serve_tcp`) exposes the same daemon over real sockets for
``launch/serve.py --daemon`` and ``benchmarks/load.py``.

Replicated failover (DESIGN.md §18.3): :class:`ReplicatedServiceDaemon`
runs N such daemons over one snapshot+WAL lineage behind a deterministic,
injectable-clock primary lease.  Requests carry client-visible idempotent
ids; when the primary is killed mid-flight, the successor re-admits its
unanswered tickets exactly once each, and — because replicas serve one
lineage deterministically — the re-admitted responses are byte-identical
to what the dead primary would have returned (pinned by
``tests/test_chaos.py``): every acknowledged write/read is answered
exactly once, exact or flagged, never silently lost.
"""

from __future__ import annotations

import json
import socket
import socketserver
import threading
from collections import deque
from typing import Sequence

from ..core.postings import QueryStats
from ..runtime.clock import SystemClock
from .engine import QueryResponse
from .frontend import SearchRequest, ServingFrontend

__all__ = [
    "Ticket",
    "ServiceDaemon",
    "RequestHandle",
    "ReplicatedServiceDaemon",
    "response_to_wire",
    "serve_tcp",
    "TcpDaemonServer",
    "request_over_tcp",
]


class Ticket:
    """A queued request's handle (DESIGN.md §16.1).

    ``submit`` returns one immediately; :meth:`result` blocks until the
    daemon completes it (already-set for queue-shed tickets).  Carries the
    per-request accounting the load harness and the queue property tests
    assert on — ``queue_wait_sec`` / ``latency_sec`` read the daemon's
    injected clock (§16.4), so under a virtual clock they are exact tick
    differences, and ``effective_deadline_sec`` records the
    post-queue-wait budget actually handed to the frontend (the value a
    serial reference run must use to reproduce this response
    byte-identically).
    """

    __slots__ = (
        "request",
        "seq",
        "enqueued_at",
        "shed_at_queue",
        "effective_deadline_sec",
        "replica",
        "batch_size",
        "queue_wait_sec",
        "latency_sec",
        "_event",
        "_response",
    )

    def __init__(self, request: SearchRequest, seq: int, enqueued_at: float):
        self.request = request
        self.seq = seq
        self.enqueued_at = enqueued_at
        self.shed_at_queue = False
        self.effective_deadline_sec: float | None = request.deadline_sec
        self.replica: int | None = None
        self.batch_size = 0
        self.queue_wait_sec = 0.0
        self.latency_sec = 0.0
        self._event = threading.Event()
        self._response: QueryResponse | None = None

    def done(self) -> bool:
        """True once the response is set (§16.1) — never un-sets."""
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> QueryResponse:
        """Block until the daemon completes this ticket and return the
        response (§16.1).  Idempotent; raises ``TimeoutError`` only when a
        real ``timeout`` expires (virtual-clock runs complete tickets
        synchronously inside ``pump``/``replay``, so tests never wait)."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"ticket {self.seq} not completed in {timeout}s")
        return self._response

    def _complete(self, response: QueryResponse) -> None:
        self._response = response
        self._event.set()


class _Inflight:
    """One launched batch: the replica it occupies, its tickets in
    admission order, and the deferred finalize from ``submit_many``."""

    __slots__ = ("replica", "tickets", "finalize", "launched_at")

    def __init__(self, replica: int, tickets: list[Ticket], finalize, launched_at: float):
        self.replica = replica
        self.tickets = tickets
        self.finalize = finalize
        self.launched_at = launched_at


class ServiceDaemon:
    """Continuous-batching request scheduler over frontend replicas
    (DESIGN.md §16; the tentpole of the serving-at-scale layer).

    Scheduling loop (:meth:`pump`): (1) *launch* — while the queue is
    non-empty and a replica is idle, pop up to ``batch_limit`` tickets
    (FIFO: admission order is batch order), shrink each deadline by its
    queue wait, and ``submit_many`` the slate — the device program is
    enqueued and the replica marked busy, but nothing blocks; (2)
    *retire* — pop the OLDEST in-flight batch and call its finalize
    (the blocking device readout) **outside the daemon lock**, so new
    requests are admitted into the queue during the device wait.  That
    overlap is the continuous-batching invariant the occupancy metric
    pins: at saturation the mean batch occupancy exceeds 1 because
    arrivals during batch N's flight form batch N+1.

    Invariants (§16.2, property-tested): batches retire FIFO, tickets
    within a batch keep admission order, at most ONE batch is in flight
    per replica (``submit_many`` is not re-entrant per frontend), every
    queued ticket is eventually completed (no starvation — FIFO pop,
    no re-ordering), and responses are byte-identical to a serial
    ``search_many`` run with the same effective deadlines.  Queue
    overflow (``max_queue``) sheds at admission: an immediate empty
    response flagged ``stats.shed`` / ``stats.partial`` that never
    reaches a frontend and is never cached.

    Deterministic mode (§16.4): give every replica AND the daemon one
    shared virtual clock and drive the scheduler with :meth:`pump` /
    :meth:`drain` / :meth:`replay` — no threads, no sleeps, exact tick
    accounting.  Real mode: :meth:`start` runs the same ``pump`` loop on
    a daemon thread with condition-variable wakeups.
    """

    def __init__(
        self,
        replicas: ServingFrontend | Sequence[ServingFrontend],
        *,
        clock=None,
        max_queue: int = 256,
        batch_limit: int | None = None,
        poll_interval_s: float = 0.005,
    ):
        if isinstance(replicas, ServingFrontend):
            replicas = [replicas]
        self.replicas = list(replicas)
        if not self.replicas:
            raise ValueError("ServiceDaemon needs at least one frontend replica")
        self.clock = clock or SystemClock()
        self.max_queue = max(1, int(max_queue))
        # one slate == one frontend chunk == ONE fused dispatch: the cap
        # never exceeds any replica's max_batch (enforced again per launch)
        self.batch_limit = (
            min(r.max_batch for r in self.replicas)
            if batch_limit is None
            else max(1, int(batch_limit))
        )
        self.poll_interval_s = float(poll_interval_s)

        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._queue: deque[Ticket] = deque()
        self._inflight: deque[_Inflight] = deque()
        self._busy = [False] * len(self.replicas)
        self._rr = 0  # round-robin replica cursor
        self._thread: threading.Thread | None = None
        self._stopping = False

        self._seq = 0
        self._submitted = 0
        self._completed = 0
        self._shed_queue = 0
        self._batches = 0
        self._batched = 0
        self._queue_peak = 0
        self._occupancy: dict[int, int] = {}
        self._per_replica_batches = [0] * len(self.replicas)

    # ---- admission ---------------------------------------------------------

    def submit(
        self,
        request: SearchRequest | str,
        *,
        top_k: int = 10,
        deadline_sec: float | None = None,
    ) -> Ticket:
        """Admit one request (§16.1) and return its :class:`Ticket`.

        Admission control is exact and deterministic: if the queue holds
        ``max_queue`` tickets (or the daemon is stopping), the request is
        load-shed HERE — the ticket completes immediately with an empty
        response flagged ``stats.shed=1`` / ``stats.partial=True`` that
        never reaches a frontend and can never be cached.  Otherwise the
        ticket joins the FIFO queue stamped with the injected clock's now
        (§16.4) — its deadline budget starts aging from this instant.
        """
        req = (
            request
            if isinstance(request, SearchRequest)
            else SearchRequest(query=str(request), top_k=top_k, deadline_sec=deadline_sec)
        )
        with self._work:
            ticket = Ticket(req, self._seq, self.clock.now())
            self._seq += 1
            self._submitted += 1
            if self._stopping or len(self._queue) >= self.max_queue:
                self._shed_queue += 1
                ticket.shed_at_queue = True
                ticket._complete(self._shed_response(req))
                return ticket
            self._queue.append(ticket)
            self._queue_peak = max(self._queue_peak, len(self._queue))
            self._work.notify_all()
        return ticket

    def _shed_response(self, req: SearchRequest) -> QueryResponse:
        stats = QueryStats()
        stats.shed = 1
        stats.partial = True  # empty-by-admission: flagged, never cached
        stats.deadline_sec = 0.0 if req.deadline_sec is None else float(req.deadline_sec)
        return QueryResponse(query=req.query, docs=[], stats=stats)

    # ---- the scheduler -----------------------------------------------------

    def _next_idle(self) -> int | None:
        n = len(self.replicas)
        for k in range(n):
            i = (self._rr + k) % n
            if not self._busy[i]:
                self._rr = (i + 1) % n
                return i
        return None

    def _launch_ready(self) -> bool:
        launched = False
        while True:
            with self._lock:
                if not self._queue:
                    return launched
                idx = self._next_idle()
                if idx is None:
                    return launched
                replica = self.replicas[idx]
                cap = max(1, min(self.batch_limit, replica.max_batch))
                take = min(cap, len(self._queue))
                tickets = [self._queue.popleft() for _ in range(take)]
                self._busy[idx] = True
            # deadline shrinking + submit happen OUTSIDE the lock: planning
            # and the device enqueue must not block concurrent admission
            now = self.clock.now()
            slate: list[SearchRequest] = []
            for t in tickets:
                wait = max(0.0, now - t.enqueued_at)
                t.queue_wait_sec = wait
                d = t.request.deadline_sec
                eff = None if d is None else max(0.0, float(d) - wait)
                t.effective_deadline_sec = eff
                t.replica = idx
                t.batch_size = len(tickets)
                slate.append(
                    SearchRequest(
                        query=t.request.query,
                        top_k=t.request.top_k,
                        deadline_sec=eff,
                    )
                )
            finalize = replica.submit_many(slate)
            with self._lock:
                self._inflight.append(_Inflight(idx, tickets, finalize, now))
                self._batches += 1
                self._batched += len(tickets)
                self._per_replica_batches[idx] += 1
                self._occupancy[len(tickets)] = self._occupancy.get(len(tickets), 0) + 1
            launched = True

    def _retire_oldest(self) -> bool:
        with self._lock:
            if not self._inflight:
                return False
            inf = self._inflight.popleft()
        # the blocking device readout runs OUTSIDE the lock: this is the
        # window in which submit() keeps admitting — continuous batching
        responses = inf.finalize()
        now = self.clock.now()
        with self._work:
            for ticket, resp in zip(inf.tickets, responses):
                ticket.latency_sec = max(0.0, now - ticket.enqueued_at)
                ticket._complete(resp)
            self._busy[inf.replica] = False
            self._completed += len(inf.tickets)
            self._work.notify_all()
        return True

    def pump(self) -> bool:
        """One deterministic scheduler step (§16.2): launch batches onto
        every idle replica, then retire the oldest in-flight batch
        (blocking readout).  Returns True when any work was done.  This is
        the ONLY scheduling logic — the daemon thread, :meth:`drain` and
        :meth:`replay` all run exactly this step, so threaded and
        virtual-clock runs make identical batching decisions for identical
        queue states."""
        launched = self._launch_ready()
        retired = self._retire_oldest()
        return launched or retired

    def drain(self) -> None:
        """Run :meth:`pump` until the queue and every in-flight batch are
        empty (§16.2) — the in-process deterministic transport: submit
        tickets, ``drain()``, read exact results from the tickets.  No
        threads or sleeps involved."""
        while True:
            with self._lock:
                if not self._queue and not self._inflight:
                    return
            self.pump()

    def replay(self, schedule, *, service_time_sec: float = 0.0) -> list[Ticket]:
        """Deterministically replay an open-loop arrival ``schedule`` on
        the virtual clock (§16.4) and return the tickets in arrival order.

        ``schedule`` is an iterable of ``(arrival_time_sec, request)``
        pairs (request: ``str`` or :class:`SearchRequest`); the clock is
        advanced to each event in time order.  ``service_time_sec`` models
        how long a launched batch occupies its replica in *virtual* time:
        arrivals that land before a batch's virtual completion queue up
        behind it and form the next batch — exactly the
        admission-during-flight behavior the real daemon shows under load,
        but with no threads, so a given (schedule, service time) pair
        yields an identical batch sequence, identical effective deadlines
        and identical responses on every run.  Requires a virtual clock.
        """
        if not getattr(self.clock, "virtual", False):
            raise ValueError("replay() requires a virtual clock (ManualClock)")
        events = sorted(
            ((float(t), k, req) for k, (t, req) in enumerate(schedule)),
            key=lambda e: (e[0], e[1]),
        )
        svc = max(0.0, float(service_time_sec))
        tickets: list[Ticket] = []
        i = 0
        while True:
            with self._lock:
                oldest = self._inflight[0].launched_at if self._inflight else None
                queued = bool(self._queue)
            if i >= len(events) and oldest is None and not queued:
                return tickets
            completion = None if oldest is None else oldest + svc
            arrival = events[i][0] if i < len(events) else None
            if arrival is not None and (completion is None or arrival <= completion):
                self.clock.advance(max(0.0, arrival - self.clock.peek()))
                tickets.append(self.submit(events[i][2]))
                i += 1
                self._launch_ready()
            elif completion is not None:
                self.clock.advance(max(0.0, completion - self.clock.peek()))
                self._retire_oldest()
                self._launch_ready()
            else:  # queued work, nothing in flight, no arrivals left
                self._launch_ready()

    # ---- threaded (real-time) mode ----------------------------------------

    def start(self) -> "ServiceDaemon":
        """Start the daemon thread (§16.3): the same :meth:`pump` loop,
        woken by condition variable on submit and batch retirement, so
        real-socket serving batches identically to the deterministic
        drivers.  Idempotent; returns self."""
        with self._work:
            if self._thread is not None:
                return self
            self._stopping = False
            self._thread = threading.Thread(
                target=self._run, name="service-daemon", daemon=True
            )
            self._thread.start()
        return self

    def _run(self) -> None:
        while True:
            with self._work:
                while not self._stopping and not self._queue and not self._inflight:
                    self._work.wait(timeout=self.poll_interval_s)
                if self._stopping and not self._queue and not self._inflight:
                    return
            self.pump()

    def stop(self, drain: bool = True) -> None:
        """Stop serving (§16.3).  New submits shed immediately from this
        point.  ``drain=True`` completes everything already queued or in
        flight first (every admitted ticket still gets its exact
        response); ``drain=False`` sheds the queue (flagged, like any
        admission shed) and only retires batches already on the device.
        Joins the daemon thread if one is running; also usable in
        deterministic mode (no thread), where it drains inline."""
        with self._work:
            self._stopping = True
            if not drain:
                while self._queue:
                    t = self._queue.popleft()
                    self._shed_queue += 1
                    t.shed_at_queue = True
                    t._complete(self._shed_response(t.request))
            self._work.notify_all()
            thread = self._thread
            self._thread = None
        if thread is not None:
            thread.join(timeout=60.0)
        else:
            self.drain()

    # ---- accounting --------------------------------------------------------

    def metrics(self) -> dict:
        """Daemon counters for the load harness and CI gates (§16.5):
        admission totals, queue-shed count, queue depth peak, batch count
        and the exact batch-occupancy histogram — ``mean_batch_occupancy``
        > 1 is the pinned evidence that batches formed from arrivals
        admitted while earlier batches were in flight (continuous
        batching), and ``submitted == completed + shed_queue + queued +
        inflight`` is the no-lost-ticket conservation the property tests
        assert."""
        with self._lock:
            inflight_reqs = sum(len(b.tickets) for b in self._inflight)
            batches = self._batches
            return {
                "replicas": len(self.replicas),
                "batch_limit": self.batch_limit,
                "max_queue": self.max_queue,
                "submitted": self._submitted,
                "completed": self._completed,
                "shed_queue": self._shed_queue,
                "queued": len(self._queue),
                "inflight_requests": inflight_reqs,
                "queue_peak": self._queue_peak,
                "batches": batches,
                "batched_requests": self._batched,
                "mean_batch_occupancy": (self._batched / batches) if batches else 0.0,
                "batch_occupancy_hist": {
                    str(k): v for k, v in sorted(self._occupancy.items())
                },
                "per_replica_batches": list(self._per_replica_batches),
            }


# ---- replicated daemon failover (DESIGN.md §18.3) --------------------------


class RequestHandle:
    """A client's durable handle on one idempotent request (§18.3).

    Keyed by a client-visible ``request_id``: re-submitting the same id —
    whether a client retry or the successor re-admitting a killed
    primary's in-flight work — always resolves to this ONE handle, and
    :meth:`result` always returns the ONE recorded response (byte-identical
    on every read; the §18.3 exactly-once contract).  ``ticket`` tracks
    the currently-assigned underlying :class:`Ticket` (it changes exactly
    once per failover re-admission); completions from a superseded ticket
    of a dead primary are accepted only while it is still current, so a
    request is never answered twice.
    """

    __slots__ = ("request_id", "request", "ticket", "readmissions", "_event", "_response")

    def __init__(self, request_id: str, request: SearchRequest):
        self.request_id = request_id
        self.request = request
        self.ticket: Ticket | None = None
        self.readmissions = 0
        self._event = threading.Event()
        self._response: QueryResponse | None = None

    def done(self) -> bool:
        """True once the one-and-only response is recorded (§18.3)."""
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> QueryResponse:
        """Block until the response is recorded and return it — the same
        object on every call, across client retries and primary failovers
        (§18.3 idempotency).  Raises ``TimeoutError`` on a real expiry."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.request_id!r} not completed in {timeout}s")
        return self._response

    def _record(self, response: QueryResponse) -> None:
        self._response = response
        self._event.set()


class ReplicatedServiceDaemon:
    """N daemon replicas over ONE snapshot+WAL lineage with deterministic
    primary failover (DESIGN.md §18.3).

    One member of ``daemons`` is the **primary** — the only replica that
    admits and schedules work.  Liveness is lease-based and entirely
    injectable-clock driven (no real sleeps): a killed primary's lease
    expires ``lease_sec`` after its recorded death on the shared clock,
    at which point the next live replica takes over and **re-admits** the
    dead primary's unanswered requests exactly once each, under their
    original client-visible request ids.  Because every replica serves
    the same index lineage and the frontends are deterministic, a
    re-admitted request's response is byte-identical to what the dead
    primary would have returned — pinned by the §18.3 chaos tests — so
    clients cannot observe which replica answered; duplicates (client
    retries of an id) resolve to the already-recorded response without
    recomputation.  Exactness: every response is the exact
    single-frontend response or explicitly flagged (shed), never silently
    wrong, and every acknowledged (admitted) request gets exactly one
    response.

    The §14 ``daemon.crash`` fault point fires once per :meth:`pump` with
    ``shard=`` the primary's index; a scheduled ``kill`` crashes the
    primary mid-flight.  Deterministic mode: deterministic underlying
    daemons + a shared virtual clock, driven by :meth:`pump` /
    :meth:`drain` (drain expires the lease by advancing the virtual
    clock when work is stranded on a dead primary).  Threaded mode:
    :meth:`start` runs the live daemons' threads plus a failover monitor.
    """

    def __init__(
        self,
        daemons: Sequence[ServiceDaemon],
        *,
        clock=None,
        lease_sec: float = 0.05,
        injector=None,
        poll_interval_s: float = 0.005,
    ):
        self.daemons = list(daemons)
        if not self.daemons:
            raise ValueError("ReplicatedServiceDaemon needs at least one daemon")
        self.clock = clock or self.daemons[0].clock
        self.lease_sec = float(lease_sec)
        self.injector = injector
        self.poll_interval_s = float(poll_interval_s)
        self._lock = threading.RLock()
        self.alive = [True] * len(self.daemons)
        self._primary = 0
        self._death_at: float | None = None
        self._registry: dict[str, RequestHandle] = {}
        self._auto = 0
        self._failovers = 0
        self._readmitted = 0
        self._dedup_hits = 0
        self._monitor: threading.Thread | None = None
        self._stopping = False

    # -- clock/lease ---------------------------------------------------------

    def _now(self) -> float:
        # reading the lease must not advance a virtual clock (peek vs now)
        if getattr(self.clock, "virtual", False):
            return self.clock.peek()
        return self.clock.now()

    @property
    def primary(self) -> int | None:
        """Index of the current primary, or None when every replica is
        dead (§18.3; reads do not advance the lease clock)."""
        with self._lock:
            return self._primary if self.alive[self._primary] else None

    # -- admission (idempotent request ids) ----------------------------------

    def submit(
        self,
        request: SearchRequest | str,
        *,
        top_k: int = 10,
        deadline_sec: float | None = None,
        request_id: str | None = None,
    ) -> RequestHandle:
        """Admit one idempotent request (§18.3) and return its
        :class:`RequestHandle`.  A known ``request_id`` returns the
        existing handle — the recorded response is served as-is
        (byte-identical, no recomputation); a fresh id is assigned to the
        current primary.  With every replica dead the request completes
        immediately as an explicitly flagged shed (never an error, never
        silently dropped)."""
        req = (
            request
            if isinstance(request, SearchRequest)
            else SearchRequest(query=str(request), top_k=top_k, deadline_sec=deadline_sec)
        )
        with self._lock:
            if request_id is None:
                request_id = f"auto-{self._auto}"
                self._auto += 1
            handle = self._registry.get(request_id)
            if handle is not None:
                self._dedup_hits += 1
                return handle
            self._maybe_failover()
            handle = RequestHandle(request_id, req)
            self._registry[request_id] = handle
            self._assign(handle)
        return handle

    def _assign(self, handle: RequestHandle) -> None:
        if self.alive[self._primary]:
            handle.ticket = self.daemons[self._primary].submit(handle.request)
            return
        if any(self.alive):
            # arrived inside the dead primary's lease window: park it —
            # failover admits it to the successor (never shed while a
            # live replica remains)
            return
        handle._record(self._shed_response(handle.request))

    def _shed_response(self, req: SearchRequest) -> QueryResponse:
        stats = QueryStats()
        stats.shed = 1
        stats.partial = True  # no live primary: flagged, never silently lost
        stats.deadline_sec = 0.0 if req.deadline_sec is None else float(req.deadline_sec)
        return QueryResponse(query=req.query, docs=[], stats=stats)

    # -- failure / failover --------------------------------------------------

    def crash_primary(self) -> int | None:
        """Kill the current primary (§18.3): fault-point targets and the
        ``kill_primary`` wire op land here.  Its queued and in-flight
        requests stay unanswered until the lease expires and the successor
        re-admits them (exactly once each).  Returns the killed index, or
        None if everything is already dead."""
        with self._lock:
            if not self.alive[self._primary]:
                return None
            killed = self._primary
            self.alive[killed] = False
            self._death_at = self._now()
            return killed

    def _maybe_fire_crash(self) -> None:
        if self.injector is None:
            return
        from .resilience import ShardCrash

        try:
            self.injector.fire("daemon.crash", shard=self._primary)
        except ShardCrash:
            self.crash_primary()

    def _maybe_failover(self) -> None:
        if self.alive[self._primary] or self._death_at is None:
            return
        if self._now() < self._death_at + self.lease_sec:
            return  # the dead primary's lease has not expired yet
        n = len(self.daemons)
        successor = None
        for k in range(1, n + 1):
            i = (self._primary + k) % n
            if self.alive[i]:
                successor = i
                break
        if successor is None:
            # nobody left: answer stranded requests as flagged sheds
            for handle in self._registry.values():
                if not handle.done():
                    handle._record(self._shed_response(handle.request))
            self._death_at = None
            return
        self._primary = successor
        self._death_at = None
        self._failovers += 1
        if self._monitor is not None:
            self.daemons[successor].start()
        # exactly-once re-admission: every unanswered request of the dead
        # primary re-enters the successor's queue under its ORIGINAL id;
        # the superseded ticket is dropped, so even if the dead process
        # somehow finished it, only one response is ever recorded
        for handle in self._registry.values():
            if handle.done():
                continue
            old_ticket = handle.ticket
            if old_ticket is not None and old_ticket.done():
                # completed before the crash reached it: accept the exact
                # response instead of recomputing
                self._record(handle, old_ticket)
                continue
            if old_ticket is None:
                # parked during the lease window: this is its FIRST
                # admission, not a re-admission
                handle.ticket = self.daemons[successor].submit(handle.request)
                continue
            handle.readmissions += 1
            self._readmitted += 1
            handle.ticket = self.daemons[successor].submit(handle.request)

    def _record(self, handle: RequestHandle, ticket: Ticket) -> None:
        if handle.ticket is ticket and not handle.done():
            handle._record(ticket._response)

    def _propagate(self) -> None:
        for handle in self._registry.values():
            t = handle.ticket
            if t is not None and t.done() and not handle.done():
                self._record(handle, t)

    # -- deterministic drivers ----------------------------------------------

    def pump(self) -> bool:
        """One deterministic replicated-scheduler step (§18.3): fire the
        ``daemon.crash`` fault point, run lease-based failover if due,
        pump the live primary, and record completed responses.  Returns
        True when any underlying work was done."""
        with self._lock:
            self._maybe_fire_crash()
            self._maybe_failover()
            p = self._primary if self.alive[self._primary] else None
        worked = self.daemons[p].pump() if p is not None else False
        with self._lock:
            self._propagate()
        return worked

    def drain(self) -> None:
        """Run :meth:`pump` until every registered request has its one
        response (§18.3).  When work is stranded on a dead primary whose
        lease has not expired, a virtual clock is advanced by
        ``lease_sec`` (the deterministic analogue of waiting the lease
        out); real clocks just keep polling."""
        import time as _time

        while True:
            with self._lock:
                pending = [h for h in self._registry.values() if not h.done()]
            if not pending:
                return
            worked = self.pump()
            if worked:
                continue
            with self._lock:
                stranded = (not self.alive[self._primary]) and self._death_at is not None
            if stranded and getattr(self.clock, "virtual", False):
                self.clock.advance(self.lease_sec)
            elif not getattr(self.clock, "virtual", False):
                _time.sleep(self.poll_interval_s)

    # -- threaded (real-time) mode -------------------------------------------

    def start(self) -> "ReplicatedServiceDaemon":
        """Threaded mode (§18.3): start the primary's daemon thread plus a
        failover monitor that watches the lease and re-admits after a
        kill; successors start on takeover.  Idempotent; returns self."""
        with self._lock:
            if self._monitor is not None:
                return self
            self._stopping = False
            self.daemons[self._primary].start()
            self._monitor = threading.Thread(
                target=self._run_monitor, name="daemon-failover-monitor", daemon=True
            )
            self._monitor.start()
        return self

    def _run_monitor(self) -> None:
        import time as _time

        while not self._stopping:
            with self._lock:
                self._maybe_failover()
                self._propagate()
            _time.sleep(self.poll_interval_s)

    def stop(self, drain: bool = True) -> None:
        """Stop the monitor and every live daemon (§18.3); dead replicas
        are left alone (their queues were re-admitted at failover)."""
        with self._lock:
            self._stopping = True
            monitor = self._monitor
            self._monitor = None
        if monitor is not None:
            monitor.join(timeout=10.0)
        for i, daemon in enumerate(self.daemons):
            if self.alive[i]:
                daemon.stop(drain=drain)
        with self._lock:
            self._propagate()

    # -- accounting ----------------------------------------------------------

    def metrics(self) -> dict:
        """Replication counters for the chaos harness and wire clients
        (§18.3): primary index, per-replica liveness, failover count,
        exactly-once re-admissions, idempotent dedup hits, and the live
        primary's scheduler metrics."""
        with self._lock:
            p = self._primary if self.alive[self._primary] else None
            return {
                "replicas": len(self.daemons),
                "primary": p,
                "alive": list(self.alive),
                "failovers": self._failovers,
                "readmitted": self._readmitted,
                "dedup_hits": self._dedup_hits,
                "requests": len(self._registry),
                "completed": sum(1 for h in self._registry.values() if h.done()),
                "primary_metrics": None if p is None else self.daemons[p].metrics(),
            }


# ---- wire format (JSON lines over TCP) ------------------------------------


def response_to_wire(resp: QueryResponse, ticket: Ticket | None = None) -> dict:
    """Encode one response for the JSON-lines transport (§16.3).

    Lossless for everything the exactness harness compares: every ranked
    doc with its exact score and its exact ``(doc_id, start, end)``
    fragments, plus the flags (``partial`` / ``shed`` /
    ``shards_degraded``) that mark a response as not-complete.  With a
    ``ticket``, the daemon-side accounting (queue wait, batch size,
    latency) rides along so the load generator needs no second channel.
    """
    out = {
        "query": resp.query,
        "docs": [
            {
                "doc_id": int(d.doc_id),
                "score": float(d.score),
                "fragments": [[int(f.doc_id), int(f.start), int(f.end)] for f in d.fragments],
            }
            for d in resp.docs
        ],
        "n_subqueries": int(resp.n_subqueries),
        "partial": bool(resp.stats.partial),
        "shed": int(resp.stats.shed),
        "shards_degraded": int(resp.stats.shards_degraded),
        "cache_hit": bool(resp.stats.cache_hits),
        "deadline_sec": float(resp.stats.deadline_sec),
    }
    if ticket is not None:
        out["seq"] = ticket.seq
        out["queue_wait_sec"] = float(ticket.queue_wait_sec)
        out["latency_sec"] = float(ticket.latency_sec)
        out["batch_size"] = int(ticket.batch_size)
        out["replica"] = ticket.replica
        out["shed_at_queue"] = bool(ticket.shed_at_queue)
    return out


class _JsonLineHandler(socketserver.StreamRequestHandler):
    """One connection: newline-delimited JSON requests, one JSON reply per
    line, in request order per connection (concurrency = connections)."""

    def handle(self) -> None:  # pragma: no cover - exercised via round-trip test
        daemon: ServiceDaemon = self.server.search_daemon  # type: ignore[attr-defined]
        for raw in self.rfile:
            line = raw.strip()
            if not line:
                continue
            try:
                msg = json.loads(line.decode("utf-8"))
            except (ValueError, UnicodeDecodeError) as e:
                reply = {"error": f"bad request: {e}"}
            else:
                reply = self._dispatch(daemon, msg)
            self.wfile.write((json.dumps(reply) + "\n").encode("utf-8"))
            self.wfile.flush()

    @staticmethod
    def _dispatch(daemon: "ServiceDaemon | ReplicatedServiceDaemon", msg: dict) -> dict:
        op = msg.get("op", "search")
        if op == "metrics":
            return {"metrics": daemon.metrics()}
        if op == "ping":
            return {"pong": True}
        if op == "kill_primary":
            # §18.3 failover walkthrough: only a replicated daemon has a
            # primary to kill
            if not isinstance(daemon, ReplicatedServiceDaemon):
                return {"error": "kill_primary requires --replicas > 1"}
            killed = daemon.crash_primary()
            return {"killed": killed, "metrics": daemon.metrics()}
        if op != "search" or "query" not in msg:
            return {"error": f"unknown op {op!r}"}
        deadline_ms = msg.get("deadline_ms")
        req = SearchRequest(
            query=str(msg["query"]),
            top_k=int(msg.get("top_k", 10)),
            deadline_sec=None if deadline_ms is None else float(deadline_ms) / 1e3,
        )
        timeout_s = float(msg.get("timeout_s", 60.0))
        if isinstance(daemon, ReplicatedServiceDaemon):
            # idempotent §18.3 path: a repeated request_id returns the
            # recorded response byte-identically, across failovers
            handle = daemon.submit(req, request_id=msg.get("request_id"))
            resp = handle.result(timeout=timeout_s)
            out = response_to_wire(resp, handle.ticket)
            out["request_id"] = handle.request_id
            out["readmissions"] = handle.readmissions
            return out
        ticket = daemon.submit(req)
        resp = ticket.result(timeout=timeout_s)
        return response_to_wire(resp, ticket)


class TcpDaemonServer(socketserver.ThreadingTCPServer):
    """JSON-lines TCP front of a :class:`ServiceDaemon` (§16.3).

    One thread per connection; every connection's requests go through the
    SAME daemon queue, so concurrent clients batch together and receive
    exactly the responses the in-process transport would return (the wire
    encoding is lossless for docs/scores/fragments/flags — pinned by the
    round-trip test in ``tests/test_service.py``).  Bind port 0 for an
    ephemeral test port; ``address`` reports the bound (host, port).
    """

    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, daemon: ServiceDaemon, host: str = "127.0.0.1", port: int = 0):
        super().__init__((host, port), _JsonLineHandler)
        self.search_daemon = daemon

    @property
    def address(self) -> tuple[str, int]:
        """The bound ``(host, port)`` — port is the ephemeral assignment
        when constructed with port 0 (§16.3)."""
        host, port = self.server_address[:2]
        return (host, port)


def serve_tcp(
    daemon: ServiceDaemon, host: str = "127.0.0.1", port: int = 0
) -> TcpDaemonServer:
    """Start the daemon (threaded mode) and a JSON-lines TCP server over
    it on a background thread (§16.3); returns the server (use
    ``server.address`` for the bound port, ``server.shutdown()`` +
    ``daemon.stop()`` to tear down).  Responses over the wire are exactly
    the in-process responses, encoded by :func:`response_to_wire`."""
    daemon.start()
    server = TcpDaemonServer(daemon, host=host, port=port)
    thread = threading.Thread(
        target=server.serve_forever, name="service-tcp", daemon=True
    )
    thread.start()
    return server


def request_over_tcp(
    address: tuple[str, int], payload: dict, timeout_s: float = 60.0
) -> dict:
    """One JSON-lines round trip against :func:`serve_tcp` (§16.3): send
    ``payload`` on a fresh connection, return the decoded reply — the
    exact wire image of the daemon's response.  The client half of the
    load generator and the transport round-trip test."""
    with socket.create_connection(address, timeout=timeout_s) as sock:
        sock.sendall((json.dumps(payload) + "\n").encode("utf-8"))
        with sock.makefile("rb") as f:
            line = f.readline()
    if not line:
        raise ConnectionError("server closed the connection without a reply")
    return json.loads(line.decode("utf-8"))
