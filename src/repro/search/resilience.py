"""Fault-injected resilient serving (DESIGN.md §14; §5 serving under failure).

The paper keeps worst-case proximity queries answerable under pressure and
arXiv 2009.03679 extends that to response-time guarantees; this module
extends both to *failure* pressure.  It supplies the three pieces the
sharded serving stack (``search/distributed.py``) needs to survive real
operation instead of being *told* which shards are dead:

* :class:`FaultInjector` — a deterministic, seeded fault schedule fired at
  named injection points threaded through the service, frontend, arena and
  snapshot store (the §14 injection-point ABI): shard crashes/kills,
  straggler delays, physical snapshot bit-flips, device-arena pressure.
* :class:`HealthMonitor` — per-shard consecutive-error circuit breakers
  (CLOSED -> OPEN -> cooldown -> HALF_OPEN probe) plus MAD-based straggler
  detection (ported from ``runtime/fault_tolerance.StragglerMonitor``; the
  MAD rule now lives here as :func:`mad_stragglers`).
* :class:`ShardSupervisor` — the per-batch probe barrier: guarded shard
  touches with hedged retries and ``RestartPolicy`` backoff for transient
  failures, and automatic recovery of crashed shards by re-restoring the
  newest restorable §12.2 snapshot.  A recovered shard claims a fresh
  §12.5 restore epoch, so every generation-keyed cache (result, posting,
  arena) self-invalidates — no explicit flush.

Exactness contract (the §14 headline invariant, pinned by the
chaos-differential harness in ``tests/test_chaos.py``): under ANY seeded
fault schedule every served response is either exact — fragment-identical
to the SE2.4 oracle over the full corpus — or explicitly flagged partial
(``QueryStats.shards_degraded`` / ``partial``) with exact ranking over the
shards it did cover; never silently wrong.  A crashed shard's recovery
restores index state that is ``index_sets_equal`` to an uncrashed replica
of the snapshotted state.
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Sequence

import numpy as np

from ..core.postings import QueryStats
from ..runtime.clock import SystemClock
from ..runtime.fault_tolerance import RestartPolicy

__all__ = [
    "InjectedFault",
    "ShardCrash",
    "FaultEvent",
    "FaultInjector",
    "HealthMonitor",
    "ResiliencePolicy",
    "ShardSupervisor",
    "mad_stragglers",
]


class InjectedFault(RuntimeError):
    """Base class for every fault the §14 harness raises at an injection
    point — catching it is how a layer opts into graceful degradation
    (e.g. the arena treats it as device-memory pressure and refuses the
    round; fragments stay exact via the host fallback)."""


class ShardCrash(InjectedFault):
    """A shard failed a probe (§14 failure model).

    ``transient=True`` models a blip worth retrying under the
    ``RestartPolicy`` backoff; ``transient=False`` models a dead process —
    the supervisor goes straight to snapshot recovery.
    """

    def __init__(self, shard: int, transient: bool = False, point: str = "shard.search"):
        super().__init__(f"injected {'transient ' if transient else ''}crash: "
                         f"shard {shard} at {point}")
        self.shard = shard
        self.transient = transient
        self.point = point


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault (§14 injection-point ABI).

    Fires when the ``at_call``-th .. ``at_call + count - 1``-th arrival
    reaches ``point`` for ``shard`` (``None`` matches any arrival at the
    point).  ``kind`` is one of ``crash`` (transient :class:`ShardCrash`),
    ``kill`` (the shard stays down until recovered), ``delay`` (sleep
    ``delay_s`` — a straggler), ``bitflip`` (XOR one byte of a snapshot
    blob on disk so the §12.2 CRC machinery rejects it), ``overflow``
    (device-arena pressure).  ``param`` positions the bit-flip
    (offset fraction of the target blob).
    """

    point: str
    kind: str
    shard: int | None = None
    at_call: int = 0
    count: int = 1
    delay_s: float = 0.0
    param: float = 0.0


class FaultInjector:
    """Deterministic seeded fault scheduler (§14).

    One injector instance is threaded through every resilient layer; each
    layer calls :meth:`fire` at its named injection point and the injector
    consults the schedule by per-(point, shard) arrival count — no clocks,
    no randomness at fire time, so a given seed replays the identical fault
    sequence on every run (the property the chaos-differential harness and
    the CI gate depend on; its responses must stay exact-or-flagged).

    Injection points (the §14 ABI — see DESIGN.md for the full table):

    ====================  =======================  =========================
    point                 fired by                 kinds honored
    ====================  =======================  =========================
    ``shard.search``      supervisor shard probe   ``crash``, ``kill``
    ``shard.straggler``   supervisor shard probe   ``delay`` (attempt 0 only)
    ``shard.commit``      service ``commit`` loop  ``crash``, ``kill``
    ``store.load_snapshot``  ``store.load_snapshot``  ``bitflip``
    ``arena.acquire``     ``PostingArena``         ``overflow``
    ``ingest.lemmatize``  bulk ingest, per chunk   ``crash``, ``kill``
    ``ingest.spill``      bulk ingest, per chunk   ``crash``, ``kill``
    ``ingest.merge``      bulk ingest merge open   ``bitflip`` (on the
                                                   chunk's spill store)
    ``wal.append``        WAL frame append (§18)   ``crash``, ``kill``
    ``wal.torn_tail``     WAL frame append (§18)   ``crash``, ``kill``
    ``daemon.crash``      replicated daemon pump   ``kill``
    ====================  =======================  =========================

    The ``wal.*`` points (§18.1) fire with ``shard=`` the WAL's shard id:
    at ``wal.append`` the fault aborts BEFORE any byte is written (the
    operation is lost but was never acknowledged — no durability hole);
    at ``wal.torn_tail`` the log flushes a *partial* frame first, so the
    reader's truncate-at-last-valid-frame path is exercised against a
    real torn tail.  A ``kill`` at either point also marks the shard down
    (the process died mid-write), handing the shard to §14 recovery —
    which now replays the WAL tail.  ``daemon.crash`` (§18.3) fires with
    ``shard=`` the daemon replica id; ``kill`` raises without touching
    the shard down-set (replica liveness is the replicated daemon's own
    state, keyed separately from index shards).

    The ``ingest.*`` points (§17) fire with ``shard=`` set to the CHUNK id
    and, for ``ingest.merge``, ``path=`` to the chunk directory so a
    ``bitflip`` physically corrupts that spill's ``seg_*/postings.bin`` —
    the merge's CRC verification and the resume re-spill are exercised
    against real corruption, not mocks (``tests/test_ingest_faults.py``).

    The legacy ``dead_shards=`` simulation argument routes through
    :meth:`hold_down` — held shards fail their probes exactly like killed
    ones, so there is ONE failure path, not two.
    """

    def __init__(self, schedule: Sequence[FaultEvent] = (), seed: int = 0,
                 clock=None):
        self.seed = seed
        self.schedule = tuple(schedule)
        # §16.4: straggler delays sleep on THIS clock — under a virtual
        # clock an injected delay advances shared virtual time instantly,
        # so hedge/deadline tests see the exact scheduled latency without
        # a real sleep.
        self.clock = clock or SystemClock()
        self._arrivals: dict[tuple, int] = {}
        self.down: set[int] = set()  # killed shards (until revive())
        self._held: set[int] = set()  # legacy dead_shards= routing (scoped)
        self.log: list[dict] = []  # fired events, for reports and tests

    @classmethod
    def from_seed(cls, seed: int, n_shards: int, wal: bool = False) -> "FaultInjector":
        """Expand ``seed`` into a deterministic fault schedule (§14): one
        or two transient crashes, one permanent kill (exercises snapshot
        recovery), a straggler delay, and — seed-dependently — a snapshot
        bit-flip on the first recovery restore and a round of arena
        pressure.  With ``wal=True`` the schedule additionally draws §18
        durability faults — crashes mid-WAL-append, a torn-tail kill
        mid-commit, and a primary daemon kill — appended AFTER the base
        draws, so base schedules are identical with or without the flag.
        Equal seeds produce equal schedules, so CI replays are exact."""
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        for _ in range(int(rng.integers(1, 3))):
            events.append(FaultEvent(
                "shard.search", "crash", shard=int(rng.integers(n_shards)),
                at_call=int(rng.integers(0, 8)), count=int(rng.integers(1, 3)),
            ))
        events.append(FaultEvent(
            "shard.search", "kill", shard=int(rng.integers(n_shards)),
            at_call=int(rng.integers(2, 10)),
        ))
        events.append(FaultEvent(
            "shard.straggler", "delay", shard=int(rng.integers(n_shards)),
            at_call=int(rng.integers(0, 6)), count=int(rng.integers(1, 3)),
            delay_s=float(rng.uniform(0.001, 0.005)),
        ))
        if rng.random() < 0.5:
            events.append(FaultEvent(
                "store.load_snapshot", "bitflip",
                at_call=0, param=float(rng.random()),
            ))
        if rng.random() < 0.5:
            events.append(FaultEvent(
                "arena.acquire", "overflow",
                at_call=int(rng.integers(0, 4)), count=int(rng.integers(1, 3)),
            ))
        if wal:
            events.append(FaultEvent(
                "wal.append", "crash", shard=int(rng.integers(n_shards)),
                at_call=int(rng.integers(0, 4)),
            ))
            if rng.random() < 0.7:
                events.append(FaultEvent(
                    "wal.torn_tail", "kill", shard=int(rng.integers(n_shards)),
                    at_call=int(rng.integers(0, 4)),
                ))
            events.append(FaultEvent(
                "daemon.crash", "kill", shard=0,
                at_call=int(rng.integers(1, 5)),
            ))
        return cls(schedule=events, seed=seed)

    # ---- legacy dead_shards routing ---------------------------------------

    def hold_down(self, shards) -> None:
        """Hold shards down for the current call scope — the single failure
        path the legacy ``dead_shards=`` argument routes through (§14);
        pair with :meth:`release`."""
        self._held.update(int(s) for s in shards)

    def release(self, shards) -> None:
        """Release shards held by :meth:`hold_down` (§14).  Killed shards
        (``kill`` events) are NOT released — only :meth:`revive` after a
        successful snapshot recovery does that."""
        self._held.difference_update(int(s) for s in shards)

    def revive(self, shard: int) -> None:
        """Mark a killed shard alive again — called by the supervisor after
        a successful §12.2 snapshot recovery, never spontaneously (§14)."""
        self.down.discard(int(shard))

    def is_down(self, shard: int) -> bool:
        """True while ``shard`` is killed or held down (§14 single failure
        path — exact degraded responses exclude exactly these shards)."""
        return shard in self.down or shard in self._held

    def is_held(self, shard: int) -> bool:
        """True while ``shard`` is held by the legacy ``dead_shards=``
        routing (§14): told-dead shards are excluded without health churn
        or recovery — the caller asked for the degraded fan-out."""
        return shard in self._held

    # ---- firing -----------------------------------------------------------

    def fire(self, point: str, shard: int | None = None, path=None,
             attempt: int = 0) -> None:
        """Arrive at injection point ``point`` (§14 ABI): consult the
        schedule by arrival count and perform whatever fault is due —
        raise :class:`ShardCrash` / :class:`InjectedFault`, sleep a
        straggler delay, or physically flip a snapshot byte under
        ``path``.  ``attempt`` > 0 marks a retry/hedge arrival: straggler
        delays fire only on the primary attempt (the retry models going to
        a replica).  No-op (beyond counting) when nothing is scheduled."""
        key = (point, shard)
        n = self._arrivals.get(key, 0)
        self._arrivals[key] = n + 1
        if point in ("shard.search", "shard.commit") and shard is not None \
                and self.is_down(shard):
            raise ShardCrash(shard, transient=False, point=point)
        for ev in self.schedule:
            if ev.point != point:
                continue
            if ev.shard is not None and ev.shard != shard:
                continue
            if not (ev.at_call <= n < ev.at_call + ev.count):
                continue
            if ev.kind == "crash":
                self._log(ev, shard=shard, arrival=n)
                raise ShardCrash(shard if shard is not None else -1,
                                 transient=True, point=point)
            if ev.kind == "kill":
                if point != "daemon.crash":
                    # daemon replicas are not index shards: their liveness
                    # lives in the replicated daemon, not the down-set
                    self.down.add(int(shard))
                self._log(ev, shard=shard, arrival=n)
                raise ShardCrash(shard, transient=False, point=point)
            if ev.kind == "delay" and attempt == 0:
                self._log(ev, shard=shard, arrival=n)
                self.clock.sleep(ev.delay_s)
            elif ev.kind == "bitflip" and path is not None:
                if self._bitflip(path, ev, n):
                    self._log(ev, shard=shard, arrival=n, path=str(path))
            elif ev.kind == "overflow":
                self._log(ev, shard=shard, arrival=n)
                raise InjectedFault(f"injected arena pressure at {point}")

    def _log(self, ev: FaultEvent, **info) -> None:
        self.log.append({"point": ev.point, "kind": ev.kind, **info})

    def _bitflip(self, path, ev: FaultEvent, arrival: int = 0) -> bool:
        """XOR one byte of a CRC-protected snapshot blob under ``path`` —
        a *physical* corruption, so detection exercises the real §12.2
        verify machinery (``open_segment_store`` CRC checks), not a mock.
        The byte offset advances with the arrival count: a repeated event
        (``count > 1``) corrupts a FRESH byte each time instead of XORing
        the same one back to its original value, so a snapshot hit twice
        stays corrupt (the unrecoverable-shard scenario)."""
        root = Path(path)
        targets = sorted(root.glob("seg_*/postings.bin"))
        targets = [t for t in targets if t.stat().st_size > 0]
        if not targets:
            return False
        target = targets[0]
        data = bytearray(target.read_bytes())
        off = (int(ev.param * len(data)) + arrival) % len(data)
        data[off] ^= 0xFF
        target.write_bytes(bytes(data))
        return True

    def metrics(self) -> dict:
        """Injector accounting for reports and the bench harness (§14):
        fired-event log length, killed/held shard sets — the ground truth
        the chaos harness compares degraded responses against (exactness
        of the degraded fan-out)."""
        return {
            "fired": len(self.log),
            "down": sorted(self.down),
            "held": sorted(self._held),
        }


def mad_stragglers(times: Sequence[Sequence[float]], mad_threshold: float = 5.0) -> list[int]:
    """MAD straggler rule (§14; ported from ``runtime/fault_tolerance``):
    a worker whose median duration sits ``mad_threshold`` MADs above the
    fleet median (floored at 5% of the fleet median so tiny absolute
    spreads don't flag everything) is a straggler.  Pure function of the
    duration windows — identical inputs give identical verdicts, which is
    what lets the runtime's training monitor and the serving
    :class:`HealthMonitor` share one implementation."""
    med_per = [float(np.median(t)) if len(t) else 0.0 for t in times]
    fleet = float(np.median([m for m in med_per if m > 0] or [0.0]))
    if fleet == 0:
        return []
    mad = float(np.median([abs(m - fleet) for m in med_per if m > 0] or [0.0]))
    thr = fleet + mad_threshold * max(mad, 0.05 * fleet)
    return [i for i, m in enumerate(med_per) if m > thr]


class HealthMonitor:
    """Per-shard health: error counters, latency windows, circuit breakers
    (§14 circuit-breaker thresholds; detection replaces the caller-supplied
    ``dead_shards`` list).

    Breaker lifecycle: CLOSED while probes succeed; ``breaker_errors``
    *consecutive* failures OPEN it (the shard is excluded without further
    probing — exact degraded responses, no error amplification); after
    ``cooldown_s`` the breaker is HALF_OPEN and exactly the next probe is
    allowed through — success closes it, failure re-opens the cooldown.
    Latency windows feed :func:`mad_stragglers`.
    """

    def __init__(
        self,
        n_shards: int,
        breaker_errors: int = 2,
        cooldown_s: float = 0.05,
        window: int = 20,
        mad_threshold: float = 5.0,
        clock=time.monotonic,
    ):
        self.n_shards = n_shards
        self.breaker_errors = max(1, int(breaker_errors))
        self.cooldown_s = float(cooldown_s)
        self.window = int(window)
        self.mad_threshold = float(mad_threshold)
        self._clock = clock
        self._consec = [0] * n_shards
        self._open_since: list[float | None] = [None] * n_shards
        self._times: list[list[float]] = [[] for _ in range(n_shards)]
        self.errors = [0] * n_shards
        self.probes = 0

    def record_success(self, shard: int, latency_s: float) -> None:
        """A probe of ``shard`` succeeded in ``latency_s`` — closes the
        breaker (a HALF_OPEN success), zeroes the consecutive-error count
        and feeds the straggler latency window (§14)."""
        self.probes += 1
        self._consec[shard] = 0
        self._open_since[shard] = None
        t = self._times[shard]
        t.append(float(latency_s))
        if len(t) > self.window:
            t.pop(0)

    def record_error(self, shard: int) -> bool:
        """A probe of ``shard`` failed; returns True when this failure
        trips the breaker OPEN (``breaker_errors`` consecutive failures —
        the §14 threshold).  A HALF_OPEN failure restarts the cooldown."""
        self.probes += 1
        self.errors[shard] += 1
        self._consec[shard] += 1
        if self._consec[shard] >= self.breaker_errors:
            was_closed = self._open_since[shard] is None
            self._open_since[shard] = self._clock()
            return was_closed
        return False

    def allows(self, shard: int) -> bool:
        """False while the breaker is OPEN and cooling down; True when
        CLOSED or HALF_OPEN (cooldown elapsed: one probe may pass — §14
        lifecycle)."""
        opened = self._open_since[shard]
        if opened is None:
            return True
        return (self._clock() - opened) >= self.cooldown_s

    def state(self, shard: int) -> str:
        """Breaker state name for dashboards: ``closed`` / ``open`` /
        ``half_open`` (§14 lifecycle)."""
        opened = self._open_since[shard]
        if opened is None:
            return "closed"
        return "half_open" if (self._clock() - opened) >= self.cooldown_s else "open"

    def note_recovered(self, shard: int) -> None:
        """Reset ``shard`` after a successful snapshot recovery — breaker
        CLOSED, consecutive errors zeroed (§14; cumulative ``errors`` stay,
        they are history not state)."""
        self._consec[shard] = 0
        self._open_since[shard] = None

    def stragglers(self) -> list[int]:
        """Shards whose probe latency violates the §14 MAD rule (see
        :func:`mad_stragglers` for the exact, deterministic criterion)."""
        return mad_stragglers(self._times, self.mad_threshold)

    def metrics(self) -> dict:
        """Health accounting for reports (§14): probe/error totals and the
        exact breaker state per shard."""
        return {
            "probes": self.probes,
            "errors": list(self.errors),
            "breaker_states": [self.state(i) for i in range(self.n_shards)],
        }


@dataclasses.dataclass
class ResiliencePolicy:
    """Knobs for the §14 failure path: retry backoff (the previously
    unwired ``runtime/fault_tolerance.RestartPolicy``), circuit-breaker
    thresholds, straggler hedging, and snapshot recovery.  Defaults are
    test-fast (zero backoff, 50 ms cooldown); production raises them.  The
    policy never affects *what* a response contains — only which shards
    serve it — so responses stay exact-or-flagged under any setting."""

    restart: RestartPolicy = dataclasses.field(
        default_factory=lambda: RestartPolicy(max_restarts=2, min_backoff_s=0.0)
    )
    breaker_errors: int = 2
    breaker_cooldown_s: float = 0.05
    hedge_after_s: float | None = None
    recover: bool = True
    snapshot_dir: str | Path | None = None


class ShardSupervisor:
    """The per-batch probe barrier of the resilient fan-out (§14).

    ``probe_live_shards`` touches every shard through its injection points
    before the batch packs into the single fused dispatch: held-down
    (legacy ``dead_shards=``) and breaker-OPEN shards are excluded up
    front; every other shard gets a guarded probe with ``RestartPolicy``
    backoff retries for transient crashes, optional hedging for
    stragglers, and — when a probe ultimately fails — automatic recovery
    by re-restoring the newest restorable §12.2 snapshot.  Exactness: the
    surviving shards still pack into ONE fused device dispatch, and a
    recovered shard claims a fresh §12.5 epoch so every generation-keyed
    cache self-invalidates (responses are exact over covered shards).
    """

    def __init__(
        self,
        service,
        policy: ResiliencePolicy | None = None,
        injector: FaultInjector | None = None,
        health: HealthMonitor | None = None,
        clock=None,
    ):
        self.service = service
        self.policy = policy or ResiliencePolicy()
        self.injector = injector or FaultInjector()
        # §16.4: one timeline for the whole barrier — probe latency
        # brackets, backoff sleeps, breaker cooldowns and injected
        # straggler delays all read/advance the same clock, so a virtual
        # clock makes the hedge decision an exact-tick comparison.
        self.clock = clock or SystemClock()
        if clock is not None:
            self.injector.clock = self.clock
        self.health = health or HealthMonitor(
            service.n_shards,
            breaker_errors=self.policy.breaker_errors,
            cooldown_s=self.policy.breaker_cooldown_s,
            clock=self.clock,
        )
        self.recoveries = 0
        # §18.2 accounting: total WAL records replayed across recoveries
        self.wal_records_replayed = 0
        self.last_excluded: frozenset[int] = frozenset()
        self._pool = None

    # ---- the probe barrier -------------------------------------------------

    def probe_live_shards(self, stats: QueryStats | None = None) -> list[int]:
        """Return the shard ids that will serve the next batch (§14).

        Recovery happens INSIDE the barrier, so by the time the caller
        resolves its live views the recovered indexer (fresh §12.5 epoch)
        is already in place — callers must resolve views and generation
        tokens AFTER this returns.  ``stats`` (batch-level) accumulates
        ``retries`` / ``hedges`` / ``recoveries`` and gets
        ``shards_degraded`` set to the exact excluded-shard count.
        """
        if stats is None:
            stats = QueryStats()
        live: list[int] = []
        excluded: list[int] = []
        for shard in range(self.service.n_shards):
            if self.injector.is_held(shard):
                # told-dead (legacy dead_shards=): excluded by request —
                # no health churn, no recovery, exact degraded fan-out
                excluded.append(shard)
                continue
            if not self.health.allows(shard):
                excluded.append(shard)  # breaker OPEN, still cooling down
                continue
            if self._probe(shard, stats):
                live.append(shard)
            else:
                excluded.append(shard)
        self.last_excluded = frozenset(excluded)
        stats.shards_degraded = len(excluded)
        return live

    def _probe(self, shard: int, stats: QueryStats) -> bool:
        attempt = 0
        while True:
            try:
                t0 = self.clock.now()
                self._touch(shard, attempt, stats)
                self.health.record_success(shard, self.clock.now() - t0)
                return True
            except ShardCrash as e:
                self.health.record_error(shard)
                if e.transient and attempt < self.policy.restart.max_restarts:
                    stats.retries += 1
                    self.clock.sleep(self.policy.restart.backoff(attempt))
                    attempt += 1
                    continue
                return self.recover_shard(shard, stats)

    def _touch(self, shard: int, attempt: int, stats: QueryStats) -> None:
        hedge = self.policy.hedge_after_s
        if hedge is None:
            self._touch_once(shard, attempt)
            return
        if getattr(self.clock, "virtual", False):
            # deterministic hedge path (§16.4): under a virtual clock the
            # primary probe runs to completion synchronously — an injected
            # straggler delay advances virtual time instead of sleeping —
            # and the hedge fires iff the primary's virtual elapsed exceeds
            # the threshold, exactly as the threaded race would decide it
            # (attempt+1 skips the injected delay, modelling the replica).
            # No threads, so the tick accounting is exact and replayable.
            t0 = self.clock.now()
            self._touch_once(shard, attempt)
            if self.clock.now() - t0 > hedge:
                stats.hedges += 1
                self._touch_once(shard, attempt + 1)
            return
        import concurrent.futures as cf

        pool = self._executor()
        first = pool.submit(self._touch_once, shard, attempt)
        try:
            first.result(timeout=hedge)
            return
        except cf.TimeoutError:
            pass
        except ShardCrash:
            raise
        # the primary probe is straggling: race a hedge (attempt+1 skips
        # the injected straggler delay — the model for "ask a replica");
        # first success wins, the loser finishes in the pool harmlessly
        stats.hedges += 1
        second = pool.submit(self._touch_once, shard, attempt + 1)
        futs = {first, second}
        err: BaseException | None = None
        while futs:
            done, futs = cf.wait(futs, return_when=cf.FIRST_COMPLETED)
            for f in done:
                try:
                    f.result()
                    return
                except BaseException as e:
                    err = e
        raise err

    def _touch_once(self, shard: int, attempt: int) -> None:
        self.injector.fire("shard.straggler", shard=shard, attempt=attempt)
        self.injector.fire("shard.search", shard=shard, attempt=attempt)
        # the real touch: resolving the live view walks the shard's segment
        # list — the in-process analogue of the per-shard health RPC
        _ = self.service.shards[shard].n_docs

    def _executor(self):
        if self._pool is None:
            from concurrent.futures import ThreadPoolExecutor

            self._pool = ThreadPoolExecutor(max_workers=2)
        return self._pool

    # ---- commit guard ------------------------------------------------------

    def guard_commit(self, shard: int) -> None:
        """Injection point for a crash mid-``commit`` (§14): the service
        calls this before each shard's commit; an injected crash records
        the error (so the next batch's barrier attempts recovery) and
        propagates — leaving some shards committed and this one not, which
        is exactly the torn state the §12.5 epoch claim makes safe."""
        try:
            self.injector.fire("shard.commit", shard=shard)
        except ShardCrash:
            self.health.record_error(shard)
            raise

    # ---- recovery ----------------------------------------------------------

    def recover_shard(self, shard: int, stats: QueryStats | None = None) -> bool:
        """Re-restore ``shard`` from the newest restorable §12.2 snapshot,
        then replay its §18 WAL tail (post-snapshot commits included).

        Walks snapshot ids downward past corrupt candidates (a bit-flipped
        blob fails the store's CRC verify and raises ``StoreError`` — the
        harness corrupts disk bytes for real).  On success the shard's
        indexer is REPLACED: the restored one claims a fresh §12.5 epoch,
        so the service token changes and result/posting/arena caches keyed
        by pre-crash tokens can never serve again (exactness across the
        crash).  When the shard lineage has a write-ahead log, ``restore``
        replays every operation durably logged after the chosen snapshot
        (§18.2), so the recovered shard is ``index_sets_equal`` to an
        uncrashed replica — zero committed-write loss, and the recovered
        FL already agrees with the service's live FL-list.  The pre-§18
        lost-commit guard survives only as a WAL-less fallback: if the
        restored FL state still disagrees with the live FL (no WAL, or a
        truncated tail), the shard re-keys under the live FL so
        cross-shard lemma typing stays agreed — the §3 invariant sharded
        exactness depends on (approximate recovery: flagged, never
        silently wrong).  Returns False (shard stays degraded, responses
        stay flagged) when recovery is disabled, no snapshot root is
        known, or every candidate is corrupt."""
        pol = self.policy
        svc = self.service
        if not pol.recover or getattr(svc, "indexers", None) is None:
            return False
        root = pol.snapshot_dir or getattr(svc, "last_snapshot_dir", None)
        if root is None:
            return False
        from ..index.incremental import IncrementalIndexer
        from ..index.store import StoreError, fl_signature, latest_snapshot

        sdir = Path(root) / f"shard_{shard:02d}"
        sid = latest_snapshot(sdir)
        if sid is None:
            return False
        while sid >= 0:
            try:
                ix = IncrementalIndexer.restore(
                    sdir,
                    snapshot_id=sid,
                    lemmatizer=svc.lemmatizer,
                    injector=self.injector,
                )
            except StoreError:
                sid -= 1  # corrupt / missing candidate: walk to an older one
                continue
            if ix.wal is not None:
                ix.wal.shard = shard  # re-key the §14 wal.* arrival counters
            self.wal_records_replayed += ix.last_wal_replay["records"]
            # WAL-less fallback (pre-§18 mechanism): with a replayed tail
            # the FL signatures already agree and this is a no-op
            if svc.fl is not None and fl_signature(ix.fl) != fl_signature(svc.fl):
                ix.commit(fl=svc.fl)
            svc.indexers[shard] = ix
            self.injector.revive(shard)
            self.health.note_recovered(shard)
            self.recoveries += 1
            if stats is not None:
                stats.recoveries += 1
            return True
        return False

    def metrics(self) -> dict:
        """Supervisor accounting (§14): recoveries, last excluded set, and
        the health monitor's exact breaker states — surfaced by the
        service, the frontend ``metrics()`` and ``launch/serve.py``."""
        return {
            "recoveries": self.recoveries,
            "wal_records_replayed": self.wal_records_replayed,
            "last_excluded": sorted(self.last_excluded),
            "stragglers": self.health.stragglers(),
            **self.health.metrics(),
            **self.injector.metrics(),
        }
