"""Document-sharded distributed search (the paper's §1 system at cluster
scale; layout in DESIGN.md §4).

The proximity-search workload is embarrassingly document-parallel: every
device owns a document shard's packed posting tensors; a query fans out to
all shards, each runs the vectorized Combiner locally, and per-shard top-k
results tree-merge through an all-gather.  The ``pod`` axis is just more
document shards — fan-out crosses pods once per query batch, the per-shard
compute never does.

Exactness contract: shards hold disjoint documents indexed under ONE
corpus-global FL-list, so the cross-shard fragment union is byte-identical
to a single-index build over the same documents (the differential harness
pins this through every engine).

This module provides both:
  * a **device-parallel** path (shard_map over the real mesh) used by the
    dry-run and (on TPU) production serving;
  * a **host-simulation** path (N logical shards on CPU) used by tests and
    the fault-tolerance drills, sharing the same shard planning code.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.keys import Subquery
from ..core.lemma import Lemmatizer
from ..core.postings import QueryStats, SearchResult
from ..index.builder import IndexSet, build_indexes
from ..index.corpus import DocumentStore
from ..search.engine import ALGORITHMS, QueryResponse, RankedDoc
from ..search.fused import serve_query_batch
from ..search.relevance import rank_documents

__all__ = ["ShardedSearchService", "shard_documents", "device_topk_merge"]


def shard_documents(store: DocumentStore, n_shards: int) -> list[DocumentStore]:
    """Round-robin document partitioning (doc ids stay global) — the §3
    document axis split of DESIGN.md §4's document-parallel serving layout."""
    shards: list[list] = [[] for _ in range(n_shards)]
    for doc in store.documents:
        shards[doc.doc_id % n_shards].append(doc)
    return [DocumentStore(documents=s, lemmatizer=store.lemmatizer) for s in shards]


@dataclasses.dataclass
class ShardStats:
    postings_read: int
    results: int
    elapsed_sec: float


class ShardedSearchService:
    """N-shard search service with straggler-aware fan-out (DESIGN.md §4;
    §5 serving over per-shard §3 indexes, fragment-exact across shards).

    Each shard builds ITS OWN indexes over its documents but shares the
    global FL-list (lemma typing must agree across shards — in production
    the FL-list is computed by a corpus-level reduce and broadcast; here we
    compute it once over the full store).

    With ``incremental=True`` every shard is an ``IncrementalIndexer``: the
    serving loop reads each shard's live multi-segment view, and the service
    grows mutation endpoints — ``add_documents`` routes new docs to shards,
    ``delete_document`` tombstones, ``commit`` runs the corpus-level FL
    reduce and broadcasts ONE new FL-list to every shard's generation commit
    (canonical key order must agree across shards), ``compact`` merges
    per-shard segments under a memory budget.
    """

    def __init__(
        self,
        store: DocumentStore,
        n_shards: int,
        sw_count: int,
        fu_count: int,
        max_distance: int = 5,
        algorithm: str = "se2.4",
        use_kernel: bool = False,
        doc_len: int = 512,
        incremental: bool = False,
        arena=None,
        resilience=None,
        injector=None,
    ):
        from ..core.lemma import FLList

        self.algorithm = algorithm
        self.use_kernel = use_kernel
        self.doc_len = doc_len
        # optional device-resident posting arena (DESIGN.md §13); per-shard
        # residencies are acquired under each shard's generation token.
        # Runtime accelerator state: never part of snapshots.
        self.arena = arena
        self.max_distance = max_distance
        self.n_shards = n_shards
        self.sw_count = sw_count
        self.fu_count = fu_count
        self.lemmatizer = store.lemmatizer
        self.indexers = None
        self._static_shards: list[IndexSet] = []
        # resilience layer (DESIGN.md §14): detection/recovery instead of a
        # caller-supplied dead list; None until enable_resilience (the
        # legacy dead_shards= argument enables it lazily)
        self.supervisor = None
        self.injector = injector
        self.last_snapshot_dir = None
        if incremental:
            from ..index.incremental import IncrementalIndexer

            self.indexers = [
                IncrementalIndexer(
                    sw_count=sw_count,
                    fu_count=fu_count,
                    max_distance=max_distance,
                    lemmatizer=store.lemmatizer,
                )
                for _ in range(n_shards)
            ]
            self._next_doc_id = 1 + max(
                (doc.doc_id for doc in store.documents), default=-1
            )
            # the store's documents are already lemmatized: ingest the
            # per-shard batches as-is, no re-lemmatization
            for shard_id, sub in enumerate(shard_documents(store, n_shards)):
                self.indexers[shard_id].add_prelemmatized(sub.documents)
            self.commit()
        else:
            global_freq = store.lemma_frequencies()
            self.fl = FLList.from_frequencies(global_freq, sw_count=sw_count, fu_count=fu_count)
            for sub in shard_documents(store, n_shards):
                # every shard indexes with the GLOBAL FL-list (lemma typing
                # and canonical key order must agree across shards)
                idx = build_indexes(sub, sw_count=sw_count, fu_count=fu_count,
                                    max_distance=max_distance, fl=self.fl)
                self._static_shards.append(idx)
        if resilience is not None or injector is not None:
            self.enable_resilience(policy=resilience, injector=injector)

    def enable_wal(self, directory, injector=None):
        """Attach a §18 write-ahead log to every shard under
        ``<directory>/shard_<i>/wal`` — the same per-shard lineage dirs
        ``snapshot``/``restore`` use (DESIGN.md §18.1).  From this point
        every routed ``add``/``delete`` and every shard commit of the
        corpus-level FL reduce is durably logged before it applies, and
        ``restore`` / §14 shard recovery replays the tails, so recovered
        shards come back ``index_sets_equal`` to uncrashed replicas
        *including post-snapshot commits* (§18.2 zero-data-loss contract).
        ``injector`` (defaults to the service's §14 injector) arms the
        ``wal.append``/``wal.torn_tail`` fault points per shard."""
        from pathlib import Path

        self._require_incremental()
        directory = Path(directory)
        inj = injector if injector is not None else self.injector
        for i, ix in enumerate(self.indexers):
            ix.enable_wal(directory / f"shard_{i:02d}", injector=inj, shard=i)
        return [ix.wal for ix in self.indexers]

    def enable_resilience(self, policy=None, injector=None, clock=None):
        """Switch the fan-out onto the §14 failure path (DESIGN.md §14).

        Installs a :class:`~repro.search.resilience.ShardSupervisor`: every
        ``search_batch`` then runs the probe barrier (circuit breakers,
        retries/hedges, snapshot recovery) before packing the surviving
        shards into the usual single fused dispatch.  Idempotent-ish:
        calling again replaces the supervisor but keeps an existing
        injector unless a new one is passed.  ``clock=`` (§16.4) threads
        an injectable clock through the supervisor, its breakers and the
        injector's straggler delays — a virtual clock makes every timing
        decision (hedge, cooldown, backoff) an exact-tick comparison.
        Returns the supervisor.  Fragments are exact-or-flagged either
        way — the supervisor decides *which shards* serve, never what a
        shard returns.
        """
        from .resilience import FaultInjector, ShardSupervisor

        if injector is not None:
            self.injector = injector
        elif self.injector is None:
            self.injector = FaultInjector()
        self.supervisor = ShardSupervisor(self, policy=policy, injector=self.injector,
                                          clock=clock)
        if self.arena is not None:
            self.arena.injector = self.injector
        return self.supervisor

    def resilience_metrics(self) -> dict:
        """Supervisor/health/injector counters (DESIGN.md §14) or ``{}``
        when the resilience layer is off — consumed by the frontend's
        ``metrics()``, ``launch/serve.py`` reports and the bench gates
        (which pin the exact zero-counter contract for fault-free runs)."""
        return {} if self.supervisor is None else self.supervisor.metrics()

    @property
    def shards(self) -> list[IndexSet]:
        """Live per-shard index views (static builds or segment unions)."""
        if self.indexers is not None:
            return [ix.index for ix in self.indexers]
        return self._static_shards

    @property
    def generation_token(self) -> tuple:
        """Cache-invalidation token across every shard (DESIGN.md §11).

        The tuple of per-shard mutation counters: any shard's ``commit`` /
        ``delete`` / ``compact`` changes the token, so a ``ServingFrontend``
        over this service invalidates exactly when the corpus-visible state
        can change.  Static (non-incremental) services are immutable and
        return a constant.
        """
        if self.indexers is None:
            return ("static",)
        return tuple(ix.generation_token for ix in self.indexers)

    # ---- incremental mutation endpoints -----------------------------------

    def add_documents(self, texts: Sequence[str]) -> list[int]:
        """Route new documents to shards (round-robin on global doc id);
        they become searchable at the next ``commit``."""
        self._require_incremental()
        per_shard: dict[int, tuple[list[str], list[int]]] = {}
        out = []
        for text in texts:
            doc_id = self._next_doc_id
            self._next_doc_id += 1
            batch = per_shard.setdefault(doc_id % self.n_shards, ([], []))
            batch[0].append(text)
            batch[1].append(doc_id)
            out.append(doc_id)
        for shard_id, (shard_texts, ids) in per_shard.items():
            self.indexers[shard_id].add_documents(shard_texts, doc_ids=ids)
        return out

    def delete_document(self, doc_id: int) -> None:
        """Tombstone on the owning shard — effective immediately."""
        self._require_incremental()
        self.indexers[doc_id % self.n_shards].delete_document(doc_id)

    def commit(self) -> dict:
        """Corpus-level FL reduce + broadcast generation commit.

        The global FL-list is recomputed over every shard's surviving
        frequencies and pinned into each shard's commit, so per-shard FL
        drift re-keying happens against ONE shared lemma typing.
        """
        self._require_incremental()
        from ..core.lemma import FLList

        global_freq: dict[str, int] = {}
        for ix in self.indexers:
            for l, n in ix.surviving_frequencies().items():
                global_freq[l] = global_freq.get(l, 0) + n
        self.fl = FLList.from_frequencies(
            global_freq, sw_count=self.sw_count, fu_count=self.fu_count
        )
        reports = []
        for i, ix in enumerate(self.indexers):
            if self.supervisor is not None:
                # §14 injection point: a crash here leaves a torn commit
                # (some shards on the new generation, this one not) — the
                # next batch's probe barrier recovers the crashed shard
                # from its snapshot under a fresh §12.5 epoch
                self.supervisor.guard_commit(i)
            reports.append(ix.commit(fl=self.fl))
        return {
            "new_docs": sum(r["new_docs"] for r in reports),
            "rekeyed_docs": sum(r["rekeyed_docs"] for r in reports),
            "segments": sum(r["segments"] for r in reports),
        }

    def compact(self, memory_budget_bytes: int | None = None) -> dict:
        self._require_incremental()
        reports = [ix.compact(memory_budget_bytes) for ix in self.indexers]
        return {
            "segments": sum(r["segments"] for r in reports),
            "collected": sum(r["collected"] for r in reports),
        }

    def _require_incremental(self) -> None:
        if self.indexers is None:
            raise RuntimeError(
                "service was built with incremental=False; mutation endpoints "
                "need ShardedSearchService(..., incremental=True)"
            )

    # ---- durability (DESIGN.md §12.2: one snapshot store per shard) -------

    def snapshot(self, directory, keep: int = 2):
        """Snapshot every shard's indexer into ``<directory>/shard_<i>/``
        plus a fsync'd ``service.json`` naming the topology (DESIGN.md
        §12.2).  Per-shard writes are individually atomic; the service
        manifest is written last, so a reader that finds it finds complete
        shard snapshots.  Returns the snapshot root directory."""
        from pathlib import Path

        from ..checkpoint import fsync_json, retain_latest
        from ..index.store import FORMAT_VERSION, SNAPSHOT_PREFIX

        self._require_incremental()
        directory = Path(directory)
        directory.mkdir(parents=True, exist_ok=True)
        # shards snapshot with retention OFF: GC only runs after the new
        # manifest is durably published, so a crash-looping snapshotter can
        # never collect a snapshot the live service.json still pins
        shard_snapshots = []
        for i, ix in enumerate(self.indexers):
            path = ix.snapshot(directory / f"shard_{i:02d}", keep=0)
            shard_snapshots.append(int(path.name.rsplit("_", 1)[1]))
        # written LAST and published atomically (fsync tmp -> rename): pins
        # one consistent cross-shard snapshot set, so a crash mid-snapshot
        # leaves the previous manifest and the set it pins untouched
        manifest_tmp = directory / "service.json.tmp"
        fsync_json(manifest_tmp, {
            "format_version": FORMAT_VERSION,
            "kind": "service",
            "shard_snapshots": shard_snapshots,
            "n_shards": self.n_shards,
            "sw_count": self.sw_count,
            "fu_count": self.fu_count,
            "max_distance": self.max_distance,
            "algorithm": self.algorithm,
            "use_kernel": self.use_kernel,
            "doc_len": self.doc_len,
        })
        manifest_tmp.replace(directory / "service.json")
        for i, ix in enumerate(self.indexers):
            retain_latest(directory / f"shard_{i:02d}", SNAPSHOT_PREFIX, keep)
            if ix.wal is not None:
                # §18.2: snapshots are WAL checkpoints — sealed segments
                # whose replay the retained snapshots no longer need are
                # truncated with the SAME retention depth
                ix.wal.prune(keep)
        # remember where durable state lives: the §14 supervisor recovers
        # crashed shards from here unless its policy pins another root
        self.last_snapshot_dir = directory
        return directory

    @classmethod
    def restore(
        cls,
        directory,
        use_mmap: bool = True,
        verify: bool = True,
        lemmatizer: Lemmatizer | None = None,
    ) -> "ShardedSearchService":
        """Warm-start a sharded service from a ``snapshot`` directory
        (DESIGN.md §12.2): every shard restores its latest snapshot lazily
        (``mmap``-backed segments), the shared FL-list and doc-id router
        resume from the stored state, and the restored service returns
        fragment sets identical to the snapshotted live one (the §12
        exactness contract).  Shards with a §18 WAL additionally replay
        the operation tail logged after their snapshots, so the restored
        service is exact vs the *uncrashed live* one — post-snapshot
        commits included (§18.2).  Raises ``StoreError`` on corruption."""
        from pathlib import Path

        from ..index.incremental import IncrementalIndexer
        from ..index.store import _load_manifest

        directory = Path(directory)
        m = _load_manifest(directory / "service.json", expect_kind="service")

        svc = cls.__new__(cls)
        svc.algorithm = m["algorithm"]
        svc.arena = None  # runtime accelerator state, not snapshotted
        svc.use_kernel = m["use_kernel"]
        svc.doc_len = m["doc_len"]
        svc.max_distance = m["max_distance"]
        svc.n_shards = m["n_shards"]
        svc.sw_count = m["sw_count"]
        svc.fu_count = m["fu_count"]
        svc.lemmatizer = lemmatizer or Lemmatizer()
        svc._static_shards = []
        svc.supervisor = None
        svc.injector = None
        svc.last_snapshot_dir = directory
        shard_snapshots = m.get("shard_snapshots") or [None] * svc.n_shards
        svc.indexers = [
            IncrementalIndexer.restore(
                directory / f"shard_{i:02d}",
                snapshot_id=shard_snapshots[i],
                use_mmap=use_mmap,
                verify=verify,
                lemmatizer=svc.lemmatizer,
            )
            for i in range(svc.n_shards)
        ]
        for i, ix in enumerate(svc.indexers):
            if ix.wal is not None:
                # re-tag re-attached WALs with their shard ids so the §14
                # wal.* fault points key per-shard arrival counters
                ix.wal.shard = i
        svc.fl = svc.indexers[0].fl
        svc._next_doc_id = max(ix._next_id for ix in svc.indexers)
        return svc

    @classmethod
    def bulk_ingest(
        cls,
        store: DocumentStore,
        directory,
        n_shards: int,
        sw_count: int,
        fu_count: int,
        max_distance: int = 5,
        algorithm: str = "se2.4",
        workers: int = 1,
        docs_per_spill: int = 64,
        resume: bool = False,
        injector=None,
    ) -> tuple["ShardedSearchService", list]:
        """External-memory cold start (DESIGN.md §17): SPIMI bulk-build every
        shard straight to its ``shard_<i>/snap_<N>`` store, then publish
        ``service.json`` and warm-start from disk.

        The FL-list is the same corpus-level reduce ``commit()`` broadcasts,
        pinned into every shard's build, so the published tree is
        byte-identical to ``ShardedSearchService(store, ...,
        incremental=True).snapshot(directory)`` (the §17.4 determinism
        contract) — but postings never round-trip through Python dicts.
        Returns ``(service, [BulkBuildStats per shard])``.
        """
        from pathlib import Path

        from ..checkpoint import fsync_json
        from ..core.lemma import FLList
        from ..index.ingest import bulk_build
        from ..index.store import FORMAT_VERSION

        directory = Path(directory)
        directory.mkdir(parents=True, exist_ok=True)
        fl = FLList.from_frequencies(
            store.lemma_frequencies(), sw_count=sw_count, fu_count=fu_count
        )
        stats = []
        shard_snapshots = []
        for i, sub in enumerate(shard_documents(store, n_shards)):
            st = bulk_build(
                out_dir=directory / f"shard_{i:02d}",
                sw_count=sw_count,
                fu_count=fu_count,
                max_distance=max_distance,
                documents=sub.documents,
                fl=fl,
                docs_per_spill=docs_per_spill,
                workers=workers,
                resume=resume,
                injector=injector,
            )
            stats.append(st)
            shard_snapshots.append(
                int(Path(st.snapshot_path).name.rsplit("_", 1)[1])
            )
        # same publish order as snapshot(): manifest LAST, atomically — a
        # reader that finds service.json finds complete shard stores
        manifest_tmp = directory / "service.json.tmp"
        fsync_json(manifest_tmp, {
            "format_version": FORMAT_VERSION,
            "kind": "service",
            "shard_snapshots": shard_snapshots,
            "n_shards": n_shards,
            "sw_count": sw_count,
            "fu_count": fu_count,
            "max_distance": max_distance,
            "algorithm": algorithm,
            "use_kernel": False,
            "doc_len": 512,
        })
        manifest_tmp.replace(directory / "service.json")
        return cls.restore(directory, lemmatizer=store.lemmatizer), stats

    def search(
        self, query: str, top_k: int = 10, dead_shards: Sequence[int] = ()
    ) -> QueryResponse:
        """Fan out to all live shards and tree-merge ranked results.

        ``dead_shards`` simulates pod failures by holding those shards
        down in the §14 fault injector for this call (ONE failure path
        with the detected-failure case): the service degrades gracefully —
        documents on dead shards are simply absent, the response is
        flagged degraded, and what it does cover is exactly ranked.
        """
        return self.search_batch([query], top_k=top_k, dead_shards=dead_shards)[0]

    def search_batch(
        self,
        queries: Sequence[str],
        top_k: int = 10,
        dead_shards: Sequence[int] = (),
    ) -> list[QueryResponse]:
        """Serve a query batch across all live shards.

        With ``algorithm="fused"`` the full (query x subquery x shard) work
        cross product packs into ONE device program (``search/fused.py``) —
        the fan-out that used to be a Python triple loop of host Combiner
        calls.  Host algorithms keep the per-subquery loop.

        Liveness comes from the §14 probe barrier when the resilience
        layer is on (``enable_resilience``): the supervisor detects,
        retries, hedges and recovers, and the surviving shards still pack
        into the single fused dispatch.  The legacy ``dead_shards=``
        argument routes through the same path — it holds those shards down
        in the :class:`~repro.search.resilience.FaultInjector` for this
        call, so there is one failure path, not two.  Degraded responses
        are flagged (``QueryStats.shards_degraded`` / ``partial``) and
        exactly ranked over the shards they cover.
        """
        import time

        from ..core.keys import expand_subqueries

        t0 = time.perf_counter()
        per_query_subs = [expand_subqueries(q, self.lemmatizer) for q in queries]
        dead = frozenset(int(s) for s in dead_shards)
        if dead and self.supervisor is None:
            self.enable_resilience()
        rstats = None
        if self.supervisor is not None:
            if dead:
                self.injector.hold_down(dead)
            try:
                rstats = QueryStats()
                live_ids = self.supervisor.probe_live_shards(rstats)
            finally:
                if dead:
                    self.injector.release(dead)
            # resolve AFTER the barrier: recovery may have replaced indexers
            shards = self.shards
            live = [shards[i] for i in live_ids]
        else:
            live = list(self.shards)
        if self.algorithm == "fused":
            responses = self._search_batch_fused(
                queries, per_query_subs, live, top_k, t0
            )
        else:
            responses = [
                self._search_host(q, subs, live, top_k)
                for q, subs in zip(queries, per_query_subs)
            ]
        if rstats is not None and (
            rstats.shards_degraded or rstats.retries
            or rstats.hedges or rstats.recoveries
        ):
            for resp in responses:
                st = resp.stats
                # batch-level, like device_dispatches: one probe barrier
                st.retries = rstats.retries
                st.hedges = rstats.hedges
                st.recoveries = rstats.recoveries
                st.shards_degraded = rstats.shards_degraded
                if rstats.shards_degraded:
                    st.partial = True
        return responses

    def _search_host(
        self,
        query: str,
        subqueries: Sequence[Subquery],
        live: Sequence[IndexSet],
        top_k: int,
    ) -> QueryResponse:
        import time

        t0 = time.perf_counter()
        fn = ALGORITHMS[self.algorithm]
        total = QueryStats()
        all_results: set[SearchResult] = set()
        for idx in live:
            for sub in subqueries:
                results, stats = fn(sub, idx)
                total.merge(stats)
                all_results.update(results)
        docs = [
            RankedDoc(doc_id=d, score=s, fragments=f)
            for d, s, f in rank_documents(all_results, top_k=top_k)
        ]
        total.results = len(all_results)
        total.elapsed_sec = time.perf_counter() - t0
        return QueryResponse(query=query, docs=docs, stats=total,
                             n_subqueries=len(subqueries))

    def _search_batch_fused(
        self,
        queries: Sequence[str],
        per_query_subs: Sequence[Sequence[Subquery]],
        live: Sequence[IndexSet],
        top_k: int,
        t0: float,
    ) -> list[QueryResponse]:
        import time

        # segments = the (subquery x live shard) cross product per query;
        # doc ids are global, so shards just contribute disjoint candidates
        work = [
            [(sub, idx) for idx in live for sub in subs]
            for subs in per_query_subs
        ]
        per_stats = [QueryStats() for _ in queries]
        residencies = None
        if self.arena is not None:
            live_ids = {id(v) for v in live}
            specs = [
                (
                    idx,
                    self.indexers[shard_id].generation_token
                    if self.indexers is not None
                    else "static",
                    shard_id,
                )
                for shard_id, idx in enumerate(self.shards)
                if id(idx) in live_ids
            ]
            residencies = {
                id(spec[0]): res
                for spec, res in zip(specs, self.arena.acquire_many(specs))
            }
        batch_stats = QueryStats()
        result = serve_query_batch(
            work,
            max_distance=self.max_distance,
            top_k=top_k,
            doc_len=self.doc_len,
            use_kernel=self.use_kernel,
            stats=per_stats,
            batch_stats=batch_stats,
            residencies=residencies,
        )
        for st in per_stats:
            # batch-level: one shared dispatch/transfer, assigned per query
            st.device_dispatches = batch_stats.device_dispatches
            st.h2d_bytes = batch_stats.h2d_bytes
        elapsed = time.perf_counter() - t0
        responses = []
        for qi, query in enumerate(queries):
            fragments = result.per_query[qi]
            docs = [
                RankedDoc(doc_id=d, score=s, fragments=f)
                for d, s, f in rank_documents(fragments, top_k=top_k)
            ]
            st = per_stats[qi]
            st.results = len(fragments)
            st.elapsed_sec = elapsed  # batch wall time (one shared dispatch)
            responses.append(
                QueryResponse(query=query, docs=docs, stats=st,
                              n_subqueries=len(per_query_subs[qi]))
            )
        return responses


# ---------------------------------------------------------------------------
# device-parallel top-k merge (used by serve_step outputs across the mesh)
# ---------------------------------------------------------------------------


def device_topk_merge(
    scores: jax.Array,  # [S, K] per-shard top scores
    doc_ids: jax.Array,  # [S, K] per-shard doc ids
    k: int,
    mesh: Mesh | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Merge per-shard top-k lists into a global top-k (tree reduction) —
    the only collective of DESIGN.md §4's document-parallel serving layout.

    Inside shard_map this is an all-gather along the document axis followed
    by a local k-selection — O(S*K) per device, the standard serving merge.
    """
    flat_scores = scores.reshape(-1)
    flat_docs = doc_ids.reshape(-1)
    top_scores, idx = jax.lax.top_k(flat_scores, min(k, flat_scores.shape[0]))
    return top_scores, flat_docs[idx]
