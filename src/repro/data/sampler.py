"""GNN graph utilities: CSR storage and a real fanout neighbor sampler
(GraphSAGE-style), producing padded static-shape subgraphs for jit.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["CSRGraph", "NeighborSampler", "random_graph"]


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # [N+1]
    indices: np.ndarray  # [E]
    features: np.ndarray  # [N, F]
    labels: np.ndarray  # [N]

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.indices)

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u] : self.indptr[u + 1]]


def random_graph(n_nodes: int, avg_degree: int, d_feat: int, n_classes: int, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    deg = rng.poisson(avg_degree, n_nodes).clip(1)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = rng.integers(0, n_nodes, int(indptr[-1])).astype(np.int32)
    return CSRGraph(
        indptr=indptr,
        indices=indices,
        features=rng.normal(size=(n_nodes, d_feat)).astype(np.float32),
        labels=rng.integers(0, n_classes, n_nodes).astype(np.int32),
    )


class NeighborSampler:
    """Uniform fanout sampling with relabeling and static padding.

    Output arrays have fixed shapes derived from (batch, fanout) budgets, so
    the jitted train step never recompiles: nodes beyond the sampled count
    are padding (mask 0), edges likewise.
    """

    def __init__(self, graph: CSRGraph, batch_nodes: int, fanout: tuple[int, ...], seed: int = 0):
        self.g = graph
        self.batch_nodes = batch_nodes
        self.fanout = fanout
        self.rng = np.random.default_rng(seed)
        # static budgets
        n = batch_nodes
        self.max_nodes = batch_nodes
        self.max_edges = 0
        for f in fanout:
            self.max_edges += n * f
            n = n * f
            self.max_nodes += n

    def sample(self, seeds: np.ndarray | None = None) -> dict[str, np.ndarray]:
        g = self.g
        if seeds is None:
            seeds = self.rng.choice(g.n_nodes, self.batch_nodes, replace=False)
        node_of: dict[int, int] = {int(u): i for i, u in enumerate(seeds)}
        nodes: list[int] = [int(u) for u in seeds]
        src: list[int] = []
        dst: list[int] = []
        frontier = list(seeds)
        for f in self.fanout:
            nxt: list[int] = []
            for u in frontier:
                nb = g.neighbors(int(u))
                if len(nb) == 0:
                    continue
                pick = self.rng.choice(nb, min(f, len(nb)), replace=False)
                for v in pick:
                    v = int(v)
                    if v not in node_of:
                        node_of[v] = len(nodes)
                        nodes.append(v)
                        nxt.append(v)
                    # message flows neighbor -> center
                    src.append(node_of[v])
                    dst.append(node_of[int(u)])
            frontier = nxt

        n_real, e_real = len(nodes), len(src)
        nn, ee = self.max_nodes, self.max_edges
        node_ids = np.zeros(nn, np.int64)
        node_ids[:n_real] = nodes
        x = np.zeros((nn, g.features.shape[1]), np.float32)
        x[:n_real] = g.features[nodes]
        labels = np.zeros(nn, np.int32)
        labels[:n_real] = g.labels[nodes]
        label_mask = np.zeros(nn, np.int32)
        label_mask[: len(seeds)] = 1  # loss on seed nodes only
        src_a = np.zeros(ee, np.int32)
        dst_a = np.zeros(ee, np.int32)
        emask = np.zeros(ee, np.int32)
        src_a[:e_real] = src
        dst_a[:e_real] = dst
        emask[:e_real] = 1
        return {
            "x": x, "src": src_a, "dst": dst_a, "edge_mask": emask,
            "labels": labels, "label_mask": label_mask,
            "n_real_nodes": np.int32(n_real), "n_real_edges": np.int32(e_real),
        }
