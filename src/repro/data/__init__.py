from .pipeline import LMTokenPipeline, RecsysBatchPipeline, PipelineState
from .sampler import NeighborSampler, CSRGraph, random_graph

__all__ = [
    "LMTokenPipeline",
    "RecsysBatchPipeline",
    "PipelineState",
    "NeighborSampler",
    "CSRGraph",
    "random_graph",
]
