"""Deterministic, checkpointable data pipelines.

Every pipeline's full state is a small pytree (counter + rng key), stored in
the training checkpoint, so restarts replay the exact batch sequence — the
property the fault-tolerance tests assert.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

__all__ = ["PipelineState", "LMTokenPipeline", "RecsysBatchPipeline"]


@dataclasses.dataclass
class PipelineState:
    step: int = 0
    seed: int = 0

    def as_tree(self) -> dict:
        return {"step": np.int64(self.step), "seed": np.int64(self.seed)}

    @classmethod
    def from_tree(cls, tree: dict) -> "PipelineState":
        return cls(step=int(tree["step"]), seed=int(tree["seed"]))


class LMTokenPipeline:
    """Synthetic-corpus next-token batches (Zipf tokens, document packing)."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0, zipf_a: float = 1.1):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        self.state = PipelineState(seed=seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = ranks ** -zipf_a
        self._p = p / p.sum()

    def next_batch(self) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.state.seed, self.state.step))
        toks = rng.choice(self.vocab, size=(self.batch, self.seq_len + 1), p=self._p)
        self.state.step += 1
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
            "mask": np.ones((self.batch, self.seq_len), np.int32),
        }


class RecsysBatchPipeline:
    """Synthetic CTR batches with Zipf-distributed ids (hot-key skew)."""

    def __init__(self, field_vocab: tuple[int, ...], batch: int, n_dense: int = 0,
                 hist_len: int = 0, seed: int = 0):
        self.field_vocab = field_vocab
        self.batch = batch
        self.n_dense = n_dense
        self.hist_len = hist_len
        self.state = PipelineState(seed=seed)

    def next_batch(self) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.state.seed, self.state.step))
        self.state.step += 1
        if self.hist_len:
            v = self.field_vocab[0]
            hist = rng.zipf(1.2, size=(self.batch, self.hist_len)) % v
            nvalid = rng.integers(1, self.hist_len + 1, self.batch)
            mask = np.arange(self.hist_len)[None, :] < nvalid[:, None]
            hist = np.where(mask, hist, -1)
            return {
                "hist_ids": hist.astype(np.int32),
                "target_id": (rng.zipf(1.2, self.batch) % v).astype(np.int32),
            }
        ids = np.stack(
            [rng.zipf(1.2, self.batch) % v for v in self.field_vocab], axis=1
        ).astype(np.int32)
        out = {
            "sparse_ids": ids,
            "label": rng.integers(0, 2, self.batch).astype(np.float32),
        }
        if self.n_dense:
            out["dense"] = rng.normal(size=(self.batch, self.n_dense)).astype(np.float32)
        return out
