"""Shared LM shape set (the assignment's seq_len x global_batch grid).

``long_500k`` is skipped for the pure full-attention assigned archs (noted in
DESIGN.md §6); a sliding-window-attention bonus variant ``long_500k[swa]``
exercises the sub-quadratic long-context path anyway.
"""

from __future__ import annotations

from .common import ArchSpec, ShapeCell

FULL_ATTN_SKIP = (
    "pure full-attention arch: 524k-token decode requires sub-quadratic "
    "attention (DESIGN.md §6); see the long_500k[swa] bonus variant"
)


def lm_shapes(swa_window: int = 4096) -> dict[str, ShapeCell]:
    return {
        "train_4k": ShapeCell(
            name="train_4k", step="train", kind="training",
            kwargs={"seq_len": 4096, "global_batch": 256},
        ),
        "prefill_32k": ShapeCell(
            name="prefill_32k", step="prefill", kind="inference-prefill",
            kwargs={"seq_len": 32768, "global_batch": 32},
        ),
        "decode_32k": ShapeCell(
            name="decode_32k", step="decode", kind="inference-decode",
            kwargs={"seq_len": 32768, "global_batch": 128},
        ),
        "long_500k": ShapeCell(
            name="long_500k", step="decode", kind="long-context-decode",
            kwargs={"seq_len": 524288, "global_batch": 1},
            skip_reason=FULL_ATTN_SKIP,
        ),
        "long_500k[swa]": ShapeCell(
            name="long_500k[swa]", step="decode", kind="long-context-decode",
            kwargs={
                "seq_len": 524288,
                "global_batch": 1,
                "sliding_window": swa_window,
            },
            variant="swa",
        ),
    }


def reduced_lm_shapes() -> dict[str, ShapeCell]:
    """CPU-runnable smoke shapes (same step kinds, tiny extents)."""
    return {
        "train_4k": ShapeCell(
            name="train_4k", step="train", kind="training",
            kwargs={"seq_len": 128, "global_batch": 4},
        ),
        "prefill_32k": ShapeCell(
            name="prefill_32k", step="prefill", kind="inference-prefill",
            kwargs={"seq_len": 256, "global_batch": 2},
        ),
        "decode_32k": ShapeCell(
            name="decode_32k", step="decode", kind="inference-decode",
            kwargs={"seq_len": 256, "global_batch": 4},
        ),
        "long_500k[swa]": ShapeCell(
            name="long_500k[swa]", step="decode", kind="long-context-decode",
            kwargs={"seq_len": 512, "global_batch": 1, "sliding_window": 64},
            variant="swa",
        ),
    }
