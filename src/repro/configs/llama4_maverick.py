"""llama4-maverick-400b-a17b [moe] 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128e top-1 — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Notes (DESIGN.md §Arch-applicability):
* Llama-4 interleaves dense and MoE FFNs (every other layer); we model that
  with ``moe_interleave=2`` (24 dense + 24 MoE layers), landing ~400B total /
  ~20B active with the assigned per-expert d_ff=8192.
* "early fusion" refers to the VLM frontend — per the assignment the modality
  frontend is a STUB: ``input_specs()`` feeds token/patch-embedding ids.
"""

from __future__ import annotations

from ..models.moe import MoEConfig
from ..models.transformer import TransformerConfig
from .common import ArchSpec
from .lm_common import lm_shapes, reduced_lm_shapes

CONFIG = TransformerConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,  # dense (non-MoE) layers
    vocab=202048,
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=128, top_k=1, d_ff=8192, n_shared_experts=0,
                  dispatch="local"),
    moe_interleave=2,
    microbatches=16,
    fsdp=True,
)

REDUCED = TransformerConfig(
    name="llama4-maverick-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    moe=MoEConfig(n_experts=8, top_k=1, d_ff=128),
    moe_interleave=2,
    q_chunk=32,
    kv_chunk=32,
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="llama4-maverick-400b-a17b",
        family="lm",
        source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
        shapes=lm_shapes(),
        model_cfg=CONFIG,
    )


def reduced_spec() -> ArchSpec:
    s = spec()
    return ArchSpec(
        arch_id=s.arch_id, family=s.family, source=s.source,
        shapes=reduced_lm_shapes(), model_cfg=REDUCED,
    )
