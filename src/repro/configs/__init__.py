"""Architecture registry: ``--arch <id>`` resolves here.

Ten assigned architectures + the paper's own ``paper_search`` system.
Every arch exposes a full-scale spec (dry-run only — ShapeDtypeStructs,
no allocation) and a reduced smoke spec (CPU-runnable).
"""

from __future__ import annotations

from .common import ArchSpec, ShapeCell
from . import (
    stablelm_3b,
    mistral_large_123b,
    tinyllama_1_1b,
    llama4_maverick,
    olmoe_1b_7b,
    gat_cora,
    autoint,
    mind,
    dcn_v2,
    fm,
    paper_search,
)

_MODULES = {
    "stablelm-3b": stablelm_3b,
    "mistral-large-123b": mistral_large_123b,
    "tinyllama-1.1b": tinyllama_1_1b,
    "llama4-maverick-400b-a17b": llama4_maverick,
    "olmoe-1b-7b": olmoe_1b_7b,
    "gat-cora": gat_cora,
    "autoint": autoint,
    "mind": mind,
    "dcn-v2": dcn_v2,
    "fm": fm,
    "paper_search": paper_search,
}

ARCH_IDS = tuple(_MODULES)
ASSIGNED_ARCH_IDS = tuple(a for a in ARCH_IDS if a != "paper_search")


def get_spec(arch_id: str) -> ArchSpec:
    return _MODULES[arch_id].spec()


def get_reduced_spec(arch_id: str) -> ArchSpec:
    return _MODULES[arch_id].reduced_spec()


__all__ = ["ArchSpec", "ShapeCell", "ARCH_IDS", "ASSIGNED_ARCH_IDS", "get_spec", "get_reduced_spec"]
