"""Shared RecSys shape set (the assignment's batch grid)."""

from __future__ import annotations

from .common import ShapeCell


def recsys_shapes() -> dict[str, ShapeCell]:
    return {
        "train_batch": ShapeCell(
            name="train_batch", step="train", kind="training",
            kwargs={"batch": 65536},
        ),
        "serve_p99": ShapeCell(
            name="serve_p99", step="score", kind="online-inference",
            kwargs={"batch": 512},
        ),
        "serve_bulk": ShapeCell(
            name="serve_bulk", step="score", kind="offline-scoring",
            kwargs={"batch": 262144},
        ),
        "retrieval_cand": ShapeCell(
            name="retrieval_cand", step="retrieval", kind="retrieval-scoring",
            kwargs={"batch": 1, "n_candidates": 1_000_000},
        ),
    }


def reduced_recsys_shapes() -> dict[str, ShapeCell]:
    return {
        "train_batch": ShapeCell(
            name="train_batch", step="train", kind="training",
            kwargs={"batch": 64},
        ),
        "serve_p99": ShapeCell(
            name="serve_p99", step="score", kind="online-inference",
            kwargs={"batch": 16},
        ),
        "retrieval_cand": ShapeCell(
            name="retrieval_cand", step="retrieval", kind="retrieval-scoring",
            kwargs={"batch": 1, "n_candidates": 512},
        ),
    }
