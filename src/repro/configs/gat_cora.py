"""gat-cora [gnn] n_layers=2 d_hidden=8 n_heads=8 aggregator=attn.
[arXiv:1710.10903; paper]

The four assigned shape cells are different graphs, so the GATConfig varies
per cell (feature width / class count follow the dataset):

  full_graph_sm — Cora          (2708 N, 10556 E, 1433 f, 7 cls, full-batch)
  minibatch_lg  — Reddit        (232965 N, 114.6M E; sampled 1024 @ 15-10)
  ogb_products  — ogbn-products (2.44M N, 61.86M E, 100 f, 47 cls, full-batch)
  molecule      — batched small graphs (30 N, 64 E, batch 128, graph-level)
"""

from __future__ import annotations

import dataclasses

from ..models.gnn import GATConfig
from .common import ArchSpec, ShapeCell

BASE = GATConfig(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8)

SHAPES = {
    "full_graph_sm": ShapeCell(
        name="full_graph_sm", step="train", kind="full-batch",
        kwargs={
            "n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_classes": 7,
            "task": "node", "shard_nodes": False, "self_loops": True,
        },
    ),
    "minibatch_lg": ShapeCell(
        name="minibatch_lg", step="train", kind="sampled-training",
        kwargs={
            # padded sampled-subgraph budget: 1024 seeds, fanout 15 then 10
            # (1024 * (1 + 15 + 150) = 169,984 nodes / edges upper bound)
            "n_nodes": 169984, "n_edges": 169984,
            "batch_nodes": 1024, "fanout": (15, 10),
            "graph_nodes": 232965, "graph_edges": 114615892,
            "d_feat": 602, "n_classes": 41,
            "task": "node", "shard_nodes": False, "self_loops": True,
        },
    ),
    "ogb_products": ShapeCell(
        name="ogb_products", step="train", kind="full-batch-large",
        kwargs={
            "n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
            "n_classes": 47, "task": "node", "shard_nodes": True,
            "self_loops": False,
        },
    ),
    "molecule": ShapeCell(
        name="molecule", step="train", kind="batched-small-graphs",
        kwargs={
            "n_nodes": 30 * 128, "n_edges": 64 * 128, "batch_graphs": 128,
            "d_feat": 16, "n_classes": 2, "task": "graph",
            "shard_nodes": False, "self_loops": False,
        },
    ),
}


def _cfg_for(cell: ShapeCell) -> GATConfig:
    return dataclasses.replace(
        BASE,
        d_feat=cell.kwargs["d_feat"],
        n_classes=cell.kwargs["n_classes"],
        task=cell.kwargs["task"],
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="gat-cora",
        family="gnn",
        source="arXiv:1710.10903; paper",
        shapes=SHAPES,
        model_cfg_fn=_cfg_for,
    )


def reduced_spec() -> ArchSpec:
    shapes = {
        "full_graph_sm": ShapeCell(
            name="full_graph_sm", step="train", kind="full-batch",
            kwargs={
                "n_nodes": 64, "n_edges": 256, "d_feat": 24, "n_classes": 5,
                "task": "node", "shard_nodes": False, "self_loops": True,
            },
        ),
        "molecule": ShapeCell(
            name="molecule", step="train", kind="batched-small-graphs",
            kwargs={
                "n_nodes": 8 * 4, "n_edges": 16 * 4, "batch_graphs": 4,
                "d_feat": 8, "n_classes": 2, "task": "graph",
                "shard_nodes": False, "self_loops": False,
            },
        ),
    }
    return ArchSpec(
        arch_id="gat-cora", family="gnn", source="arXiv:1710.10903; paper",
        shapes=shapes, model_cfg_fn=_cfg_for,
    )
