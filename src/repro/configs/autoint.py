"""autoint [recsys] n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2
d_attn=32 interaction=self-attn.  [arXiv:1810.11921; paper]

AutoInt's Criteo setup discretizes the 13 numeric fields, giving 39 sparse
fields over ~1M feature values total.
"""

from __future__ import annotations

import dataclasses

from ..models.recsys import RecsysConfig
from .common import ArchSpec, zipf_vocab_split
from .recsys_common import recsys_shapes, reduced_recsys_shapes

CONFIG = RecsysConfig(
    name="autoint",
    model="autoint",
    n_sparse=39,
    embed_dim=16,
    field_vocab=zipf_vocab_split(998_960, 39),
    n_attn_layers=3,
    n_attn_heads=2,
    d_attn=32,
)

REDUCED = dataclasses.replace(
    CONFIG, name="autoint-smoke", field_vocab=zipf_vocab_split(2_000, 39)
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="autoint", family="recsys", source="arXiv:1810.11921; paper",
        shapes=recsys_shapes(), model_cfg=CONFIG,
    )


def reduced_spec() -> ArchSpec:
    return ArchSpec(
        arch_id="autoint", family="recsys", source="arXiv:1810.11921; paper",
        shapes=reduced_recsys_shapes(), model_cfg=REDUCED,
    )
