"""Config-registry plumbing shared by every architecture."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

__all__ = ["ShapeCell", "ArchSpec", "zipf_vocab_split"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (architecture x input-shape) cell."""

    name: str
    step: str  # "train" | "prefill" | "decode" | "score" | "retrieval" | "serve" | "build"
    kind: str  # reporting label from the assignment ("training", ...)
    kwargs: dict[str, Any] = dataclasses.field(default_factory=dict)
    skip_reason: str | None = None  # e.g. long_500k on pure full-attention
    variant: str | None = None  # e.g. "swa" bonus rows


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # "lm" | "gnn" | "recsys" | "search"
    source: str  # provenance note from the assignment
    shapes: dict[str, ShapeCell]
    # model config; GNN archs vary per-cell (different graphs), hence a fn
    model_cfg: Any = None
    model_cfg_fn: Callable[[ShapeCell], Any] | None = None

    def cfg_for(self, shape_name: str) -> Any:
        cell = self.shapes[shape_name]
        if self.model_cfg_fn is not None:
            return self.model_cfg_fn(cell)
        return self.model_cfg

    def cells(self, include_skipped: bool = False) -> list[ShapeCell]:
        return [
            c for c in self.shapes.values() if include_skipped or c.skip_reason is None
        ]


def zipf_vocab_split(total: int, n_fields: int, alpha: float = 1.1, min_rows: int = 4) -> tuple[int, ...]:
    """Deterministic Zipf-ish split of a total vocabulary across fields —
    mimics real CTR datasets (a few huge ID fields, many small ones)."""
    weights = [(i + 1) ** -alpha for i in range(n_fields)]
    s = sum(weights)
    sizes = [max(min_rows, int(total * w / s)) for w in weights]
    # fix rounding drift on the largest field
    sizes[0] += total - sum(sizes)
    return tuple(sizes)
