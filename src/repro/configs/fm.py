"""fm [recsys] n_sparse=39 embed_dim=10 interaction=fm-2way — pairwise
<v_i, v_j> x_i x_j via the O(nk) sum-square trick.  [ICDM'10 (Rendle); paper]"""

from __future__ import annotations

import dataclasses

from ..models.recsys import RecsysConfig
from .common import ArchSpec, zipf_vocab_split
from .recsys_common import recsys_shapes, reduced_recsys_shapes

CONFIG = RecsysConfig(
    name="fm",
    model="fm",
    n_sparse=39,
    embed_dim=10,
    field_vocab=zipf_vocab_split(998_960, 39),
)

REDUCED = dataclasses.replace(
    CONFIG, name="fm-smoke", field_vocab=zipf_vocab_split(2_000, 39)
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="fm", family="recsys", source="ICDM'10 (Rendle); paper",
        shapes=recsys_shapes(), model_cfg=CONFIG,
    )


def reduced_spec() -> ArchSpec:
    return ArchSpec(
        arch_id="fm", family="recsys", source="ICDM'10 (Rendle); paper",
        shapes=reduced_recsys_shapes(), model_cfg=REDUCED,
    )
