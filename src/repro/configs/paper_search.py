"""paper_search — the paper's own architecture as an 11th config.

Multi-component key proximity search serving (document-sharded) and index
building, with the vectorized Combiner as the device compute.  Shapes are
fixed serving budgets: B queries x P postings x C candidate clusters x
L lemmas x N window positions.
"""

from __future__ import annotations

import dataclasses

from .common import ArchSpec, ShapeCell


@dataclasses.dataclass(frozen=True)
class SearchServeConfig:
    name: str
    max_distance: int = 5
    n_lemmas: int = 8  # max unique lemmas per subquery (queries are 3-5 words)
    window_len: int = 128  # positions per candidate cluster window
    top_k: int = 16
    build_buckets: int = 65536

    def param_count(self) -> int:
        return 0  # index structures, not learned parameters


CONFIG = SearchServeConfig(name="paper_search")

SHAPES = {
    "serve_online": ShapeCell(
        name="serve_online", step="serve", kind="online-search",
        kwargs={"batch": 256, "postings": 8192, "clusters": 256},
    ),
    "serve_bulk": ShapeCell(
        name="serve_bulk", step="serve", kind="bulk-search",
        kwargs={"batch": 4096, "postings": 8192, "clusters": 256},
    ),
    "score_1m": ShapeCell(
        name="score_1m", step="serve", kind="candidate-scoring",
        kwargs={"batch": 8, "postings": 262144, "clusters": 131072},
    ),
    "build_chunk": ShapeCell(
        name="build_chunk", step="build", kind="index-build",
        kwargs={"docs": 4096, "doc_len": 1024},
    ),
}


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="paper_search", family="search",
        source="Veretennikov, IntelliSys 2020 (this paper)",
        shapes=SHAPES, model_cfg=CONFIG,
    )


def reduced_spec() -> ArchSpec:
    shapes = {
        "serve_online": ShapeCell(
            name="serve_online", step="serve", kind="online-search",
            kwargs={"batch": 4, "postings": 128, "clusters": 8},
        ),
        "build_chunk": ShapeCell(
            name="build_chunk", step="build", kind="index-build",
            kwargs={"docs": 4, "doc_len": 128},
        ),
    }
    return ArchSpec(
        arch_id="paper_search", family="search",
        source="Veretennikov, IntelliSys 2020 (this paper)",
        shapes=shapes,
        model_cfg=dataclasses.replace(CONFIG, build_buckets=1024),
    )
