"""mistral-large-123b [dense] 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""

from __future__ import annotations

from ..models.transformer import TransformerConfig
from .common import ArchSpec
from .lm_common import lm_shapes, reduced_lm_shapes

CONFIG = TransformerConfig(
    name="mistral-large-123b",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    rope_theta=1_000_000.0,
    microbatches=16,
    fsdp=True,
)

REDUCED = TransformerConfig(
    name="mistral-large-smoke",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=192,
    vocab=256,
    q_chunk=32,
    kv_chunk=32,
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="mistral-large-123b",
        family="lm",
        source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
        shapes=lm_shapes(),
        model_cfg=CONFIG,
    )


def reduced_spec() -> ArchSpec:
    s = spec()
    return ArchSpec(
        arch_id=s.arch_id, family=s.family, source=s.source,
        shapes=reduced_lm_shapes(), model_cfg=REDUCED,
    )
