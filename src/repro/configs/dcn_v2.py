"""dcn-v2 [recsys] n_dense=13 n_sparse=26 embed_dim=16 n_cross_layers=3
mlp=1024-1024-512 interaction=cross.  [arXiv:2008.13535; paper]

Full-vocabulary Criteo scale: ~33.76M embedding rows across 26 fields —
the table IS the memory footprint; rows shard across the ``model`` axis.
"""

from __future__ import annotations

import dataclasses

from ..models.recsys import RecsysConfig
from .common import ArchSpec, zipf_vocab_split
from .recsys_common import recsys_shapes, reduced_recsys_shapes

CONFIG = RecsysConfig(
    name="dcn-v2",
    model="dcn_v2",
    n_dense=13,
    n_sparse=26,
    embed_dim=16,
    field_vocab=zipf_vocab_split(33_762_577, 26),
    n_cross_layers=3,
    mlp_dims=(1024, 1024, 512),
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="dcn-v2-smoke",
    field_vocab=zipf_vocab_split(2_000, 26),
    mlp_dims=(64, 64, 32),
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="dcn-v2", family="recsys", source="arXiv:2008.13535; paper",
        shapes=recsys_shapes(), model_cfg=CONFIG,
    )


def reduced_spec() -> ArchSpec:
    return ArchSpec(
        arch_id="dcn-v2", family="recsys", source="arXiv:2008.13535; paper",
        shapes=reduced_recsys_shapes(), model_cfg=REDUCED,
    )
