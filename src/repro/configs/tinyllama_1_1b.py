"""tinyllama-1.1b [dense] 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000 — llama2-arch small.  [arXiv:2401.02385; hf]"""

from __future__ import annotations

from ..models.transformer import TransformerConfig
from .common import ArchSpec
from .lm_common import lm_shapes, reduced_lm_shapes

CONFIG = TransformerConfig(
    name="tinyllama-1.1b",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab=32000,
    microbatches=2,
)

REDUCED = TransformerConfig(
    name="tinyllama-smoke",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=160,
    vocab=256,
    q_chunk=32,
    kv_chunk=32,
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="tinyllama-1.1b",
        family="lm",
        source="arXiv:2401.02385; hf",
        shapes=lm_shapes(),
        model_cfg=CONFIG,
    )


def reduced_spec() -> ArchSpec:
    s = spec()
    return ArchSpec(
        arch_id=s.arch_id, family=s.family, source=s.source,
        shapes=reduced_lm_shapes(), model_cfg=REDUCED,
    )
