"""mind [recsys] embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest.  [arXiv:1904.08030; unverified]

Multi-Interest Network with Dynamic routing: behavior-sequence capsule
routing into 4 interest vectors; retrieval scores = max over interests.
Item vocabulary sized to the paper's Taobao setting (~3.7M items).
"""

from __future__ import annotations

import dataclasses

from ..models.recsys import RecsysConfig
from .common import ArchSpec
from .recsys_common import recsys_shapes, reduced_recsys_shapes

CONFIG = RecsysConfig(
    name="mind",
    model="mind",
    n_sparse=1,  # the item-id space
    embed_dim=64,
    field_vocab=(3_706_119,),
    n_interests=4,
    capsule_iters=3,
    hist_len=50,
)

REDUCED = dataclasses.replace(
    CONFIG, name="mind-smoke", field_vocab=(4_000,), hist_len=16
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="mind", family="recsys", source="arXiv:1904.08030; unverified",
        shapes=recsys_shapes(), model_cfg=CONFIG,
    )


def reduced_spec() -> ArchSpec:
    return ArchSpec(
        arch_id="mind", family="recsys", source="arXiv:1904.08030; unverified",
        shapes=reduced_recsys_shapes(), model_cfg=REDUCED,
    )
