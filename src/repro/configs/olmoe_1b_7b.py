"""olmoe-1b-7b [moe] 16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304,
MoE 64e top-8.  [arXiv:2409.02060; hf]"""

from __future__ import annotations

from ..models.moe import MoEConfig
from ..models.transformer import TransformerConfig
from .common import ArchSpec
from .lm_common import lm_shapes, reduced_lm_shapes

CONFIG = TransformerConfig(
    name="olmoe-1b-7b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    # dispatch="local": replicated-activation EP (EXPERIMENTS.md §Perf-1);
    # baselines "einsum"/"sort" remain selectable for comparison
    moe=MoEConfig(n_experts=64, top_k=8, d_ff=1024, dispatch="local"),
    microbatches=4,
)

REDUCED = TransformerConfig(
    name="olmoe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=64),
    q_chunk=32,
    kv_chunk=32,
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="olmoe-1b-7b",
        family="lm",
        source="arXiv:2409.02060; hf",
        shapes=lm_shapes(),
        model_cfg=CONFIG,
    )


def reduced_spec() -> ArchSpec:
    s = spec()
    return ArchSpec(
        arch_id=s.arch_id, family=s.family, source=s.source,
        shapes=reduced_lm_shapes(), model_cfg=REDUCED,
    )
