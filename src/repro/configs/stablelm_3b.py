"""stablelm-3b [dense] 32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""

from __future__ import annotations

from ..models.transformer import TransformerConfig
from .common import ArchSpec
from .lm_common import lm_shapes, reduced_lm_shapes

CONFIG = TransformerConfig(
    name="stablelm-3b",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    microbatches=4,
)

REDUCED = TransformerConfig(
    name="stablelm-3b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    q_chunk=32,
    kv_chunk=32,
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="stablelm-3b",
        family="lm",
        source="hf:stabilityai/stablelm-2-1_6b; unverified",
        shapes=lm_shapes(),
        model_cfg=CONFIG,
    )


def reduced_spec() -> ArchSpec:
    s = spec()
    return ArchSpec(
        arch_id=s.arch_id, family=s.family, source=s.source,
        shapes=reduced_lm_shapes(), model_cfg=REDUCED,
    )
