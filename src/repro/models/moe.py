"""Mixture-of-Experts FFN with two interchangeable dispatch strategies.

* ``einsum``  — GShard-style one-hot dispatch/combine tensors.  Fully
  GSPMD-friendly (pure einsums; experts shard on the ``model`` axis, tokens
  on ``data``; the dispatch contraction lowers to an all-to-all).  Its known
  tax: the dispatch einsum burns ``T*E*C*D`` FLOPs, significant when experts
  are small (OLMoE's d_ff=1024) — visible in the roofline's
  MODEL_FLOPS/HLO_FLOPs ratio and attacked in EXPERIMENTS.md §Perf.

* ``sort``    — MegaBlocks-lite scatter dispatch: argsort tokens by expert,
  position-in-expert from segment arithmetic, unique-destination scatter into
  expert buffers.  No E×C one-hots; the cost is sort + gather/scatter (the
  global argsort still reshards under GSPMD — see §Perf).

* ``local``   — replicated-activation expert parallelism via ``shard_map``:
  activations are data-sharded and replicated across the ``model`` axis, so
  the model-column that owns an expert already holds every token locally —
  routing needs NO communication at all.  Each column sorts/packs only its
  own experts' tokens; the single collective is the per-layer psum of the
  partial outputs ``[T_local, D]``.  This is the §Perf-1 optimized path.

All share capacity semantics: per-expert buffer ``C = ceil(T*k/E * cf)``;
overflow tokens are dropped (standard Switch behaviour).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import dense_init

__all__ = ["MoEConfig", "init_moe_params", "moe_ffn"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    dispatch: Literal["einsum", "sort", "local"] = "einsum"


def init_moe_params(key: jax.Array, d_model: int, cfg: MoEConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d_model, cfg.n_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (cfg.n_experts, d_model, cfg.d_ff), dtype),
        "w_up": dense_init(ks[2], (cfg.n_experts, d_model, cfg.d_ff), dtype),
        "w_down": dense_init(ks[3], (cfg.n_experts, cfg.d_ff, d_model), dtype),
    }
    if cfg.n_shared_experts:
        f = cfg.d_ff * cfg.n_shared_experts
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(sk[0], (d_model, f), dtype),
            "w_up": dense_init(sk[1], (d_model, f), dtype),
            "w_down": dense_init(sk[2], (f, d_model), dtype),
        }
    return p


def _capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, (c + 7) // 8 * 8)


def _router(x2d: jax.Array, params, cfg: MoEConfig):
    logits = jnp.einsum(
        "td,de->te", x2d.astype(jnp.float32), params["router"],
        preferred_element_type=jnp.float32,
    )
    gates = jax.nn.softmax(logits, axis=-1)
    # Switch load-balancing loss
    me = gates.mean(axis=0)
    return gates, me


def _moe_einsum(x2d: jax.Array, params, cfg: MoEConfig):
    t, d = x2d.shape
    e, c = cfg.n_experts, _capacity(t, cfg)
    gates, me = _router(x2d, params, cfg)

    # identical selection + normalization across all dispatch strategies
    w_topk, e_topk = jax.lax.top_k(gates, cfg.top_k)  # [T, k]
    w_topk = w_topk / jnp.maximum(w_topk.sum(-1, keepdims=True), 1e-9)
    base = jnp.zeros((e,), jnp.float32)
    dispatch = jnp.zeros((t, e, c), x2d.dtype)
    combine = jnp.zeros((t, e, c), jnp.float32)
    ce = jnp.zeros((e,), jnp.float32)
    for s_ in range(cfg.top_k):  # static unroll over slots
        idx = e_topk[:, s_]
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # [T, E]
        ce = ce + onehot.mean(axis=0)
        w = w_topk[:, s_]  # [T]
        pos = (jnp.cumsum(onehot, axis=0) - 1.0 + base[None, :])
        base = base + onehot.sum(axis=0)
        pos_tok = (pos * onehot).sum(axis=-1)  # [T] position in chosen expert
        valid = pos_tok < c
        pos_oh = jax.nn.one_hot(pos_tok.astype(jnp.int32), c, dtype=jnp.float32)
        slot = onehot[:, :, None] * pos_oh[:, None, :] * valid[:, None, None]
        dispatch = dispatch + slot.astype(x2d.dtype)
        combine = combine + slot * w[:, None, None]

    xe = jnp.einsum("tec,td->ecd", dispatch, x2d, preferred_element_type=x2d.dtype)
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"], preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x2d.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"], preferred_element_type=jnp.float32)
    y = jnp.einsum("tec,ecd->td", combine.astype(x2d.dtype), ye.astype(x2d.dtype),
                   preferred_element_type=jnp.float32)
    aux = cfg.n_experts * jnp.sum(me * (ce / cfg.top_k))
    return y.astype(x2d.dtype), aux


def _moe_sort(x2d: jax.Array, params, cfg: MoEConfig):
    t, d = x2d.shape
    e, k = cfg.n_experts, cfg.top_k
    c = _capacity(t, cfg)
    gates, me = _router(x2d, params, cfg)
    w_topk, e_topk = jax.lax.top_k(gates, k)  # [T, k]
    w_topk = w_topk / jnp.maximum(w_topk.sum(-1, keepdims=True), 1e-9)

    e_flat = e_topk.reshape(-1)  # [T*k]
    w_flat = w_topk.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(e_flat)  # stable
    e_s, w_s, tok_s = e_flat[order], w_flat[order], tok_flat[order]
    counts = jnp.bincount(e_flat, length=e)
    seg_start = jnp.cumsum(counts) - counts  # exclusive
    pos_in_e = jnp.arange(t * k) - seg_start[e_s]
    valid = pos_in_e < c
    dest = jnp.where(valid, e_s * c + pos_in_e, 0)

    buf = jnp.zeros((e * c, d), x2d.dtype)
    vals = x2d[tok_s] * valid[:, None].astype(x2d.dtype)
    buf = buf.at[dest].add(vals)  # unique destinations where valid
    bufe = buf.reshape(e, c, d)
    g = jnp.einsum("ecd,edf->ecf", bufe, params["w_gate"], preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", bufe, params["w_up"], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x2d.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"], preferred_element_type=jnp.float32)
    y_s = ye.reshape(e * c, d)[dest] * (valid * w_s)[:, None]
    y = jnp.zeros((t, d), jnp.float32).at[tok_s].add(y_s)

    ce = jnp.zeros((e,), jnp.float32).at[e_flat].add(1.0) / (t * k)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return y.astype(x2d.dtype), aux


def _pack_local(x_loc, w_gate, w_up, w_down, gates, cfg: MoEConfig, n_cols: int):
    """One model-column's expert compute: pack MY experts' tokens, matmul,
    scatter back.  Pure local ops — runs inside shard_map."""
    t_loc, d = x_loc.shape
    e, k = cfg.n_experts, cfg.top_k
    e_loc = w_gate.shape[0]  # experts owned by this column
    col = jax.lax.axis_index("model")
    lo = col * e_loc
    w_topk, e_topk = jax.lax.top_k(gates, k)  # [T, k]
    w_topk = w_topk / jnp.maximum(w_topk.sum(-1, keepdims=True), 1e-9)
    e_flat = e_topk.reshape(-1)
    w_flat = w_topk.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t_loc), k)
    mine = (e_flat >= lo) & (e_flat < lo + e_loc)
    le = jnp.where(mine, e_flat - lo, e_loc)  # sentinel e_loc sorts last
    order = jnp.argsort(le)
    le_s, w_s, tok_s = le[order], w_flat[order], tok_flat[order]
    counts = jnp.bincount(le, length=e_loc + 1)
    seg_start = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t_loc * k) - seg_start[le_s]
    c = max(8, int(t_loc * k / e * cfg.capacity_factor + 7) // 8 * 8)
    valid = (pos_in_e < c) & (le_s < e_loc)
    dest = jnp.where(valid, le_s * c + pos_in_e, 0)
    buf = jnp.zeros((e_loc * c, d), x_loc.dtype)
    buf = buf.at[dest].add(x_loc[tok_s] * valid[:, None].astype(x_loc.dtype))
    bufe = buf.reshape(e_loc, c, d)
    g = jnp.einsum("ecd,edf->ecf", bufe, w_gate, preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", bufe, w_up, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x_loc.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down, preferred_element_type=jnp.float32)
    y_s = ye.reshape(e_loc * c, d)[dest] * (valid * w_s)[:, None]
    y = jnp.zeros((t_loc, d), jnp.float32).at[tok_s].add(y_s)
    return y


def _moe_local(x2d: jax.Array, params, cfg: MoEConfig):
    """Replicated-activation EP: route locally, psum partial outputs."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names or "model" not in mesh.axis_names:
        return _moe_sort(x2d, params, cfg)  # single-device fallback
    sizes = dict(mesh.shape)
    n_cols = sizes["model"]
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    n_data = 1
    for a in data_axes:
        n_data *= sizes[a]
    # tokens must tile the data axes (decode with B=1 falls back)
    if cfg.n_experts % n_cols or x2d.shape[0] % n_data:
        return _moe_sort(x2d, params, cfg)

    @functools.partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(
            P(data_axes, None),  # x2d: tokens data-sharded, replicated on model
            P(),  # router
            P("model", None, None),  # w_gate [E, D, F]
            P("model", None, None),  # w_up
            P("model", None, None),  # w_down
        ),
        out_specs=(P(data_axes, None), P()),
        check_vma=False,
    )
    def inner(x_loc, router, w_gate, w_up, w_down):
        logits = jnp.einsum(
            "td,de->te", x_loc.astype(jnp.float32), router,
            preferred_element_type=jnp.float32,
        )
        gates = jax.nn.softmax(logits, axis=-1)
        y = _pack_local(x_loc, w_gate, w_up, w_down, gates, cfg, n_cols)
        # the ONLY collective: combine per-column partial outputs
        y = jax.lax.psum(y, "model")
        # Switch aux loss from local statistics (identical in expectation)
        me = gates.mean(axis=0)
        _, e_topk = jax.lax.top_k(gates, cfg.top_k)
        ce = jnp.zeros((cfg.n_experts,), jnp.float32).at[e_topk.reshape(-1)].add(1.0)
        ce = ce / (x_loc.shape[0] * cfg.top_k)
        aux = cfg.n_experts * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, "model")
        for ax in data_axes:
            aux = jax.lax.pmean(aux, ax)
        return y.astype(x_loc.dtype), aux

    return inner(x2d, params["router"], params["w_gate"], params["w_up"], params["w_down"])


def moe_ffn(x: jax.Array, params, cfg: MoEConfig) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y, aux_loss)."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    if cfg.dispatch == "local":
        y, aux = _moe_local(x2d, params, cfg)
    elif cfg.dispatch == "sort":
        y, aux = _moe_sort(x2d, params, cfg)
    else:
        y, aux = _moe_einsum(x2d, params, cfg)
    if cfg.n_shared_experts:
        sp = params["shared"]
        g = jnp.einsum("td,df->tf", x2d, sp["w_gate"], preferred_element_type=jnp.float32)
        u = jnp.einsum("td,df->tf", x2d, sp["w_up"], preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(x2d.dtype)
        y = y + jnp.einsum("tf,fd->td", h, sp["w_down"], preferred_element_type=jnp.float32).astype(x2d.dtype)
    return y.reshape(b, s, d), aux
