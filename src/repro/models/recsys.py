"""RecSys / ranking models: FM, AutoInt, DCN-v2, MIND.

The shared substrate is a single concatenated sparse-feature embedding table
(``[total_vocab, dim]``, per-field row offsets) — the standard trick for
sharding one huge table instead of many small ones.  JAX has no native
``nn.EmbeddingBag``; :func:`embedding_bag` builds it from ``jnp.take`` +
``jax.ops.segment_sum`` (multi-hot fields, padding = -1), as required.

Every model exposes:
  * ``loss``            — pointwise BCE (CTR models) / in-batch sampled
                          softmax (MIND retrieval), for ``train_batch``;
  * ``score``           — forward scores, for ``serve_p99`` / ``serve_bulk``;
  * ``retrieval_score`` — one context against ``n_candidates`` items as a
                          single batched-dot/broadcast forward (NO loops),
                          for ``retrieval_cand``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = [
    "RecsysConfig",
    "embedding_bag",
    "init_recsys_params",
    "recsys_loss",
    "recsys_score",
    "recsys_retrieval_score",
]


# ---------------------------------------------------------------------------
# EmbeddingBag (gather + segment-reduce) — the RecSys hot path
# ---------------------------------------------------------------------------


def embedding_bag(
    table: jax.Array,  # [V, D]
    ids: jax.Array,  # [B, K] int32, pad = -1
    weights: jax.Array | None = None,  # [B, K]
    mode: str = "sum",
) -> jax.Array:
    """torch.nn.EmbeddingBag(sum/mean) built from take + masked reduce."""
    ok = (ids >= 0)
    safe = jnp.maximum(ids, 0)
    emb = jnp.take(table, safe, axis=0)  # [B, K, D]
    w = ok.astype(table.dtype)
    if weights is not None:
        w = w * weights.astype(table.dtype)
    out = (emb * w[..., None]).sum(axis=1)
    if mode == "mean":
        out = out / jnp.maximum(ok.sum(axis=1, keepdims=True).astype(table.dtype), 1.0)
    return out


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    model: str  # "fm" | "autoint" | "dcn_v2" | "mind"
    n_sparse: int
    embed_dim: int
    field_vocab: tuple[int, ...]  # per-field vocabulary sizes
    n_dense: int = 0
    # autoint
    n_attn_layers: int = 3
    n_attn_heads: int = 2
    d_attn: int = 32
    # dcn-v2
    n_cross_layers: int = 3
    mlp_dims: tuple[int, ...] = (1024, 1024, 512)
    # mind
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def total_vocab(self) -> int:
        return int(sum(self.field_vocab))

    @property
    def field_offsets(self) -> tuple[int, ...]:
        off, acc = [], 0
        for v in self.field_vocab:
            off.append(acc)
            acc += v
        return tuple(off)

    @property
    def x0_dim(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim

    def param_count(self) -> int:
        d = self.embed_dim
        n = self.total_vocab * d
        if self.model == "fm":
            n += self.total_vocab + 1
        elif self.model == "autoint":
            da = self.d_attn * self.n_attn_heads
            fan = d
            for _ in range(self.n_attn_layers):
                n += 3 * fan * da + fan * da  # qkv + residual proj
                fan = da
            n += self.n_sparse * fan
        elif self.model == "dcn_v2":
            x0 = self.x0_dim
            n += self.n_cross_layers * (x0 * x0 + x0)
            fan = x0
            for m in self.mlp_dims:
                n += fan * m + m
                fan = m
            n += (x0 + self.mlp_dims[-1]) + 1
        elif self.model == "mind":
            n += d * d + self.n_interests * d  # bilinear + interest init
        return n


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_recsys_params(key: jax.Array, cfg: RecsysConfig) -> dict[str, Any]:
    dt = cfg.jdtype
    d = cfg.embed_dim
    keys = jax.random.split(key, 16)
    p: dict[str, Any] = {
        "table": dense_init(keys[0], (cfg.total_vocab, d), dt, scale=0.01),
    }
    if cfg.model == "fm":
        p["w_linear"] = dense_init(keys[1], (cfg.total_vocab,), dt, scale=0.01)
        p["w0"] = jnp.zeros((), dt)
    elif cfg.model == "autoint":
        da, h = cfg.d_attn, cfg.n_attn_heads
        fan = d
        layers = []
        for i in range(cfg.n_attn_layers):
            k = jax.random.split(keys[2 + i], 4)
            layers.append(
                {
                    "wq": dense_init(k[0], (fan, h, da), dt),
                    "wk": dense_init(k[1], (fan, h, da), dt),
                    "wv": dense_init(k[2], (fan, h, da), dt),
                    "w_res": dense_init(k[3], (fan, h * da), dt),
                }
            )
            fan = h * da
        p["attn_layers"] = layers
        p["w_out"] = dense_init(keys[10], (cfg.n_sparse * fan, 1), dt)
    elif cfg.model == "dcn_v2":
        x0 = cfg.x0_dim
        p["cross_w"] = dense_init(keys[2], (cfg.n_cross_layers, x0, x0), dt)
        p["cross_b"] = jnp.zeros((cfg.n_cross_layers, x0), dt)
        mlp = []
        fan = x0
        for i, m in enumerate(cfg.mlp_dims):
            mlp.append(
                {
                    "w": dense_init(jax.random.fold_in(keys[3], i), (fan, m), dt),
                    "b": jnp.zeros((m,), dt),
                }
            )
            fan = m
        p["mlp"] = mlp
        p["w_out"] = dense_init(keys[4], (x0 + cfg.mlp_dims[-1], 1), dt)
    elif cfg.model == "mind":
        p["bilinear"] = dense_init(keys[2], (d, d), dt)
    return p


# ---------------------------------------------------------------------------
# model forwards
# ---------------------------------------------------------------------------


def _field_embeddings(params, cfg: RecsysConfig, sparse_ids: jax.Array) -> jax.Array:
    """[B, F] per-field ids -> [B, F, D] (ids are field-local; offsets added)."""
    off = jnp.asarray(cfg.field_offsets, jnp.int32)
    return jnp.take(params["table"], sparse_ids + off[None, :], axis=0)


def _fm_logit(params, cfg: RecsysConfig, sparse_ids: jax.Array) -> jax.Array:
    """Rendle's O(nk) sum-square trick: ½((Σv)² − Σv²)."""
    off = jnp.asarray(cfg.field_offsets, jnp.int32)
    idx = sparse_ids + off[None, :]
    v = jnp.take(params["table"], idx, axis=0)  # [B, F, K]
    lin = jnp.take(params["w_linear"], idx, axis=0).sum(-1)  # [B]
    s = v.sum(axis=1)  # [B, K]
    pair = 0.5 * (s * s - (v * v).sum(axis=1)).sum(-1)
    return params["w0"] + lin + pair


def _autoint_logit(params, cfg: RecsysConfig, sparse_ids: jax.Array) -> jax.Array:
    x = _field_embeddings(params, cfg, sparse_ids)  # [B, F, D]
    for layer in params["attn_layers"]:
        q = jnp.einsum("bfd,dhk->bfhk", x, layer["wq"])
        k = jnp.einsum("bfd,dhk->bfhk", x, layer["wk"])
        v = jnp.einsum("bfd,dhk->bfhk", x, layer["wv"])
        s = jnp.einsum("bfhk,bghk->bhfg", q, k) / jnp.sqrt(jnp.asarray(cfg.d_attn, x.dtype))
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhfg,bghk->bfhk", a, v)
        o = o.reshape(*o.shape[:2], -1)  # [B, F, H*K]
        x = jax.nn.relu(o + jnp.einsum("bfd,dk->bfk", x, layer["w_res"]))
    flat = x.reshape(x.shape[0], -1)
    return jnp.einsum("bi,io->bo", flat, params["w_out"])[:, 0]


def _dcn_logit(
    params, cfg: RecsysConfig, sparse_ids: jax.Array, dense: jax.Array
) -> jax.Array:
    emb = _field_embeddings(params, cfg, sparse_ids).reshape(sparse_ids.shape[0], -1)
    x0 = jnp.concatenate([dense.astype(emb.dtype), emb], axis=-1)  # [B, X]
    x = x0
    for i in range(cfg.n_cross_layers):
        xw = jnp.einsum("bx,xy->by", x, params["cross_w"][i]) + params["cross_b"][i]
        x = x0 * xw + x  # DCN-v2 cross
    h = x0
    for layer in params["mlp"]:
        h = jax.nn.relu(jnp.einsum("bx,xy->by", h, layer["w"]) + layer["b"])
    cat = jnp.concatenate([x, h], axis=-1)
    return jnp.einsum("bi,io->bo", cat, params["w_out"])[:, 0]


def _squash(x: jax.Array) -> jax.Array:
    n2 = (x * x).sum(-1, keepdims=True)
    return x * (n2 / (1.0 + n2)) / jnp.sqrt(jnp.maximum(n2, 1e-9))


def _mind_interests(params, cfg: RecsysConfig, hist_ids: jax.Array) -> jax.Array:
    """Behavior-to-Interest dynamic routing -> [B, n_interests, D]."""
    e = embedding_bag_gather(params["table"], hist_ids)  # [B, T, D] w/ mask 0
    mask = (hist_ids >= 0).astype(e.dtype)[..., None]
    eh = jnp.einsum("btd,de->bte", e, params["bilinear"]) * mask
    b = jnp.zeros((*hist_ids.shape, cfg.n_interests), e.dtype)  # routing logits
    for _ in range(cfg.capsule_iters):  # static unroll (§MIND routing)
        c = jax.nn.softmax(b, axis=-1) * mask  # [B, T, I]
        s = jnp.einsum("bti,btd->bid", c, eh)
        u = _squash(s)  # [B, I, D]
        b = b + jnp.einsum("bid,btd->bti", u, eh)
    return u


def embedding_bag_gather(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Masked gather (pad = -1 -> zero rows); keeps the T axis."""
    ok = (ids >= 0)[..., None]
    return jnp.take(table, jnp.maximum(ids, 0), axis=0) * ok.astype(table.dtype)


# ---------------------------------------------------------------------------
# public API: loss / score / retrieval
# ---------------------------------------------------------------------------


def recsys_score(params, batch: dict[str, jax.Array], cfg: RecsysConfig) -> jax.Array:
    if cfg.model == "fm":
        return _fm_logit(params, cfg, batch["sparse_ids"])
    if cfg.model == "autoint":
        return _autoint_logit(params, cfg, batch["sparse_ids"])
    if cfg.model == "dcn_v2":
        return _dcn_logit(params, cfg, batch["sparse_ids"], batch["dense"])
    if cfg.model == "mind":
        interests = _mind_interests(params, cfg, batch["hist_ids"])  # [B, I, D]
        target = jnp.take(params["table"], batch["target_id"], axis=0)  # [B, D]
        return jnp.einsum("bid,bd->bi", interests, target).max(axis=-1)
    raise ValueError(cfg.model)


def recsys_loss(params, batch: dict[str, jax.Array], cfg: RecsysConfig) -> tuple[jax.Array, dict]:
    if cfg.model == "mind":
        # in-batch sampled softmax with label-aware attention (p=2)
        interests = _mind_interests(params, cfg, batch["hist_ids"])
        targets = jnp.take(params["table"], batch["target_id"], axis=0)  # [B, D]
        att = jax.nn.softmax(
            2.0 * jnp.einsum("bid,bd->bi", interests, targets), axis=-1
        )
        user = jnp.einsum("bi,bid->bd", att, interests)  # [B, D]
        logits = jnp.einsum("bd,cd->bc", user, targets)  # in-batch negatives
        labels = jnp.arange(user.shape[0])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        loss = (logz - gold).mean()
        return loss, {"loss": loss}
    logit = recsys_score(params, batch, cfg).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logit, 0.0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )
    return loss, {"loss": loss}


def recsys_retrieval_score(
    params, batch: dict[str, jax.Array], cfg: RecsysConfig
) -> jax.Array:
    """One context vs n_candidates as one batched forward (no loops).

    ``batch["cand_ids"]``: [C] candidate item ids (field 0 for CTR models).
    """
    cand = batch["cand_ids"]  # [C]
    if cfg.model == "mind":
        interests = _mind_interests(params, cfg, batch["hist_ids"])  # [1, I, D]
        cand_emb = jnp.take(params["table"], cand, axis=0)  # [C, D]
        return jnp.einsum("bid,cd->bci", interests, cand_emb).max(axis=-1)[0]
    # CTR models: broadcast the context row across candidates (item = field 0)
    ctx = batch["sparse_ids"]  # [1, F]
    c = cand.shape[0]
    ids = jnp.broadcast_to(ctx, (c, ctx.shape[1])).at[:, 0].set(cand)
    b2 = {"sparse_ids": ids}
    if cfg.model == "dcn_v2":
        b2["dense"] = jnp.broadcast_to(batch["dense"], (c, batch["dense"].shape[1]))
    return recsys_score(params, b2, cfg)
