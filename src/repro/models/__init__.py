from . import gnn, layers, moe, recsys, transformer

__all__ = ["layers", "transformer", "moe", "gnn", "recsys"]
