"""Shared transformer layers: RMSNorm, RoPE, GQA attention (dense, chunked
flash-style, sliding-window, decode), SwiGLU MLP.

Everything is a pure function over explicit param pytrees; layer stacks are
*stacked* along a leading axis and driven by ``jax.lax.scan`` so the HLO (and
compile time on a 512-device mesh) stays one-layer-sized.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "rope",
    "flash_attention",
    "decode_attention",
    "swiglu",
    "dense_init",
]

Params = dict[str, Any]


def dense_init(key: jax.Array, shape: tuple[int, ...], dtype=jnp.bfloat16, scale: float | None = None) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * gamma.astype(jnp.float32)).astype(dtype)


def _rope_angles(positions: jax.Array, d_head: int, theta: float) -> tuple[jax.Array, jax.Array]:
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    cos, sin = _rope_angles(positions, d, theta)  # [..., S, half]
    cos = cos[..., None, :]  # head axis
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def flash_attention(
    q: jax.Array,  # [B, Sq, Hq, D]
    k: jax.Array,  # [B, Skv, Hkv, D]
    v: jax.Array,  # [B, Skv, Hkv, D]
    *,
    causal: bool = True,
    q_offset: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    sliding_window: int | None = None,
    remat_qblock: bool = False,
) -> jax.Array:
    """Online-softmax (FlashAttention-style) chunked attention in pure JAX.

    Double ``lax.scan`` over query and KV chunks keeps the peak score tile at
    ``[B, H, q_chunk, kv_chunk]`` — the memory-roofline lever for the 32k
    prefill shapes.  ``sliding_window`` masks keys older than the window (the
    sub-quadratic long-context mode; with it, whole KV chunks that fall out
    of every query's window contribute zeros and XLA's masking keeps the
    cost, while a real deployment also skips their HBM reads — see
    DESIGN.md §6).

    GQA handling under tensor parallelism (EXPERIMENTS.md §Perf-4): queries
    keep the FLAT head layout [B, S, Hq, D] (Hq divides the TP axis for every
    assigned arch; a grouped [Hkv, n_rep] layout divides for none of the
    GQA ones and forces GSPMD reshards every layer).  KV heads are expanded
    per *tile* with a constant-index ``take`` — on a replicated or aligned KV
    tensor this is local lane duplication, never a collective, and the
    full-sequence repeated KV is never materialized.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    n_rep = hq // hkv
    scale = d ** -0.5
    head_map = jnp.arange(hq) // n_rep  # q head -> kv head

    import math

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    if sq % q_chunk:  # degrade to the largest divisor (odd smoke shapes)
        q_chunk = math.gcd(sq, q_chunk)
    if skv % kv_chunk:
        kv_chunk = math.gcd(skv, kv_chunk)
    nq, nkv = sq // q_chunk, skv // kv_chunk
    qr = q.reshape(b, nq, q_chunk, hq, d).transpose(1, 0, 3, 2, 4)  # [nq,B,Hq,Cq,D]
    kr = k.reshape(b, nkv, kv_chunk, hkv, d).transpose(1, 0, 3, 2, 4)  # [nkv,B,Hkv,Ckv,D]
    vr = v.reshape(b, nkv, kv_chunk, hkv, d).transpose(1, 0, 3, 2, 4)

    q_pos_base = jnp.arange(q_chunk)
    kv_pos_base = jnp.arange(kv_chunk)

    def tile_update(carry, q_tile, q_pos, k_tile, v_tile, ki):
        """One online-softmax tile: [B,Hq,Cq] stats + [B,Hq,Cq,D] acc."""
        m, l, acc = carry
        if n_rep > 1:  # tile-local KV head expansion (no collective)
            k_tile = jnp.take(k_tile, head_map, axis=1)
            v_tile = jnp.take(v_tile, head_map, axis=1)
        kv_pos = ki * kv_chunk + kv_pos_base
        s = jnp.einsum(
            "bhqd,bhkd->bhqk", q_tile, k_tile,
            preferred_element_type=jnp.float32,
        ) * scale  # [B, Hq, Cq, Ckv]
        mask = jnp.ones((q_chunk, kv_chunk), jnp.bool_)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if sliding_window is not None:
            mask &= kv_pos[None, :] > q_pos[:, None] - sliding_window
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(v_tile.dtype), v_tile,
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    def init_carry():
        return (
            jnp.full((b, hq, q_chunk), -jnp.inf, jnp.float32),
            jnp.zeros((b, hq, q_chunk), jnp.float32),
            jnp.zeros((b, hq, q_chunk, d), jnp.float32),
        )

    def q_block(qi, q_tile):
        q_pos = q_offset + qi * q_chunk + q_pos_base  # absolute positions

        def kv_block(carry, inp):
            ki, k_tile, v_tile = inp
            return tile_update(carry, q_tile, q_pos, k_tile, v_tile, ki), None

        (m, l, acc), _ = jax.lax.scan(
            kv_block, init_carry(), (jnp.arange(nkv), kr, vr)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)

    def paired_blocks(pi, q_lo, q_hi):
        """Causal load balancing (§Perf-4): q-block pi pairs with nq-1-pi;
        together they need exactly nq+1 kv tiles, so below-diagonal tiles
        are never computed — attention FLOPs drop to the causal S^2/2."""
        qi_lo = pi
        qi_hi = nq - 1 - pi
        pos_lo = q_offset + qi_lo * q_chunk + q_pos_base
        pos_hi = q_offset + qi_hi * q_chunk + q_pos_base

        def step(carry, s):
            c_lo, c_hi = carry
            use_lo = s <= qi_lo
            ki = jnp.where(use_lo, jnp.minimum(s, qi_lo), s - (qi_lo + 1))
            k_tile = jnp.take(kr, ki, axis=0)
            v_tile = jnp.take(vr, ki, axis=0)
            q_tile = jnp.where(use_lo, q_lo, q_hi)
            q_pos = jnp.where(use_lo, pos_lo, pos_hi)
            upd = tile_update(
                jax.tree.map(lambda a, bb: jnp.where(use_lo, a, bb), c_lo, c_hi),
                q_tile, q_pos, k_tile, v_tile, ki,
            )
            c_lo2 = jax.tree.map(lambda old, new: jnp.where(use_lo, new, old), c_lo, upd)
            c_hi2 = jax.tree.map(lambda old, new: jnp.where(use_lo, old, new), c_hi, upd)
            return (c_lo2, c_hi2), None

        (c_lo, c_hi), _ = jax.lax.scan(
            step, (init_carry(), init_carry()), jnp.arange(nq + 1)
        )
        outs = []
        for m, l, acc in (c_lo, c_hi):
            outs.append((acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype))
        return outs[0], outs[1]

    # the pairing walks kv tiles in q-chunk units -> chunk sizes must match
    balanced = (
        causal and sliding_window is None
        and nq >= 2 and nq % 2 == 0 and q_chunk == kv_chunk
    )
    if balanced:
        half = nq // 2
        q_lo_stack = qr[:half]
        q_hi_stack = qr[nq - 1 : half - 1 : -1] if half >= 1 else qr[:0]
        pair_fn = jax.checkpoint(paired_blocks) if remat_qblock else paired_blocks
        out_lo, out_hi = jax.lax.map(
            lambda t: pair_fn(t[0], t[1], t[2]),
            (jnp.arange(half), q_lo_stack, q_hi_stack),
        )
        out = jnp.concatenate([out_lo, out_hi[::-1]], axis=0)
    else:
        block = jax.checkpoint(q_block) if remat_qblock else q_block
        out = jax.lax.map(lambda t: block(t[0], t[1]), (jnp.arange(nq), qr))
    # [nq, B, Hq, Cq, D] -> [B, Sq, Hq, D]
    return out.transpose(1, 0, 3, 2, 4).reshape(b, sq, hq, d)


def decode_attention(
    q: jax.Array,  # [B, 1, Hq, D]
    k_cache: jax.Array,  # [B, S, Hkv, D]
    v_cache: jax.Array,  # [B, S, Hkv, D]
    cache_len: jax.Array | int,  # valid prefix length
    sliding_window: int | None = None,
) -> jax.Array:
    """Single-token attention against a KV cache (serve_step).

    GQA-aware (no repeated-KV materialization): with the cache Dh-sharded on
    the model axis, the only collective left is the per-layer score psum —
    ~[B,Hkv,r,S] fp32 — instead of an all-gather of the whole cache."""
    b, s, hkv, d = k_cache.shape
    hq = q.shape[2]
    n_rep = hq // hkv
    q2 = q.reshape(b, hkv, n_rep, d)
    scale = d ** -0.5
    scores = jnp.einsum(
        "bhrd,bshd->bhrs", q2, k_cache, preferred_element_type=jnp.float32
    ) * scale  # [B, Hkv, r, S]
    pos = jnp.arange(s)
    mask = pos[None, :] < jnp.asarray(cache_len).reshape(-1, 1)
    if sliding_window is not None:
        mask &= pos[None, :] >= jnp.asarray(cache_len).reshape(-1, 1) - sliding_window
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum(
        "bhrs,bshd->bhrd", p, v_cache, preferred_element_type=jnp.float32
    )
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, w_gate, preferred_element_type=jnp.float32)
    u = jnp.einsum("bsd,df->bsf", x, w_up, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, w_down, preferred_element_type=jnp.float32).astype(x.dtype)
