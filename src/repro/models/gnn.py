"""Graph attention network (GAT, Veličković et al. 2018) in segment-op JAX.

JAX has no CSR SpMM — message passing is built (as required) from
``jax.ops.segment_sum`` / ``segment_max`` over an edge list:

    SDDMM  (edge scores)  -> gather src/dst + add          (attention logits)
    edge-softmax          -> segment_max / segment_sum     (per-destination)
    SpMM   (aggregate)    -> weighted gather + segment_sum

Supports node classification (Cora / Reddit-minibatch / ogbn-products) and
graph classification (batched small molecules) through ``task``; padded
edges/nodes carry a mask so every shape is static (shard_map-friendly:
edges shard across devices, partial segment sums psum into node space).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = ["GATConfig", "init_gat_params", "gat_forward", "gat_loss"]


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_feat: int = 1433
    n_classes: int = 7
    task: str = "node"  # "node" | "graph"
    negative_slope: float = 0.2
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def layer_dims(self) -> list[tuple[int, int]]:
        """(fan_in, out_per_head) per layer; last layer maps to classes."""
        dims = []
        fan_in = self.d_feat
        for i in range(self.n_layers - 1):
            dims.append((fan_in, self.d_hidden))
            fan_in = self.d_hidden * self.n_heads
        dims.append((fan_in, self.n_classes))
        return dims

    def param_count(self) -> int:
        total = 0
        for fi, do in self.layer_dims():
            total += fi * self.n_heads * do + 2 * self.n_heads * do
        return total


def init_gat_params(key: jax.Array, cfg: GATConfig) -> list[dict[str, Any]]:
    dt = cfg.jdtype
    params = []
    for i, (fi, do) in enumerate(cfg.layer_dims()):
        k1, k2, k3, key = jax.random.split(key, 4)
        params.append(
            {
                "w": dense_init(k1, (fi, cfg.n_heads, do), dt),
                "a_src": dense_init(k2, (cfg.n_heads, do), dt),
                "a_dst": dense_init(k3, (cfg.n_heads, do), dt),
            }
        )
    return params


def _gat_layer(
    x: jax.Array,  # [N, F]
    layer: dict[str, Any],
    src: jax.Array,  # [E] int32 (padded edges -> 0 with mask 0)
    dst: jax.Array,  # [E]
    edge_mask: jax.Array,  # [E] 0/1
    n_nodes: int,
    *,
    negative_slope: float,
    final: bool,
) -> jax.Array:
    h = jnp.einsum("nf,fhd->nhd", x, layer["w"])  # [N, H, D]
    al_src = (h * layer["a_src"][None]).sum(-1)  # [N, H]
    al_dst = (h * layer["a_dst"][None]).sum(-1)
    e = al_src[src] + al_dst[dst]  # SDDMM: [E, H]
    e = jax.nn.leaky_relu(e, negative_slope)
    neg = jnp.asarray(-1e9, e.dtype)
    e = jnp.where(edge_mask[:, None] > 0, e, neg)
    # segment softmax over incoming edges of each destination
    m = jax.ops.segment_max(e, dst, num_segments=n_nodes)  # [N, H]
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    ex = jnp.exp(e - m[dst]) * edge_mask[:, None]
    denom = jax.ops.segment_sum(ex, dst, num_segments=n_nodes)  # [N, H]
    msg = jax.ops.segment_sum(ex[..., None] * h[src], dst, num_segments=n_nodes)
    out = msg / jnp.maximum(denom[..., None], 1e-9)  # [N, H, D]
    if final:
        return out.mean(axis=1)  # average heads -> [N, n_classes]
    n = out.shape[0]
    return jax.nn.elu(out.reshape(n, -1))  # concat heads


def gat_forward(
    params, batch: dict[str, jax.Array], cfg: GATConfig, n_graphs: int = 1
) -> jax.Array:
    """batch: {x [N,F], src [E], dst [E], edge_mask [E], (graph_ids [N])}."""
    x = batch["x"].astype(cfg.jdtype)
    n_nodes = x.shape[0]
    for i, layer in enumerate(params):
        x = _gat_layer(
            x, layer, batch["src"], batch["dst"], batch["edge_mask"], n_nodes,
            negative_slope=cfg.negative_slope,
            final=(i == len(params) - 1),
        )
    if cfg.task == "graph":
        # mean-pool node logits per graph (batched small molecules)
        gid = batch["graph_ids"]  # [N]
        num = jax.ops.segment_sum(x, gid, num_segments=n_graphs)
        cnt = jax.ops.segment_sum(jnp.ones((n_nodes, 1), x.dtype), gid, num_segments=n_graphs)
        return num / jnp.maximum(cnt, 1.0)
    return x  # [N, n_classes]


def gat_loss(
    params, batch: dict[str, jax.Array], cfg: GATConfig, n_graphs: int = 1
) -> tuple[jax.Array, dict]:
    logits = gat_forward(params, batch, cfg, n_graphs=n_graphs).astype(jnp.float32)
    labels = batch["labels"]
    mask = batch["label_mask"].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    acc = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    acc = (acc * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"acc": acc}
