"""Decoder-only transformer family (dense / GQA / MoE / sliding-window).

One code path serves all five assigned LM architectures; layer stacks are
scanned (stacked params, one-layer HLO) and optionally remat'd, attention is
chunked online-softmax (``layers.flash_attention``) so 32k prefill fits, and
the KV cache supports both full and sliding-window (sub-quadratic) modes.

Step functions (lowered by the dry-run):
  * ``loss_fn``       — next-token cross-entropy (+ MoE aux), for train_4k
  * ``prefill_step``  — full-sequence forward, emits the KV cache + last logits
  * ``decode_step``   — one token against a KV cache, for decode_32k/long_500k
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .layers import decode_attention, dense_init, flash_attention, rms_norm, rope
from .moe import MoEConfig, init_moe_params, moe_ffn

__all__ = ["TransformerConfig", "init_params", "forward", "loss_fn", "prefill_step", "decode_step"]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    moe: MoEConfig | None = None
    moe_interleave: int = 1  # 2 = alternate dense/MoE FFNs (Llama-4 style)
    rope_theta: float = 10000.0
    sliding_window: int | None = None
    remat: bool = True
    remat_block: int = 1  # sqrt-remat: checkpoint every `remat_block` layers
    microbatches: int = 1  # gradient-accumulation chunks per train step
    fsdp: bool = False  # ZeRO-3: params+opt sharded over `data`, gathered per layer
    q_chunk: int = 1024
    kv_chunk: int = 1024
    aux_loss_weight: float = 0.01
    dtype: str = "bfloat16"

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_moe_layers(self) -> int:
        if self.moe is None:
            return 0
        return self.n_layers // self.moe_interleave

    def _attn_params(self) -> int:
        d, dh = self.d_model, self.d_head
        return d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh + self.n_heads * dh * d

    def param_count(self) -> int:
        """Total parameters N (for MODEL_FLOPS = 6*N*D bookkeeping)."""
        d = self.d_model
        attn = self._attn_params()
        n_moe = self.n_moe_layers
        n_dense = self.n_layers - n_moe
        ffn_dense = 3 * d * self.d_ff
        total = n_dense * (attn + ffn_dense + 2 * d)
        if self.moe is not None:
            ffn_moe = 3 * d * self.moe.d_ff * self.moe.n_experts + d * self.moe.n_experts
            if self.moe.n_shared_experts:
                ffn_moe += 3 * d * self.moe.d_ff * self.moe.n_shared_experts
            total += n_moe * (attn + ffn_moe + 2 * d)
        return total + 2 * self.vocab * d + d

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        attn = self._attn_params()
        n_moe = self.n_moe_layers
        n_dense = self.n_layers - n_moe
        ffn_moe = 3 * d * self.moe.d_ff * (self.moe.top_k + self.moe.n_shared_experts)
        total = n_dense * (attn + 3 * d * self.d_ff + 2 * d)
        total += n_moe * (attn + ffn_moe + 2 * d)
        return total + 2 * self.vocab * d + d


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key: jax.Array, cfg: TransformerConfig, use_moe: bool) -> dict[str, Any]:
    dt = cfg.jdtype
    d, dh = cfg.d_model, cfg.d_head
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {
        "ln1": jnp.ones((d,), dt),
        "ln2": jnp.ones((d,), dt),
        "wq": dense_init(ks[0], (d, cfg.n_heads * dh), dt),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * dh), dt),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * dh), dt),
        "wo": dense_init(ks[3], (cfg.n_heads * dh, d), dt),
    }
    if use_moe:
        p["moe"] = init_moe_params(ks[4], d, cfg.moe, dt)
    else:
        p["ffn"] = {
            "w_gate": dense_init(ks[5], (d, cfg.d_ff), dt),
            "w_up": dense_init(ks[6], (d, cfg.d_ff), dt),
            "w_down": dense_init(ks[7], (cfg.d_ff, d), dt),
        }
    return p


def _interleaved(cfg: TransformerConfig) -> bool:
    return cfg.moe is not None and cfg.moe_interleave > 1


def _tp_size() -> int:
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names or "model" not in mesh.axis_names:
        return 1
    return dict(mesh.shape)["model"]


def init_params(key: jax.Array, cfg: TransformerConfig) -> dict[str, Any]:
    dt = cfg.jdtype
    k_emb, k_un, k_layers = jax.random.split(key, 3)
    if _interleaved(cfg):
        # blocks of (dense layer, moe layer), scanned homogeneously
        n_blocks = cfg.n_layers // cfg.moe_interleave
        bkeys = jax.random.split(k_layers, n_blocks)
        layers = jax.vmap(
            lambda k: {
                "dense_sub": _init_layer(jax.random.fold_in(k, 0), cfg, use_moe=False),
                "moe_sub": _init_layer(jax.random.fold_in(k, 1), cfg, use_moe=True),
            }
        )(bkeys)
    else:
        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        layers = jax.vmap(lambda k: _init_layer(k, cfg, use_moe=cfg.moe is not None))(layer_keys)
    return {
        "embed": dense_init(k_emb, (cfg.vocab, cfg.d_model), dt, scale=0.02),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "unembed": dense_init(k_un, (cfg.d_model, cfg.vocab), dt),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _qkv(h: jax.Array, layer, cfg: TransformerConfig, positions: jax.Array):
    b, s, d = h.shape
    dh = cfg.d_head
    x = rms_norm(h, layer["ln1"])
    q = jnp.einsum("bsd,dk->bsk", x, layer["wq"]).reshape(b, s, cfg.n_heads, dh)
    k = jnp.einsum("bsd,dk->bsk", x, layer["wk"]).reshape(b, s, cfg.n_kv_heads, dh)
    v = jnp.einsum("bsd,dk->bsk", x, layer["wv"]).reshape(b, s, cfg.n_kv_heads, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(x: jax.Array, layer, cfg: TransformerConfig):
    if "moe" in layer:
        return moe_ffn(x, layer["moe"], cfg.moe)
    f = layer["ffn"]
    g = jnp.einsum("bsd,df->bsf", x, f["w_gate"], preferred_element_type=jnp.float32)
    u = jnp.einsum("bsd,df->bsf", x, f["w_up"], preferred_element_type=jnp.float32)
    # w_down crosses the TP boundary: keep the output (= the all-reduce
    # payload) in bf16 — §Perf-4
    y = jnp.einsum("bsf,fd->bsd", (jax.nn.silu(g) * u).astype(x.dtype), f["w_down"])
    return y, jnp.zeros((), jnp.float32)


def _layer_fwd(h: jax.Array, layer, cfg: TransformerConfig, positions: jax.Array):
    b, s, d = h.shape
    if cfg.fsdp:
        from ..parallel.sharding import fsdp_gather_layer

        tp = _tp_size()
        layer = fsdp_gather_layer(layer, kv_shardable=(cfg.n_kv_heads % tp == 0))
    q, k, v = _qkv(h, layer, cfg, positions)
    attn = flash_attention(
        q, k, v,
        causal=True,
        q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk,
        sliding_window=cfg.sliding_window,
    ).reshape(b, s, cfg.n_heads * cfg.d_head)
    h = h + jnp.einsum("bsk,kd->bsd", attn, layer["wo"]).astype(h.dtype)
    x = rms_norm(h, layer["ln2"])
    y, aux = _ffn(x, layer, cfg)
    return h + y, (k, v), aux


def forward(params, tokens: jax.Array, cfg: TransformerConfig, collect_cache: bool = False):
    """tokens [B, S] -> (hidden [B, S, D], aux, optional cache [L,B,S,Hkv,Dh]x2)."""
    b, s = tokens.shape
    positions = jnp.arange(s)[None, :]
    h = jnp.take(params["embed"], tokens, axis=0)
    interleaved = _interleaved(cfg)

    def body(carry, layer):
        hh, aux = carry
        if interleaved:
            hh, kv1, a1 = _layer_fwd(hh, layer["dense_sub"], cfg, positions)
            hh, kv2, a2 = _layer_fwd(hh, layer["moe_sub"], cfg, positions)
            a = a1 + a2
            kv = (jnp.stack([kv1[0], kv2[0]]), jnp.stack([kv1[1], kv2[1]]))
        else:
            hh, kv, a = _layer_fwd(hh, layer, cfg, positions)
        ys = kv if collect_cache else None
        return (hh, aux + a), ys

    layers = params["layers"]
    rb = max(1, cfg.remat_block)
    n_stack = jax.tree.leaves(layers)[0].shape[0]
    if cfg.remat and rb > 1 and n_stack % rb == 0:
        # sqrt-remat (EXPERIMENTS.md §Perf-4): checkpoint BLOCKS of rb
        # layers — the bwd residual footprint drops from n_stack
        # activations to n_stack/rb, at one extra fwd per block
        grouped = jax.tree.map(
            lambda x: x.reshape(n_stack // rb, rb, *x.shape[1:]), layers
        )

        def block_body(carry, block):
            def inner(c, layer):
                return body(c, layer)

            c2, ys = jax.lax.scan(inner, carry, block)
            return c2, ys

        scan_body = jax.checkpoint(block_body)
        (h, aux), kv = jax.lax.scan(
            scan_body, (h, jnp.zeros((), jnp.float32)), grouped
        )
        if collect_cache:
            kv = tuple(x.reshape(n_stack, *x.shape[2:]) for x in kv)
    else:
        scan_body = jax.checkpoint(body) if cfg.remat else body
        (h, aux), kv = jax.lax.scan(
            scan_body, (h, jnp.zeros((), jnp.float32)), layers
        )
    if collect_cache and interleaved:
        # [nb, 2, B, S, H, Dh] -> [L, B, S, H, Dh]
        kv = tuple(x.reshape(cfg.n_layers, *x.shape[2:]) for x in kv)
    h = rms_norm(h, params["final_norm"])
    return h, aux, kv


def loss_fn(params, batch: dict[str, jax.Array], cfg: TransformerConfig) -> tuple[jax.Array, dict]:
    """Next-token cross-entropy; ``batch`` = {"tokens", "targets", "mask"}."""
    h, aux, _ = forward(params, batch["tokens"], cfg)
    logits = jnp.einsum(
        "bsd,dv->bsv", h, params["unembed"], preferred_element_type=jnp.float32
    )
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["targets"][..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch["mask"].astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss + cfg.aux_loss_weight * aux
    return total, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill_step(params, tokens: jax.Array, cfg: TransformerConfig):
    """Full-sequence forward; returns (last-token logits [B,V], kv cache)."""
    h, _, kv = forward(params, tokens, cfg, collect_cache=True)
    logits = jnp.einsum(
        "bd,dv->bv", h[:, -1], params["unembed"], preferred_element_type=jnp.float32
    )
    k_cache, v_cache = kv  # each [L, B, S, Hkv, Dh]
    return logits, {"k": k_cache, "v": v_cache}


def _sublayer_decode(hh, layer, kc, vc, cfg: TransformerConfig, positions, cache_len):
    b = hh.shape[0]
    dh = cfg.d_head
    q, k, v = _qkv(hh, layer, cfg, positions)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, cache_len, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, cache_len, 0, 0))
    w = cfg.sliding_window
    s_cache = kc.shape[1]
    if w is not None and s_cache > w:
        # sub-quadratic long-context decode: attend only over the last
        # window — O(w) compute against an O(S) cache (DESIGN.md §6)
        start = jnp.clip(cache_len + 1 - w, 0, s_cache - w)
        k_att = jax.lax.dynamic_slice(kc, (0, start, 0, 0), (kc.shape[0], w, *kc.shape[2:]))
        v_att = jax.lax.dynamic_slice(vc, (0, start, 0, 0), (vc.shape[0], w, *vc.shape[2:]))
        valid = jnp.minimum(cache_len + 1, w)
        attn = decode_attention(q, k_att, v_att, valid)
    else:
        attn = decode_attention(q, kc, vc, cache_len + 1, sliding_window=w)
    attn = attn.reshape(b, 1, cfg.n_heads * dh)
    hh = hh + jnp.einsum("bsk,kd->bsd", attn, layer["wo"]).astype(hh.dtype)
    x = rms_norm(hh, layer["ln2"])
    y, _ = _ffn(x, layer, cfg)
    return hh + y, kc, vc


def decode_step(
    params,
    cache: dict[str, jax.Array],  # {"k","v"}: [L, B, S, Hkv, Dh]
    tokens: jax.Array,  # [B, 1]
    cache_len: jax.Array,  # scalar int32: filled prefix length
    cfg: TransformerConfig,
):
    """One new token with a KV cache of length ``cache_len`` (serve_step)."""
    b = tokens.shape[0]
    positions = jnp.full((b, 1), cache_len, jnp.int32)
    h = jnp.take(params["embed"], tokens, axis=0)  # [B, 1, D]
    interleaved = _interleaved(cfg)

    k_in, v_in = cache["k"], cache["v"]
    if interleaved:
        nb = cfg.n_layers // cfg.moe_interleave
        k_in = k_in.reshape(nb, 2, *k_in.shape[1:])
        v_in = v_in.reshape(nb, 2, *v_in.shape[1:])

    def body(hh, xs):
        layer, kc, vc = xs
        if interleaved:
            hh, kc0, vc0 = _sublayer_decode(
                hh, layer["dense_sub"], kc[0], vc[0], cfg, positions, cache_len
            )
            hh, kc1, vc1 = _sublayer_decode(
                hh, layer["moe_sub"], kc[1], vc[1], cfg, positions, cache_len
            )
            return hh, (jnp.stack([kc0, kc1]), jnp.stack([vc0, vc1]))
        hh, kc, vc = _sublayer_decode(hh, layer, kc, vc, cfg, positions, cache_len)
        return hh, (kc, vc)

    h, (k_new, v_new) = jax.lax.scan(body, h, (params["layers"], k_in, v_in))
    if interleaved:
        k_new = k_new.reshape(cfg.n_layers, *k_new.shape[2:])
        v_new = v_new.reshape(cfg.n_layers, *v_new.shape[2:])
    h = rms_norm(h, params["final_norm"])
    logits = jnp.einsum(
        "bd,dv->bv", h[:, -1], params["unembed"], preferred_element_type=jnp.float32
    )
    return logits, {"k": k_new, "v": v_new}
