from .checkpoint import (
    CheckpointManager,
    fsync_json,
    latest_numbered,
    replace_dir,
    restore_checkpoint,
    retain_latest,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager",
    "save_checkpoint",
    "restore_checkpoint",
    "fsync_json",
    "replace_dir",
    "retain_latest",
    "latest_numbered",
]
