from .checkpoint import (
    CheckpointManager,
    append_durable,
    fsync_json,
    latest_numbered,
    replace_dir,
    restore_checkpoint,
    retain_latest,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager",
    "save_checkpoint",
    "restore_checkpoint",
    "fsync_json",
    "append_durable",
    "replace_dir",
    "retain_latest",
    "latest_numbered",
]
