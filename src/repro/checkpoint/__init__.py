from .checkpoint import CheckpointManager, save_checkpoint, restore_checkpoint

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint"]
