"""Fault-tolerant checkpointing.

Design points (1000+-node deployments):

* **Logical layout, not device layout** — checkpoints store flat arrays plus
  the pytree structure and the *PartitionSpec* strings.  Restore re-shards to
  whatever mesh the job comes back with (elastic re-shard: a 512-chip job can
  resume on 256 chips).
* **Atomicity** — writes go to ``step_N.tmp/`` and are renamed only after the
  manifest fsyncs; a crash mid-write never corrupts the latest checkpoint.
* **Double buffering / retention** — keep the last ``keep`` checkpoints;
  deletion only after a newer one is durable.
* **Async** — ``save_async`` snapshots to host memory (device_get) on the
  training thread, then writes on a background thread so the step loop only
  blocks for the copy, not the I/O.
* **Data-pipeline state** — the sampler/shard cursor is part of the payload,
  so restarts are bit-identical (no skipped or repeated batches).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable

import numpy as np

import jax

__all__ = ["save_checkpoint", "restore_checkpoint", "CheckpointManager"]

_MANIFEST = "manifest.json"


def _flatten(tree: Any) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def save_checkpoint(directory: str | Path, step: int, payload: Any, keep: int = 3) -> Path:
    """Atomic synchronous save of an arbitrary pytree ``payload``."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"step_{step}.tmp"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten(payload)
    np.savez(tmp / "arrays.npz", **{f"a{i}": l for i, l in enumerate(leaves)})
    meta = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "time": time.time(),
        "leaf_shapes": [list(l.shape) for l in leaves],
        "leaf_dtypes": [str(l.dtype) for l in leaves],
    }
    with open(tmp / _MANIFEST, "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic on POSIX
    _gc(directory, keep)
    return final


def _gc(directory: Path, keep: int) -> None:
    steps = sorted(
        (int(p.name.split("_")[1]), p)
        for p in directory.glob("step_*")
        if p.is_dir() and not p.name.endswith(".tmp")
    )
    for _, p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    steps = [
        int(p.name.split("_")[1])
        for p in directory.glob("step_*")
        if p.is_dir() and not p.name.endswith(".tmp") and (p / _MANIFEST).exists()
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str | Path,
    template: Any,
    step: int | None = None,
    shardings: Any | None = None,
) -> tuple[Any, int] | None:
    """Restore into the structure of ``template``; optionally re-shard with
    ``shardings`` (a pytree of NamedSharding for the *current* mesh —
    elastic resume)."""
    directory = Path(directory)
    step = step if step is not None else latest_step(directory)
    if step is None:
        return None
    path = directory / f"step_{step}"
    with np.load(path / "arrays.npz") as z:
        arrays = [z[f"a{i}"] for i in range(len(z.files))]
    _, treedef = jax.tree.flatten(template)
    restored = jax.tree.unflatten(treedef, arrays)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else jax.device_put(a),
            restored,
            shardings,
        )
    return restored, step


class CheckpointManager:
    """Async double-buffered manager with restart-counter bookkeeping."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save_async(self, step: int, payload: Any) -> None:
        self.wait()  # one in flight at a time (double buffering)
        host = jax.tree.map(np.asarray, jax.device_get(payload))

        def _write():
            try:
                save_checkpoint(self.directory, step, host, keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, template: Any, shardings: Any | None = None):
        return restore_checkpoint(self.directory, template, shardings=shardings)
