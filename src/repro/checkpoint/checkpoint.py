"""Fault-tolerant checkpointing and durable-write primitives (DESIGN.md §12.4).

Design points (1000+-node deployments):

* **Logical layout, not device layout** — checkpoints store flat arrays plus
  the pytree structure and the *PartitionSpec* strings.  Restore re-shards to
  whatever mesh the job comes back with (elastic re-shard: a 512-chip job can
  resume on 256 chips).
* **Atomicity** — writes go to ``<name>.tmp/`` and are renamed only after the
  manifest fsyncs; a crash mid-write never corrupts the latest checkpoint.
  The write/rename/retention primitives (``fsync_json``, ``replace_dir``,
  ``retain_latest``, ``latest_numbered``) are shared with the durable index
  store (``index/store.py``, DESIGN.md §12), so both subsystems have ONE
  crash-safety story.
* **Double buffering / retention** — keep the last ``keep`` checkpoints;
  deletion only after a newer one is durable.
* **Async** — ``save_async`` snapshots to host memory (device_get) on the
  training thread, then writes on a background thread so the step loop only
  blocks for the copy, not the I/O.
* **Data-pipeline state** — the sampler/shard cursor is part of the payload,
  so restarts are bit-identical (no skipped or repeated batches).

Exactness contract: ``restore_checkpoint(save_checkpoint(payload))`` returns
arrays bit-identical to the saved host copies; restarts resume the data
pipeline bit-identically (no skipped or repeated batches).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable

import numpy as np

import jax

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "CheckpointManager",
    "fsync_json",
    "replace_dir",
    "retain_latest",
    "latest_numbered",
]

_MANIFEST = "manifest.json"


# ---------------------------------------------------------------------------
# durable-write primitives (shared with index/store.py — DESIGN.md §12.4)
# ---------------------------------------------------------------------------


def fsync_json(path: str | Path, obj: Any) -> None:
    """Dump ``obj`` as JSON and fsync before returning (DESIGN.md §12.4).

    The manifest fsync is the durability point of every atomic directory
    write: once it returns, a rename of the enclosing directory publishes a
    complete, self-consistent artifact.
    """
    with open(path, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())


def append_durable(path: str | Path, data: bytes) -> int:
    """Append ``data`` to ``path`` and fsync before returning (DESIGN.md
    §12.4 / §18.1) — the durability point of every write-ahead log frame:
    once this returns, the bytes survive any crash, so an operation logged
    through it may be acknowledged.  Returns the byte offset the frame was
    written at (the file length before the append).  The file is opened and
    closed per call so crashed writers never hold a recovered-over handle.
    The appended bytes are identical to ``data`` (framing/CRC is the
    caller's job — see ``index/wal.py``)."""
    with open(path, "ab") as f:
        offset = f.tell()
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    return offset


def replace_dir(tmp: str | Path, final: str | Path) -> None:
    """Publish ``tmp`` as ``final`` without ever exposing a partial artifact
    (DESIGN.md §12.4).

    Directories cannot be renamed over on POSIX, so an existing ``final``
    is first renamed aside to ``<final>.old`` (atomic), then ``tmp`` is
    renamed into place (atomic), then the old copy is deleted.  No reader
    ever sees a half-written directory under the final name; a crash
    between the two renames loses only the *name* — the complete previous
    artifact survives as ``<final>.old`` (and numbered readers like
    ``latest_numbered`` simply fall back to the previous entry).
    """
    tmp, final = Path(tmp), Path(final)
    old = final.with_name(final.name + ".old")
    if old.exists():
        shutil.rmtree(old)
    had_old = False
    if final.exists():
        final.rename(old)
        had_old = True
    tmp.rename(final)
    if had_old:
        shutil.rmtree(old, ignore_errors=True)


def retain_latest(directory: str | Path, prefix: str, keep: int) -> None:
    """Delete all but the ``keep`` highest-numbered ``<prefix>_<N>`` dirs
    (DESIGN.md §12.4 retention; ``keep <= 0`` retains everything)."""
    if keep <= 0:
        return
    entries = sorted(_numbered(Path(directory), prefix))
    for _, p in entries[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_numbered(directory: str | Path, prefix: str) -> int | None:
    """Highest N among complete ``<prefix>_<N>`` dirs — complete means the
    manifest exists, i.e. the §12.4 rename happened (``None`` if none)."""
    entries = _numbered(Path(directory), prefix)
    return max((n for n, _ in entries), default=None)


def _numbered(directory: Path, prefix: str) -> list[tuple[int, Path]]:
    out: list[tuple[int, Path]] = []
    for p in directory.glob(f"{prefix}_*"):
        if not p.is_dir() or p.name.endswith(".tmp"):
            continue
        if not (p / _MANIFEST).exists():
            continue
        try:
            out.append((int(p.name.rsplit("_", 1)[1]), p))
        except ValueError:
            continue
    return out


def _flatten(tree: Any) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def save_checkpoint(directory: str | Path, step: int, payload: Any, keep: int = 3) -> Path:
    """Atomic synchronous save of an arbitrary pytree ``payload``
    (DESIGN.md §12.4 write protocol: tmp dir -> manifest fsync -> rename)."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"step_{step}.tmp"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten(payload)
    np.savez(tmp / "arrays.npz", **{f"a{i}": l for i, l in enumerate(leaves)})
    meta = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "time": time.time(),
        "leaf_shapes": [list(l.shape) for l in leaves],
        "leaf_dtypes": [str(l.dtype) for l in leaves],
    }
    fsync_json(tmp / _MANIFEST, meta)
    replace_dir(tmp, final)
    retain_latest(directory, "step", keep)
    return final


def latest_step(directory: str | Path) -> int | None:
    """Highest durable checkpoint step in ``directory`` (DESIGN.md §12.4)."""
    return latest_numbered(directory, "step")


def restore_checkpoint(
    directory: str | Path,
    template: Any,
    step: int | None = None,
    shardings: Any | None = None,
) -> tuple[Any, int] | None:
    """Restore into the structure of ``template``; optionally re-shard with
    ``shardings`` (a pytree of NamedSharding for the *current* mesh —
    elastic resume, DESIGN.md §12.4)."""
    directory = Path(directory)
    step = step if step is not None else latest_step(directory)
    if step is None:
        return None
    path = directory / f"step_{step}"
    with np.load(path / "arrays.npz") as z:
        arrays = [z[f"a{i}"] for i in range(len(z.files))]
    _, treedef = jax.tree.flatten(template)
    restored = jax.tree.unflatten(treedef, arrays)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else jax.device_put(a),
            restored,
            shardings,
        )
    return restored, step


class CheckpointManager:
    """Async double-buffered manager with restart-counter bookkeeping
    (DESIGN.md §12.4: one write in flight, errors surfaced on ``wait``)."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save_async(self, step: int, payload: Any) -> None:
        self.wait()  # one in flight at a time (double buffering)
        host = jax.tree.map(np.asarray, jax.device_get(payload))

        def _write():
            try:
                save_checkpoint(self.directory, step, host, keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, template: Any, shardings: Any | None = None):
        return restore_checkpoint(self.directory, template, shardings=shardings)
