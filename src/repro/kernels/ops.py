"""Public jit'd entry points for the Pallas kernels.

``interpret=True`` everywhere in this repo (CPU container); on a real TPU
deployment the same calls run compiled — the flag is plumbed through configs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .intersect import PAD, block_offsets, intersect_sorted
from .proximity import proximity_window
from .ref import (
    embedding_bag_ref,
    fragment_scores_ref,
    intersect_ref,
    proximity_window_ref,
)

__all__ = [
    "proximity_window",
    "proximity_window_ref",
    "intersect_sorted",
    "intersect_ref",
    "block_offsets",
    "embedding_bag_ref",
    "fragment_scores_ref",
    "proximity_search_scores",
    "PAD",
]


@functools.partial(
    jax.jit,
    static_argnames=("max_distance", "use_kernel", "interpret", "compute_dtype"),
)
def proximity_search_scores(
    occ: jax.Array,  # [B, L, N] occupancy per candidate window
    mult: jax.Array,  # [B, L]
    max_distance: int,
    use_kernel: bool = False,
    interpret: bool = True,
    compute_dtype: str = "int32",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused cover + §14 relevance: returns (emit, start, scores[B]).

    ``compute_dtype`` narrows the occupancy/prefix-count rows (kernel and jnp
    ref agree — §Perf-3); int32 reproduces the historical behaviour exactly.
    """
    cdt = jnp.dtype(compute_dtype)
    if use_kernel:
        emit, start = proximity_window(
            occ, mult, max_distance, interpret=interpret, compute_dtype=compute_dtype
        )
    else:
        emit, start = proximity_window_ref(occ.astype(cdt), mult, max_distance)
    scores = fragment_scores_ref(emit, start)
    return emit, start, scores
