"""Pallas TPU kernel: parallel minimal-fragment cover (the Combiner's Step 3).

Hardware mapping (see DESIGN.md §2):

* the Position table's 64-bit masks become dense int32 occupancy rows in
  VMEM — one row per subquery lemma, one lane per document position;
* Bit Scan Forward disappears: a bitmask's sortedness is the lane order;
* the Source/Processed queues become prefix counts (``C``) computed with
  log2(N) doubling shifts on the VPU;
* the §10.2 shrink loop becomes a static ``2*MaxDistance+1``-step window
  scan, each step one shifted vector compare over all lemma rows.

Grid: one program per document.  Block shapes keep the whole (padded)
document in VMEM: ``occ`` is [L, N] int32 with N a multiple of 128 lanes,
L <= 8 sublanes — ~32 KB for N=1024, far under the ~16 MB VMEM budget, so
multiple docs pipeline cleanly (double buffering hides the HBM streams).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["proximity_window_kernel", "proximity_window"]


def _shift_right(x: jax.Array, o: int) -> jax.Array:
    """x[..., p] -> x[..., p-o] with zero fill (static o)."""
    if o == 0:
        return x
    n = x.shape[-1]
    pad = jnp.zeros(x.shape[:-1] + (o,), x.dtype)
    return jnp.concatenate([pad, x[..., : n - o]], axis=-1)


def proximity_window_kernel(
    occ_ref,  # [1, L, N] compute-dtype occupancy
    mult_ref,  # [1, L] compute-dtype
    emit_ref,  # [1, N] int32 out
    start_ref,  # [1, N] int32 out
    *,
    window: int,
):
    occ = occ_ref[0]  # [L, N]
    mult = mult_ref[0]  # [L]
    L, n = occ.shape

    # prefix counts via doubling shifts (log2 N steps, VPU adds).  In a
    # narrow unsigned dtype the running count wraps, but the cover test only
    # reads window *differences* (`c - cq + oq` <= window), so wraparound
    # cancels exactly — same invariant as core/window.py's ref (§Perf-3).
    c = occ
    k = 1
    while k < n:
        c = c + _shift_right(c, k)
        k <<= 1

    active = (mult > 0)[:, None]  # [L, 1]
    is_event = jnp.any((occ > 0) & active, axis=0)  # [N]
    pos = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)

    found = jnp.zeros((n,), jnp.bool_)
    o_star = jnp.zeros((n,), jnp.int32)
    for o in range(window):  # static unroll: window = 2*MaxDistance+1 <= 64
        cq = _shift_right(c, o)
        oq = _shift_right(occ, o)
        cnt = c - cq + oq  # occurrences in [e-o, e]
        cover = jnp.all((cnt >= mult[:, None]) | ~active, axis=0)
        cover = cover & (pos >= o)
        o_star = jnp.where(cover & ~found, o, o_star)
        found = found | cover

    emit_ref[0] = (found & is_event).astype(jnp.int32)
    start_ref[0] = pos - o_star


@functools.partial(
    jax.jit, static_argnames=("max_distance", "interpret", "compute_dtype")
)
def proximity_window(
    occ: jax.Array,  # [B, L, N] occupancy (any integer dtype)
    mult: jax.Array,  # [B, L] int32
    max_distance: int,
    interpret: bool = True,
    compute_dtype: str = "int32",
) -> tuple[jax.Array, jax.Array]:
    """Batched minimal-fragment cover via ``pl.pallas_call``.

    Returns ``(emit bool [B, N], start int32 [B, N])`` — identical semantics
    to ``kernels.ref.proximity_window_ref``.  ``compute_dtype`` narrows the
    occupancy rows held in VMEM (uint8 quarters the HBM stream per doc, see
    DESIGN.md §2); it must fit the window length, like the jnp ref.
    """
    b, l, n = occ.shape
    window = 2 * max_distance + 1
    cdt = jnp.dtype(compute_dtype)
    if cdt != jnp.int32 and window > jnp.iinfo(cdt).max:
        raise ValueError(
            f"compute_dtype {compute_dtype} cannot hold window counts up to {window}"
        )
    kernel = functools.partial(proximity_window_kernel, window=window)
    emit, start = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, l, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, l), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.int32),
            jax.ShapeDtypeStruct((b, n), jnp.int32),
        ],
        interpret=interpret,
    )(occ.astype(cdt), mult.astype(cdt))
    return emit.astype(jnp.bool_), start
