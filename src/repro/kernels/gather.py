"""Pallas TPU kernel: arena block gather (the device-resident posting fetch).

The device-resident posting arena (``search/arena.py``, DESIGN.md §13) keeps
each §3 posting family's concatenated rows in ONE device buffer, every key's
extent aligned to a ``BLOCK``-row boundary.  Serving a batch then only needs
to *slice* the arena: the host ships a per-output-block indirection table
(``src_block``: which arena block fills output block ``i``; ``n_valid``: how
many of its rows are live) and the kernel copies block ``src_block[i]`` of
the arena into output block ``i``, masking the tail rows of each extent with
the ``-1`` sentinel.

This is the same scalar-prefetch indirection pattern as
``kernels/intersect.py`` (and block-sparse attention's block tables): the
indirection arrays land in SMEM via ``PrefetchScalarGridSpec`` *before* the
grid runs, so the ``BlockSpec`` index map can steer each grid step's DMA —
the gather IS the address computation, no gathered element ever round-trips
through the host.  ``gather_blocks_ref`` is the jnp form of the identical
computation (the default on CPU, where a per-block interpret-mode grid walk
costs more than one fused XLA gather); both produce bit-identical outputs
and the differential tests pin them against each other.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ARENA_BLOCK", "gather_blocks", "gather_blocks_ref"]

# Arena extent alignment (rows).  128 matches the TPU lane count, so one
# arena block is one natural VMEM tile per column.
ARENA_BLOCK = 128


def _gather_kernel(src_ref, nv_ref, arena_ref, out_ref):
    i = pl.program_id(0)
    rows = arena_ref[...]  # [BLOCK, W] the steered arena block
    iota = jax.lax.broadcasted_iota(jnp.int32, rows.shape, 0)
    live = iota < nv_ref[i]
    out_ref[...] = jnp.where(live, rows, jnp.int32(-1))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def gather_blocks(
    arena: jax.Array,  # [NB * block, W] int32 device-resident posting rows
    src_block: jax.Array,  # [G] int32 arena block index per output block
    n_valid: jax.Array,  # [G] int32 live rows in each output block
    block: int = ARENA_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """Copy arena block ``src_block[i]`` into output block ``i`` (``[G *
    block, W]`` int32), masking rows past ``n_valid[i]`` with ``-1``.

    Exactness: output row ``i * block + j`` equals arena row
    ``src_block[i] * block + j`` when ``j < n_valid[i]`` and the ``-1``
    sentinel row otherwise — identical to ``gather_blocks_ref``.
    """
    g = src_block.shape[0]
    w = arena.shape[1]
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # src_block + n_valid land in SMEM first
            grid=(g,),
            in_specs=[
                pl.BlockSpec((block, w), lambda i, src, nv: (src[i], 0)),
            ],
            out_specs=pl.BlockSpec((block, w), lambda i, src, nv: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((g * block, w), jnp.int32),
        interpret=interpret,
    )(src_block, n_valid, arena)


def gather_blocks_ref(
    arena: jax.Array,
    src_block: jax.Array,
    n_valid: jax.Array,
    block: int = ARENA_BLOCK,
) -> jax.Array:
    """jnp reference of :func:`gather_blocks` (one fused XLA gather; the
    default arena fetch on CPU).  Bit-identical to the kernel."""
    g = src_block.shape[0]
    within = jnp.arange(g * block, dtype=jnp.int32) % block
    blk = jnp.arange(g * block, dtype=jnp.int32) // block
    src = src_block[blk] * block + within
    rows = jnp.take(arena, jnp.clip(src, 0, arena.shape[0] - 1), axis=0)
    live = within < n_valid[blk]
    return jnp.where(live[:, None], rows, jnp.int32(-1))
