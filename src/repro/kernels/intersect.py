"""Pallas TPU kernel: sorted posting-list intersection (the Combiner's Step 1).

The paper aligns iterators on a document with an O(log n)-per-step heap.  The
TPU-native analogue is *block intersection with scalar-prefetched
indirection*: the host computes, per 128-element block of the probe list
``a``, the block offset into the build list ``b`` that could contain matches
(a ``searchsorted`` — the galloping skip of ``KeyIterator.skip_to_doc``).
The kernel then loads that ``b`` tile into VMEM and does a broadcast-compare
on the VPU — the same trick block-sparse attention uses for its block tables.

Multiple ``b`` tiles per ``a`` block (``n_chunks`` grid axis) OR-accumulate
into the output, so arbitrarily dense matches stay correct.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["intersect_sorted", "block_offsets"]

PAD = np.int32(2**31 - 1)


def block_offsets(a: np.ndarray, b: np.ndarray, block_a: int, block_b: int) -> np.ndarray:
    """Host-side indirection: for each ``a`` block, the aligned start tile
    in ``b`` (rounded down to a ``block_b`` multiple)."""
    starts = a[::block_a]
    off = np.searchsorted(b, starts, side="left")
    off = (off // block_b) * block_b
    max_off = max(0, len(b) - block_b)
    return np.minimum(off, max_off).astype(np.int32)


def _intersect_kernel(off_ref, a_ref, b_ref, out_ref):
    j = pl.program_id(1)
    a = a_ref[...]  # [1, BA]
    btile = b_ref[...]  # [1, BB]
    hit = jnp.any(a[0][:, None] == btile[0][None, :], axis=1)
    hit = hit & (a[0] != PAD)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = out_ref[...] | hit[None, :].astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("block_a", "block_b", "n_chunks", "interpret")
)
def intersect_sorted(
    a: jax.Array,  # [NA] sorted int32, padded with PAD
    b: jax.Array,  # [NB] sorted int32, padded with PAD
    offsets: jax.Array,  # [NA / block_a] int32 from `block_offsets`
    block_a: int = 128,
    block_b: int = 256,
    n_chunks: int = 2,
    interpret: bool = True,
) -> jax.Array:
    """1/0 membership of each ``a`` element in ``b``.

    ``n_chunks`` extra ``b`` tiles after the prefetched offset bound the
    match span per block; ``block_offsets`` guarantees matches start inside
    tile 0, and sortedness bounds them within ``n_chunks * block_b`` unless
    a single ``a`` block spans more duplicates than that (callers size
    ``n_chunks`` from data statistics; tests sweep it).
    """
    na = a.shape[0]
    nb = b.shape[0]
    grid = (na // block_a, n_chunks)

    def b_index(i, j, off_ref):
        # tile index into b: prefetched block offset + chunk j
        return (0, jnp.minimum(off_ref[i] // block_b + j, nb // block_b - 1))

    out = pl.pallas_call(
        _intersect_kernel,
        # scalar prefetch: offsets land in SMEM before the grid runs
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_a), lambda i, j, off: (0, i)),
                pl.BlockSpec((1, block_b), b_index),
            ],
            out_specs=pl.BlockSpec((1, block_a), lambda i, j, off: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((1, na), jnp.int32),
        interpret=interpret,
    )(offsets, a[None, :], b[None, :])
    return out[0]
