from .ops import (
    PAD,
    block_offsets,
    embedding_bag_ref,
    fragment_scores_ref,
    intersect_ref,
    intersect_sorted,
    proximity_search_scores,
    proximity_window,
    proximity_window_ref,
)

__all__ = [
    "PAD",
    "block_offsets",
    "embedding_bag_ref",
    "fragment_scores_ref",
    "intersect_ref",
    "intersect_sorted",
    "proximity_search_scores",
    "proximity_window",
    "proximity_window_ref",
]
