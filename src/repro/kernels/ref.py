"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and ``assert_allclose`` against these
references; the references themselves are validated against the paper-faithful
scalar implementation in ``tests/test_vectorized.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.window import window_cover, window_cover_batch

__all__ = [
    "proximity_window_ref",
    "intersect_ref",
    "embedding_bag_ref",
    "fragment_scores_ref",
]


def proximity_window_ref(
    occ: jax.Array, mult: jax.Array, max_distance: int
) -> tuple[jax.Array, jax.Array]:
    """Batched minimal-fragment cover; see ``core/window.py``."""
    emit, start = window_cover_batch(occ, mult, window=2 * max_distance + 1)
    return emit, start


def intersect_ref(a: jax.Array, b: jax.Array, pad_value: int = 2**31 - 1) -> jax.Array:
    """Membership of each element of sorted ``a`` in sorted ``b`` (1/0)."""
    idx = jnp.searchsorted(b, a)
    idx = jnp.clip(idx, 0, b.shape[0] - 1)
    hit = (b[idx] == a) & (a != pad_value)
    return hit.astype(jnp.int32)


def embedding_bag_ref(
    table: jax.Array,  # [V, D]
    indices: jax.Array,  # [B, K] (pad = -1)
    weights: jax.Array | None = None,  # [B, K]
) -> jax.Array:
    """Sum-mode embedding bag with padding; the RecSys gather-reduce op."""
    ok = (indices >= 0).astype(table.dtype)[..., None]
    safe = jnp.maximum(indices, 0)
    gathered = table[safe] * ok
    if weights is not None:
        gathered = gathered * weights[..., None].astype(table.dtype)
    return gathered.sum(axis=1)


def fragment_scores_ref(emit: jax.Array, start: jax.Array) -> jax.Array:
    """§14 proximity relevance: sum of 1/(span+1)^2 over emitted fragments."""
    n = emit.shape[-1]
    span = jnp.arange(n, dtype=jnp.float32) - start.astype(jnp.float32)
    contrib = jnp.where(emit, 1.0 / (span + 1.0) ** 2, 0.0)
    return contrib.sum(axis=-1)
