from .clock import ManualClock, SystemClock
from .fault_tolerance import RestartPolicy, run_with_restarts, StragglerMonitor
from .elastic import ElasticTopology

__all__ = [
    "RestartPolicy",
    "run_with_restarts",
    "StragglerMonitor",
    "ElasticTopology",
    "ManualClock",
    "SystemClock",
]
