"""Injectable clocks for every time-dependent serving layer (DESIGN.md §16.4).

All serving-side timing — deadline budgets and EWMA throughput calibration
in ``search/frontend.py``, circuit-breaker cooldowns and straggler hedging
in ``search/resilience.py``, queue wait and latency accounting in
``search/service.py`` — reads time through one of these clock objects
instead of calling ``time`` directly.  Production uses :class:`SystemClock`
(identical behavior to the previous direct ``time.perf_counter`` /
``time.sleep`` calls); tests inject :class:`ManualClock`, whose time only
moves when the test (or an injected fault's virtual ``sleep``) advances it,
so deadline/shed/straggler tests assert **exact tick boundaries** — no real
sleeps, no wall-clock flakiness, and a given schedule of advances replays
identically on every run.

Both clocks are *callable* (returning "now") so they can be passed anywhere
a bare ``clock()`` callable is expected (e.g. the ``HealthMonitor`` breaker
cooldown in ``search/resilience.py``).
"""

from __future__ import annotations

import time

__all__ = ["SystemClock", "ManualClock"]


class SystemClock:
    """The real wall clock (DESIGN.md §16.4): ``now()`` is
    ``time.perf_counter`` and ``sleep`` is ``time.sleep`` — byte-for-byte
    the timing behavior the serving layers had before clock injection, so
    production timing is identical with or without an explicit clock."""

    #: virtual clocks advance only when told to; schedulers use this flag
    #: to pick deterministic (thread-free) code paths.
    virtual = False

    def now(self) -> float:
        """Monotonic seconds (``time.perf_counter``)."""
        return time.perf_counter()

    __call__ = now

    @staticmethod
    def sleep(seconds: float) -> None:
        """Real ``time.sleep`` (§16.4); no-op for non-positive durations."""
        if seconds > 0:
            time.sleep(seconds)


class ManualClock:
    """A deterministic fake clock (DESIGN.md §16.4).

    Time starts at ``start`` and moves ONLY via :meth:`advance` /
    :meth:`sleep` (an injected straggler delay "sleeps" by advancing
    virtual time instantly) or the optional ``tick`` auto-advance: with
    ``tick > 0`` every ``now()`` reading advances time by exactly one tick
    first, so code that brackets work with two readings observes an elapsed
    time of exactly ``tick`` — the exactness hook the EWMA-calibration and
    queue-timer tests assert against (identical advance schedules produce
    identical timestamps on every run).
    """

    virtual = True

    def __init__(self, start: float = 0.0, tick: float = 0.0):
        self._now = float(start)
        self.tick = float(tick)

    def now(self) -> float:
        """Current virtual time; auto-advances by ``tick`` per reading."""
        if self.tick:
            self._now += self.tick
        return self._now

    __call__ = now

    def peek(self) -> float:
        """Read the virtual time WITHOUT consuming an auto-advance tick
        (test assertions use this so observing time never moves it —
        §16.4 exact-tick contract)."""
        return self._now

    def sleep(self, seconds: float) -> None:
        """Advance virtual time by ``seconds`` instantly — the injected
        form of ``time.sleep`` (§16.4): a scheduled straggler delay is
        observable as an exact timestamp difference, but costs no real
        time."""
        self.advance(seconds)

    def advance(self, seconds: float) -> float:
        """Move the clock forward ``seconds`` (negative is clamped to 0 —
        virtual time is monotonic like ``time.perf_counter``); returns the
        new virtual now."""
        self._now += max(0.0, float(seconds))
        return self._now
