"""Elastic topology: recompute the mesh when pods join or leave.

Checkpoints are layout-free (see ``checkpoint.py``), so resuming on a
different chip count only requires a new mesh + re-derived shardings.  The
policy here picks the largest (pods x data x model) grid that (a) fits the
surviving chips, (b) keeps the model axis unchanged (TP degree is baked into
layer shapes' divisibility), and (c) keeps the global batch divisible.

Scope after PR 6 (DESIGN.md §14): this planner is TRAINING-only — it
remaps the accelerator mesh for offline jobs driven by
``fault_tolerance.run_with_restarts``.  Serving-side failure handling
(shard health, snapshot recovery, degraded fan-out) deliberately does NOT
remap topology; it lives in ``search/resilience.py``, where a crashed
document shard recovers from its §12.2 snapshot in place.
"""

from __future__ import annotations

import dataclasses

__all__ = ["ElasticTopology"]


@dataclasses.dataclass(frozen=True)
class ElasticTopology:
    chips_per_pod: int = 256
    model_parallel: int = 16
    global_batch: int = 256

    def plan(self, healthy_pods: int) -> dict:
        if healthy_pods < 1:
            raise RuntimeError("no healthy pods")
        chips = healthy_pods * self.chips_per_pod
        data = chips // self.model_parallel // healthy_pods
        # shrink data-parallel degree until the global batch divides
        while data > 1 and self.global_batch % (data * healthy_pods):
            data -= 1
        shape = (
            (healthy_pods, data, self.model_parallel)
            if healthy_pods > 1
            else (data, self.model_parallel)
        )
        axes = ("pod", "data", "model") if healthy_pods > 1 else ("data", "model")
        return {
            "mesh_shape": shape,
            "mesh_axes": axes,
            "chips": healthy_pods * self.chips_per_pod,
            "per_device_batch": self.global_batch // (data * healthy_pods),
        }
