"""Fault tolerance: checkpoint/restart driver and straggler mitigation.

On a real multi-pod deployment each pod runs this driver; the coordinator
(GCS/etcd in production, a file heartbeat here) detects dead pods and
triggers a restart from the latest durable checkpoint with the surviving
topology (see ``elastic.py``).  The logic is hardware-agnostic and unit
tested by injecting failures.

Scope after PR 6 (DESIGN.md §14): this module is the TRAINING-loop side
of fault tolerance — ``run_with_restarts`` drives offline index-build /
calibration jobs against a ``CheckpointManager``.  The SERVING side lives
in ``search/resilience.py``, which wires :class:`RestartPolicy` into the
shard probe barrier (retry backoff for transient crashes) and owns the
canonical MAD straggler rule (``mad_stragglers``); the
:class:`StragglerMonitor` here keeps its training-driver interface but
delegates the math there, so the two layers can never disagree on what a
straggler is.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

__all__ = ["RestartPolicy", "run_with_restarts", "StragglerMonitor"]


@dataclasses.dataclass
class RestartPolicy:
    """Exponential-backoff restart budget, shared by the training driver
    below and the serving probe barrier (``search/resilience.py``, DESIGN.md
    §14) — one retry policy for both layers."""

    max_restarts: int = 10
    min_backoff_s: float = 0.0  # 0 for tests; seconds in production
    backoff_factor: float = 2.0
    max_backoff_s: float = 300.0

    def backoff(self, attempt: int) -> float:
        return min(self.max_backoff_s, self.min_backoff_s * self.backoff_factor ** attempt)


def run_with_restarts(
    make_state: Callable[[], Any],
    step_fn: Callable[[Any, int], Any],
    n_steps: int,
    manager,  # CheckpointManager
    policy: RestartPolicy = RestartPolicy(),
    checkpoint_every: int = 10,
    on_restart: Callable[[int, BaseException], None] | None = None,
) -> tuple[Any, dict]:
    """Run ``step_fn`` for ``n_steps``, checkpointing and restarting on
    failure.  Returns (final_state, stats).  Deterministic: the state pytree
    includes the data cursor, so a restarted run replays identically."""
    stats = {"restarts": 0, "steps_run": 0, "recovered_from": []}
    attempt = 0
    while True:
        try:
            ckpt = manager.restore_latest(make_state())
        except Exception:
            ckpt = None
        if ckpt is not None:
            state, start = ckpt
            stats["recovered_from"].append(start)
        else:
            state = make_state()
            start = 0
        try:
            for step in range(start, n_steps):
                state = step_fn(state, step)
                stats["steps_run"] += 1
                if (step + 1) % checkpoint_every == 0 or step + 1 == n_steps:
                    manager.save_async(step + 1, state)
            manager.wait()
            return state, stats
        except KeyboardInterrupt:
            raise
        except BaseException as e:
            stats["restarts"] += 1
            attempt += 1
            if on_restart is not None:
                on_restart(attempt, e)
            if attempt > policy.max_restarts:
                raise
            time.sleep(policy.backoff(attempt))
            try:
                manager.wait()
            except BaseException:
                pass  # a failed async save must not block recovery


class StragglerMonitor:
    """Detect slow pods from per-step durations and recommend remapping.

    At scale, persistent stragglers (bad HBM, thermal throttling) show up as
    one pod's step time sitting k MADs above the fleet median.  The runtime
    swaps the straggler with a spare pod (topology remap) at the next
    checkpoint boundary rather than killing the job.

    The MAD rule itself is owned by ``search.resilience.mad_stragglers``
    (DESIGN.md §14) — the serving ``HealthMonitor`` applies the identical
    criterion to shard probe latencies, so training and serving agree on
    what a straggler is.  This class keeps the training-driver interface
    (``record``/``stragglers``) and delegates.
    """

    def __init__(self, n_workers: int, window: int = 20, mad_threshold: float = 5.0):
        self.n_workers = n_workers
        self.window = window
        self.mad_threshold = mad_threshold
        self._times: list[list[float]] = [[] for _ in range(n_workers)]

    def record(self, worker: int, step_time_s: float) -> None:
        t = self._times[worker]
        t.append(step_time_s)
        if len(t) > self.window:
            t.pop(0)

    def stragglers(self) -> list[int]:
        from ..search.resilience import mad_stragglers

        return mad_stragglers(self._times, self.mad_threshold)
