"""Baseline search algorithms (paper §4, experiments SE1 and SE2.1–SE2.3).

All of these are prior work the paper compares against; the paper's own
contribution (SE2.4, the Combiner) lives in ``combiner.py``.  Every algorithm
returns ``(results, stats)`` where ``stats`` carries the §11 metrics.

* ``se1_ordinary``       — DAAT merge over the plain inverted index (Idx1).
* ``se21_main_cell``     — Main-Cell [17]: the main lemma is the first
  component of every key; all iterators are aligned on (ID, P).
* ``se22_intermediate``  — Intermediate-Lists [14]: simple key cover, per-doc
  intermediate per-lemma streams, then merged.
* ``se23_optimized``     — Optimized-Intermediate-Lists [15]: §6 key
  selection, but still materializes intermediate streams.
"""

from __future__ import annotations

import heapq
import time
from typing import Mapping, Sequence

import numpy as np

from ..index.builder import IndexSet
from .keys import SelectedKey, Subquery, select_keys
from .lemma import FLList
from .oracle import sweep_events
from .postings import KeyIterator, QueryStats, SearchResult

__all__ = [
    "se1_ordinary",
    "se21_main_cell",
    "se22_intermediate",
    "se23_optimized",
    "simple_key_cover",
    "main_cell_keys",
]


# ---------------------------------------------------------------------------
# SE1 — ordinary inverted index
# ---------------------------------------------------------------------------


def se1_ordinary(
    subquery: Subquery, index: IndexSet
) -> tuple[list[SearchResult], QueryStats]:
    """Full posting-list DAAT merge (the paper's 193-million-postings case).

    The ordinary index must be read in full for every query lemma — this is
    precisely the cost the multi-component indexes exist to avoid.
    """
    stats = QueryStats()
    t0 = time.perf_counter()
    mult = subquery.multiplicity()
    lists: dict[str, np.ndarray] = {}
    for lemma in mult:
        rows = index.ordinary.get(lemma)
        if rows is None or not len(rows):
            stats.elapsed_sec = time.perf_counter() - t0
            return [], stats  # some lemma never occurs -> no results
        lists[lemma] = rows
        stats.postings_read += len(rows)
        stats.bytes_read += rows.nbytes

    # document-level intersection
    doc_sets = [np.unique(rows[:, 0]) for rows in lists.values()]
    docs = doc_sets[0]
    for ds in doc_sets[1:]:
        docs = np.intersect1d(docs, ds, assume_unique=True)

    results: list[SearchResult] = []
    max_span = 2 * index.max_distance
    for doc in docs.tolist():
        # heap-merge the per-lemma position streams within the document
        streams = []
        for lemma, rows in lists.items():
            lo = np.searchsorted(rows[:, 0], doc, side="left")
            hi = np.searchsorted(rows[:, 0], doc, side="right")
            streams.append([(int(p), lemma) for p in rows[lo:hi, 1]])
        merged: list[tuple[int, str]] = []
        heap = [(s[0], i, 0) for i, s in enumerate(streams) if s]
        heapq.heapify(heap)
        while heap:
            head, si, ei = heapq.heappop(heap)
            stats.heap_ops += 1
            merged.append(head)
            if ei + 1 < len(streams[si]):
                heapq.heappush(heap, (streams[si][ei + 1], si, ei + 1))
        # dedup (multi-lemma positions can repeat)
        merged = sorted(set(merged))
        results.extend(sweep_events(doc, merged, mult, max_span=max_span))
    stats.results = len(results)
    stats.elapsed_sec = time.perf_counter() - t0
    return results, stats


# ---------------------------------------------------------------------------
# key covers used by the baselines
# ---------------------------------------------------------------------------


def simple_key_cover(subquery: Subquery, fl: FLList) -> list[SelectedKey]:
    """SE2.2's unoptimized cover [14]: FL-sorted unique lemmas chunked into
    consecutive triples; a short final chunk is padded by reusing earlier
    lemmas *unstarred* (they produce redundant stream records — the
    inefficiency §6 was designed to remove)."""
    uniq = sorted(subquery.unique_lemmas(), key=fl.number)
    if not uniq:
        return []
    arity = min(3, max(1, len(subquery)))
    keys: list[SelectedKey] = []
    for i in range(0, len(uniq), arity):
        chunk = uniq[i : i + arity]
        j = 0
        while len(chunk) < arity and len(uniq) > len(chunk):
            if uniq[j] not in chunk:
                chunk.append(uniq[j])
            j += 1
        if len(chunk) < arity:  # subquery has < arity unique lemmas
            chunk = chunk + [chunk[-1]] * (arity - len(chunk))
        chunk = sorted(chunk, key=fl.number)
        keys.append(SelectedKey(tuple(chunk), tuple([False] * len(chunk))))
    return keys


def main_cell_keys(subquery: Subquery, fl: FLList) -> list[SelectedKey]:
    """SE2.1's cover [17]: main lemma duplicated as first component."""
    uniq = sorted(subquery.unique_lemmas(), key=fl.number)
    if not uniq:
        return []
    main, rest = uniq[0], uniq[1:]
    if not rest:
        return [SelectedKey((main, main, main), (False, True, True))]
    keys: list[SelectedKey] = []
    for i in range(0, len(rest), 2):
        pair = rest[i : i + 2]
        if len(pair) == 1:
            # pad with a *different* query lemma (starred: it is present at
            # any full result anyway, but must not emit duplicate events)
            pool = [l for l in uniq if l != pair[0] and l != main]
            pad = max(pool, key=fl.number) if pool else main
            comps = [main, pair[0], pad]
            stars = [False, False, True]
            order = sorted(range(3), key=lambda k: (fl.number(comps[k]), stars[k]))
            keys.append(
                SelectedKey(
                    tuple(comps[k] for k in order),
                    tuple(stars[k] for k in order),
                )
            )
            continue
        comps = sorted([main] + pair, key=fl.number)
        keys.append(SelectedKey(tuple(comps), (False, False, False)))
    return keys


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _open_iterators(
    keys: Sequence[SelectedKey], index: IndexSet, stats: QueryStats
) -> list[KeyIterator]:
    return [KeyIterator(k, index.key_postings(k.components), stats) for k in keys]


def _align_docs(iters: list[KeyIterator], stats: QueryStats) -> int | None:
    """Step 1: advance the min-doc iterator until all agree; None if done."""
    while True:
        if any(it.exhausted for it in iters):
            return None
        docs = [it.doc for it in iters]
        stats.heap_ops += 1
        lo, hi = min(docs), max(docs)
        if lo == hi:
            return lo
        for it in iters:
            if it.doc == lo:
                it.skip_to_doc(hi)
                break


def _doc_events(
    it: KeyIterator, doc: int, stats: QueryStats, honor_stars: bool
) -> list[tuple[int, str]]:
    """Read every record of ``it`` for ``doc``; emit (pos, lemma) events."""
    events: list[tuple[int, str]] = []
    while not it.exhausted and it.doc == doc:
        events.extend(it.events(honor_stars=honor_stars))
        it.next()
    stats.intermediate_records += len(events)
    return events


# ---------------------------------------------------------------------------
# SE2.2 / SE2.3 — intermediate-lists family
# ---------------------------------------------------------------------------


def _intermediate_lists_search(
    subquery: Subquery,
    keys: list[SelectedKey],
    index: IndexSet,
    honor_stars: bool,
) -> tuple[list[SearchResult], QueryStats]:
    stats = QueryStats()
    t0 = time.perf_counter()
    mult = subquery.multiplicity()
    max_span = 2 * index.max_distance
    results: list[SearchResult] = []
    iters = _open_iterators(keys, index, stats)
    while True:
        doc = _align_docs(iters, stats)
        if doc is None:
            break
        # materialize the intermediate per-lemma streams, then merge
        events: set[tuple[int, str]] = set()
        for it in iters:
            events.update(_doc_events(it, doc, stats, honor_stars))
        results.extend(sweep_events(doc, sorted(events), mult, max_span=max_span))
    stats.results = len(results)
    stats.elapsed_sec = time.perf_counter() - t0
    return results, stats


def se22_intermediate(
    subquery: Subquery, index: IndexSet
) -> tuple[list[SearchResult], QueryStats]:
    keys = simple_key_cover(subquery, index.fl)
    return _intermediate_lists_search(subquery, keys, index, honor_stars=True)


def se23_optimized(
    subquery: Subquery, index: IndexSet
) -> tuple[list[SearchResult], QueryStats]:
    """§6 key selection, but: (a) intermediate streams are materialized, and
    (b) ``*``-marked components still emit stream records — the duplicate
    work the Combiner's §10.4 star-skip removes (§12's 10.1 s vs 1.7 s)."""
    keys = select_keys(subquery, index.fl)
    return _intermediate_lists_search(subquery, keys, index, honor_stars=False)


# ---------------------------------------------------------------------------
# SE2.1 — Main-Cell
# ---------------------------------------------------------------------------


def se21_main_cell(
    subquery: Subquery, index: IndexSet
) -> tuple[list[SearchResult], QueryStats]:
    """Align every iterator on the same (ID, P) of the main lemma [17].

    The oldest algorithm treats the query as a *set* of lemmas (duplicate
    query lemmas are not multiplicity-counted — §14 names duplicate handling
    as a limitation the Combiner removes)."""
    stats = QueryStats()
    t0 = time.perf_counter()
    keys = main_cell_keys(subquery, index.fl)
    mult = {l: 1 for l in subquery.unique_lemmas()}
    max_span = 2 * index.max_distance
    iters = _open_iterators(keys, index, stats)
    results: list[SearchResult] = []
    seen: set[SearchResult] = set()
    while True:
        if any(it.exhausted for it in iters):
            break
        cells = [(it.doc, it.pos) for it in iters]
        stats.heap_ops += 1
        lo, hi = min(cells), max(cells)
        if lo != hi:
            for it in iters:
                if (it.doc, it.pos) == lo:
                    it.next()
                    break
            continue
        # aligned: consume the whole (ID, P) group in every iterator
        doc, pos = lo
        events: set[tuple[int, str]] = set()
        for it in iters:
            while not it.exhausted and it.doc == doc and it.pos == pos:
                events.update(it.events(honor_stars=False))
                it.next()
        for r in sweep_events(doc, sorted(events), mult, max_span=max_span):
            if r not in seen:
                seen.add(r)
                results.append(r)
    stats.results = len(results)
    stats.elapsed_sec = time.perf_counter() - t0
    return sorted(results), stats
