"""Result-semantics oracle.

The shared semantics of SE2.2 / SE2.3 / SE2.4 (and the vectorized / Pallas
engines) decomposes into two layers:

1. an *event stream* per document — the deduplicated ``(pos, lemma)``
   occurrences derivable from the selected keys' postings (honouring §6
   ``*`` marks);

2. a *minimal-covering-window sweep* over that stream — the Lemma-table
   process of §10.1–10.2: walk events in position order, keep capped
   per-lemma counts, and each time every subquery lemma is covered with
   multiplicity, shrink from the left while the front event is over-counted
   and emit the fragment ``(front.pos, event.pos)``.

Results are reported with the proximity filter ``span <= 2 * MaxDistance``
(fragments wider than the Step-2 window can never be *guaranteed* found by
the multi-key algorithms; see DESIGN.md §7).
"""

from __future__ import annotations

from collections import deque
from typing import Iterable, Mapping, Sequence

import numpy as np

from .keys import SelectedKey, Subquery
from .postings import SearchResult

__all__ = ["key_events", "sweep_events", "oracle_search", "ordinary_events"]


def key_events(
    keys: Sequence[SelectedKey],
    postings: Mapping[SelectedKey, np.ndarray],
    honor_stars: bool = True,
) -> dict[int, list[tuple[int, str]]]:
    """Deduplicated per-document event streams from key postings."""
    per_doc: dict[int, set[tuple[int, str]]] = {}
    for key in keys:
        rows = postings[key]
        comps, stars = key.components, key.starred
        for row in np.asarray(rows):
            doc, p = int(row[0]), int(row[1])
            bucket = per_doc.setdefault(doc, set())
            if not (honor_stars and stars[0]):
                bucket.add((p, comps[0]))
            for slot in range(1, len(comps)):
                if not (honor_stars and stars[slot]):
                    bucket.add((p + int(row[1 + slot]), comps[slot]))
    return {doc: sorted(evts) for doc, evts in per_doc.items()}


def ordinary_events(
    lemmas: Iterable[str],
    ordinary: Mapping[str, np.ndarray],
) -> dict[int, list[tuple[int, str]]]:
    """Event streams straight from the ordinary index (SE1 semantics)."""
    per_doc: dict[int, set[tuple[int, str]]] = {}
    for lemma in set(lemmas):
        rows = ordinary.get(lemma)
        if rows is None:
            continue
        for row in rows:
            per_doc.setdefault(int(row[0]), set()).add((int(row[1]), lemma))
    return {doc: sorted(evts) for doc, evts in per_doc.items()}


def sweep_events(
    doc_id: int,
    events: Sequence[tuple[int, str]],
    multiplicity: Mapping[str, int],
    max_span: int | None = None,
) -> list[SearchResult]:
    """§10.1–10.2 Lemma-table sweep over one document's event stream.

    Positions are processed atomically (a text position is one word; when a
    multi-lemma word contributes several events at the same position, the
    completion check runs once after all of them) — this is also the
    vectorized engines' semantics.
    """
    needed_total = sum(multiplicity.values())
    counts: dict[str, int] = {l: 0 for l in multiplicity}
    covered = 0
    window: deque[tuple[int, str]] = deque()
    out: list[SearchResult] = []
    i, n = 0, len(events)
    while i < n:
        pos = events[i][0]
        while i < n and events[i][0] == pos:  # all events at this position
            lem = events[i][1]
            i += 1
            if lem not in counts:
                continue
            if counts[lem] < multiplicity[lem]:
                covered += 1
            counts[lem] += 1
            window.append((pos, lem))
        if covered != needed_total:
            continue
        # shrink from the left while the front is over-counted
        while window:
            fpos, flem = window[0]
            if counts[flem] > multiplicity[flem]:
                counts[flem] -= 1
                window.popleft()
            else:
                break
        start = window[0][0]
        if max_span is None or pos - start <= max_span:
            out.append(SearchResult(doc_id=doc_id, start=start, end=pos))
    return out


def oracle_search(
    subquery: Subquery,
    keys: Sequence[SelectedKey],
    postings: Mapping[SelectedKey, np.ndarray],
    max_distance: int,
) -> list[SearchResult]:
    """Reference result set for the multi-key algorithms."""
    mult = subquery.multiplicity()
    results: list[SearchResult] = []
    for doc, events in sorted(key_events(keys, postings).items()):
        results.extend(sweep_events(doc, events, mult, max_span=2 * max_distance))
    return results
