"""The Combiner algorithm — SE2.4, the paper's contribution (§5–§10, §13).

A Document-At-A-Time three-level merge over multi-component key posting
lists that produces minimal result fragments **without materializing
intermediate per-lemma posting lists**:

Step 1 (§8)  — document alignment: advance the min-doc iterator until every
               iterator sits on the same document.
Step 2 (§9)  — position alignment inside the document: advance the
               min-position iterator until ``maxP - minP < 2*MaxDistance``.
Step 3 (§10) — the Position table: three cyclic buffers of ``WindowSize``
               entries, each with a 64-bit occupancy ``Mask``.  ``Set(P,Lem)``
               writes the entry at relative position ``P - Start``; Bit Scan
               Forward over the first buffer's mask yields the sorted
               ``Source`` queue for free; the Lemma table (capped per-lemma
               counts, §10.1–10.2) turns the event stream into minimal
               fragments via the ``Processed`` queue; the buffer switch
               (§10.5) rotates buffers cyclically and advances ``Start``.

Fidelity notes (see DESIGN.md §7):
* the paper's trace (§13) shows ``Set`` is also called for ``Key[0]`` at
  ``Value.P`` (§10.4 lists only Key[1]/Key[2]); we follow the trace;
* §10.5's Processed-queue cleaning must mirror the Lemma-table bookkeeping
  of the §10.2 shrink loop (decrement counts), otherwise stale counts
  produce fragments that do not actually contain every lemma — we decrement;
* one entry per text position, but the entry holds the position's *lemma
  set*, not a single lemma: a §2 multi-lemma word ("are" -> are, be) can
  satisfy two subquery lemmas at one position, and the verbatim
  ``Set``-overwrites reading silently drops one of them (missing e.g. the
  minimal fragment of [to be who you are] whose "be" is supplied by the
  word "are").  Duplicate ``Set`` calls for the SAME (position, lemma) still
  overwrite, and the §10.1 completion check runs once per position (all of
  the position's events enter the Lemma table first) — exactly the oracle's
  atomic-position sweep, so SE2.4 stays fragment-identical to
  ``core/oracle.py`` and every device engine.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Sequence

from ..index.builder import IndexSet
from .keys import SelectedKey, Subquery, select_keys
from .postings import KeyIterator, QueryStats, SearchResult

__all__ = ["se24_combiner", "PositionTable", "LemmaTable", "CombinerState"]


# ---------------------------------------------------------------------------
# Lemma table (§10.1, §10.6 local renumbering)
# ---------------------------------------------------------------------------


class LemmaTable:
    """Capped per-lemma occurrence counts over the current fragment."""

    __slots__ = ("max_per", "count_per", "total_max", "total_count")

    def __init__(self, subquery: Subquery):
        mult = subquery.multiplicity()
        self.max_per = mult  # Entry.Max
        self.count_per = {l: 0 for l in mult}  # Entry.Count
        self.total_max = len(subquery)  # Lemma.Max
        self.total_count = 0  # Lemma.Count

    def add(self, lemma: str) -> None:
        if self.count_per[lemma] < self.max_per[lemma]:
            self.total_count += 1
        self.count_per[lemma] += 1

    def remove(self, lemma: str) -> None:
        if self.count_per[lemma] <= self.max_per[lemma]:
            self.total_count -= 1
        self.count_per[lemma] -= 1

    @property
    def complete(self) -> bool:
        return self.total_count == self.total_max

    def overcounted(self, lemma: str) -> bool:
        return self.count_per[lemma] > self.max_per[lemma]

    def reset(self) -> None:
        for l in self.count_per:
            self.count_per[l] = 0
        self.total_count = 0


# ---------------------------------------------------------------------------
# Position table (§10.3) — three cyclic buffers with 64-bit masks
# ---------------------------------------------------------------------------


@dataclass
class _Entry:
    # one entry per text position; ``lems`` is the position's lemma set
    # (multi-lemma words can satisfy several subquery lemmas at one position
    # — see the module fidelity notes)
    lems: list[str] = field(default_factory=list)
    p: int = -1


class PositionTable:
    """Three ``WindowSize``-entry buffers; each has a 64-bit ``Mask``.

    ``MaxDistance * 2 <= WindowSize <= 64`` (§10.3).  Masks are Python ints
    used as 64-bit registers; Bit Scan Forward is ``(m & -m).bit_length()-1``.
    """

    def __init__(self, window_size: int, max_distance: int):
        if not (2 * max_distance <= window_size <= 64):
            raise ValueError("need MaxDistance*2 <= WindowSize <= 64")
        self.W = window_size
        self.D = max_distance
        self.flush_border = int(window_size * 1.5)  # WindowFlushBorder (§10.3)
        self.start = 0
        self.order = [0, 1, 2]  # order[0] is "the first buffer"
        self.entries = [[_Entry() for _ in range(window_size)] for _ in range(3)]
        self.mask = [0, 0, 0]

    # -- §10.3 -------------------------------------------------------------
    def shift(self, new_start: int) -> None:
        """Monotone re-anchor; only legal when all buffers are drained."""
        assert new_start >= self.start, "Start never moves backwards (§10.4)"
        assert not any(self.mask), "shift with pending entries would drop them"
        self.start = new_start

    def set(self, p: int, lem: str) -> None:
        r = p - self.start
        if r < 0:
            return  # event behind the frontier (already flushed region)
        buf = r // self.W
        assert buf < 3, "event beyond the third buffer violates §10.4"
        rel = r % self.W
        phys = self.order[buf]
        e = self.entries[phys][rel]
        if e.p != p:  # entry reused from an older window: start fresh
            e.p = p
            e.lems = [lem]
        elif lem not in e.lems:  # same (p, lem) overwrites; new lemma joins
            e.lems.append(lem)
        self.mask[phys] |= 1 << rel

    def flush_first(self) -> list[tuple[int, str]]:
        """Bit-Scan-Forward the first buffer's mask into the Source queue
        (one event per (position, lemma); a multi-lemma position emits its
        lemmas in sorted order, matching the oracle's event stream)."""
        phys = self.order[0]
        m = self.mask[phys]
        out: list[tuple[int, str]] = []
        while m:
            lsb = m & -m
            rel = lsb.bit_length() - 1
            e = self.entries[phys][rel]
            for lem in sorted(e.lems):
                out.append((e.p, lem))
            m ^= lsb
        self.mask[phys] = 0
        return out  # sorted by construction

    def switch(self) -> None:
        """§10.5 cyclic renumbering; Start advances one window."""
        self.order = self.order[1:] + self.order[:1]
        self.start += self.W

    @property
    def empty(self) -> bool:
        return not any(self.mask)


# ---------------------------------------------------------------------------
# Per-document combiner state
# ---------------------------------------------------------------------------


class CombinerState:
    """Source/Processed queues + Lemma table + Position table for one doc."""

    def __init__(self, subquery: Subquery, window_size: int, max_distance: int):
        self.table = LemmaTable(subquery)
        self.ptable = PositionTable(window_size, max_distance)
        self.processed: deque[tuple[int, str]] = deque()
        self.results: list[SearchResult] = []

    def shift(self, new_start: int) -> None:
        # a far-forward shift expires stale Processed entries (same
        # bookkeeping as the §10.5 cleaning)
        self._clean_processed(new_start)
        self.ptable.shift(new_start)

    def set(self, p: int, lem: str) -> None:
        self.ptable.set(p, lem)

    def process_source(self, doc_id: int) -> None:
        """§10.1 main loop: Source -> Processed + Lemma table + results.

        Positions are processed atomically: every event of a multi-lemma
        position enters the Lemma table before the §10.2 completion check,
        exactly like the oracle sweep — per-event checks would emit an extra
        stale-start fragment when the position's first lemma already
        completes the cover."""
        src = self.ptable.flush_first()
        i, n = 0, len(src)
        while i < n:
            p = src[i][0]
            while i < n and src[i][0] == p:  # all events at this position
                _, lem = src[i]
                i += 1
                self.processed.append((p, lem))
                self.table.add(lem)
            # §10.2 check
            if not self.table.complete:
                continue
            while self.processed:
                fp, fl = self.processed[0]
                if self.table.overcounted(fl):
                    self.table.remove(fl)
                    self.processed.popleft()
                else:
                    break
            start = self.processed[0][0]
            self.results.append(SearchResult(doc_id=doc_id, start=start, end=p))

    def switch(self) -> None:
        """§10.5: clean Processed, rotate buffers, advance Start."""
        self._clean_border()
        self.ptable.switch()

    def _clean_border(self) -> None:
        # remove entries with (Start + WindowSize - Entry.P) > MaxDistance*2
        limit = self.ptable.start + self.ptable.W - 2 * self.ptable.D
        while self.processed and self.processed[0][0] < limit:
            _, lem = self.processed.popleft()
            self.table.remove(lem)

    def _clean_processed(self, new_start: int) -> None:
        limit = new_start - 2 * self.ptable.D
        while self.processed and self.processed[0][0] < limit:
            _, lem = self.processed.popleft()
            self.table.remove(lem)

    @property
    def drained(self) -> bool:
        return self.ptable.empty


# ---------------------------------------------------------------------------
# SE2.4 top level
# ---------------------------------------------------------------------------


def _align_docs(iters: list[KeyIterator], stats: QueryStats) -> int | None:
    """Step 1 (§8)."""
    while True:
        if any(it.exhausted for it in iters):
            return None
        docs = [it.doc for it in iters]
        stats.heap_ops += 1
        lo, hi = min(docs), max(docs)
        if lo == hi:
            return lo
        for it in iters:
            if it.doc == lo:
                it.skip_to_doc(hi)
                break


def _step3(
    doc: int,
    iters: list[KeyIterator],
    state: CombinerState,
    max_span: int,
) -> None:
    """§10.4: rounds of read -> flush -> process -> switch until drained."""
    live = [it for it in iters if not it.exhausted and it.doc == doc]
    if not live:
        return
    p_min = min(it.pos for it in live)
    state.shift(max(state.ptable.start, p_min - min(p_min, state.ptable.D)))
    while True:
        read_any = False
        border = state.ptable.start + state.ptable.flush_border
        for it in iters:
            while not it.exhausted and it.doc == doc and it.pos < border:
                for p, lem in it.events():  # honours * marks (§10.4)
                    state.set(p, lem)
                it.next()
                read_any = True
        state.process_source(doc)
        state.switch()
        if not read_any and state.drained:
            return


def se24_combiner(
    subquery: Subquery,
    index: IndexSet,
    window_size: int = 64,
    keys: Sequence[SelectedKey] | None = None,
) -> tuple[list[SearchResult], QueryStats]:
    """The paper's new algorithm.  ``window_size=64`` per §13's advice."""
    stats = QueryStats()
    t0 = time.perf_counter()
    D = index.max_distance
    window_size = min(64, max(window_size, 2 * D))
    key_list = list(keys) if keys is not None else select_keys(subquery, index.fl)
    iters = [KeyIterator(k, index.key_postings(k.components), stats) for k in key_list]
    max_span = 2 * D
    results: list[SearchResult] = []

    while True:
        doc = _align_docs(iters, stats)  # Step 1
        if doc is None:
            break
        state = CombinerState(subquery, window_size, D)
        # Step 2 (§9)
        while True:
            in_doc = [it for it in iters if not it.exhausted and it.doc == doc]
            if len(in_doc) < len(iters):
                break  # Step 2 exit 1 -> Step 1
            ps = [it.pos for it in in_doc]
            stats.heap_ops += 1
            delta = max(ps) - min(ps)
            if delta < 2 * D:
                _step3(doc, iters, state, max_span)  # Step 3, then back here
                continue
            # advance the min-position iterator
            for it in in_doc:
                if it.pos == min(ps):
                    it.next()
                    break
        # drain anything Step 3 buffered but had not flushed yet
        while not state.drained:
            state.process_source(doc)
            state.switch()
        results.extend(r for r in state.results if r.span <= max_span)

    stats.results = len(results)
    stats.elapsed_sec = time.perf_counter() - t0
    return results, stats
