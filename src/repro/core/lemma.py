"""Lemmatization and lemma typing (paper §2).

The paper uses a morphological analyzer that maps each word to a list of
*lemmas* (canonical forms); a word may have several lemmas ("are" -> ["are",
"be"] in the paper's dictionary).  All lemmas are then sorted by decreasing
corpus frequency into the *FL-list*; the position of a lemma in that list is
its *FL-number*.  The first ``SWCount`` lemmas are *stop lemmas*, the next
``FUCount`` are *frequently used*, the rest are *ordinary*.

The paper's analyzer is closed-source; we ship a compact rule-based English
lemmatizer (exceptions table + suffix rules) that reproduces every example in
the paper, including the multi-lemma case "are" -> ("are", "be").
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Iterable, Mapping, Sequence

__all__ = [
    "LemmaType",
    "FLList",
    "Lemmatizer",
    "tokenize",
    "DEFAULT_SW_COUNT",
    "DEFAULT_FU_COUNT",
]

# Representative parameter values from the paper (§2, Experiment 1).
DEFAULT_SW_COUNT = 700
DEFAULT_FU_COUNT = 2100

_TOKEN_RE = re.compile(r"[a-z0-9']+")


def tokenize(text: str) -> list[str]:
    """Lowercase word tokenizer; positions are word ordinals (paper §3)."""
    return _TOKEN_RE.findall(text.lower())


class LemmaType(IntEnum):
    STOP = 0          # first SWCount of the FL-list
    FREQUENTLY_USED = 1  # next FUCount
    ORDINARY = 2      # everything else


# ---------------------------------------------------------------------------
# Lemmatizer
# ---------------------------------------------------------------------------

# Irregular forms.  Values are tuples because the paper's dictionary is
# multi-valued: a word form may map to several lemmas and the query is then
# expanded into subqueries (§5: "who are you who" -> [who][are,be][you][who]).
_EXCEPTIONS: dict[str, tuple[str, ...]] = {
    "are": ("are", "be"),  # the paper's own example keeps both lemmas
    "is": ("be",),
    "am": ("be",),
    "was": ("be",),
    "were": ("be",),
    "been": ("be",),
    "being": ("be",),
    "has": ("have",),
    "had": ("have",),
    "having": ("have",),
    "does": ("do",),
    "did": ("do",),
    "done": ("do",),
    "doing": ("do",),
    "said": ("say",),
    "says": ("say",),
    "saying": ("say",),
    "went": ("go",),
    "gone": ("go",),
    "goes": ("go",),
    "found": ("find",),
    "me": ("i", "me"),
    "my": ("i", "my"),
    "you": ("you",),
    "your": ("you", "your"),
    "who": ("who",),
    "whom": ("who", "whom"),
    "what": ("what",),
    "men": ("man",),
    "women": ("woman",),
    "children": ("child",),
    "mice": ("mouse",),
    "feet": ("foot",),
    "teeth": ("tooth",),
    "made": ("make",),
    "making": ("make",),
    "took": ("take",),
    "taken": ("take",),
    "got": ("get",),
    "gotten": ("get",),
    "came": ("come",),
    "knew": ("know",),
    "known": ("know",),
    "thought": ("think",),
    "saw": ("see", "saw"),
    "seen": ("see",),
    "left": ("leave", "left"),
    "better": ("good", "better"),
    "best": ("good", "best"),
    "worse": ("bad", "worse"),
    "worst": ("bad", "worst"),
    "an": ("a",),
    "its": ("it",),
    "their": ("they", "their"),
    "them": ("they", "them"),
    "these": ("this",),
    "those": ("that",),
    "us": ("we", "us"),
    "songs": ("song",),
    "wars": ("war",),
    "times": ("time",),
}

# Suffix rules applied in order; (suffix, replacement, min_stem_len).
_SUFFIX_RULES: tuple[tuple[str, str, int], ...] = (
    ("iest", "y", 2),
    ("ies", "y", 2),
    ("sses", "ss", 2),
    ("shes", "sh", 2),
    ("ches", "ch", 2),
    ("xes", "x", 2),
    ("zes", "z", 2),
    ("ied", "y", 2),
    ("ing", "", 3),
    ("ingly", "", 3),
    ("edly", "", 3),
    ("ed", "", 3),
    ("est", "", 3),
    ("er", "", 3),
    ("ly", "", 3),
    ("s", "", 2),
)

_VOWELS = set("aeiou")


class Lemmatizer:
    """Rule-based lemmatizer with a user-extensible exceptions table."""

    def __init__(self, extra_exceptions: Mapping[str, tuple[str, ...]] | None = None):
        self._exceptions = dict(_EXCEPTIONS)
        if extra_exceptions:
            self._exceptions.update(extra_exceptions)
        # Rules are pure per-word-form, so memoize: corpora repeat word forms
        # heavily (Zipf), and bulk ingest lemmatizes millions of tokens.
        self._memo: dict[str, tuple[str, ...]] = dict(self._exceptions)

    def lemmas(self, word: str) -> tuple[str, ...]:
        """All lemmas of ``word`` (multi-valued, like the paper's dictionary).

        Memoized — the suffix rules run once per distinct word form.
        """
        w = word.lower()
        hit = self._memo.get(w)
        if hit is not None:
            return hit
        out = self._lemmas_uncached(w)
        self._memo[w] = out
        return out

    def _lemmas_uncached(self, w: str) -> tuple[str, ...]:
        if w in self._exceptions:
            return self._exceptions[w]
        if len(w) <= 3 or w.endswith("ss"):
            return (w,)
        for suffix, repl, min_stem in _SUFFIX_RULES:
            if w.endswith(suffix) and len(w) - len(suffix) >= min_stem:
                stem = w[: len(w) - len(suffix)] + repl
                # undouble final consonant: "running" -> "runn" -> "run"
                if (
                    len(stem) >= 3
                    and stem[-1] == stem[-2]
                    and stem[-1] not in _VOWELS
                    and stem[-1] not in ("s", "l", "z")
                ):
                    stem = stem[:-1]
                # restore silent e: "making" handled by exceptions; generic
                # heuristic: consonant-vowel-consonant stems often need 'e'.
                return (stem,)
        return (w,)

    def lemmatize_text(self, text: str) -> list[tuple[str, ...]]:
        """Per-token lemma tuples for a document."""
        memo = self._memo
        uncached = self._lemmas_uncached
        out = []
        for tok in tokenize(text):
            hit = memo.get(tok)
            if hit is None:
                hit = memo[tok] = uncached(tok)
            out.append(hit)
        return out

    def lemmatize_texts(self, texts: Sequence[str]) -> list[list[tuple[str, ...]]]:
        """Batched ingestion path: lemmatize many documents, resolving each
        DISTINCT word form once across the whole batch (the memo makes the
        marginal document a dict-lookup loop, not a suffix-rule loop)."""
        return [self.lemmatize_text(t) for t in texts]

    def first_lemma_text(self, text: str) -> list[str]:
        """Indexing view: the paper indexes every lemma of every occurrence;
        for index building we emit *all* lemmas per position (see builder)."""
        return [self.lemmas(tok)[0] for tok in tokenize(text)]


# ---------------------------------------------------------------------------
# FL-list
# ---------------------------------------------------------------------------


@dataclass
class FLList:
    """Frequency-ordered lemma list (paper §2).

    ``fl_number[lemma]`` is the 0-based rank in decreasing-frequency order.
    Lemma comparisons in the paper ("you" < "who") are FL-number comparisons.
    """

    lemmas: list[str]
    fl_number: dict[str, int]
    frequency: dict[str, int]
    sw_count: int = DEFAULT_SW_COUNT
    fu_count: int = DEFAULT_FU_COUNT

    @classmethod
    def from_frequencies(
        cls,
        freq: Mapping[str, int],
        sw_count: int = DEFAULT_SW_COUNT,
        fu_count: int = DEFAULT_FU_COUNT,
    ) -> "FLList":
        # Sort by decreasing frequency; ties broken lexicographically so the
        # FL-numbering is deterministic across runs/shards.
        ordered = sorted(freq.items(), key=lambda kv: (-kv[1], kv[0]))
        lemmas = [l for l, _ in ordered]
        fl = {l: i for i, l in enumerate(lemmas)}
        # store frequencies in FL order, not the caller's accumulation order:
        # serialized snapshots (DESIGN.md §12.2/§17.4) must be byte-identical
        # no matter how the counts were reduced (corpus scan, per-shard
        # merge, spill-chunk counters)
        return cls(lemmas=lemmas, fl_number=fl,
                   frequency={l: freq[l] for l in lemmas},
                   sw_count=sw_count, fu_count=fu_count)

    def lemma_type(self, lemma: str) -> LemmaType:
        n = self.fl_number.get(lemma)
        if n is None:
            return LemmaType.ORDINARY
        if n < self.sw_count:
            return LemmaType.STOP
        if n < self.sw_count + self.fu_count:
            return LemmaType.FREQUENTLY_USED
        return LemmaType.ORDINARY

    def is_stop(self, lemma: str) -> bool:
        return self.lemma_type(lemma) == LemmaType.STOP

    def number(self, lemma: str) -> int:
        """FL-number; unknown lemmas sort after everything known."""
        return self.fl_number.get(lemma, len(self.lemmas))

    def compare(self, a: str, b: str) -> int:
        """Paper ordering: a < b iff FL-number(a) < FL-number(b)."""
        na, nb = self.number(a), self.number(b)
        return (na > nb) - (na < nb)

    def __len__(self) -> int:
        return len(self.lemmas)
