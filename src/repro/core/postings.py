"""Posting-list iterators (paper §4) and per-query accounting.

A posting array for a key of arity ``a`` has rows ``(doc, P, D1 .. D_{a-1})``
sorted lexicographically — the §4 record order.  ``KeyIterator`` exposes the
paper's iterator protocol: ``Next()``, ``Value`` (current record) and ``Key``
(canonical components, plus the §6 ``*`` marks).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np

from .keys import SelectedKey

__all__ = ["KeyIterator", "QueryStats", "SearchResult"]

_RECORD_BYTES = 4  # int32 per field


class SearchResult(NamedTuple):
    """A minimal text fragment containing every subquery lemma (§10.2).

    A ``NamedTuple`` rather than a dataclass so batch readout can
    materialize thousands of fragments per batch via ``SearchResult._make``
    without ``__init__``/``__setattr__`` overhead dominating the readout
    phase (§15.1); field order ``(doc_id, start, end)`` matches both the
    dense device result-buffer columns and the order-by-(doc, start)
    contract the merge paths rely on.
    """

    doc_id: int
    start: int
    end: int

    @property
    def span(self) -> int:
        return self.end - self.start


@dataclass
class QueryStats:
    """Per-query accounting: the paper's three reported metrics (§11 —
    postings read, data read size, results) plus the serving-layer counters
    added by the fused pipeline and the planner/frontend (arXiv 2009.03679's
    response-time-guarantee reporting).

    ``partial`` is True when the deadline-aware frontend early-exited: the
    returned top-k is exact over the *executed* subqueries (every reported
    fragment and score is exact; skipped subqueries could only add docs or
    raise scores) — see ``search/frontend.py``.
    """

    postings_read: int = 0
    bytes_read: int = 0
    intermediate_records: int = 0  # SE2.2/SE2.3 stream materialization
    heap_ops: int = 0
    results: int = 0
    empty_subqueries: int = 0  # subqueries short-circuited before dispatch
    device_dispatches: int = 0  # device programs issued for this query/batch
    elapsed_sec: float = 0.0
    # ---- planner / frontend counters (PR 3) -------------------------------
    cache_hits: int = 0  # whole-query result-cache hits
    cache_misses: int = 0  # planned + executed (not served from cache)
    posting_cache_hits: int = 0  # hot posting-slice reuse during planning
    pruned_subqueries: int = 0  # planner-proved-empty (exact, no work lost)
    skipped_subqueries: int = 0  # deadline admission dropped (partial result)
    partial: bool = False  # deadline early-exit happened
    deadline_sec: float = 0.0  # the request's admission budget (0 = none)
    # ---- posting-arena counters (PR 5, DESIGN.md §13) ---------------------
    arena_hits: int = 0  # keys served from device-resident extents
    arena_misses: int = 0  # keys that fell back to the host-pack path
    h2d_bytes: int = 0  # bytes actually shipped host->device this query/batch
    # ---- resilience counters (PR 6, DESIGN.md §14) ------------------------
    # batch-level like device_dispatches: the probe barrier runs once per
    # batch, so every response in the batch reports the same values.
    # Fault-free traffic leaves ALL of them at zero (pinned by tests).
    retries: int = 0  # transient-crash probe retries (RestartPolicy backoff)
    hedges: int = 0  # straggler probes raced against a hedged second attempt
    shards_degraded: int = 0  # shards excluded from this response's fan-out
    recoveries: int = 0  # shards re-restored from snapshot for this batch
    shed: int = 0  # request load-shed to the admission-control budget

    def merge(self, other: "QueryStats") -> None:
        self.postings_read += other.postings_read
        self.bytes_read += other.bytes_read
        self.intermediate_records += other.intermediate_records
        self.heap_ops += other.heap_ops
        self.results += other.results
        self.empty_subqueries += other.empty_subqueries
        self.device_dispatches += other.device_dispatches
        self.elapsed_sec += other.elapsed_sec
        self.cache_hits += other.cache_hits
        self.cache_misses += other.cache_misses
        self.posting_cache_hits += other.posting_cache_hits
        self.pruned_subqueries += other.pruned_subqueries
        self.skipped_subqueries += other.skipped_subqueries
        self.partial = self.partial or other.partial
        self.deadline_sec = max(self.deadline_sec, other.deadline_sec)
        self.arena_hits += other.arena_hits
        self.arena_misses += other.arena_misses
        self.h2d_bytes += other.h2d_bytes
        self.retries += other.retries
        self.hedges += other.hedges
        self.shards_degraded = max(self.shards_degraded, other.shards_degraded)
        self.recoveries += other.recoveries
        self.shed = max(self.shed, other.shed)


class KeyIterator:
    """Sequential reader over one key's posting array.

    Reading is *accounted*: every ``Next`` charges one posting and the record
    byte size to ``stats`` — this is the "data read size"/"postings per
    query" measure of §11 (our in-memory analogue of the paper's disk reads).
    """

    __slots__ = ("key", "rows", "idx", "stats", "_n", "_width")

    def __init__(self, key: SelectedKey, rows: np.ndarray, stats: QueryStats):
        self.key = key
        self.rows = rows
        self.idx = 0
        self.stats = stats
        self._n = rows.shape[0]
        self._width = rows.shape[1] if rows.ndim == 2 else 0
        if self._n:  # the first record is materialized by opening the iterator
            stats.postings_read += 1
            stats.bytes_read += self._width * _RECORD_BYTES

    # -- paper protocol ----------------------------------------------------
    @property
    def exhausted(self) -> bool:
        return self.idx >= self._n

    @property
    def doc(self) -> int:
        return int(self.rows[self.idx, 0])

    @property
    def pos(self) -> int:
        return int(self.rows[self.idx, 1])

    def distances(self) -> tuple[int, ...]:
        return tuple(int(x) for x in self.rows[self.idx, 2:])

    def next(self) -> None:
        self.idx += 1
        if self.idx < self._n:
            self.stats.postings_read += 1
            self.stats.bytes_read += self._width * _RECORD_BYTES

    def skip_to_doc(self, doc_id: int) -> None:
        """Galloping skip used by Step 1 (doc alignment)."""
        lo = np.searchsorted(self.rows[:, 0], doc_id, side="left")
        if lo > self.idx:
            # charge skipped block reads conservatively: sequential readers
            # in the paper fetch pages; we charge each skipped record once.
            n_skipped = int(lo) - self.idx
            self.stats.postings_read += min(n_skipped, 1)
            self.stats.bytes_read += self._width * _RECORD_BYTES
            self.idx = int(lo)

    def events(self, honor_stars: bool = True) -> list[tuple[int, str]]:
        """(pos, lemma) events of the current record.

        With ``honor_stars`` (SE2.4, §10.4) the ``*``-marked components are
        skipped; the pre-Combiner algorithms (SE2.1–SE2.3) lack that
        optimization and emit every component — the duplicate work §12
        measures on "to be or not to be".
        """
        row = self.rows[self.idx]
        p = int(row[1])
        out = []
        comps, stars = self.key.components, self.key.starred
        if not (honor_stars and stars[0]):
            out.append((p, comps[0]))
        for slot in range(1, len(comps)):
            if not (honor_stars and stars[slot]):
                out.append((p + int(row[1 + slot]), comps[slot]))
        return out
