"""Vectorized (TPU-native) reformulation of the Combiner's Step 3.

The paper's Position-table machinery is inherently sequential (queues, Bit
Scan Forward).  On a TPU we exploit the same two invariants the paper does —

  (1) every reportable fragment has span ``<= 2 * MaxDistance`` (the Step-2
      gate), and
  (2) occurrences can be represented as *occupancy* over document positions
      (the Position table's 64-bit masks),

— but evaluate **all candidate windows in parallel** instead of walking a
queue:

  For local lemma ``l`` let ``occ[l, p] ∈ {0,1}`` be the occupancy and
  ``C[l, p] = Σ_{q<=p} occ[l, q]`` its prefix count.  The window ``[q, e]``
  covers the subquery iff  ``C[l,e] - C[l,q] + occ[l,q] >= mult[l]`` for all
  ``l``.  A fragment is emitted at every event position ``e`` where some
  ``q >= e - 2D`` covers; its start is the *largest* covering ``q`` — exactly
  the §10.2 shrink result.

This file is the pure-jnp reference ("ref" semantics); the Pallas kernel in
``kernels/proximity.py`` computes the identical function with explicit VMEM
blocking, and ``kernels/ref.py`` re-exports this for the allclose tests.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "window_cover",
    "window_cover_batch",
    "events_to_occupancy",
    "results_from_cover",
    "results_from_cover_batch",
]


def window_cover(
    occ: jax.Array,  # [L, N] int32 0/1 occupancy per local lemma
    mult: jax.Array,  # [L] int32 required multiplicity (0 = unused slot)
    window: int,  # 2*MaxDistance + 1 candidate window width
) -> tuple[jax.Array, jax.Array]:
    """Per-position emission mask and fragment starts for one document.

    Returns ``(emit, start)`` with shapes ``([N], [N])``: ``emit[e]`` is True
    where a minimal fragment ends at ``e``; ``start[e]`` is its start
    position (undefined where ``emit`` is False).
    """
    # narrow compute dtype (§Perf-3): the cover test only ever looks at
    # *differences* of prefix counts over one candidate window, so unsigned
    # wraparound cancels — ``c - cq + oq`` is exact whenever the true window
    # count (<= window) fits the dtype, regardless of document length.
    if occ.dtype in (jnp.uint8, jnp.uint16) and window <= jnp.iinfo(occ.dtype).max:
        cdt = occ.dtype
    else:
        cdt = jnp.dtype(jnp.int32)
    occ = occ.astype(cdt)
    mult = mult.astype(cdt)
    n = occ.shape[-1]
    active = (mult > 0)[:, None]  # [L, 1]
    c = jnp.cumsum(occ, axis=-1, dtype=cdt)  # C[l, p]
    is_event = jnp.any((occ > 0) & active, axis=0)  # [N]

    def shifted(x: jax.Array, o: int) -> jax.Array:
        if o == 0:
            return x
        pad = jnp.zeros(x.shape[:-1] + (o,), x.dtype)
        return jnp.concatenate([pad, x[..., : n - o]], axis=-1)

    found = jnp.zeros((n,), jnp.bool_)
    o_star = jnp.zeros((n,), jnp.int32)
    for o in range(window):
        cq = shifted(c, o)
        oq = shifted(occ, o)
        cnt = c - cq + oq  # occurrences in [e-o, e]
        cover = jnp.all((cnt >= mult[:, None]) | ~active, axis=0)
        # a window must start inside the document
        cover = cover & (jnp.arange(n) >= o)
        o_star = jnp.where(cover & ~found, o, o_star)
        found = found | cover
    emit = found & is_event
    start = jnp.arange(n, dtype=jnp.int32) - o_star
    return emit, start


def window_cover_batch(
    occ: jax.Array,  # [B, L, N]
    mult: jax.Array,  # [B, L]
    window: int,
) -> tuple[jax.Array, jax.Array]:
    """vmap of :func:`window_cover` over a padded document batch."""
    return jax.vmap(lambda o, m: window_cover(o, m, window))(occ, mult)


def window_cover_rank_batch(
    occ: jax.Array,  # [B, L, N] occupancy (any integer dtype)
    mult: jax.Array,  # [B, L]
    window: int,
) -> tuple[jax.Array, jax.Array]:
    """Rank-based cover: same (emit, start) as :func:`window_cover_batch`
    in O(L*N) instead of O(window*L*N).

    ``[q, e]`` covers lemma ``l`` iff ``q <= p_l(e)``, where ``p_l(e)`` is
    the position of the ``mult[l]``-th latest occurrence of ``l`` at or
    before ``e``.  So the §10.2 shrink result is closed-form:

        start[e] = min over active l of p_l(e)          (largest covering q)
        emit[e]  = event(e)  and  e - start[e] < window

    ``p_l(e)`` is one gather: scatter occurrence positions by their prefix
    rank, then index with ``C[l, e] - mult[l]``.  No per-offset sweep — the
    window length drops out of the complexity entirely.
    """
    b, l, n = occ.shape
    occ2 = (occ > 0).reshape(b * l, n)
    mult2 = mult.reshape(b * l, 1).astype(jnp.int32)
    active = mult2 > 0
    c = jnp.cumsum(occ2, axis=-1, dtype=jnp.int32)  # exact ranks, no wrap

    # P[row, r] = position of the (r+1)-th occurrence in the row
    m = b * l
    pos = jnp.arange(n, dtype=jnp.int32)[None, :]
    dump = m * n  # padding slot for non-occurrence lanes
    flat_rank = jnp.where(
        occ2, jnp.arange(m, dtype=jnp.int32)[:, None] * n + (c - 1), dump
    )
    p_table = (
        jnp.full((m * n + 1,), -1, jnp.int32)
        .at[flat_rank.reshape(-1)]
        .set(jnp.broadcast_to(pos, (m, n)).reshape(-1))
    )

    idx = c - mult2  # rank of the mult-th latest occurrence at/before e
    valid = (idx >= 0) | ~active
    gather_idx = jnp.arange(m, dtype=jnp.int32)[:, None] * n + jnp.maximum(idx, 0)
    p_le = p_table[gather_idx]  # [M, N]
    p_le = jnp.where(active & (idx >= 0), p_le, n)  # inactive -> +inf for min

    p_b = p_le.reshape(b, l, n)
    start = jnp.min(p_b, axis=1)  # [B, N] largest covering q
    all_valid = jnp.all(valid.reshape(b, l, n), axis=1)
    is_event = jnp.any(occ2.reshape(b, l, n) & active.reshape(b, l, 1), axis=1)
    e_pos = jnp.arange(n, dtype=jnp.int32)[None, :]
    emit = is_event & all_valid & (start < n) & (e_pos - start < window)
    # match window_cover's convention: start defaults to e where no cover
    start = jnp.where(emit, start, e_pos)
    return emit, start


def events_to_occupancy(
    events_pos: np.ndarray,  # [E] positions (pad = -1)
    events_lem: np.ndarray,  # [E] local lemma ids
    n_lemmas: int,
    doc_len: int,
) -> np.ndarray:
    """Host-side scatter of (pos, lemma) events into dense occupancy."""
    occ = np.zeros((n_lemmas, doc_len), dtype=np.int32)
    ok = events_pos >= 0
    occ[events_lem[ok], events_pos[ok]] = 1
    return occ


def results_from_cover(
    doc_id: int, emit: np.ndarray, start: np.ndarray
) -> list[tuple[int, int, int]]:
    """(doc, start, end) triples from the emission mask."""
    ends = np.nonzero(np.asarray(emit))[0]
    starts = np.asarray(start)[ends]
    return [(doc_id, int(s), int(e)) for s, e in zip(starts, ends)]


def results_from_cover_batch(
    doc_ids: np.ndarray,  # [B] global doc id per row (pad = -1)
    emit: np.ndarray,  # [B, N] emission mask
    start: np.ndarray,  # [B, N] fragment starts
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized fragment readout over a whole emit batch.

    One ``np.nonzero`` replaces the per-document Python loop: returns
    ``(rows, docs, starts, ends)`` — ``rows`` is the batch row of each
    fragment (callers map rows back to queries/segments), the other three
    are the fragment triples.  Padding rows (``doc_ids < 0``) emit nothing.
    """
    doc_ids = np.asarray(doc_ids)
    emit = np.asarray(emit)
    rows, ends = np.nonzero(emit & (doc_ids >= 0)[:, None])
    starts = np.asarray(start)[rows, ends]
    return rows, doc_ids[rows], starts.astype(np.int64), ends
