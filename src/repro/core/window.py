"""Vectorized (TPU-native) reformulation of the Combiner's Step 3.

The paper's Position-table machinery is inherently sequential (queues, Bit
Scan Forward).  On a TPU we exploit the same two invariants the paper does —

  (1) every reportable fragment has span ``<= 2 * MaxDistance`` (the Step-2
      gate), and
  (2) occurrences can be represented as *occupancy* over document positions
      (the Position table's 64-bit masks),

— but evaluate **all candidate windows in parallel** instead of walking a
queue:

  For local lemma ``l`` let ``occ[l, p] ∈ {0,1}`` be the occupancy and
  ``C[l, p] = Σ_{q<=p} occ[l, q]`` its prefix count.  The window ``[q, e]``
  covers the subquery iff  ``C[l,e] - C[l,q] + occ[l,q] >= mult[l]`` for all
  ``l``.  A fragment is emitted at every event position ``e`` where some
  ``q >= e - 2D`` covers; its start is the *largest* covering ``q`` — exactly
  the §10.2 shrink result.

This file is the pure-jnp reference ("ref" semantics); the Pallas kernel in
``kernels/proximity.py`` computes the identical function with explicit VMEM
blocking, and ``kernels/ref.py`` re-exports this for the allclose tests.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "window_cover",
    "window_cover_batch",
    "events_to_occupancy",
    "results_from_cover",
]


def window_cover(
    occ: jax.Array,  # [L, N] int32 0/1 occupancy per local lemma
    mult: jax.Array,  # [L] int32 required multiplicity (0 = unused slot)
    window: int,  # 2*MaxDistance + 1 candidate window width
) -> tuple[jax.Array, jax.Array]:
    """Per-position emission mask and fragment starts for one document.

    Returns ``(emit, start)`` with shapes ``([N], [N])``: ``emit[e]`` is True
    where a minimal fragment ends at ``e``; ``start[e]`` is its start
    position (undefined where ``emit`` is False).
    """
    # narrow compute dtype (§Perf-3): occupancy and prefix counts fit in u8
    # for window lengths <= 255, quartering the HBM traffic of the cover loop
    if occ.dtype in (jnp.uint8, jnp.uint16) and occ.shape[-1] <= jnp.iinfo(occ.dtype).max:
        cdt = occ.dtype
    else:
        cdt = jnp.dtype(jnp.int32)
    occ = occ.astype(cdt)
    mult = mult.astype(cdt)
    n = occ.shape[-1]
    active = (mult > 0)[:, None]  # [L, 1]
    c = jnp.cumsum(occ, axis=-1, dtype=cdt)  # C[l, p]
    is_event = jnp.any((occ > 0) & active, axis=0)  # [N]

    def shifted(x: jax.Array, o: int) -> jax.Array:
        if o == 0:
            return x
        pad = jnp.zeros(x.shape[:-1] + (o,), x.dtype)
        return jnp.concatenate([pad, x[..., : n - o]], axis=-1)

    found = jnp.zeros((n,), jnp.bool_)
    o_star = jnp.zeros((n,), jnp.int32)
    for o in range(window):
        cq = shifted(c, o)
        oq = shifted(occ, o)
        cnt = c - cq + oq  # occurrences in [e-o, e]
        cover = jnp.all((cnt >= mult[:, None]) | ~active, axis=0)
        # a window must start inside the document
        cover = cover & (jnp.arange(n) >= o)
        o_star = jnp.where(cover & ~found, o, o_star)
        found = found | cover
    emit = found & is_event
    start = jnp.arange(n, dtype=jnp.int32) - o_star
    return emit, start


def window_cover_batch(
    occ: jax.Array,  # [B, L, N]
    mult: jax.Array,  # [B, L]
    window: int,
) -> tuple[jax.Array, jax.Array]:
    """vmap of :func:`window_cover` over a padded document batch."""
    return jax.vmap(lambda o, m: window_cover(o, m, window))(occ, mult)


def events_to_occupancy(
    events_pos: np.ndarray,  # [E] positions (pad = -1)
    events_lem: np.ndarray,  # [E] local lemma ids
    n_lemmas: int,
    doc_len: int,
) -> np.ndarray:
    """Host-side scatter of (pos, lemma) events into dense occupancy."""
    occ = np.zeros((n_lemmas, doc_len), dtype=np.int32)
    ok = events_pos >= 0
    occ[events_lem[ok], events_pos[ok]] = 1
    return occ


def results_from_cover(
    doc_id: int, emit: np.ndarray, start: np.ndarray
) -> list[tuple[int, int, int]]:
    """(doc, start, end) triples from the emission mask."""
    ends = np.nonzero(np.asarray(emit))[0]
    starts = np.asarray(start)[ends]
    return [(doc_id, int(s), int(e)) for s, e in zip(starts, ends)]
