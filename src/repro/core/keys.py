"""Multi-component key selection (paper §6) and subquery expansion (§5).

A *subquery* is a list of lemmas (one lemma per query position).  Key
selection greedily covers the subquery's lemmas with three-component keys:

* first component  — the most frequently occurring (min FL-number) unused lemma;
* second component — an unused lemma occupying a query index different from the
  first's; among acceptable candidates, the *least* frequently occurring
  (max FL-number); if none, the "used" mark is ignored and the component is
  marked ``*`` (duplicate);
* third component  — same rule with the first two indexes excluded.

``*``-marked components do not contribute ``Set`` calls during the search
(paper §10.4); they exist only so the key has full arity.

Keys are stored canonically with components ordered by FL-number
(``f <= s <= t``, paper §3); star marks travel with their component.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Iterable, Sequence

from .lemma import FLList, LemmaType, Lemmatizer

__all__ = [
    "SelectedKey",
    "select_keys",
    "expand_subqueries",
    "Subquery",
    "canonicalize_key",
    "lemma_order_signature",
    "classify_lemmas",
    "key_family",
    "EXECUTABLE_FAMILIES",
]


def canonicalize_key(
    components: Sequence[str], starred: Sequence[bool], fl: FLList
) -> tuple[tuple[str, ...], tuple[bool, ...]]:
    """Canonical §3 component order (``f <= s <= t`` by FL-number, lexeme tie
    break, star marks travel with their component).

    Shared by §6 selection and by the incremental indexer: segment posting
    dicts are keyed by these tuples, so every segment of a multi-segment
    index must canonicalize against the SAME FL-list for query-time key
    lookup to see a single merged posting list per key.
    """
    order = sorted(
        range(len(components)),
        key=lambda i: (fl.number(components[i]), components[i], starred[i]),
    )
    return (
        tuple(components[i] for i in order),
        tuple(starred[i] for i in order),
    )


def lemma_order_signature(
    lemmas: Iterable[str], fl: FLList
) -> tuple[tuple[str, ...], tuple[int, ...]]:
    """The projection of FL-list state that §3 row generation and §6 key
    selection actually depend on, restricted to one document's lemma set:
    the *relative* FL order of the lemmas plus each lemma's type.

    Two FL generations that agree on this signature for a document produce
    byte-identical postings for it (absolute FL-numbers only ever reach disk
    through NSW stop-lemma ids, which the incremental indexer remaps
    separately) — this is the exactness test behind FL-drift re-keying in
    ``index/incremental.py``.
    """
    ordered = sorted(set(lemmas), key=lambda l: (fl.number(l), l))
    return tuple(ordered), tuple(int(fl.lemma_type(l)) for l in ordered)


@dataclass(frozen=True)
class SelectedKey:
    """A canonical multi-component key plus per-component duplicate marks.

    ``components`` are FL-sorted (f <= s <= t).  ``starred[i]`` is True when
    the i-th canonical component was a ``*`` duplicate in §6 selection.
    ``arity`` is 3 for (f,s,t) keys; shorter subqueries degrade to 2- or
    1-component keys (paper §14: "the new algorithm can also be used with any
    multi-component indexes and one-component indexes").
    """

    components: tuple[str, ...]
    starred: tuple[bool, ...]

    @property
    def arity(self) -> int:
        return len(self.components)

    def active_components(self) -> list[tuple[int, str]]:
        """(slot, lemma) pairs that DO produce Set() calls (unstarred)."""
        return [(i, c) for i, (c, s) in enumerate(zip(self.components, self.starred)) if not s]


@dataclass(frozen=True)
class Subquery:
    """A fully lemma-resolved query: one lemma per position."""

    lemmas: tuple[str, ...]

    def __len__(self) -> int:
        return len(self.lemmas)

    def unique_lemmas(self) -> list[str]:
        seen: dict[str, None] = {}
        for l in self.lemmas:
            seen.setdefault(l)
        return list(seen)

    def multiplicity(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for l in self.lemmas:
            out[l] = out.get(l, 0) + 1
        return out


def expand_subqueries(query: str, lemmatizer: Lemmatizer, limit: int = 16) -> list[Subquery]:
    """§5: expand a word query into subqueries over the lemma alternatives.

    "who are you who" -> [who][are][you][who], [who][be][you][who].
    ``limit`` caps the cartesian blow-up for pathological inputs.
    """
    per_position = lemmatizer.lemmatize_text(query)
    if not per_position:
        return []
    combos = itertools.product(*per_position)
    return [Subquery(tuple(c)) for c in itertools.islice(combos, limit)]


# ---------------------------------------------------------------------------
# §5 lemma classification and §3 index-family binding (the planner's inputs)
# ---------------------------------------------------------------------------

# §3 index families that `IndexSet.key_postings` actually serves; keys bound
# to any other family read zero postings in the current engines.
EXECUTABLE_FAMILIES = frozenset({"triple", "stop_pair", "pair", "stop_single"})


def classify_lemmas(lemmas: Iterable[str], fl: FLList) -> dict[str, LemmaType]:
    """§5 query-lemma classification against the corpus FL thresholds.

    Each lemma's class is its position in the FL-list relative to the
    ``SWCount`` / ``SWCount + FUCount`` boundaries: stop, frequently-used, or
    ordinary (unknown lemmas are ordinary).  This classification — not the
    lemma text — decides which §3 index family can answer a subquery, so it
    is the first step of query planning (``search/planner.py``).
    """
    return {l: fl.lemma_type(l) for l in lemmas}


def key_family(key: SelectedKey, fl: FLList) -> str:
    """The §3 index family that answers a canonical §6 key.

    Mirrors ``IndexSet.key_postings`` dispatch exactly for the families the
    engines serve (``EXECUTABLE_FAMILIES``); the remaining labels name the
    paper's index that *would* cover the key but is not wired into query
    execution, so planned cost (and results) for them is zero:

    * arity 3                      -> ``"triple"``      — (f,s,t) stop triples
    * arity 2, both stop           -> ``"stop_pair"``   — degenerate (f,s)
    * arity 2, FU first            -> ``"pair"``        — (w,v) two-component
    * arity 2, stop + non-stop     -> ``"nsw"``         — NSW records (§3)
    * arity 2, both ordinary       -> ``"ordinary"``    — ordinary-index merge
    * arity 1, stop                -> ``"stop_single"`` — degenerate (f)
    * arity 1, non-stop            -> ``"ordinary"``

    The planner prunes subqueries whose lemma event supply is zero (which
    subsumes non-executable bindings) — exact w.r.t. the engines, which read
    the same empty posting lists.
    """
    types = [fl.lemma_type(c) for c in key.components]
    if key.arity == 3:
        return "triple"
    if key.arity == 2:
        if all(t == LemmaType.STOP for t in types):
            return "stop_pair"
        if types[0] == LemmaType.FREQUENTLY_USED:
            return "pair"
        if LemmaType.STOP in types:
            return "nsw"
        return "ordinary"
    return "stop_single" if types[0] == LemmaType.STOP else "ordinary"


# ---------------------------------------------------------------------------
# §6 key selection
# ---------------------------------------------------------------------------


def _positions_of(lemmas: Sequence[str]) -> dict[str, list[int]]:
    pos: dict[str, list[int]] = {}
    for i, l in enumerate(lemmas):
        pos.setdefault(l, []).append(i)
    return pos


def _pick(
    candidates: list[str],
    fl: FLList,
    *,
    most_frequent: bool,
) -> str | None:
    if not candidates:
        return None
    key = lambda l: (fl.number(l), l)
    return min(candidates, key=key) if most_frequent else max(candidates, key=key)


def select_keys(subquery: Subquery, fl: FLList, arity: int = 3) -> list[SelectedKey]:
    """Greedy §6 selection.  Returns canonical keys covering every lemma.

    Fidelity refinement (DESIGN.md §7): a fallback component is ``*``-starred
    only when the lemma already has as many UNSTARRED slots as its query
    multiplicity.  Verbatim §6 stars every fallback, which silently loses the
    second occurrence of a duplicated lemma that never anchors a key (e.g.
    the query [a a b b] selects the single key (a, b, b*) and can then never
    satisfy b's multiplicity).  All §6 paper examples are unaffected.
    """
    lemmas = list(subquery.lemmas)
    if not lemmas:
        return []
    arity = min(arity, max(1, len(lemmas)))
    positions = _positions_of(lemmas)
    mult = subquery.multiplicity()
    unstarred_slots: dict[str, int] = {l: 0 for l in positions}
    used: set[str] = set()
    keys: list[SelectedKey] = []

    def free_index(lemma: str, taken: set[int]) -> int | None:
        for i in positions[lemma]:
            if i not in taken:
                return i
        return None

    while True:
        unused = [l for l in positions if l not in used]
        if not unused:
            break
        # --- first component: most frequent unused lemma -------------------
        first = _pick(unused, fl, most_frequent=True)
        assert first is not None
        comps: list[str] = [first]
        stars: list[bool] = [False]
        used.add(first)
        unstarred_slots[first] += 1
        taken_idx: set[int] = {positions[first][0]}

        # --- remaining components ------------------------------------------
        for _slot in range(1, arity):
            unused_ok = [
                l for l in positions
                if l not in used and free_index(l, taken_idx) is not None
            ]
            if unused_ok:
                pick = _pick(unused_ok, fl, most_frequent=False)
                assert pick is not None
                comps.append(pick)
                stars.append(False)
                used.add(pick)
                unstarred_slots[pick] += 1
                idx = free_index(pick, taken_idx)
                assert idx is not None
                taken_idx.add(idx)
                continue
            # fallback: ignore the "used" mark -> * duplicate, UNLESS the
            # lemma still needs unstarred slots to satisfy its multiplicity
            any_ok = [l for l in positions if free_index(l, taken_idx) is not None]
            if any_ok:
                pick = _pick(any_ok, fl, most_frequent=False)
                assert pick is not None
                star = unstarred_slots[pick] >= mult[pick]
                comps.append(pick)
                stars.append(star)
                if not star:
                    unstarred_slots[pick] += 1
                idx = free_index(pick, taken_idx)
                assert idx is not None
                taken_idx.add(idx)
                continue
            # final fallback (subquery shorter than arity w/ duplicates):
            # relax the index-distinctness requirement as well.
            pick = _pick(list(positions), fl, most_frequent=False)
            assert pick is not None
            comps.append(pick)
            stars.append(True)

        # canonicalize: sort components by FL-number, stars travel along.
        comps_c, stars_c = canonicalize_key(comps, stars, fl)
        keys.append(SelectedKey(components=comps_c, starred=stars_c))
    return keys
