"""Serving launcher for the paper's search system.

``python -m repro.launch.serve --queries "who are you who" "to be or not to be"``

Builds a synthetic corpus, shards it, and serves queries through the
Combiner (SE2.4) with per-query latency/postings accounting — the CPU-scale
end-to-end driver.  ``--algorithm`` switches between SE1/SE2.1–SE2.4 for
side-by-side comparison; ``--kill-shard`` demonstrates degraded fan-out.
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", nargs="+", default=[
        "who are you who", "to be or not to be", "what do you do all day",
    ])
    ap.add_argument("--algorithm", default="se2.4",
                    choices=["se1", "se2.1", "se2.2", "se2.3", "se2.4"])
    ap.add_argument("--n-docs", type=int, default=150)
    ap.add_argument("--n-shards", type=int, default=4)
    ap.add_argument("--sw-count", type=int, default=60)
    ap.add_argument("--fu-count", type=int, default=150)
    ap.add_argument("--max-distance", type=int, default=5)
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--kill-shard", type=int, action="append", default=[])
    args = ap.parse_args()

    from ..index.corpus import synthesize_corpus
    from ..search.distributed import ShardedSearchService

    print(f"building corpus ({args.n_docs} docs) and {args.n_shards} index shards...")
    store = synthesize_corpus(n_docs=args.n_docs, seed=7)
    svc = ShardedSearchService(
        store, n_shards=args.n_shards, sw_count=args.sw_count,
        fu_count=args.fu_count, max_distance=args.max_distance,
        algorithm=args.algorithm,
    )
    for q in args.queries:
        resp = svc.search(q, top_k=args.top_k, dead_shards=args.kill_shard)
        print(f"\nquery: {q!r}  ({args.algorithm}, {resp.n_subqueries} subqueries, "
              f"{resp.stats.postings_read} postings, "
              f"{resp.stats.elapsed_sec*1000:.1f} ms)")
        for d in resp.docs:
            frags = ", ".join(f"[{f.start},{f.end}]" for f in d.fragments[:4])
            print(f"  doc {d.doc_id:5d} score={d.score:.4f} fragments: {frags}")


if __name__ == "__main__":
    main()
