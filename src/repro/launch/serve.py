"""Serving launcher for the paper's search system (§5 end to end).

``python -m repro.launch.serve --queries "who are you who" "to be or not to be"``

Builds a synthetic corpus, shards it, and serves queries — by default
through the deadline-aware :class:`~repro.search.frontend.ServingFrontend`
(query planner + micro-batched fused dispatch + generation-keyed caches),
or through the raw per-algorithm engines with ``--no-frontend``.

Useful flags:

* ``--explain``       print each query's plan (lemma classes, §3 index-family
                      bindings, live posting-cost estimates) before serving;
* ``--deadline-ms``   per-request response-time budget (arXiv 2009.03679);
                      partial responses are flagged in the output;
* ``--repeat N``      serve the query list N times to show cache hit rates;
* ``--algorithm``     SE1/SE2.1–SE2.4 host loops or the fused device batch
                      (``--no-frontend`` path only);
* ``--kill-shard``    degraded fan-out demo (``--no-frontend`` path only);
* ``--snapshot-dir``  durable-index warm start (DESIGN.md §12 + §18): if the
                      directory holds a service snapshot, restore it, replay
                      each shard's write-ahead-log tail (post-snapshot ops
                      come back too — §18.2 zero data loss) and serve
                      straight from mmap'd disk pages — no corpus build, no
                      re-lemmatization; otherwise build the corpus once, arm
                      the §18 WAL and snapshot into the directory so the
                      NEXT run warm-starts (the crash-recovery loop);
* ``--daemon``        serve over the network (DESIGN.md §16): start the
                      continuous-batching :class:`ServiceDaemon` behind the
                      JSON-lines TCP transport and run until Ctrl-C;
                      ``--port`` picks the listen port (0 = ephemeral,
                      printed on startup); with ``--replicas N`` (N > 1) the
                      replicas run behind the §18.3 lease-based
                      :class:`ReplicatedServiceDaemon` — kill the primary
                      from a client (``--kill-primary``) and the successor
                      re-admits its in-flight requests exactly once;
* ``--connect``       be the client instead: send ``--queries`` to a
                      running ``--daemon`` at HOST:PORT and print the wire
                      responses (no corpus build on this side);
* ``--chaos-seed``    serve under a seeded fault schedule (DESIGN.md §14):
                      shard crashes/kills, straggler delays, snapshot
                      bit-flips fire deterministically at the §14 injection
                      points while the resilience layer detects, retries
                      and recovers.  Responses stay exact or flagged
                      DEGRADED; pair with ``--snapshot-dir`` so killed
                      shards can recover from durable snapshots, and with
                      ``--repeat`` to watch recovery happen mid-run.
"""

from __future__ import annotations

import argparse


def _print_response(resp, show_partial: bool = True) -> None:
    flags = []
    if resp.stats.cache_hits:
        flags.append("CACHED")
    if resp.stats.shards_degraded:
        flags.append(f"DEGRADED ({resp.stats.shards_degraded} shard(s) down)")
    if resp.stats.shed:
        flags.append("SHED")
    if show_partial and resp.stats.partial and not resp.stats.shards_degraded:
        flags.append(
            f"PARTIAL (skipped {resp.stats.skipped_subqueries} subqueries)"
        )
    # §14 failure-path counters (batch-level): only shown when non-zero, so
    # fault-free serving output is unchanged
    for name in ("retries", "hedges", "recoveries"):
        n = getattr(resp.stats, name)
        if n:
            flags.append(f"{name}={n}")
    tag = f"  [{', '.join(flags)}]" if flags else ""
    print(
        f"\nquery: {resp.query!r}  ({resp.n_subqueries} subqueries, "
        f"{resp.stats.postings_read} postings, "
        f"{resp.stats.elapsed_sec * 1000:.1f} ms){tag}"
    )
    for d in resp.docs:
        frags = ", ".join(f"[{f.start},{f.end}]" for f in d.fragments[:4])
        print(f"  doc {d.doc_id:5d} score={d.score:.4f} fragments: {frags}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", nargs="+", default=[
        "who are you who", "to be or not to be", "what do you do all day",
    ])
    ap.add_argument("--algorithm", default="se2.4",
                    choices=["se1", "se2.1", "se2.2", "se2.3", "se2.4", "fused"],
                    help="engine for the raw-engine path; passing a non-default "
                         "value implies --no-frontend (the frontend always "
                         "plans into the fused pipeline)")
    ap.add_argument("--n-docs", type=int, default=150)
    ap.add_argument("--n-shards", type=int, default=4)
    ap.add_argument("--sw-count", type=int, default=60)
    ap.add_argument("--fu-count", type=int, default=150)
    ap.add_argument("--max-distance", type=int, default=5)
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--kill-shard", type=int, action="append", default=[],
                    help="simulate dead shards; implies --no-frontend (the "
                         "frontend serves every live shard)")
    ap.add_argument("--no-frontend", action="store_true",
                    help="serve through the raw engines instead of the "
                         "planner + frontend layer")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request response-time budget (frontend mode)")
    ap.add_argument("--repeat", type=int, default=1,
                    help="serve the query list this many times (shows the "
                         "result-cache hit rate in frontend mode)")
    ap.add_argument("--explain", action="store_true",
                    help="print each query's plan before serving")
    ap.add_argument("--snapshot-dir", default=None,
                    help="warm start from (or bootstrap) a durable index "
                         "snapshot directory (DESIGN.md §12)")
    ap.add_argument("--bulk-ingest", action="store_true",
                    help="cold-start through the §17 external-memory SPIMI "
                         "pipeline: shards spill+merge straight to disk "
                         "under --snapshot-dir (required) instead of "
                         "building in RAM, then serve from the published "
                         "snapshot — byte-identical to the in-RAM build")
    ap.add_argument("--bulk-workers", type=int, default=1,
                    help="spill worker processes for --bulk-ingest")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="serve under the §14 seeded fault schedule: "
                         "deterministic shard crashes/kills, stragglers and "
                         "snapshot bit-flips, detected and recovered by the "
                         "resilience layer (recovery needs --snapshot-dir)")
    ap.add_argument("--daemon", action="store_true",
                    help="serve over TCP through the §16 continuous-batching "
                         "daemon until interrupted (frontend mode only)")
    ap.add_argument("--port", type=int, default=0,
                    help="TCP listen port for --daemon (0 = ephemeral, "
                         "printed on startup)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="daemon replicas behind --daemon (one shared "
                         "snapshot+WAL lineage).  With N > 1 the replicas "
                         "run behind the §18.3 primary lease: kill the "
                         "primary (--connect ... --kill-primary) and the "
                         "successor re-admits its in-flight requests "
                         "exactly once with byte-identical responses")
    ap.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="client mode: send --queries to a running --daemon "
                         "and print the wire responses")
    ap.add_argument("--kill-primary", action="store_true",
                    help="client mode: kill the serving daemon's primary "
                         "replica (§18.3 failover walkthrough) before "
                         "sending --queries")
    ap.add_argument("--arena-budget-mb", type=float, default=64.0,
                    help="device-resident posting arena byte budget "
                         "(DESIGN.md §13; 0 disables — frontend mode only): "
                         "hot posting columns upload once per index "
                         "generation and serving batches gather/pack on "
                         "device instead of in host numpy")
    args = ap.parse_args()

    import time
    from pathlib import Path

    if args.connect:
        from ..search.service import request_over_tcp

        host, _, port = args.connect.rpartition(":")
        address = (host or "127.0.0.1", int(port))
        if args.kill_primary:
            out = request_over_tcp(address, {"op": "kill_primary"})
            if "error" in out:
                print(f"kill_primary: {out['error']}")
            else:
                print(f"kill_primary: replica {out['killed']} killed; "
                      f"successor takes over after the lease expires")
        for q in args.queries * args.repeat:
            payload = {"query": q, "top_k": args.top_k}
            if args.deadline_ms is not None:
                payload["deadline_ms"] = args.deadline_ms
            out = request_over_tcp(address, payload)
            flags = [f for f in ("partial", "shed") if out.get(f)]
            tag = f"  [{', '.join(f.upper() for f in flags)}]" if flags else ""
            print(f"\nquery: {out['query']!r}  "
                  f"(batch_size={out.get('batch_size')}, "
                  f"replica={out.get('replica')}, "
                  f"wait={1e3 * (out.get('queue_wait_sec') or 0):.1f} ms){tag}")
            for d in out["docs"]:
                frags = ", ".join(f"[{s},{e}]" for _, s, e in d["fragments"][:4])
                print(f"  doc {d['doc_id']:5d} score={d['score']:.4f} "
                      f"fragments: {frags}")
        m = request_over_tcp(address, {"op": "metrics"})["metrics"]
        if "failovers" in m:  # §18.3 replicated daemon
            print(f"\ndaemon: primary={m['primary']} alive={m['alive']}, "
                  f"{m['completed']}/{m['requests']} completed, "
                  f"{m['failovers']} failover(s), "
                  f"{m['readmitted']} re-admitted exactly-once")
        else:
            print(f"\ndaemon: {m['completed']} completed, {m['shed_queue']} shed, "
                  f"{m['batches']} batches, "
                  f"mean occupancy {m['mean_batch_occupancy']:.2f}")
        return

    from ..index.corpus import synthesize_corpus
    from ..search.distributed import ShardedSearchService

    svc = None
    if args.snapshot_dir and (Path(args.snapshot_dir) / "service.json").exists():
        t0 = time.perf_counter()
        svc = ShardedSearchService.restore(args.snapshot_dir)
        if args.algorithm != ap.get_default("algorithm"):
            svc.algorithm = args.algorithm  # explicit CLI choice wins
        else:
            args.algorithm = svc.algorithm  # else keep the stored engine
        n_docs = sum(len(ix.documents) for ix in svc.indexers)
        print(f"warm start: restored {svc.n_shards} shards / {n_docs} docs "
              f"from {args.snapshot_dir} in "
              f"{(time.perf_counter() - t0) * 1000:.0f} ms (no rebuild)")
        replayed = sum(ix.last_wal_replay["records"] for ix in svc.indexers)
        if any(ix.wal is None for ix in svc.indexers):
            # pre-§18 snapshot tree (no wal/ dirs): start logging now so the
            # NEXT crash is covered by the zero-data-loss contract
            svc.enable_wal(args.snapshot_dir)
        if replayed:
            replay_ms = 1e3 * sum(
                ix.last_wal_replay["seconds"] for ix in svc.indexers)
            print(f"wal: replayed {replayed} post-snapshot record(s) in "
                  f"{replay_ms:.0f} ms (§18.2 zero-data-loss)")
        # build flags describe a NEW corpus; a warm start serves the stored
        # one — surface any conflicting explicit flags instead of silently
        # dropping them (delete the snapshot dir to rebuild)
        ignored = [
            f"--{name.replace('_', '-')}={getattr(args, name)} "
            f"(snapshot has {stored})"
            for name, stored in (
                ("n_shards", svc.n_shards),
                ("sw_count", svc.sw_count),
                ("fu_count", svc.fu_count),
                ("max_distance", svc.max_distance),
                ("n_docs", n_docs),
            )
            # flag non-default (user typed it) AND disagreeing with the store
            if getattr(args, name) != ap.get_default(name)
            and getattr(args, name) != stored
        ]
        if ignored:
            print("note: warm start ignores build flags: " + ", ".join(ignored))
    if svc is None and args.bulk_ingest:
        if not args.snapshot_dir:
            ap.error("--bulk-ingest needs --snapshot-dir (the spill/merge "
                     "pipeline publishes a §12.2 snapshot tree)")
        print(f"bulk ingest: corpus ({args.n_docs} docs) -> "
              f"{args.n_shards} shard stores under {args.snapshot_dir}...")
        t0 = time.perf_counter()
        store = synthesize_corpus(n_docs=args.n_docs, seed=7)
        svc, stats = ShardedSearchService.bulk_ingest(
            store, args.snapshot_dir, n_shards=args.n_shards,
            sw_count=args.sw_count, fu_count=args.fu_count,
            max_distance=args.max_distance, algorithm=args.algorithm,
            workers=args.bulk_workers,
        )
        n_docs = sum(s.n_docs for s in stats)
        total_s = time.perf_counter() - t0
        print(f"bulk ingest: {n_docs} docs / {len(stats)} shards in "
              f"{total_s * 1000:.0f} ms "
              f"({sum(s.spill_bytes for s in stats) / 1024:.0f} KB spilled, "
              f"{n_docs / total_s:.0f} docs/s incl. corpus synthesis); "
              f"rerun to warm-start")
    if svc is None:
        print(f"building corpus ({args.n_docs} docs) and {args.n_shards} index shards...")
        t0 = time.perf_counter()
        store = synthesize_corpus(n_docs=args.n_docs, seed=7)
        svc = ShardedSearchService(
            store, n_shards=args.n_shards, sw_count=args.sw_count,
            fu_count=args.fu_count, max_distance=args.max_distance,
            algorithm=args.algorithm,
            # chaos mode wants incremental shards too: snapshot recovery
            # (the §14 failure path) only exists for IncrementalIndexer
            incremental=bool(args.snapshot_dir) or args.chaos_seed is not None,
        )
        build_ms = (time.perf_counter() - t0) * 1000
        if args.snapshot_dir:
            # arm the §18 WAL before the first snapshot so snap_0 carries a
            # checkpoint anchor and every later op is durably logged
            svc.enable_wal(args.snapshot_dir)
            svc.snapshot(args.snapshot_dir)
            print(f"cold start: built in {build_ms:.0f} ms, snapshotted to "
                  f"{args.snapshot_dir} (rerun to warm-start; §18 WAL armed)")

    if args.chaos_seed is not None:
        from ..search.resilience import FaultInjector, ResiliencePolicy

        injector = FaultInjector.from_seed(args.chaos_seed, n_shards=svc.n_shards)
        svc.enable_resilience(
            policy=ResiliencePolicy(snapshot_dir=args.snapshot_dir),
            injector=injector,
        )
        print(f"chaos: seed {args.chaos_seed} armed "
              f"{len(injector.schedule)} fault event(s) at the §14 "
              f"injection points"
              + ("" if args.snapshot_dir else
                 " (no --snapshot-dir: killed shards stay degraded)"))

    # --kill-shard / a non-default --algorithm only make sense on the raw
    # engine path: honor them there instead of silently ignoring them
    if args.kill_shard or args.algorithm != "se2.4":
        if not args.no_frontend:
            print("note: --kill-shard/--algorithm select the raw engine path "
                  "(frontend disabled for this run)")
        args.no_frontend = True

    if args.no_frontend:
        for q in args.queries * args.repeat:
            resp = svc.search(q, top_k=args.top_k, dead_shards=args.kill_shard)
            _print_response(resp, show_partial=False)
        _print_resilience(svc.resilience_metrics())
        return

    from ..search.frontend import SearchRequest, ServingFrontend

    deadline = args.deadline_ms / 1000.0 if args.deadline_ms is not None else None
    frontend = ServingFrontend(
        svc,
        default_deadline_sec=deadline,
        arena_budget_mb=args.arena_budget_mb,
    )
    # warm through the REAL serving path with the actual query slate and
    # top_k: shape budgets and top_k are static device-program arguments,
    # so this compiles exactly the programs the first served round reuses
    warm = frontend.warmup(queries=args.queries, top_k=args.top_k)
    print(f"warmup: precompiled {warm['programs']} device program(s) in "
          f"{warm['seconds'] * 1000:.0f} ms (cold p99 excludes jit compile)")

    if args.daemon:
        from ..search.service import (
            ReplicatedServiceDaemon,
            ServiceDaemon,
            serve_tcp,
        )

        fronts = [frontend] + [
            ServingFrontend(
                svc,
                default_deadline_sec=deadline,
                arena_budget_mb=args.arena_budget_mb,
            )
            for _ in range(max(1, args.replicas) - 1)
        ]
        if args.replicas > 1:
            # §18.3: N independent daemon replicas behind a lease-based
            # primary.  --kill-primary (client mode) crashes the primary;
            # the successor re-admits its in-flight tickets exactly once
            # under the original request ids.
            daemon = ReplicatedServiceDaemon([ServiceDaemon([f]) for f in fronts])
        else:
            daemon = ServiceDaemon(fronts)
        server = serve_tcp(daemon, port=args.port)
        host, port = server.address
        print(f"daemon: {len(fronts)} replica(s) listening on {host}:{port}"
              + (" (§18.3 lease-based failover armed)"
                 if args.replicas > 1 else ""))
        print(f"  try:  python -m repro.launch.serve --connect {host}:{port} "
              f"--queries 'who are you who'"
              + (" --kill-primary" if args.replicas > 1 else ""))
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            pass
        finally:
            server.shutdown()
            server.server_close()
            daemon.stop()
            m = daemon.metrics()
            if "failovers" in m:  # §18.3 replicated daemon
                print(f"\ndaemon: primary={m['primary']} alive={m['alive']}, "
                      f"{m['completed']}/{m['requests']} completed, "
                      f"{m['failovers']} failover(s), "
                      f"{m['readmitted']} re-admitted exactly-once")
            else:
                print(f"\ndaemon: {m['completed']} completed, "
                      f"{m['shed_queue']} shed, {m['batches']} batches, "
                      f"mean occupancy {m['mean_batch_occupancy']:.2f}")
        return
    if args.explain:
        for q in args.queries:
            print(frontend.planner.plan(q).explain())
    for _round in range(args.repeat):
        requests = [SearchRequest(q, top_k=args.top_k) for q in args.queries]
        for resp in frontend.search_many(requests):
            _print_response(resp)
    m = frontend.metrics()
    print(
        f"\nfrontend: served {m['served']} requests, "
        f"result-cache hit rate {m['result_cache_hit_rate']:.2f}, "
        f"posting-cache hit rate {m['posting_cache_hit_rate']:.2f} "
        f"({m['posting_cache_entries']} slices, "
        f"{m['posting_cache_bytes'] / 1024:.0f} KB), "
        f"{m['partial_responses']} partial responses"
    )
    if "arena_bytes" in m:
        print(
            f"arena: {m['arena_entries']} resident families, "
            f"{m['arena_bytes'] / (1 << 20):.1f} MB, "
            f"hit rate {m['arena_hit_rate']:.2f} "
            f"({m['arena_uploads']} uploads, "
            f"{m['arena_upload_bytes'] / (1 << 20):.1f} MB shipped once per "
            f"generation)"
        )
    _print_resilience(m.get("resilience", {}), sheds=m.get("sheds", 0))


def _print_resilience(rm: dict, sheds: int = 0) -> None:
    """Post-run §14 report: fired faults, breaker states, recoveries.
    Silent when the resilience layer is off (no --chaos-seed, no
    --kill-shard), so fault-free output is unchanged."""
    if not rm:
        return
    print(
        f"resilience: {rm['fired']} fault(s) fired, "
        f"{rm['recoveries']} snapshot recoveries, "
        f"{rm['errors']} probe errors, "
        f"breakers {rm['breaker_states']}, "
        f"down={rm['down']} stragglers={rm['stragglers']} sheds={sheds}"
    )


if __name__ == "__main__":
    main()
