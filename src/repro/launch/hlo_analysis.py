"""Trip-count-aware cost analysis over compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE (verified
empirically: a 7-step scan of a 128^3 matmul reports 1 matmul of FLOPs), so
for scanned layer stacks it under-reports by ~n_layers.  XLA annotates
``backend_config={"known_trip_count":{"n":...}}`` on every while it bounds —
this module walks the computation graph, multiplies loop bodies out, and
produces per-device totals:

  * flops             — dots (2*M*N*K from contracting dims) + elementwise
  * hbm_bytes         — per-instruction operand+result bytes at fusion
                        granularity (post-fusion HLO ≈ one kernel per instr)
  * collective_bytes  — result bytes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute,
                        multiplied through enclosing loops

Shapes in the partitioned module are per-device shard shapes, so totals are
per-device — exactly what the roofline terms need.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Iterable

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|pred|c64|c128)\[([\d,]*)\]"
)

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "logistic", "rsqrt", "sqrt", "negate",
    "cosine", "sine", "select", "compare", "and", "or", "xor", "clamp",
    "convert", "floor", "ceil", "round-nearest-afz", "sign", "abs",
    "exponential-minus-one", "log-plus-one", "atan2", "remainder",
}

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
}

# instruction: [%]name = <shape-or-tuple> opname(...)
# the shape may be a tuple containing /*index=N*/ comments; the op name is
# the first lowercase word directly followed by '(' after the '='
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([a-z][\w\-]*)\("
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLED_RE = re.compile(r"(?:to_apply|condition|body|calls)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_stats(shape_str: str) -> tuple[int, int]:
    """(numel, bytes) over all array components of a shape string."""
    numel = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        numel += n
        nbytes += n * _DTYPE_BYTES[dt]
    return numel, nbytes


def _dims_of(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v * mult

    @property
    def collective_bytes(self) -> float:
        return sum(self.collectives.values())


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    op: str
    line: str


def _parse_computations(hlo: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    current: list[_Instr] | None = None
    entry_names: list[str] = []
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        # computation header: "%name (args) -> type {" possibly prefixed
        # ENTRY; args may contain nested tuple parens
        hm = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{$", s)
        if hm and not s.startswith("//"):
            current = []
            comps[hm.group(1)] = current
            if s.startswith("ENTRY") or "ENTRY" in line.split("(")[0]:
                entry_names.append(hm.group(1))
            continue
        if s == "}" or s.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        im = _INSTR_RE.match(s)
        if im:
            current.append(_Instr(im.group(1), im.group(2), im.group(3), s))
        else:
            # parameters appear as "%p = f32[...] parameter(0)" (already
            # matched); anything else is ignorable metadata
            pm = re.match(r"^\s*%?([\w.\-]+)\s*=\s*(\S+)\s+parameter\(", s)
            if pm:
                current.append(_Instr(pm.group(1), pm.group(2), "parameter", s))
    comps["__entry__"] = comps.get(entry_names[0], []) if entry_names else []
    return comps


def _instr_cost(ins: _Instr, symtab: dict[str, str]) -> HloCost:
    c = HloCost()
    numel, nbytes = _shape_stats(ins.shape)
    op = ins.op
    if op in _FREE_OPS:
        return c
    # ---- flops -------------------------------------------------------------
    if op == "dot":
        operands = _OPERAND_RE.findall(ins.line.split("(", 1)[1])
        lhs_shape = symtab.get(operands[0], "") if operands else ""
        contract = 1
        cm = _CONTRACT_RE.search(ins.line)
        if cm and cm.group(1):
            ldims = _dims_of(lhs_shape)
            for d in cm.group(1).split(","):
                di = int(d)
                if di < len(ldims):
                    contract *= ldims[di]
        c.flops += 2.0 * numel * contract
    elif op in _ELEMENTWISE:
        c.flops += numel
    elif op in ("reduce", "reduce-window", "scatter", "gather", "cumsum"):
        # charge the larger of input/output element counts
        operands = _OPERAND_RE.findall(ins.line.split("(", 1)[1])
        in_numel = max(
            (_shape_stats(symtab.get(o, ""))[0] for o in operands[:1]), default=0
        )
        c.flops += max(numel, in_numel)
    elif op == "sort":
        # comparison-sort work: n log2(n) compares over all sorted columns
        # (the serving programs' packed/segmented sorts are their dominant
        # non-gather compute — charging them keeps the roofline honest)
        c.flops += numel * max(1.0, float((max(numel, 2) - 1).bit_length()))
    # ---- collectives --------------------------------------------------------
    base = op.replace("-start", "")
    if base in _COLLECTIVES:
        c.collectives[base] = c.collectives.get(base, 0.0) + nbytes
    if op.endswith("-done"):
        return c  # bytes were charged at -start
    # ---- hbm traffic (fusion-granularity kernels) ---------------------------
    if op == "dynamic-update-slice":
        # XLA updates in place (buffer aliasing): traffic = the update slice,
        # not the full operand — critical for KV-cache decode accounting
        operands = _OPERAND_RE.findall(ins.line.split("(", 1)[1])
        upd = operands[1] if len(operands) > 1 else ""
        c.hbm_bytes += 2.0 * _shape_stats(symtab.get(upd, ""))[1]
        return c
    c.hbm_bytes += nbytes  # result write
    if op in ("dynamic-slice", "slice", "gather", "broadcast", "iota"):
        # reads only the sliced/gathered bytes (~= result), never the
        # full operand — loop bodies slice hoisted loop-invariant tensors
        c.hbm_bytes += nbytes
        return c
    if op in ("fusion", "dot", "copy", "transpose", "concatenate", "reduce",
              "reduce-window", "scatter", "convert", "custom-call",
              "sort", "select-and-scatter") or base in _COLLECTIVES:
        args = ins.line.split("(", 1)[1]
        # strip called-computation/config tails to avoid phantom operands
        args = args.split("), ")[0]
        result_bytes = max(nbytes, 1)
        for o in _OPERAND_RE.findall(args):
            ob = _shape_stats(symtab.get(o, ""))[1]
            if op == "fusion":
                # fused dynamic-slices read O(result)-sized windows of big
                # operands; cap each operand's charge at 8x the output
                ob = min(ob, 8 * result_bytes)
            c.hbm_bytes += ob
    return c


def analyze_hlo(hlo: str) -> HloCost:
    comps = _parse_computations(hlo)
    memo: dict[str, HloCost] = {}

    def comp_cost(name: str, stack: tuple[str, ...] = ()) -> HloCost:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return HloCost()
        total = HloCost()
        symtab = {i.name: i.shape for i in comps[name]}
        for ins in comps[name]:
            if ins.op == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    trip = int(tm.group(1))
                called = _CALLED_RE.findall(ins.line)
                for sub in called:  # condition + body
                    total.add(comp_cost(sub, stack + (name,)), mult=trip)
                continue
            if ins.op == "conditional":
                bm = _BRANCHES_RE.search(ins.line)
                if bm:
                    subs = [
                        s.strip().lstrip("%")
                        for s in bm.group(1).split(",")
                        if s.strip()
                    ]
                    branch_costs = [comp_cost(s, stack + (name,)) for s in subs]
                    if branch_costs:
                        big = max(branch_costs, key=lambda x: x.flops + x.hbm_bytes)
                        total.add(big)
                continue
            total.add(_instr_cost(ins, symtab))
            if ins.op in ("fusion", "call", "custom-call", "async-start"):
                for sub in _CALLED_RE.findall(ins.line):
                    sub_cost = comp_cost(sub, stack + (name,))
                    # inner flops count; inner bytes don't (registers/VMEM)
                    inner = HloCost(flops=sub_cost.flops, collectives=dict(sub_cost.collectives))
                    total.add(inner)
        memo[name] = total
        return total

    return comp_cost("__entry__")
