"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first init.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; only gradient reduction,
document-shard fan-out and top-k merges cross the slow ``pod`` (DCI) axis.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_smoke_mesh", "MESH_AXES"]

MESH_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
