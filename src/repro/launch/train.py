"""Training launcher: ``python -m repro.launch.train --arch <id> [options]``.

Runs REAL steps (reduced configs on CPU; full configs on a TPU slice), with
checkpoint/restart, deterministic data, straggler monitoring hooks and
optional cross-pod int8 gradient compression.  The same Cell abstraction the
dry-run lowers is what executes here — there is one code path.
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs import get_reduced_spec, get_spec
from ..data.pipeline import LMTokenPipeline, RecsysBatchPipeline
from ..data.sampler import NeighborSampler, random_graph
from .mesh import make_smoke_mesh
from .steps import build_cell


def _concrete_batch(spec, shape_name, cell, seed=0):
    """Materialize one real batch matching the cell's abstract batch."""
    kw = spec.shapes[shape_name].kwargs
    cfg = spec.cfg_for(shape_name)
    rng = np.random.default_rng(seed)
    if spec.family == "lm":
        pipe = LMTokenPipeline(cfg.vocab, kw["seq_len"], kw["global_batch"], seed=seed)
        return pipe.next_batch(), pipe
    if spec.family == "recsys":
        pipe = RecsysBatchPipeline(
            cfg.field_vocab, kw["batch"], n_dense=cfg.n_dense,
            hist_len=cfg.hist_len if cfg.model == "mind" else 0, seed=seed,
        )
        b = pipe.next_batch()
        if cfg.model == "mind":
            b["hist_ids"] = np.clip(b["hist_ids"], -1, cfg.field_vocab[0] - 1)
            b["target_id"] = np.clip(b["target_id"], 0, cfg.field_vocab[0] - 1)
        else:
            b["sparse_ids"] = np.stack(
                [rng.integers(0, v, kw["batch"]) for v in cfg.field_vocab], axis=1
            ).astype(np.int32)
        return b, pipe
    if spec.family == "gnn":
        n, e, f = kw["n_nodes"], kw["n_edges"], kw["d_feat"]
        g = random_graph(max(n, 8), avg_degree=4, d_feat=f, n_classes=kw["n_classes"], seed=seed)
        batch = {
            "x": g.features[:n],
            "src": rng.integers(0, n, e).astype(np.int32),
            "dst": rng.integers(0, n, e).astype(np.int32),
            "edge_mask": np.ones(e, np.int32),
        }
        task_graph = kw["task"] == "graph"
        ng = kw.get("batch_graphs", 1)
        nl = ng if task_graph else n
        batch["labels"] = rng.integers(0, kw["n_classes"], nl).astype(np.int32)
        batch["label_mask"] = np.ones(nl, np.int32)
        if task_graph:
            batch["graph_ids"] = np.repeat(np.arange(ng), n // ng).astype(np.int32)
        return batch, None
    raise ValueError(spec.family)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None, help="defaults to the train cell")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--full-scale", action="store_true",
                    help="use the full config (requires a real TPU slice)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    spec = get_spec(args.arch) if args.full_scale else get_reduced_spec(args.arch)
    shape = args.shape
    if shape is None:
        shape = next(n for n, c in spec.shapes.items() if c.step == "train")
    mesh = make_smoke_mesh() if not args.full_scale else None
    if mesh is None:
        from .mesh import make_production_mesh

        mesh = make_production_mesh()
    cell = build_cell(spec, shape, mesh)

    key = jax.random.key(0)
    if spec.family == "lm":
        from ..models import transformer

        params = transformer.init_params(key, spec.cfg_for(shape))
    elif spec.family == "gnn":
        from ..models import gnn

        params = gnn.init_gat_params(key, spec.cfg_for(shape))
    else:
        from ..models import recsys

        params = recsys.init_recsys_params(key, spec.cfg_for(shape))
    from ..optim import adamw_init

    opt_state = adamw_init(params)
    batch, pipe = _concrete_batch(spec, shape, cell)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    with mesh:
        step_fn = jax.jit(cell.fn)
        mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        t0 = time.time()
        for step in range(args.steps):
            if pipe is not None and step > 0:
                nb = pipe.next_batch()
                batch = {k: jnp.asarray(v) for k, v in nb.items()} if set(nb) == set(batch) else batch
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % 5 == 0 or step == args.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                print(f"step {step:4d} " + " ".join(f"{k}={v:.4f}" for k, v in m.items()))
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save_async(step + 1, {"params": params, "opt": opt_state,
                                          "pipe": pipe.state.as_tree() if pipe else {}})
        if mgr:
            mgr.wait()
        dt = time.time() - t0
        print(f"done: {args.steps} steps in {dt:.1f}s ({dt/args.steps*1000:.0f} ms/step)")


if __name__ == "__main__":
    main()
