"""Cell builder: (architecture, shape) -> jit-able step + abstract inputs +
sharding specs.  This is what both the dry-run and the real launchers use.

All full-scale inputs are ``jax.ShapeDtypeStruct``s (params via
``jax.eval_shape`` over the initializer) — nothing is allocated until a
launcher decides to.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.common import ArchSpec, ShapeCell
from ..models import gnn, recsys, transformer
from ..optim import AdamWConfig, adamw_init, adamw_update, global_norm
from ..parallel.sharding import (
    batch_specs,
    data_axes,
    gnn_specs,
    lm_param_specs,
    recsys_param_specs,
)
from ..search.serving_step import build_step, serve_step_sharded

__all__ = ["Cell", "build_cell"]

S = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    step: str
    fn: Callable  # positional args match `abstract_args`
    abstract_args: tuple
    in_specs: tuple
    out_specs: Any  # None -> let GSPMD infer
    model_flops_fn: Callable[[], float]  # 6*N*D-style useful-work model
    notes: str = ""


# ---------------------------------------------------------------------------
# spec sanitation: drop mesh axes that do not divide the dim
# ---------------------------------------------------------------------------


def _axis_size(mesh: Mesh, axis) -> int:
    names = axis if isinstance(axis, tuple) else (axis,)
    out = 1
    for n in names:
        out *= dict(zip(mesh.axis_names, mesh.devices.shape))[n]
    return out


def sanitize_specs(spec_tree: Any, shape_tree: Any, mesh: Mesh) -> Any:
    def fix(spec, leaf):
        if spec is None or not isinstance(spec, P):
            return spec
        dims = leaf.shape
        new = []
        for i in range(len(dims)):
            axis = spec[i] if i < len(spec) else None
            if axis is None:
                new.append(None)
            elif dims[i] % _axis_size(mesh, axis) == 0:
                new.append(axis)
            else:
                new.append(None)
        return P(*new)

    return jax.tree.map(
        fix, spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, P) or x is None
    )


def _rep_like(tree: Any) -> Any:
    return jax.tree.map(lambda _: P(), tree)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh) -> Cell:
    cfg: transformer.TransformerConfig = spec.cfg_for(cell.name)
    if "sliding_window" in cell.kwargs:
        cfg = dataclasses.replace(cfg, sliding_window=cell.kwargs["sliding_window"])
    seq = cell.kwargs["seq_len"]
    batch = cell.kwargs["global_batch"]
    da = data_axes(mesh)
    key = jax.random.key(0)
    p_shapes = jax.eval_shape(functools.partial(transformer.init_params, cfg=cfg), key)
    tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    p_specs = lm_param_specs(p_shapes, kv_shardable=(cfg.n_kv_heads % tp == 0), fsdp=cfg.fsdp)

    n_tok = batch * seq
    n_active = cfg.active_param_count()

    if cell.step == "train":
        opt_cfg = AdamWConfig()
        o_shapes = jax.eval_shape(adamw_init, p_shapes)
        o_specs = {
            "m": p_specs, "v": p_specs, "master": p_specs, "step": P(),
        }
        b_shapes = {
            "tokens": S((batch, seq), jnp.int32),
            "targets": S((batch, seq), jnp.int32),
            "mask": S((batch, seq), jnp.int32),
        }
        b_specs = batch_specs(b_shapes, mesh)

        n_micro = max(1, cfg.microbatches)

        def train_fn(params, opt_state, bat):
            if n_micro > 1:
                # gradient accumulation: peak activation memory scales with
                # B/n_micro instead of B (EXPERIMENTS.md §Perf-4).  The
                # constraint pins the MICRO axis replicated and the batch
                # axis data-sharded — otherwise GSPMD shards the micro axis
                # and every device runs all microbatches (measured 5.75x
                # compute, §Perf-4 refuted iteration).
                mbs = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]),
                        P(None, da, *([None] * (x.ndim - 1))),
                    ),
                    bat,
                )
                zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def micro(carry, mb):
                    gacc, nll_a, aux_a = carry
                    (loss, metrics), grads = jax.value_and_grad(
                        transformer.loss_fn, has_aux=True
                    )(params, mb, cfg)
                    gacc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32), gacc, grads
                    )
                    return (gacc, nll_a + metrics["nll"], aux_a + metrics["aux"]), None

                (gacc, nll, aux), _ = jax.lax.scan(
                    micro, (zero, jnp.zeros(()), jnp.zeros(())), mbs
                )
                grads = jax.tree.map(lambda g: g / n_micro, gacc)
                nll, aux = nll / n_micro, aux / n_micro
                loss = nll + cfg.aux_loss_weight * aux
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    transformer.loss_fn, has_aux=True
                )(params, bat, cfg)
                nll, aux = metrics["nll"], metrics["aux"]
            master, new_state = adamw_update(grads, opt_state, opt_cfg)
            new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
            out_metrics = {
                "loss": loss, "nll": nll, "aux": aux,
                "grad_norm": global_norm(grads),
            }
            return new_params, new_state, out_metrics

        return Cell(
            arch_id=spec.arch_id, shape_name=cell.name, step="train",
            fn=train_fn,
            abstract_args=(p_shapes, o_shapes, b_shapes),
            in_specs=(
                sanitize_specs(p_specs, p_shapes, mesh),
                sanitize_specs(o_specs, o_shapes, mesh),
                sanitize_specs(b_specs, b_shapes, mesh),
            ),
            out_specs=None,
            model_flops_fn=lambda: 6.0 * n_active * n_tok,
        )

    if cell.step == "prefill":
        tok = {"tokens": S((batch, seq), jnp.int32)}
        t_specs = batch_specs(tok, mesh)

        def prefill_fn(params, bat):
            return transformer.prefill_step(params, bat["tokens"], cfg)

        return Cell(
            arch_id=spec.arch_id, shape_name=cell.name, step="prefill",
            fn=prefill_fn,
            abstract_args=(p_shapes, tok),
            in_specs=(
                sanitize_specs(p_specs, p_shapes, mesh),
                sanitize_specs(t_specs, tok, mesh),
            ),
            out_specs=None,
            model_flops_fn=lambda: 2.0 * n_active * n_tok,
        )

    # decode (decode_32k / long_500k)
    dh = cfg.d_head
    cache_shape = S((cfg.n_layers, batch, seq, cfg.n_kv_heads, dh), cfg.jdtype)
    # context-parallel decode: cache sequence dim shards over `model`; the
    # per-layer collectives are softmax stats + a [B,H,Dh] out psum (KBs)
    # instead of gathering score/V tensors (EXPERIMENTS.md §Perf-2)
    cache_spec = P(None, da, "model", None, None)
    args = (
        p_shapes,
        {"k": cache_shape, "v": cache_shape},
        S((batch, 1), jnp.int32),
        S((), jnp.int32),
    )
    in_specs = (
        sanitize_specs(p_specs, p_shapes, mesh),
        sanitize_specs({"k": cache_spec, "v": cache_spec}, args[1], mesh),
        sanitize_specs(P(da, None), args[2], mesh),
        P(),
    )

    def decode_fn(params, cache, tokens, cache_len):
        return transformer.decode_step(params, cache, tokens, cache_len, cfg)

    # useful work for one decoded token: active params + KV reads
    attended = min(seq, cfg.sliding_window or seq)
    flops = 2.0 * n_active * batch + 4.0 * batch * attended * cfg.n_heads * dh * cfg.n_layers

    return Cell(
        arch_id=spec.arch_id, shape_name=cell.name, step="decode",
        fn=decode_fn,
        abstract_args=args,
        in_specs=in_specs,
        out_specs=None,
        model_flops_fn=lambda: flops,
        notes=f"sliding_window={cfg.sliding_window}",
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh) -> Cell:
    cfg: gnn.GATConfig = spec.cfg_for(cell.name)
    kw = cell.kwargs
    n, e, f = kw["n_nodes"], kw["n_edges"], kw["d_feat"]
    task_graph = kw["task"] == "graph"
    n_graphs = kw.get("batch_graphs", 1)
    key = jax.random.key(0)
    p_shapes = jax.eval_shape(functools.partial(gnn.init_gat_params, cfg=cfg), key)
    p_specs = _rep_like(p_shapes)  # GAT params are tiny: replicate
    opt_cfg = AdamWConfig(lr=5e-3, weight_decay=5e-4)
    o_shapes = jax.eval_shape(adamw_init, p_shapes)

    b_shapes: dict[str, Any] = {
        "x": S((n, f), jnp.float32),
        "src": S((e,), jnp.int32),
        "dst": S((e,), jnp.int32),
        "edge_mask": S((e,), jnp.int32),
        "labels": S((n_graphs if task_graph else n,), jnp.int32),
        "label_mask": S((n_graphs if task_graph else n,), jnp.int32),
    }
    if task_graph:
        b_shapes["graph_ids"] = S((n,), jnp.int32)
    b_specs = gnn_specs(b_shapes, mesh, shard_nodes=kw.get("shard_nodes", False))
    if task_graph:
        b_specs["labels"] = P()
        b_specs["label_mask"] = P()

    def train_fn(params, opt_state, bat):
        def loss(p):
            return gnn.gat_loss(p, bat, cfg, n_graphs=n_graphs)

        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        master, new_state = adamw_update(grads, opt_state, opt_cfg)
        new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
        return new_params, new_state, {"loss": l, "acc": metrics["acc"]}

    # SpMM-ish useful work: per edge per layer, gather+reduce over head dims
    dims = cfg.layer_dims()
    flops = 0.0
    for fi, do in dims:
        flops += 2.0 * n * fi * cfg.n_heads * do  # dense projections
        flops += 6.0 * e * cfg.n_heads * do  # edge score + weighted aggregate
    flops *= 3  # fwd + bwd(2x)

    return Cell(
        arch_id=spec.arch_id, shape_name=cell.name, step="train",
        fn=train_fn,
        abstract_args=(p_shapes, o_shapes, b_shapes),
        in_specs=(
            p_specs,
            _rep_like(o_shapes),
            sanitize_specs(b_specs, b_shapes, mesh),
        ),
        out_specs=None,
        model_flops_fn=lambda: flops,
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _recsys_batch_shapes(cfg: recsys.RecsysConfig, cell: ShapeCell) -> dict[str, Any]:
    b = cell.kwargs["batch"]
    if cfg.model == "mind":
        out = {
            "hist_ids": S((b, cfg.hist_len), jnp.int32),
            "target_id": S((b,), jnp.int32),
        }
    else:
        out = {"sparse_ids": S((b, cfg.n_sparse), jnp.int32)}
        if cfg.n_dense:
            out["dense"] = S((b, cfg.n_dense), jnp.float32)
    if cell.step == "train" and cfg.model != "mind":
        out["label"] = S((b,), jnp.float32)
    return out


def _recsys_flops(cfg: recsys.RecsysConfig, batch: int) -> float:
    d = cfg.embed_dim
    if cfg.model == "fm":
        per = 4.0 * cfg.n_sparse * d
    elif cfg.model == "autoint":
        da, h, f = cfg.d_attn, cfg.n_attn_heads, cfg.n_sparse
        per = cfg.n_attn_layers * (6.0 * f * d * h * da + 4.0 * f * f * h * da)
    elif cfg.model == "dcn_v2":
        x0 = cfg.x0_dim
        per = cfg.n_cross_layers * 2.0 * x0 * x0
        fan = x0
        for m in cfg.mlp_dims:
            per += 2.0 * fan * m
            fan = m
    else:  # mind
        per = cfg.capsule_iters * 6.0 * cfg.hist_len * cfg.n_interests * d + 2.0 * cfg.hist_len * d * d
    return per * batch


def _recsys_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh) -> Cell:
    cfg: recsys.RecsysConfig = spec.cfg_for(cell.name)
    da = data_axes(mesh)
    key = jax.random.key(0)
    p_shapes = jax.eval_shape(functools.partial(recsys.init_recsys_params, cfg=cfg), key)
    p_specs = recsys_param_specs(p_shapes)
    b_shapes = _recsys_batch_shapes(cfg, cell)
    b_specs = batch_specs(b_shapes, mesh)

    if cell.step == "train":
        opt_cfg = AdamWConfig(lr=1e-3, weight_decay=1e-5)
        o_shapes = jax.eval_shape(adamw_init, p_shapes)
        o_specs = {"m": p_specs, "v": p_specs, "master": p_specs, "step": P()}

        def train_fn(params, opt_state, bat):
            (l, metrics), grads = jax.value_and_grad(
                recsys.recsys_loss, has_aux=True
            )(params, bat, cfg)
            master, new_state = adamw_update(grads, opt_state, opt_cfg)
            new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
            return new_params, new_state, {"loss": l}

        bsz = cell.kwargs["batch"]
        return Cell(
            arch_id=spec.arch_id, shape_name=cell.name, step="train",
            fn=train_fn,
            abstract_args=(p_shapes, o_shapes, b_shapes),
            in_specs=(
                sanitize_specs(p_specs, p_shapes, mesh),
                sanitize_specs(o_specs, o_shapes, mesh),
                sanitize_specs(b_specs, b_shapes, mesh),
            ),
            out_specs=None,
            model_flops_fn=lambda: 3.0 * _recsys_flops(cfg, bsz),
        )

    if cell.step == "score":
        def score_fn(params, bat):
            return recsys.recsys_score(params, bat, cfg)

        bsz = cell.kwargs["batch"]
        return Cell(
            arch_id=spec.arch_id, shape_name=cell.name, step="score",
            fn=score_fn,
            abstract_args=(p_shapes, b_shapes),
            in_specs=(
                sanitize_specs(p_specs, p_shapes, mesh),
                sanitize_specs(b_specs, b_shapes, mesh),
            ),
            out_specs=None,
            model_flops_fn=lambda: _recsys_flops(cfg, bsz),
        )

    # retrieval: one context vs n_candidates
    c = cell.kwargs["n_candidates"]
    b_shapes = _recsys_batch_shapes(cfg, cell)
    b_shapes["cand_ids"] = S((c,), jnp.int32)
    b_specs = {k: P() for k in b_shapes}
    b_specs["cand_ids"] = P(da)

    def retrieval_fn(params, bat):
        return recsys.recsys_retrieval_score(params, bat, cfg)

    return Cell(
        arch_id=spec.arch_id, shape_name=cell.name, step="retrieval",
        fn=retrieval_fn,
        abstract_args=(p_shapes, b_shapes),
        in_specs=(
            sanitize_specs(p_specs, p_shapes, mesh),
            sanitize_specs(b_specs, b_shapes, mesh),
        ),
        out_specs=None,
        model_flops_fn=lambda: _recsys_flops(cfg, c),
    )


# ---------------------------------------------------------------------------
# paper_search cells
# ---------------------------------------------------------------------------


def _search_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh) -> Cell:
    cfg = spec.model_cfg
    da = data_axes(mesh)
    if cell.step == "serve":
        b = cell.kwargs["batch"]
        p = cell.kwargs["postings"]
        c = cell.kwargs["clusters"]
        l, n, d = cfg.n_lemmas, cfg.window_len, cfg.max_distance
        # document/cluster-sharded layout (§Perf-3): every device owns one
        # cluster shard's postings end-to-end
        ns = int(mesh.devices.size)
        c_loc = max(1, -(-max(c, ns) // ns))
        p_loc = max(1, -(-p // ns))
        shard_axes = tuple(mesh.axis_names)
        args = (
            S((ns, b, p_loc, 3), jnp.int32),
            S((ns, b, c_loc), jnp.int32),
            S((b, l), jnp.int32),
        )
        in_specs = (
            P(shard_axes, None, None, None),
            P(shard_axes, None, None),
            P(),
        )

        def fn(postings, cluster_doc, mult):
            return serve_step_sharded(
                postings, cluster_doc, mult,
                max_distance=d, n_clusters=c_loc, window_len=n, top_k=cfg.top_k,
            )

        # useful work: the window cover — (2D+1) window steps x L lemmas x N
        flops = float(b) * ns * c_loc * (2 * d + 1) * l * n * 4.0
        return Cell(
            arch_id=spec.arch_id, shape_name=cell.name, step="serve",
            fn=fn, abstract_args=args, in_specs=in_specs, out_specs=None,
            model_flops_fn=lambda: flops,
        )

    docs, doc_len = cell.kwargs["docs"], cell.kwargs["doc_len"]
    d = cfg.max_distance
    args = (S((docs, doc_len), jnp.int32), S((docs, doc_len), jnp.bool_))
    in_specs = (
        sanitize_specs(P(da, None), args[0], mesh),
        sanitize_specs(P(da, None), args[1], mesh),
    )

    def fn(tokens, is_stop):
        return build_step(tokens, is_stop, max_distance=d, n_buckets=cfg.build_buckets)

    n_off = d * (2 * d - 1)
    flops = float(docs) * doc_len * n_off * 6.0
    return Cell(
        arch_id=spec.arch_id, shape_name=cell.name, step="build",
        fn=fn, abstract_args=args, in_specs=in_specs, out_specs=None,
        model_flops_fn=lambda: flops,
    )


# ---------------------------------------------------------------------------


def build_cell(spec: ArchSpec, shape_name: str, mesh: Mesh) -> Cell:
    cell = spec.shapes[shape_name]
    if spec.family == "lm":
        return _lm_cell(spec, cell, mesh)
    if spec.family == "gnn":
        return _gnn_cell(spec, cell, mesh)
    if spec.family == "recsys":
        return _recsys_cell(spec, cell, mesh)
    if spec.family == "search":
        return _search_cell(spec, cell, mesh)
    raise ValueError(spec.family)


def input_specs(arch_id: str, shape_name: str, mesh: Mesh | None = None) -> tuple:
    """Public helper (dry-run contract): the ShapeDtypeStruct stand-ins for
    every input of the (architecture x shape) cell — weak-type-correct,
    shardable, no device allocation."""
    from ..configs import get_spec
    from .mesh import make_production_mesh

    if mesh is None:
        mesh = make_production_mesh()
    return build_cell(get_spec(arch_id), shape_name, mesh).abstract_args
