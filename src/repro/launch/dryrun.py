import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost analysis + the collective schedule.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/]

The XLA_FLAGS line above MUST run before any other jax-touching import —
jax locks the device count at first init.  Smoke tests / benches never import
this module, so they see the real single CPU device.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import ARCH_IDS, get_spec
from .hlo_analysis import analyze_hlo
from .mesh import make_production_mesh
from .steps import Cell, build_cell


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True) -> dict:
    spec = get_spec(arch)
    cell_meta = spec.shapes[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rec: dict = {
        "arch": arch, "shape": shape, "step": cell_meta.step,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": n_dev,
        "kind": cell_meta.kind,
    }
    if cell_meta.skip_reason is not None:
        rec["status"] = "skipped"
        rec["skip_reason"] = cell_meta.skip_reason
        return rec
    t0 = time.time()
    try:
        cell: Cell = build_cell(spec, shape, mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P

        def to_sharding(tree):
            return jax.tree.map(
                lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
                tree,
                is_leaf=lambda x: isinstance(x, P) or x is None,
            )

        with jax.set_mesh(mesh):
            jitted = jax.jit(
                cell.fn,
                in_shardings=to_sharding(cell.in_specs),
                out_shardings=to_sharding(cell.out_specs),
            )
            lowered = jitted.lower(*cell.abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        # trip-count-aware per-device analysis (cost_analysis counts while
        # bodies once — see hlo_analysis.py)
        hc = analyze_hlo(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            # per-device numbers (shapes in the SPMD module are shard shapes)
            flops_per_device=hc.flops,
            hbm_bytes_per_device=hc.hbm_bytes,
            collective_bytes_per_device=dict(hc.collectives),
            collective_total_per_device=hc.collective_bytes,
            xla_cost_flops_raw=float(cost.get("flops", 0.0)),
            model_flops_global=float(cell.model_flops_fn()),
            notes=cell.notes,
        )
        for attr in (
            "temp_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "generated_code_size_in_bytes",
        ):
            if hasattr(mem, attr):
                rec[attr] = int(getattr(mem, attr))
        if verbose:
            print(f"[dryrun] {arch} x {shape} ({rec['mesh']}): OK "
                  f"flops/dev={hc.flops:.3e} hbm/dev={hc.hbm_bytes:.3e} "
                  f"coll/dev={hc.collective_bytes:.3e}B "
                  f"lower={t_lower:.0f}s compile={t_compile:.0f}s")
    except Exception as e:  # a dry-run failure is a bug in the system
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape}: FAIL {rec['error'][:200]}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--include-skipped", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    targets: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_IDS:
            spec = get_spec(arch)
            for name, c in spec.shapes.items():
                targets.append((arch, name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        targets.append((args.arch, args.shape))

    n_fail = 0
    for multi_pod in meshes:
        for arch, shape in targets:
            tag = "multipod" if multi_pod else "singlepod"
            safe_shape = shape.replace("[", "_").replace("]", "")
            path = outdir / f"{arch}__{safe_shape}__{tag}.json"
            if path.exists():
                prev = json.loads(path.read_text())
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[dryrun] {arch} x {shape} ({tag}): cached")
                    continue
            rec = run_cell(arch, shape, multi_pod)
            path.write_text(json.dumps(rec, indent=2))
            if rec["status"] == "error":
                n_fail += 1
    print(f"[dryrun] done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
