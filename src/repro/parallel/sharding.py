"""Per-architecture sharding rules (GSPMD PartitionSpecs).

Conventions (see DESIGN.md §4):

* ``data``  — batch / tokens / edges / queries; gradient all-reduce axis.
* ``model`` — TP: attention heads & FFN hidden; EP: MoE experts; embedding-
  table rows (recsys); head-dim for KV caches (uniform across kv-head counts).
* ``pod``   — outermost DP axis (multi-pod); composed with ``data`` for batch
  dims via ``("pod", "data")``.

Rules are name-keyed over the param pytree so they survive arbitrary nesting
(`tree_map_with_path`); anything unmatched is replicated.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "lm_param_specs",
    "recsys_param_specs",
    "gnn_specs",
    "batch_specs",
    "named_tree",
    "opt_state_specs",
    "data_axes",
]


def data_axes(mesh: Mesh):
    """Batch axis spec: ('pod','data') on the multi-pod mesh, else 'data'."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# LM transformer
# ---------------------------------------------------------------------------


def _lm_rule(name: str, ndim: int, kv_shardable: bool = True, fsdp: bool = True) -> P:
    """TP over `model`; FSDP/ZeRO over `data` on a second dim.

    With ``fsdp`` the data axis additionally shards a weight dim; GSPMD
    all-gathers each layer's weights at use inside the layer scan (classic
    FSDP), which cuts resident params + optimizer state by the DP degree —
    required for the 123B/400B cells to fit 16 GB HBM (EXPERIMENTS.md
    §Perf-4)."""
    dp = "data" if fsdp else None
    # stacked layer params carry a leading L axis (never sharded)
    if name.endswith("embed"):  # [V, D] -> rows on data, D on model
        return P(dp, "model")
    if name.endswith("unembed"):  # [D, V] -> V on model (sharded logits)
        return P(dp, "model")
    if name.endswith("wq"):  # [L, D, Hq*Dh]
        return P(None, dp, "model")
    if name.endswith("wk") or name.endswith("wv"):  # [L, D, Hkv*Dh]
        # replicate KV projections over `model` when Hkv doesn't divide the
        # TP axis: redundant-compute KV (a few GB) beats the per-layer
        # reshard GSPMD otherwise inserts (EXPERIMENTS.md §Perf-4)
        return P(None, dp, "model") if kv_shardable else P(None, dp, None)
    if name.endswith("wo"):  # [L, H*Dh, D]
        return P(None, "model", dp)
    if name.endswith("w_gate") or name.endswith("w_up"):
        if ndim == 4:  # moe experts [L, E, D, F] -> expert parallel + FSDP
            return P(None, "model", dp, None)
        if ndim == 3:  # dense [L, D, F] -> tensor parallel + FSDP
            return P(None, dp, "model")
        return P(dp, "model")  # shared expert [D, F]
    if name.endswith("w_down"):
        if ndim == 4:  # [L, E, F, D]
            return P(None, "model", dp, None)
        if ndim == 3:  # [L, F, D]
            return P(None, "model", dp)
        return P("model", dp)  # shared expert [F, D]
    if name.endswith("router"):  # [L, D, E]
        return P()
    return P()  # norms etc. replicated


def lm_param_specs(param_shapes: Any, kv_shardable: bool = True, fsdp: bool = True) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _lm_rule(_path_str(path), len(leaf.shape), kv_shardable, fsdp),
        param_shapes,
    )


def fsdp_gather_layer(layer: Any, kv_shardable: bool = True) -> Any:
    """Per-layer FSDP all-gather at use (inside the layer scan).

    FSDP-sharded weights carry `data` on a dim; left to propagation, GSPMD
    gathers the WHOLE stacked [L, ...] array before the scan (155 GB temps —
    EXPERIMENTS.md §Perf-4 refuted iteration), and sharding *constraints*
    inside the body still partition pathologically.  So the gather is an
    EXPLICIT ``shard_map`` + ``lax.all_gather`` — the collective and its
    transpose (a per-layer gradient reduce-scatter: exactly ZeRO) are pinned
    down, nothing is left to partitioner cost models."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names or "data" not in mesh.axis_names:
        return layer
    # ONLY the axis the rule shards over ("data"); gathering over "pod" too
    # would double the gathered dim (weights are replicated across pods)
    gather_axes = ("data",)

    def fix(path, x):
        name = _path_str(path)
        # the per-layer slice has no leading L dim: shift the rule right
        full = _lm_rule("dummy/" + name.split("/")[-1], x.ndim + 1, kv_shardable, fsdp=True)
        spec = P(*full[1:]) if len(full) > 1 else P()
        dims = list(spec) + [None] * (x.ndim - len(spec))
        if "data" not in [d for d in dims if isinstance(d, str)]:
            return x
        g_dim = dims.index("data")
        out_dims = [d if d != "data" else None for d in dims]

        def gather(w):
            return jax.lax.all_gather(w, gather_axes, axis=g_dim, tiled=True)

        return jax.shard_map(
            gather, mesh=mesh, in_specs=P(*dims), out_specs=P(*out_dims),
            check_vma=False,
        )(x)

    return jax.tree_util.tree_map_with_path(fix, layer)


def lm_cache_spec() -> P:
    """KV cache [L, B, S, Hkv, Dh]: batch on data, head-dim on model
    (uniform: every assigned arch has Dh % 16 == 0, unlike Hkv)."""
    return P(None, "data", None, None, "model")


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------


def _recsys_rule(name: str, ndim: int) -> P:
    if name.endswith("table"):  # [V, D] — THE memory: row-sharded
        return P("model", None)
    if name.endswith("w_linear"):  # FM [V]
        return P("model")
    if name.endswith("cross_w"):  # [C, X, X]
        return P(None, None, "model")
    if name.endswith("/w") or name.endswith("w_out"):  # MLP [in, out]
        return P(None, "model") if ndim == 2 else P()
    return P()


def recsys_param_specs(param_shapes: Any) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _recsys_rule(_path_str(path), len(leaf.shape)), param_shapes
    )


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------


def gnn_specs(batch_shapes: dict[str, Any], mesh: Mesh, shard_nodes: bool) -> dict[str, P]:
    """Edges always shard over (data, model) flattened; node tensors shard
    over data only on the large graphs (ogbn-products), else replicate."""
    da = data_axes(mesh)
    edge_axes = (*da, "model") if isinstance(da, tuple) else ("data", "model")
    out: dict[str, P] = {}
    for k, v in batch_shapes.items():
        nd = len(v.shape) if hasattr(v, "shape") else 0
        if k in ("src", "dst", "edge_mask"):
            out[k] = P(edge_axes)
        elif k in ("x",):
            out[k] = P("data", None) if shard_nodes else P()
        elif k in ("labels", "label_mask", "graph_ids"):
            out[k] = P("data") if shard_nodes else P()
        else:
            out[k] = P()
    return out


# ---------------------------------------------------------------------------
# generic helpers
# ---------------------------------------------------------------------------


def batch_specs(batch_shapes: Any, mesh: Mesh) -> Any:
    """Default data-parallel batch sharding: leading dim on (pod, data)."""
    da = data_axes(mesh)

    def rule(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return P(da, *([None] * (nd - 1)))

    return jax.tree.map(rule, batch_shapes)


def opt_state_specs(param_specs: Any) -> Any:
    """Adam moments + fp32 master copy inherit the param specs (scalars
    replicated)."""
    return param_specs


def named_tree(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
