from .sharding import (
    batch_specs,
    lm_param_specs,
    gnn_specs,
    recsys_param_specs,
    named_tree,
    opt_state_specs,
)

__all__ = [
    "batch_specs",
    "lm_param_specs",
    "gnn_specs",
    "recsys_param_specs",
    "named_tree",
    "opt_state_specs",
]
