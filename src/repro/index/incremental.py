"""Incremental segment-based index construction with update/delete semantics.

The companion construction paper (Veretennikov, "An efficient algorithm for
three-component key index construction", arXiv 2006.07954) builds the §3
indexes from sorted sub-index runs that are merged; this module is that
architecture made *maintainable*: a production index that stays fresh under
document churn (arXiv 2009.03679's serving requirement) without whole-corpus
rebuilds.

Design
------

* **Segments** — documents are ingested in batches; ``commit()`` freezes the
  batch into an immutable sorted segment (a complete §3 ``IndexSet`` over the
  batch: ordinary + NSW + pair/triple/degenerate postings).  Per-document row
  generation is shared with ``build_indexes`` (``builder._RowAccumulator``),
  so a segment's per-document content is byte-identical to a from-scratch
  rebuild's.

* **Tombstones** — ``delete_document`` marks a doc id dead; queries filter
  tombstoned rows at segment-union time, so deletion is O(1) and visible
  immediately.  ``compact()`` physically drops dead rows.

* **Query-time union** — ``IncrementalIndexer.index`` is a
  :class:`SegmentedIndexSet`, an ``IndexSet`` whose posting dicts are lazy
  *merging* mappings: the first lookup of a key runs a vectorized k-way merge
  (concat + ``np.lexsort`` over the §4 lexicographic row order, honoring the
  NSW ragged offsets) of the per-segment arrays minus dead docs, and caches
  the result.  Every engine (scalar SE2.4, vectorized, fused, Pallas-kernel)
  serves over the view transparently and returns byte-identical fragments to
  a from-scratch rebuild of the surviving documents.

* **FL drift** — the FL-list is recomputed from surviving-document
  frequencies at each ``commit(refresh_fl=True)`` generation.  Row
  generation for a document depends ONLY on (a) the relative FL order and
  types of the document's own lemmas (``core.keys.lemma_order_signature``)
  and (b) absolute FL-numbers of stop lemmas, which reach posting storage
  only through NSW stop-lemma ids.  So on drift we re-key ONLY the affected
  postings: documents whose signature changed are superseded in place and
  re-indexed into the new generation's segment; every other document's
  postings are kept verbatim with a vectorized NSW stop-id remap.  This is
  exact — ``to_index_set()`` equals ``build_indexes`` over the survivors —
  and is the contract the differential test harness pins.

* **Compaction** — ``compact(memory_budget_bytes)`` greedily groups adjacent
  segments so each rewritten segment stays under the budget (the merge
  working set), materializes the group's union with dead rows dropped, and
  clears the now-physically-deleted tombstones.
"""

from __future__ import annotations

import time
from collections import Counter
from collections.abc import Mapping
from dataclasses import dataclass, field
from pathlib import Path
from typing import Sequence

import numpy as np

from ..core.keys import lemma_order_signature
from ..core.lemma import FLList, Lemmatizer
from .builder import IndexSet, NSWRecords, POSTING_WIDTH, build_segment
from .corpus import Document, DocumentStore

__all__ = [
    "IncrementalIndexer",
    "Segment",
    "SegmentedIndexSet",
    "as_index_set",
    "generation_token",
    "index_sets_equal",
    "merge_posting_arrays",
]

_WIDTH = POSTING_WIDTH


# ---------------------------------------------------------------------------
# vectorized k-way merge primitives
# ---------------------------------------------------------------------------


def _drop_dead_mask(doc_col: np.ndarray, dead: np.ndarray) -> np.ndarray:
    """Boolean keep-mask for rows whose doc id is NOT in sorted ``dead``."""
    if not len(dead) or not len(doc_col):
        return np.ones(len(doc_col), dtype=bool)
    i = np.searchsorted(dead, doc_col)
    i = np.minimum(i, len(dead) - 1)
    return dead[i] != doc_col


def merge_posting_arrays(arrays: Sequence[np.ndarray], width: int) -> np.ndarray:
    """K-way merge of sorted posting arrays into one §4-ordered array.

    Segments hold disjoint doc sets, so the merged lexicographic order is a
    permutation of the concatenation — one ``np.lexsort`` over all columns
    (last column least significant) reproduces a from-scratch sort exactly.
    """
    arrays = [a for a in arrays if len(a)]
    if not arrays:
        return np.empty((0, width), dtype=np.int32)
    if len(arrays) == 1:
        return arrays[0]
    merged = np.concatenate(arrays)
    order = np.lexsort(tuple(merged[:, c] for c in range(width - 1, -1, -1)))
    return merged[order]


def _merge_ordinary_nsw(
    parts: Sequence[tuple[np.ndarray, NSWRecords | None]],
) -> tuple[np.ndarray, NSWRecords | None]:
    """Merge per-segment (ordinary rows, parallel NSW) for one lemma.

    NSW offsets are ragged and parallel to the (doc, pos)-sorted ordinary
    array, so the merge permutation computed over the posting rows is applied
    to the per-posting *slice lengths*, and the payload is gathered with a
    repeat/arange ragged gather — no Python loop over postings.
    """
    parts = [(rows, rec) for rows, rec in parts if len(rows)]
    if not parts:
        return np.empty((0, 2), dtype=np.int32), None
    rows_list = [rows for rows, _ in parts]
    have_nsw = any(rec is not None for _, rec in parts)
    if len(rows_list) == 1:
        return parts[0]

    all_rows = np.concatenate(rows_list)
    order = np.lexsort((all_rows[:, 1], all_rows[:, 0]))
    merged_rows = all_rows[order]
    if not have_nsw:
        return merged_rows, None

    counts_list, starts_list, payload_sl, payload_d = [], [], [], []
    base = 0
    for rows, rec in parts:
        if not len(rows):
            continue
        assert rec is not None, "NSW present in some segments but not others"
        counts_list.append(np.diff(rec.offsets))
        starts_list.append(rec.offsets[:-1] + base)
        payload_sl.append(rec.stop_lemma)
        payload_d.append(rec.distance)
        base += len(rec.stop_lemma)
    counts = np.concatenate(counts_list)[order]
    starts = np.concatenate(starts_list)[order]
    sl = np.concatenate(payload_sl) if payload_sl else np.empty(0, np.int32)
    dist = np.concatenate(payload_d) if payload_d else np.empty(0, np.int32)

    offsets = np.zeros(len(counts) + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    total = int(offsets[-1])
    # ragged gather: element j of posting i reads payload[starts[i] + j]
    idx = (
        np.repeat(starts, counts)
        + np.arange(total, dtype=np.int64)
        - np.repeat(offsets[:-1], counts)
    )
    rec = NSWRecords(
        offsets=offsets,
        stop_lemma=sl[idx].astype(np.int32, copy=False),
        distance=dist[idx].astype(np.int32, copy=False),
    )
    return merged_rows, rec


def _filter_ordinary_nsw(
    rows: np.ndarray, rec: NSWRecords | None, dead: np.ndarray
) -> tuple[np.ndarray, NSWRecords | None]:
    """Drop tombstoned postings (and their ragged NSW slices) for one lemma."""
    if not len(dead) or not len(rows):
        return rows, rec
    keep = _drop_dead_mask(rows[:, 0], dead)
    if keep.all():
        return rows, rec
    rows = rows[keep]
    if rec is None:
        return rows, None
    counts = np.diff(rec.offsets)[keep]
    starts = rec.offsets[:-1][keep]
    offsets = np.zeros(len(counts) + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    total = int(offsets[-1])
    idx = (
        np.repeat(starts, counts)
        + np.arange(total, dtype=np.int64)
        - np.repeat(offsets[:-1], counts)
    )
    return rows, NSWRecords(
        offsets=offsets,
        stop_lemma=rec.stop_lemma[idx],
        distance=rec.distance[idx],
    )


# ---------------------------------------------------------------------------
# lazy merging mapping views
# ---------------------------------------------------------------------------


class _MergedPostings(Mapping):
    """Lazy union of one posting dict (pair/triple/...) across segments.

    A key's merged array is computed on first access (tombstone filter +
    k-way merge) and cached for the lifetime of the view; the indexer drops
    the whole view on any mutation, which drops every cache with it.
    """

    def __init__(self, contribs: list[tuple[IndexSet, np.ndarray]], fname: str):
        self._contribs = contribs
        self._field = fname
        self._width = _WIDTH[fname]
        self._cache: dict = {}
        self._keys: set | None = None

    def _key_union(self) -> set:
        if self._keys is None:
            keys: set = set()
            for idx, _ in self._contribs:
                keys.update(getattr(idx, self._field).keys())
            self._keys = keys
        return self._keys

    def __getitem__(self, key):
        try:
            return self._cache[key]
        except KeyError:
            pass
        parts = []
        present = False
        for idx, dead in self._contribs:
            a = getattr(idx, self._field).get(key)
            if a is None:
                continue
            present = True
            if len(dead) and len(a):
                a = a[_drop_dead_mask(a[:, 0], dead)]
            parts.append(a)
        if not present:
            raise KeyError(key)
        merged = merge_posting_arrays(parts, self._width)
        self._cache[key] = merged
        return merged

    def __iter__(self):
        return iter(self._key_union())

    def __len__(self):
        return len(self._key_union())

    def __contains__(self, key):
        return key in self._key_union()


class _MergedOrdinary(Mapping):
    """Ordinary-index view; stays offset-aligned with the NSW view by
    sharing one per-lemma merge (see ``SegmentedIndexSet._merged_lemma``)."""

    def __init__(self, view: "SegmentedIndexSet"):
        self._view = view

    def __getitem__(self, lemma):
        rows, _ = self._view._merged_lemma(lemma)
        return rows

    def __iter__(self):
        return iter(self._view._ordinary_keys())

    def __len__(self):
        return len(self._view._ordinary_keys())

    def __contains__(self, lemma):
        return lemma in self._view._ordinary_keys()


class _MergedNSW(Mapping):
    def __init__(self, view: "SegmentedIndexSet"):
        self._view = view

    def _keys(self) -> set:
        return {
            l
            for l in self._view._ordinary_keys()
            if self._view._merged_lemma(l)[1] is not None
        }

    def __getitem__(self, lemma):
        rec = self._view._merged_lemma(lemma)[1]
        if rec is None:
            raise KeyError(lemma)
        return rec

    def __iter__(self):
        return iter(self._keys())

    def __len__(self):
        return len(self._keys())

    def __contains__(self, lemma):
        return self._view._merged_lemma(lemma)[1] is not None if lemma in self._view._ordinary_keys() else False


class SegmentedIndexSet(IndexSet):
    """Query-time union of immutable segments minus tombstoned documents
    (DESIGN.md §10.1; posting merges preserve the §4 row order exactly).

    Duck-compatible with (and a subclass of) :class:`IndexSet`: the posting
    dict fields hold lazy merging mappings, ``key_postings`` and every engine
    work unchanged.  ``to_index_set()`` materializes the union into a plain
    ``IndexSet`` — byte-identical to ``build_indexes`` over the surviving
    documents (the differential harness pins this).
    """

    def __init__(
        self,
        fl: FLList,
        max_distance: int,
        contribs: list[tuple[IndexSet, np.ndarray]],
        n_docs: int,
    ):
        self._contribs = contribs
        self._lemma_cache: dict[str, tuple[np.ndarray, NSWRecords | None]] = {}
        self._ordinary_key_union: set | None = None
        IndexSet.__init__(
            self,
            fl=fl,
            max_distance=max_distance,
            ordinary=_MergedOrdinary(self),
            nsw=_MergedNSW(self),
            pair=_MergedPostings(contribs, "pair"),
            triple=_MergedPostings(contribs, "triple"),
            stop_single=_MergedPostings(contribs, "stop_single"),
            stop_pair=_MergedPostings(contribs, "stop_pair"),
            n_docs=n_docs,
        )

    # -- per-lemma ordinary + NSW (one shared merge keeps them aligned) -----

    def _ordinary_keys(self) -> set:
        if self._ordinary_key_union is None:
            keys: set = set()
            for idx, _ in self._contribs:
                keys.update(idx.ordinary.keys())
            self._ordinary_key_union = keys
        return self._ordinary_key_union

    def _merged_lemma(self, lemma: str) -> tuple[np.ndarray, NSWRecords | None]:
        try:
            return self._lemma_cache[lemma]
        except KeyError:
            pass
        if lemma not in self._ordinary_keys():
            raise KeyError(lemma)
        parts: list[tuple[np.ndarray, NSWRecords | None]] = []
        for idx, dead in self._contribs:
            rows = idx.ordinary.get(lemma)
            if rows is None:
                continue
            rows, rec = _filter_ordinary_nsw(rows, idx.nsw.get(lemma), dead)
            parts.append((rows, rec))
        out = _merge_ordinary_nsw(parts)
        self._lemma_cache[lemma] = out
        return out

    # -- materialization ----------------------------------------------------

    def to_index_set(self) -> IndexSet:
        """Force every merge; drop keys whose postings are fully tombstoned
        (a rebuild would not have them)."""
        ordinary: dict[str, np.ndarray] = {}
        nsw: dict[str, NSWRecords] = {}
        for lemma in sorted(self._ordinary_keys()):
            rows, rec = self._merged_lemma(lemma)
            if not len(rows):
                continue
            ordinary[lemma] = rows
            if rec is not None:
                nsw[lemma] = rec

        def materialize(mapping: Mapping) -> dict:
            out = {}
            for key in mapping:
                arr = mapping[key]
                if len(arr):
                    out[key] = arr
            return out

        return IndexSet(
            fl=self.fl,
            max_distance=self.max_distance,
            ordinary=ordinary,
            nsw=nsw,
            pair=materialize(self.pair),
            triple=materialize(self.triple),
            stop_single=materialize(self.stop_single),
            stop_pair=materialize(self.stop_pair),
            n_docs=self.n_docs,
        )


# ---------------------------------------------------------------------------
# the incremental indexer
# ---------------------------------------------------------------------------


@dataclass
class Segment:
    """One immutable sorted generation unit: a complete §3 ``IndexSet`` over
    one ingest batch (DESIGN.md §10.1).

    ``superseded`` lists docs re-keyed into a LATER segment after FL drift —
    they are filtered from this segment exactly like tombstones, but stay
    live in the index through their re-keyed copies.
    """

    index: IndexSet
    doc_ids: frozenset[int]
    superseded: set[int] = field(default_factory=set)

    def live_bytes(self) -> int:
        return self.index.size_bytes()["total"]


class IncrementalIndexer:
    """Segment-based incremental builder of the §3 multi-component indexes.

    Typical loop::

        ix = IncrementalIndexer(sw_count=80, fu_count=250, max_distance=5)
        ix.add_documents(["some text", ...])      # buffered
        ix.commit()                               # -> new immutable segment
        engine = SearchEngine(ix)                 # serves the live union view
        ix.delete_document(3)                     # tombstone, visible now
        ix.add_documents([...]); ix.commit()      # FL drift handled exactly
        ix.compact(memory_budget_bytes=64 << 20)  # physical merge + GC

    ``commit(refresh_fl=False)`` pins the current FL-list (the low-latency
    serving mode: no drift scan, exact w.r.t. a rebuild that passes the same
    ``fl``); the default recomputes the FL-list from surviving frequencies
    and re-keys drifted documents, staying exact w.r.t. a plain
    ``build_indexes`` rebuild.
    """

    def __init__(
        self,
        sw_count: int,
        fu_count: int,
        max_distance: int = 5,
        lemmatizer: Lemmatizer | None = None,
        build_pair: bool = True,
        build_degenerate: bool = True,
        use_fast_builder: bool = True,
    ):
        self.sw_count = sw_count
        self.fu_count = fu_count
        self.max_distance = max_distance
        self.lemmatizer = lemmatizer or Lemmatizer()
        self.build_pair = build_pair
        self.build_degenerate = build_degenerate
        # commit() routes segment construction through the vectorized
        # builder (§17.1) by default; the scalar build_segment stays the
        # oracle the property/differential suites compare against
        self.use_fast_builder = use_fast_builder
        self.fl: FLList | None = None
        self.segments: list[Segment] = []
        self.tombstones: set[int] = set()
        self.documents: dict[int, Document] = {}  # committed survivors
        self.generation = 0
        self._buffer: dict[int, Document] = {}
        self._freq: Counter = Counter()
        # per-doc unique lemma sets, cached at ingest (docs are immutable):
        # the drift scan tests set intersections instead of re-walking
        # lemma_streams, keeping commit cost off the token count
        self._doc_lemmas: dict[int, frozenset[str]] = {}
        self._next_id = 0
        self._view: SegmentedIndexSet | None = None
        # monotone mutation counter: bumped whenever the QUERY-VISIBLE state
        # changes (commit, committed delete, compact) — the cache-invalidation
        # token the serving frontend keys its LRU caches by (DESIGN.md §11)
        self._mutations = 0
        # restore epoch (DESIGN.md §12.5): 0 for a freshly built indexer,
        # bumped past the snapshot's stored epoch on every restore so tokens
        # from different boots of the same snapshot lineage never collide
        self._restore_epoch = 0
        # mutation listeners (DESIGN.md §13.2): called after every token
        # bump, so generation-keyed device caches (the posting arena) can
        # evict stale buffers eagerly instead of waiting for LRU pressure
        self._listeners: list = []
        # write-ahead log (DESIGN.md §18): when attached via enable_wal /
        # restore, every mutating op appends a durable record BEFORE the
        # in-memory state changes; None = §12 snapshot-only durability
        self.wal = None
        # stats of the last §18.2 replay this indexer was restored through
        self.last_wal_replay: dict = {"records": 0, "seconds": 0.0}

    def subscribe(self, callback):
        """Register ``callback(indexer)`` to run after every query-visible
        mutation (commit, committed delete, compact) — i.e. after every
        ``generation_token`` bump.  The serving-side consumer is
        ``PostingArena.attach`` (DESIGN.md §13.2), which evicts
        device-resident posting buffers keyed by tokens this indexer no
        longer serves.  Returns an unsubscribe callable (idempotent) —
        short-lived consumers over a long-lived indexer must call it (see
        ``PostingArena.detach``) or their closures outlive them.  Listeners
        are droppable accelerator state, so they are intentionally NOT part
        of snapshots (a restored indexer starts with none)."""
        self._listeners.append(callback)

        def unsubscribe() -> None:
            try:
                self._listeners.remove(callback)
            except ValueError:
                pass

        return unsubscribe

    def _notify(self) -> None:
        for cb in list(self._listeners):
            cb(self)

    @property
    def generation_token(self):
        """Token identifying the current query-visible index state — an int
        (the monotone mutation counter) for a freshly built indexer, or an
        ``(epoch, mutations)`` tuple after a snapshot restore (DESIGN.md
        §12.5).

        Bumps on every ``commit``, committed ``delete_document`` and
        ``compact`` — any event that can change the fragment set an engine
        serving this indexer would return.  Frontend caches (§11 of
        DESIGN.md) key entries by this token, so a generation bump
        invalidates them without any explicit flush; buffered (uncommitted)
        adds do not bump it because they are not query-visible yet.

        Across restarts (§12.5): ``restore`` resumes the stored mutation
        counter under a fresh epoch claimed from the snapshot lineage's
        persisted ``restore_epoch`` counter — strictly greater than the
        stored epoch AND any epoch an earlier boot of the same lineage
        claimed.  Equal tokens therefore still imply equal index states
        (even across sibling boots of one snapshot), and a state the
        *previous* process reached after the snapshot point can never share
        a token with a state this process reaches — pre-restart cached
        results are correctly invalidated, post-restart caches warm
        normally.
        """
        if self._restore_epoch:
            return (self._restore_epoch, self._mutations)
        return self._mutations

    # -- durability (DESIGN.md §12/§18; implementation in index/store.py
    # and index/wal.py) ----------------------------------------------------

    def enable_wal(self, lineage_dir, injector=None, shard=None):
        """Attach a §18 write-ahead log at ``<lineage_dir>/wal`` — the same
        lineage directory ``snapshot``/``restore`` use, so checkpoints,
        retention and replay share one root.  From this point every
        ``add``/``delete``/``commit``/``compact`` appends a durable record
        before mutating, and ``restore`` of this lineage replays the tail
        (the post-snapshot state is exact, not just the snapshot).
        ``injector``/``shard`` feed the §14 ``wal.*`` fault points.
        Returns the attached :class:`~repro.index.wal.WriteAheadLog`."""
        from .wal import WriteAheadLog

        self.wal = WriteAheadLog(
            Path(lineage_dir) / "wal", injector=injector, shard=shard
        )
        return self.wal

    def snapshot(self, directory, keep: int = 2):
        """Freeze this indexer into ``<directory>/snap_<N>`` — the durable
        §12.2 on-disk form: delta+bitpacked segment stores, pre-lemmatized
        documents, tombstones, FL state and the §12.5 generation token.
        Atomic (tmp -> fsync -> rename) with ``keep``-newest retention;
        returns the published snapshot path.

        With a §18 WAL attached, the snapshot is also a WAL checkpoint:
        a ``checkpoint`` record anchors it in the log *before* it
        publishes (a crash in between leaves a dangling anchor that
        replays as a no-op), the active segment is sealed, and replayed
        prefixes beyond the retention window are truncated."""
        from .store import latest_snapshot, save_snapshot

        if self.wal is not None:
            prev = latest_snapshot(Path(directory))
            upcoming = 0 if prev is None else prev + 1
            self.wal.checkpoint(upcoming, self._mutations)
        path = save_snapshot(self, directory, keep=keep)
        if self.wal is not None:
            self.wal.prune(keep=keep)
        return path

    @classmethod
    def restore(
        cls,
        directory,
        snapshot_id: int | None = None,
        use_mmap: bool = True,
        verify: bool = True,
        lemmatizer: Lemmatizer | None = None,
        injector=None,
        replay_wal: bool = True,
    ) -> "IncrementalIndexer":
        """Warm-start an indexer from a §12.2 snapshot: segments serve
        lazily from ``mmap`` pages and nothing is re-lemmatized.  When the
        lineage has a §18 WAL (``<directory>/wal``), the tail logged after
        the restored snapshot's checkpoint is replayed on top, so the
        restored index is exact (``index_sets_equal``) vs the uncrashed
        live indexer *including post-snapshot commits* — the §18.2
        zero-data-loss contract; without a WAL it is exact vs the
        snapshotted view (the §12 contract), as before.  Raises
        ``StoreError`` on corruption.  ``injector`` is the §14
        fault-injection hook passed through to ``load_snapshot`` and the
        re-attached WAL (the chaos harness corrupts snapshot and WAL bytes
        for real there); ``replay_wal=False`` restores the bare snapshot.
        Replay stats land in ``last_wal_replay`` (record count, seconds)."""
        from .store import latest_snapshot, load_snapshot

        directory = Path(directory)
        ix = load_snapshot(
            directory,
            snapshot_id=snapshot_id,
            use_mmap=use_mmap,
            verify=verify,
            lemmatizer=lemmatizer,
            injector=injector,
        )
        wal_dir = directory / "wal"
        if wal_dir.exists():
            from .wal import WriteAheadLog
            from .wal import replay as wal_replay

            ix.wal = WriteAheadLog(wal_dir, injector=injector)
            if replay_wal:
                sid = (
                    snapshot_id
                    if snapshot_id is not None
                    else latest_snapshot(directory)
                )
                tail = ix.wal.tail_after_snapshot(sid)
                t0 = time.perf_counter()
                applied = wal_replay(ix, tail)
                ix.last_wal_replay = {
                    "records": applied,
                    "seconds": time.perf_counter() - t0,
                }
        return ix

    @classmethod
    def bulk_build(
        cls,
        texts: Sequence[str] | None = None,
        *,
        out_dir,
        sw_count: int,
        fu_count: int,
        max_distance: int = 5,
        build_pair: bool = True,
        build_degenerate: bool = True,
        documents: Sequence[Document] | None = None,
        doc_ids: Sequence[int] | None = None,
        fl: FLList | None = None,
        docs_per_spill: int = 64,
        workers: int = 1,
        resume: bool = False,
        keep_spills: bool = False,
        injector=None,
        lemmatizer: Lemmatizer | None = None,
        wal: bool = False,
    ) -> tuple["IncrementalIndexer", "object"]:
        """External-memory cold build (§17): SPIMI spill/merge straight to a
        published §12.2 snapshot, then warm-start an indexer from it.  The
        result is byte-identical to ``snapshot()`` after a one-commit build of
        the same corpus (the §17.4 determinism contract), but an order of
        magnitude faster because postings never round-trip through Python
        dicts.  Returns ``(indexer, BulkBuildStats)``.

        With ``wal=True`` the returned indexer gets a §18 write-ahead log
        attached under ``out_dir/wal`` anchored by a typed ``bulk_build``
        checkpoint record for the published snapshot, so incremental
        mutations after the cold build are crash-recoverable with zero
        committed-write loss (§18.2)."""
        from .ingest import bulk_build as _bulk_build

        stats = _bulk_build(
            texts,
            out_dir=out_dir,
            sw_count=sw_count,
            fu_count=fu_count,
            max_distance=max_distance,
            build_pair=build_pair,
            build_degenerate=build_degenerate,
            documents=documents,
            doc_ids=doc_ids,
            fl=fl,
            docs_per_spill=docs_per_spill,
            workers=workers,
            resume=resume,
            keep_spills=keep_spills,
            injector=injector,
        )
        ix = cls.restore(out_dir, lemmatizer=lemmatizer)
        if wal and ix.wal is None:
            from .store import latest_snapshot

            log = ix.enable_wal(out_dir, injector=injector)
            log.checkpoint(
                latest_snapshot(Path(out_dir)), ix._mutations, rtype="bulk_build"
            )
        return ix, stats

    # -- ingest / delete ----------------------------------------------------

    def add_documents(
        self,
        texts: Sequence[str],
        doc_ids: Sequence[int] | None = None,
    ) -> list[int]:
        """Buffer documents for the next ``commit``; returns their doc ids.

        ``doc_ids`` lets a router (e.g. the sharded service) assign globally
        unique ids; they must be fresh — tombstoned ids are never reused.
        """
        if doc_ids is not None and len(doc_ids) != len(texts):
            raise ValueError("doc_ids must parallel texts")
        base = self._next_id
        docs = [
            Document(
                doc_id=base + i if doc_ids is None else int(doc_ids[i]),
                text=text,
                lemma_stream=self.lemmatizer.lemmatize_text(text),
            )
            for i, text in enumerate(texts)
        ]
        return self.add_prelemmatized(docs)

    def add_prelemmatized(self, documents: Sequence[Document]) -> list[int]:
        """Ingest documents that already carry a ``lemma_stream`` (e.g. from
        a ``DocumentStore``) without re-lemmatizing; doc ids are taken from
        the documents and must be fresh.  The batch is validated up front
        and (with a §18 WAL attached) logged as ONE durable ``add`` record
        carrying the pre-lemmatized payloads BEFORE any buffer mutates —
        a batch either appends entirely or raises without side effects."""
        docs = list(documents)
        seen: set[int] = set()
        for doc in docs:
            if (
                doc.doc_id in self.documents
                or doc.doc_id in self._buffer
                or doc.doc_id in self.tombstones
                or doc.doc_id in seen
            ):
                raise ValueError(f"doc id {doc.doc_id} already used")
            seen.add(doc.doc_id)
        if self.wal is not None and docs:
            from .wal import docs_to_payload

            self.wal.append("add", {"docs": docs_to_payload(docs)})
        for doc in docs:
            self._ingest(doc)
        return [doc.doc_id for doc in docs]

    def _ingest(self, doc: Document) -> None:
        doc_id = doc.doc_id
        if (
            doc_id in self.documents
            or doc_id in self._buffer
            or doc_id in self.tombstones
        ):
            raise ValueError(f"doc id {doc_id} already used")
        self._next_id = max(self._next_id, doc_id + 1)
        self._buffer[doc_id] = doc
        self._freq.update(l for lemmas in doc.lemma_stream for l in lemmas)
        self._doc_lemmas[doc_id] = frozenset(
            l for lemmas in doc.lemma_stream for l in lemmas
        )

    def delete_document(self, doc_id: int) -> None:
        """Tombstone a committed doc (effective immediately at query time) or
        drop it from the ingest buffer.  Raises ``KeyError`` if unknown.
        With a §18 WAL attached the delete is durably logged before it
        applies (unknown ids raise without logging)."""
        if doc_id not in self._buffer and doc_id not in self.documents:
            raise KeyError(doc_id)
        if self.wal is not None:
            self.wal.append("delete", {"doc_id": int(doc_id)})
        if doc_id in self._buffer:
            doc = self._buffer.pop(doc_id)
        elif doc_id in self.documents:
            doc = self.documents.pop(doc_id)
            self.tombstones.add(doc_id)
            self._view = None  # tombstone filter must take effect
            self._mutations += 1  # query-visible: invalidate frontend caches
            self._notify()
        else:
            raise KeyError(doc_id)
        self._doc_lemmas.pop(doc_id, None)
        self._freq.subtract(l for lemmas in doc.lemma_stream for l in lemmas)

    def surviving_frequencies(self) -> dict[str, int]:
        """Lemma frequencies over committed survivors + the ingest buffer —
        exactly ``DocumentStore.lemma_frequencies()`` of a rebuild corpus."""
        return {l: n for l, n in self._freq.items() if n > 0}

    # -- generations --------------------------------------------------------

    def commit(self, refresh_fl: bool = True, fl: FLList | None = None) -> dict:
        """Freeze the ingest buffer into a new immutable segment.

        With ``refresh_fl`` (or an explicit ``fl`` from a corpus-level
        reduce), the FL-list moves to the new generation and drifted
        documents are re-keyed (see module docstring).  Returns a generation
        report: ``{"new_docs", "rekeyed_docs", "drifted_lemmas", "segments"}``.

        With a §18 WAL attached, the commit's *resolved* FL (explicit,
        refreshed from surviving frequencies, or kept) is computed first
        and durably logged before any state mutates — so replaying the
        record on another process reproduces this commit exactly, even
        when the FL came from a corpus-level reduce this shard could not
        recompute alone (§18.2).
        """
        if self.wal is not None:
            from .wal import fl_to_payload

            if fl is not None:
                resolved = fl
            elif refresh_fl or self.fl is None:
                resolved = FLList.from_frequencies(
                    self.surviving_frequencies(),
                    sw_count=self.sw_count,
                    fu_count=self.fu_count,
                )
            else:
                resolved = self.fl
            self.wal.append("commit", {"fl": fl_to_payload(resolved)})
            fl = resolved
        new_docs = list(self._buffer.values())
        self._buffer = {}
        if fl is not None:
            new_fl = fl
        elif refresh_fl or self.fl is None:
            new_fl = FLList.from_frequencies(
                self.surviving_frequencies(),
                sw_count=self.sw_count,
                fu_count=self.fu_count,
            )
        else:
            new_fl = self.fl

        rekeyed: list[Document] = []
        n_drifted = 0
        if self.fl is not None and new_fl is not self.fl:
            rekeyed, n_drifted = self._rekey_drifted(self.fl, new_fl)
        self.fl = new_fl

        batch = rekeyed + new_docs
        if batch:
            if self.use_fast_builder:
                from .fastbuild import build_segment_fast as _builder
            else:
                _builder = build_segment
            seg_index = _builder(
                batch,
                new_fl,
                max_distance=self.max_distance,
                build_pair=self.build_pair,
                build_degenerate=self.build_degenerate,
            )
            self.segments.append(
                Segment(index=seg_index, doc_ids=frozenset(d.doc_id for d in batch))
            )
        for doc in new_docs:
            self.documents[doc.doc_id] = doc
        self.generation += 1
        self._view = None
        self._mutations += 1
        self._notify()
        return {
            "new_docs": len(new_docs),
            "rekeyed_docs": len(rekeyed),
            "drifted_lemmas": n_drifted,
            "segments": len(self.segments),
        }

    def _rekey_drifted(
        self, old_fl: FLList, new_fl: FLList
    ) -> tuple[list[Document], int]:
        """FL-drift handling: supersede-and-reindex ONLY affected documents.

        A document is affected iff its ``lemma_order_signature`` changed —
        the exact invariance condition of per-document row generation.  For
        every kept document, stored postings remain valid except the NSW
        stop-lemma ids (absolute FL-numbers), which are remapped in bulk.
        """
        changed: set[str] = set()
        for l in set(old_fl.fl_number) | set(new_fl.fl_number):
            if l not in old_fl.fl_number or l not in new_fl.fl_number:
                # absent lemmas share one sentinel FL-number: always drifted
                changed.add(l)
            elif old_fl.fl_number[l] != new_fl.fl_number[l] or old_fl.lemma_type(
                l
            ) != new_fl.lemma_type(l):
                changed.add(l)
        if not changed:
            return [], 0

        rekeyed: list[Document] = []
        for seg in self.segments:
            live = seg.doc_ids - self.tombstones - seg.superseded
            for doc_id in live:
                doc = self.documents[doc_id]
                lemmas = self._doc_lemmas[doc_id]
                if not (lemmas & changed):
                    continue
                # the signature IS the invariance condition: it orders
                # sentinel-tied (FL-unknown) lemmas deterministically by
                # string and carries each lemma's type, so comparing it
                # re-keys exactly the docs whose rows could differ — a
                # lemma merely ENTERING the FL list (e.g. under a pinned
                # shard-global FL) does not re-key docs whose relative
                # order and types are unchanged
                if lemma_order_signature(lemmas, old_fl) != lemma_order_signature(
                    lemmas, new_fl
                ):
                    seg.superseded.add(doc_id)
                    rekeyed.append(doc)

        # bulk NSW remap for kept docs: old stop FL-number -> new FL-number.
        # Stop lemmas that left the stop class only occur in superseded or
        # dead docs (a type change flips the signature), so -1 never serves.
        remap = np.full(max(old_fl.sw_count, 1), -1, dtype=np.int32)
        remap_needed = False
        for l, old_n in old_fl.fl_number.items():
            if old_n >= old_fl.sw_count:
                continue
            new_n = new_fl.fl_number.get(l)
            if new_n is not None and new_n < new_fl.sw_count:
                remap[old_n] = new_n
                if new_n != old_n:
                    remap_needed = True
        if remap_needed:
            for seg in self.segments:
                for lemma, rec in list(seg.index.nsw.items()):
                    if len(rec.stop_lemma):
                        # replace, don't mutate: materialized to_index_set()
                        # snapshots may share the NSWRecords object (single-
                        # contributor merges return originals) and must stay
                        # consistent with their pinned FL generation
                        seg.index.nsw[lemma] = NSWRecords(
                            offsets=rec.offsets,
                            stop_lemma=remap[rec.stop_lemma],
                            distance=rec.distance,
                        )
        return rekeyed, len(changed)

    # -- compaction ---------------------------------------------------------

    def compact(self, memory_budget_bytes: int | None = None) -> dict:
        """Rewrite segments: k-way merge adjacent segments into as few as the
        ``memory_budget_bytes`` working-set bound allows, physically dropping
        tombstoned and superseded rows; clears the collected tombstones.
        With a §18 WAL attached the compaction (a deterministic function
        of the budget and current state) is durably logged before it runs.
        """
        if not self.segments:
            return {"segments": 0, "collected": 0}
        if self.wal is not None:
            self.wal.append("compact", {"memory_budget_bytes": memory_budget_bytes})
        groups: list[list[Segment]] = []
        cur: list[Segment] = []
        cur_bytes = 0
        for seg in self.segments:
            nbytes = seg.live_bytes()
            if cur and memory_budget_bytes and cur_bytes + nbytes > memory_budget_bytes:
                groups.append(cur)
                cur, cur_bytes = [], 0
            cur.append(seg)
            cur_bytes += nbytes
        groups.append(cur)

        new_segments: list[Segment] = []
        collected = 0
        for group in groups:
            dead_ids = set()
            for seg in group:
                dead_ids |= (seg.doc_ids & self.tombstones) | seg.superseded
            if len(group) == 1 and not dead_ids:
                new_segments.append(group[0])
                continue
            contribs = [
                (seg.index, self._dead_array(seg)) for seg in group
            ]
            # liveness is per segment: a doc superseded in one segment may be
            # live through its re-keyed copy in another segment of the group
            live_ids = frozenset().union(
                *(
                    seg.doc_ids - seg.superseded - self.tombstones
                    for seg in group
                )
            )
            view = SegmentedIndexSet(
                fl=self.fl
                or FLList.from_frequencies(
                    {}, sw_count=self.sw_count, fu_count=self.fu_count
                ),
                max_distance=self.max_distance,
                contribs=contribs,
                n_docs=len(live_ids),
            )
            merged = view.to_index_set()
            new_segments.append(Segment(index=merged, doc_ids=live_ids))
            # a tombstone is collectable only once its LIVE (non-superseded)
            # copy is physically gone — superseded copies in other groups are
            # filtered by their segment's superseded set, not the tombstone
            dropped_tombstones = set()
            for seg in group:
                dropped_tombstones |= (seg.doc_ids & self.tombstones) - seg.superseded
            self.tombstones -= dropped_tombstones
            collected += len(dropped_tombstones)
        self.segments = new_segments
        self._view = None
        self._mutations += 1
        self._notify()
        return {"segments": len(self.segments), "collected": collected}

    # -- the live view ------------------------------------------------------

    def _dead_array(self, seg: Segment) -> np.ndarray:
        dead = (seg.doc_ids & self.tombstones) | seg.superseded
        return np.asarray(sorted(dead), dtype=np.int64)

    @property
    def index(self) -> SegmentedIndexSet:
        """The live multi-segment ``IndexSet`` view (cached per mutation)."""
        if self._view is None:
            fl = self.fl or FLList.from_frequencies(
                {}, sw_count=self.sw_count, fu_count=self.fu_count
            )
            contribs = [(seg.index, self._dead_array(seg)) for seg in self.segments]
            self._view = SegmentedIndexSet(
                fl=fl,
                max_distance=self.max_distance,
                contribs=contribs,
                n_docs=len(self.documents),
            )
        return self._view

    def surviving_store(self) -> DocumentStore:
        """The rebuild corpus: committed survivors in doc-id order."""
        return DocumentStore.from_documents(
            (self.documents[i] for i in sorted(self.documents)),
            lemmatizer=self.lemmatizer,
        )

    def rebuild_index_set(self) -> IndexSet:
        """From-scratch ``build_indexes`` over the survivors — the oracle the
        differential harness compares ``index.to_index_set()`` against."""
        from .builder import build_indexes

        return build_indexes(
            self.surviving_store(),
            sw_count=self.sw_count,
            fu_count=self.fu_count,
            max_distance=self.max_distance,
            build_pair=self.build_pair,
            build_degenerate=self.build_degenerate,
        )


def as_index_set(obj) -> IndexSet:
    """Engines accept either a plain §3 ``IndexSet`` or an
    ``IncrementalIndexer`` (resolved to its live DESIGN.md §10 view per
    call, so commits/deletes are picked up)."""
    if isinstance(obj, IncrementalIndexer):
        return obj.index
    return obj


def generation_token(obj) -> object:
    """The cache-invalidation token for any index source (DESIGN.md §11).

    * ``IncrementalIndexer`` (or anything exposing ``generation_token``,
      e.g. ``ShardedSearchService``) — its monotone mutation token;
    * plain ``IndexSet`` — the constant 0 (immutable snapshot, caches never
      go stale).

    Frontend LRU caches key every entry by this token: a bump makes all old
    entries unreachable (natural invalidation, eventual LRU eviction).
    """
    tok = getattr(obj, "generation_token", None)
    if tok is None:
        return 0
    return tok


# ---------------------------------------------------------------------------
# structural equality (the differential harness' pin)
# ---------------------------------------------------------------------------


def _nsw_equal(a: NSWRecords, b: NSWRecords) -> bool:
    return (
        np.array_equal(a.offsets, b.offsets)
        and np.array_equal(a.stop_lemma, b.stop_lemma)
        and np.array_equal(a.distance, b.distance)
    )


def index_sets_equal(a: IndexSet, b: IndexSet) -> tuple[bool, str]:
    """Byte-level structural equality of two §3 index sets — the
    incremental == rebuild pin of DESIGN.md §10.3.

    Returns ``(equal, reason)`` — the reason names the first divergence so a
    failing differential test points straight at the broken layer.
    """
    if a.max_distance != b.max_distance:
        return False, f"max_distance {a.max_distance} != {b.max_distance}"
    if a.n_docs != b.n_docs:
        return False, f"n_docs {a.n_docs} != {b.n_docs}"
    if a.fl.lemmas != b.fl.lemmas:
        return False, "fl.lemmas order differs"
    if a.fl.frequency != b.fl.frequency:
        return False, "fl.frequency differs"
    if (a.fl.sw_count, a.fl.fu_count) != (b.fl.sw_count, b.fl.fu_count):
        return False, "fl sw/fu counts differ"
    for fname in ("ordinary", "pair", "triple", "stop_single", "stop_pair"):
        da, db = getattr(a, fname), getattr(b, fname)
        ka, kb = set(da.keys()), set(db.keys())
        if ka != kb:
            return False, f"{fname} key sets differ (e.g. {sorted(ka ^ kb)[:3]})"
        for key in ka:
            if not np.array_equal(da[key], db[key]):
                return False, f"{fname}[{key!r}] rows differ"
    ka, kb = set(a.nsw.keys()), set(b.nsw.keys())
    if ka != kb:
        return False, f"nsw key sets differ (e.g. {sorted(ka ^ kb)[:3]})"
    for key in ka:
        if not _nsw_equal(a.nsw[key], b.nsw[key]):
            return False, f"nsw[{key!r}] differs"
    return True, "equal"
