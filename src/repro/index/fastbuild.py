"""Vectorized §3 row generation (DESIGN.md §17.1): ``build_segment_fast``.

``builder._RowAccumulator`` walks every occurrence with Python loops — per
occurrence it re-scans a ±MaxDistance window for pairs, stop pairs and
triples, and per non-stop occurrence it collects near-stop-word neighbours.
That per-token interpretation cost is what caps full builds at double-digit
docs/s.  This module generates the SAME rows as flat numpy batches:

* all documents of a batch are flattened into one occurrence table
  ``(doc, pos, gpos, lemma_id)`` where ``gpos`` is a *global* position with a
  ``MaxDistance + 1`` gap between documents — a single sorted axis on which
  every ±D window is two ``np.searchsorted`` calls and windows can never
  cross a document boundary;
* window memberships become ``repeat``/``arange`` ragged gathers, the §3
  pair/stop-pair/triple acceptance rules become boolean masks over those
  gathers (triples enumerate each window's unordered occurrence pairs once
  and orient them by the §3 rank/position rules, blocked to bound the
  working set);
* each family is finalized with ONE ``np.lexsort`` over (packed key, row
  columns) and split at key boundaries — per key this is exactly
  ``builder._sorted_rows``'s order, and NSW payloads are gathered under the
  same (stable) permutation the scalar ``finalize`` applies.

Exactness is the whole point: ``build_segment_fast(...)`` is
``index_sets_equal``-identical (rows, NSW offsets and payload order
included) to ``builder.build_segment(...)`` for every input — the §17
property suite and the CI differential gate pin this, which is what lets
the SPIMI bulk-ingest pipeline (``index/ingest.py``) and the incremental
committer use the fast path while ``build_segment`` stays the scalar
oracle.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from ..core.lemma import FLList, LemmaType
from .builder import IndexSet, NSWRecords

__all__ = ["build_segment_fast"]

_STOP = int(LemmaType.STOP)
_FU = int(LemmaType.FREQUENTLY_USED)

# per-center window-pair candidates processed per block: bounds the peak
# working set of the triple cross product without changing any output
_TRIPLE_BLOCK = 1 << 21


def _cumsum0(a: np.ndarray) -> np.ndarray:
    out = np.zeros(len(a) + 1, dtype=np.int64)
    np.cumsum(a, out=out[1:])
    return out


def _ragged_take(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Flat gather indices for ragged slices: element j of slice i is
    ``starts[i] + j`` — the repeat/arange pattern shared with the
    incremental NSW merge."""
    total = int(counts.sum())
    return (
        np.repeat(starts, counts)
        + np.arange(total, dtype=np.int64)
        - np.repeat(_cumsum0(counts)[:-1], counts)
    )


def _pack_keys(keycols: Sequence[np.ndarray], n_vocab: int) -> np.ndarray:
    """Mixed-radix pack of per-row key-id tuples into one int64 column —
    packed order == lexicographic id-tuple order, so one sort key replaces
    ``arity`` of them."""
    assert n_vocab ** len(keycols) < 2**63, "vocabulary too large to pack keys"
    packed = keycols[0].astype(np.int64, copy=True)
    for k in keycols[1:]:
        packed *= n_vocab
        packed += k
    return packed


def _family_dict(
    keycols: Sequence[np.ndarray],
    rowcols: Sequence[np.ndarray],
    vlist: list[str],
) -> dict:
    """Sort rows (packed key major, row columns minor — per key exactly
    ``_sorted_rows``'s lexicographic order), split at key boundaries, and
    assemble the key -> rows dict without per-row Python work."""
    n = len(rowcols[0])
    if n == 0:
        return {}
    packed = _pack_keys(keycols, len(vlist))
    order = np.lexsort(tuple(reversed(rowcols)) + (packed,))
    packed = packed[order]
    rows = np.stack([r[order] for r in rowcols], axis=1).astype(np.int32)
    starts = np.concatenate(
        ([0], np.flatnonzero(packed[1:] != packed[:-1]) + 1, [n])
    )
    arity = len(keycols)
    head = packed[starts[:-1]]
    cols: list[list[str]] = []
    for _ in range(arity):
        cols.append([vlist[i] for i in (head % len(vlist)).tolist()])
        head = head // len(vlist)
    keys = list(zip(*reversed(cols)))  # zip builds the key tuples in C
    return {
        k: rows[s:e]
        for k, s, e in zip(keys, starts[:-1].tolist(), starts[1:].tolist())
    }


def _candidates(
    documents: Sequence,
    fl: FLList,
    D: int,
    build_pair: bool,
    build_degenerate: bool,
    triple_key_filter: set[tuple[str, str, str]] | None,
) -> dict | None:
    """Shared §3 candidate generation: the occurrence table, the NSW flats
    and every family's pre-sort (key-id columns, row columns) arrays.
    ``build_segment_fast`` assembles these into an in-RAM ``IndexSet``;
    the spill writer (``ingest._write_spill_fast``) sorts the same arrays
    by lexicographic key rank and encodes them straight to disk.  Returns
    ``None`` when the batch has no occurrences."""

    # ---- flatten the batch into one occurrence table ---------------------
    # One pass over the whole batch instead of ~10 small numpy calls per
    # document: token counts and per-token lemma counts are gathered once,
    # and positions / doc ids / gap-shifted global positions are derived
    # with batch-wide repeat/cumsum arithmetic (identical values to the
    # per-doc construction — pinned by the builder differential suite).
    streams = [doc.lemma_stream for doc in documents]
    n_tok = np.fromiter(
        (len(s) for s in streams), dtype=np.int64, count=len(streams)
    )
    total_tok = int(n_tok.sum())
    flat = [l for s in streams for t in s for l in t]
    n = len(flat)
    if n == 0:
        return None
    lens = np.fromiter(
        (len(t) for s in streams for t in s), dtype=np.int64, count=total_tok
    )
    tok_start = _cumsum0(n_tok)  # doc boundaries on the token axis
    # token position within its document
    tok_pos = np.arange(total_tok, dtype=np.int64) - np.repeat(
        tok_start[:-1], n_tok
    )
    occ_start = _cumsum0(lens)  # doc boundaries on the occurrence axis
    occ_per_doc = occ_start[tok_start[1:]] - occ_start[tok_start[:-1]]
    pos = np.repeat(tok_pos, lens)
    doc = np.repeat(
        np.fromiter((d.doc_id for d in documents), dtype=np.int64,
                    count=len(documents)),
        occ_per_doc,
    )
    # windows can never cross documents: shift each doc by a D+1 gap
    gpos = pos + np.repeat(_cumsum0(n_tok + D + 1)[:-1], occ_per_doc)

    # one C-level unique pass interns the vocabulary: ids ARE lexicographic
    # ranks (ascending lemma order), which the spill writer relies on
    vlist_arr, lid = np.unique(np.asarray(flat), return_inverse=True)
    lid = lid.astype(np.int64)
    vlist = vlist_arr.tolist()
    vtyp = np.asarray([int(fl.lemma_type(l)) for l in vlist], dtype=np.int8)
    vnum = np.asarray([fl.number(l) for l in vlist], dtype=np.int64)
    typ = vtyp[lid]
    num = vnum[lid]

    # ±D window of every occurrence over the one sorted global-position axis
    lo = np.searchsorted(gpos, gpos - D, side="left")
    hi = np.searchsorted(gpos, gpos + D + 1, side="left")  # exclusive

    sidx = np.flatnonzero(typ == _STOP)  # stop occurrences, in batch order
    sg = gpos[sidx]
    slo = np.searchsorted(sg, gpos - D, side="left")
    shi = np.searchsorted(sg, gpos + D + 1, side="left")

    # ---- NSW payload flats (pre-sort, per occurrence) --------------------
    nsw_counts = np.where(typ != _STOP, shi - slo, 0)
    pay_idx = _ragged_take(slo, nsw_counts)  # indices into sidx
    rep_occ = np.repeat(np.arange(n, dtype=np.int64), nsw_counts)
    nsw_stop_flat = vnum[lid[sidx[pay_idx]]]
    nsw_dist_flat = pos[sidx[pay_idx]] - pos[rep_occ]
    pay_starts = _cumsum0(nsw_counts)[:-1]  # per-occurrence payload start

    # ---- (w,v) pair candidates ------------------------------------------
    pair_cand = None
    if build_pair:
        c = np.flatnonzero(typ == _FU)
        cnt = hi[c] - lo[c]
        j = _ragged_take(lo[c], cnt)  # neighbour occ index (incl. self)
        ci = np.repeat(c, cnt)
        keep = (
            (j != ci)
            & (typ[j] != _STOP)
            & ~((typ[j] == _FU) & (num[ci] >= num[j]))
        )
        ci, j = ci[keep], j[keep]
        pair_cand = (
            (lid[ci], lid[j]),
            (doc[ci], pos[ci], pos[j] - pos[ci]),
        )

    # ---- degenerate stop pair candidates ---------------------------------
    # per-stop-occurrence views: one gather each, then every candidate
    # lookup below indexes these directly instead of through sidx twice
    stop_pair_cand = None
    ns = len(sidx)
    sclo = slo[sidx]  # stop-window bounds per stop occurrence
    schi = shi[sidx]
    snum = num[sidx]
    sgpos = gpos[sidx]
    slid = lid[sidx]
    sdoc = doc[sidx]
    spos = pos[sidx]
    if build_degenerate and ns:
        cnt = schi - sclo
        js = _ragged_take(sclo, cnt)  # neighbour index into sidx
        ai = np.repeat(np.arange(ns, dtype=np.int64), cnt)  # center's sidx pos
        keep = (js != ai) & (
            (snum[ai] < snum[js])
            | ((snum[ai] == snum[js]) & (sgpos[ai] < sgpos[js]))
        )
        ai, js = ai[keep], js[keep]
        stop_pair_cand = (
            (slid[ai], slid[js]),
            (sdoc[ai], spos[ai], spos[js] - spos[ai]),
        )

    # ---- (f,s,t) triple candidates --------------------------------------
    triple_cand = None
    if ns:
        allowed = None
        V = len(vlist)
        if triple_key_filter is not None:
            vocab = {l: i for i, l in enumerate(vlist)}
            packed = [
                (vocab[a] * V + vocab[b]) * V + vocab[c]
                for a, b, c in triple_key_filter
                if a in vocab and b in vocab and c in vocab
            ]
            allowed = np.asarray(sorted(packed), dtype=np.int64)
        m = schi - sclo
        msq = m * m
        key_parts: list[np.ndarray] = []
        row_parts: list[tuple[np.ndarray, ...]] = []
        blocks = _cumsum0(msq)
        start = 0
        while start < ns:
            # grow the center block until its window cross-product count
            # hits the cap
            end = int(
                np.searchsorted(blocks, blocks[start] + _TRIPLE_BLOCK, side="left")
            )
            end = min(max(end, start + 1), ns)
            A = np.arange(start, end, dtype=np.int64)
            msq_a = msq[A]
            total = int(msq_a.sum())
            start = end
            if total == 0:
                continue
            t = (
                np.arange(total, dtype=np.int64)
                - np.repeat(_cumsum0(msq_a)[:-1], msq_a)
            )
            mrep = np.repeat(m[A], msq_a)
            base = np.repeat(sclo[A], msq_a)
            ai = np.repeat(A, msq_a)  # center's index into sidx
            # enumerate each unordered window pair {u < v} once (strict
            # upper triangle), excluding the center itself
            us = base + t // mrep
            vs = base + t % mrep
            tri = (us < vs) & (us != ai) & (vs != ai)
            us, vs, ai = us[tri], vs[tri], ai[tri]
            ni = snum[ai]
            nu = snum[us]
            nv = snum[vs]
            keep = (nu >= ni) & (nv >= ni)  # f most frequent of the triple
            us, vs, ai = us[keep], vs[keep], ai[keep]
            nu, nv = nu[keep], nv[keep]
            # orient the pair into canonical (s, t): ascending rank; equal
            # ranks order by position, exact position ties by the scalar's
            # window-order rule (`b < a`), which for u < v emits (v, u)
            gu = sgpos[us]
            gv = sgpos[vs]
            swap = (nv < nu) | ((nu == nv) & (gu >= gv))
            js = np.where(swap, vs, us)
            ks = np.where(swap, us, vs)
            pk = (slid[ai] * V + slid[js]) * V + slid[ks]
            if allowed is not None:
                inset = np.isin(pk, allowed)
                ai, js, ks, pk = ai[inset], js[inset], ks[inset], pk[inset]
            key_parts.append(pk)
            row_parts.append(
                (sdoc[ai], spos[ai], spos[js] - spos[ai], spos[ks] - spos[ai])
            )
        if key_parts:
            packed_all = np.concatenate(key_parts)
            k1 = packed_all // (V * V)
            rem = packed_all % (V * V)
            rowcols = tuple(
                np.concatenate([p[i] for p in row_parts]) for i in range(4)
            )
            triple_cand = ((k1, rem // V, rem % V), rowcols)

    return {
        "n": n,
        "vlist": vlist,
        "vtyp": vtyp,
        "vnum": vnum,
        "lid": lid,
        "doc": doc,
        "pos": pos,
        "nsw_counts": nsw_counts,
        "pay_starts": pay_starts,
        "nsw_stop_flat": nsw_stop_flat,
        "nsw_dist_flat": nsw_dist_flat,
        "pair": pair_cand,
        "stop_pair": stop_pair_cand,
        "triple": triple_cand,
    }


def build_segment_fast(
    documents: Sequence,
    fl: FLList,
    max_distance: int = 5,
    build_pair: bool = True,
    build_degenerate: bool = True,
    triple_key_filter: set[tuple[str, str, str]] | None = None,
) -> IndexSet:
    """Drop-in vectorized replacement for ``builder.build_segment`` (the
    §3 index families; DESIGN.md §17.1) — byte-identical output (see
    module docstring), same signature."""
    D = int(max_distance)
    n_docs = len(documents)
    cand = _candidates(
        documents, fl, D, build_pair, build_degenerate, triple_key_filter
    )
    if cand is None:
        return IndexSet(
            fl=fl, max_distance=D, ordinary={}, nsw={}, pair={}, triple={},
            stop_single={}, stop_pair={}, n_docs=n_docs,
        )
    n = cand["n"]
    vlist = cand["vlist"]
    vtyp = cand["vtyp"]
    lid, doc, pos = cand["lid"], cand["doc"], cand["pos"]

    # ---- ordinary index + NSW -------------------------------------------
    # One stable lexsort (pos, doc, lemma) gives every lemma's rows in
    # exactly _sorted_rows order AND — because ties keep insertion order —
    # the same per-lemma permutation finalize() applies to NSW slices.
    order = np.lexsort((pos, doc, lid))
    lid_s = lid[order]
    ord_rows = np.stack((doc[order], pos[order]), axis=1).astype(np.int32)
    counts_s = cand["nsw_counts"][order]
    src = _ragged_take(cand["pay_starts"][order], counts_s)
    stop_s = cand["nsw_stop_flat"][src].astype(np.int32)
    dist_s = cand["nsw_dist_flat"][src].astype(np.int32)
    pcs = _cumsum0(counts_s)

    bnd = np.concatenate(
        ([0], np.flatnonzero(lid_s[1:] != lid_s[:-1]) + 1, [n])
    )
    group_ids = lid_s[bnd[:-1]].tolist()
    group_stop = (vtyp[lid_s[bnd[:-1]]] == _STOP).tolist()
    ordinary: dict[str, np.ndarray] = {}
    nsw: dict[str, NSWRecords] = {}
    stop_single: dict[tuple[str], np.ndarray] = {}
    for v, is_stop, s, e in zip(
        group_ids, group_stop, bnd[:-1].tolist(), bnd[1:].tolist()
    ):
        lemma = vlist[v]
        rows = ord_rows[s:e]
        ordinary[lemma] = rows
        if not is_stop:
            nsw[lemma] = NSWRecords(
                offsets=pcs[s : e + 1] - pcs[s],
                stop_lemma=stop_s[pcs[s] : pcs[e]],
                distance=dist_s[pcs[s] : pcs[e]],
            )
        elif build_degenerate:
            # a stop lemma's degenerate single-key rows ARE its ordinary
            # rows (same (doc,pos) content, same order) — share the slice
            stop_single[(lemma,)] = rows

    pair = (
        _family_dict(*cand["pair"], vlist) if cand["pair"] is not None else {}
    )
    stop_pair = (
        _family_dict(*cand["stop_pair"], vlist)
        if cand["stop_pair"] is not None else {}
    )
    triple = (
        _family_dict(*cand["triple"], vlist)
        if cand["triple"] is not None else {}
    )

    return IndexSet(
        fl=fl,
        max_distance=D,
        ordinary=ordinary,
        nsw=nsw,
        pair=pair,
        triple=triple,
        stop_single=stop_single,
        stop_pair=stop_pair,
        n_docs=n_docs,
    )
