"""Index builder (paper §3): ordinary index with NSW records, two-component
(w,v) indexes and three-component (f,s,t) indexes, all as sorted numpy arrays.

Posting layouts (int32, lexicographically sorted rows — the §4 order):

  ordinary:          (doc, pos)
  (w,v)    arity 2:  (doc, pos_w, d_v)               |d| <= MaxDistance
  (f,s,t)  arity 3:  (doc, pos_f, d1_s, d2_t)        |d1|,|d2| <= MaxDistance

Three-component keys are built for stop-lemma triples with FL(f)<=FL(s)<=FL(t)
(paper: "only when f, s, and t are all stop lemmas and only for f <= s <= t").
When s == t the (d1, d2) pair enumerates *unordered distinct* occurrence pairs
with d1 < d2 (exactly the paper's (be, who, who) example records).

NSW (near-stop-word) records attach, to every ordinary posting of a
frequently-used/ordinary lemma, the stop lemmas within MaxDistance — stored as
a ragged (offsets, lemma_id, distance) triple.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

from ..core.lemma import FLList, LemmaType
from .corpus import DocumentStore

__all__ = ["IndexSet", "build_indexes", "build_segment", "NSWRecords", "POSTING_WIDTH"]

_POSTING_BYTES = {1: 8, 2: 12, 3: 16}  # int32 record sizes per key arity

# §4 row widths (int32 columns) per posting family — the ONE table the
# incremental merge layer and the on-disk store both key their layouts by
POSTING_WIDTH = {
    "ordinary": 2,
    "stop_single": 2,
    "pair": 3,
    "stop_pair": 3,
    "triple": 4,
}


@dataclass
class NSWRecords:
    """Ragged §3 near-stop-word records parallel to an ordinary posting
    array: per posting, the stop lemmas within MaxDistance and their signed
    distances (stop lemma ids are absolute FL-numbers — the one place they
    reach storage, see DESIGN.md §10.2)."""

    offsets: np.ndarray  # (n_postings + 1,) int64
    stop_lemma: np.ndarray  # (total,) int32 FL-numbers
    distance: np.ndarray  # (total,) int32


@dataclass
class IndexSet:
    """Everything §3 defines, over one document shard."""

    fl: FLList
    max_distance: int
    # ordinary inverted index: lemma -> (n,2) [doc, pos]
    ordinary: dict[str, np.ndarray]
    # NSW records parallel to `ordinary` for FU/ordinary lemmas
    nsw: dict[str, NSWRecords]
    # multi-component indexes keyed by canonical lemma tuples
    pair: dict[tuple[str, str], np.ndarray]
    triple: dict[tuple[str, str, str], np.ndarray]
    # degenerate stop-lemma keys for 1/2-lemma subqueries (paper §14 allows
    # "any multi-component indexes and one-component indexes")
    stop_single: dict[tuple[str], np.ndarray] = field(default_factory=dict)
    stop_pair: dict[tuple[str, str], np.ndarray] = field(default_factory=dict)
    n_docs: int = 0

    def key_postings(self, key: tuple[str, ...]) -> np.ndarray:
        """Postings for a canonical key of any arity (empty if absent)."""
        if len(key) == 3:
            return self.triple.get(key, _EMPTY3)
        if len(key) == 2:
            arr = self.stop_pair.get(key)
            if arr is None:
                arr = self.pair.get(key, _EMPTY2)
            return arr
        return self.stop_single.get(key, _EMPTY1)

    def size_bytes(self) -> dict[str, int]:
        ordinary = sum(a.nbytes for a in self.ordinary.values())
        nsw = sum(r.stop_lemma.nbytes + r.distance.nbytes + r.offsets.nbytes for r in self.nsw.values())
        pair = sum(a.nbytes for a in self.pair.values())
        triple = sum(a.nbytes for a in self.triple.values())
        extra = sum(a.nbytes for a in self.stop_single.values()) + sum(
            a.nbytes for a in self.stop_pair.values()
        )
        return {
            "ordinary": ordinary,
            "nsw": nsw,
            "pair": pair,
            "triple": triple,
            "stop_degenerate": extra,
            "total": ordinary + nsw + pair + triple + extra,
        }


_EMPTY1 = np.empty((0, 2), dtype=np.int32)
_EMPTY2 = np.empty((0, 3), dtype=np.int32)
_EMPTY3 = np.empty((0, 4), dtype=np.int32)


def _sorted_rows(rows: list[tuple[int, ...]], width: int) -> np.ndarray:
    if not rows:
        return np.empty((0, width), dtype=np.int32)
    arr = np.asarray(rows, dtype=np.int32)
    order = np.lexsort(tuple(arr[:, c] for c in range(arr.shape[1] - 1, -1, -1)))
    return arr[order]


class _RowAccumulator:
    """Per-document §3 row generation.

    The unit of construction is ONE document: ``add_document`` appends every
    row the document contributes to every index, and ``finalize`` sorts/packs
    the accumulated rows into an immutable :class:`IndexSet`.  Whole-corpus
    builds (``build_indexes``) and incremental segment builds
    (``build_segment``, used by ``index/incremental.py``) share this code, so
    a segment over a document batch is byte-identical to the corresponding
    slice of a full rebuild.
    """

    def __init__(
        self,
        fl: FLList,
        max_distance: int,
        build_pair: bool = True,
        build_degenerate: bool = True,
        triple_key_filter: set[tuple[str, str, str]] | None = None,
    ):
        self.fl = fl
        self.max_distance = max_distance
        self.build_pair = build_pair
        self.build_degenerate = build_degenerate
        self.triple_key_filter = triple_key_filter
        self.ordinary_rows: dict[str, list[tuple[int, int]]] = {}
        self.pair_rows: dict[tuple[str, str], list[tuple[int, int, int]]] = {}
        self.triple_rows: dict[tuple[str, str, str], list[tuple[int, int, int, int]]] = {}
        self.single_rows: dict[tuple[str], list[tuple[int, int]]] = {}
        self.spair_rows: dict[tuple[str, str], list[tuple[int, int, int]]] = {}
        self.nsw_raw: dict[str, list[list[tuple[int, int]]]] = {}

    def add_document(self, doc) -> None:
        fl = self.fl
        D = self.max_distance
        build_pair = self.build_pair
        build_degenerate = self.build_degenerate
        triple_key_filter = self.triple_key_filter
        ordinary_rows = self.ordinary_rows
        pair_rows = self.pair_rows
        triple_rows = self.triple_rows
        single_rows = self.single_rows
        spair_rows = self.spair_rows
        nsw_raw = self.nsw_raw
        # occurrence list: (pos, lemma) for every lemma of every position
        occ: list[tuple[int, str]] = []
        for pos, lemmas in enumerate(doc.lemma_stream):
            for l in lemmas:
                occ.append((pos, l))
        n = len(occ)
        types = [fl.lemma_type(l) for _, l in occ]
        numbers = [fl.number(l) for _, l in occ]

        # ---- ordinary index + NSW ---------------------------------------
        for (pos, l), t in zip(occ, types):
            ordinary_rows.setdefault(l, []).append((doc.doc_id, pos))
            if t != LemmaType.STOP:
                near: list[tuple[int, int]] = []
                for (p2, l2), t2 in zip(occ, types):
                    if t2 == LemmaType.STOP and abs(p2 - pos) <= D:
                        near.append((fl.number(l2), p2 - pos))
                nsw_raw.setdefault(l, []).append(near)
            elif build_degenerate:
                single_rows.setdefault((l,), []).append((doc.doc_id, pos))

        # ---- windowed co-occurrence scan ---------------------------------
        # occ is sorted by position (multi-lemma entries share a position).
        for i in range(n):
            pi, li = occ[i]
            ti, ni = types[i], numbers[i]
            # neighbours within +-D of occurrence i (excluding i itself)
            lo = i
            while lo > 0 and occ[lo - 1][0] >= pi - D:
                lo -= 1
            hi = i
            while hi + 1 < n and occ[hi + 1][0] <= pi + D:
                hi += 1
            neigh = [j for j in range(lo, hi + 1) if j != i]

            # (w,v) index: w frequently used, v FU-or-ordinary;
            # if both FU then only w < v.
            if build_pair and ti == LemmaType.FREQUENTLY_USED:
                for j in neigh:
                    pj, lj = occ[j]
                    tj, nj = types[j], numbers[j]
                    if tj == LemmaType.STOP:
                        continue
                    if tj == LemmaType.FREQUENTLY_USED and not (ni < nj):
                        continue
                    pair_rows.setdefault((li, lj), []).append((doc.doc_id, pi, pj - pi))

            if ti != LemmaType.STOP:
                continue

            # stop-lemma neighbours only, for (f,s,t) and (f,s) keys
            sneigh = [j for j in neigh if types[j] == LemmaType.STOP]

            if build_degenerate:
                for j in sneigh:
                    pj, lj, nj = occ[j][0], occ[j][1], numbers[j]
                    if ni < nj or (ni == nj and pi < pj):
                        spair_rows.setdefault((li, lj), []).append((doc.doc_id, pi, pj - pi))

            # center occurrence i is an occurrence of f; every pair (j,k)
            # of stop neighbours with FL(f) <= FL(s) <= FL(t) yields a record.
            m = len(sneigh)
            for a in range(m):
                j = sneigh[a]
                pj, lj, nj = occ[j][0], occ[j][1], numbers[j]
                if nj < ni:
                    continue  # f must be the most frequent of the triple
                for b in range(m):
                    if b == a:
                        continue
                    k = sneigh[b]
                    pk, lk, nk = occ[k][0], occ[k][1], numbers[k]
                    if nk < ni:
                        continue
                    # canonical order inside (s, t)
                    if nj > nk:
                        continue  # handled when (a, b) swapped
                    if nj == nk:
                        # same lemma rank: unordered distinct pair, d1 < d2
                        if not (pj < pk or (pj == pk and b < a)):
                            continue
                    key = (li, lj, lk)
                    if triple_key_filter is not None and key not in triple_key_filter:
                        continue
                    triple_rows.setdefault(key, []).append(
                        (doc.doc_id, pi, pj - pi, pk - pi)
                    )

    def finalize(self, n_docs: int) -> IndexSet:
        ordinary = {l: _sorted_rows(r, 2) for l, r in self.ordinary_rows.items()}

        # pack NSW records aligned with the *sorted* ordinary posting order
        nsw: dict[str, NSWRecords] = {}
        for l, per_posting in self.nsw_raw.items():
            rows = self.ordinary_rows[l]
            order = np.lexsort(
                (np.asarray([p for _, p in rows]), np.asarray([d for d, _ in rows]))
            )
            offsets = [0]
            stop_l: list[int] = []
            dist: list[int] = []
            for idx in order:
                for sl, dd in per_posting[idx]:
                    stop_l.append(sl)
                    dist.append(dd)
                offsets.append(len(stop_l))
            nsw[l] = NSWRecords(
                offsets=np.asarray(offsets, dtype=np.int64),
                stop_lemma=np.asarray(stop_l, dtype=np.int32),
                distance=np.asarray(dist, dtype=np.int32),
            )

        return IndexSet(
            fl=self.fl,
            max_distance=self.max_distance,
            ordinary=ordinary,
            nsw=nsw,
            pair={k: _sorted_rows(r, 3) for k, r in self.pair_rows.items()},
            triple={k: _sorted_rows(r, 4) for k, r in self.triple_rows.items()},
            stop_single={k: _sorted_rows(r, 2) for k, r in self.single_rows.items()},
            stop_pair={k: _sorted_rows(r, 3) for k, r in self.spair_rows.items()},
            n_docs=n_docs,
        )


def build_indexes(
    store: DocumentStore,
    sw_count: int,
    fu_count: int,
    max_distance: int = 5,
    build_pair: bool = True,
    build_degenerate: bool = True,
    triple_key_filter: set[tuple[str, str, str]] | None = None,
    fl: FLList | None = None,
) -> IndexSet:
    """Build every §3 index over ``store``.

    ``triple_key_filter`` restricts the (f,s,t) build to a key subset —
    used by large-corpus benchmarks to bound build time exactly like an
    on-demand index materialization would.  ``fl`` overrides the FL-list
    (document shards must share the corpus-global lemma typing — in
    production the FL-list is a corpus-level reduce broadcast to builders).
    """
    if fl is None:
        freq = store.lemma_frequencies()
        fl = FLList.from_frequencies(freq, sw_count=sw_count, fu_count=fu_count)
    return build_segment(
        store.documents,
        fl,
        max_distance=max_distance,
        build_pair=build_pair,
        build_degenerate=build_degenerate,
        triple_key_filter=triple_key_filter,
    )


def build_segment(
    documents: Sequence,
    fl: FLList,
    max_distance: int = 5,
    build_pair: bool = True,
    build_degenerate: bool = True,
    triple_key_filter: set[tuple[str, str, str]] | None = None,
) -> IndexSet:
    """Build one immutable sorted segment over a document batch.

    This is the incremental-construction unit (``index/incremental.py``): a
    segment is a complete §3 ``IndexSet`` over its batch, and because row
    generation is per-document, a segment's per-document content is
    byte-identical to a whole-corpus rebuild's — k-way segment merges can
    therefore reproduce a from-scratch build exactly.
    """
    acc = _RowAccumulator(
        fl,
        max_distance,
        build_pair=build_pair,
        build_degenerate=build_degenerate,
        triple_key_filter=triple_key_filter,
    )
    for doc in documents:
        acc.add_document(doc)
    return acc.finalize(n_docs=len(documents))
