"""SPIMI-style external-memory bulk ingestion (DESIGN.md §17).

The companion construction paper (arXiv 2006.07954) argues index
*construction* is the engineering bottleneck of the multi-component key
scheme; the classic answer is SPIMI — single-pass in-memory indexing over
corpus blocks with immutable on-disk spill segments and a k-way merge.
This module is that pipeline on top of the repo's existing pieces:

* **Chunking (phase L)** — the corpus is split at FIXED document
  boundaries (``docs_per_spill``; never dependent on worker count or
  scheduling).  Each chunk is lemmatized (batched/memoized §2 lemmatizer)
  and persisted as ``chunk_XXXX/docs.jsonl`` plus a fsync'd ``chunk.json``
  carrying the chunk's lemma frequencies and a CRC32 of the doc file —
  the durable unit of resume.
* **FL reduce** — chunk frequency counters merge into the global FL-list
  (or an explicit ``fl=`` is used, e.g. the shard-global FL of
  ``serve.py --bulk-ingest``); identical corpus -> identical FL.
* **Spill (phase S)** — each chunk builds its §3 families with the
  vectorized ``build_segment_fast`` and writes an immutable §12.2 segment
  store at ``chunk_XXXX/seg_000``.  The store's manifest is written last
  (fsync'd), so a crash mid-spill leaves a spill that simply fails
  validation and is rebuilt on resume — §12.4 ordering, no new machinery.
* **Merge** — a single deterministic pass streams every family from the
  spill stores into one final segment: per family the sorted key UNION is
  split into row-budgeted batches; each spill contributes one contiguous
  mmap'd column slice per batch (its keys are sorted, so a union key range
  is one row range), slices are merged with ONE stable ``np.lexsort``
  (batch-key rank major, §4 row columns minor — exactly
  ``merge_posting_arrays`` / ``_merge_ordinary_nsw`` semantics, NSW
  payloads gathered under the same permutation), and re-encoded with the
  §12.1 codec through bounded temp-file column spools.  Peak memory is
  one batch, never the corpus.

The merged segment + concatenated document store are published atomically
as a normal ``snap_<N>`` snapshot (``repro.checkpoint`` tmp -> fsync ->
rename), so ``load_snapshot``/``IncrementalIndexer.restore`` serve a bulk
build exactly like any other snapshot and a crash mid-merge publishes
nothing.

Determinism contract (§17.4): chunk boundaries are worker-independent,
every spill is a pure function of (chunk docs, FL, params), the merge is
single-process over sorted key unions, and all artifacts use pinned zip
metadata — so two bulk builds of the same corpus with ANY worker counts
produce byte-identical snapshot directories.  Exactness: the merged index
is ``index_sets_equal``-identical to ``build_indexes`` over the same
corpus (property-tested, CI-gated).

Fault injection (§14 ABI, honored inline): ``ingest.lemmatize`` and
``ingest.spill`` fire per chunk before the phase work (``crash``/``kill``
abort the run mid-phase), ``ingest.merge`` fires per chunk as the merge
opens its spill (``bitflip`` physically corrupts that chunk's spill so
the CRC verify rejects it for real).
"""

from __future__ import annotations

import json
import multiprocessing
import os
import shutil
import time
import zlib
from collections import Counter
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Sequence

import numpy as np

from ..checkpoint import fsync_json, replace_dir, retain_latest
from ..core.lemma import FLList, Lemmatizer
from .builder import IndexSet, POSTING_WIDTH
from .corpus import Document
from .fastbuild import _STOP, _candidates, build_segment_fast
from .store import (
    FORMAT_VERSION,
    SNAPSHOT_PREFIX,
    StoreError,
    _KEY_SEP,
    _load_manifest,
    _open_blob,
    _PACK_DTYPES,
    _PACK_MAX,
    _pack,
    _savez_deterministic,
    _unzigzag,
    _write_durable,
    _zigzag,
    fl_signature,
    latest_snapshot,
    write_segment_store,
)

__all__ = ["BulkBuildStats", "bulk_build"]

_FAMILIES = tuple(POSTING_WIDTH)
_RUN_DIR = "ingest_run"
_SPILL = "seg_000"  # matches the §14 bitflip glob (seg_*/postings.bin)
_DOCS = "docs.jsonl"
_CHUNK_META = "chunk.json"
_RUN_META = "run.json"

# default rows decoded per merge batch; tests shrink this to force many
# batches on tiny corpora
DEFAULT_MERGE_BATCH_ROWS = 1 << 19


@dataclass
class BulkBuildStats:
    """Outcome of one :func:`bulk_build` run (DESIGN.md §17; the BENCH
    ingest section)."""

    snapshot_path: str
    n_docs: int
    n_chunks: int
    workers: int
    docs_per_spill: int
    chunks_reused: int      # valid chunks carried over by resume
    spills_reused: int      # valid spills carried over by resume
    lemmatize_s: float
    spill_s: float
    merge_s: float
    total_s: float
    docs_per_sec: float
    spill_bytes: int
    timings: dict = field(default_factory=dict)


# ---------------------------------------------------------------------------
# chunk layout + phase L (lemmatize)
# ---------------------------------------------------------------------------


def _chunk_dir(run_dir: Path, cid: int) -> Path:
    return run_dir / f"chunk_{cid:04d}"


def _corpus_crc(doc_ids: Sequence[int], texts: Sequence[str]) -> int:
    payload = json.dumps([[int(i), t] for i, t in zip(doc_ids, texts)])
    return zlib.crc32(payload.encode())


def _doc_line(doc: Document) -> str:
    # identical record shape to store.save_snapshot, so the merged
    # documents.jsonl is byte-identical to a live-indexer snapshot's
    return json.dumps({
        "doc_id": doc.doc_id,
        "text": doc.text,
        "lemmas": [list(t) for t in doc.lemma_stream],
    }) + "\n"


def _write_chunk(cdir: Path, docs: Sequence[Document]) -> None:
    # No fsync: a chunk is only ever trusted after its docs.jsonl bytes
    # match the CRC recorded in chunk.json (see _chunk_meta), so a torn
    # write is indistinguishable from an absent chunk and simply redone —
    # durability lives in the published snapshot, not the run directory.
    cdir.mkdir(parents=True, exist_ok=True)
    payload = "".join(_doc_line(d) for d in docs).encode()
    (cdir / _DOCS).write_bytes(payload)
    freq = Counter(
        l for d in docs for t in d.lemma_stream for l in t
    )
    with open(cdir / _CHUNK_META, "w") as f:
        json.dump({
            "n_docs": len(docs),
            "doc_ids": [int(d.doc_id) for d in docs],
            "freq": dict(freq),
            "docs_crc32": zlib.crc32(payload),
        }, f)


def _chunk_meta(cdir: Path) -> dict | None:
    """The chunk's fsync'd metadata iff the chunk is intact (docs.jsonl
    bytes match the recorded CRC) — resume's validity test."""
    try:
        with open(cdir / _CHUNK_META) as f:
            meta = json.load(f)
        payload = (cdir / _DOCS).read_bytes()
    except (OSError, json.JSONDecodeError, UnicodeDecodeError):
        return None
    if not isinstance(meta, dict) or zlib.crc32(payload) != meta.get("docs_crc32"):
        return None
    return meta


def _read_chunk_docs(cdir: Path) -> list[Document]:
    docs = []
    with open(cdir / _DOCS) as f:
        for line in f:
            rec = json.loads(line)
            docs.append(Document(
                doc_id=rec["doc_id"],
                text=rec["text"],
                lemma_stream=[tuple(t) for t in rec["lemmas"]],
            ))
    return docs


def _lemmatize_chunk(args) -> None:
    """Phase-L worker (top-level for multiprocessing): lemmatize one chunk
    and persist it.  Pure per-chunk -> identical output for any worker
    count."""
    run_dir, cid, pairs = args
    lem = Lemmatizer()
    docs = [
        Document(doc_id=i, text=t, lemma_stream=lem.lemmatize_text(t))
        for i, t in pairs
    ]
    _write_chunk(_chunk_dir(Path(run_dir), cid), docs)


# ---------------------------------------------------------------------------
# phase S (spill)
# ---------------------------------------------------------------------------


def _spill_chunk(args, docs: Sequence[Document] | None = None) -> None:
    """Phase-S worker: build one chunk's §3 families (vectorized) and write
    the immutable §12.2 spill store.  The store manifest lands last, so an
    interrupted spill is invalid, not torn.  Spills are CRC-validated
    caches (any torn/corrupt state fails ``_SpillReader`` and is rebuilt on
    resume), so the writer skips fsync — durability comes from the chunk
    files and the final snapshot, not the spills.

    Pool workers read the chunk docs back from disk; the single-process
    path passes them via ``docs`` (same values — the chunk file was either
    just written from them or CRC+corpus-crc validated against them), so
    spill output is byte-identical either way (§17.4)."""
    run_dir, cid, fl, max_distance, build_pair, build_degenerate, fl_crc = args
    cdir = _chunk_dir(Path(run_dir), cid)
    if docs is None:
        docs = _read_chunk_docs(cdir)
    spill = cdir / _SPILL
    if spill.exists():
        shutil.rmtree(spill)
    _write_spill_fast(
        docs, fl, spill, fl_crc=fl_crc,
        doc_ids=[d.doc_id for d in docs], max_distance=max_distance,
        build_pair=build_pair, build_degenerate=build_degenerate,
    )


def _shrunk_keys(strings) -> np.ndarray:
    """Key-table string array built exactly like ``write_segment_store``'s
    (``np.asarray(list, dtype=str)``), so the dtype width — and therefore
    the ``keys.npz`` bytes — match the generic writer."""
    if not isinstance(strings, list):
        strings = strings.tolist()
    return np.asarray(strings, dtype=str)


def _write_spill_fast(
    docs: Sequence[Document],
    fl: FLList,
    path: Path,
    fl_crc: int,
    doc_ids: Sequence[int],
    max_distance: int,
    build_pair: bool,
    build_degenerate: bool,
) -> None:
    """Encode one chunk's §3 families straight from the vectorized
    candidate arrays to a §12.2 segment store — byte-identical to
    ``write_segment_store(build_segment_fast(...), ...)`` (property-tested)
    but without materializing the key->rows dicts: vocabulary ids are
    mapped to lexicographic ranks, ONE packed stable sort per family yields
    the final on-disk key order, and columns are delta/zigzag/width-packed
    directly from the sorted int64 arrays.  Files are written without
    fsync (spills are CRC-validated caches, see ``_spill_chunk``)."""
    D = int(max_distance)
    cand = _candidates(docs, fl, D, build_pair, build_degenerate, None)
    if cand is None:
        # no occurrences: the generic writer handles the all-empty layout
        write_segment_store(
            build_segment_fast(docs, fl, max_distance=D,
                               build_pair=build_pair,
                               build_degenerate=build_degenerate),
            path, fl_crc=fl_crc, doc_ids=doc_ids,
        )
        return

    n = cand["n"]
    vlist = cand["vlist"]
    V = len(vlist)
    varr = np.asarray(vlist)
    order_v = np.argsort(varr, kind="stable")  # rank -> vocab id
    vrank = np.empty(V, dtype=np.int64)
    vrank[order_v] = np.arange(V, dtype=np.int64)
    svlist = varr[order_v]                      # lemma string by rank
    svtyp = cand["vtyp"][order_v]

    path.mkdir(parents=True, exist_ok=True)
    blob = bytearray()
    families_meta: dict[str, dict] = {}
    key_table: dict[str, np.ndarray] = {}

    def add_family(fname, keys, starts, rows, cols):
        width = POSTING_WIDTH[fname]
        nrows = len(cols[0]) if cols else 0
        boundary = starts[starts < nrows] if nrows else starts[:0]
        codes, offsets, sizes = [], [], []
        for c in range(width):
            col = (
                cols[c].astype(np.int64) if nrows
                else np.empty(0, dtype=np.int64)
            )
            if c == 0 and nrows:
                dv = np.diff(col, prepend=np.int64(0))
                dv[boundary] = col[boundary]  # absolute at each key start
                col = dv
            code, raw = _pack(_zigzag(col))
            codes.append(code)
            offsets.append(len(blob))
            sizes.append(len(raw))
            blob.extend(raw)
        families_meta[fname] = {
            "n_rows": int(rows.sum()) if len(rows) else 0,
            "codes": codes,
            "offsets": offsets,
            "sizes": sizes,
        }
        key_table[f"{fname}_keys"] = _shrunk_keys(keys)
        key_table[f"{fname}_start"] = starts.astype(np.int64)
        key_table[f"{fname}_rows"] = rows.astype(np.int64)

    def ranked_family(fname, kcols, rcols):
        """Sort candidate rows by (packed key RANKS, row columns): packed
        rank order == sorted-tuple key order, so groups come out in the
        generic writer's on-disk order."""
        kranks = [vrank[k] for k in kcols]
        packed = kranks[0].astype(np.int64, copy=True)
        for k in kranks[1:]:
            packed *= V
            packed += k
        perm = _sort_perm(packed, rcols)
        packed_s = packed[perm]
        m = len(packed_s)
        b = np.concatenate(
            ([0], np.flatnonzero(packed_s[1:] != packed_s[:-1]) + 1, [m])
        )
        rows_f = np.diff(b)
        head = packed_s[b[:-1]]
        comps = []
        for _ in range(len(kcols)):
            comps.append(head % V)
            head = head // V
        comps.reverse()
        strs = svlist[comps[0]]
        for cr in comps[1:]:
            strs = np.char.add(np.char.add(strs, _KEY_SEP), svlist[cr])
        add_family(fname, strs, _cumsum0(rows_f)[:-1], rows_f,
                   [r[perm] for r in rcols])

    empty_i64 = np.zeros(0, dtype=np.int64)

    # ---- ordinary (+ NSW riding the same permutation) --------------------
    lid, doc, pos = cand["lid"], cand["doc"], cand["pos"]
    rank = vrank[lid]
    perm = _sort_perm(rank, (doc, pos))
    rank_s = rank[perm]
    doc_s = doc[perm]
    pos_s = pos[perm]
    counts_s = cand["nsw_counts"][perm]
    src = _ragged_take(cand["pay_starts"][perm], counts_s)
    stop_s = cand["nsw_stop_flat"][src]
    dist_s = cand["nsw_dist_flat"][src]
    bnd = np.concatenate(
        ([0], np.flatnonzero(rank_s[1:] != rank_s[:-1]) + 1, [n])
    )
    gs = np.diff(bnd)             # rows per present key, in rank order
    heads = rank_s[bnd[:-1]]      # present ranks (ascending)
    group_stop = svtyp[heads] == _STOP
    add_family("ordinary", svlist[heads], _cumsum0(gs)[:-1], gs,
               [doc_s, pos_s])

    # ---- stop_single: a stop lemma's rows ARE its ordinary rows ----------
    if build_degenerate and bool(group_stop.any()):
        row_mask = np.repeat(group_stop, gs)
        rows_ss = gs[group_stop]
        add_family("stop_single", svlist[heads[group_stop]],
                   _cumsum0(rows_ss)[:-1], rows_ss,
                   [doc_s[row_mask], pos_s[row_mask]])
    else:
        add_family("stop_single", [], empty_i64, empty_i64,
                   [empty_i64, empty_i64])

    # ---- pair / stop_pair / triple ---------------------------------------
    for fname in ("pair", "stop_pair", "triple"):
        c = cand[fname]
        if c is not None and len(c[1][0]):
            ranked_family(fname, c[0], c[1])
        else:
            width = POSTING_WIDTH[fname]
            add_family(fname, [], empty_i64, empty_i64,
                       [empty_i64] * width)

    # ---- NSW table: non-stop ordinary groups, same row order -------------
    nonstop = ~group_stop
    row_nonstop = np.repeat(nonstop, gs)
    counts_col = counts_s[row_nonstop]
    pay_mask = np.repeat(row_nonstop, counts_s)
    nsw_blob = bytearray()
    nsw_meta = {"codes": [], "offsets": [], "sizes": [],
                "n_counts": len(counts_col),
                "n_payload": int(counts_col.sum())}
    for col in (counts_col, stop_s[pay_mask], dist_s[pay_mask]):
        code, raw = _pack(_zigzag(col.astype(np.int64)))
        nsw_meta["codes"].append(code)
        nsw_meta["offsets"].append(len(nsw_blob))
        nsw_meta["sizes"].append(len(raw))
        nsw_blob.extend(raw)
    n_posts = gs[nonstop]
    totals = np.add.reduceat(counts_s, bnd[:-1])[nonstop]
    key_table["nsw_lemmas"] = _shrunk_keys(svlist[heads[nonstop]])
    key_table["nsw_post_start"] = _cumsum0(n_posts)[:-1]
    key_table["nsw_n_post"] = n_posts.astype(np.int64)
    key_table["nsw_pay_start"] = _cumsum0(totals)[:-1]
    key_table["nsw_total"] = totals.astype(np.int64)

    # ---- files: same layout/manifest as write_segment_store, no fsync ----
    import io
    (path / "postings.bin").write_bytes(bytes(blob))
    (path / "nsw.bin").write_bytes(bytes(nsw_blob))
    keys_buf = io.BytesIO()
    _savez_deterministic(keys_buf, key_table)
    keys_bytes = keys_buf.getvalue()
    (path / "keys.npz").write_bytes(keys_bytes)
    manifest = {
        "format_version": FORMAT_VERSION,
        "kind": "segment",
        "n_docs": len(docs),
        "doc_ids": [int(d) for d in sorted(doc_ids)],
        "superseded": [],
        "max_distance": D,
        "fl_crc32": int(fl_crc),
        "families": families_meta,
        "nsw": nsw_meta,
        "postings": {"bytes": len(blob), "crc32": zlib.crc32(bytes(blob))},
        "nsw_blob": {"bytes": len(nsw_blob),
                     "crc32": zlib.crc32(bytes(nsw_blob))},
        "keys_file": {"bytes": len(keys_bytes),
                      "crc32": zlib.crc32(keys_bytes)},
    }
    with open(path / "manifest.json", "w") as f:
        json.dump(manifest, f)


# ---------------------------------------------------------------------------
# merge-side spill access: raw key tables + packed columns (no per-key
# laziness — the merge reads contiguous multi-key ranges)
# ---------------------------------------------------------------------------


class _SpillReader:
    """Verified low-level view of one spill store: manifest, CRC-checked
    mmap'd blobs, and the raw key-extent tables."""

    def __init__(self, path: Path, expect_fl_crc: int):
        self.path = path
        m = _load_manifest(path / "manifest.json", expect_kind="segment")
        if m["fl_crc32"] != expect_fl_crc:
            raise StoreError(
                f"{path}: spill keyed under FL signature {m['fl_crc32']}, "
                f"merge expects {expect_fl_crc}"
            )
        self.manifest = m
        self.blob = _open_blob(path / "postings.bin", m["postings"],
                               use_mmap=True, verify=True)
        self.nsw_blob = _open_blob(path / "nsw.bin", m["nsw_blob"],
                                   use_mmap=True, verify=True)
        keys_bytes = (path / "keys.npz").read_bytes()
        if len(keys_bytes) != m["keys_file"]["bytes"] or \
                zlib.crc32(keys_bytes) != m["keys_file"]["crc32"]:
            raise StoreError(f"corrupt key table in {path}")
        import io
        try:
            with np.load(io.BytesIO(keys_bytes)) as kt:
                self.table = {name: kt[name] for name in kt.files}
        except Exception as e:
            raise StoreError(f"corrupt key table in {path}: {e}") from e
        self.doc_ids = [int(d) for d in m["doc_ids"]]
        self.n_docs = int(m["n_docs"])

    def family(self, fname: str):
        fm = self.manifest["families"][fname]
        return (
            self.table[f"{fname}_keys"],
            self.table[f"{fname}_start"].astype(np.int64),
            self.table[f"{fname}_rows"].astype(np.int64),
            fm["codes"],
            fm["offsets"],
        )

    def nsw(self):
        nm = self.manifest["nsw"]
        return (
            self.table["nsw_lemmas"],
            self.table["nsw_post_start"].astype(np.int64),
            self.table["nsw_n_post"].astype(np.int64),
            self.table["nsw_pay_start"].astype(np.int64),
            self.table["nsw_total"].astype(np.int64),
            nm["codes"],
            nm["offsets"],
        )


def _decode_col_range(blob, code: int, offset: int, start: int, n: int) -> np.ndarray:
    dt = _PACK_DTYPES[code]
    try:
        raw = np.frombuffer(
            blob, dtype=dt, count=n, offset=offset + start * np.dtype(dt).itemsize
        )
    except ValueError as e:
        raise StoreError(f"truncated spill column: {e}") from e
    return _unzigzag(raw.astype(np.int64))


def _decode_family_range(
    blob, codes, offsets, start: int, n: int, width: int,
    rel_boundaries: np.ndarray,
) -> list[np.ndarray]:
    """Decode rows ``[start, start+n)`` of a family — a MULTI-key contiguous
    range (``rel_boundaries`` are the range-relative key starts, first is 0).
    Column 0's delta chain resets to an absolute value at each boundary
    (§12.1), so the cumulative sum is re-based per key segment."""
    cols: list[np.ndarray] = []
    for c in range(width):
        v = _decode_col_range(blob, codes[c], offsets[c], start, n)
        if c == 0 and n:
            cs = np.cumsum(v)
            seg_lens = np.diff(np.append(rel_boundaries, n))
            adjust = cs[rel_boundaries] - v[rel_boundaries]
            v = cs - np.repeat(adjust, seg_lens)
        cols.append(v)
    return cols


# ---------------------------------------------------------------------------
# §12.1 re-encode spools: bounded temp-file columns -> narrowed final blobs
# ---------------------------------------------------------------------------


class _ColumnSpool:
    """One output column spooled to disk as uint32 zigzag values; narrowed
    to the final §12.1 pack dtype in a streaming pass once its global max
    is known.  This is what keeps merge memory bounded by the batch size
    instead of the family size."""

    def __init__(self, path: Path):
        self.path = path
        self._f = open(path, "wb")
        self.max = 0
        self.n = 0

    def append(self, values: np.ndarray) -> None:
        z = _zigzag(values.astype(np.int64))
        if len(z):
            m = int(z.max())
            if m > _PACK_MAX[-1]:
                raise StoreError(f"packed value {m} exceeds uint32 range")
            self.max = max(self.max, m)
            self.n += len(z)
            self._f.write(z.astype(np.uint32).tobytes())

    def code(self) -> int:
        for code, top in enumerate(_PACK_MAX):
            if self.max <= top:
                return code
        raise StoreError("unreachable: max checked at append")

    def spool_into(self, out, crc: int, chunk_rows: int = 1 << 20) -> tuple[int, int]:
        """Stream-narrow into the final blob file; returns (bytes, crc)."""
        self._f.close()
        dt = _PACK_DTYPES[self.code()]
        written = 0
        with open(self.path, "rb") as f:
            while True:
                buf = f.read(4 * chunk_rows)
                if not buf:
                    break
                vals = np.frombuffer(buf, dtype=np.uint32).astype(dt)
                raw = vals.tobytes()
                out.write(raw)
                crc = zlib.crc32(raw, crc)
                written += len(raw)
        os.unlink(self.path)
        return written, crc


def _sort_perm(rank: np.ndarray, cols: Sequence[np.ndarray]) -> np.ndarray:
    """Stable permutation sorting rows by ``(rank, cols[0], ..., cols[-1])``
    — the ``merge_posting_arrays`` order with the batch-key rank major.

    Fast path packs all sort keys into ONE int64 word (rank in the high
    bits, columns below) and argsorts once; a single 300k-row argsort is
    ~6x cheaper than the equivalent multi-pass ``np.lexsort``.  Falls back
    to ``np.lexsort`` whenever the packed width would overflow 63 bits or
    a column is negative (packing would break the order)."""
    n = len(rank)
    if n == 0:
        return np.empty(0, dtype=np.intp)
    keys = [rank, *cols]
    spans = []
    bits = 0
    for k in keys:
        lo, hi = int(k.min()), int(k.max())
        spans.append(lo)
        bits += max((hi - lo).bit_length(), 1)
    if bits <= 63:
        packed = (keys[0] - np.int64(spans[0])).astype(np.int64)
        for k, lo in zip(keys[1:], spans[1:]):
            s = k - np.int64(lo)
            packed = (packed << np.int64(max(int(s.max()).bit_length(), 1))) | s
        return np.argsort(packed, kind="stable")
    return np.lexsort(tuple(reversed(list(cols))) + (rank,))


def _cumsum0(a: np.ndarray) -> np.ndarray:
    out = np.zeros(len(a) + 1, dtype=np.int64)
    np.cumsum(a, out=out[1:])
    return out


def _ragged_take(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    total = int(counts.sum())
    return (
        np.repeat(starts, counts)
        + np.arange(total, dtype=np.int64)
        - np.repeat(_cumsum0(counts)[:-1], counts)
    )


def _merge_spills(
    readers: Sequence[_SpillReader],
    fl: FLList,
    max_distance: int,
    out_dir: Path,
    merge_batch_rows: int,
) -> None:
    """Stream every §3 family from the spills into one §12.2 segment store
    at ``out_dir`` — identical bytes to ``write_segment_store`` over the
    union index (see module docstring for the merge invariants)."""
    out_dir.mkdir(parents=True, exist_ok=True)
    tmp_dir = out_dir / "_merge_tmp"
    tmp_dir.mkdir(exist_ok=True)

    stop_flag_cache: dict[str, bool] = {}

    def _is_stop(lemma: str) -> bool:
        hit = stop_flag_cache.get(lemma)
        if hit is None:
            hit = stop_flag_cache[lemma] = bool(fl.is_stop(lemma))
        return hit

    families_meta: dict[str, dict] = {}
    key_table: dict[str, np.ndarray] = {}
    nsw_spools = [
        _ColumnSpool(tmp_dir / f"nsw_col{c}.u32") for c in range(3)
    ]
    nsw_lemmas: list[str] = []
    nsw_n_post: list[np.ndarray] = []
    nsw_totals: list[np.ndarray] = []

    postings_path = out_dir / "postings.bin"
    blob_pos = 0
    blob_crc = 0
    out = open(postings_path, "wb")
    try:
        for fname in _FAMILIES:
            width = POSTING_WIDTH[fname]
            per_spill = [r.family(fname) for r in readers]
            key_arrays = [p[0] for p in per_spill if len(p[0])]
            union = (
                np.unique(np.concatenate(key_arrays))
                if key_arrays else np.empty(0, dtype=str)
            )
            pos_in_union = [
                np.searchsorted(union, p[0]) if len(p[0])
                else np.empty(0, dtype=np.int64)
                for p in per_spill
            ]
            totals = np.zeros(len(union), dtype=np.int64)
            for s, p in enumerate(per_spill):
                if len(p[0]):
                    np.add.at(totals, pos_in_union[s], p[2])

            spools = [
                _ColumnSpool(tmp_dir / f"{fname}_col{c}.u32")
                for c in range(width)
            ]
            is_ord = fname == "ordinary"
            nsw_tables = [r.nsw() for r in readers] if is_ord else None

            # row-budgeted key batches: each spill contributes ONE
            # contiguous decoded slice per batch
            cum = _cumsum0(totals)
            lo = 0
            while lo < len(union):
                hi = int(np.searchsorted(cum, cum[lo] + merge_batch_rows,
                                         side="left"))
                hi = min(max(hi, lo + 1), len(union))
                n_keys = hi - lo

                part_cols: list[list[np.ndarray]] = [[] for _ in range(width)]
                rank_parts: list[np.ndarray] = []
                counts_parts: list[np.ndarray] = []
                pstart_parts: list[np.ndarray] = []
                stop_parts: list[np.ndarray] = []
                dist_parts: list[np.ndarray] = []
                pay_base = 0
                for s, (keys_s, starts_s, rows_s, codes, offsets) in enumerate(per_spill):
                    pu = pos_in_union[s]
                    j0 = int(np.searchsorted(pu, lo, side="left"))
                    j1 = int(np.searchsorted(pu, hi, side="left"))
                    if j0 == j1:
                        continue
                    row0 = int(starts_s[j0])
                    nrows = int(starts_s[j1 - 1] + rows_s[j1 - 1] - row0)
                    rel_bnd = starts_s[j0:j1] - row0
                    cols = _decode_family_range(
                        readers[s].blob, codes, offsets, row0, nrows, width,
                        rel_bnd,
                    )
                    for c in range(width):
                        part_cols[c].append(cols[c])
                    rank_parts.append(
                        np.repeat(pu[j0:j1] - lo, rows_s[j0:j1])
                    )
                    if is_ord:
                        # per-row NSW count + payload-start vectors for this
                        # slice: spill NSW lemmas are the non-stop subset of
                        # its ordinary keys, scattered to their row ranges
                        (nl, nps, nnp, nys, ntot, ncodes, noffs) = nsw_tables[s]
                        counts_vec = np.zeros(nrows, dtype=np.int64)
                        pstart_vec = np.zeros(nrows, dtype=np.int64)
                        k0 = int(np.searchsorted(nl, keys_s[j0]))
                        k1 = int(np.searchsorted(nl, keys_s[j1 - 1], side="right"))
                        if k0 < k1:
                            post0 = int(nps[k0])
                            n_counts = int(nnp[k0:k1].sum())
                            counts_flat = _decode_col_range(
                                readers[s].nsw_blob, ncodes[0], noffs[0],
                                post0, n_counts,
                            )
                            pay0 = int(nys[k0])
                            n_pay = int(ntot[k0:k1].sum())
                            stop_flat = _decode_col_range(
                                readers[s].nsw_blob, ncodes[1], noffs[1],
                                pay0, n_pay,
                            )
                            dist_flat = _decode_col_range(
                                readers[s].nsw_blob, ncodes[2], noffs[2],
                                pay0, n_pay,
                            )
                            # destination rows of each NSW lemma inside the
                            # decoded ordinary slice
                            kpos = np.searchsorted(keys_s[j0:j1], nl[k0:k1])
                            dest = _ragged_take(
                                rel_bnd[kpos], nnp[k0:k1]
                            )
                            counts_vec[dest] = counts_flat
                            pstart_vec[dest] = (
                                _cumsum0(counts_flat)[:-1] + pay_base
                            )
                            stop_parts.append(stop_flat)
                            dist_parts.append(dist_flat)
                            pay_base += n_pay
                        counts_parts.append(counts_vec)
                        pstart_parts.append(pstart_vec)

                cat = [np.concatenate(part_cols[c]) for c in range(width)]
                rank = np.concatenate(rank_parts)
                # reference per-key merge order: stable §4 row columns over
                # parts concatenated in chunk (= doc) order; for ordinary the
                # NSW payload rides the same permutation
                perm = _sort_perm(rank, cat)
                rank_m = rank[perm]
                key_start_rows = np.concatenate(
                    ([0], np.flatnonzero(rank_m[1:] != rank_m[:-1]) + 1)
                )
                if len(key_start_rows) != n_keys:
                    raise StoreError(
                        f"merge dropped keys in {fname}: "
                        f"{len(key_start_rows)} groups for {n_keys} keys"
                    )
                for c in range(width):
                    col = cat[c][perm]
                    if c == 0:
                        dv = np.diff(col, prepend=np.int64(0))
                        dv[key_start_rows] = col[key_start_rows]
                        spools[c].append(dv)
                    else:
                        spools[c].append(col)

                if is_ord:
                    counts_m = np.concatenate(counts_parts)[perm]
                    pstart_m = np.concatenate(pstart_parts)[perm]
                    stop_cat = (
                        np.concatenate(stop_parts) if stop_parts
                        else np.empty(0, dtype=np.int64)
                    )
                    dist_cat = (
                        np.concatenate(dist_parts) if dist_parts
                        else np.empty(0, dtype=np.int64)
                    )
                    gather = _ragged_take(pstart_m, counts_m)
                    names = union[lo:hi].tolist()
                    nonstop = np.asarray(
                        [not _is_stop(nm) for nm in names], dtype=bool
                    )
                    row_mask = np.repeat(
                        nonstop,
                        np.diff(np.append(key_start_rows, len(rank_m))),
                    )
                    nsw_spools[0].append(counts_m[row_mask])
                    nsw_spools[1].append(stop_cat[gather])
                    nsw_spools[2].append(dist_cat[gather])
                    per_key_rows = np.diff(
                        np.append(key_start_rows, len(rank_m))
                    )
                    per_key_pay = np.add.reduceat(
                        counts_m, key_start_rows
                    ) if len(rank_m) else np.zeros(0, dtype=np.int64)
                    nsw_lemmas.extend(
                        nm for nm, ns in zip(names, nonstop) if ns
                    )
                    nsw_n_post.append(per_key_rows[nonstop])
                    nsw_totals.append(per_key_pay[nonstop])
                lo = hi

            # narrow this family's spools into the final blob
            codes_out, offsets_out, sizes_out = [], [], []
            n_rows_total = int(totals.sum())
            for sp in spools:
                codes_out.append(sp.code())
                offsets_out.append(blob_pos)
                written, blob_crc = sp.spool_into(out, blob_crc)
                sizes_out.append(written)
                blob_pos += written
            families_meta[fname] = {
                "n_rows": n_rows_total,
                "codes": codes_out,
                "offsets": offsets_out,
                "sizes": sizes_out,
            }
            key_table[f"{fname}_keys"] = union.astype(str)
            key_table[f"{fname}_start"] = _cumsum0(totals)[:-1]
            key_table[f"{fname}_rows"] = totals
        out.flush()
        os.fsync(out.fileno())
    finally:
        out.close()

    nsw_path = out_dir / "nsw.bin"
    nsw_meta = {"codes": [], "offsets": [], "sizes": [],
                "n_counts": nsw_spools[0].n, "n_payload": nsw_spools[1].n}
    nsw_pos = 0
    nsw_crc = 0
    with open(nsw_path, "wb") as nout:
        for sp in nsw_spools:
            nsw_meta["codes"].append(sp.code())
            nsw_meta["offsets"].append(nsw_pos)
            written, nsw_crc = sp.spool_into(nout, nsw_crc)
            nsw_meta["sizes"].append(written)
            nsw_pos += written
        nout.flush()
        os.fsync(nout.fileno())

    n_post_all = (
        np.concatenate(nsw_n_post) if nsw_n_post else np.zeros(0, np.int64)
    )
    totals_all = (
        np.concatenate(nsw_totals) if nsw_totals else np.zeros(0, np.int64)
    )
    key_table["nsw_lemmas"] = np.asarray(nsw_lemmas, dtype=str)
    key_table["nsw_post_start"] = _cumsum0(n_post_all)[:-1]
    key_table["nsw_n_post"] = n_post_all
    key_table["nsw_pay_start"] = _cumsum0(totals_all)[:-1]
    key_table["nsw_total"] = totals_all

    import io
    keys_buf = io.BytesIO()
    _savez_deterministic(keys_buf, key_table)
    keys_bytes = keys_buf.getvalue()
    _write_durable(out_dir / "keys.npz", keys_bytes)

    all_doc_ids = sorted(d for r in readers for d in r.doc_ids)
    manifest = {
        "format_version": FORMAT_VERSION,
        "kind": "segment",
        "n_docs": sum(r.n_docs for r in readers),
        "doc_ids": [int(d) for d in all_doc_ids],
        "superseded": [],
        "max_distance": int(max_distance),
        "fl_crc32": int(fl_signature(fl)),
        "families": families_meta,
        "nsw": nsw_meta,
        "postings": {"bytes": blob_pos, "crc32": blob_crc},
        "nsw_blob": {"bytes": nsw_pos, "crc32": nsw_crc},
        "keys_file": {"bytes": len(keys_bytes), "crc32": zlib.crc32(keys_bytes)},
    }
    fsync_json(out_dir / "manifest.json", manifest)
    shutil.rmtree(tmp_dir)


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------


def _run_pool(workers: int, fn: Callable, tasks: list, inline: bool) -> None:
    """Run phase tasks inline or over a spawn pool.  Inline is forced when
    a fault injector is attached (schedules are counted in-process) — the
    outputs are identical either way (§17.4).  Spawn, not fork: the caller
    usually has jax initialized (serve.py), and forking a multithreaded
    parent can deadlock the child; workers only need the numpy spill path,
    and the ~0.5s interpreter start amortizes over chunk batches."""
    if inline or workers <= 1 or len(tasks) <= 1:
        for t in tasks:
            fn(t)
        return
    ctx = multiprocessing.get_context("spawn")
    with ctx.Pool(processes=min(workers, len(tasks))) as pool:
        # any worker exception propagates and aborts the run (fail clean)
        pool.map(fn, tasks, chunksize=1)


def bulk_build(
    texts: Sequence[str] | None = None,
    *,
    out_dir: str | Path,
    sw_count: int,
    fu_count: int,
    max_distance: int = 5,
    build_pair: bool = True,
    build_degenerate: bool = True,
    documents: Sequence[Document] | None = None,
    doc_ids: Sequence[int] | None = None,
    fl: FLList | None = None,
    docs_per_spill: int = 64,
    workers: int = 1,
    merge_batch_rows: int = DEFAULT_MERGE_BATCH_ROWS,
    resume: bool = False,
    keep_spills: bool = False,
    injector=None,
    keep: int = 2,
) -> BulkBuildStats:
    """SPIMI bulk build (DESIGN.md §17): lemmatize + spill + merge
    ``texts`` (or pre-lemmatized ``documents``) into an atomically
    published ``snap_<N>`` under ``out_dir`` — every §3 family, built
    out-of-core (see module docstring for phases and contracts).

    ``resume=True`` revalidates an interrupted run's chunks and spills by
    CRC and redoes only the invalid ones; the finished snapshot is
    byte-identical to an uninterrupted run.  ``fl`` pins an external
    FL-list (shard-global builds); otherwise the FL reduces from the chunk
    frequency counters.  ``keep_spills`` leaves the spill directory in
    place (CI uploads it as an artifact)."""
    t_start = time.perf_counter()
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    run_dir = out_dir / _RUN_DIR

    if documents is not None:
        if texts is not None:
            raise ValueError("pass texts or documents, not both")
        docs_all = list(documents)
        ids = [int(d.doc_id) for d in docs_all]
        corpus_crc = _corpus_crc(ids, [d.text for d in docs_all])
    else:
        if texts is None:
            raise ValueError("pass texts or documents")
        ids = (
            [int(i) for i in doc_ids] if doc_ids is not None
            else list(range(len(texts)))
        )
        if len(ids) != len(texts):
            raise ValueError("doc_ids and texts length mismatch")
        docs_all = None
        corpus_crc = _corpus_crc(ids, texts)

    n_docs = len(ids)
    dps = max(1, int(docs_per_spill))
    n_chunks = (n_docs + dps - 1) // dps
    chunk_bounds = [
        (c * dps, min((c + 1) * dps, n_docs)) for c in range(n_chunks)
    ]
    run_meta = {
        "format_version": FORMAT_VERSION,
        "kind": "ingest_run",
        "sw_count": int(sw_count),
        "fu_count": int(fu_count),
        "max_distance": int(max_distance),
        "build_pair": bool(build_pair),
        "build_degenerate": bool(build_degenerate),
        "docs_per_spill": dps,
        "n_docs": n_docs,
        "corpus_crc32": corpus_crc,
        "chunks": chunk_bounds,
        "pinned_fl": fl is not None,
    }

    if run_dir.exists():
        existing = None
        try:
            with open(run_dir / _RUN_META) as f:
                existing = json.load(f)
        except (OSError, json.JSONDecodeError):
            existing = None
        compatible = existing is not None and all(
            existing.get(k) == run_meta[k]
            for k in run_meta
            if k != "chunks"
        ) and [tuple(b) for b in existing.get("chunks", [])] == chunk_bounds
        if not (resume and compatible):
            # a fresh build (or an incompatible leftover) starts clean —
            # partial runs are only ever continued under resume=True
            shutil.rmtree(run_dir)
    run_dir.mkdir(exist_ok=True)
    if not (run_dir / _RUN_META).exists():
        fsync_json(run_dir / _RUN_META, run_meta)

    inline = injector is not None

    # ---- phase L: lemmatize + persist chunks ----------------------------
    t0 = time.perf_counter()
    chunk_metas: list[dict | None] = [
        _chunk_meta(_chunk_dir(run_dir, c)) for c in range(n_chunks)
    ]
    chunks_reused = sum(m is not None for m in chunk_metas)
    todo_l = []
    for c, meta in enumerate(chunk_metas):
        if meta is not None:
            continue
        lo, hi = chunk_bounds[c]
        if docs_all is not None:
            if injector is not None:
                injector.fire("ingest.lemmatize", shard=c,
                              path=_chunk_dir(run_dir, c))
            _write_chunk(_chunk_dir(run_dir, c), docs_all[lo:hi])
        else:
            todo_l.append((str(run_dir), c,
                           list(zip(ids[lo:hi], texts[lo:hi]))))
    if todo_l:
        if inline:
            # fire each chunk's injection point right before its work, so a
            # crash at chunk c leaves chunks < c durable (resume picks them up)
            for task in todo_l:
                injector.fire("ingest.lemmatize", shard=task[1],
                              path=_chunk_dir(run_dir, task[1]))
                _lemmatize_chunk(task)
        else:
            _run_pool(workers, _lemmatize_chunk, todo_l, inline)
    for c in range(n_chunks):
        if chunk_metas[c] is None:
            chunk_metas[c] = _chunk_meta(_chunk_dir(run_dir, c))
            if chunk_metas[c] is None:
                raise StoreError(f"chunk {c} failed to persist")
    t_lem = time.perf_counter() - t0

    # ---- FL reduce ------------------------------------------------------
    if fl is None:
        freq: Counter = Counter()
        for meta in chunk_metas:
            freq.update(meta["freq"])
        fl = FLList.from_frequencies(freq, sw_count, fu_count)
    fl_crc = fl_signature(fl)

    # ---- phase S: spill segments ----------------------------------------
    t0 = time.perf_counter()

    def _spill_valid(c: int) -> bool:
        try:
            _SpillReader(_chunk_dir(run_dir, c) / _SPILL, fl_crc)
            return True
        except StoreError:
            return False

    spill_ok = [_spill_valid(c) for c in range(n_chunks)]
    spills_reused = sum(spill_ok)
    todo_s = [
        (str(run_dir), c, fl, max_distance, build_pair, build_degenerate,
         fl_crc)
        for c, ok in enumerate(spill_ok)
        if not ok
    ]
    if todo_s:
        if inline or workers <= 1 or len(todo_s) <= 1:
            for task in todo_s:
                c = task[1]
                if injector is not None:
                    injector.fire("ingest.spill", shard=c,
                                  path=_chunk_dir(run_dir, c))
                # prelemmatized single-process path: spill straight from
                # the in-memory docs (the chunk file carries the same
                # values — just written from them, or CRC-validated under
                # the run's pinned corpus_crc)
                chunk_docs = None
                if docs_all is not None:
                    lo, hi = chunk_bounds[c]
                    chunk_docs = docs_all[lo:hi]
                _spill_chunk(task, docs=chunk_docs)
        else:
            _run_pool(workers, _spill_chunk, todo_s, inline)
    t_spill = time.perf_counter() - t0

    # ---- merge + snapshot publish ---------------------------------------
    t0 = time.perf_counter()
    readers = []
    for c in range(n_chunks):
        cdir = _chunk_dir(run_dir, c)
        if injector is not None:
            # bitflip events physically corrupt THIS chunk's spill before
            # the CRC-verified open below — real §12.2 rejection under test
            injector.fire("ingest.merge", shard=c, path=cdir)
        readers.append(_SpillReader(cdir / _SPILL, fl_crc))

    latest = latest_snapshot(out_dir)
    snap_n = 0 if latest is None else latest + 1
    tmp = out_dir / f"{SNAPSHOT_PREFIX}_{snap_n}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    _merge_spills(readers, fl, max_distance, tmp / "seg_000",
                  merge_batch_rows)

    with open(tmp / "documents.jsonl", "wb") as f:
        for c in range(n_chunks):
            f.write((_chunk_dir(run_dir, c) / _DOCS).read_bytes())
        f.flush()
        os.fsync(f.fileno())

    manifest = {
        "format_version": FORMAT_VERSION,
        "kind": "snapshot",
        "sw_count": int(fl.sw_count),
        "fu_count": int(fl.fu_count),
        "max_distance": int(max_distance),
        "build_pair": bool(build_pair),
        "build_degenerate": bool(build_degenerate),
        "fl": {
            "lemmas": fl.lemmas,
            "frequency": fl.frequency,
            "sw_count": fl.sw_count,
            "fu_count": fl.fu_count,
        },
        "fl_crc32": fl_crc,
        "tombstones": [],
        "generation": 1,
        "mutations": 1,
        "epoch": 0,
        "next_id": (max(ids) + 1) if ids else 0,
        "segments": ["seg_000"],
        "n_documents": n_docs,
        "n_buffered": 0,
    }
    fsync_json(tmp / "manifest.json", manifest)
    final = out_dir / f"{SNAPSHOT_PREFIX}_{snap_n}"
    replace_dir(tmp, final)
    retain_latest(out_dir, SNAPSHOT_PREFIX, keep)
    t_merge = time.perf_counter() - t0

    spill_bytes = sum(
        p.stat().st_size
        for c in range(n_chunks)
        for p in (_chunk_dir(run_dir, c) / _SPILL).rglob("*")
        if p.is_file()
    )
    if not keep_spills:
        shutil.rmtree(run_dir)

    total = time.perf_counter() - t_start
    return BulkBuildStats(
        snapshot_path=str(final),
        n_docs=n_docs,
        n_chunks=n_chunks,
        workers=workers,
        docs_per_spill=dps,
        chunks_reused=chunks_reused,
        spills_reused=spills_reused,
        lemmatize_s=t_lem,
        spill_s=t_spill,
        merge_s=t_merge,
        total_s=total,
        docs_per_sec=(n_docs / total) if total > 0 else 0.0,
        spill_bytes=spill_bytes,
        timings={
            "lemmatize_s": t_lem,
            "spill_s": t_spill,
            "merge_s": t_merge,
            "total_s": total,
        },
    )
