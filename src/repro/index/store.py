"""Durable on-disk segment store: snapshot/restore for the §3 indexes
(DESIGN.md §12; byte-level format in §12.1–§12.2).

The companion construction paper (arXiv 2006.07954) treats index
materialization and storage as first-class, and the response-time-guarantee
work (arXiv 2009.03679) assumes a server that *restarts against an existing
index* instead of re-lemmatizing the corpus.  This module is that layer:

* **Columnar codec** (§12.1) — each §3 posting family stores the rows of
  ALL its keys concatenated (keys in sorted order), column-wise: the doc
  column is delta-encoded with the chain *reset to an absolute value at
  every key boundary* (so any key's slice decodes independently), every
  column is zigzag-mapped and bit-packed to the narrowest of
  uint8/uint16/uint32 that fits the column.  NSW records store ragged-slice
  *lengths* (not int64 offsets) plus packed payload columns.  Encoding is
  one vectorized pass per column; per-key decode is offset arithmetic into
  the packed column.  The codec is lossless: decoded slices are
  byte-identical (dtype, shape, values) to the in-memory arrays — the
  differential harness gates this.

* **Segment stores** (§12.2) — one directory per immutable
  :class:`~repro.index.incremental.Segment`: two blob files
  (``postings.bin``, ``nsw.bin``), a binary key table (``keys.npz``:
  per-key row extents), and a fsync'd ``manifest.json`` with the format
  version, per-column pack codes and offsets, doc ids, superseded set,
  CRC32s and the FL signature of the generation the segment was keyed
  under.

* **Snapshots** (§12.2) — ``save_snapshot`` freezes a whole
  ``IncrementalIndexer`` (segments + surviving documents + tombstones + FL
  state + generation token) into an atomically published ``snap_<N>``
  directory, reusing the checkpoint layer's write/retention primitives
  (``repro.checkpoint``: tmp dir -> manifest fsync -> rename, keep-latest
  GC).  ``load_snapshot`` restores a fully functional indexer whose
  segments serve straight from ``mmap``-ed disk pages via
  :class:`StoredIndexSet` — postings decode on first touch and every engine
  works unchanged.

Exactness contract: a restored index is *indistinguishable* from the live
one it was snapshotted from — ``restore(snapshot(ix)).index.to_index_set()``
is ``index_sets_equal``-identical to ``ix.index.to_index_set()``, every
decoded posting slice is byte-identical to its in-memory original, and the
restored indexer keeps committing/deleting/compacting exactly
(``tests/test_store.py``, ``tests/test_differential.py``).  Generation
tokens resume across restarts under a bumped restore epoch, so a serving
cache can never confuse pre- and post-restart index states (§12.5).
"""

from __future__ import annotations

import json
import mmap
import os
import shutil
import zlib
from collections.abc import MutableMapping
from pathlib import Path
from typing import Sequence

import numpy as np

from ..checkpoint import fsync_json, latest_numbered, replace_dir, retain_latest
from ..core.lemma import FLList
from .builder import IndexSet, NSWRecords, POSTING_WIDTH
from .corpus import Document

__all__ = [
    "FORMAT_VERSION",
    "StoreError",
    "StoredIndexSet",
    "family_rows",
    "fl_signature",
    "latest_snapshot",
    "load_snapshot",
    "open_segment_store",
    "save_snapshot",
    "write_segment_store",
]

FORMAT_VERSION = 1

SNAPSHOT_PREFIX = "snap"
_MANIFEST = "manifest.json"
_POSTINGS_BLOB = "postings.bin"
_NSW_BLOB = "nsw.bin"
_KEYS_FILE = "keys.npz"
_DOCUMENTS = "documents.jsonl"
_KEY_SEP = "\x1f"  # joins tuple-key components in the key table

# §3 posting families and their §4 row widths — the builder's canonical
# table, so a family added there cannot be silently missing from snapshots
FAMILY_WIDTH = POSTING_WIDTH
_FAMILIES = tuple(FAMILY_WIDTH)


def _savez_deterministic(buf, arrays: dict) -> None:
    """``np.savez`` with pinned zip metadata: fixed DOS timestamp, fixed
    permissions, no compression.  Equal arrays -> equal bytes, which is the
    property the §17.4 determinism contract (bulk-ingest runs with different
    worker counts produce byte-identical snapshots) rests on — stock
    ``np.savez`` stamps each member with the wall clock."""
    import io
    import zipfile

    from numpy.lib import format as npformat

    with zipfile.ZipFile(buf, "w", zipfile.ZIP_STORED) as zf:
        for name, arr in arrays.items():
            member = io.BytesIO()
            npformat.write_array(member, np.asanyarray(arr), allow_pickle=False)
            info = zipfile.ZipInfo(name + ".npy", date_time=(1980, 1, 1, 0, 0, 0))
            info.external_attr = 0o600 << 16
            zf.writestr(info, member.getvalue())


def _write_durable(path: Path, data: bytes) -> None:
    """Write + flush + fsync one data file (§12.4): every payload file is
    durable BEFORE the manifest fsync that publishes it, so a
    manifest-complete snapshot never points at torn data pages."""
    with open(path, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())


class StoreError(RuntimeError):
    """A snapshot/segment store is unreadable (DESIGN.md §12.2): missing or
    malformed manifest, format-version mismatch, truncated blob, CRC or FL
    signature mismatch.  Restores fail loudly instead of serving a corrupt
    index — exactness is the §12 contract."""


# ---------------------------------------------------------------------------
# §12.1 columnar codec: boundary-reset delta + zigzag + byte-width packing
# ---------------------------------------------------------------------------

_PACK_DTYPES = (np.uint8, np.uint16, np.uint32)
_PACK_MAX = (0xFF, 0xFFFF, 0xFFFFFFFF)


def _zigzag(v: np.ndarray) -> np.ndarray:
    # int64 -> non-negative int64: 0,-1,1,-2,... -> 0,1,2,3,...
    return (v << 1) ^ (v >> 63)


def _unzigzag(u: np.ndarray) -> np.ndarray:
    return (u >> 1) ^ -(u & 1)


def _pack(values: np.ndarray) -> tuple[int, bytes]:
    """Narrowest-uint packing of non-negative int64 values."""
    m = int(values.max()) if len(values) else 0
    for code, top in enumerate(_PACK_MAX):
        if m <= top:
            return code, values.astype(_PACK_DTYPES[code]).tobytes()
    raise StoreError(f"packed value {m} exceeds uint32 range")


def _encode_family(
    arrays: Sequence[np.ndarray], starts: np.ndarray, width: int
) -> tuple[list[bytes], list[int], list[int]]:
    """Encode one family's concatenated rows column-wise (§12.1): returns
    (per-column packed bytes, per-column pack codes, per-column byte sizes).
    ``starts`` are the key-boundary row indices where the doc-delta chain
    resets to the absolute doc id."""
    concat = (
        np.concatenate(arrays).astype(np.int64)
        if arrays
        else np.empty((0, width), dtype=np.int64)
    )
    blobs: list[bytes] = []
    codes: list[int] = []
    sizes: list[int] = []
    n = len(concat)
    boundary = starts[starts < n] if n else starts[:0]
    for c in range(width):
        col = concat[:, c]
        if c == 0 and n:
            dv = np.diff(col, prepend=np.int64(0))
            dv[boundary] = col[boundary]  # absolute at each key's first row
            col = dv
        code, raw = _pack(_zigzag(col))
        blobs.append(raw)
        codes.append(code)
        sizes.append(len(raw))
    return blobs, codes, sizes


def _decode_rows(
    blob, codes: Sequence[int], offsets: Sequence[int], start: int, n: int, width: int
) -> np.ndarray:
    """Decode one key's ``(n, width)`` int32 row slice from globally packed
    columns (§12.1) — byte-identical to the array that was encoded."""
    if n == 0:
        return np.empty((0, width), dtype=np.int32)
    cols = []
    for c in range(width):
        dt = _PACK_DTYPES[codes[c]]
        try:
            raw = np.frombuffer(
                blob, dtype=dt, count=n, offset=offsets[c] + start * np.dtype(dt).itemsize
            )
        except ValueError as e:
            raise StoreError(f"truncated posting column: {e}") from e
        vals = _unzigzag(raw.astype(np.int64))
        if c == 0:
            vals = np.cumsum(vals)  # slice starts with its absolute doc id
        cols.append(vals.astype(np.int32))
    return np.stack(cols, axis=1)


def _decode_scalar_col(blob, code: int, offset: int, start: int, n: int) -> np.ndarray:
    dt = _PACK_DTYPES[code]
    try:
        raw = np.frombuffer(
            blob, dtype=dt, count=n, offset=offset + start * np.dtype(dt).itemsize
        )
    except ValueError as e:
        raise StoreError(f"truncated NSW column: {e}") from e
    return _unzigzag(raw.astype(np.int64))


def fl_signature(fl: FLList | None) -> int:
    """CRC32 signature of an FL generation (DESIGN.md §12.2): the lemma
    *order* plus the stop/FU thresholds — exactly the FL state §3 row
    generation depends on (§10.2).  Segment manifests embed the signature
    they were keyed under; a snapshot whose segments disagree with its FL
    state is rejected at restore instead of serving mis-keyed postings."""
    if fl is None:
        return 0
    payload = json.dumps([fl.lemmas, fl.sw_count, fl.fu_count]).encode()
    return zlib.crc32(payload)


# ---------------------------------------------------------------------------
# §12.3 lazy mmap-backed views: decode on first touch
# ---------------------------------------------------------------------------


class _LazyPostings(MutableMapping):
    """One posting family served straight from its packed columns: a key's
    array is decoded on first access and cached (DESIGN.md §12.3).  Even the
    key table itself materializes lazily (first family access), so restore
    does no per-key work at all.  Mutable so in-place overrides
    (e.g. the §10.2 NSW remap pattern) stay possible."""

    __slots__ = ("_blob", "_codes", "_offsets", "_raw", "_fname", "_entries",
                 "_width", "_cache")  # key table builds on first family access

    def __init__(self, blob, codes, offsets, raw_table, fname: str, width: int):
        self._blob = blob
        self._codes = codes
        self._offsets = offsets
        self._raw = raw_table  # (keys, starts, rows) arrays, or None
        self._fname = fname
        self._entries: dict | None = None  # key -> (row_start, n_rows)
        self._width = width
        self._cache: dict = {}

    def _table(self) -> dict:
        if self._entries is None:
            keys, starts, rows = self._raw
            self._entries = {
                _key_from_table(self._fname, k): (s, r)
                for k, s, r in zip(keys.tolist(), starts.tolist(), rows.tolist())
            }
            self._raw = None
        return self._entries

    def __getitem__(self, key):
        try:
            return self._cache[key]
        except KeyError:
            pass
        start, n = self._table()[key]
        arr = _decode_rows(self._blob, self._codes, self._offsets, start, n, self._width)
        self._cache[key] = arr
        return arr

    def __setitem__(self, key, value):
        self._cache[key] = value
        if key not in self._table():
            self._table()[key] = (0, 0)  # placeholder: cache always wins

    def __delitem__(self, key):
        found = key in self._table() or key in self._cache
        self._table().pop(key, None)
        self._cache.pop(key, None)
        if not found:
            raise KeyError(key)

    def __iter__(self):
        return iter(self._table())

    def __len__(self):
        return len(self._table())

    def __contains__(self, key):
        return key in self._table()


class _LazyNSW(MutableMapping):
    """NSW records served from ``nsw.bin``, decoded on first touch
    (DESIGN.md §12.3); mutable for the §10.2 stop-id bulk remap."""

    __slots__ = ("_blob", "_codes", "_offsets", "_raw", "_entries", "_cache")

    def __init__(self, blob, codes, offsets, raw_table):
        self._blob = blob
        self._codes = codes  # (counts, stop_lemma, distance) pack codes
        self._offsets = offsets  # matching byte offsets into the blob
        self._raw = raw_table  # (lemmas, post_start, n_post, pay_start, total)
        self._entries: dict | None = None
        self._cache: dict = {}

    def _table(self) -> dict:
        if self._entries is None:
            lemmas, post_starts, n_posts, pay_starts, totals = self._raw
            self._entries = {
                l: (ps, np_, ys, t)
                for l, ps, np_, ys, t in zip(
                    lemmas.tolist(), post_starts.tolist(), n_posts.tolist(),
                    pay_starts.tolist(), totals.tolist(),
                )
            }
            self._raw = None
        return self._entries

    def __getitem__(self, lemma):
        try:
            return self._cache[lemma]
        except KeyError:
            pass
        post_start, n_post, pay_start, total = self._table()[lemma]
        counts = _decode_scalar_col(
            self._blob, self._codes[0], self._offsets[0], post_start, n_post
        )
        offsets = np.zeros(n_post + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        rec = NSWRecords(
            offsets=offsets,
            stop_lemma=_decode_scalar_col(
                self._blob, self._codes[1], self._offsets[1], pay_start, total
            ).astype(np.int32),
            distance=_decode_scalar_col(
                self._blob, self._codes[2], self._offsets[2], pay_start, total
            ).astype(np.int32),
        )
        self._cache[lemma] = rec
        return rec

    def __setitem__(self, lemma, rec):
        self._cache[lemma] = rec
        if lemma not in self._table():
            self._table()[lemma] = (0, 0, 0, 0)

    def __delitem__(self, lemma):
        found = lemma in self._table() or lemma in self._cache
        self._table().pop(lemma, None)
        self._cache.pop(lemma, None)
        if not found:
            raise KeyError(lemma)

    def __iter__(self):
        return iter(self._table())

    def __len__(self):
        return len(self._table())

    def __contains__(self, lemma):
        return lemma in self._table()


class StoredIndexSet(IndexSet):
    """A complete §3 ``IndexSet`` served from an on-disk segment store
    (DESIGN.md §12.3): posting dicts are lazy ``mmap``-backed mappings that
    decode on first touch, so a restored index pays NO decode cost at boot
    (with the default ``verify=True`` the boot does one sequential CRC read
    of the blobs — still no decode, no dict builds) and decodes only the
    keys queries actually hit.

    Exactness: every decoded slice is byte-identical to the in-memory array
    it was encoded from, so ``SegmentedIndexSet`` merges, all engines, FL
    drift re-keying and compaction work over stored segments unchanged —
    the differential harness pins restored == live fragment sets.
    """

    def __init__(
        self,
        fl: FLList,
        max_distance: int,
        n_docs: int,
        ordinary: _LazyPostings,
        nsw: _LazyNSW,
        pair: _LazyPostings,
        triple: _LazyPostings,
        stop_single: _LazyPostings,
        stop_pair: _LazyPostings,
        totals: dict | None = None,
    ):
        # manifest row totals: {family: n_rows, "nsw": (n_lemmas, n_counts,
        # n_payload)} — lets size_bytes() answer without touching the tables
        self._totals = totals or {}
        IndexSet.__init__(
            self,
            fl=fl,
            max_distance=max_distance,
            ordinary=ordinary,
            nsw=nsw,
            pair=pair,
            triple=triple,
            stop_single=stop_single,
            stop_pair=stop_pair,
            n_docs=n_docs,
        )

    def size_bytes(self) -> dict[str, int]:
        """In-memory footprint *as if decoded*, computed from the key-table
        row counts without touching a single blob page — identical numbers
        to ``IndexSet.size_bytes()`` on the materialized arrays (int32
        rows, int64 NSW offsets), so §10 compaction budgeting and the §12
        compression-ratio bench see the same denominators either way."""
        out = {}
        for fname, width in FAMILY_WIDTH.items():
            out[fname] = self._totals.get(fname, 0) * width * 4
        n_lemmas, n_counts, n_payload = self._totals.get("nsw", (0, 0, 0))
        nsw = (n_counts + n_lemmas) * 8 + n_payload * 4 + n_payload * 4
        return {
            "ordinary": out["ordinary"],
            "nsw": nsw,
            "pair": out["pair"],
            "triple": out["triple"],
            "stop_degenerate": out["stop_single"] + out["stop_pair"],
            "total": out["ordinary"] + nsw + out["pair"] + out["triple"]
            + out["stop_single"] + out["stop_pair"],
        }


# ---------------------------------------------------------------------------
# §12.2 segment stores
# ---------------------------------------------------------------------------


def family_rows(
    mapping, width: int
) -> tuple[list, list[np.ndarray], np.ndarray, np.ndarray]:
    """One family's concatenated-rows bookkeeping (DESIGN.md §12.1/§13.1):
    sorted keys, their int32 row arrays, per-key row counts and cumulative
    start offsets.  This is the SINGLE definition of the concatenated
    columnar key layout — the on-disk codec (``write_segment_store``) and
    the device-resident posting arena (``search/arena.py``) both build their
    extents from it, so a key's rows land in the same order on disk and on
    device."""
    keys = sorted(mapping.keys())
    arrays = [np.asarray(mapping[k], dtype=np.int32) for k in keys]
    rows = np.asarray([len(a) for a in arrays], dtype=np.int64)
    starts = np.zeros(len(rows), dtype=np.int64)
    if len(rows):
        np.cumsum(rows[:-1], out=starts[1:])
    return keys, arrays, rows, starts


def _key_to_table(key) -> str:
    return key if isinstance(key, str) else _KEY_SEP.join(key)


def _key_from_table(fname: str, key: str):
    return key if fname == "ordinary" else tuple(key.split(_KEY_SEP))


def write_segment_store(
    index: IndexSet,
    path: str | Path,
    fl_crc: int,
    doc_ids: Sequence[int] = (),
    superseded: Sequence[int] = (),
) -> None:
    """Serialize one immutable segment ``IndexSet`` into ``path`` (DESIGN.md
    §12.2): ``postings.bin`` + ``nsw.bin`` packed column blobs (§12.1 codec,
    keys in sorted order for determinism), a binary ``keys.npz`` row-extent
    table, and a fsync'd manifest with pack codes, column offsets, CRC32s
    and the FL signature the rows were keyed under.  Works over plain and
    :class:`StoredIndexSet` segments alike (re-snapshotting a restored
    index decodes lazily and re-encodes identically)."""
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    blob = bytearray()
    families_meta: dict[str, dict] = {}
    key_table: dict[str, np.ndarray] = {}
    for fname in _FAMILIES:
        width = FAMILY_WIDTH[fname]
        keys, arrays, rows, starts = family_rows(getattr(index, fname), width)
        col_blobs, codes, sizes = _encode_family(arrays, starts, width)
        offsets = []
        for raw in col_blobs:
            offsets.append(len(blob))
            blob += raw
        families_meta[fname] = {
            "n_rows": int(rows.sum()) if len(rows) else 0,
            "codes": codes,
            "offsets": offsets,
            "sizes": sizes,
        }
        key_table[f"{fname}_keys"] = np.asarray(
            [_key_to_table(k) for k in keys], dtype=str
        )
        key_table[f"{fname}_start"] = starts
        key_table[f"{fname}_rows"] = rows

    nsw_blob = bytearray()
    lemmas = sorted(index.nsw.keys())
    recs = [index.nsw[l] for l in lemmas]
    counts_cols = [np.diff(r.offsets.astype(np.int64)) for r in recs]
    n_posts = np.asarray([len(c) for c in counts_cols], dtype=np.int64)
    totals = np.asarray([len(r.stop_lemma) for r in recs], dtype=np.int64)
    post_starts = np.zeros(len(lemmas), dtype=np.int64)
    pay_starts = np.zeros(len(lemmas), dtype=np.int64)
    if len(lemmas):
        np.cumsum(n_posts[:-1], out=post_starts[1:])
        np.cumsum(totals[:-1], out=pay_starts[1:])
    nsw_meta = {"codes": [], "offsets": [], "sizes": [],
                "n_counts": int(n_posts.sum()) if len(lemmas) else 0,
                "n_payload": int(totals.sum()) if len(lemmas) else 0}
    for col in (
        np.concatenate(counts_cols) if counts_cols else np.empty(0, np.int64),
        np.concatenate([r.stop_lemma for r in recs]).astype(np.int64)
        if recs else np.empty(0, np.int64),
        np.concatenate([r.distance for r in recs]).astype(np.int64)
        if recs else np.empty(0, np.int64),
    ):
        code, raw = _pack(_zigzag(col))
        nsw_meta["codes"].append(code)
        nsw_meta["offsets"].append(len(nsw_blob))
        nsw_meta["sizes"].append(len(raw))
        nsw_blob += raw
    key_table["nsw_lemmas"] = np.asarray(lemmas, dtype=str)
    key_table["nsw_post_start"] = post_starts
    key_table["nsw_n_post"] = n_posts
    key_table["nsw_pay_start"] = pay_starts
    key_table["nsw_total"] = totals

    import io

    _write_durable(path / _POSTINGS_BLOB, bytes(blob))
    _write_durable(path / _NSW_BLOB, bytes(nsw_blob))
    keys_buf = io.BytesIO()
    _savez_deterministic(keys_buf, key_table)
    keys_bytes = keys_buf.getvalue()
    _write_durable(path / _KEYS_FILE, keys_bytes)
    manifest = {
        "format_version": FORMAT_VERSION,
        "kind": "segment",
        "n_docs": int(index.n_docs),
        "doc_ids": [int(d) for d in sorted(doc_ids)],
        "superseded": [int(d) for d in sorted(superseded)],
        "max_distance": int(index.max_distance),
        "fl_crc32": int(fl_crc),
        "families": families_meta,
        "nsw": nsw_meta,
        "postings": {"bytes": len(blob), "crc32": zlib.crc32(bytes(blob))},
        "nsw_blob": {"bytes": len(nsw_blob), "crc32": zlib.crc32(bytes(nsw_blob))},
        "keys_file": {"bytes": len(keys_bytes), "crc32": zlib.crc32(keys_bytes)},
    }
    fsync_json(path / _MANIFEST, manifest)


def _load_manifest(path: Path, expect_kind: str) -> dict:
    try:
        with open(path) as f:
            m = json.load(f)
    except FileNotFoundError as e:
        raise StoreError(f"missing manifest {path}") from e
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise StoreError(f"corrupt manifest {path}: {e}") from e
    if not isinstance(m, dict) or m.get("kind") != expect_kind:
        raise StoreError(f"{path} is not a {expect_kind} manifest")
    if m.get("format_version") != FORMAT_VERSION:
        raise StoreError(
            f"{path}: format version {m.get('format_version')} "
            f"not supported (this build reads {FORMAT_VERSION})"
        )
    return m


def _open_blob(path: Path, declared: dict, use_mmap: bool, verify: bool):
    try:
        size = os.path.getsize(path)
    except OSError as e:
        raise StoreError(f"missing blob {path}") from e
    if size != declared["bytes"]:
        raise StoreError(
            f"truncated blob {path}: {size} bytes on disk, "
            f"manifest says {declared['bytes']}"
        )
    if size == 0:
        return b""
    if use_mmap:
        with open(path, "rb") as f:
            buf = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
    else:
        buf = path.read_bytes()
    if verify and zlib.crc32(buf) != declared["crc32"]:
        raise StoreError(f"CRC mismatch in {path}")
    return buf


def open_segment_store(
    path: str | Path,
    fl: FLList,
    use_mmap: bool = True,
    verify: bool = True,
    expect_fl_crc: int | None = None,
) -> tuple[StoredIndexSet, frozenset, set]:
    """Open one §12.2 segment directory as a lazy :class:`StoredIndexSet`
    plus its ``(doc_ids, superseded)`` liveness sets.  ``verify`` checks the
    blob and key-table CRC32s up front (one sequential read; decode stays
    lazy either way); truncation, version and FL-signature mismatches
    always raise :class:`StoreError` — a restored segment is exact or
    refused."""
    path = Path(path)
    m = _load_manifest(path / _MANIFEST, expect_kind="segment")
    if expect_fl_crc is not None and m["fl_crc32"] != expect_fl_crc:
        raise StoreError(
            f"{path}: segment keyed under FL signature {m['fl_crc32']}, "
            f"snapshot expects {expect_fl_crc}"
        )
    postings = _open_blob(path / _POSTINGS_BLOB, m["postings"], use_mmap, verify)
    nsw_blob = _open_blob(path / _NSW_BLOB, m["nsw_blob"], use_mmap, verify)
    keys_path = path / _KEYS_FILE
    try:
        keys_bytes = keys_path.read_bytes()  # one read: CRC + parse
    except OSError as e:
        raise StoreError(f"missing key table {keys_path}") from e
    if len(keys_bytes) != m["keys_file"]["bytes"]:
        raise StoreError(f"truncated key table {keys_path}")
    if verify and zlib.crc32(keys_bytes) != m["keys_file"]["crc32"]:
        raise StoreError(f"CRC mismatch in {keys_path}")
    try:
        import io

        with np.load(io.BytesIO(keys_bytes)) as kt:
            table = {name: kt[name] for name in kt.files}
    except Exception as e:  # zipfile/format errors on corrupt npz
        raise StoreError(f"corrupt key table {keys_path}: {e}") from e

    lazy: dict[str, _LazyPostings] = {}
    totals: dict = {}
    for fname in _FAMILIES:
        fm = m["families"][fname]
        if fm["sizes"] and fm["offsets"][-1] + fm["sizes"][-1] > m["postings"]["bytes"]:
            raise StoreError(f"{path}: {fname} columns overrun postings.bin")
        raw = (table[f"{fname}_keys"], table[f"{fname}_start"], table[f"{fname}_rows"])
        lazy[fname] = _LazyPostings(
            postings, fm["codes"], fm["offsets"], raw, fname, FAMILY_WIDTH[fname]
        )
        totals[fname] = fm["n_rows"]
    nm = m["nsw"]
    if nm["sizes"] and nm["offsets"][-1] + nm["sizes"][-1] > m["nsw_blob"]["bytes"]:
        raise StoreError(f"{path}: NSW columns overrun nsw.bin")
    nsw_raw = (
        table["nsw_lemmas"],
        table["nsw_post_start"],
        table["nsw_n_post"],
        table["nsw_pay_start"],
        table["nsw_total"],
    )
    totals["nsw"] = (len(table["nsw_lemmas"]), nm["n_counts"], nm["n_payload"])
    stored = StoredIndexSet(
        fl=fl,
        max_distance=m["max_distance"],
        n_docs=m["n_docs"],
        ordinary=lazy["ordinary"],
        nsw=_LazyNSW(nsw_blob, nm["codes"], nm["offsets"], nsw_raw),
        pair=lazy["pair"],
        triple=lazy["triple"],
        stop_single=lazy["stop_single"],
        stop_pair=lazy["stop_pair"],
        totals=totals,
    )
    return stored, frozenset(m["doc_ids"]), set(m["superseded"])


# ---------------------------------------------------------------------------
# §12.2 whole-indexer snapshots
# ---------------------------------------------------------------------------


def _claim_restore_epoch(directory: Path, stored_epoch: int) -> int:
    """Hand out a restore epoch no other boot of this snapshot lineage has
    used (§12.5).  Claiming is race-free across concurrent restores: each
    boot creates an empty ``restore_epoch.<E>`` claim file with
    ``O_CREAT|O_EXCL`` (atomic claim-or-exists on POSIX), starting above
    both the snapshot's stored epoch and every existing claim, and walking
    E upward past collisions.  Claim files are tiny, one per boot, and
    never pruned — they ARE the lineage's boot history, so two sibling
    restores of the SAME snapshot always get distinct epochs and can never
    mint the same token for different post-restore states.  Best-effort on
    read-only media: if nothing can be written the epoch still advances
    past the stored epoch and existing claims for THIS boot, but
    cross-boot uniqueness then needs a writable lineage directory
    (documented §12.5 restriction)."""
    claimed = [0]
    try:
        for p in directory.glob("restore_epoch.*"):
            suffix = p.name.rsplit(".", 1)[1]
            if suffix.isdigit():
                claimed.append(int(suffix))
    except OSError:
        pass
    epoch = max(max(claimed), stored_epoch) + 1
    while True:
        try:
            fd = os.open(
                directory / f"restore_epoch.{epoch}",
                os.O_CREAT | os.O_EXCL | os.O_WRONLY,
            )
            os.close(fd)
            return epoch
        except FileExistsError:
            epoch += 1  # lost the race for this epoch: claim the next
        except OSError:
            return epoch  # read-only lineage dir: best effort (docstring)


def latest_snapshot(directory: str | Path) -> int | None:
    """Highest durable ``snap_<N>`` id in ``directory`` (``None`` if none) —
    durable means its manifest exists, i.e. the §12.4 atomic rename
    happened; half-written ``.tmp`` dirs are never visible."""
    return latest_numbered(directory, SNAPSHOT_PREFIX)


def save_snapshot(indexer, directory: str | Path, keep: int = 2) -> Path:
    """Freeze an ``IncrementalIndexer`` into ``<directory>/snap_<N>``
    (DESIGN.md §12.2): every segment as a §12.2 segment store, surviving +
    buffered documents as pre-lemmatized JSONL (restarts never re-lemmatize
    — the arXiv 2006.07954 concern), tombstones, FL state and the §12.5
    generation token.  The write is atomic (tmp dir -> manifest fsync ->
    rename, via ``repro.checkpoint``) and the ``keep`` newest snapshots are
    retained.  Returns the published snapshot path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    latest = latest_snapshot(directory)
    n = 0 if latest is None else latest + 1
    tmp = directory / f"{SNAPSHOT_PREFIX}_{n}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    fl = indexer.fl
    fl_crc = fl_signature(fl)
    seg_names = []
    for i, seg in enumerate(indexer.segments):
        name = f"seg_{i:03d}"
        write_segment_store(
            seg.index,
            tmp / name,
            fl_crc=fl_crc,
            doc_ids=sorted(seg.doc_ids),
            superseded=sorted(seg.superseded),
        )
        seg_names.append(name)

    with open(tmp / _DOCUMENTS, "w") as f:
        for doc_id in sorted(indexer.documents):
            doc = indexer.documents[doc_id]
            f.write(json.dumps({
                "doc_id": doc_id,
                "text": doc.text,
                "lemmas": [list(t) for t in doc.lemma_stream],
            }) + "\n")
        for doc_id in sorted(indexer._buffer):
            doc = indexer._buffer[doc_id]
            f.write(json.dumps({
                "doc_id": doc_id,
                "text": doc.text,
                "lemmas": [list(t) for t in doc.lemma_stream],
                "buffered": True,
            }) + "\n")
        f.flush()
        os.fsync(f.fileno())  # durable before the manifest publishes it

    manifest = {
        "format_version": FORMAT_VERSION,
        "kind": "snapshot",
        "sw_count": indexer.sw_count,
        "fu_count": indexer.fu_count,
        "max_distance": indexer.max_distance,
        "build_pair": indexer.build_pair,
        "build_degenerate": indexer.build_degenerate,
        "fl": None if fl is None else {
            "lemmas": fl.lemmas,
            "frequency": fl.frequency,
            "sw_count": fl.sw_count,
            "fu_count": fl.fu_count,
        },
        "fl_crc32": fl_crc,
        "tombstones": sorted(indexer.tombstones),
        "generation": indexer.generation,
        "mutations": indexer._mutations,
        "epoch": indexer._restore_epoch,
        "next_id": indexer._next_id,
        "segments": seg_names,
        "n_documents": len(indexer.documents),
        "n_buffered": len(indexer._buffer),
    }
    fsync_json(tmp / _MANIFEST, manifest)
    final = directory / f"{SNAPSHOT_PREFIX}_{n}"
    replace_dir(tmp, final)
    retain_latest(directory, SNAPSHOT_PREFIX, keep)
    return final


def load_snapshot(
    directory: str | Path,
    snapshot_id: int | None = None,
    use_mmap: bool = True,
    verify: bool = True,
    lemmatizer=None,
    injector=None,
):
    """Restore an ``IncrementalIndexer`` from a §12.2 snapshot — warm start:
    no re-lemmatization, no index rebuild, no replay; segments serve lazily
    from ``mmap`` pages (:class:`StoredIndexSet`).  The restored indexer is
    exact (``index_sets_equal`` vs the snapshotted live view) and fully
    mutable: commits, FL-drift re-keying, deletes and compaction continue
    from the stored generation.  Its generation token resumes under a
    bumped restore epoch (§12.5), so cached results keyed by pre-restart
    tokens can never be served against post-restart states.  Raises
    :class:`StoreError` on any corruption (see ``open_segment_store``).

    ``injector`` is the §14 (DESIGN.md) fault-injection hook: a scheduled
    ``bitflip`` event physically corrupts a blob of THIS snapshot on disk
    before it is read, so the CRC verify below rejects it for real and
    recovery walks back to an older snapshot — the detection path under
    test is the production one, not a mock."""
    from .incremental import IncrementalIndexer, Segment

    directory = Path(directory)
    sid = snapshot_id if snapshot_id is not None else latest_snapshot(directory)
    if sid is None:
        raise StoreError(f"no snapshot found in {directory}")
    path = directory / f"{SNAPSHOT_PREFIX}_{sid}"
    if injector is not None:
        injector.fire("store.load_snapshot", path=path)
    m = _load_manifest(path / _MANIFEST, expect_kind="snapshot")

    fl = None
    if m["fl"] is not None:
        mf = m["fl"]
        fl = FLList(
            lemmas=list(mf["lemmas"]),
            fl_number={l: i for i, l in enumerate(mf["lemmas"])},
            frequency={l: int(n) for l, n in mf["frequency"].items()},
            sw_count=mf["sw_count"],
            fu_count=mf["fu_count"],
        )
    if fl_signature(fl) != m["fl_crc32"]:
        raise StoreError(f"{path}: FL state does not match its recorded signature")

    ix = IncrementalIndexer(
        sw_count=m["sw_count"],
        fu_count=m["fu_count"],
        max_distance=m["max_distance"],
        lemmatizer=lemmatizer,
        build_pair=m["build_pair"],
        build_degenerate=m["build_degenerate"],
    )
    ix.fl = fl
    try:
        with open(path / _DOCUMENTS) as f:
            for line in f:
                rec = json.loads(line)
                doc = Document(
                    doc_id=rec["doc_id"],
                    text=rec["text"],
                    lemma_stream=[tuple(t) for t in rec["lemmas"]],
                )
                if rec.get("buffered"):
                    ix._buffer[doc.doc_id] = doc
                else:
                    ix.documents[doc.doc_id] = doc
                ix._doc_lemmas[doc.doc_id] = frozenset(
                    l for t in doc.lemma_stream for l in t
                )
                ix._freq.update(l for t in doc.lemma_stream for l in t)
    except FileNotFoundError as e:
        raise StoreError(f"missing document store {path / _DOCUMENTS}") from e
    except (json.JSONDecodeError, KeyError) as e:
        raise StoreError(f"corrupt document store in {path}: {e}") from e
    if len(ix.documents) != m["n_documents"] or len(ix._buffer) != m["n_buffered"]:
        raise StoreError(
            f"truncated document store in {path}: "
            f"{len(ix.documents)}+{len(ix._buffer)} docs, manifest says "
            f"{m['n_documents']}+{m['n_buffered']}"
        )

    ix.tombstones = set(m["tombstones"])
    ix.generation = m["generation"]
    ix._mutations = m["mutations"]
    ix._restore_epoch = _claim_restore_epoch(directory, m["epoch"])
    ix._next_id = m["next_id"]
    segments = []
    for name in m["segments"]:
        stored, doc_ids, superseded = open_segment_store(
            path / name,
            fl=fl,
            use_mmap=use_mmap,
            verify=verify,
            expect_fl_crc=m["fl_crc32"],
        )
        segments.append(Segment(index=stored, doc_ids=doc_ids, superseded=superseded))
    ix.segments = segments
    return ix
