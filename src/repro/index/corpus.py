"""Document store and synthetic Zipf corpus (paper §1, §11).

The paper's experiments use a 71.5 GB fiction collection and GOV2; neither is
shippable, but the paper argues (§11) that "in typical texts the words are
distributed similarly, as Zipf stated" — so a Zipf-synthesized corpus with a
realistic stop-lemma head reproduces the *algorithmic* behaviour (posting-list
sizes, window densities) that the algorithms are sensitive to.

The generator mixes:
  * a high-frequency function-word head (real English stop words, Zipf ranks),
  * a Zipf tail of synthetic content words,
  * injected phrase snippets (the paper's running examples) so that the
    paper's example queries have non-trivial result sets.

Exactness contract: a ``DocumentStore`` is the ground truth the differential
harness rebuilds from — ``lemma_frequencies`` defines the FL-list, and the
per-position ``lemma_stream`` is exactly what §3 row generation consumes, so
any two builds over equal stores are byte-identical.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

from ..core.lemma import Lemmatizer, tokenize

__all__ = ["Document", "DocumentStore", "synthesize_corpus", "PAPER_EXAMPLE_DOCS"]


# The paper's §3 example documents (word positions are 0-based).
PAPER_EXAMPLE_DOCS: tuple[str, ...] = (
    "Who are you is the album by The Who",
    "Who has reality, who is real, who is true",
)

# Head of the English frequency distribution (order ~ real Zipf rank).
_FUNCTION_WORDS: tuple[str, ...] = (
    "the", "be", "to", "of", "and", "a", "in", "that", "have", "i",
    "it", "for", "not", "on", "with", "he", "as", "you", "do", "at",
    "this", "but", "his", "by", "from", "they", "we", "say", "her", "she",
    "or", "an", "will", "my", "one", "all", "would", "there", "their", "what",
    "so", "up", "out", "if", "about", "who", "get", "which", "go", "me",
    "when", "make", "can", "like", "time", "no", "just", "him", "know", "take",
    "people", "into", "year", "your", "good", "some", "could", "them", "see", "other",
    "than", "then", "now", "look", "only", "come", "its", "over", "think", "also",
    "back", "after", "use", "two", "how", "our", "work", "first", "well", "way",
    "even", "new", "want", "because", "any", "these", "give", "day", "most", "us",
    "is", "are", "was", "were", "why", "need", "war", "man", "old", "great",
)

_PHRASES: tuple[str, ...] = (
    "who are you who",
    "to be or not to be",
    "who are you and why did you say what you did",
    "the who are an english rock band",
    "i need you",
    "one at a time",
    "who is who in the world of war",
    "what do you do all day",
    "how to find the mean",
    "time and time again",
)


@dataclass
class Document:
    """One indexed text: word positions are 0-based ordinals (§3), and
    ``lemma_stream`` holds one tuple of lemmas per position (§2 multi-lemma
    words, e.g. "are" -> ("are", "be"))."""

    doc_id: int
    text: str
    # one tuple of lemmas per word position (multi-lemma words possible)
    lemma_stream: list[tuple[str, ...]] = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.lemma_stream)


@dataclass
class DocumentStore:
    """The corpus a §3 build (or incremental rebuild oracle) runs over:
    pre-lemmatized documents plus the shared §2 lemmatizer."""

    documents: list[Document]
    lemmatizer: Lemmatizer

    @classmethod
    def from_texts(cls, texts: Sequence[str], lemmatizer: Lemmatizer | None = None) -> "DocumentStore":
        lem = lemmatizer or Lemmatizer()
        docs = [
            Document(doc_id=i, text=t, lemma_stream=lem.lemmatize_text(t))
            for i, t in enumerate(texts)
        ]
        return cls(documents=docs, lemmatizer=lem)

    @classmethod
    def from_documents(
        cls, documents: Iterable[Document], lemmatizer: Lemmatizer | None = None
    ) -> "DocumentStore":
        """Wrap already-lemmatized documents (doc ids preserved) — the
        rebuild corpus of the incremental indexer's differential checks."""
        return cls(documents=list(documents), lemmatizer=lemmatizer or Lemmatizer())

    def subset(self, doc_ids: Iterable[int]) -> "DocumentStore":
        """Store restricted to ``doc_ids`` (original ids and order kept)."""
        keep = set(doc_ids)
        return DocumentStore(
            documents=[d for d in self.documents if d.doc_id in keep],
            lemmatizer=self.lemmatizer,
        )

    def __len__(self) -> int:
        return len(self.documents)

    def lemma_frequencies(self) -> dict[str, int]:
        """Occurrence counts over every lemma of every position (the FL basis)."""
        freq: dict[str, int] = {}
        for d in self.documents:
            for lemmas in d.lemma_stream:
                for l in lemmas:
                    freq[l] = freq.get(l, 0) + 1
        return freq

    def total_positions(self) -> int:
        return sum(len(d) for d in self.documents)


def synthesize_corpus(
    n_docs: int = 200,
    doc_len: int = 250,
    vocab_size: int = 5000,
    zipf_a: float = 1.2,
    seed: int = 0,
    phrase_rate: float = 0.04,
    include_paper_examples: bool = True,
) -> DocumentStore:
    """Zipf-distributed synthetic corpus with injected paper phrases — the
    §11 experimental stand-in (see module docstring for the Zipf argument)."""
    rng = np.random.default_rng(seed)
    n_func = len(_FUNCTION_WORDS)
    tail = [f"w{idx:05d}" for idx in range(vocab_size)]
    vocab = list(_FUNCTION_WORDS) + tail
    # Zipf ranks over the merged vocabulary
    ranks = np.arange(1, len(vocab) + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()

    texts: list[str] = list(PAPER_EXAMPLE_DOCS) if include_paper_examples else []
    for _ in range(n_docs):
        draws = rng.choice(len(vocab), size=doc_len, p=probs)
        words: list[str] = []
        for tok_idx in draws:
            if rng.random() < phrase_rate:
                words.extend(tokenize(_PHRASES[int(rng.integers(len(_PHRASES)))]))
            words.append(vocab[int(tok_idx)])
        texts.append(" ".join(words))
    return DocumentStore.from_texts(texts)
