from .corpus import Document, DocumentStore, synthesize_corpus, PAPER_EXAMPLE_DOCS
from .builder import IndexSet, build_indexes, build_segment
from .incremental import (
    IncrementalIndexer,
    Segment,
    SegmentedIndexSet,
    as_index_set,
    generation_token,
    index_sets_equal,
)
from .store import (
    StoreError,
    StoredIndexSet,
    latest_snapshot,
    load_snapshot,
    save_snapshot,
)
from .wal import WalError, WalRecord, WriteAheadLog, read_frames, replay

__all__ = [
    "Document",
    "DocumentStore",
    "synthesize_corpus",
    "PAPER_EXAMPLE_DOCS",
    "IndexSet",
    "build_indexes",
    "build_segment",
    "IncrementalIndexer",
    "Segment",
    "SegmentedIndexSet",
    "as_index_set",
    "generation_token",
    "index_sets_equal",
    "StoreError",
    "StoredIndexSet",
    "latest_snapshot",
    "load_snapshot",
    "save_snapshot",
    "WalError",
    "WalRecord",
    "WriteAheadLog",
    "read_frames",
    "replay",
]
