from .corpus import Document, DocumentStore, synthesize_corpus, PAPER_EXAMPLE_DOCS
from .builder import IndexSet, build_indexes

__all__ = [
    "Document",
    "DocumentStore",
    "synthesize_corpus",
    "PAPER_EXAMPLE_DOCS",
    "IndexSet",
    "build_indexes",
]
