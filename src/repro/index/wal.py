"""Write-ahead operation log for the incremental indexer (DESIGN.md §18).

Durability model (§18.1): every mutating operation on a WAL-attached
:class:`~repro.index.incremental.IncrementalIndexer` — ``add`` /
``delete`` / ``commit`` / ``compact`` — appends one CRC-framed, fsync'd
record *before* the live indexer mutates.  Records carry pre-lemmatized
payloads and are monotonically sequence-numbered; snapshots append
``checkpoint`` records that anchor replay (§18.2) and let the shared
``retain_latest`` primitive truncate replayed prefixes.  The on-disk
layout is numbered segment directories under ``<lineage>/wal/``::

    wal/
      wal_0/records.bin  manifest.json   # sealed at checkpoint time
      wal_1/records.bin                  # active tail (no manifest yet)

A sealed segment gets a fsync'd ``manifest.json`` (first/last sequence
number, sealing snapshot id), which is exactly the completeness marker
``retain_latest`` / ``latest_numbered`` key on (DESIGN.md §12.4) — the
active tail is invisible to retention and can never be collected.

Frame format (§18.1)::

    magic u16 | seq u64 | type u8 | payload_len u32 | crc u32 | payload

All little-endian; ``crc`` is ``zlib.crc32`` over ``seq | type | payload``.
A torn tail (crash mid-append) or a bitflipped record fails the magic /
length / CRC / monotonic-seq checks and the reader truncates the file at
the last valid frame — replay then reproduces exactly the prefix of
operations whose ``append`` returned (i.e. everything that could have
been acknowledged).

Exactness contract: restoring the latest snapshot and replaying the WAL
tail after its checkpoint record yields an indexer ``index_sets_equal``
to the uncrashed live indexer — *including commits after the snapshot*
(the §18.2 zero-data-loss invariant the chaos harness pins).  Replay of a
``commit`` record re-applies the logged resolved FL, so single-shard
recovery reproduces a corpus-level FL reduce without the other shards.

Fault points (§14 ABI): ``wal.append`` fires before a frame is written
(``crash``/``kill`` abort the append — the operation is lost but was
never acknowledged); ``wal.torn_tail`` fires between serialization and
the durable write — when it raises, a *partial* frame is flushed to disk
first, producing a real torn tail for the reader to truncate.
"""

from __future__ import annotations

import json
import os
import struct
import zlib
from dataclasses import dataclass
from pathlib import Path
from typing import TYPE_CHECKING, Iterable, Sequence

from repro.checkpoint import append_durable, fsync_json, latest_numbered, retain_latest

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from .incremental import IncrementalIndexer

_MAGIC = 0xA11E
_HEADER = struct.Struct("<HQBI I")  # magic, seq, type, payload_len, crc
WAL_PREFIX = "wal"
_RECORDS = "records.bin"
_MANIFEST = "manifest.json"

# record types (§18.1): the complete set of mutating indexer operations
# plus the checkpoint anchor snapshots append
RT_ADD = 1
RT_DELETE = 2
RT_COMMIT = 3
RT_COMPACT = 4
RT_CHECKPOINT = 5
RT_BULK_BUILD = 6

_TYPE_NAMES = {
    RT_ADD: "add",
    RT_DELETE: "delete",
    RT_COMMIT: "commit",
    RT_COMPACT: "compact",
    RT_CHECKPOINT: "checkpoint",
    RT_BULK_BUILD: "bulk_build",
}
_TYPE_IDS = {v: k for k, v in _TYPE_NAMES.items()}


class WalError(RuntimeError):
    """Unrecoverable WAL protocol violation (§18) — corruption is NOT one
    (torn/bitflipped tails are truncated, not raised); this fires only on
    misuse, e.g. replaying against a state the log does not anchor."""


@dataclass(frozen=True)
class WalRecord:
    """One decoded §18.1 frame: ``rtype`` is the symbolic record type
    (``add``/``delete``/``commit``/``compact``/``checkpoint``/``bulk_build``)
    and ``payload`` the JSON-decoded operation body — byte-exact round-trip
    of what :meth:`WriteAheadLog.append` logged (identical after any number
    of reopen cycles)."""

    seq: int
    rtype: str
    payload: dict


def encode_frame(seq: int, rtype: str, payload: dict) -> bytes:
    """Serialize one §18.1 frame (exact inverse of the reader: decoding the
    returned bytes yields an identical :class:`WalRecord`)."""
    body = json.dumps(payload, sort_keys=True).encode("utf-8")
    tid = _TYPE_IDS[rtype]
    crc = zlib.crc32(struct.pack("<QB", seq, tid) + body) & 0xFFFFFFFF
    return _HEADER.pack(_MAGIC, seq, tid, len(body), crc) + body


def read_frames(path: str | Path, truncate: bool = True) -> list[WalRecord]:
    """Scan ``records.bin`` and return every valid frame in order (§18.1
    torn-tail rule).  Scanning stops at the first invalid frame — bad
    magic, short header, truncated payload, CRC mismatch or non-monotonic
    sequence number — and with ``truncate`` the file is physically cut
    back to the last valid frame so subsequent appends extend a clean
    tail.  The returned records are exactly the acknowledged prefix."""
    path = Path(path)
    if not path.exists():
        return []
    data = path.read_bytes()
    records: list[WalRecord] = []
    off = 0
    last_seq = -1
    valid_end = 0
    while off + _HEADER.size <= len(data):
        magic, seq, tid, plen, crc = _HEADER.unpack_from(data, off)
        body_end = off + _HEADER.size + plen
        if magic != _MAGIC or tid not in _TYPE_NAMES or body_end > len(data):
            break
        body = data[off + _HEADER.size : body_end]
        if zlib.crc32(struct.pack("<QB", seq, tid) + body) & 0xFFFFFFFF != crc:
            break
        if seq <= last_seq:
            break
        try:
            payload = json.loads(body.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError):
            break
        records.append(WalRecord(seq=seq, rtype=_TYPE_NAMES[tid], payload=payload))
        last_seq = seq
        off = valid_end = body_end
    if truncate and valid_end < len(data):
        with open(path, "r+b") as f:
            f.truncate(valid_end)
            f.flush()
            os.fsync(f.fileno())
    return records


class WriteAheadLog:
    """CRC-framed, fsync'd operation log over one snapshot lineage
    (DESIGN.md §18.1-§18.2).

    Exactness: ``records()`` after any crash returns exactly the prefix of
    operations whose :meth:`append` returned (durable-before-acknowledge),
    and :func:`replay` of that prefix onto the anchoring snapshot is
    ``index_sets_equal`` to the uncrashed indexer.

    ``injector`` is the §14 fault hook (points ``wal.append`` and
    ``wal.torn_tail``); ``shard`` keys its per-shard arrival counters.
    """

    def __init__(self, directory: str | Path, injector=None, shard=None):
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self.injector = injector
        self.shard = shard
        self._segment = self._open_tail()
        tail = read_frames(self._segment / _RECORDS)
        self._next_seq = (tail[-1].seq + 1) if tail else self._sealed_next_seq()

    # -- segments -----------------------------------------------------------

    def _segments(self) -> list[tuple[int, Path]]:
        out = []
        for p in self.directory.glob(f"{WAL_PREFIX}_*"):
            if not p.is_dir():
                continue
            try:
                out.append((int(p.name.rsplit("_", 1)[1]), p))
            except ValueError:
                continue
        return sorted(out)

    def _open_tail(self) -> Path:
        segs = self._segments()
        # the active tail is the highest-numbered UNSEALED segment (no
        # manifest); if every segment is sealed, start a fresh one after it
        if segs and not (segs[-1][1] / _MANIFEST).exists():
            return segs[-1][1]
        n = (segs[-1][0] + 1) if segs else 0
        seg = self.directory / f"{WAL_PREFIX}_{n}"
        seg.mkdir(parents=True, exist_ok=True)
        return seg

    def _sealed_next_seq(self) -> int:
        sealed = latest_numbered(self.directory, WAL_PREFIX)
        if sealed is None:
            return 0
        m = json.loads((self.directory / f"{WAL_PREFIX}_{sealed}" / _MANIFEST).read_text())
        return int(m["last_seq"]) + 1

    # -- append path --------------------------------------------------------

    def append(self, rtype: str, payload: dict) -> int:
        """Durably log one operation BEFORE it mutates the indexer (§18.1);
        returns the record's sequence number.  Crash semantics: if this
        raises, the operation was never acknowledged and recovery does not
        replay it; if it returns, the record survives any crash."""
        if self.injector is not None:
            # crash/kill here aborts the append before any byte is written:
            # the op is lost but was never acknowledged (no durability hole)
            self.injector.fire("wal.append", shard=self.shard)
        seq = self._next_seq
        frame = encode_frame(seq, rtype, payload)
        path = self._segment / _RECORDS
        if self.injector is not None:
            try:
                self.injector.fire("wal.torn_tail", shard=self.shard, path=path)
            except Exception:
                # simulate a crash mid-write: flush a PARTIAL frame so the
                # reader finds a real torn tail to truncate (§18.1)
                append_durable(path, frame[: max(1, len(frame) // 2)])
                raise
        append_durable(path, frame)
        self._next_seq = seq + 1
        return seq

    def checkpoint(self, snapshot_id: int, mutations: int, rtype: str = "checkpoint") -> int:
        """Anchor an about-to-publish snapshot in the log (§18.2): appends a
        ``checkpoint`` (or ``bulk_build``) record carrying the snapshot id
        and mutation counter, then seals the active segment with a fsync'd
        manifest.  Replay-after-restore starts strictly after this record.
        Call BEFORE publishing ``snap_<id>``: if the snapshot publish then
        crashes, restore falls back to the previous snapshot and the
        dangling checkpoint record replays as a no-op."""
        seq = self.append(rtype, {"snapshot_id": int(snapshot_id), "mutations": int(mutations)})
        self._seal(snapshot_id)
        return seq

    def _seal(self, snapshot_id: int) -> None:
        records = read_frames(self._segment / _RECORDS)
        fsync_json(
            self._segment / _MANIFEST,
            {
                "kind": "wal_segment",
                "first_seq": records[0].seq if records else self._next_seq,
                "last_seq": records[-1].seq if records else self._next_seq - 1,
                "sealed_by_snapshot": int(snapshot_id),
            },
        )
        self._segment = self._open_tail()

    def prune(self, keep: int = 2) -> None:
        """Truncate replayed prefixes (§18.2): drop all but the ``keep``
        newest *sealed* segments via the shared ``retain_latest`` primitive
        — the unsealed active tail has no manifest and is never collected.
        Mirrors snapshot retention: with ``keep`` matching the snapshot
        ``keep``, every retained snapshot keeps its replay tail."""
        retain_latest(self.directory, WAL_PREFIX, keep)

    # -- read / replay path -------------------------------------------------

    def records(self) -> list[WalRecord]:
        """All surviving records across sealed segments + the active tail,
        in sequence order, with torn/bitflipped tails truncated (§18.1)."""
        out: list[WalRecord] = []
        for _, seg in self._segments():
            out.extend(read_frames(seg / _RECORDS))
        return out

    def tail_after_snapshot(self, snapshot_id: int) -> list[WalRecord]:
        """The replay suffix for a restore of ``snap_<snapshot_id>`` (§18.2):
        every record strictly after that snapshot's checkpoint record.
        Returns ``[]`` when the snapshot is not anchored in the log (a WAL
        attached after the snapshot existed — nothing to replay is the safe
        answer: recovery degrades to the §12 snapshot-only RPO)."""
        records = self.records()
        anchor = None
        for i, rec in enumerate(records):
            if (
                rec.rtype in ("checkpoint", "bulk_build")
                and rec.payload.get("snapshot_id") == snapshot_id
            ):
                anchor = i
        if anchor is None:
            return []
        return records[anchor + 1 :]

    def close(self) -> None:
        """No-op for API symmetry: appends open/fsync/close per frame, so a
        crashed holder never pins a file handle recovery must steal."""


# ---------------------------------------------------------------------------
# replay (§18.2)
# ---------------------------------------------------------------------------


def fl_to_payload(fl) -> dict | None:
    """JSON form of an FL list for ``commit`` records (§18.1) — round-trips
    exactly (``fl_from_payload(fl_to_payload(fl))`` has identical lemmas,
    numbering, frequencies and class splits, hence equal
    ``fl_signature``), so single-shard replay reproduces the §18.2
    corpus-level FL reduce without the other shards."""
    if fl is None:
        return None
    return {
        "lemmas": fl.lemmas,
        "frequency": fl.frequency,
        "sw_count": fl.sw_count,
        "fu_count": fl.fu_count,
    }


def fl_from_payload(payload: dict | None):
    """Inverse of :func:`fl_to_payload` (§18.1; exact round-trip, see
    there)."""
    from repro.core.lemma import FLList

    if payload is None:
        return None
    lemmas = list(payload["lemmas"])
    return FLList(
        lemmas=lemmas,
        fl_number={l: i for i, l in enumerate(lemmas)},
        frequency={l: int(n) for l, n in payload["frequency"].items()},
        sw_count=payload["sw_count"],
        fu_count=payload["fu_count"],
    )


def docs_to_payload(docs: Sequence) -> list[dict]:
    """Pre-lemmatized document payload for ``add`` records (§18.1) — the
    same ``{doc_id, text, lemmas}`` row shape as the §12.2 snapshot
    ``documents.jsonl``, so replay never re-lemmatizes (exact
    lemma-stream round-trip)."""
    return [
        {
            "doc_id": d.doc_id,
            "text": d.text,
            "lemmas": [list(position) for position in d.lemma_stream],
        }
        for d in docs
    ]


def docs_from_payload(rows: Iterable[dict]) -> list:
    """Inverse of :func:`docs_to_payload` (§18.1; exact round-trip, see
    there)."""
    from .corpus import Document

    return [
        Document(
            doc_id=int(r["doc_id"]),
            text=r["text"],
            lemma_stream=[tuple(p) for p in r["lemmas"]],
        )
        for r in rows
    ]


def replay(indexer: "IncrementalIndexer", records: Sequence[WalRecord]) -> int:
    """Re-apply a WAL suffix onto a restored indexer (§18.2); returns the
    number of mutating records applied.

    Exactness contract: for a suffix produced by
    :meth:`WriteAheadLog.tail_after_snapshot`, the replayed indexer is
    ``index_sets_equal`` to the uncrashed live indexer that executed the
    same operations — including post-snapshot commits — because every
    record carries its full pre-resolved inputs (pre-lemmatized documents,
    the resolved FL of each commit) and the segment builders are
    deterministic.  ``checkpoint``/``bulk_build`` anchors replay as no-ops.
    WAL appends are suppressed during replay (the records are already
    durable; re-logging them would double the tail)."""
    wal = getattr(indexer, "wal", None)
    indexer.wal = None  # suppress re-logging while replaying
    applied = 0
    try:
        for rec in records:
            if rec.rtype == "add":
                indexer.add_prelemmatized(docs_from_payload(rec.payload["docs"]))
            elif rec.rtype == "delete":
                indexer.delete_document(int(rec.payload["doc_id"]))
            elif rec.rtype == "commit":
                indexer.commit(fl=fl_from_payload(rec.payload["fl"]))
            elif rec.rtype == "compact":
                indexer.compact(memory_budget_bytes=rec.payload["memory_budget_bytes"])
            elif rec.rtype in ("checkpoint", "bulk_build"):
                continue
            else:  # pragma: no cover - reader only yields known types
                raise WalError(f"unknown WAL record type {rec.rtype!r}")
            applied += 1
    finally:
        indexer.wal = wal
    return applied
