"""Int8 gradient compression with error feedback for cross-pod all-reduce.

At multi-pod scale the ``pod`` axis crosses the slow DCI links; compressing
gradients 4x (fp32 -> int8 with a per-tensor scale) cuts that traffic
proportionally.  Error feedback (Seide et al., 1-bit SGD; Karimireddy et al.
2019) keeps convergence: the quantization residual is carried into the next
step, making the compression unbiased in the long run.

Implemented as an explicit ``shard_map`` collective so the quantize ->
psum -> dequantize pipeline is visible to the compiler (GSPMD's implicit
all-reduce cannot be intercepted).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["init_error_feedback", "compressed_psum", "compressed_grad_allreduce"]


def init_error_feedback(grads_template: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(
    g: jax.Array, err: jax.Array, axis_name: str
) -> tuple[jax.Array, jax.Array]:
    """Error-feedback int8 psum of one tensor along ``axis_name``.

    Returns (mean-reduced gradient, new error residual).
    """
    x = g.astype(jnp.float32) + err
    q, scale = _quantize(x)
    new_err = x - q.astype(jnp.float32) * scale
    # int8 payload crosses the wire; accumulate in int32 to avoid overflow
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_sum = jax.lax.psum(scale, axis_name)  # scales are cheap (1 scalar)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # each shard contributed ~q*scale; use the mean scale for dequantization
    out = summed.astype(jnp.float32) * (scale_sum / n) / n
    return out.astype(g.dtype), new_err


def compressed_grad_allreduce(
    grads: Any, err_state: Any, mesh: Mesh, axis_name: str = "pod"
) -> tuple[Any, Any]:
    """Tree-wide compressed all-reduce over one mesh axis via shard_map."""
    specs = jax.tree.map(lambda _: P(), grads)

    @functools.partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(specs, specs),
        out_specs=(specs, specs),
    )
    def _inner(g_tree, e_tree):
        flat_g, treedef = jax.tree.flatten(g_tree)
        flat_e = treedef.flatten_up_to(e_tree)
        outs = [compressed_psum(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
        return (
            treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]),
        )

    return _inner(grads, err_state)
