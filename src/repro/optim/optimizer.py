"""AdamW with fp32 master weights, global-norm clipping and cosine schedule.

Implemented from scratch (no optax in the container).  The optimizer state
is a pytree mirroring the params: fp32 ``m``/``v`` moments and an fp32
``master`` copy (params themselves may live in bf16); sharding specs for the
state reuse the param specs (ZeRO-style sharding is applied by the caller's
PartitionSpecs, not here).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000


def cosine_schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_init(params: Any) -> dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads: Any, state: dict[str, Any], cfg: AdamWConfig
) -> tuple[Any, dict[str, Any]]:
    """Returns (new bf16/bf32 params cast from master, new state)."""
    step = state["step"] + 1
    lr = cosine_schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1c = 1.0 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.beta1 * m + (1 - cfg.beta1) * g
        v_new = cfg.beta2 * v + (1 - cfg.beta2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master
        master_new = master - lr * delta
        return m_new, v_new, master_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_w = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "master": new_w, "step": step}
    return new_w, new_state


def cast_like(master: Any, params_template: Any) -> Any:
    return jax.tree.map(lambda w, p: w.astype(p.dtype), master, params_template)
