"""Docstring audit for the public ``repro.search`` / ``repro.index`` /
``repro.checkpoint`` APIs.

The repo's documentation contract (ISSUE 3 satellite; extended to the
persistence layers by ISSUE 4): every public class and module-level
function of the search, index and checkpoint layers must state

* its **paper-§ anchor** — a ``§`` reference tying the code to the source
  paper or to a stable ``DESIGN.md`` section; and
* (at module level) its **exactness contract** — what the code promises to
  be exact/identical/equal to (the differential harness pins these).

``pydocstyle`` is not available in the minimal container, so this is a
self-contained stdlib checker with exactly those two project-specific rules;
CI runs it next to the doctest step (``.github/workflows/ci.yml``), and
``tests/test_docstrings.py`` enforces it in the tier-1 suite.

Usage::

    PYTHONPATH=src python tools/docstring_audit.py [-v]
"""

from __future__ import annotations

import argparse
import importlib
import inspect
import pkgutil
import sys

PACKAGES = ("repro.search", "repro.index", "repro.checkpoint")

# module docstrings must state what the code is exact with respect to
EXACTNESS_KEYWORDS = (
    "exact",
    "identical",
    "equality",
    "ground truth",
    "must reproduce",
)

ANCHOR = "§"


def iter_modules(package_name: str):
    pkg = importlib.import_module(package_name)
    yield pkg
    for info in pkgutil.iter_modules(pkg.__path__, prefix=package_name + "."):
        yield importlib.import_module(info.name)


def public_symbols(module):
    """Top-level classes/functions the module itself defines and exports."""
    names = getattr(module, "__all__", None)
    if names is None:
        names = [n for n in vars(module) if not n.startswith("_")]
    for name in names:
        obj = getattr(module, name, None)
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue
        if getattr(obj, "__module__", None) != module.__name__:
            continue  # re-export: audited where it is defined
        yield name, obj


def audit(verbose: bool = False) -> list[str]:
    problems: list[str] = []
    n_modules = n_symbols = 0
    for package in PACKAGES:
        for module in iter_modules(package):
            # package __init__ modules re-export; audited where defined.
            # (Compared by full name: repro.checkpoint.checkpoint must NOT
            # be mistaken for the repro.checkpoint package itself.)
            is_init = module.__name__ in PACKAGES
            doc = inspect.getdoc(module) or ""
            if not is_init:
                n_modules += 1
                if not doc:
                    problems.append(f"{module.__name__}: missing module docstring")
                else:
                    if ANCHOR not in doc:
                        problems.append(
                            f"{module.__name__}: module docstring lacks a "
                            f"paper-§ anchor"
                        )
                    if not any(k in doc.lower() for k in EXACTNESS_KEYWORDS):
                        problems.append(
                            f"{module.__name__}: module docstring states no "
                            f"exactness contract "
                            f"(one of: {', '.join(EXACTNESS_KEYWORDS)})"
                        )
            for name, obj in public_symbols(module):
                n_symbols += 1
                sdoc = inspect.getdoc(obj) or ""
                where = f"{module.__name__}.{name}"
                if not sdoc:
                    problems.append(f"{where}: missing docstring")
                elif ANCHOR not in sdoc:
                    problems.append(f"{where}: docstring lacks a paper-§ anchor")
                elif verbose:
                    print(f"ok  {where}")
    if verbose or not problems:
        print(
            f"audited {n_modules} modules, {n_symbols} public symbols "
            f"across {', '.join(PACKAGES)}: "
            f"{len(problems)} problem(s)"
        )
    return problems


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args()
    problems = audit(verbose=args.verbose)
    for p in problems:
        print(f"FAIL {p}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
