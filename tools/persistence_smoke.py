"""CI persistence smoke: snapshot in one process, restore in another,
re-serve the golden §10.1–10.2 queries (DESIGN.md §12 + §18).

Four subcommands, run as SEPARATE processes so the restore can share
nothing with the build (the restart the durable store exists for):

    PYTHONPATH=src python tools/persistence_smoke.py save <dir>
    PYTHONPATH=src python tools/persistence_smoke.py check <dir>
    PYTHONPATH=src python tools/persistence_smoke.py crash <dir>
    PYTHONPATH=src python tools/persistence_smoke.py replay <dir>

``save`` builds the paper's example corpus + a Zipf tail incrementally
(commits across generations, one delete), snapshots a sharded service into
``<dir>``, and records every golden query's exact fragment set in
``<dir>/expected.json``.  ``check`` restores the service from disk in a
fresh process, re-serves the same queries through the frontend AND the raw
engines, and exits non-zero unless the fragment sets are identical — the
§12 exactness contract, enforced end to end across a process boundary.

``crash`` builds the same service with a §18 WAL armed, snapshots it,
applies ACKNOWLEDGED post-snapshot work (adds + commits + a delete), then
crashes a final commit mid-WAL-append via the ``wal.torn_tail`` fault
point — leaving a torn frame on disk exactly as a power cut would.  It
records the acked fragment sets (the crashed op excluded) before dying.
``replay`` restores in a fresh process: the WAL tail must replay every
acked record, truncate the torn frame, and reproduce the acked fragment
sets exactly — the §18.2 zero-data-loss contract across a real process
boundary.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

# the golden §10.1–10.2 queries of tests/test_golden.py plus the §12
# duplicate-lemma running example
GOLDEN_QUERIES = ("who are you", "who are you who", "to be or not to be")


def _fragments(resp) -> list:
    return sorted((d.doc_id, f.start, f.end) for d in resp.docs for f in d.fragments)


def _build_service():
    from repro.index import DocumentStore, PAPER_EXAMPLE_DOCS
    from repro.index.corpus import synthesize_corpus
    from repro.search.distributed import ShardedSearchService

    tail = synthesize_corpus(n_docs=40, doc_len=80, vocab_size=800, seed=29)
    store = DocumentStore.from_texts(
        list(PAPER_EXAMPLE_DOCS) + [d.text for d in tail.documents]
    )
    svc = ShardedSearchService(
        store, n_shards=2, sw_count=60, fu_count=150, incremental=True
    )
    svc.add_documents(["who is who in the world of war, who are you"])
    svc.commit()
    svc.delete_document(3)
    return svc


def save(directory: Path) -> int:
    from repro.search.frontend import ServingFrontend

    svc = _build_service()
    frontend = ServingFrontend(svc)
    expected = {
        q: _fragments(frontend.search(q, top_k=64)) for q in GOLDEN_QUERIES
    }
    svc.snapshot(directory)
    (directory / "expected.json").write_text(json.dumps(expected, indent=1))
    print(f"saved service snapshot + {len(expected)} golden fragment sets "
          f"to {directory}")
    return 0


def check(directory: Path) -> int:
    from repro.search.distributed import ShardedSearchService
    from repro.search.frontend import ServingFrontend

    expected = json.loads((directory / "expected.json").read_text())
    frontend = ServingFrontend.from_snapshot(directory)
    svc = ShardedSearchService.restore(directory)
    failures = []
    for q, want in expected.items():
        want = [tuple(f) for f in want]
        got_frontend = _fragments(frontend.search(q, top_k=64))
        got_raw = _fragments(svc.search(q, top_k=64))
        if got_frontend != want:
            failures.append(f"frontend fragments diverged for {q!r}")
        if got_raw != want:
            failures.append(f"raw-engine fragments diverged for {q!r}")
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    if not failures:
        print(f"restored service reproduced {len(expected)} golden fragment "
              f"sets exactly (fresh process, mmap warm start)")
    return 1 if failures else 0


def crash(directory: Path) -> int:
    from repro.search.frontend import ServingFrontend
    from repro.search.resilience import FaultEvent, FaultInjector

    svc = _build_service()
    svc.enable_wal(directory)
    svc.snapshot(directory)
    # ACKED post-snapshot tail: every one of these ops returns before the
    # crash, so §18.2 says a fresh restore must reproduce all of them
    svc.add_documents([
        "to be who you are is not to be nobody",
        "war and peace and who goes to war again",
    ])
    svc.commit()
    svc.delete_document(5)
    svc.commit()
    frontend = ServingFrontend(svc)
    expected = {
        q: _fragments(frontend.search(q, top_k=64)) for q in GOLDEN_QUERIES
    }
    # the crashed op targets the shard that will route the next doc id;
    # its WAL add-append dies mid-write, leaving a real torn frame
    target = svc._next_doc_id % svc.n_shards
    tail = sorted((directory / f"shard_{target:02d}" / "wal").glob("wal_*"))[-1]
    acked_size = (tail / "records.bin").stat().st_size
    svc.enable_wal(directory, injector=FaultInjector(schedule=[
        FaultEvent("wal.torn_tail", "crash", shard=target, at_call=0),
    ]))
    try:
        svc.add_documents(["this unacknowledged write is torn mid frame"])
    except Exception as exc:
        crashed = type(exc).__name__
    else:
        print("FAIL injected wal.torn_tail crash did not fire", file=sys.stderr)
        return 1
    torn_size = (tail / "records.bin").stat().st_size
    if torn_size <= acked_size:
        print("FAIL no partial frame reached the WAL tail", file=sys.stderr)
        return 1
    (directory / "expected_acked.json").write_text(json.dumps({
        "fragments": expected,
        "torn_tail": str((tail / "records.bin").relative_to(directory)),
        "acked_size": acked_size,
        "torn_size": torn_size,
    }, indent=1))
    print(f"crashed mid-commit via {crashed}: WAL tail torn at byte "
          f"{torn_size} (last acked frame ends at {acked_size}); recorded "
          f"{len(expected)} acked fragment sets")
    return 0


def replay(directory: Path) -> int:
    from repro.search.distributed import ShardedSearchService
    from repro.search.frontend import ServingFrontend

    meta = json.loads((directory / "expected_acked.json").read_text())
    svc = ShardedSearchService.restore(directory)
    replayed = sum(ix.last_wal_replay["records"] for ix in svc.indexers)
    frontend = ServingFrontend(svc)
    failures = []
    if replayed == 0:
        failures.append("restore replayed no WAL records")
    # replay must have truncated the torn frame back to the acked prefix
    healed_size = (directory / meta["torn_tail"]).stat().st_size
    if healed_size != meta["acked_size"]:
        failures.append(
            f"torn tail not truncated to acked prefix: {healed_size} != "
            f"{meta['acked_size']} (crashed at {meta['torn_size']})"
        )
    for q, want in meta["fragments"].items():
        if _fragments(frontend.search(q, top_k=64)) != [tuple(f) for f in want]:
            failures.append(f"acked fragments diverged for {q!r}")
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    if not failures:
        print(f"fresh process replayed {replayed} WAL record(s), truncated "
              f"the torn tail, and reproduced {len(meta['fragments'])} acked "
              f"fragment sets exactly (§18.2 zero data loss)")
    return 1 if failures else 0


def main() -> int:
    modes = {"save": save, "check": check, "crash": crash, "replay": replay}
    if len(sys.argv) != 3 or sys.argv[1] not in modes:
        print(__doc__, file=sys.stderr)
        return 2
    return modes[sys.argv[1]](Path(sys.argv[2]))


if __name__ == "__main__":
    sys.exit(main())
