"""CI persistence smoke: snapshot in one process, restore in another,
re-serve the golden §10.1–10.2 queries (DESIGN.md §12).

Two subcommands, run as SEPARATE processes so the restore can share
nothing with the build (the restart the durable store exists for):

    PYTHONPATH=src python tools/persistence_smoke.py save <dir>
    PYTHONPATH=src python tools/persistence_smoke.py check <dir>

``save`` builds the paper's example corpus + a Zipf tail incrementally
(commits across generations, one delete), snapshots a sharded service into
``<dir>``, and records every golden query's exact fragment set in
``<dir>/expected.json``.  ``check`` restores the service from disk in a
fresh process, re-serves the same queries through the frontend AND the raw
engines, and exits non-zero unless the fragment sets are identical — the
§12 exactness contract, enforced end to end across a process boundary.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

# the golden §10.1–10.2 queries of tests/test_golden.py plus the §12
# duplicate-lemma running example
GOLDEN_QUERIES = ("who are you", "who are you who", "to be or not to be")


def _fragments(resp) -> list:
    return sorted((d.doc_id, f.start, f.end) for d in resp.docs for f in d.fragments)


def _build_service():
    from repro.index import DocumentStore, PAPER_EXAMPLE_DOCS
    from repro.index.corpus import synthesize_corpus
    from repro.search.distributed import ShardedSearchService

    tail = synthesize_corpus(n_docs=40, doc_len=80, vocab_size=800, seed=29)
    store = DocumentStore.from_texts(
        list(PAPER_EXAMPLE_DOCS) + [d.text for d in tail.documents]
    )
    svc = ShardedSearchService(
        store, n_shards=2, sw_count=60, fu_count=150, incremental=True
    )
    svc.add_documents(["who is who in the world of war, who are you"])
    svc.commit()
    svc.delete_document(3)
    return svc


def save(directory: Path) -> int:
    from repro.search.frontend import ServingFrontend

    svc = _build_service()
    frontend = ServingFrontend(svc)
    expected = {
        q: _fragments(frontend.search(q, top_k=64)) for q in GOLDEN_QUERIES
    }
    svc.snapshot(directory)
    (directory / "expected.json").write_text(json.dumps(expected, indent=1))
    print(f"saved service snapshot + {len(expected)} golden fragment sets "
          f"to {directory}")
    return 0


def check(directory: Path) -> int:
    from repro.search.distributed import ShardedSearchService
    from repro.search.frontend import ServingFrontend

    expected = json.loads((directory / "expected.json").read_text())
    frontend = ServingFrontend.from_snapshot(directory)
    svc = ShardedSearchService.restore(directory)
    failures = []
    for q, want in expected.items():
        want = [tuple(f) for f in want]
        got_frontend = _fragments(frontend.search(q, top_k=64))
        got_raw = _fragments(svc.search(q, top_k=64))
        if got_frontend != want:
            failures.append(f"frontend fragments diverged for {q!r}")
        if got_raw != want:
            failures.append(f"raw-engine fragments diverged for {q!r}")
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    if not failures:
        print(f"restored service reproduced {len(expected)} golden fragment "
              f"sets exactly (fresh process, mmap warm start)")
    return 1 if failures else 0


def main() -> int:
    if len(sys.argv) != 3 or sys.argv[1] not in ("save", "check"):
        print(__doc__, file=sys.stderr)
        return 2
    directory = Path(sys.argv[2])
    return save(directory) if sys.argv[1] == "save" else check(directory)


if __name__ == "__main__":
    sys.exit(main())
