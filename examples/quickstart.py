"""Quickstart: build a corpus, index it, run proximity queries (SE2.4),
keep the index fresh with incremental ingest / delete / compact, then make
it durable with snapshot/restore (DESIGN.md §12).

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile
import time

from repro.index import IncrementalIndexer, build_indexes, synthesize_corpus
from repro.search.engine import SearchEngine

# 1) corpus: Zipf-distributed synthetic text + the paper's example phrases
store = synthesize_corpus(n_docs=120, doc_len=200, seed=42)
print(f"corpus: {len(store)} documents, {store.total_positions():,} positions")

# 2) indexes (§3): ordinary + NSW, (w,v) pairs, (f,s,t) stop-lemma triples
index = build_indexes(store, sw_count=80, fu_count=250, max_distance=5)
sizes = index.size_bytes()
print(f"index: {len(index.triple):,} three-component keys, "
      f"{sizes['total'] / 1e6:.1f} MB total "
      f"(triple={sizes['triple'] / 1e6:.1f} MB)")

# 3) search with the paper's Combiner algorithm (SE2.4)
engine = SearchEngine(index, algorithm="se2.4")
for query in ["who are you who", "to be or not to be", "how to find the mean"]:
    resp = engine.search(query, top_k=3)
    print(f"\nquery {query!r}: {resp.stats.postings_read} postings read, "
          f"{resp.stats.results} fragments, "
          f"{resp.stats.elapsed_sec * 1000:.1f} ms")
    for doc in resp.docs:
        frags = ", ".join(f"[{f.start}..{f.end}]" for f in doc.fragments[:3])
        words = store.documents[doc.doc_id].text.split()
        f0 = doc.fragments[0]
        snippet = " ".join(words[f0.start : f0.end + 1])
        print(f"  doc {doc.doc_id:4d}  score={doc.score:.4f}  {frags}")
        print(f"       ...{snippet}...")

# 4) incremental construction: ingest in batches, delete, compact — the
#    SAME engine keeps serving the live multi-segment view throughout
print("\n-- incremental ingest --")
indexer = IncrementalIndexer(sw_count=80, fu_count=250, max_distance=5,
                             lemmatizer=store.lemmatizer)
live = SearchEngine(indexer, lemmatizer=store.lemmatizer, algorithm="se2.4")
texts = [d.text for d in store.documents]
for start in range(0, len(texts), 40):
    indexer.add_documents(texts[start : start + 40])
    report = indexer.commit()
    hits = live.search("who are you who", top_k=1)
    print(f"gen {indexer.generation}: +{report['new_docs']} docs "
          f"(re-keyed {report['rekeyed_docs']} for FL drift, "
          f"{report['segments']} segments) -> "
          f"{hits.stats.results} fragments live")

doomed = next(iter(indexer.documents))
indexer.delete_document(doomed)  # tombstone: visible immediately
report = indexer.compact(memory_budget_bytes=32 << 20)
print(f"deleted doc {doomed}, compacted to {report['segments']} segment(s), "
      f"collected {report['collected']} tombstone(s)")
print(f"post-compact: {live.search('who are you who', top_k=1).stats.results} "
      f"fragments live")

# 5) durability (DESIGN.md §12): snapshot to disk, restore as a warm start —
#    mmap-backed, nothing replayed or re-lemmatized, byte-identical results
print("\n-- snapshot / restore --")
with tempfile.TemporaryDirectory() as snap_dir:
    t0 = time.perf_counter()
    path = indexer.snapshot(snap_dir)
    print(f"snapshot -> {path.name} in {(time.perf_counter() - t0) * 1000:.0f} ms")
    t0 = time.perf_counter()
    restored = IncrementalIndexer.restore(snap_dir, lemmatizer=store.lemmatizer)
    warm = SearchEngine(restored, lemmatizer=store.lemmatizer, algorithm="se2.4")
    hits = warm.search("who are you who", top_k=1)
    print(f"restored + first query in {(time.perf_counter() - t0) * 1000:.0f} ms "
          f"(warm start, {hits.stats.results} fragments — same as live), "
          f"token {restored.generation_token}")
    restored.add_documents(["the restored index keeps indexing new text"])
    restored.commit()
    print(f"post-restore commit: generation {restored.generation}, "
          f"{len(restored.documents)} docs")
