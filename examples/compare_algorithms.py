"""Side-by-side run of SE1 / SE2.1–SE2.4 on one duplicate-heavy query —
the §12 comparison, reproduced interactively.

    PYTHONPATH=src python examples/compare_algorithms.py [query...]
"""

import sys
import time

from repro.core.keys import expand_subqueries, select_keys
from repro.core.lemma import Lemmatizer
from repro.index import build_indexes, synthesize_corpus
from repro.search.engine import ALGORITHMS

query = " ".join(sys.argv[1:]) or "to be or not to be"

store = synthesize_corpus(n_docs=150, doc_len=220, seed=13)
index = build_indexes(store, sw_count=80, fu_count=300, max_distance=5)
lem = Lemmatizer()
sub = expand_subqueries(query, lem)[0]
keys = select_keys(sub, index.fl)

print(f"query: {query!r}")
print(f"subquery lemmas: {list(sub.lemmas)}")
print("selected keys (§6):")
for k in keys:
    comps = ", ".join(c + ("*" if s else "") for c, s in zip(k.components, k.starred))
    print(f"  ({comps})")
print()
print(f"{'algorithm':10s} {'ms':>8s} {'postings':>9s} {'intermediate':>13s} {'results':>8s}")
for name, fn in ALGORITHMS.items():
    t0 = time.perf_counter()
    results, stats = fn(sub, index)
    ms = (time.perf_counter() - t0) * 1000
    print(f"{name:10s} {ms:8.2f} {stats.postings_read:9d} "
          f"{stats.intermediate_records:13d} {len(results):8d}")
print("\nSE2.4 = the paper's Combiner: fewest postings, ZERO intermediate records.")
