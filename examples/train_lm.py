"""Train a reduced LM config end to end on CPU with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py [--arch tinyllama-1.1b] [--steps 30]

(The full-scale configs are exercised by the dry-run / real TPU slices via
``python -m repro.launch.train --full-scale``.)
"""

import argparse
import subprocess
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="tinyllama-1.1b")
ap.add_argument("--steps", type=int, default=30)
args = ap.parse_args()

cmd = [
    sys.executable, "-m", "repro.launch.train",
    "--arch", args.arch, "--steps", str(args.steps),
    "--ckpt-dir", "/tmp/repro_ckpt", "--ckpt-every", "10",
]
print("+", " ".join(cmd))
raise SystemExit(subprocess.call(cmd))
