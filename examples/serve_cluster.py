"""End-to-end serving driver (the paper's workload is search serving).

Builds a document-sharded index "cluster", serves a batch of mixed queries
through the Combiner with per-query accounting, compares against the
ordinary-index baseline, and runs a dead-shard degradation drill.

    PYTHONPATH=src python examples/serve_cluster.py
"""

import time

from repro.index import synthesize_corpus
from repro.search.distributed import ShardedSearchService

QUERIES = [
    "who are you who",
    "to be or not to be",
    "what do you do all day",
    "the time of war",
    "how to find the mean",
    "time and time again",
    "who is who in the world of war",
    "i need you",
]

store = synthesize_corpus(n_docs=200, doc_len=220, seed=7)
print(f"corpus: {len(store)} docs; building 8 index shards...")
t0 = time.perf_counter()
svc = ShardedSearchService(store, n_shards=8, sw_count=80, fu_count=250,
                           max_distance=5, algorithm="se2.4")
print(f"built in {time.perf_counter() - t0:.1f}s "
      f"(global FL-list broadcast to all shards)\n")

# ---- serve a batch -----------------------------------------------------
total_ms = total_postings = 0.0
for q in QUERIES:
    resp = svc.search(q, top_k=3)
    total_ms += resp.stats.elapsed_sec * 1000
    total_postings += resp.stats.postings_read
    top = ", ".join(f"doc{d.doc_id}:{d.score:.3f}" for d in resp.docs)
    print(f"  {q!r}: {resp.stats.elapsed_sec*1000:6.1f} ms "
          f"{resp.stats.postings_read:6d} postings  -> {top}")
print(f"\nbatch: {total_ms:.0f} ms total, "
      f"{total_postings / len(QUERIES):.0f} postings/query average")

# ---- baseline comparison ------------------------------------------------
svc_se1 = ShardedSearchService(store, n_shards=8, sw_count=80, fu_count=250,
                               max_distance=5, algorithm="se1")
t0 = time.perf_counter()
p1 = sum(svc_se1.search(q).stats.postings_read for q in QUERIES)
t1 = time.perf_counter() - t0
print(f"SE1 ordinary-index baseline: {t1*1000:.0f} ms, {p1/len(QUERIES):.0f} "
      f"postings/query -> the multi-component keys read "
      f"{p1/max(total_postings,1):.0f}x fewer postings")

# ---- dead-shard drill ----------------------------------------------------
resp_full = svc.search("who are you who", top_k=50)
resp_degraded = svc.search("who are you who", top_k=50, dead_shards=[3])
lost = {d.doc_id for d in resp_full.docs} - {d.doc_id for d in resp_degraded.docs}
print(f"\ndead-shard drill: shard 3 down -> served "
      f"{len(resp_degraded.docs)}/{len(resp_full.docs)} docs "
      f"(lost doc_ids % 8 == 3: {sorted(lost)[:6]}...) — graceful degradation")
