"""End-to-end serving driver (the paper's workload is search serving).

Builds a document-sharded index "cluster", serves a batch of mixed queries
through the FUSED device pipeline — every (query, subquery, shard) work item
packed into ONE device program (scatter -> uint8 cover -> §14 scoring ->
per-query top-k) — compares against the host Combiner loop and the
ordinary-index baseline, and runs a dead-shard degradation drill.

    PYTHONPATH=src python examples/serve_cluster.py
"""

import time

from repro.index import synthesize_corpus
from repro.search import fused
from repro.search.distributed import ShardedSearchService

QUERIES = [
    "who are you who",
    "to be or not to be",
    "what do you do all day",
    "the time of war",
    "how to find the mean",
    "time and time again",
    "who is who in the world of war",
    "i need you",
]

store = synthesize_corpus(n_docs=200, doc_len=220, seed=7)
print(f"corpus: {len(store)} docs; building 8 index shards...")
t0 = time.perf_counter()
svc = ShardedSearchService(store, n_shards=8, sw_count=80, fu_count=250,
                           max_distance=5, algorithm="fused")
print(f"built in {time.perf_counter() - t0:.1f}s "
      f"(global FL-list broadcast to all shards)\n")

# ---- serve the batch: ONE device program for 8 queries x subqueries x 8 shards
fused.reset_dispatch_count()
svc.search_batch(QUERIES, top_k=3)  # warm the jit cache (fixed shape budgets)
fused.reset_dispatch_count()
t0 = time.perf_counter()
resps = svc.search_batch(QUERIES, top_k=3)
batch_ms = (time.perf_counter() - t0) * 1000
total_postings = 0.0
for q, resp in zip(QUERIES, resps):
    total_postings += resp.stats.postings_read
    top = ", ".join(f"doc{d.doc_id}:{d.score:.3f}" for d in resp.docs)
    print(f"  {q!r}: {resp.stats.postings_read:6d} postings  -> {top}")
print(f"\nfused batch: {batch_ms:.0f} ms total, "
      f"{fused.dispatch_count()} device dispatch(es) for {len(QUERIES)} queries, "
      f"{total_postings / len(QUERIES):.0f} postings/query average")

# ---- host Combiner loop (the old per-subquery-per-shard serving path) ----
svc_host = ShardedSearchService(store, n_shards=8, sw_count=80, fu_count=250,
                                max_distance=5, algorithm="se2.4")
t0 = time.perf_counter()
for q in QUERIES:
    svc_host.search(q, top_k=3)
host_ms = (time.perf_counter() - t0) * 1000
print(f"host Combiner loop: {host_ms:.0f} ms total "
      f"({host_ms / max(batch_ms, 1e-9):.1f}x the fused batch)")

# ---- baseline comparison ------------------------------------------------
svc_se1 = ShardedSearchService(store, n_shards=8, sw_count=80, fu_count=250,
                               max_distance=5, algorithm="se1")
t0 = time.perf_counter()
p1 = sum(svc_se1.search(q).stats.postings_read for q in QUERIES)
t1 = time.perf_counter() - t0
print(f"SE1 ordinary-index baseline: {t1*1000:.0f} ms, {p1/len(QUERIES):.0f} "
      f"postings/query -> the multi-component keys read "
      f"{p1/max(total_postings,1):.0f}x fewer postings")

# ---- dead-shard drill ----------------------------------------------------
# dead_shards= routes through the §14 resilience layer (hold-down scoped to
# this call): the shard is excluded like a failed one, the response is
# flagged via stats.shards_degraded, and the next call serves it again.
# For injected faults + automatic snapshot recovery see DESIGN.md §14 and
# `python -m repro.launch.serve --chaos-seed`.
resp_full = svc.search("who are you who", top_k=50)
resp_degraded = svc.search("who are you who", top_k=50, dead_shards=[3])
lost = {d.doc_id for d in resp_full.docs} - {d.doc_id for d in resp_degraded.docs}
print(f"\ndead-shard drill: shard 3 down -> served "
      f"{len(resp_degraded.docs)}/{len(resp_full.docs)} docs "
      f"(lost doc_ids % 8 == 3: {sorted(lost)[:6]}...) — graceful degradation")
