"""Device-resident posting arena (DESIGN.md §13): exact fragment equality
with the host-pack path and the se2.4 oracle, transparent fallback under
budget-forced partial residency, generation-keyed invalidation, the Pallas
gather kernel vs its jnp form, descriptor-only host planning, recompile
churn, and the new QueryStats arena counters."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core.combiner import se24_combiner
from repro.core.keys import Subquery, expand_subqueries, select_keys
from repro.core.postings import QueryStats
from repro.index import DocumentStore, build_indexes, synthesize_corpus
from repro.index.incremental import IncrementalIndexer, generation_token
from repro.kernels.gather import ARENA_BLOCK, gather_blocks, gather_blocks_ref
from repro.search import fused
from repro.search.arena import PostingArena, plan_arena_batch
from repro.search.distributed import ShardedSearchService
from repro.search.engine import SearchEngine
from repro.search.frontend import ServingFrontend
from repro.search.vectorized import VectorizedEngine

QUERIES = [
    "who are you who",
    "to be or not to be",
    "what do you do all day",
    "the time of war",
    "i need you",
]


def _residency(idx, arena=None):
    arena = arena or PostingArena()
    return arena, {id(idx): arena.acquire(idx, generation_token(idx))}


def _work(queries, idx, lemmatizer):
    return [[(sub, idx) for sub in expand_subqueries(q, lemmatizer)] for q in queries]


# ---------------------------------------------------------------------------
# the gather kernel: Pallas form == jnp form, exact masking
# ---------------------------------------------------------------------------


def test_gather_blocks_kernel_equals_ref():
    rng = np.random.default_rng(0)
    arena = jnp.asarray(rng.integers(0, 1000, (8 * ARENA_BLOCK, 2)).astype(np.int32))
    src = jnp.asarray(np.array([3, 0, 7, 7], np.int32))
    nv = jnp.asarray(np.array([ARENA_BLOCK, 5, 0, 128], np.int32))
    k = np.asarray(gather_blocks(arena, src, nv))
    r = np.asarray(gather_blocks_ref(arena, src, nv))
    np.testing.assert_array_equal(k, r)
    # masking: rows past n_valid are the -1 sentinel, live rows are copies
    np.testing.assert_array_equal(
        k[: ARENA_BLOCK], np.asarray(arena)[3 * ARENA_BLOCK : 4 * ARENA_BLOCK]
    )
    assert (k[ARENA_BLOCK + 5 : 2 * ARENA_BLOCK] == -1).all()
    assert (k[2 * ARENA_BLOCK : 3 * ARENA_BLOCK] == -1).all()


# ---------------------------------------------------------------------------
# exact fragment equality: arena == host pack == se2.4 oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernel", [False, True])
def test_arena_equals_host_pack_and_oracle(small_index, lemmatizer, use_kernel):
    work = _work(QUERIES, small_index, lemmatizer)
    host = fused.serve_query_batch(work, max_distance=small_index.max_distance)
    _, res = _residency(small_index)
    fused.reset_dispatch_count()
    got = fused.serve_query_batch(
        work,
        max_distance=small_index.max_distance,
        residencies=res,
        use_kernel=use_kernel,
    )
    assert fused.dispatch_count() == 1, "fully resident batch = ONE dispatch"
    for qi, (subs, frags) in enumerate(zip(work, got.per_query)):
        assert set(frags) == set(host.per_query[qi])
        oracle = set()
        for sub, _ in subs:
            r, _ = se24_combiner(sub, small_index)
            oracle.update(r)
        assert set(frags) == oracle


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_arena_random_corpora_random_subqueries(seed):
    """Random Zipf corpora + duplicate-lemma subqueries: the arena program's
    on-device dedup/Step-1/Step-2/cover reproduce the scalar Combiner."""
    rng = np.random.default_rng(seed)
    vocab = [f"w{i}" for i in range(15)]
    probs = np.array([1 / (i + 1) ** 1.1 for i in range(15)])
    probs /= probs.sum()
    texts = [" ".join(rng.choice(vocab, size=60, p=probs)) for _ in range(8)]
    store = DocumentStore.from_texts(texts)
    idx = build_indexes(store, sw_count=10_000, fu_count=0, max_distance=4)
    subs = [
        Subquery(tuple(rng.choice(vocab[:6], size=int(rng.integers(1, 5)), replace=True)))
        for _ in range(3)
    ]
    _, res = _residency(idx)
    got = fused.serve_query_batch(
        [[(s, idx)] for s in subs], max_distance=4, residencies=res
    )
    for sub, frags in zip(subs, got.per_query):
        expected, _ = se24_combiner(sub, idx)
        assert set(frags) == set(expected)


def test_vectorized_engine_arena_equals_plain(small_index, lemmatizer):
    batch = [expand_subqueries(q, lemmatizer) for q in QUERIES]
    plain = VectorizedEngine(small_index)
    arena_eng = VectorizedEngine(small_index, arena=PostingArena())
    r0, _ = plain.search_query_batch(batch)
    r1, s1 = arena_eng.search_query_batch(batch)
    for a, b in zip(r0.per_query, r1.per_query):
        assert set(a) == set(b)
    assert s1.device_dispatches == 1


def test_sharded_service_arena_with_dead_shards(small_corpus):
    svc_a = ShardedSearchService(
        small_corpus, n_shards=4, sw_count=60, fu_count=150,
        algorithm="fused", arena=PostingArena(),
    )
    svc_h = ShardedSearchService(
        small_corpus, n_shards=4, sw_count=60, fu_count=150, algorithm="fused"
    )
    for dead in ((), (1,), (0, 3)):
        fused.reset_dispatch_count()
        ra = svc_a.search_batch(QUERIES[:3], top_k=32, dead_shards=dead)
        assert fused.dispatch_count() == 1
        rh = svc_h.search_batch(QUERIES[:3], top_k=32, dead_shards=dead)
        for a, h in zip(ra, rh):
            fa = {(d.doc_id, f.start, f.end) for d in a.docs for f in d.fragments}
            fh = {(d.doc_id, f.start, f.end) for d in h.docs for f in d.fragments}
            assert fa == fh


# ---------------------------------------------------------------------------
# descriptor planning: no posting reads, provably-empty short-circuits
# ---------------------------------------------------------------------------


def test_arena_plan_is_descriptor_only(small_index, lemmatizer):
    """Arena planning must not touch posting data: stats count the same
    §11 postings the host pack reads, but from upload-time extents."""
    arena, res = _residency(small_index)
    work = _work(QUERIES[:2], small_index, lemmatizer)
    host_stats = QueryStats()
    fused.plan_query_batch(work, stats=host_stats)
    arena_stats = QueryStats()
    fused.serve_query_batch(
        work,
        max_distance=small_index.max_distance,
        residencies=res,
        stats=arena_stats,
    )
    assert arena_stats.postings_read == host_stats.postings_read
    assert arena_stats.bytes_read == host_stats.bytes_read
    assert arena_stats.arena_hits > 0
    assert arena_stats.arena_misses == 0


def test_arena_empty_subquery_short_circuits(small_index):
    arena, res = _residency(small_index)
    stats = QueryStats()
    fused.reset_dispatch_count()
    got = fused.serve_query_batch(
        [[(Subquery(("zzzunknown", "qqqmissing")), small_index)]],
        max_distance=small_index.max_distance,
        residencies=res,
        stats=stats,
    )
    assert got.per_query == [[]]
    assert fused.dispatch_count() == 0
    assert stats.empty_subqueries == 1


def test_arena_stats_fields_merge():
    a, b = QueryStats(), QueryStats()
    a.arena_hits, a.arena_misses, a.h2d_bytes = 2, 1, 100
    b.arena_hits, b.arena_misses, b.h2d_bytes = 3, 4, 50
    a.merge(b)
    assert (a.arena_hits, a.arena_misses, a.h2d_bytes) == (5, 5, 150)


# ---------------------------------------------------------------------------
# residency: LRU budget, partial fallback, generation invalidation
# ---------------------------------------------------------------------------


def test_budget_forced_partial_residency_still_exact(small_index, lemmatizer):
    """A budget too small for every family leaves some non-resident; their
    work items fall back to the host pack and fragments stay identical."""
    work = _work(QUERIES, small_index, lemmatizer)
    host = fused.serve_query_batch(work, max_distance=small_index.max_distance)
    full = PostingArena()
    full.acquire(small_index, 0)
    sizes = sorted(fb.nbytes for fb in full._entries.values())
    # room for roughly half the families
    arena = PostingArena(budget_bytes=sum(sizes[:2]) + 1)
    res = {id(small_index): arena.acquire(small_index, 0)}
    assert 0 < len(arena) < 4, "budget must force PARTIAL residency"
    stats = QueryStats()
    got = fused.serve_query_batch(
        work,
        max_distance=small_index.max_distance,
        residencies=res,
        stats=stats,
    )
    for a, b in zip(got.per_query, host.per_query):
        assert set(a) == set(b)
    assert stats.arena_misses > 0, "non-resident keys must fall back"


def test_generation_bump_evicts_stale_buffers(lemmatizer):
    ix = IncrementalIndexer(sw_count=30, fu_count=60, max_distance=5,
                            lemmatizer=lemmatizer)
    ix.add_documents(["who are you who and what do you do", "to be or not to be"])
    ix.commit()
    arena = PostingArena()
    arena.attach(ix)
    arena.acquire(ix.index, ix.generation_token)
    assert len(arena) == 4
    tok0 = ix.generation_token
    ix.add_documents(["the time of war and the world of war"])
    ix.commit()  # mutation hook fires: stale-token entries evicted eagerly
    assert len(arena) == 0
    assert arena.evictions == 4
    assert ix.generation_token != tok0
    # re-acquiring under the new token serves the NEW live view exactly
    res = {id(ix.index): arena.acquire(ix.index, ix.generation_token)}
    work = _work(QUERIES[:2], ix.index, lemmatizer)
    got = fused.serve_query_batch(work, max_distance=5, residencies=res)
    host = fused.serve_query_batch(work, max_distance=5)
    for a, b in zip(got.per_query, host.per_query):
        assert set(a) == set(b)


def test_frontend_arena_equals_plain_after_mutations(lemmatizer):
    store = synthesize_corpus(n_docs=40, doc_len=80, vocab_size=500, seed=3)
    ix = IncrementalIndexer(sw_count=60, fu_count=120, max_distance=5,
                            lemmatizer=store.lemmatizer)
    ix.add_documents([d.text for d in store.documents[:20]])
    ix.commit()
    fa = ServingFrontend(ix, lemmatizer=store.lemmatizer, arena_budget_mb=256)
    fh = ServingFrontend(ix, lemmatizer=store.lemmatizer)

    def frag_set(resp):
        return {(d.doc_id, f.start, f.end) for d in resp.docs for f in d.fragments}

    for q in QUERIES[:3]:
        assert frag_set(fa.search(q, top_k=32)) == frag_set(fh.search(q, top_k=32))
    ix.add_documents([d.text for d in store.documents[20:]])
    ix.commit()
    ix.delete_document(sorted(ix.documents)[0])
    for q in QUERIES[:3]:
        assert frag_set(fa.search(q, top_k=32)) == frag_set(fh.search(q, top_k=32))
    ix.compact()
    for q in QUERIES[:3]:
        assert frag_set(fa.search(q, top_k=32)) == frag_set(fh.search(q, top_k=32))
    m = fa.metrics()
    assert m["arena_entries"] > 0
    assert m["arena_hits"] > 0


def test_overflow_falls_back_without_double_counting(lemmatizer):
    """Doc ids beyond the int32 composite budget raise ArenaOverflow at
    plan time; the batch must fall back to the host pack with fragments
    intact and the §11 postings accounting charged exactly ONCE."""
    ix = IncrementalIndexer(sw_count=30, fu_count=60, max_distance=5,
                            lemmatizer=lemmatizer)
    ix.add_documents(
        ["who are you who and what do you do", "to be or not to be"],
        doc_ids=[7, 2**28],  # wide doc-id space: composite bits overflow
    )
    ix.commit()
    view = ix.index
    work = _work(QUERIES[:2], view, lemmatizer)
    host_stats = QueryStats()
    host = fused.serve_query_batch(work, max_distance=5, stats=host_stats)
    arena = PostingArena()
    res = {id(view): arena.acquire(view, ix.generation_token)}
    stats = QueryStats()
    got = fused.serve_query_batch(
        work, max_distance=5, residencies=res, stats=stats
    )
    for a, b in zip(got.per_query, host.per_query):
        assert set(a) == set(b)
    assert stats.postings_read == host_stats.postings_read, "no double charge"
    assert stats.arena_hits == 0, "overflow fallback served nothing on device"
    assert stats.arena_misses > 0, "the fallback must be observable per query"


def test_shared_arena_keeps_sources_apart(lemmatizer):
    """One arena shared by two index sources with EQUAL generation tokens
    (every plain IndexSet has token 0) must never serve one corpus's
    buffers for the other's queries."""
    s1 = DocumentStore.from_texts(["who are you who", "to be or not to be"])
    s2 = DocumentStore.from_texts(["you who you who you", "not to be who you"])
    i1 = build_indexes(s1, sw_count=100, fu_count=0, max_distance=5)
    i2 = build_indexes(s2, sw_count=100, fu_count=0, max_distance=5)
    arena = PostingArena()
    r1 = {id(i1): arena.acquire(i1, generation_token(i1))}
    r2 = {id(i2): arena.acquire(i2, generation_token(i2))}
    for idx, res in ((i1, r1), (i2, r2)):
        for q in ("who are you who", "to be or not to be"):
            for sub in expand_subqueries(q, lemmatizer):
                got = fused.serve_query_batch(
                    [[(sub, idx)]], max_distance=5, residencies=res
                )
                exp, _ = se24_combiner(sub, idx)
                assert set(got.per_query[0]) == set(exp), (q, sub.lemmas)


def test_attach_eviction_spares_other_sources(lemmatizer, small_index):
    """A commit on the attached source evicts only ITS stale-token buffers;
    a shared arena's entries for an unrelated static index survive."""
    ix = IncrementalIndexer(sw_count=30, fu_count=60, max_distance=5,
                            lemmatizer=lemmatizer)
    ix.add_documents(["who are you who and what do you do"])
    ix.commit()
    arena = PostingArena(budget_bytes=1 << 30)
    arena.attach(ix)
    arena.acquire(ix.index, ix.generation_token)
    arena.acquire(small_index, generation_token(small_index))  # token 0
    n_total = len(arena)
    ix.add_documents(["to be or not to be"])
    ix.commit()  # must evict ONLY ix's stale generation (4 families)
    assert len(arena) == n_total - 4
    assert (
        arena.acquire(small_index, generation_token(small_index)).families
    ), "the static source's buffers must survive the other source's commit"


def test_detach_removes_mutation_listeners(lemmatizer):
    ix = IncrementalIndexer(sw_count=30, fu_count=60, max_distance=5,
                            lemmatizer=lemmatizer)
    ix.add_documents(["who are you who"])
    ix.commit()
    arena = PostingArena()
    arena.attach(ix)
    assert len(ix._listeners) == 1
    arena.detach()
    arena.detach()  # idempotent
    assert ix._listeners == []
    arena.acquire(ix.index, ix.generation_token)
    n = len(arena)
    ix.add_documents(["to be or not to be"])
    ix.commit()  # detached: no eager eviction (entries age out by LRU)
    assert len(arena) == n


# ---------------------------------------------------------------------------
# recompile churn: identical bucketed budgets reuse ONE compiled program
# ---------------------------------------------------------------------------


def test_no_recompile_for_identical_bucketed_batches(small_index, lemmatizer):
    if fused.compile_count() is None:
        pytest.skip("jax version exposes no jit cache introspection")
    arena, res = _residency(small_index)
    serve = lambda qs: fused.serve_query_batch(
        _work(qs, small_index, lemmatizer),
        max_distance=small_index.max_distance,
        residencies=res,
    )
    serve(QUERIES[:2])  # compile the bucket
    before = fused.compile_count()
    # different batch content, identical bucketed budgets: reversed query
    # order repacks every descriptor but leaves all pow2 budgets unchanged
    serve(list(reversed(QUERIES[:2])))
    assert fused.compile_count() == before, (
        "identically-bucketed batches must reuse one compiled program"
    )


def test_frontend_warmup_precompiles(small_index, lemmatizer):
    if fused.compile_count() is None:
        pytest.skip("jax version exposes no jit cache introspection")
    frontend = ServingFrontend(
        small_index, lemmatizer=lemmatizer, arena_budget_mb=256
    )
    # warm ONE query at the top_k real requests will use: a single-request
    # serve then hits the same bucketed budgets AND static top_k
    report = frontend.warmup(queries=[QUERIES[0]], top_k=16)
    assert report["programs"] >= 1 and report["seconds"] > 0
    before = fused.compile_count()
    frontend.search(QUERIES[0], top_k=16)  # same buckets as the warmed query
    assert fused.compile_count() == before, "warmed traffic must not compile"


# ---------------------------------------------------------------------------
# the slot-stream upload: extents carry exact §11 accounting statistics
# ---------------------------------------------------------------------------


def test_key_extents_match_raw_postings(small_index):
    arena = PostingArena()
    res = arena.acquire(small_index, 0)
    checked = 0
    for fname in ("stop_single", "stop_pair", "pair", "triple"):
        mapping = getattr(small_index, fname)
        for key in list(mapping)[:5]:
            ext = res.lookup(key if isinstance(key, tuple) else (key,))
            rows = np.asarray(mapping[key])
            assert ext is not None and ext.n_rows == len(rows)
            assert ext.n_docs == len(np.unique(rows[:, 0]))
            assert ext.max_doc == int(rows[:, 0].max())
            # slot streams hold the sorted-unique (doc, pos) pairs per slot
            for s, se in enumerate(ext.slots):
                pos = rows[:, 1] if s == 0 else rows[:, 1] + rows[:, 1 + s]
                uniq = np.unique(rows[:, 0].astype(np.int64) * (1 << 32) + pos)
                assert se.n_events == len(uniq)
                assert se.max_pos == int(pos.max())
            checked += 1
    assert checked > 0
