"""Checkpointing, fault tolerance, elastic topology, data determinism."""

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.data.pipeline import LMTokenPipeline, RecsysBatchPipeline
from repro.data.sampler import NeighborSampler, random_graph
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime import ElasticTopology, RestartPolicy, StragglerMonitor, run_with_restarts


def test_checkpoint_roundtrip(tmp_path):
    payload = {"a": np.arange(6).reshape(2, 3), "b": [np.float32(1.5), np.ones(4)]}
    save_checkpoint(tmp_path, 3, payload)
    restored, step = restore_checkpoint(tmp_path, payload)
    assert step == 3
    np.testing.assert_array_equal(restored["a"], payload["a"])
    np.testing.assert_array_equal(restored["b"][1], payload["b"][1])


def test_checkpoint_retention_and_latest(tmp_path):
    for s in [1, 2, 3, 4, 5]:
        save_checkpoint(tmp_path, s, {"x": np.array([s])}, keep=2)
    restored, step = restore_checkpoint(tmp_path, {"x": np.array([0])})
    assert step == 5
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2  # retention


def test_checkpoint_atomicity_tmp_never_restored(tmp_path):
    save_checkpoint(tmp_path, 1, {"x": np.array([1])})
    # a crashed write leaves a .tmp dir that must be ignored
    (tmp_path / "step_9.tmp").mkdir()
    restored, step = restore_checkpoint(tmp_path, {"x": np.array([0])})
    assert step == 1


def test_async_manager(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(1, {"w": np.ones(8)})
    mgr.wait()
    out = mgr.restore_latest({"w": np.zeros(8)})
    assert out is not None and out[1] == 1


def test_run_with_restarts_recovers_and_is_deterministic(tmp_path):
    """Inject a crash at step 7; the run must resume from the checkpoint and
    produce the same final state as an uninterrupted run."""

    def make_state():
        return {"acc": np.zeros(4), "pipe": np.int64(0)}

    def make_step(crash_once):
        crashed = {"done": False}

        def step(state, i):
            if crash_once and i == 7 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("injected pod failure")
            rng = np.random.default_rng(int(state["pipe"]))
            return {
                "acc": state["acc"] + rng.normal(size=4),
                "pipe": state["pipe"] + 1,
            }

        return step

    mgr = CheckpointManager(tmp_path / "a")
    final, stats = run_with_restarts(
        make_state, make_step(True), n_steps=12, manager=mgr,
        policy=RestartPolicy(min_backoff_s=0.0), checkpoint_every=5,
    )
    assert stats["restarts"] == 1 and stats["recovered_from"] == [5]

    mgr2 = CheckpointManager(tmp_path / "b")
    clean, _ = run_with_restarts(
        make_state, make_step(False), n_steps=12, manager=mgr2,
        checkpoint_every=5,
    )
    np.testing.assert_allclose(final["acc"], clean["acc"])  # bit-identical


def test_straggler_monitor():
    mon = StragglerMonitor(n_workers=8, window=10, mad_threshold=4.0)
    rng = np.random.default_rng(0)
    for _ in range(10):
        for w in range(8):
            t = 1.0 + rng.normal(0, 0.02)
            if w == 5:
                t *= 3.0  # persistent straggler
            mon.record(w, t)
    assert mon.stragglers() == [5]


def test_elastic_topology_plan():
    topo = ElasticTopology(chips_per_pod=256, model_parallel=16, global_batch=256)
    p2 = topo.plan(2)
    assert p2["mesh_shape"] == (2, 16, 16) and p2["chips"] == 512
    p1 = topo.plan(1)
    assert p1["mesh_shape"] == (16, 16)
    with pytest.raises(RuntimeError):
        topo.plan(0)


def test_pipeline_determinism():
    a = LMTokenPipeline(vocab=100, seq_len=16, batch=4, seed=9)
    b = LMTokenPipeline(vocab=100, seq_len=16, batch=4, seed=9)
    for _ in range(3):
        x, y = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    # resuming from a cursor replays identically
    c = LMTokenPipeline(vocab=100, seq_len=16, batch=4, seed=9)
    c.state.step = a.state.step
    np.testing.assert_array_equal(a.next_batch()["tokens"], c.next_batch()["tokens"])


def test_recsys_pipeline_fields():
    p = RecsysBatchPipeline(field_vocab=(50, 20, 10), batch=8, n_dense=3)
    b = p.next_batch()
    assert b["sparse_ids"].shape == (8, 3)
    assert (b["sparse_ids"] < np.array([50, 20, 10])).all()
    assert b["dense"].shape == (8, 3)


def test_neighbor_sampler_static_shapes_and_validity():
    g = random_graph(500, avg_degree=6, d_feat=8, n_classes=5, seed=1)
    s = NeighborSampler(g, batch_nodes=16, fanout=(4, 3), seed=2)
    out1 = s.sample()
    out2 = s.sample()
    assert out1["x"].shape == out2["x"].shape == (s.max_nodes, 8)
    assert out1["src"].shape == (s.max_edges,)
    n_real = int(out1["n_real_nodes"])
    e_real = int(out1["n_real_edges"])
    assert 16 <= n_real <= s.max_nodes
    assert (out1["src"][:e_real] < n_real).all()
    assert (out1["dst"][:e_real] < n_real).all()
    assert out1["label_mask"].sum() == 16  # loss only on seeds


def test_adamw_converges_on_quadratic():
    import jax
    import jax.numpy as jnp

    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, cfg)
    assert float(loss(params)) < 1e-3


@pytest.mark.skipif(
    not hasattr(__import__("jax"), "shard_map"),
    reason="subprocess uses jax.shard_map (jax >= 0.6); not available here",
)
def test_grad_compression_error_feedback_subprocess():
    """int8 compressed psum with error feedback: mean of shard gradients is
    recovered to within quantization noise, and residuals carry over."""
    import subprocess, sys, os

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.optim.grad_compression import compressed_psum
mesh = jax.make_mesh((4,), ("pod",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)  # per-shard grads
err = jnp.zeros((4, 64), jnp.float32)
def f(g, e):
    return compressed_psum(g, e, "pod")
out, new_err = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                  out_specs=(P("pod"), P("pod")), check_vma=False))(g, err)
mean = np.asarray(g).mean(axis=0)
got = np.asarray(out)[0]
rel = np.abs(got - mean).max() / (np.abs(mean).max() + 1e-9)
# one-shot int8+mean-scale reconstruction is coarse; error feedback carries
# the residual into the next step (the convergence guarantee), so a single
# round only needs to be in the right ballpark
assert rel < 0.3, rel
assert np.abs(np.asarray(new_err)).max() > 0  # residual captured
print("COMPRESS_OK", rel)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "COMPRESS_OK" in r.stdout, r.stdout + r.stderr


import os  # noqa: E402  (used by the subprocess env above)
