"""Property-based suite for the §17 external-memory (SPIMI) bulk ingest.

Three layers of the pipeline are pinned against their in-RAM oracles over
the shared ``tests/strategies`` corpora (runs under real ``hypothesis`` or
the fixed-seed shim alike):

* ``build_segment_fast`` == scalar ``build_segment`` (the vectorized
  candidate builder is a pure reimplementation of §3's per-doc scan);
* ``_write_spill_fast`` == ``write_segment_store(build_segment_fast(...))``
  **byte for byte** — the raw spill writer skips the key->rows dict
  round-trip but must land on the identical §12.1 encoded store;
* ``bulk_build`` over random spill boundaries and worker counts ==
  ``build_indexes`` over the same corpus (``index_sets_equal``, NSW
  included), plus the §17.4 determinism regression: 1 worker vs N workers
  produce byte-identical published snapshot trees.
"""

from __future__ import annotations

import tempfile
from pathlib import Path

import numpy as np

from repro.core.lemma import FLList
from repro.index import DocumentStore, build_indexes, index_sets_equal
from repro.index.builder import build_segment
from repro.index.fastbuild import build_segment_fast
from repro.index.ingest import _write_spill_fast, bulk_build
from repro.index.store import (
    fl_signature,
    load_snapshot,
    open_segment_store,
    write_segment_store,
)
from tests._hypothesis_compat import given, settings, st
from tests.strategies import make_corpus, seeds


def _spec_store(spec):
    store = DocumentStore.from_texts(spec.texts)
    fl = FLList.from_frequencies(
        store.lemma_frequencies(), sw_count=spec.sw_count, fu_count=spec.fu_count
    )
    return store, fl


def _tree_bytes(root: Path) -> dict[str, bytes]:
    return {
        str(p.relative_to(root)): p.read_bytes()
        for p in sorted(Path(root).rglob("*"))
        if p.is_file()
    }


def _assert_trees_identical(a: Path, b: Path, ctx: str) -> None:
    ta, tb = _tree_bytes(a), _tree_bytes(b)
    assert set(ta) == set(tb), (
        f"{ctx}: file sets differ: only-a={sorted(set(ta) - set(tb))} "
        f"only-b={sorted(set(tb) - set(ta))}"
    )
    diff = [k for k in sorted(ta) if ta[k] != tb[k]]
    assert not diff, f"{ctx}: files differ byte-wise: {diff}"


# ---------------------------------------------------------------------------
# layer 1: vectorized builder == scalar builder
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None, derandomize=True)
@given(seeds)
def test_fastbuild_equals_scalar_builder(seed):
    spec = make_corpus(seed, max_docs=8)
    store, fl = _spec_store(spec)
    ref = build_segment(store.documents, fl, max_distance=spec.max_distance)
    fast = build_segment_fast(store.documents, fl, max_distance=spec.max_distance)
    equal, why = index_sets_equal(fast, ref)
    assert equal, f"seed {seed}: {why}"


# ---------------------------------------------------------------------------
# layer 2: raw spill writer == generic store writer, byte for byte
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None, derandomize=True)
@given(seeds)
def test_raw_spill_writer_byte_identical(seed):
    spec = make_corpus(seed, max_docs=8)
    store, fl = _spec_store(spec)
    docs = store.documents
    ids = [d.doc_id for d in docs]
    crc = fl_signature(fl)
    with tempfile.TemporaryDirectory() as td:
        ref_dir, fast_dir = Path(td) / "ref", Path(td) / "fast"
        write_segment_store(
            build_segment_fast(docs, fl, max_distance=spec.max_distance),
            ref_dir,
            fl_crc=crc,
            doc_ids=ids,
        )
        _write_spill_fast(
            docs, fl, fast_dir, fl_crc=crc, doc_ids=ids,
            max_distance=spec.max_distance, build_pair=True,
            build_degenerate=True,
        )
        _assert_trees_identical(ref_dir, fast_dir, f"seed {seed}")
        # the fast store must also round-trip through the verifying reader
        open_segment_store(fast_dir, fl, expect_fl_crc=crc)


def test_raw_spill_writer_empty_and_degenerate(tmp_path):
    """Edge chunks: no candidates at all, a single one-word doc, and a doc
    whose every position carries the same (duplicate) lemma."""
    fl = FLList.from_frequencies({"the": 9, "who": 5, "walk": 2},
                                 sw_count=1, fu_count=1)
    crc = fl_signature(fl)
    cases = {
        "empty": [],
        "single": ["walk"],
        "dup": ["walk walking walked walk walks"],
    }
    for name, texts in cases.items():
        docs = DocumentStore.from_texts(texts).documents
        ids = [d.doc_id for d in docs]
        ref_dir = tmp_path / f"{name}_ref"
        fast_dir = tmp_path / f"{name}_fast"
        write_segment_store(
            build_segment_fast(docs, fl), ref_dir, fl_crc=crc, doc_ids=ids
        )
        _write_spill_fast(docs, fl, fast_dir, fl_crc=crc, doc_ids=ids,
                          max_distance=5, build_pair=True,
                          build_degenerate=True)
        _assert_trees_identical(ref_dir, fast_dir, name)


# ---------------------------------------------------------------------------
# layer 3: end-to-end bulk build == in-RAM build over random spill shapes
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None, derandomize=True)
@given(seeds)
def test_bulk_build_equals_in_ram_build(seed):
    spec = make_corpus(seed, max_docs=10)
    rng = np.random.default_rng(seed ^ 0x5B1711)
    docs_per_spill = int(rng.integers(1, len(spec.texts) + 2))
    with tempfile.TemporaryDirectory() as td:
        bulk_build(
            spec.texts,
            out_dir=td,
            sw_count=spec.sw_count,
            fu_count=spec.fu_count,
            max_distance=spec.max_distance,
            docs_per_spill=docs_per_spill,
        )
        restored = load_snapshot(td)
        ref = build_indexes(
            DocumentStore.from_texts(spec.texts),
            sw_count=spec.sw_count,
            fu_count=spec.fu_count,
            max_distance=spec.max_distance,
        )
        got = restored.index.to_index_set()
        equal, why = index_sets_equal(got, ref)
        assert equal, f"seed {seed} dps={docs_per_spill}: {why}"
        # NSW payloads specifically (ragged offsets survive the disk merge)
        assert set(got.nsw) == set(ref.nsw)


def test_bulk_build_single_doc_and_duplicate_lemma_corpus(tmp_path):
    for name, texts in {
        "single": ["to be or not to be"],
        "dup": ["walk walking walked", "walks walk the walk"],
    }.items():
        out = tmp_path / name
        bulk_build(texts, out_dir=out, sw_count=2, fu_count=2,
                   docs_per_spill=1)
        restored = load_snapshot(out)
        ref = build_indexes(DocumentStore.from_texts(texts),
                            sw_count=2, fu_count=2)
        equal, why = index_sets_equal(restored.index.to_index_set(), ref)
        assert equal, f"{name}: {why}"


# ---------------------------------------------------------------------------
# §17.4 determinism regression: worker count must not leak into the bytes
# ---------------------------------------------------------------------------


def test_bulk_build_worker_count_invariant(tmp_path):
    """1-worker and N-worker builds publish byte-identical snapshot trees —
    exact, not statistical: the §17.4 contract that lets CI compare bulk
    stores across machines."""
    store = DocumentStore.from_texts(make_corpus(1234, max_docs=12).texts)
    texts = [d.text for d in store.documents]
    out1, out2 = tmp_path / "w1", tmp_path / "w2"
    bulk_build(texts, out_dir=out1, sw_count=10, fu_count=20,
               docs_per_spill=3, workers=1)
    bulk_build(texts, out_dir=out2, sw_count=10, fu_count=20,
               docs_per_spill=3, workers=3)
    _assert_trees_identical(out1 / "snap_0", out2 / "snap_0", "workers 1 vs 3")
