"""Integration: the ``paper_search`` device serve_step must reproduce the
host engine's §14 ranking when fed the same postings (clusters == documents).
This ties the dry-run's arch to the paper-faithful implementation."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.keys import expand_subqueries, select_keys
from repro.search.engine import SearchEngine
from repro.search.serving_step import serve_step
from repro.search.vectorized import pack_subquery_events


@pytest.mark.parametrize("query", ["who are you who", "what do you do all day"])
def test_serve_step_matches_engine_ranking(query, small_index, lemmatizer):
    sub = expand_subqueries(query, lemmatizer)[0]
    packed = pack_subquery_events(sub, small_index, doc_len=128)
    n_docs = packed.occ.shape[0]
    L, N = packed.occ.shape[1], packed.occ.shape[2]
    # clusters == documents; postings = occupancy events re-encoded
    events = np.argwhere(packed.occ > 0)  # (doc, lemma, pos)
    P = 1 + len(events)
    postings = np.full((1, P, 3), -1, np.int32)
    for i, (d, l, p) in enumerate(events):
        postings[0, i] = (d, p, l)
    cluster_doc = packed.doc_ids[None].astype(np.int32)
    mult = packed.mult[None]
    out = serve_step(
        jnp.asarray(postings), jnp.asarray(cluster_doc), jnp.asarray(mult),
        max_distance=small_index.max_distance,
        n_clusters=n_docs, window_len=N, top_k=min(8, n_docs),
    )
    top_docs = [int(d) for d in np.asarray(out["top_docs"][0]) if d >= 0]
    top_scores = np.asarray(out["top_scores"][0])

    # engine ranking for the SAME single subquery
    from repro.core.combiner import se24_combiner
    from repro.search.relevance import rank_documents

    results, _ = se24_combiner(sub, small_index)
    ranked = rank_documents(results, top_k=len(top_docs))
    exp_docs = [d for d, _, _ in ranked]
    exp_scores = np.array([s for _, s, _ in ranked])

    k = min(len(exp_docs), len(top_docs))
    assert top_docs[:k] == exp_docs[:k]
    np.testing.assert_allclose(top_scores[:k], exp_scores[:k], rtol=1e-5)


def test_serve_step_fragment_counts(small_index, lemmatizer):
    sub = expand_subqueries("who are you who", lemmatizer)[0]
    packed = pack_subquery_events(sub, small_index, doc_len=128)
    events = np.argwhere(packed.occ > 0)
    postings = np.full((1, len(events) + 1, 3), -1, np.int32)
    for i, (d, l, p) in enumerate(events):
        postings[0, i] = (d, p, l)
    out = serve_step(
        jnp.asarray(postings),
        jnp.asarray(packed.doc_ids[None].astype(np.int32)),
        jnp.asarray(packed.mult[None]),
        max_distance=small_index.max_distance,
        n_clusters=packed.occ.shape[0], window_len=128, top_k=4,
    )
    from repro.core.combiner import se24_combiner

    results, _ = se24_combiner(sub, small_index)
    assert int(out["n_fragments"][0]) == len(results)
