"""Integration: the ``paper_search`` device serve_step must reproduce the
host engine's §14 ranking when fed the same postings (clusters == documents).
This ties the dry-run's arch to the paper-faithful implementation.

The compact event transport (``pack_subquery_events``) emits exactly
serve_step's posting format — (doc_slot, pos, lemma) triples — so the packer
output feeds the device program directly, no re-encoding."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.keys import expand_subqueries, select_keys
from repro.search.engine import SearchEngine
from repro.search.serving_step import serve_step
from repro.search.vectorized import pack_subquery_events


@pytest.mark.parametrize("query", ["who are you who", "what do you do all day"])
def test_serve_step_matches_engine_ranking(query, small_index, lemmatizer):
    sub = expand_subqueries(query, lemmatizer)[0]
    packed = pack_subquery_events(sub, small_index, doc_len=128)
    assert packed is not None
    n_docs = len(packed.doc_ids)
    # clusters == documents; the compact triples ARE serve_step postings
    postings = packed.events[None]
    cluster_doc = packed.doc_ids[None].astype(np.int32)
    mult = packed.mult[None]
    out = serve_step(
        jnp.asarray(postings), jnp.asarray(cluster_doc), jnp.asarray(mult),
        max_distance=small_index.max_distance,
        n_clusters=n_docs, window_len=128, top_k=min(8, n_docs),
    )
    top_docs = [int(d) for d in np.asarray(out["top_docs"][0]) if d >= 0]
    top_scores = np.asarray(out["top_scores"][0])

    # engine ranking for the SAME single subquery
    from repro.core.combiner import se24_combiner
    from repro.search.relevance import rank_documents

    results, _ = se24_combiner(sub, small_index)
    ranked = rank_documents(results, top_k=len(top_docs))
    exp_docs = [d for d, _, _ in ranked]
    exp_scores = np.array([s for _, s, _ in ranked])

    k = min(len(exp_docs), len(top_docs))
    assert top_docs[:k] == exp_docs[:k]
    np.testing.assert_allclose(top_scores[:k], exp_scores[:k], rtol=1e-5)


def test_serve_step_fragment_counts(small_index, lemmatizer):
    sub = expand_subqueries("who are you who", lemmatizer)[0]
    packed = pack_subquery_events(sub, small_index, doc_len=128)
    assert packed is not None
    out = serve_step(
        jnp.asarray(packed.events[None]),
        jnp.asarray(packed.doc_ids[None].astype(np.int32)),
        jnp.asarray(packed.mult[None]),
        max_distance=small_index.max_distance,
        n_clusters=len(packed.doc_ids), window_len=128, top_k=4,
    )
    from repro.core.combiner import se24_combiner

    results, _ = se24_combiner(sub, small_index)
    assert int(out["n_fragments"][0]) == len(results)
