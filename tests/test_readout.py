"""§15 device-side result assembly, phase timers and pipelined dispatch.

Pins the contracts the DESIGN.md §15 refactor introduced:

* **Readout equivalence** — ``readout="device"`` (one fixed-shape D2H copy
  of the §15.1 dense result buffer) returns byte-identical fragments, in
  identical order, to the legacy ``readout="host"`` ``np.nonzero`` + dedup
  path, on the fused AND arena serving paths.
* **Two-tier host dedup** — ``_dedup_fragments`` gives identical output on
  its packed-int64 fast tier and its lexsort fallback, and picks the
  fallback (instead of silently overflowing) when the packed key cannot
  hold the value ranges.
* **Phase-timer schema** — one instrumented batch produces exactly the six
  §15.3 phases, each bracket non-negative and summing to at most the serial
  batch wall time (no double-counting).
* **Deferred dispatch** — ``defer=True`` returns a ``PendingBatch`` whose
  idempotent ``result()`` equals the eager call's result.
* **Pipelined frontend** — the §15.2 two-deep driver returns byte-identical
  responses, in admission order, to the serial submit→finish loop.
"""

import time

import numpy as np
import pytest

from repro.core.keys import expand_subqueries
from repro.core.lemma import Lemmatizer
from repro.index import build_indexes, synthesize_corpus
from repro.search import fused
from repro.search.fused import PendingBatch, _dedup_fragments, serve_query_batch

QUERIES = [
    "who are you who",
    "to be or not to be",
    "what do you do all day",
    "the time of war",
    "i need you",
    "time and time again",
]

PHASE_KEYS = {
    "plan_us", "pack_us", "h2d_us", "dispatch_us", "compute_us", "readout_us",
}


@pytest.fixture(scope="module")
def corpus():
    store = synthesize_corpus(n_docs=60, doc_len=120, vocab_size=500, seed=7)
    idx = build_indexes(store, sw_count=60, fu_count=120, max_distance=5)
    lem = Lemmatizer()
    work = [
        [(sub, idx) for sub in expand_subqueries(q, lem)] for q in QUERIES
    ]
    return store, idx, work


def _result_key(res):
    """Everything a FusedBatchResult exposes, materialized for comparison."""
    return (
        [sorted(p) for p in res.per_query],
        res.top_docs.tolist(),
        np.asarray(res.top_scores).round(6).tolist(),
        res.n_fragments.tolist(),
    )


# ---------------------------------------------------------------------------
# device readout == host readout (fused and arena paths)
# ---------------------------------------------------------------------------


def test_device_readout_equals_host_fused(corpus):
    _, idx, work = corpus
    dev = serve_query_batch(work, max_distance=idx.max_distance, readout="device")
    host = serve_query_batch(work, max_distance=idx.max_distance, readout="host")
    assert _result_key(dev) == _result_key(host)
    # §15.1 buffer order: compacted rows come back sorted, already unique
    for qi in range(dev.n_queries):
        frs = dev.per_query[qi]
        assert frs == sorted(set(frs))
        assert dev.n_results(qi) == len(frs)


def test_device_readout_equals_host_arena(corpus):
    from repro.search.arena import PostingArena

    _, idx, work = corpus
    arena = PostingArena(budget_bytes=1 << 30)
    res = arena.acquire(idx, 0)
    residencies = {id(idx): res}
    try:
        got = {
            mode: serve_query_batch(
                work,
                max_distance=idx.max_distance,
                residencies=residencies,
                readout=mode,
            )
            for mode in ("device", "host")
        }
        assert _result_key(got["device"]) == _result_key(got["host"])
    finally:
        arena.release()


def test_unknown_readout_mode_rejected(corpus):
    _, idx, work = corpus
    with pytest.raises(ValueError, match="readout"):
        serve_query_batch(work, max_distance=idx.max_distance, readout="dma")


# ---------------------------------------------------------------------------
# _dedup_fragments: packed fast tier == lexsort fallback, overflow-safe
# ---------------------------------------------------------------------------


def _dedup_reference(q, d, s, e):
    uniq = sorted(set(zip(q, d, s, e)))
    cols = list(zip(*uniq)) if uniq else [[], [], [], []]
    return [list(c) for c in cols]


def test_dedup_fragments_packed_tier_matches_reference():
    rng = np.random.default_rng(3)
    q = rng.integers(0, 7, 200).astype(np.int64)
    d = rng.integers(0, 50, 200).astype(np.int64)
    s = rng.integers(0, 30, 200).astype(np.int64)
    e = s + rng.integers(0, 5, 200).astype(np.int64)
    got = [c.tolist() for c in _dedup_fragments(q, d, s, e)]
    assert got == _dedup_reference(q.tolist(), d.tolist(), s.tolist(), e.tolist())


def test_dedup_fragments_lexsort_tier_on_overflow():
    # doc ids near 2^58: q*doc*n*n no longer fits 63 bits, so the packed
    # tier must NOT be used — the fallback still dedups exactly
    q = np.array([1, 0, 1, 1, 0], np.int64)
    d = np.array([1 << 58, (1 << 58) + 3, 1 << 58, 1 << 58, (1 << 58) + 3], np.int64)
    s = np.array([5, 2, 5, 7, 2], np.int64)
    e = np.array([9, 4, 9, 8, 4], np.int64)
    mods = [int(c.max()) + 1 for c in (q, d, s, e)]
    assert (mods[0] * mods[1] * mods[2] * mods[3] - 1).bit_length() > 63
    got = [c.tolist() for c in _dedup_fragments(q, d, s, e)]
    assert got == _dedup_reference(q.tolist(), d.tolist(), s.tolist(), e.tolist())


def test_dedup_fragments_empty():
    empty = np.empty(0, np.int64)
    got = _dedup_fragments(empty, empty, empty, empty)
    assert all(len(c) == 0 for c in got)


# ---------------------------------------------------------------------------
# §15.3 phase-timer schema: six disjoint brackets, no double-counting
# ---------------------------------------------------------------------------


def test_phase_schema_and_no_double_counting(corpus):
    _, idx, work = corpus
    serve_query_batch(work, max_distance=idx.max_distance)  # jit warm
    phases: dict = {}
    prev = fused.collect_phases(phases)
    t0 = time.perf_counter()
    serve_query_batch(work, max_distance=idx.max_distance)
    wall = time.perf_counter() - t0
    fused.collect_phases(prev)
    assert set(phases) == PHASE_KEYS
    assert all(us >= 0.0 for v in phases.values() for us in v)
    # disjoint brackets: the phase sum cannot exceed the measured wall time
    # (equality up to the unbracketed merge/return tail)
    assert sum(sum(v) for v in phases.values()) <= wall * 1e6 + 1.0


# ---------------------------------------------------------------------------
# defer=True: PendingBatch equals the eager result, result() is idempotent
# ---------------------------------------------------------------------------


def test_deferred_serve_equals_eager(corpus):
    _, idx, work = corpus
    eager = serve_query_batch(work, max_distance=idx.max_distance)
    pending = serve_query_batch(work, max_distance=idx.max_distance, defer=True)
    assert isinstance(pending, PendingBatch)
    got = pending.result()
    assert _result_key(got) == _result_key(eager)
    assert pending.result() is got  # idempotent: no re-finalize


# ---------------------------------------------------------------------------
# §15.2 pipelined frontend: identical responses, admission order preserved
# ---------------------------------------------------------------------------


def test_pipelined_frontend_matches_serial_in_admission_order(corpus):
    from repro.search.frontend import SearchRequest, ServingFrontend

    store, idx, _ = corpus
    requests = [SearchRequest(q, top_k=16) for q in QUERIES]

    def run(pipeline):
        fe = ServingFrontend(
            idx, lemmatizer=store.lemmatizer, max_batch=2, pipeline=pipeline
        )
        return fe.search_many(requests)

    serial, piped = run(False), run(True)
    assert [r.query for r in piped] == [rq.query for rq in requests]
    for a, b in zip(serial, piped):
        assert a.query == b.query
        assert [
            (d.doc_id, d.score, [(f.start, f.end) for f in d.fragments])
            for d in a.docs
        ] == [
            (d.doc_id, d.score, [(f.start, f.end) for f in d.fragments])
            for d in b.docs
        ]
