"""§6 key selection, including the paper's Lord Hornblower example."""

from _hypothesis_compat import given, settings, st

from repro.core.keys import Subquery, select_keys
from repro.core.lemma import FLList


def _fl(freqs):
    return FLList.from_frequencies(freqs, sw_count=len(freqs), fu_count=0)


def test_paper_hornblower_example():
    """'Who are you and why did you say what you did' (§6).

    FL-numbers from the paper: and=28, you=47, what=132, do=154, say=165,
    are=268, who=293, why=528.
    """
    freqs = {"and": 1000, "you": 900, "what": 800, "do": 700, "say": 600,
             "are": 500, "who": 400, "why": 300}
    fl = _fl(freqs)
    sub = Subquery(("who", "are", "you", "and", "why", "do", "you", "say",
                    "what", "you", "do"))
    keys = select_keys(sub, fl)
    assert len(keys) == 3
    # key 1: (and, why, who) selection order; canonical = FL order
    assert set(keys[0].components) == {"and", "who", "why"}
    assert keys[0].starred == (False, False, False)
    # key 2: (you, are, say)
    assert set(keys[1].components) == {"you", "are", "say"}
    assert keys[1].starred == (False, False, False)
    # key 3: (what, do, why*) — why is the starred duplicate
    assert set(keys[2].components) == {"what", "do", "why"}
    stars = dict(zip(keys[2].components, keys[2].starred))
    assert stars["why"] is True
    assert stars["what"] is False and stars["do"] is False


def test_canonical_order_is_fl_order():
    fl = _fl({"a": 100, "b": 50, "c": 10})
    (key,) = select_keys(Subquery(("c", "a", "b")), fl)
    assert key.components == ("a", "b", "c")


def test_first_component_most_frequent_unused():
    fl = _fl({"a": 100, "b": 50, "c": 10, "d": 5})
    keys = select_keys(Subquery(("d", "c", "b", "a")), fl)
    # first key's most frequent component must be 'a'
    assert keys[0].components[0] == "a"


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from("abcdefgh"), min_size=1, max_size=8))
def test_selection_invariants(lemmas):
    freqs = {c: 100 - i for i, c in enumerate("abcdefgh")}
    fl = _fl(freqs)
    sub = Subquery(tuple(lemmas))
    keys = select_keys(sub, fl)
    covered = set()
    for k in keys:
        assert len(k.components) == min(3, max(1, len(lemmas)))
        # canonical order
        nums = [fl.number(c) for c in k.components]
        assert nums == sorted(nums)
        # first component of every key is unstarred
        order = sorted(range(len(k.components)), key=lambda i: fl.number(k.components[i]))
        covered.update(c for c, s in zip(k.components, k.starred) if not s)
    # every unique lemma is covered by an unstarred component
    assert covered == set(lemmas)
