"""Generation strategies for the differential property-test harness.

Works with the real ``hypothesis`` package AND the fixed-seed shim in
``tests/_hypothesis_compat.py``: every strategy draws a single integer seed
and the generators below expand it deterministically with numpy — so runs
are reproducible under both engines, and under real hypothesis the seed
still shrinks to a minimal failing example.

Corpora are Zipf-shaped with a forced stop/FU/ordinary mix (a function-word
head reused from the corpus module, a mid-frequency band, a long tail),
document lengths 1–200, plus injected paper phrases so multi-lemma query
words ("are" -> are/be) and duplicate-lemma queries have non-trivial result
sets.  Queries are k=1..5 words drawn from the corpus vocabulary with
deliberate duplicates.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from tests._hypothesis_compat import st

# one strategy: an integer seed, expanded by the builders below
seeds = st.integers(min_value=0, max_value=2**31 - 1)

_HEAD = (
    "the", "be", "to", "of", "and", "a", "in", "that", "you", "who",
    "it", "for", "not", "on", "is", "are", "what", "do", "this", "at",
)
_PHRASES = (
    "who are you who",
    "to be or not to be",
    "the who are an english rock band",
    "time and time again",
    "what do you do all day",
)


@dataclass
class CorpusSpec:
    """A drawn corpus + index configuration."""

    texts: list[str]
    sw_count: int
    fu_count: int
    max_distance: int
    vocab: list[str]


def make_corpus(seed: int, max_docs: int = 14) -> CorpusSpec:
    """Deterministically expand ``seed`` into a corpus spec.

    Doc lengths span 1–200; the stop/FU boundary is drawn so the same lemma
    population lands in different frequency classes across seeds (stop-heavy,
    FU-heavy and ordinary-heavy corpora all occur).
    """
    rng = np.random.default_rng(seed)
    n_docs = int(rng.integers(2, max_docs + 1))
    n_tail = int(rng.integers(5, 40))
    vocab = list(_HEAD) + [f"w{j:03d}" for j in range(n_tail)]
    ranks = np.arange(1, len(vocab) + 1, dtype=np.float64)
    probs = ranks ** -float(rng.uniform(0.9, 1.6))
    probs /= probs.sum()

    texts: list[str] = []
    for _ in range(n_docs):
        doc_len = int(rng.integers(1, 201))
        words = [vocab[int(k)] for k in rng.choice(len(vocab), size=doc_len, p=probs)]
        if doc_len > 4 and rng.random() < 0.7:
            phrase = _PHRASES[int(rng.integers(len(_PHRASES)))].split()
            at = int(rng.integers(0, doc_len - 1))
            words[at:at] = phrase
        texts.append(" ".join(words))

    # forced class mixes: boundaries drawn over the realized lemma count
    sw_count = int(rng.integers(3, 25))
    fu_count = int(rng.integers(3, 30))
    max_distance = int(rng.choice([3, 5, 7]))
    return CorpusSpec(
        texts=texts,
        sw_count=sw_count,
        fu_count=fu_count,
        max_distance=max_distance,
        vocab=vocab,
    )


def make_queries(seed: int, spec: CorpusSpec, n_queries: int = 4) -> list[str]:
    """k=1..5-word queries over the corpus vocabulary, duplicates included."""
    rng = np.random.default_rng(seed + 0x9E3779B9)
    queries: list[str] = []
    for _ in range(n_queries):
        k = int(rng.integers(1, 6))
        words: list[str] = []
        for _ in range(k):
            if words and rng.random() < 0.3:
                words.append(words[int(rng.integers(len(words)))])  # duplicate
            elif rng.random() < 0.7:
                words.append(_HEAD[int(rng.integers(len(_HEAD)))])
            else:
                words.append(spec.vocab[int(rng.integers(len(spec.vocab)))])
        queries.append(" ".join(words))
    return queries


@dataclass
class ArrivalSpec:
    """A drawn open-loop arrival schedule for the §16 queue tests.

    ``events`` are ``(arrival_time_sec, query, top_k, deadline_sec|None)``
    in time order — bursty (several arrivals can share an instant) with a
    mixed deadline population (none / generous / tight / zero); replayed
    on a virtual clock via ``ServiceDaemon.replay``.
    ``service_time_sec`` is the drawn virtual per-batch service time.
    """

    events: list[tuple]
    service_time_sec: float


def make_arrival_schedule(
    seed: int, queries: list[str], max_events: int = 24
) -> ArrivalSpec:
    """Deterministically expand ``seed`` into an :class:`ArrivalSpec`.

    Inter-arrival gaps mix zero (bursts: QPS spikes that must queue behind
    an in-flight batch) with short pauses; deadlines mix ``None`` (never
    sheds work), generous (admits everything), tight (forces partials) and
    zero (admits nothing).  Equal seeds produce equal schedules under both
    hypothesis and the fixed-seed shim.
    """
    rng = np.random.default_rng(seed ^ 0xA5A5_A5A5)
    n = int(rng.integers(3, max_events + 1))
    t = 0.0
    events: list[tuple] = []
    for _ in range(n):
        if rng.random() < 0.55:  # else: same-instant burst
            t += float(rng.uniform(0.0005, 0.012))
        q = queries[int(rng.integers(len(queries)))]
        top_k = int(rng.choice([3, 10, 1000]))
        r = rng.random()
        if r < 0.55:
            deadline = None
        elif r < 0.75:
            deadline = float(rng.uniform(0.5, 2.0))
        elif r < 0.92:
            deadline = float(rng.uniform(1e-4, 5e-3))
        else:
            deadline = 0.0
        events.append((t, q, top_k, deadline))
    return ArrivalSpec(
        events=events, service_time_sec=float(rng.uniform(0.001, 0.01))
    )


@dataclass
class OpSequence:
    """A randomized add/delete/compact schedule for the incremental tests."""

    batches: list[list[str]]  # texts per ingest batch
    # ops[i] runs AFTER batch i commits: ("delete", frac) / ("compact", budget)
    ops: list[list[tuple]]


def make_op_sequence(seed: int, spec: CorpusSpec) -> OpSequence:
    rng = np.random.default_rng(seed ^ 0x5DEECE66D)
    texts = list(spec.texts)
    n_batches = int(rng.integers(2, 5))
    cuts = sorted(rng.choice(np.arange(1, len(texts)), size=min(n_batches - 1, len(texts) - 1), replace=False).tolist()) if len(texts) > 1 else []
    batches, prev = [], 0
    for c in cuts + [len(texts)]:
        batches.append(texts[prev:c])
        prev = c
    ops: list[list[tuple]] = []
    for _ in batches:
        step: list[tuple] = []
        if rng.random() < 0.6:
            step.append(("delete", float(rng.uniform(0.05, 0.4))))
        if rng.random() < 0.4:
            step.append(("compact", int(rng.integers(20_000, 300_000))))
        ops.append(step)
    return OpSequence(batches=batches, ops=ops)
