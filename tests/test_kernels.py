"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.intersect import PAD, block_offsets, intersect_sorted
from repro.kernels.ops import proximity_search_scores
from repro.kernels.proximity import proximity_window
from repro.kernels.ref import (
    embedding_bag_ref,
    fragment_scores_ref,
    intersect_ref,
    proximity_window_ref,
)


@pytest.mark.parametrize("b,l,n", [(1, 4, 128), (3, 8, 256), (2, 2, 512), (5, 8, 128)])
@pytest.mark.parametrize("max_distance", [2, 5, 7])
@pytest.mark.parametrize("dtype", [np.int32, np.uint8])
def test_proximity_kernel_sweep(b, l, n, max_distance, dtype):
    rng = np.random.default_rng(b * 1000 + l * 10 + max_distance)
    occ = (rng.random((b, l, n)) < 0.1).astype(dtype)
    mult = np.zeros((b, l), np.int32)
    active = rng.integers(1, l + 1)
    mult[:, :active] = rng.integers(1, 3, (b, active))
    emit_k, start_k = proximity_window(
        jnp.asarray(occ.astype(np.int32)), jnp.asarray(mult), max_distance
    )
    emit_r, start_r = proximity_window_ref(
        jnp.asarray(occ.astype(np.int32)), jnp.asarray(mult), max_distance
    )
    np.testing.assert_array_equal(np.asarray(emit_k), np.asarray(emit_r))
    np.testing.assert_array_equal(
        np.where(np.asarray(emit_r), np.asarray(start_k), 0),
        np.where(np.asarray(emit_r), np.asarray(start_r), 0),
    )


@pytest.mark.parametrize("na,nb,univ", [(128, 256, 1000), (512, 512, 800), (256, 1024, 10**6)])
@pytest.mark.parametrize("n_chunks", [2, 4])
def test_intersect_kernel_sweep(na, nb, univ, n_chunks):
    rng = np.random.default_rng(na + nb)
    a_real = np.sort(rng.choice(univ, min(na - 16, univ - 1), replace=False)).astype(np.int32)
    a = np.concatenate([a_real, np.full(na - len(a_real), PAD, np.int32)])
    b_real = np.sort(rng.choice(univ, min(nb - 32, univ - 1), replace=False)).astype(np.int32)
    b = np.concatenate([b_real, np.full(nb - len(b_real), PAD, np.int32)])
    off = block_offsets(a, b, 128, 256)
    got = intersect_sorted(jnp.asarray(a), jnp.asarray(b), jnp.asarray(off),
                           n_chunks=n_chunks)
    ref = intersect_ref(jnp.asarray(a), jnp.asarray(b))
    if n_chunks * 256 >= nb:  # full coverage guaranteed
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    else:  # partial tiles may under-report but never false-positive
        assert np.all(np.asarray(got) <= np.asarray(ref))


def test_embedding_bag_ref_matches_loop():
    rng = np.random.default_rng(0)
    table = rng.normal(size=(50, 8)).astype(np.float32)
    ids = rng.integers(-1, 50, (6, 5)).astype(np.int32)
    w = rng.normal(size=(6, 5)).astype(np.float32)
    got = np.asarray(embedding_bag_ref(jnp.asarray(table), jnp.asarray(ids), jnp.asarray(w)))
    for i in range(6):
        exp = np.zeros(8, np.float32)
        for j in range(5):
            if ids[i, j] >= 0:
                exp += table[ids[i, j]] * w[i, j]
        np.testing.assert_allclose(got[i], exp, rtol=1e-5)


def test_fragment_scores():
    emit = jnp.asarray([[False, True, False, True]])
    start = jnp.asarray([[0, 0, 0, 2]])
    s = np.asarray(fragment_scores_ref(emit, start))
    # spans: 1 (pos1, start0) and 1 (pos3, start2) -> 2 * 1/4
    np.testing.assert_allclose(s, [0.5])


def test_fused_scores_kernel_vs_ref():
    rng = np.random.default_rng(7)
    occ = (rng.random((4, 8, 128)) < 0.12).astype(np.int32)
    mult = np.tile([1, 1, 2, 0, 0, 0, 0, 0], (4, 1)).astype(np.int32)
    for use_kernel in (False, True):
        emit, start, scores = proximity_search_scores(
            jnp.asarray(occ), jnp.asarray(mult), 5, use_kernel=use_kernel
        )
        if use_kernel:
            np.testing.assert_allclose(np.asarray(scores), ref_scores, rtol=1e-6)
        else:
            ref_scores = np.asarray(scores)
