"""MoE dispatch equivalence: einsum == sort (same capacity semantics) on
no-overflow loads; the shard_map `local` path is exercised in a forced
8-device subprocess (device count locks at first jax init)."""

import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.moe import MoEConfig, init_moe_params, moe_ffn


def _cfg(dispatch):
    return MoEConfig(n_experts=8, top_k=2, d_ff=32, capacity_factor=8.0,
                     dispatch=dispatch)


def test_einsum_equals_sort_no_overflow():
    cfg_e, cfg_s = _cfg("einsum"), _cfg("sort")
    params = init_moe_params(jax.random.key(0), 16, cfg_e, jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 12, 16)), jnp.float32)
    y_e, aux_e = moe_ffn(x, params, cfg_e)
    y_s, aux_s = moe_ffn(x, params, cfg_s)
    np.testing.assert_allclose(np.asarray(y_e), np.asarray(y_s), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_e), float(aux_s), rtol=1e-4)


def test_capacity_drops_tokens():
    """With capacity factor << 1 outputs shrink (tokens dropped), not NaN."""
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff=32, capacity_factor=0.1,
                    dispatch="sort")
    params = init_moe_params(jax.random.key(1), 16, cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 64, 16)), jnp.float32)
    y, _ = moe_ffn(x, params, cfg)
    assert np.isfinite(np.asarray(y)).all()


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.moe import MoEConfig, init_moe_params, moe_ffn
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg_l = MoEConfig(n_experts=8, top_k=2, d_ff=32, capacity_factor=8.0, dispatch="local")
cfg_s = MoEConfig(n_experts=8, top_k=2, d_ff=32, capacity_factor=8.0, dispatch="sort")
params = init_moe_params(jax.random.key(0), 16, cfg_l, jnp.float32)
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8, 16)), jnp.float32)
with jax.set_mesh(mesh):
    y_l, _ = jax.jit(lambda x, p: moe_ffn(x, p, cfg_l))(x, params)
y_s, _ = moe_ffn(x, params, cfg_s)
err = float(jnp.max(jnp.abs(y_l - y_s)))
assert err < 2e-4, err
print("LOCAL_OK", err)
"""


@pytest.mark.skipif(
    not hasattr(jax, "set_mesh"),
    reason="subprocess uses jax.set_mesh (jax >= 0.6); not available here",
)
def test_local_dispatch_multidevice_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert "LOCAL_OK" in r.stdout, r.stdout + r.stderr
