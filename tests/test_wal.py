"""Unit tests for the §18 write-ahead op log (frame format, torn tails,
checkpoint/seal/prune retention, replay exactness, fault points).

The contract under test (DESIGN.md §18.1-§18.2): every record whose
``append`` returned survives any crash — torn tails and bitflips truncate
to the acknowledged prefix, never corrupt it — and restoring the latest
snapshot then replaying the WAL tail yields an indexer
``index_sets_equal`` to the uncrashed live one, *including post-snapshot
commits* (zero data loss).
"""

from __future__ import annotations

import json

import pytest

from repro.index import (
    IncrementalIndexer,
    WriteAheadLog,
    build_indexes,
    index_sets_equal,
    read_frames,
    synthesize_corpus,
)
from repro.index.wal import (
    encode_frame,
    fl_from_payload,
    fl_to_payload,
    replay,
)
from repro.search.resilience import FaultEvent, FaultInjector, ShardCrash

SW, FU, D = 40, 80, 5


def _texts(n=18, seed=11):
    store = synthesize_corpus(n_docs=n, doc_len=50, vocab_size=250, seed=seed)
    return [d.text for d in store.documents], store.lemmatizer


def _fresh(lem):
    return IncrementalIndexer(sw_count=SW, fu_count=FU, max_distance=D, lemmatizer=lem)


def _assert_same_index(a, b, ctx=""):
    eq, why = index_sets_equal(a.index.to_index_set(), b.index.to_index_set())
    assert eq, f"{ctx}: {why}"


# ---------------------------------------------------------------------------
# frame format (§18.1)
# ---------------------------------------------------------------------------


def test_frame_round_trip_byte_exact(tmp_path):
    path = tmp_path / "records.bin"
    payloads = [
        ("add", {"docs": [{"doc_id": 0, "text": "a b", "lemmas": [["a", 0]]}]}),
        ("delete", {"doc_id": 3}),
        ("commit", {"fl": None}),
        ("compact", {"memory_budget_bytes": None}),
        ("checkpoint", {"snapshot_id": 0, "mutations": 4}),
    ]
    with open(path, "wb") as f:
        for seq, (rtype, payload) in enumerate(payloads):
            f.write(encode_frame(seq, rtype, payload))
    records = read_frames(path)
    assert [(r.seq, r.rtype, r.payload) for r in records] == [
        (i, t, p) for i, (t, p) in enumerate(payloads)
    ]


@pytest.mark.parametrize(
    "mutate,survivors",
    [
        (lambda data: data[:-1], 3),                 # torn tail: short payload
        (lambda data: data[: len(data) // 2], 2),    # torn mid-frame
        (lambda data: data[:-3] + bytes([data[-3] ^ 0x40]) + data[-2:], 3),  # bitflip
    ],
)
def test_torn_or_flipped_tail_truncates_to_acknowledged_prefix(
    tmp_path, mutate, survivors
):
    path = tmp_path / "records.bin"
    frames = [encode_frame(i, "delete", {"doc_id": i}) for i in range(4)]
    data = b"".join(frames)
    path.write_bytes(mutate(data))
    records = read_frames(path)
    # the damaged frame and everything after it are cut; every earlier
    # (acknowledged) one survives intact
    assert [r.seq for r in records] == list(range(survivors))
    # physical truncation: the file is now exactly the valid prefix and a
    # fresh append extends a clean tail
    assert path.read_bytes() == b"".join(frames[:survivors])
    with open(path, "ab") as f:
        f.write(encode_frame(survivors, "delete", {"doc_id": 99}))
    assert [r.payload["doc_id"] for r in read_frames(path)] == (
        list(range(survivors)) + [99]
    )


def test_mid_file_corruption_stops_scan_never_resyncs(tmp_path):
    """A flipped byte in the MIDDLE record invalidates everything after it:
    the reader must not resynchronize onto later frames (their ops may
    depend on the lost one)."""
    path = tmp_path / "records.bin"
    frames = [encode_frame(i, "delete", {"doc_id": i}) for i in range(3)]
    bad = bytearray(b"".join(frames))
    bad[len(frames[0]) + 8] ^= 0x01  # inside frame 1
    path.write_bytes(bytes(bad))
    assert [r.seq for r in read_frames(path)] == [0]
    assert path.read_bytes() == frames[0]


def test_non_monotonic_sequence_rejected(tmp_path):
    path = tmp_path / "records.bin"
    path.write_bytes(
        encode_frame(5, "delete", {"doc_id": 0}) + encode_frame(5, "delete", {"doc_id": 1})
    )
    assert [r.seq for r in read_frames(path)] == [5]


def test_fl_payload_round_trip_exact():
    texts, lem = _texts()
    ix = _fresh(lem)
    ix.add_documents(texts)
    ix.commit()
    fl = ix.fl
    back = fl_from_payload(json.loads(json.dumps(fl_to_payload(fl))))
    assert back.lemmas == fl.lemmas
    assert back.fl_number == fl.fl_number
    assert back.frequency == fl.frequency
    assert (back.sw_count, back.fu_count) == (fl.sw_count, fl.fu_count)
    assert fl_from_payload(None) is None and fl_to_payload(None) is None


# ---------------------------------------------------------------------------
# segments: checkpoint / seal / prune (§18.2)
# ---------------------------------------------------------------------------


def test_checkpoint_seals_segment_and_prune_keeps_tail(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal")
    wal.append("delete", {"doc_id": 0})
    wal.checkpoint(0, mutations=1)          # seals wal_0
    wal.append("delete", {"doc_id": 1})
    wal.checkpoint(1, mutations=2)          # seals wal_1
    wal.append("delete", {"doc_id": 2})     # active tail wal_2 (unsealed)
    segs = sorted(p.name for p in (tmp_path / "wal").glob("wal_*"))
    assert segs == ["wal_0", "wal_1", "wal_2"]
    assert not (tmp_path / "wal" / "wal_2" / "manifest.json").exists()
    wal.prune(keep=1)
    # only the newest SEALED segment is retained; the tail is untouchable
    assert sorted(p.name for p in (tmp_path / "wal").glob("wal_*")) == [
        "wal_1",
        "wal_2",
    ]
    # sequence numbering continues monotonically across reopen
    reopened = WriteAheadLog(tmp_path / "wal")
    seq = reopened.append("delete", {"doc_id": 3})
    assert seq == 5  # 0:delete 1:ckpt 2:delete 3:ckpt 4:delete -> next is 5
    assert [r.seq for r in reopened.records()] == [2, 3, 4, 5]


def test_tail_after_snapshot_anchors_and_unanchored_is_empty(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal")
    wal.append("delete", {"doc_id": 0})
    wal.checkpoint(0, mutations=1)
    wal.append("delete", {"doc_id": 1})
    wal.append("delete", {"doc_id": 2})
    tail = wal.tail_after_snapshot(0)
    assert [r.payload["doc_id"] for r in tail] == [1, 2]
    # a snapshot the log never anchored -> nothing to replay (safe §12 RPO)
    assert wal.tail_after_snapshot(7) == []


# ---------------------------------------------------------------------------
# replay exactness (§18.2): restore + tail == uncrashed live indexer
# ---------------------------------------------------------------------------


def test_restore_replays_post_snapshot_ops_exactly(tmp_path):
    texts, lem = _texts()
    live = _fresh(lem)
    live.enable_wal(tmp_path)
    ids = live.add_documents(texts[:12])
    live.commit()
    live.snapshot(tmp_path)
    # post-snapshot mutations: the §12 snapshot alone would lose ALL of these
    live.add_documents(texts[12:])
    live.commit()
    live.delete_document(ids[2])
    live.commit(refresh_fl=True)
    live.compact(memory_budget_bytes=None)

    recovered = IncrementalIndexer.restore(tmp_path, lemmatizer=lem)
    assert recovered.last_wal_replay["records"] > 0
    _assert_same_index(recovered, live, "restore+replay vs live")
    assert recovered.documents.keys() == live.documents.keys()
    assert recovered.tombstones == live.tombstones
    assert recovered.fl.lemmas == live.fl.lemmas
    # the recovered indexer keeps logging: further ops land in the SAME log
    recovered.delete_document(ids[5])
    assert recovered.wal.records()[-1].payload == {"doc_id": ids[5]}


def test_restore_without_replay_is_snapshot_only(tmp_path):
    texts, lem = _texts()
    live = _fresh(lem)
    live.enable_wal(tmp_path)
    live.add_documents(texts[:12])
    live.commit()
    live.snapshot(tmp_path)
    live.add_documents(texts[12:])
    live.commit()
    snap_only = IncrementalIndexer.restore(tmp_path, lemmatizer=lem, replay_wal=False)
    assert snap_only.last_wal_replay["records"] == 0
    assert len(snap_only.documents) == 12  # the §12 RPO: post-snapshot ops lost
    replayed = IncrementalIndexer.restore(tmp_path, lemmatizer=lem)
    assert len(replayed.documents) == len(texts)


def test_replay_reproduces_full_build_equivalence(tmp_path):
    """The §12.3 equivalence extended through the WAL: replayed state still
    matches a from-scratch ``build_indexes`` over the surviving corpus."""
    texts, lem = _texts()
    live = _fresh(lem)
    live.enable_wal(tmp_path)
    live.add_documents(texts[:10])
    live.commit()
    live.snapshot(tmp_path)
    live.add_documents(texts[10:])
    live.commit(refresh_fl=True)
    recovered = IncrementalIndexer.restore(tmp_path, lemmatizer=lem)
    eq, why = index_sets_equal(
        recovered.index.to_index_set(), recovered.rebuild_index_set()
    )
    assert eq, f"replayed state vs full rebuild: {why}"


def test_torn_wal_tail_recovers_acknowledged_prefix(tmp_path):
    """Crash mid-append (real torn bytes on disk): recovery replays exactly
    the acknowledged ops and the damaged tail is cut, not interpreted."""
    texts, lem = _texts()
    live = _fresh(lem)
    wal = live.enable_wal(tmp_path)
    live.add_documents(texts[:12])
    live.commit()
    live.snapshot(tmp_path)
    ids = live.add_documents(texts[12:15])
    live.commit()
    # tear the tail: append garbage half-frame bytes as a crash would leave
    tail_file = wal._segment / "records.bin"
    good = tail_file.read_bytes()
    tail_file.write_bytes(good + encode_frame(999, "delete", {"doc_id": 1})[:9])
    recovered = IncrementalIndexer.restore(tmp_path, lemmatizer=lem)
    _assert_same_index(recovered, live, "torn tail")
    assert tail_file.read_bytes() == good
    assert set(ids) <= recovered.documents.keys()


def test_replay_is_suppressed_from_relogging(tmp_path):
    texts, lem = _texts(n=8)
    live = _fresh(lem)
    wal = live.enable_wal(tmp_path)
    live.add_documents(texts)
    live.commit()
    live.snapshot(tmp_path)
    live.add_documents(["extra doc one two"])
    live.commit()
    n_records = len(wal.records())
    recovered = IncrementalIndexer.restore(tmp_path, lemmatizer=lem)
    # replay applied records but logged nothing new
    assert recovered.last_wal_replay["records"] == 2
    assert len(recovered.wal.records()) == n_records


def test_replay_helper_counts_only_mutations(tmp_path):
    texts, lem = _texts(n=8)
    live = _fresh(lem)
    wal = live.enable_wal(tmp_path)
    live.add_documents(texts)
    live.commit()
    records = wal.records()
    fresh = _fresh(lem)
    applied = replay(fresh, records)
    assert applied == 2  # add + commit; no checkpoint anchors in this log
    _assert_same_index(fresh, live, "replay onto empty")


# ---------------------------------------------------------------------------
# §14 fault points: wal.append / wal.torn_tail
# ---------------------------------------------------------------------------


def test_wal_append_fault_loses_op_without_acknowledging(tmp_path):
    texts, lem = _texts(n=8)
    live = _fresh(lem)
    live.enable_wal(
        tmp_path,
        injector=FaultInjector(
            schedule=[FaultEvent("wal.append", "kill", shard=0, at_call=2)]
        ),
        shard=0,
    )
    live.add_documents(texts)
    live.commit()
    with pytest.raises(ShardCrash):
        live.delete_document(min(live.documents))
    # the aborted delete wrote NOTHING: no frame, no indexer mutation
    assert [r.rtype for r in live.wal.records()] == ["add", "commit"]
    assert min(live.documents) in live.documents


def test_wal_torn_tail_fault_leaves_truncatable_partial_frame(tmp_path):
    texts, lem = _texts(n=8)
    live = _fresh(lem)
    wal = live.enable_wal(
        tmp_path,
        injector=FaultInjector(
            schedule=[FaultEvent("wal.torn_tail", "kill", shard=0, at_call=2)]
        ),
        shard=0,
    )
    live.add_documents(texts)
    live.commit()
    tail_file = wal._segment / "records.bin"
    clean = tail_file.read_bytes()
    with pytest.raises(ShardCrash):
        live.delete_document(min(live.documents))
    assert len(tail_file.read_bytes()) > len(clean)  # real partial bytes
    # a fresh reader truncates the torn frame and sees only acked records
    assert [r.rtype for r in read_frames(tail_file)] == ["add", "commit"]
    assert tail_file.read_bytes() == clean


def test_bulk_build_anchors_wal_for_post_build_replay(tmp_path):
    store = synthesize_corpus(n_docs=10, doc_len=50, vocab_size=250, seed=11)
    live, _stats = IncrementalIndexer.bulk_build(
        documents=list(store.documents),
        out_dir=tmp_path,
        sw_count=SW,
        fu_count=FU,
        max_distance=D,
        lemmatizer=store.lemmatizer,
        wal=True,
    )
    assert live.wal is not None
    assert live.wal.records()[0].rtype == "bulk_build"
    live.add_documents(["post build doc alpha beta"])
    live.commit()
    recovered = IncrementalIndexer.restore(tmp_path, lemmatizer=store.lemmatizer)
    assert recovered.last_wal_replay["records"] == 2
    _assert_same_index(recovered, live, "bulk_build + replay")
