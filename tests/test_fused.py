"""Fused batched serving pipeline: exact fragment equivalence with the host
Combiner (se2.4) across corpora / multi-lemma queries / dead-shard fan-out,
one-device-dispatch-per-query-batch serving, jit-cache stability under the
power-of-two shape budgets, and the Step-1 intersect pre-filter."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core.combiner import se24_combiner
from repro.core.keys import Subquery, expand_subqueries, select_keys
from repro.core.lemma import Lemmatizer
from repro.core.oracle import oracle_search
from repro.core.window import window_cover_batch, window_cover_rank_batch
from repro.index import DocumentStore, build_indexes, synthesize_corpus
from repro.search import fused
from repro.search.distributed import ShardedSearchService
from repro.search.vectorized import VectorizedEngine, pack_subquery_events

QUERIES = [
    "who are you who",
    "to be or not to be",
    "what do you do all day",
    "the time of war",
    "time and time again",
    "i need you",
    "how to find the mean",
    "who is who in the world of war",
]


def _expected_union(batch_subs, idx):
    out = []
    for subs in batch_subs:
        frs = set()
        for sub in subs:
            r, _ = se24_combiner(sub, idx)
            frs.update(r)
        out.append(frs)
    return out


# ---------------------------------------------------------------------------
# exact equivalence with the host Combiner
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_docs,seed", [(25, 3), (60, 7), (110, 1)])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_fused_batch_equals_combiner_across_corpora(n_docs, seed, use_kernel):
    store = synthesize_corpus(n_docs=n_docs, doc_len=120, vocab_size=500, seed=seed)
    idx = build_indexes(store, sw_count=60, fu_count=120, max_distance=5)
    lem = Lemmatizer()
    batch = [expand_subqueries(q, lem) for q in QUERIES[:5]]
    eng = VectorizedEngine(idx, use_kernel=use_kernel)
    res, stats = eng.search_query_batch(batch)
    for frs, expected in zip(res.per_query, _expected_union(batch, idx)):
        assert set(frs) == expected
    assert stats.device_dispatches == 1


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_fused_random_corpus_random_subqueries(seed):
    """Random Zipf corpora + random multi-lemma subqueries (with duplicate
    lemmas): the fused pipeline equals the scalar Combiner exactly."""
    rng = np.random.default_rng(seed)
    vocab = [f"w{i}" for i in range(15)]
    probs = np.array([1 / (i + 1) ** 1.1 for i in range(15)])
    probs /= probs.sum()
    texts = [" ".join(rng.choice(vocab, size=60, p=probs)) for _ in range(8)]
    store = DocumentStore.from_texts(texts)
    idx = build_indexes(store, sw_count=10_000, fu_count=0, max_distance=4)
    eng = VectorizedEngine(idx)
    subs = [
        Subquery(tuple(rng.choice(vocab[:6], size=int(rng.integers(2, 5)), replace=True)))
        for _ in range(3)
    ]
    res, _ = eng.search_query_batch([[s] for s in subs])
    for sub, frs in zip(subs, res.per_query):
        expected, _ = se24_combiner(sub, idx)
        assert set(frs) == set(expected)


def test_fused_keeps_fragments_beyond_doc_len_hint():
    """Documents longer than the engine's doc_len hint must not lose
    fragments: the position budget follows the data, not the hint."""
    filler = " ".join(f"x{i % 37}" for i in range(640))
    texts = [filler + " alpha beta gamma", "alpha beta gamma " + filler]
    store = DocumentStore.from_texts(texts)
    idx = build_indexes(store, sw_count=10_000, fu_count=0, max_distance=5)
    sub = Subquery(("alpha", "beta", "gamma"))
    expected, _ = se24_combiner(sub, idx)
    assert any(r.start >= 512 for r in expected), "needs a match beyond 512"
    eng = VectorizedEngine(idx, doc_len=512)
    got, _ = eng.search_subquery(sub)
    assert set(got) == set(expected)


def test_fused_sharded_service_with_dead_shards(small_corpus):
    svc_f = ShardedSearchService(small_corpus, n_shards=4, sw_count=60,
                                 fu_count=150, algorithm="fused")
    svc_h = ShardedSearchService(small_corpus, n_shards=4, sw_count=60,
                                 fu_count=150, algorithm="se2.4")
    for dead in ((), (1,), (0, 3)):
        fused.reset_dispatch_count()
        resps_f = svc_f.search_batch(QUERIES[:4], top_k=20, dead_shards=dead)
        assert fused.dispatch_count() == 1
        for q, rf in zip(QUERIES[:4], resps_f):
            rh = svc_h.search(q, top_k=20, dead_shards=dead)
            assert {d.doc_id for d in rf.docs} == {d.doc_id for d in rh.docs}
            np.testing.assert_allclose(
                sorted(d.score for d in rf.docs),
                sorted(d.score for d in rh.docs),
                rtol=1e-6,
            )


# ---------------------------------------------------------------------------
# one device dispatch per query batch (acceptance criterion)
# ---------------------------------------------------------------------------


def test_single_dispatch_for_8_query_batch(small_index, lemmatizer):
    batch = [expand_subqueries(q, lemmatizer) for q in QUERIES]
    assert len(batch) == 8
    assert any(len(subs) > 1 for subs in batch), "needs multi-subquery queries"
    eng = VectorizedEngine(small_index)
    fused.reset_dispatch_count()
    res, stats = eng.search_query_batch(batch)
    assert fused.dispatch_count() == 1
    assert stats.device_dispatches == 1
    assert sum(len(r) for r in res.per_query) > 0


def test_device_topk_is_ranked_and_doc_level_sane(small_index, lemmatizer):
    batch = [expand_subqueries(q, lemmatizer) for q in QUERIES[:4]]
    eng = VectorizedEngine(small_index)
    res, _ = eng.search_query_batch(batch, top_k=8)
    sc = res.top_scores
    finite = np.isfinite(sc)
    diffs = np.diff(np.where(finite, sc, np.float32(0.0)), axis=1)
    both_finite = finite[:, 1:] & finite[:, :-1]
    assert (diffs[both_finite] <= 1e-9).all()
    # padding (-inf) only ever trails real scores
    assert (finite[:, :-1] | ~finite[:, 1:]).all()
    # every finite-score doc id is a real doc that has fragments
    for qi, frs in enumerate(res.per_query):
        docs_with_frags = {f.doc_id for f in frs}
        listed = set(res.top_docs[qi][finite[qi]].tolist())
        assert listed <= docs_with_frags | {-1}


# ---------------------------------------------------------------------------
# empty-subquery short-circuit (no all-padding dispatch)
# ---------------------------------------------------------------------------


def test_empty_subquery_short_circuits_before_dispatch(small_index):
    eng = VectorizedEngine(small_index)
    sub = Subquery(("zzzunknownlemma", "qqqmissing"))
    fused.reset_dispatch_count()
    results, stats = eng.search_subquery(sub)
    assert results == []
    assert fused.dispatch_count() == 0, "empty subquery must not dispatch"
    assert stats.empty_subqueries == 1
    assert stats.device_dispatches == 0
    assert pack_subquery_events(sub, small_index) is None


# ---------------------------------------------------------------------------
# jit-cache stability: bucketed shapes => bounded compilations
# ---------------------------------------------------------------------------


def test_jit_cache_bounded_under_varying_batches(small_index, lemmatizer):
    cache_size = getattr(fused.fused_serve_batch, "_cache_size", None)
    if cache_size is None:
        pytest.skip("jax version exposes no jit cache introspection")
    eng = VectorizedEngine(small_index)
    before = cache_size()
    n_calls = 0
    # vary query count, query mix, and subquery counts: the pow2 budgets
    # must collapse these onto a handful of compiled shapes
    for size in (1, 2, 3, 4, 4, 3, 2, 1):
        for offset in (0, 2):
            batch = [
                expand_subqueries(q, lemmatizer)
                for q in QUERIES[offset : offset + size]
            ]
            eng.search_query_batch(batch)
            n_calls += 1
    grown = cache_size() - before
    assert n_calls == 16
    assert grown <= 8, f"{grown} compilations for 16 bucketed calls"


# ---------------------------------------------------------------------------
# Step-1 intersect pre-filter (device kernel == host searchsorted)
# ---------------------------------------------------------------------------


def test_intersect_candidates_device_matches_host():
    rng = np.random.default_rng(2)
    lists = [
        np.unique(rng.integers(0, 4000, size=rng.integers(50, 1500)).astype(np.int32))
        for _ in range(3)
    ]
    host = fused.intersect_candidates(lists, device_threshold=10**9)
    dev = fused.intersect_candidates(lists, device_threshold=1)
    np.testing.assert_array_equal(host, dev)
    expected = lists[0]
    for other in lists[1:]:
        expected = np.intersect1d(expected, other)
    np.testing.assert_array_equal(np.sort(host), expected)


def test_prefilter_matches_combiner_doc_gate(small_index, lemmatizer):
    """Docs dropped by the pre-filter are exactly those the Combiner's Step-1
    alignment would never visit: fused results stay equal to se2.4."""
    for q in QUERIES[:4]:
        for sub in expand_subqueries(q, lemmatizer)[:1]:
            keys = select_keys(sub, small_index.fl)
            if len(keys) < 2:
                continue
            seg = fused.extract_segment_events(sub, small_index)
            expected, _ = se24_combiner(sub, small_index)
            if seg is None:
                assert expected == []
                continue
            assert {r.doc_id for r in expected} <= set(seg.doc_ids.tolist())


# ---------------------------------------------------------------------------
# rank-based cover == windowed cover (the identity the fused path relies on)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_rank_cover_equals_window_cover(seed):
    rng = np.random.default_rng(seed)
    L = int(rng.integers(1, 5))
    N = int(rng.choice([32, 96, 128]))
    D = int(rng.integers(1, 6))
    occ = (rng.random((3, L, N)) < rng.choice([0.05, 0.2, 0.5])).astype(np.int32)
    mult = rng.integers(0, 3, (3, L)).astype(np.int32)
    mult[:, 0] = np.maximum(mult[:, 0], 1)  # at least one active lemma
    w = 2 * D + 1
    e1, s1 = window_cover_batch(jnp.asarray(occ), jnp.asarray(mult), w)
    e2, s2 = window_cover_rank_batch(jnp.asarray(occ), jnp.asarray(mult), w)
    e1, s1, e2, s2 = map(np.asarray, (e1, s1, e2, s2))
    np.testing.assert_array_equal(e1, e2)
    np.testing.assert_array_equal(np.where(e1, s1, 0), np.where(e1, s2, 0))


# ---------------------------------------------------------------------------
# compute_dtype plumbing: uint8 kernel == int32 kernel == jnp ref
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("compute_dtype", ["uint8", "int32"])
def test_proximity_kernel_compute_dtype(compute_dtype):
    from repro.kernels.ops import proximity_window, proximity_window_ref

    rng = np.random.default_rng(5)
    occ = (rng.random((4, 3, 256)) < 0.1).astype(np.int32)
    mult = np.tile([1, 2, 1], (4, 1)).astype(np.int32)
    ek, sk = proximity_window(
        jnp.asarray(occ), jnp.asarray(mult), 5, compute_dtype=compute_dtype
    )
    er, sr = proximity_window_ref(jnp.asarray(occ), jnp.asarray(mult), 5)
    np.testing.assert_array_equal(np.asarray(ek), np.asarray(er))
    np.testing.assert_array_equal(
        np.where(np.asarray(er), np.asarray(sk), 0),
        np.where(np.asarray(er), np.asarray(sr), 0),
    )
