import numpy as np
import pytest

from repro.core.lemma import Lemmatizer
from repro.index import build_indexes, synthesize_corpus


@pytest.fixture(scope="session")
def small_corpus():
    return synthesize_corpus(n_docs=50, doc_len=100, vocab_size=600, seed=11)


@pytest.fixture(scope="session")
def small_index(small_corpus):
    return build_indexes(small_corpus, sw_count=60, fu_count=150, max_distance=5)


@pytest.fixture(scope="session")
def lemmatizer():
    return Lemmatizer()
