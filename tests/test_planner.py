"""Planner + frontend differential and behavioural tests (DESIGN.md §11).

The load-bearing contract: **planned execution is fragment-identical to the
unplanned SE2.4 oracle** on the same live view, across the same randomized
corpora the engine-equivalence harness uses (``tests/strategies.py``) —
the planner re-orders and prunes provably-empty work, it never changes
results.  On top of that: micro-batching dispatch counts, result/posting
cache behaviour (including invalidation after ``compact``), and deadline
early-exit semantics.
"""

from __future__ import annotations

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings
from tests.strategies import make_corpus, make_queries, seeds

from repro.core.combiner import se24_combiner
from repro.core.keys import EXECUTABLE_FAMILIES, expand_subqueries, select_keys
from repro.core.lemma import LemmaType
from repro.core.oracle import oracle_search
from repro.index import DocumentStore, IncrementalIndexer, build_indexes
from repro.runtime.clock import ManualClock
from repro.search import fused
from repro.search.distributed import ShardedSearchService
from repro.search.engine import SearchEngine
from repro.search.frontend import SearchRequest, ServingFrontend
from repro.search.planner import QueryPlanner
from repro.search.relevance import rank_documents


def _frag_set(results):
    return {(r.doc_id, r.start, r.end) for r in results}


def _response_frags(resp):
    return sorted((d.doc_id, f.start, f.end) for d in resp.docs for f in d.fragments)


def _oracle_union(query, index, lemmatizer):
    union = set()
    for sub in expand_subqueries(query, lemmatizer):
        keys = select_keys(sub, index.fl)
        postings = {k: index.key_postings(k.components) for k in keys}
        union |= _frag_set(oracle_search(sub, keys, postings, index.max_distance))
    return union


def _build(seed, max_docs=12):
    spec = make_corpus(seed, max_docs=max_docs)
    store = DocumentStore.from_texts(spec.texts)
    index = build_indexes(
        store,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
    )
    return spec, store, index


# ---------------------------------------------------------------------------
# differential: planned execution == unplanned SE2.4 oracle
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None, derandomize=True)
@given(seeds)
def test_planned_execution_matches_oracle(seed):
    spec, store, index = _build(seed)
    eng = SearchEngine(index, lemmatizer=store.lemmatizer, algorithm="fused")
    frontend = ServingFrontend(index, lemmatizer=store.lemmatizer)
    for query in make_queries(seed, spec, n_queries=3):
        oracle = sorted(_oracle_union(query, index, store.lemmatizer))
        planned = eng.search_planned(eng.plan(query), top_k=64)
        assert _response_frags(planned) == oracle, (query, "planned != oracle")
        served = frontend.search(query, top_k=64)
        assert _response_frags(served) == oracle, (query, "frontend != oracle")
        # repeat pass: served from the result cache, still identical
        cached = frontend.search(query, top_k=64)
        assert cached.stats.cache_hits == 1
        assert _response_frags(cached) == oracle, (query, "cached != oracle")


@settings(max_examples=4, deadline=None, derandomize=True)
@given(seeds)
def test_frontend_over_sharded_service_matches_unplanned(seed):
    spec, store, index = _build(seed, max_docs=8)
    svc = ShardedSearchService(
        store,
        n_shards=2,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
        algorithm="fused",
    )
    frontend = ServingFrontend(svc)
    queries = make_queries(seed, spec, n_queries=2)
    unplanned = svc.search_batch(queries, top_k=64)
    served = frontend.search_many(
        [SearchRequest(q, top_k=64) for q in queries]
    )
    for a, b in zip(unplanned, served):
        assert _response_frags(a) == _response_frags(b), (a.query, "sharded")


# ---------------------------------------------------------------------------
# plan structure: classification, bindings, live-view costs, pruning
# ---------------------------------------------------------------------------


def test_plan_structure_and_costs(small_index, lemmatizer):
    planner = QueryPlanner(small_index, lemmatizer=lemmatizer)
    plan = planner.plan("who are you who")
    assert plan.subqueries and not plan.n_pruned
    for sp in plan.subqueries:
        # §5 classification comes straight from the FL thresholds
        for lemma, t in sp.lemma_types.items():
            assert t == small_index.fl.lemma_type(lemma)
        # bindings mirror select_keys + key_postings exactly
        assert sp.keys == tuple(select_keys(sp.subquery, small_index.fl))
        for b in sp.bindings:
            rows = small_index.key_postings(b.key.components)
            assert b.est_postings == len(rows)
            assert b.est_bytes == rows.nbytes
            assert (b.est_postings == 0) or b.family in EXECUTABLE_FAMILIES
        assert sp.est_postings == sum(b.est_postings for b in sp.bindings)
    assert plan.est_postings > 0


def test_plan_prunes_unknown_lemma_exactly(small_index, lemmatizer):
    """A query word absent from the corpus has zero posting supply: the plan
    prunes the subquery, and the engines agree that it yields nothing."""
    eng = SearchEngine(small_index, lemmatizer=lemmatizer, algorithm="fused")
    query = "who are zzzunknownlemma"
    plan = eng.plan(query)
    assert plan.n_pruned == len(plan.subqueries)
    planned = eng.search_planned(plan, top_k=16)
    assert planned.docs == []
    assert planned.stats.pruned_subqueries == plan.n_pruned
    unplanned = eng.search(query, top_k=16)
    assert _response_frags(planned) == _response_frags(unplanned) == []


# ---------------------------------------------------------------------------
# frontend: micro-batching, caches, invalidation, deadlines
# ---------------------------------------------------------------------------


@pytest.fixture
def incremental_frontend(small_corpus):
    ix = IncrementalIndexer(
        sw_count=60, fu_count=150, max_distance=5,
        lemmatizer=small_corpus.lemmatizer,
    )
    ix.add_documents([d.text for d in small_corpus.documents])
    ix.commit()
    return ix, ServingFrontend(ix, lemmatizer=small_corpus.lemmatizer)


def test_microbatch_one_dispatch_per_admitted_batch(small_index, lemmatizer):
    frontend = ServingFrontend(small_index, lemmatizer=lemmatizer, max_batch=8)
    queries = ["who are you who", "to be or not to be", "what do you do all day"]
    fused.reset_dispatch_count()
    out = frontend.search_many([SearchRequest(q, top_k=8) for q in queries])
    assert fused.dispatch_count() == 1  # one fused program for the whole slate
    assert all(r.stats.device_dispatches == 1 for r in out)
    # a second slate of the same queries is served without any dispatch
    fused.reset_dispatch_count()
    out2 = frontend.search_many([SearchRequest(q, top_k=8) for q in queries])
    assert fused.dispatch_count() == 0
    assert all(r.stats.cache_hits == 1 for r in out2)
    for a, b in zip(out, out2):
        assert _response_frags(a) == _response_frags(b)
    # max_batch=1 splits the same slate into one dispatch per request
    frontend2 = ServingFrontend(small_index, lemmatizer=lemmatizer, max_batch=1)
    fused.reset_dispatch_count()
    frontend2.search_many([SearchRequest(q, top_k=8) for q in queries])
    assert fused.dispatch_count() == len(queries)


def test_result_cache_invalidated_after_commit_and_compact(incremental_frontend):
    ix, frontend = incremental_frontend
    query = "who are you who"
    first = frontend.search(query, top_k=8)
    assert first.stats.cache_misses == 1 and first.docs
    assert frontend.search(query, top_k=8).stats.cache_hits == 1

    # delete the top document, compact: generation bumps, cache must miss
    victim = first.docs[0].doc_id
    ix.delete_document(victim)
    ix.compact()
    fresh = frontend.search(query, top_k=8)
    assert fresh.stats.cache_hits == 0 and fresh.stats.cache_misses == 1
    assert victim not in [d.doc_id for d in fresh.docs]
    # fresh results are exact w.r.t. the post-compact oracle
    oracle = sorted(_oracle_union(query, ix.index, ix.lemmatizer))
    got = sorted(
        set(_response_frags(frontend.search(query, top_k=1000)))
    )
    assert got == oracle

    # a commit (new docs) also invalidates
    before = frontend.search(query, top_k=8)
    ix.add_documents(["who are you who are you"])
    ix.commit()
    after = frontend.search(query, top_k=8)
    assert after.stats.cache_hits == 0
    assert after.stats.results > before.stats.results


def test_deadline_zero_budget_is_empty_partial(small_index, lemmatizer):
    # ManualClock (§16.4): deadline behavior is hermetic — calibration
    # sees zero elapsed and the budget comparison is pure arithmetic
    frontend = ServingFrontend(
        small_index, lemmatizer=lemmatizer, clock=ManualClock()
    )
    resp = frontend.search("who are you who", top_k=8, deadline_sec=0.0)
    assert resp.stats.partial
    assert resp.stats.skipped_subqueries > 0
    assert resp.docs == [] and resp.stats.results == 0
    # partial responses are never cached
    full = frontend.search("who are you who", top_k=8)
    assert full.stats.cache_hits == 0 and full.docs


def test_deadline_early_exit_is_correctly_ranked_partial(small_index, lemmatizer):
    """With a budget that fits only the cheapest subquery, the response is
    partial AND exactly the ranking of that subquery's fragment set."""
    frontend = ServingFrontend(
        small_index,
        lemmatizer=lemmatizer,
        # ManualClock (§16.4): zero elapsed per batch, so calibration never
        # moves the 1-posting/sec estimate between the two searches below —
        # admission is exactly arithmetic on est_postings, no wall clock
        clock=ManualClock(),
        postings_per_sec=1.0,  # 1 posting per second: any budget is tight
    )
    query = "who are you who"
    plan = frontend.planner.plan(query)
    execs = sorted(plan.executable(), key=lambda sp: sp.est_postings)
    assert len(execs) >= 2, "query must expand to multiple subqueries"
    cheapest = execs[0]
    budget = (cheapest.est_postings + 0.5)  # seconds; admits exactly one

    resp = frontend.search(query, top_k=16, deadline_sec=budget)
    assert resp.stats.partial
    assert resp.stats.skipped_subqueries == len(execs) - 1
    assert resp.stats.deadline_sec == budget

    # the partial result equals the exact ranking over the admitted subset
    results, _ = se24_combiner(cheapest.subquery, small_index)
    expected = rank_documents(_as_results(_frag_set(results)), top_k=16)
    got = [(d.doc_id, d.score) for d in resp.docs]
    assert got == [(doc, score) for doc, score, _ in expected]

    # no deadline -> the full (non-partial) result, strictly a superset
    full = frontend.search(query, top_k=16)
    assert not full.stats.partial
    assert set(_response_frags(resp)) <= set(_response_frags(full))


def _as_results(frags):
    from repro.core.postings import SearchResult

    return [SearchResult(doc_id=d, start=s, end=e) for d, s, e in frags]


def test_ewma_calibration_is_exact_on_tick_clock(small_index, lemmatizer):
    """EWMA throughput calibration under ``ManualClock(tick=t)``: the
    elapsed between a chunk's submit and finish readings is exactly one
    tick, so the post-batch estimate equals
    ``0.5*prior + 0.5*(admitted_postings / t)`` as pure arithmetic — the
    §16.4 exact-tick contract (previously untestable without sleeping)."""
    tick = 0.25
    prior = 1000.0
    frontend = ServingFrontend(
        small_index,
        lemmatizer=lemmatizer,
        clock=ManualClock(tick=tick),
        postings_per_sec=prior,
    )
    plan = frontend.planner.plan("who are you who")
    postings = sum(sp.est_postings for sp in plan.executable())
    assert postings > 0
    frontend.search("who are you who", top_k=8)
    assert frontend.postings_per_sec == 0.5 * prior + 0.5 * (postings / tick)


def test_mixed_top_k_requests_each_get_their_own_cut(small_index, lemmatizer):
    """A micro-batch chunk ranks at the chunk-wide max top_k; every response
    (and its cached copy) must still be trimmed to its own request's top_k."""
    frontend = ServingFrontend(small_index, lemmatizer=lemmatizer)
    small, big = frontend.search_many(
        [
            SearchRequest("who are you who", top_k=1),
            SearchRequest("who are you who", top_k=10),
        ]
    )
    assert len(small.docs) == 1 and len(big.docs) > 1
    # the rank prefix property: small's doc is big's top doc
    assert small.docs[0].doc_id == big.docs[0].doc_id
    # and the cached copy stays trimmed
    again = frontend.search("who are you who", top_k=1)
    assert again.stats.cache_hits == 1 and len(again.docs) == 1


def test_duplicate_slate_requests_coalesce(small_index, lemmatizer):
    """Identical no-deadline misses in one slate are planned/executed once."""
    frontend = ServingFrontend(small_index, lemmatizer=lemmatizer)
    fused.reset_dispatch_count()
    out = frontend.search_many(
        [SearchRequest("who are you who", top_k=8)] * 3
    )
    assert fused.dispatch_count() == 1
    assert frontend.metrics()["result_cache_misses"] == 1  # one planned miss
    frags = [_response_frags(r) for r in out]
    assert frags[0] == frags[1] == frags[2] and frags[0]


def test_posting_cache_lru_eviction():
    from repro.search.frontend import PostingCache

    cache = PostingCache(capacity_bytes=100)
    a = np.zeros(10, np.int32)  # 40 bytes
    b = np.zeros(10, np.int32)
    c = np.zeros(10, np.int32)
    cache.put(("g", 0, "a"), a)
    cache.put(("g", 0, "b"), b)
    assert cache.get(("g", 0, "a")) is a  # refresh a's recency
    cache.put(("g", 0, "c"), c)  # 120 bytes total -> evicts LRU (b)
    assert cache.get(("g", 0, "b")) is None
    assert cache.get(("g", 0, "a")) is a
    assert cache.get(("g", 0, "c")) is c
    # an oversized slice is never cached
    cache.put(("g", 0, "huge"), np.zeros(1000, np.int32))
    assert cache.get(("g", 0, "huge")) is None


def test_result_cache_invalidated_across_crash_recovery(tmp_path):
    """A crash + snapshot recovery replaces a shard's indexer under a fresh
    §12.5 restore epoch, which changes the service generation token — so
    every result cached before the crash must MISS afterwards (a stale hit
    could serve pre-crash state the recovered shard no longer has), while
    the re-served fragments stay identical to the pre-crash ones when the
    recovered state equals the snapshotted state (DESIGN.md §14)."""
    from repro.runtime.fault_tolerance import RestartPolicy
    from repro.search.resilience import FaultEvent, ResiliencePolicy

    spec = make_corpus(11, max_docs=10)
    store = DocumentStore.from_texts(spec.texts)
    svc = ShardedSearchService(
        store,
        n_shards=2,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
        algorithm="fused",
        incremental=True,
    )
    svc.snapshot(tmp_path / "snap")
    svc.enable_resilience(policy=ResiliencePolicy(
        restart=RestartPolicy(max_restarts=1, min_backoff_s=0.0),
        breaker_cooldown_s=0.0,
    ))
    frontend = ServingFrontend(svc)
    queries = make_queries(11, spec, n_queries=3)

    before = frontend.search_many([SearchRequest(q, top_k=1000) for q in queries])
    token_before = svc.generation_token
    hits = frontend.search_many([SearchRequest(q, top_k=1000) for q in queries])
    assert all(r.stats.cache_hits == 1 for r in hits)

    # kill shard 1; the next slate's probe barrier recovers it in place
    svc.injector.schedule = (
        FaultEvent("shard.search", "kill", shard=1, at_call=2),
    )
    after = frontend.search_many([SearchRequest(q, top_k=1000) for q in queries])
    assert svc.supervisor.recoveries == 1
    assert svc.generation_token != token_before  # fresh epoch on shard 1
    for b, a in zip(before, after):
        # every pre-crash entry is stranded by the token change: a MISS,
        # not a stale hit ...
        assert a.stats.cache_hits == 0 and a.stats.cache_misses == 1
        assert a.stats.recoveries == 1 and a.stats.shards_degraded == 0
        # ... and the recovered state serves the identical fragments
        assert _response_frags(a) == _response_frags(b)
    # the post-recovery entries cached normally under the new token
    warm = frontend.search_many([SearchRequest(q, top_k=1000) for q in queries])
    assert all(r.stats.cache_hits == 1 for r in warm)
