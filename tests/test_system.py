"""End-to-end behaviour tests for the paper's system."""

import numpy as np
import pytest

from repro.core.keys import expand_subqueries
from repro.index import synthesize_corpus
from repro.search.distributed import ShardedSearchService, shard_documents
from repro.search.engine import ALGORITHMS, SearchEngine


@pytest.fixture(scope="module")
def corpus():
    return synthesize_corpus(n_docs=60, doc_len=100, vocab_size=600, seed=5)


def test_engine_end_to_end(small_index):
    eng = SearchEngine(small_index, algorithm="se2.4")
    resp = eng.search("who are you who", top_k=5)
    assert resp.n_subqueries == 2  # [are] and [be] subqueries
    assert resp.docs, "paper example query must hit the injected phrases"
    assert resp.docs[0].score >= resp.docs[-1].score
    for d in resp.docs:
        for f in d.fragments:
            assert 0 <= f.span <= 2 * small_index.max_distance


def test_all_algorithms_agree_on_ranking_heads(small_index):
    """SE2.2/SE2.4 share result semantics; rankings should agree."""
    tops = {}
    for alg in ("se2.2", "se2.4"):
        eng = SearchEngine(small_index, algorithm=alg)
        resp = eng.search("what do you do all day", top_k=3)
        tops[alg] = [d.doc_id for d in resp.docs]
    assert tops["se2.2"] == tops["se2.4"]


def test_sharded_service_equals_single_index(corpus):
    svc = ShardedSearchService(corpus, n_shards=4, sw_count=60, fu_count=150)
    from repro.index import build_indexes

    mono = build_indexes(corpus, sw_count=60, fu_count=150, max_distance=5)
    single = SearchEngine(mono, algorithm="se2.4")
    for q in ["who are you who", "to be or not to be"]:
        a = svc.search(q, top_k=8)
        b = single.search(q, top_k=8)
        assert {d.doc_id for d in a.docs} == {d.doc_id for d in b.docs}
        np.testing.assert_allclose(
            sorted(d.score for d in a.docs), sorted(d.score for d in b.docs),
            rtol=1e-9,
        )


def test_sharded_service_survives_dead_shard(corpus):
    svc = ShardedSearchService(corpus, n_shards=4, sw_count=60, fu_count=150)
    full = svc.search("who are you who", top_k=10_000)
    degraded = svc.search("who are you who", top_k=10_000, dead_shards=[2])
    full_docs = {d.doc_id for d in full.docs}
    deg_docs = {d.doc_id for d in degraded.docs}
    # degraded results = full results minus shard 2's documents
    assert deg_docs <= full_docs
    assert all(doc % 4 != 2 for doc in deg_docs)


def test_shard_documents_partition(corpus):
    shards = shard_documents(corpus, 4)
    assert sum(len(s) for s in shards) == len(corpus)
    for i, s in enumerate(shards):
        assert all(d.doc_id % 4 == i for d in s.documents)


def test_postings_accounting_ordering(small_index, lemmatizer):
    """The paper's headline: multi-key algorithms read far fewer postings
    than the ordinary index, and SE2.4 creates no intermediate records."""
    from repro.core.baselines import se1_ordinary, se23_optimized
    from repro.core.combiner import se24_combiner

    total = {"se1": 0, "se23": 0, "se24": 0, "interm23": 0, "interm24": 0}
    for q in ["who are you who", "the time of war", "to be or not to be"]:
        sub = expand_subqueries(q, lemmatizer)[0]
        _, s1 = se1_ordinary(sub, small_index)
        _, s23 = se23_optimized(sub, small_index)
        _, s24 = se24_combiner(sub, small_index)
        total["se1"] += s1.postings_read
        total["se23"] += s23.postings_read
        total["se24"] += s24.postings_read
        total["interm23"] += s23.intermediate_records
        total["interm24"] += s24.intermediate_records
    assert total["se24"] < total["se1"] / 3
    assert total["interm24"] == 0 and total["interm23"] > 0


def test_serving_step_sharded_host_fallback():
    """serve_step_sharded vmap fallback merges per-shard top-k correctly."""
    import jax.numpy as jnp

    from repro.search.serving_step import serve_step_sharded

    rng = np.random.default_rng(4)
    NS, B, P, C, L, N = 4, 2, 64, 8, 4, 128
    postings = np.full((NS, B, P, 3), -1, np.int32)
    for s in range(NS):
        for b in range(B):
            k = 24
            postings[s, b, :k, 0] = rng.integers(0, C, k)
            postings[s, b, :k, 1] = rng.integers(0, N, k)
            postings[s, b, :k, 2] = rng.integers(0, 2, k)
    cluster_doc = rng.integers(0, 500, (NS, B, C)).astype(np.int32)
    mult = np.tile([1, 1, 0, 0], (B, 1)).astype(np.int32)
    out = serve_step_sharded(
        jnp.asarray(postings), jnp.asarray(cluster_doc), jnp.asarray(mult),
        max_distance=5, n_clusters=C, window_len=N, top_k=8,
    )
    assert out["top_docs"].shape == (B, 8)
    assert out["top_scores"].shape == (B, 8)
    sc = np.asarray(out["top_scores"])
    assert (np.diff(sc, axis=1) <= 1e-9).all()  # sorted descending


def test_build_step_counts_match_bruteforce():
    import jax.numpy as jnp

    from repro.search.serving_step import build_step

    rng = np.random.default_rng(9)
    toks = rng.integers(0, 50, (3, 64)).astype(np.int32)
    stop = toks < 20
    out = build_step(jnp.asarray(toks), jnp.asarray(stop), max_distance=3,
                     n_buckets=256)
    cnt = 0
    D = 3
    for b in range(3):
        for p in range(64):
            if not stop[b, p]:
                continue
            for d1 in range(-D, D + 1):
                for d2 in range(-D, D + 1):
                    if d1 == 0 or d2 == 0 or not d1 < d2:
                        continue
                    if 0 <= p + d1 < 64 and 0 <= p + d2 < 64 and stop[b, p + d1] and stop[b, p + d2]:
                        cnt += 1
    assert int(out["n_postings"]) == cnt
    assert int(out["bucket_histogram"].sum()) == cnt
